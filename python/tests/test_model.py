"""L2 model graph tests: the composed jax functions reproduce a NumPy
implementation of one Algorithm-1 candidate evaluation, and the AOT
lowering emits loadable HLO text.
"""

import numpy as np

import jax
import jax.numpy as jnp

from compile import aot, model

jax.config.update("jax_enable_x64", False)


def _setup(n=48, d=12, m=6, seed=0, nu=0.7):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, d)).astype(np.float32)
    b = rng.standard_normal(n).astype(np.float32)
    sa = rng.standard_normal((m, d)).astype(np.float32) * 0.5
    k = nu * nu * np.eye(m, dtype=np.float32) + sa @ sa.T
    l_factor = np.linalg.cholesky(k).astype(np.float32)
    x = rng.standard_normal(d).astype(np.float32)
    return a, b, sa, l_factor, x, nu


def test_woodbury_apply_inverts_hs():
    a, b, sa, l_factor, x, nu = _setup()
    d = a.shape[1]
    g = np.linspace(-1, 1, d).astype(np.float32)
    nu2 = jnp.asarray([nu * nu], jnp.float32)
    z = np.asarray(model.woodbury_apply(jnp.asarray(sa), jnp.asarray(l_factor), jnp.asarray(g), nu2))
    hs = sa.T @ sa + nu * nu * np.eye(d, dtype=np.float32)
    np.testing.assert_allclose(hs @ z, g, rtol=1e-3, atol=1e-3)


def test_factor_sketch_matches_numpy_cholesky():
    a, b, sa, l_factor, x, nu = _setup()
    nu2 = jnp.asarray([nu * nu], jnp.float32)
    l_jax = np.asarray(model.factor_sketch_jit(jnp.asarray(sa), nu2))
    np.testing.assert_allclose(l_jax, l_factor, rtol=1e-4, atol=1e-4)


def test_ihs_iteration_matches_numpy():
    a, b, sa, l_factor, x, nu = _setup()
    n, d = a.shape
    rng = np.random.default_rng(1)
    x_prev = rng.standard_normal(d).astype(np.float32)
    g = a.T @ (a @ x - b) + nu * nu * x
    hs = sa.T @ sa + nu * nu * np.eye(d, dtype=np.float32)
    g_tilde = np.linalg.solve(hs, g).astype(np.float32)
    mu, beta = 0.8, 0.3

    xp, gp, gtp, rp = model.ihs_iteration_jit(
        jnp.asarray(a), jnp.asarray(b), jnp.asarray([nu * nu], jnp.float32),
        jnp.asarray(sa), jnp.asarray(l_factor),
        jnp.asarray(x), jnp.asarray(x_prev), jnp.asarray(g_tilde),
        jnp.asarray([mu], jnp.float32), jnp.asarray([beta], jnp.float32),
    )

    x_plus = x - mu * g_tilde + beta * (x - x_prev)
    g_plus = a.T @ (a @ x_plus - b) + nu * nu * x_plus
    gt_plus = np.linalg.solve(hs, g_plus)
    r_plus = 0.5 * float(g_plus @ gt_plus)

    np.testing.assert_allclose(np.asarray(xp), x_plus, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gp), g_plus, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(gtp), gt_plus, rtol=2e-3, atol=2e-3)
    assert abs(float(rp) - r_plus) < 2e-3 * max(1.0, abs(r_plus))


def test_srht_sketch_shapes_and_isometry():
    n, d, m = 64, 8, 64
    rng = np.random.default_rng(2)
    a = rng.standard_normal((n, d)).astype(np.float32)
    signs = rng.choice([-1.0, 1.0], size=n).astype(np.float32)
    rows = np.arange(n, dtype=np.int32)  # full transform: exact isometry
    sa = np.asarray(model.srht_sketch_jit(jnp.asarray(a), jnp.asarray(signs), jnp.asarray(rows)))
    assert sa.shape == (m, d)
    np.testing.assert_allclose(sa.T @ sa, a.T @ a, rtol=1e-3, atol=1e-3)


def test_aot_lowering_produces_hlo_text():
    lowered = model.gradient_jit.lower(
        aot.f32(32, 8), aot.f32(8), aot.f32(32), aot.f32(1)
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "parameter" in text.lower()


def test_aot_build_artifacts_covers_all_ops():
    names = [name for name, _ in aot.build_artifacts(64, 16, [4, 8])]
    assert any(n.startswith("gradient_") for n in names)
    for m in (4, 8):
        for op in ("ihs_iteration", "sketch_gaussian", "srht", "factor"):
            assert any(n.startswith(f"{op}_") and n.endswith(f"_m{m}") for n in names), (op, m)
    # m > d artifacts are skipped (Woodbury small-sketch branch only).
    names_big = [name for name, _ in aot.build_artifacts(64, 16, [32])]
    assert all(not n.startswith("ihs_iteration") for n in names_big)
