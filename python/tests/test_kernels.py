"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes (and block sizes, so ragged tiling edges are
exercised) and asserts allclose against ``kernels/ref.py``.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile.kernels import fwht as fwht_k
from compile.kernels import ihs_step as ihs_k
from compile.kernels import ref
from compile.kernels import ridge_gradient as grad_k
from compile.kernels import sketch_matmul as sm_k

jax.config.update("jax_enable_x64", False)

COMMON = dict(max_examples=20, deadline=None)


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32)


# ---------------------------------------------------------------------------
# sketch_matmul
# ---------------------------------------------------------------------------


@settings(**COMMON)
@given(
    m=st.integers(1, 40),
    n=st.integers(1, 60),
    d=st.integers(1, 40),
    bm=st.sampled_from([8, 16, 128]),
    bk=st.sampled_from([8, 32, 128]),
    bd=st.sampled_from([8, 16, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_sketch_matmul_matches_ref(m, n, d, bm, bk, bd, seed):
    rng = np.random.default_rng(seed)
    s = rand(rng, m, n)
    a = rand(rng, n, d)
    got = sm_k.sketch_matmul(s, a, bm=bm, bk=bk, bd=bd)
    want = ref.sketch_matmul(s, a)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_sketch_matmul_identity():
    s = jnp.eye(5, dtype=jnp.float32)
    a = jnp.arange(15, dtype=jnp.float32).reshape(5, 3)
    np.testing.assert_allclose(sm_k.sketch_matmul(s, a), a, atol=1e-6)


# ---------------------------------------------------------------------------
# fwht / srht
# ---------------------------------------------------------------------------


@settings(**COMMON)
@given(
    logn=st.integers(0, 8),
    d=st.integers(1, 20),
    bd=st.sampled_from([4, 16, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fwht_matches_hadamard_matrix(logn, d, bd, seed):
    n = 1 << logn
    rng = np.random.default_rng(seed)
    x = rand(rng, n, d)
    got = fwht_k.fwht(x, bd=bd)
    want = ref.fwht_reference(x)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(**COMMON)
@given(
    logn=st.integers(1, 8),
    d=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_srht_matches_ref(logn, d, seed):
    n = 1 << logn
    rng = np.random.default_rng(seed)
    a = rand(rng, n, d)
    signs = jnp.asarray(rng.choice([-1.0, 1.0], size=n), dtype=jnp.float32)
    m = int(rng.integers(1, n + 1))
    rows = jnp.asarray(rng.choice(n, size=m, replace=False), dtype=jnp.int32)
    got = fwht_k.srht_apply(a, signs, rows, m=m)
    want = ref.srht_apply(a, signs, rows, m)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_fwht_involution():
    rng = np.random.default_rng(0)
    x = rand(rng, 64, 3)
    twice = fwht_k.fwht(fwht_k.fwht(x)) / 64.0
    np.testing.assert_allclose(twice, x, rtol=1e-5, atol=1e-5)


def test_fwht_rejects_non_power_of_two():
    with pytest.raises(AssertionError):
        fwht_k.fwht(jnp.zeros((6, 2), dtype=jnp.float32))


# ---------------------------------------------------------------------------
# ridge_gradient
# ---------------------------------------------------------------------------


@settings(**COMMON)
@given(
    n=st.integers(1, 80),
    d=st.integers(1, 32),
    bn=st.sampled_from([8, 32, 256]),
    nu=st.floats(0.01, 10.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_ridge_gradient_matches_ref(n, d, bn, nu, seed):
    rng = np.random.default_rng(seed)
    a = rand(rng, n, d)
    x = rand(rng, d)
    b = rand(rng, n)
    nu2 = jnp.asarray([nu * nu], dtype=jnp.float32)
    got = grad_k.ridge_gradient(a, x, b, nu2, bn=bn)
    want = ref.ridge_gradient(a, x, b, jnp.float32(nu))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_gradient_zero_at_optimum():
    # x* = (A^T A + nu^2 I)^{-1} A^T b  =>  kernel gradient ~ 0.
    rng = np.random.default_rng(1)
    a = rand(rng, 40, 8)
    b = rand(rng, 40)
    nu = 0.5
    h = a.T @ a + nu * nu * jnp.eye(8)
    x_star = jnp.linalg.solve(h, a.T @ b)
    g = grad_k.ridge_gradient(a, x_star, b, jnp.asarray([nu * nu], jnp.float32))
    assert float(jnp.linalg.norm(g)) < 1e-4


# ---------------------------------------------------------------------------
# ihs_update
# ---------------------------------------------------------------------------


@settings(**COMMON)
@given(
    d=st.integers(1, 200),
    mu=st.floats(0.0, 2.0),
    beta=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_ihs_update_matches_ref(d, mu, beta, seed):
    rng = np.random.default_rng(seed)
    x, xp, gt = rand(rng, d), rand(rng, d), rand(rng, d)
    mu_a = jnp.asarray([mu], jnp.float32)
    beta_a = jnp.asarray([beta], jnp.float32)
    got = ihs_k.ihs_update(x, xp, gt, mu_a, beta_a)
    want = ref.ihs_update(x, xp, gt, jnp.float32(mu), jnp.float32(beta))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_ihs_update_zero_step_is_identity():
    x = jnp.arange(7, dtype=jnp.float32)
    z = jnp.asarray([0.0], jnp.float32)
    got = ihs_k.ihs_update(x, x, x, z, z)
    np.testing.assert_allclose(got, x, atol=0)
