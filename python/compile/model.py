"""L2: the paper's compute graph in JAX, composed from the L1 kernels.

Everything here is build-time only. ``aot.py`` lowers these jitted
functions to HLO text; the Rust runtime (rust/src/runtime/) loads and
executes them via PJRT. Python never runs on the solve path.

The graph mirrors one iteration of Algorithm 1:

* ``gradient``          — fused ridge gradient (L1 kernel)
* ``ihs_iteration``     — heavy-ball candidate + gradient + Woodbury
                          preconditioning + sketched Newton decrement, as a
                          single fused module (one PJRT dispatch per
                          candidate evaluation)
* ``sketch_gaussian``   — tiled S @ A (L1 kernel)
* ``srht_sketch``       — sign flip + Pallas FWHT + row gather (L1 kernel)
"""

import jax
import jax.numpy as jnp
import jax.scipy.linalg as jsl

from .kernels import fwht as fwht_k
from .kernels import ihs_step as ihs_k
from .kernels import ridge_gradient as grad_k
from .kernels import sketch_matmul as sm_k


def gradient(a, x, b, nu2):
    """``∇f(x) = A^T (A x - b) + nu^2 x`` — L1 fused kernel."""
    return grad_k.ridge_gradient(a, x, b, nu2)


def woodbury_apply(sa, l_factor, g, nu2):
    """``H_S^{-1} g`` from the cached Cholesky factor of
    ``K = nu^2 I_m + SA SA^T`` (small-sketch branch, m <= d)."""
    sag = sa @ g
    y = jsl.solve_triangular(l_factor, sag, lower=True)
    kinv_sag = jsl.solve_triangular(l_factor.T, y, lower=False)
    return (g - sa.T @ kinv_sag) / nu2[0]


def newton_decrement(g, g_tilde):
    """Lemma 1: ``r = 1/2 g^T H_S^{-1} g``."""
    return 0.5 * jnp.vdot(g, g_tilde)


def ihs_iteration(a, b, nu2, sa, l_factor, x, x_prev, g_tilde, mu, beta):
    """One full candidate evaluation of Algorithm 1 (steps 4 / 9).

    Returns ``(x_plus, g_plus, g_tilde_plus, r_plus)``. With ``beta = 0``
    this is the gradient-IHS candidate; otherwise the Polyak one.
    """
    x_plus = ihs_k.ihs_update(x, x_prev, g_tilde, mu, beta)
    g_plus = gradient(a, x_plus, b, nu2)
    g_tilde_plus = woodbury_apply(sa, l_factor, g_plus, nu2)
    r_plus = newton_decrement(g_plus, g_tilde_plus)
    return x_plus, g_plus, g_tilde_plus, r_plus


def sketch_gaussian(s, a):
    """``S @ A`` — L1 tiled-GEMM kernel."""
    return sm_k.sketch_matmul(s, a)


def srht_sketch(a, signs, rows):
    """SRHT ``S A`` — sign flip + Pallas FWHT + gather."""
    m = rows.shape[0]
    return fwht_k.srht_apply(a, signs, rows, m=m)


def factor_sketch(sa, nu2):
    """Cholesky factor of ``K = nu^2 I_m + SA SA^T`` — runs once per sketch
    change; emitted as its own artifact so Rust can refactor on doubling
    without leaving PJRT."""
    m = sa.shape[0]
    k = nu2[0] * jnp.eye(m, dtype=sa.dtype) + sa @ sa.T
    return jnp.linalg.cholesky(k)


# ---------------------------------------------------------------------------
# jit wrappers with the exact signatures the AOT step lowers.
# ---------------------------------------------------------------------------

gradient_jit = jax.jit(gradient)
ihs_iteration_jit = jax.jit(ihs_iteration)
sketch_gaussian_jit = jax.jit(sketch_gaussian)
srht_sketch_jit = jax.jit(srht_sketch)
factor_sketch_jit = jax.jit(factor_sketch)
