"""AOT lowering: jitted L2 functions -> HLO *text* artifacts for Rust/PJRT.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
(the version the published ``xla`` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly.

Usage::

    python -m compile.aot --out-dir ../artifacts [--n 4096] [--d 256] \
        [--m 16,64,256] [--bn 256]

Emits one ``<op>.hlo.txt`` per (op, shape) plus ``manifest.json``
describing every artifact (op, input shapes, dtype) for the Rust artifact
registry.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def build_artifacts(n: int, d: int, m_list: list[int]):
    """Yield (name, lowered) for every artifact of the configured shapes."""
    # Per-iteration hot path: the fused gradient.
    yield (
        f"gradient_n{n}_d{d}",
        model.gradient_jit.lower(f32(n, d), f32(d), f32(n), f32(1)),
    )
    # Full candidate evaluation + sketch/factor ops, one per sketch size.
    for m in m_list:
        if m > d:
            # The small-sketch Woodbury artifact only applies for m <= d;
            # larger sketches fall back to the native direct branch.
            continue
        yield (
            f"ihs_iteration_n{n}_d{d}_m{m}",
            model.ihs_iteration_jit.lower(
                f32(n, d), f32(n), f32(1), f32(m, d), f32(m, m),
                f32(d), f32(d), f32(d), f32(1), f32(1),
            ),
        )
        yield (
            f"sketch_gaussian_n{n}_d{d}_m{m}",
            model.sketch_gaussian_jit.lower(f32(m, n), f32(n, d)),
        )
        yield (
            f"srht_n{n}_d{d}_m{m}",
            model.srht_sketch_jit.lower(f32(n, d), f32(n), i32(m)),
        )
        yield (
            f"factor_n{n}_d{d}_m{m}",
            model.factor_sketch_jit.lower(f32(m, d), f32(1)),
        )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--d", type=int, default=256)
    ap.add_argument(
        "--m",
        default="16,64,256",
        help="comma-separated sketch sizes to specialize (power-of-two "
        "doubling grid of the adaptive solver)",
    )
    args = ap.parse_args()
    m_list = [int(x) for x in args.m.split(",") if x]

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"n": args.n, "d": args.d, "m_list": m_list, "artifacts": []}
    for name, lowered in build_artifacts(args.n, args.d, m_list):
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append({"name": name, "file": f"{name}.hlo.txt", "bytes": len(text)})
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"manifest: {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
