"""Pure-jnp oracles for every L1 Pallas kernel.

These are the correctness ground truth: ``python/tests/`` sweeps shapes and
dtypes with hypothesis and asserts each Pallas kernel matches its oracle to
float tolerance. They are also, deliberately, the *simplest possible*
spelling of each operation so a reviewer can audit the math in seconds.
"""

import jax.numpy as jnp


def sketch_matmul(s, a):
    """Dense sketch application: ``S @ A``."""
    return jnp.dot(s, a, preferred_element_type=jnp.float32)


def ridge_gradient(a, x, b, nu):
    """Ridge gradient ``A^T (A x - b) + nu^2 x``."""
    r = a @ x - b
    return a.T @ r + (nu * nu) * x


def fwht(v):
    """Unnormalized fast Walsh-Hadamard transform along axis 0.

    ``v``: (n, d) with n a power of two. O(n log n) butterflies.
    """
    n = v.shape[0]
    assert n & (n - 1) == 0, "FWHT needs power-of-two leading dim"
    tail = v.shape[1:]
    h = 1
    while h < n:
        v = v.reshape(n // (2 * h), 2, h, *tail)
        u = v[:, 0] + v[:, 1]
        w = v[:, 0] - v[:, 1]
        v = jnp.concatenate([u[:, None], w[:, None]], axis=1).reshape(n, *tail)
        h *= 2
    return v


def fwht_reference(v):
    """FWHT via the explicit Hadamard matrix — O(n^2), tiny-n oracle."""
    n = v.shape[0]
    h = jnp.array([[1.0]], dtype=v.dtype)
    while h.shape[0] < n:
        h = jnp.block([[h, h], [h, -h]])
    return h @ v


def srht_apply(a, signs, rows, m):
    """SRHT ``S A``: sign-flip rows, FWHT, select ``rows``, scale 1/sqrt(m).

    ``a``: (n, d) with n a power of two (pre-padded); ``signs``: (n,);
    ``rows``: (m,) int32 indices into the transformed rows.
    """
    v = a * signs[:, None]
    v = fwht(v)
    return v[rows] * (1.0 / jnp.sqrt(m))


def ihs_update(x, x_prev, g_tilde, mu, beta):
    """Heavy-ball update ``x - mu * g_tilde + beta * (x - x_prev)``."""
    return x - mu * g_tilde + beta * (x - x_prev)


def woodbury_apply(sa, l_factor, g, nu):
    """``H_S^{-1} g`` with cached Cholesky ``L L^T = nu^2 I + SA SA^T``:
    ``(1/nu^2) (g - SA^T K^{-1} SA g)`` via two triangular solves.
    """
    import jax.scipy.linalg as jsl

    sag = sa @ g
    y = jsl.solve_triangular(l_factor, sag, lower=True)
    kinv_sag = jsl.solve_triangular(l_factor.T, y, lower=False)
    return (g - sa.T @ kinv_sag) / (nu * nu)


def newton_decrement(g, g_tilde):
    """Sketched Newton decrement ``r = 1/2 g^T H_S^{-1} g`` (Lemma 1)."""
    return 0.5 * jnp.vdot(g, g_tilde)
