"""L1 Pallas kernel: fused heavy-ball update
``x^+ = x - mu * g_tilde + beta * (x - x_prev)``.

A pure-VPU elementwise fusion: one pass over three length-``d`` vectors.
On TPU this avoids three separate HBM-bound elementwise launches; here it
demonstrates the scalar-parameter plumbing (``mu``/``beta`` arrive as
(1,)-arrays so one AOT artifact serves any step-size schedule).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ihs_update_kernel(x_ref, xp_ref, gt_ref, mu_ref, beta_ref, o_ref):
    x = x_ref[...]
    o_ref[...] = x - mu_ref[0] * gt_ref[...] + beta_ref[0] * (x - xp_ref[...])


@functools.partial(jax.jit, static_argnames=("bd",))
def ihs_update(x, x_prev, g_tilde, mu, beta, *, bd=1024):
    """Heavy-ball update; ``mu``/``beta`` are (1,) arrays."""
    (d,) = x.shape
    bd = min(bd, d)
    grid = (pl.cdiv(d, bd),)
    return pl.pallas_call(
        _ihs_update_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bd,), lambda i: (i,)),
            pl.BlockSpec((bd,), lambda i: (i,)),
            pl.BlockSpec((bd,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bd,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d,), jnp.float32),
        interpret=True,
    )(x, x_prev, g_tilde, mu, beta)
