"""L1 Pallas kernel: fast Walsh–Hadamard transform over the row dimension.

This is the compute core of the SRHT (paper §3.2). TPU mapping
(DESIGN.md §Hardware-Adaptation): the CPU-style recursive FWHT becomes a
*stage-unrolled, column-tiled* kernel — the grid splits the `d` columns
into VMEM-sized tiles, each grid step keeps its whole `(n, bd)` panel
VMEM-resident and runs all `log2(n)` butterfly stages in-register as
reshape/add/sub (pure VPU work, no MXU). The butterflies at stage `h` are
contiguous vector ops of width `bd`, exactly the layout the paper's
`O(nd log n)` bound wants.

VMEM budget: one `(n, bd)` f32 panel; with n = 8192 and bd = 256 that is
8 MiB — comfortably under the ~16 MiB/core budget with double-buffering
disabled (the panel is both input and output of the stage loop).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwht_kernel(x_ref, o_ref):
    """Full log2(n)-stage butterfly on a VMEM-resident (n, bd) panel."""
    v = x_ref[...]
    n = v.shape[0]
    tail = v.shape[1:]
    h = 1
    # Stage loop is static (n is a compile-time shape), so it unrolls into
    # log2(n) fused reshape/add/sub layers.
    while h < n:
        v = v.reshape(n // (2 * h), 2, h, *tail)
        u = v[:, 0] + v[:, 1]
        w = v[:, 0] - v[:, 1]
        v = jnp.concatenate([u[:, None], w[:, None]], axis=1).reshape(n, *tail)
        h *= 2
    o_ref[...] = v


@functools.partial(jax.jit, static_argnames=("bd",))
def fwht(x, *, bd=256):
    """Unnormalized FWHT along axis 0 of ``x``: (n, d), n a power of two."""
    n, d = x.shape
    assert n & (n - 1) == 0, f"FWHT needs power-of-two rows, got {n}"
    bd = min(bd, d)
    grid = (pl.cdiv(d, bd),)
    return pl.pallas_call(
        _fwht_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((n, bd), lambda j: (0, j))],
        out_specs=pl.BlockSpec((n, bd), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=True,
    )(x)


@functools.partial(jax.jit, static_argnames=("m", "bd"))
def srht_apply(a, signs, rows, *, m, bd=256):
    """Full SRHT ``S A`` pipeline: sign flip -> Pallas FWHT -> row gather.

    ``a``: (n, d) pre-padded to power-of-two n; ``signs``: (n,) Rademacher;
    ``rows``: (m,) int32 indices. The gather stays in XLA (dynamic-slice
    lowering); the O(nd log n) transform is the Pallas kernel.
    """
    v = a * signs[:, None]
    v = fwht(v, bd=bd)
    return v[rows] * (1.0 / jnp.sqrt(jnp.float32(m)))


def vmem_footprint_bytes(n, bd=256, dtype_bytes=4):
    """Panel residency for one grid step."""
    return dtype_bytes * n * bd
