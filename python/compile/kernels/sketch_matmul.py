"""L1 Pallas kernel: tiled sketch application ``S @ A``.

TPU mapping (DESIGN.md §Hardware-Adaptation): the Gaussian sketch is a
plain GEMM, so the kernel is a classic three-level tiling —
``(bm x bk) @ (bk x bd)`` tiles stream HBM -> VMEM under the BlockSpec
index maps and feed the MXU via ``jnp.dot`` with
``preferred_element_type=float32``; the output tile stays VMEM-resident
across the contraction (k) grid dimension and is accumulated in place.
Block sizes default to the MXU-native 128 and are clamped to the problem,
so the same kernel serves both unit-test shapes and the production
(8192 x 1024) workload.

``interpret=True`` everywhere: the CPU PJRT runtime cannot execute Mosaic
custom-calls; structure (not wallclock) is what we optimize here.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(s_ref, a_ref, o_ref, *, n_total, bk):
    """One (i, j, k) grid step: o[i,j] += S[i,k] @ A[k,j].

    The final k-tile may overhang the contraction dimension; Pallas pads
    out-of-bounds reads (with NaN in interpret mode), so the overhang is
    masked to zero before it enters the dot — contraction padding is the
    one place tile raggedness is *not* automatically safe.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    valid = n_total - k * bk  # how many contraction rows are real
    lane = jax.lax.broadcasted_iota(jnp.int32, (bk, 1), 0)
    a_tile = jnp.where(lane < valid, a_ref[...], 0.0)
    s_tile = jnp.where(lane.T < valid, s_ref[...], 0.0)
    o_ref[...] += jnp.dot(s_tile, a_tile, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bd"))
def sketch_matmul(s, a, *, bm=128, bk=128, bd=128):
    """Compute ``S @ A`` with a tiled Pallas kernel.

    ``s``: (m, n), ``a``: (n, d). Dimensions need not be multiples of the
    block sizes; Pallas masks the ragged edges.
    """
    m, n = s.shape
    n2, d = a.shape
    assert n == n2, f"inner dims mismatch: {n} vs {n2}"
    bm, bk, bd = min(bm, m), min(bk, n), min(bd, d)
    grid = (pl.cdiv(m, bm), pl.cdiv(d, bd), pl.cdiv(n, bk))
    kernel = functools.partial(_matmul_kernel, n_total=n, bk=bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bd), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bd), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, d), jnp.float32),
        interpret=True,
    )(s, a)


def vmem_footprint_bytes(bm=128, bk=128, bd=128, dtype_bytes=4):
    """Estimated VMEM residency per grid step: S-tile + A-tile + out-tile."""
    return dtype_bytes * (bm * bk + bk * bd + bm * bd)
