"""L1 Pallas kernel: fused ridge gradient ``A^T (A x - b) + nu^2 x``.

This is the per-iteration hot spot of every solver in the paper (O(nd),
executed hundreds of times per solve). TPU mapping (DESIGN.md
§Hardware-Adaptation): the two GEMVs are fused into a single pass over
row-panels of ``A`` — each grid step loads one ``(bn, d)`` panel, computes
the residual slice ``r = A_i x - b_i`` *and* immediately accumulates
``A_i^T r`` into the VMEM-resident output, so the length-``n`` residual is
never materialized in HBM (a CPU/GPU implementation writes it out and
reads it back; on TPU that round-trip is pure HBM bandwidth waste).

The ``nu^2 x`` term seeds the accumulator at grid step 0.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gradient_kernel(a_ref, x_ref, b_ref, nu2_ref, o_ref, *, n_total, bn):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = nu2_ref[0] * x_ref[...]

    # Mask the ragged final row-panel: Pallas pads out-of-bounds reads
    # (NaN in interpret mode) and those rows would pollute the A^T r
    # reduction.
    valid = n_total - i * bn
    row = jax.lax.broadcasted_iota(jnp.int32, (bn, 1), 0)
    a_tile = jnp.where(row < valid, a_ref[...], 0.0)  # (bn, d) panel
    b_tile = jnp.where(row[:, 0] < valid, b_ref[...], 0.0)
    x = x_ref[...]               # (d,)
    r = a_tile @ x - b_tile      # (bn,) residual slice — VMEM only
    o_ref[...] += a_tile.T @ r


@functools.partial(jax.jit, static_argnames=("bn",))
def ridge_gradient(a, x, b, nu2, *, bn=256):
    """Fused gradient. ``a``: (n, d); ``x``: (d,); ``b``: (n,);
    ``nu2``: (1,) array holding nu^2 (runtime input so one artifact serves
    the whole regularization path)."""
    n, d = a.shape
    bn = min(bn, n)
    grid = (pl.cdiv(n, bn),)
    kernel = functools.partial(_gradient_kernel, n_total=n, bn=bn)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((d,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((d,), jnp.float32),
        interpret=True,
    )(a, x, b, nu2)


def vmem_footprint_bytes(d, bn=256, dtype_bytes=4):
    """Panel + vectors resident per grid step."""
    return dtype_bytes * (bn * d + 2 * d + bn + 1)
