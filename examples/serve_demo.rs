//! Coordinator-as-a-service demo: start the TCP server in-process, drive
//! it with the line-JSON client, print metrics.
//!
//! ```sh
//! cargo run --release --example serve_demo
//! ```

use effdim::coordinator::server::{Client, Server};
use std::sync::atomic::Ordering;

fn main() {
    let server = Server::bind("127.0.0.1:0", 2).expect("bind");
    let addr = server.local_addr();
    let stop = server.stop_handle();
    println!("coordinator listening on {addr}");
    let handle = std::thread::spawn(move || server.run());

    let mut client = Client::connect(addr).expect("connect");

    // Discover the solver registry over the wire — every "spec" string
    // below is a valid "solver" field for a solve request.
    let listing = client.call(r#"{"cmd":"solvers"}"#).expect("solvers request");
    let solvers = listing.get("solvers").unwrap().as_arr().unwrap();
    println!("server advertises {} solvers:", solvers.len());
    for entry in solvers {
        println!(
            "  {:<26} {}",
            entry.get("spec").unwrap().as_str().unwrap(),
            entry.get("description").unwrap().as_str().unwrap()
        );
    }
    println!();

    // Submit a small batch of heterogeneous solves.
    let mut jobs = Vec::new();
    for (profile, solver, nu) in [
        ("mnist-like", "adaptive-srht", 1.0),
        ("cifar-like", "adaptive-gd-srht", 0.1),
        ("exp", "cg", 1.0),
        ("poly", "pcg-srht", 0.5),
        ("exp", "ihs-gaussian@m=64", 1.0),
    ] {
        let req = format!(
            r#"{{"cmd":"solve","profile":"{profile}","n":512,"d":64,"nu":{nu},"solver":"{solver}","eps":1e-8,"seed":5}}"#
        );
        let resp = client.call(&req).expect("solve request");
        assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(true), "{resp:?}");
        let job = resp.get("job").unwrap().as_usize().unwrap();
        println!("submitted {profile}/{solver} as job {job}");
        jobs.push(job);
    }

    // Wait for each and print the result lines.
    for job in jobs {
        let resp = client
            .call(&format!(r#"{{"cmd":"wait","job":{job},"timeout_s":120}}"#))
            .expect("wait");
        let state = resp.get("state").unwrap().as_str().unwrap().to_string();
        let result = resp.get("result");
        match (state.as_str(), result) {
            ("done", Some(r)) => println!(
                "job {job}: {} iters={} m={} time={:.3}s converged={}",
                r.get("solver").unwrap().as_str().unwrap(),
                r.get("iterations").unwrap().as_usize().unwrap(),
                r.get("peak_m").unwrap().as_usize().unwrap(),
                r.get("wall_time_s").unwrap().as_f64().unwrap(),
                r.get("converged").unwrap().as_bool().unwrap(),
            ),
            other => panic!("job {job} unexpected state {other:?}"),
        }
    }

    let metrics = client.call(r#"{"cmd":"metrics"}"#).expect("metrics");
    println!("\nmetrics: {}", metrics.get("metrics").unwrap().to_string());

    stop.store(true, Ordering::SeqCst);
    handle.join().unwrap();
    println!("server stopped cleanly");
}
