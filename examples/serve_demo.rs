//! Coordinator-as-a-service demo: start the TCP server in-process, drive
//! it with the line-JSON client — batch solve jobs first, then the model
//! registry (register once, query many times against cached
//! sketch/factorization state) — and print metrics.
//!
//! ```sh
//! cargo run --release --example serve_demo
//! ```

use effdim::coordinator::server::{Client, Server};
use std::sync::atomic::Ordering;

fn main() {
    let server = Server::bind("127.0.0.1:0", 2).expect("bind");
    let addr = server.local_addr();
    let stop = server.stop_handle();
    println!("coordinator listening on {addr}");
    let handle = std::thread::spawn(move || server.run());

    let mut client = Client::connect(addr).expect("connect");

    // Discover the solver registry over the wire — every "spec" string
    // below is a valid "solver" field for a solve request.
    let listing = client.call(r#"{"cmd":"solvers"}"#).expect("solvers request");
    let solvers = listing.get("solvers").unwrap().as_arr().unwrap();
    println!("server advertises {} solvers:", solvers.len());
    for entry in solvers {
        println!(
            "  {:<26} {}",
            entry.get("spec").unwrap().as_str().unwrap(),
            entry.get("description").unwrap().as_str().unwrap()
        );
    }
    println!();

    // Submit a small batch of heterogeneous solves.
    let mut jobs = Vec::new();
    for (profile, solver, nu) in [
        ("mnist-like", "adaptive-srht", 1.0),
        ("cifar-like", "adaptive-gd-srht", 0.1),
        ("exp", "cg", 1.0),
        ("poly", "pcg-srht", 0.5),
        ("exp", "ihs-gaussian@m=64", 1.0),
    ] {
        let req = format!(
            r#"{{"cmd":"solve","profile":"{profile}","n":512,"d":64,"nu":{nu},"solver":"{solver}","eps":1e-8,"seed":5}}"#
        );
        let resp = client.call(&req).expect("solve request");
        assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(true), "{resp:?}");
        let job = resp.get("job").unwrap().as_usize().unwrap();
        println!("submitted {profile}/{solver} as job {job}");
        jobs.push(job);
    }

    // Wait for each and print the result lines.
    for job in jobs {
        let resp = client
            .call(&format!(r#"{{"cmd":"wait","job":{job},"timeout_s":120}}"#))
            .expect("wait");
        let state = resp.get("state").unwrap().as_str().unwrap().to_string();
        let result = resp.get("result");
        match (state.as_str(), result) {
            ("done", Some(r)) => println!(
                "job {job}: {} iters={} m={} time={:.3}s converged={}",
                r.get("solver").unwrap().as_str().unwrap(),
                r.get("iterations").unwrap().as_usize().unwrap(),
                r.get("peak_m").unwrap().as_usize().unwrap(),
                r.get("wall_time_s").unwrap().as_f64().unwrap(),
                r.get("converged").unwrap().as_bool().unwrap(),
            ),
            other => panic!("job {job} unexpected state {other:?}"),
        }
    }

    // --- Model registry: register once, query many times -------------
    // The registered model keeps its operand, grown sketch and
    // factorization server-side; every query below reuses them.
    let reg = client
        .call(r#"{"cmd":"register","profile":"exp","n":1024,"d":128,"seed":7,"sketch":"srht","name":"exp-1k"}"#)
        .expect("register");
    assert_eq!(reg.get("ok").and_then(|v| v.as_bool()), Some(true), "{reg:?}");
    let model = reg.get("model").unwrap().as_usize().unwrap();
    println!(
        "\nregistered model {model} ({} x {}, {} bytes of state)",
        reg.get("n").unwrap().as_usize().unwrap(),
        reg.get("d").unwrap().as_usize().unwrap(),
        reg.get("bytes").unwrap().as_usize().unwrap(),
    );

    // Repeat queries at different regularization levels: the first (at
    // the smallest nu, the largest effective dimension) grows the
    // sketch; the later, larger-nu queries reuse it outright — watch
    // sketch_time_s drop to 0. The final query repeats nu=0.3 exactly
    // and is served from the solution cache (it replays the first
    // nu=0.3 report verbatim, time buckets included).
    for nu in [0.1, 0.3, 1.0, 0.3] {
        let resp = client
            .call(&format!(r#"{{"cmd":"query","model":{model},"nu":{nu},"eps":1e-8}}"#))
            .expect("query");
        assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(true), "{resp:?}");
        let r = resp.get("result").unwrap();
        println!(
            "query nu={nu:<4} iters={:<3} m={:<4} sketch_time={:.4}s wall={:.4}s",
            r.get("iterations").unwrap().as_usize().unwrap(),
            resp.get("m").unwrap().as_usize().unwrap(),
            r.get("sketch_time_s").unwrap().as_f64().unwrap(),
            r.get("wall_time_s").unwrap().as_f64().unwrap(),
        );
    }

    // Batched regularization path + prediction on a new row.
    let path = client
        .call(&format!(r#"{{"cmd":"query","model":{model},"nus":[10,1,0.1],"eps":1e-8}}"#))
        .expect("path query");
    println!("path points: {}", path.get("path").unwrap().as_arr().unwrap().len());
    let row: Vec<String> = (0..128).map(|j| format!("{:.3}", (j as f64 * 0.05).sin())).collect();
    let pred = client
        .call(&format!(
            r#"{{"cmd":"predict","model":{model},"nu":0.1,"rows":[[{}]]}}"#,
            row.join(",")
        ))
        .expect("predict");
    println!("prediction at nu=0.1: {}", pred.get("y").unwrap().to_string());

    let listing = client.call(r#"{"cmd":"models"}"#).expect("models");
    println!("models: {}", listing.get("models").unwrap().to_string());

    let metrics = client.call(r#"{"cmd":"metrics"}"#).expect("metrics");
    println!("\nmetrics: {}", metrics.get("metrics").unwrap().to_string());
    println!("registry: {}", metrics.get("registry").unwrap().to_string());

    stop.store(true, Ordering::SeqCst);
    handle.join().unwrap();
    println!("server stopped cleanly");
}
