//! Quickstart: solve one ridge problem through the unified solver API.
//!
//! Pick any solver by its spec string — `"cg"`, `"pcg-gaussian"`,
//! `"adaptive-srht"`, `"ihs-sparse@m=256"`, ... — build it with a seed,
//! and call `solve`. `effdim solvers` (or `effdim::solvers::registry()`)
//! lists every available spec.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use effdim::data::synthetic;
use effdim::solvers::{direct, RidgeProblem, Solver as _, SolverSpec, StopRule};

fn main() {
    // A synthetic overdetermined problem with fast spectral decay
    // (sigma_j = 0.95^j), the regime where d_e << d.
    let ds = synthetic::exponential_decay(2048, 256, 42);
    let nu = 0.1;
    let problem = RidgeProblem::new(ds.a.clone(), ds.b.clone(), nu);

    println!("problem: n = {}, d = {}, nu = {}", problem.n(), problem.d(), nu);
    println!("effective dimension d_e = {:.1} (of d = {})", ds.effective_dimension(nu), ds.d());

    // Ground truth for the error metric (the paper's experimental
    // protocol measures against the exact solution).
    let x_star = direct::solve(&problem);
    let stop = StopRule::TrueError { x_star, eps: 1e-10 };

    // Algorithm 1 by name: starts at m = 1, grows only as needed. Swap
    // the string for "cg", "pcg-srht", "ihs-gaussian@m=64", ... — the
    // rest of the program does not change.
    let spec: SolverSpec = "adaptive-srht".parse().expect("valid solver spec");
    let solver = spec.build(7);
    println!(
        "solver '{spec}': warm-start={}, randomized={}",
        solver.supports_warm_start(),
        solver.is_randomized()
    );
    let solution = solver.solve(&problem, &vec![0.0; problem.d()], &stop);

    let r = &solution.report;
    println!("\nsolver          : {}", r.solver);
    println!("converged       : {}", r.converged);
    println!("iterations      : {}", r.iterations);
    println!("rejected steps  : {}", r.rejections);
    println!("sketch doublings: {}", r.doublings);
    println!("final sketch m  : {} (vs d = {})", r.final_m, problem.d());
    println!("rel. error      : {:.2e}", r.final_rel_error.unwrap_or(f64::NAN));
    println!(
        "time            : {:.3}s (sketch {:.3}s, factor {:.3}s, iterate {:.3}s)",
        r.wall_time_s, r.sketch_time_s, r.factor_time_s, r.iter_time_s
    );
    assert!(r.converged, "quickstart must converge");
}
