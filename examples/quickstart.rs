//! Quickstart: solve one ridge problem with the adaptive sketching solver.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use effdim::data::synthetic;
use effdim::sketch::SketchKind;
use effdim::solvers::adaptive::{solve, AdaptiveConfig};
use effdim::solvers::{direct, RidgeProblem, StopRule};

fn main() {
    // A synthetic overdetermined problem with fast spectral decay
    // (sigma_j = 0.95^j), the regime where d_e << d.
    let ds = synthetic::exponential_decay(2048, 256, 42);
    let nu = 0.1;
    let problem = RidgeProblem::new(ds.a.clone(), ds.b.clone(), nu);

    println!("problem: n = {}, d = {}, nu = {}", problem.n(), problem.d(), nu);
    println!("effective dimension d_e = {:.1} (of d = {})", ds.effective_dimension(nu), ds.d());

    // Ground truth for the error metric (the paper's experimental
    // protocol measures against the exact solution).
    let x_star = direct::solve(&problem);
    let stop = StopRule::TrueError { x_star, eps: 1e-10 };

    // Algorithm 1: starts at m = 1, grows only as needed.
    let config = AdaptiveConfig::new(SketchKind::Srht, stop);
    let solution = solve(&problem, &vec![0.0; problem.d()], &config, 7);

    let r = &solution.report;
    println!("\nsolver          : {}", r.solver);
    println!("converged       : {}", r.converged);
    println!("iterations      : {}", r.iterations);
    println!("rejected steps  : {}", r.rejections);
    println!("sketch doublings: {}", r.doublings);
    println!("final sketch m  : {} (vs d = {})", r.final_m, problem.d());
    println!("rel. error      : {:.2e}", r.final_rel_error.unwrap_or(f64::NAN));
    println!(
        "time            : {:.3}s (sketch {:.3}s, factor {:.3}s, iterate {:.3}s)",
        r.wall_time_s, r.sketch_time_s, r.factor_time_s, r.iter_time_s
    );
    assert!(r.converged, "quickstart must converge");
}
