//! END-TO-END DRIVER — proves all three layers compose on a real workload.
//!
//! Pipeline exercised:
//!   L1 (Pallas kernels) + L2 (JAX graph)  --AOT-->  artifacts/*.hlo.txt
//!   L3 (this binary): loads the fused-gradient artifact via PJRT,
//!   plugs it into Algorithm 1 as the per-iteration gradient oracle, and
//!   solves a CIFAR-like regularized least-squares workload end to end,
//!   then cross-checks against the pure-native solve and runs the same
//!   job through the coordinator service.
//!
//! Run `make artifacts` first (shape n=4096, d=256 by default):
//! ```sh
//! make artifacts && cargo run --release --example end_to_end
//! ```
//! Falls back to the native oracle (with a notice) if artifacts are
//! missing, so the example always demonstrates the full solve.

use effdim::coordinator::job::{execute, JobSpec, Workload};
use effdim::data::synthetic;
use effdim::runtime::GradientOracle;
use effdim::sketch::SketchKind;
use effdim::solvers::adaptive::{AdaptiveConfig, AdaptiveSolver};
use effdim::solvers::{direct, RidgeProblem, StopRule};

fn main() {
    // Shape must match the AOT artifacts (python -m compile.aot --n --d).
    let (n, d) = (4096, 256);
    let nu = 1.0;
    let ds = synthetic::cifar_like(n, d, 2026);
    let problem = RidgeProblem::new(ds.a.clone(), ds.b.clone(), nu);
    let d_e = ds.effective_dimension(nu);
    println!("=== end-to-end: adaptive IHS on {} (n={n}, d={d}, nu={nu}) ===", ds.name);
    println!("effective dimension d_e = {d_e:.1}  (d_e/d = {:.3})", d_e / d as f64);

    let x_star = direct::solve(&problem);

    // --- native solve (f64 reference) ---
    let stop_native = StopRule::TrueError { x_star: x_star.clone(), eps: 1e-10 };
    let cfg = AdaptiveConfig::new(SketchKind::Srht);
    let native = AdaptiveSolver::new(&problem, &vec![0.0; d], cfg.clone(), stop_native, 404).run();
    report("native (f64)", &native.report);

    // --- PJRT-backed solve: the AOT fused-gradient artifact is the hot op ---
    #[cfg(feature = "xla-runtime")]
    {
        match effdim::runtime::PjrtRuntime::load(effdim::runtime::DEFAULT_ARTIFACTS_DIR) {
            Err(e) => println!("\n[artifacts unavailable: {e}]\n[skipping PJRT-backed solve]"),
            Ok(runtime) => match runtime.gradient_oracle(&problem) {
                Err(e) => println!("\n[gradient artifact unavailable: {e}]"),
                Ok(oracle) => {
                    // f32 artifacts cap achievable relative error ~1e-6.
                    let stop = StopRule::TrueError { x_star: x_star.clone(), eps: 1e-5 };
                    let cfg_xla = AdaptiveConfig::new(SketchKind::Srht);
                    let mut solver =
                        AdaptiveSolver::new(&problem, &vec![0.0; d], cfg_xla, stop, 404);
                    solver.set_gradient_fn(|x| oracle.gradient(x));
                    let sol = solver.run();
                    report("pjrt-xla (f32 AOT gradient)", &sol.report);
                    assert!(sol.report.converged, "XLA-backed solve must converge");

                    // Conformance: XLA and native gradients agree to f32.
                    let x_test: Vec<f64> = (0..d).map(|i| (i as f64 * 0.01).sin()).collect();
                    let g_native = problem.gradient(&x_test);
                    let g_xla = oracle.gradient(&x_test);
                    let scale = g_native.iter().map(|v| v.abs()).fold(0.0, f64::max);
                    let max_diff = g_native
                        .iter()
                        .zip(&g_xla)
                        .map(|(a, b)| (a - b).abs())
                        .fold(0.0, f64::max);
                    println!("gradient conformance: max |native - xla| / scale = {:.2e}", max_diff / scale);
                    assert!(max_diff / scale < 1e-4, "backend mismatch");
                }
            },
        }
    }

    // --- the same workload through the coordinator service ---
    println!("\n=== coordinator path ===");
    let spec = JobSpec {
        workload: Workload::Synthetic { profile: "cifar-like".into(), n, d, seed: 2026 },
        nu,
        solver: "adaptive-gd-srht".parse().expect("valid solver spec"),
        eps: 1e-8,
        seed: 505,
        path_nus: Vec::new(),
        threads: None,
    };
    let outcome = execute(&spec).expect("coordinator job");
    report("coordinator job (adaptive-gd-srht)", &outcome.report);
    assert!(outcome.report.converged);

    println!("\nend_to_end: all layers composed OK");
}

fn report(label: &str, r: &effdim::solvers::SolveReport) {
    println!("\n-- {label} --");
    println!("solver     : {}", r.solver);
    println!("converged  : {} (rel err {:.1e})", r.converged, r.final_rel_error.unwrap_or(f64::NAN));
    println!("iterations : {} (+{} rejected, {} doublings)", r.iterations, r.rejections, r.doublings);
    println!("sketch m   : final {} / peak {}", r.final_m, r.peak_m);
    println!(
        "time       : {:.3}s = sketch {:.3} + factor {:.3} + iterate {:.3}",
        r.wall_time_s, r.sketch_time_s, r.factor_time_s, r.iter_time_s
    );
}
