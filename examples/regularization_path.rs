//! Regularization path (the paper's Figure 1 workload): compute ridge
//! solutions over a decreasing grid of `nu`, warm-starting each solve,
//! and compare the adaptive solver against CG.
//!
//! Solvers are chosen by [`SolverSpec`] string — the same names the CLI
//! (`effdim path --solver ...`) and the coordinator accept.
//!
//! ```sh
//! cargo run --release --example regularization_path
//! ```

use effdim::data::synthetic;
use effdim::solvers::path::run_path;
use effdim::solvers::SolverSpec;

fn main() {
    let ds = synthetic::mnist_like(2048, 256, 3);
    let nus: Vec<f64> = (-2..=4).rev().map(|j| 10f64.powi(j)).collect();
    let eps = 1e-8;

    println!("dataset: {} (n = {}, d = {})", ds.name, ds.n(), ds.d());
    println!("path: nu in {nus:?}, eps = {eps:.0e}\n");

    for name in ["cg", "adaptive-srht", "adaptive-gd-srht"] {
        let spec: SolverSpec = name.parse().expect("valid solver spec");
        let res = run_path(&ds.a, &ds.b, &nus, eps, &spec, 17);
        println!("== {} ==", res.solver);
        println!("{:<10} {:>8} {:>12} {:>8} {:>8}", "nu", "d_e", "cum_time_s", "iters", "m");
        for p in &res.points {
            println!(
                "{:<10.0e} {:>8.1} {:>12.4} {:>8} {:>8}",
                p.nu,
                ds.effective_dimension(p.nu),
                p.cumulative_time_s,
                p.report.iterations,
                p.report.peak_m
            );
            assert!(p.report.converged, "{} failed at nu={}", res.solver, p.nu);
        }
        println!("total: {:.3}s, peak m: {}\n", res.total_time_s(), res.peak_m());
    }
}
