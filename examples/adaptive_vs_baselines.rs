//! Head-to-head: Algorithm 1 (both variants, both embeddings) vs CG and
//! randomized-preconditioned CG on one fixed-`nu` problem — the paper's
//! Figure 2 protocol at example scale.
//!
//! ```sh
//! cargo run --release --example adaptive_vs_baselines
//! ```

use effdim::data::synthetic;
use effdim::rng::Xoshiro256;
use effdim::sketch::SketchKind;
use effdim::solvers::adaptive::{self, AdaptiveConfig, AdaptiveVariant};
use effdim::solvers::cg::{self, CgConfig};
use effdim::solvers::pcg::{self, PcgConfig};
use effdim::solvers::{direct, RidgeProblem, SolveReport, StopRule};

fn main() {
    let ds = synthetic::cifar_like(2048, 256, 11);
    let nu = 1.0;
    let eps = 1e-8;
    let problem = RidgeProblem::new(ds.a.clone(), ds.b.clone(), nu);
    let x_star = direct::solve(&problem);
    let stop = StopRule::TrueError { x_star, eps };
    let x0 = vec![0.0; problem.d()];

    println!(
        "dataset {} (n={}, d={}), nu={}, d_e={:.1}, eps={:.0e}\n",
        ds.name,
        problem.n(),
        problem.d(),
        nu,
        ds.effective_dimension(nu),
        eps
    );

    let mut reports: Vec<SolveReport> = Vec::new();

    reports.push(
        cg::solve(&problem, &x0, &CgConfig { max_iters: 100_000, stop: stop.clone() }).report,
    );

    for kind in [SketchKind::Srht, SketchKind::Gaussian] {
        let mut rng = Xoshiro256::seed_from_u64(21);
        reports.push(pcg::solve(&problem, &x0, &PcgConfig::new(kind, 0.5, stop.clone()), &mut rng).report);
    }

    for kind in [SketchKind::Srht, SketchKind::Gaussian] {
        for variant in [AdaptiveVariant::PolyakFirst, AdaptiveVariant::GradientOnly] {
            let mut cfg = AdaptiveConfig::new(kind, stop.clone());
            cfg.variant = variant;
            reports.push(adaptive::solve(&problem, &x0, &cfg, 31).report);
        }
    }

    println!(
        "{:<26} {:>8} {:>8} {:>10} {:>10} {:>8}",
        "solver", "iters", "m", "time_s", "rel_err", "conv"
    );
    for r in &reports {
        println!(
            "{:<26} {:>8} {:>8} {:>10.4} {:>10.1e} {:>8}",
            r.solver,
            r.iterations,
            r.peak_m,
            r.wall_time_s,
            r.final_rel_error.unwrap_or(f64::NAN),
            r.converged
        );
        assert!(r.converged, "{} did not converge", r.solver);
    }

    // The paper's headline at this scale: adaptive uses far less memory
    // (sketch size) than pCG.
    let pcg_m = reports.iter().find(|r| r.solver.starts_with("pcg")).unwrap().peak_m;
    let ada_m = reports.iter().find(|r| r.solver.starts_with("adaptive")).unwrap().peak_m;
    println!("\nsketch memory: adaptive m = {ada_m} vs pCG m = {pcg_m}");
}
