//! Head-to-head: Algorithm 1 (both variants, both embeddings) vs CG and
//! randomized-preconditioned CG on one fixed-`nu` problem — the paper's
//! Figure 2 protocol at example scale.
//!
//! Every contender is named by a [`SolverSpec`] string and run through
//! the unified `Solver` trait — one loop, no per-solver plumbing.
//!
//! ```sh
//! cargo run --release --example adaptive_vs_baselines
//! ```

use effdim::data::synthetic;
use effdim::solvers::{direct, RidgeProblem, SolveReport, Solver as _, SolverSpec, StopRule};

fn main() {
    let ds = synthetic::cifar_like(2048, 256, 11);
    let nu = 1.0;
    let eps = 1e-8;
    let problem = RidgeProblem::new(ds.a.clone(), ds.b.clone(), nu);
    let x_star = direct::solve(&problem);
    let stop = StopRule::TrueError { x_star, eps };
    let x0 = vec![0.0; problem.d()];

    println!(
        "dataset {} (n={}, d={}), nu={}, d_e={:.1}, eps={:.0e}\n",
        ds.name,
        problem.n(),
        problem.d(),
        nu,
        ds.effective_dimension(nu),
        eps
    );

    let contenders = [
        "cg",
        "pcg-srht",
        "pcg-gaussian",
        "adaptive-srht",
        "adaptive-gd-srht",
        "adaptive-gaussian",
        "adaptive-gd-gaussian",
    ];

    let mut reports: Vec<SolveReport> = Vec::new();
    for name in contenders {
        let spec: SolverSpec = name.parse().expect("valid solver spec");
        reports.push(spec.build(31).solve(&problem, &x0, &stop).report);
    }

    println!(
        "{:<26} {:>8} {:>8} {:>10} {:>10} {:>8}",
        "solver", "iters", "m", "time_s", "rel_err", "conv"
    );
    for r in &reports {
        println!(
            "{:<26} {:>8} {:>8} {:>10.4} {:>10.1e} {:>8}",
            r.solver,
            r.iterations,
            r.peak_m,
            r.wall_time_s,
            r.final_rel_error.unwrap_or(f64::NAN),
            r.converged
        );
        assert!(r.converged, "{} did not converge", r.solver);
    }

    // The paper's headline at this scale: adaptive uses far less memory
    // (sketch size) than pCG.
    let pcg_m = reports.iter().find(|r| r.solver.starts_with("pcg")).unwrap().peak_m;
    let ada_m = reports.iter().find(|r| r.solver.starts_with("adaptive")).unwrap().peak_m;
    println!("\nsketch memory: adaptive m = {ada_m} vs pCG m = {pcg_m}");
}
