//! Underdetermined ridge regression (`d >= n`) via the dual problem
//! (paper Appendix A.2): the dual is overdetermined, Algorithm 1 applies
//! verbatim, and the primal solution is recovered as `x = A^T z`.
//!
//! The dual reduction is a registry solver like any other: spec string
//! `"dual-adaptive-gaussian"`, dispatched through the unified `Solver`
//! trait.
//!
//! ```sh
//! cargo run --release --example underdetermined_dual
//! ```

use effdim::data::synthetic;
use effdim::linalg::norm2;
use effdim::rng::Xoshiro256;
use effdim::solvers::dual::solve_direct;
use effdim::solvers::{RidgeProblem, Solver as _, SolverSpec, StopRule};

fn main() {
    // Wide problem: n = 128 samples, d = 1024 features.
    let (n, d, nu) = (128, 1024, 0.5);
    let base = synthetic::exponential_decay(d, n, 5); // transpose trick
    let a = base.a.transpose(); // n x d
    let mut rng = Xoshiro256::seed_from_u64(6);
    let mut b = vec![0.0; n];
    rng.fill_gaussian(&mut b, 1.0);

    println!("underdetermined problem: n = {n}, d = {d}, nu = {nu}");

    // Exact solution through the dual normal equations (O(d n^2)).
    let x_exact = solve_direct(&a, &b, nu);

    // Adaptive solve on the dual, via the unified API. The solver builds
    // the dual reduction internally: the gradient is A A^T z + nu^2 z - b,
    // so the pseudo-inverse b_hat = A^+ b never needs to be formed.
    let problem = RidgeProblem::new(a, b, nu);
    let spec: SolverSpec = "dual-adaptive-gaussian".parse().expect("valid solver spec");
    let stop = StopRule::TrueError { x_star: x_exact.clone(), eps: 1e-12 };
    let sol = spec.build(9).solve(&problem, &vec![0.0; d], &stop);

    let mut diff = sol.x.clone();
    for i in 0..d {
        diff[i] -= x_exact[i];
    }
    let rel = norm2(&diff) / norm2(&x_exact);
    println!("solver       : {}", sol.report.solver);
    println!("converged    : {}", sol.report.converged);
    println!("iterations   : {}", sol.report.iterations);
    println!("final m      : {} (dual dimension n = {n})", sol.report.final_m);
    println!("||x - x*||/||x*|| = {rel:.2e}");

    // Primal optimality check: gradient of the primal objective at x.
    let g = problem.gradient(&sol.x);
    println!("primal gradient norm = {:.2e}", norm2(&g));
    assert!(sol.report.converged && rel < 1e-4);
}
