//! Underdetermined ridge regression (`d >= n`) via the dual problem
//! (paper Appendix A.2): the dual is overdetermined, Algorithm 1 applies
//! verbatim, and the primal solution is recovered as `x = A^T z`.
//!
//! ```sh
//! cargo run --release --example underdetermined_dual
//! ```

use effdim::data::synthetic;
use effdim::linalg::norm2;
use effdim::sketch::SketchKind;
use effdim::solvers::adaptive::AdaptiveConfig;
use effdim::solvers::dual::{dual_stop, solve_direct, DualRidge};
use effdim::solvers::RidgeProblem;
use effdim::rng::Xoshiro256;

fn main() {
    // Wide problem: n = 128 samples, d = 1024 features.
    let (n, d, nu) = (128, 1024, 0.5);
    let base = synthetic::exponential_decay(d, n, 5); // transpose trick
    let a = base.a.transpose(); // n x d
    let mut rng = Xoshiro256::seed_from_u64(6);
    let mut b = vec![0.0; n];
    rng.fill_gaussian(&mut b, 1.0);

    println!("underdetermined problem: n = {n}, d = {d}, nu = {nu}");

    // Exact solution through the dual normal equations (O(d n^2)).
    let x_exact = solve_direct(&a, &b, nu);

    // Adaptive solve on the dual: the gradient is A A^T z + nu^2 z - b,
    // so the pseudo-inverse b_hat = A^+ b never needs to be formed.
    let dual = DualRidge::new(a.clone(), b.clone(), nu);
    let cfg = AdaptiveConfig::new(SketchKind::Gaussian, dual_stop(&dual.dual, 1e-12));
    let sol = dual.solve_adaptive(&cfg, 9);

    let mut diff = sol.x.clone();
    for i in 0..d {
        diff[i] -= x_exact[i];
    }
    let rel = norm2(&diff) / norm2(&x_exact);
    println!("solver       : {}", sol.report.solver);
    println!("converged    : {}", sol.report.converged);
    println!("iterations   : {}", sol.report.iterations);
    println!("final m      : {} (dual dimension n = {n})", sol.report.final_m);
    println!("||x - x*||/||x*|| = {rel:.2e}");

    // Primal optimality check: gradient of the primal objective at x.
    let primal = RidgeProblem::new(a, b, nu);
    let g = primal.gradient(&sol.x);
    println!("primal gradient norm = {:.2e}", norm2(&g));
    assert!(sol.report.converged && rel < 1e-4);
}
