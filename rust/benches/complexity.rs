//! Theorem 7 (quick mode): sketch/factor/iterate decomposition and the
//! adaptive-vs-pCG crossover as d_e/d varies.
//! Full runs: `cargo run --release --bin bench_figures -- complexity`.

use effdim::bench_harness::complexity::{self, ComplexityConfig};

fn main() {
    let cfg = ComplexityConfig { n: 1024, d: 128, eps: 1e-8, seed: 5 };
    let rows = complexity::run(&cfg, &[100.0, 1.0, 0.01]);
    println!("{}", complexity::render_table(&rows));
    // d_e shrinks with nu; at the largest nu the adaptive method must use
    // a (much) smaller sketch than pCG — the memory claim of §4.2.
    let big_nu = &rows[0];
    assert!(big_nu.ada_m < big_nu.pcg_m, "adaptive m must be below pCG at small d_e");
}
