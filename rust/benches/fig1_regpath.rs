//! Figure 1 (quick mode): regularization path on the MNIST/CIFAR-like
//! surrogates. Full runs: `cargo run --release --bin bench_figures -- fig1`.

use effdim::bench_harness::figures::{self, FigureConfig};

fn main() {
    let cfg = FigureConfig { n: 512, d: 64, trials: 2, eps: 1e-8, seed: 1 };
    let series = figures::fig1(&cfg);
    println!("{}", figures::render_table(&series));
    assert!(series.iter().all(|s| s.all_converged), "all solvers must converge");
    // Reproduction check (Figure 1's qualitative claim): the adaptive
    // methods beat pCG on total path time at this scale.
    for ds in ["mnist-like", "cifar-like"] {
        let total = |solver: &str| {
            series
                .iter()
                .find(|s| s.dataset == ds && s.solver == solver)
                .map(|s| *s.cum_time_mean.last().unwrap())
                .unwrap()
        };
        let ada = total("adaptive-gd-srht").min(total("adaptive-srht"));
        let pcg = total("pcg-srht");
        println!("{ds}: adaptive {ada:.3}s vs pcg {pcg:.3}s -> {}", if ada < pcg { "adaptive wins" } else { "pcg wins" });
    }
}
