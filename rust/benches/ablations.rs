//! Design-choice ablations called out in DESIGN.md:
//!
//! 1. **Fixed vs refreshed embeddings** (paper §1.3): refreshing `S` every
//!    iteration gives no rate advantage and pays sketch+factor per step.
//! 2. **Adaptive vs Hutchinson-estimate-then-fixed-m** ([31]-style): the
//!    estimator needs Gram-scale work up front and carries no accuracy
//!    guarantee; Algorithm 1 reaches the same error without it.
//! 3. **Polyak-first vs gradient-only** (paper §5): when the Polyak
//!    candidate is often rejected (SRHT), the gradient-only variant wins.

use effdim::data::synthetic;
use effdim::sketch::SketchKind;
use effdim::solvers::adaptive::{self, AdaptiveConfig, AdaptiveVariant};
use effdim::solvers::ihs::{self, IhsConfig};
use effdim::solvers::{direct, RidgeProblem, StopRule};

fn main() {
    let ds = synthetic::exponential_decay(1024, 128, 21);
    let nu = 0.1;
    let p = RidgeProblem::new(ds.a.clone(), ds.b.clone(), nu);
    let x_star = direct::solve(&p);
    let d_e = ds.effective_dimension(nu);
    let stop = StopRule::TrueError { x_star, eps: 1e-8 };
    let x0 = vec![0.0; p.d()];
    println!("ablations on synthetic-exp (n=1024, d=128, nu={nu}, d_e={d_e:.1})\n");

    // --- 1. fixed vs refreshed ---
    let m = ((d_e / 0.15).ceil() as usize).max(8);
    let mut fixed_cfg = IhsConfig::gaussian(m, 0.15);
    fixed_cfg.momentum = false;
    let mut refresh_cfg = fixed_cfg.clone();
    refresh_cfg.refresh = true;
    let fixed = ihs::solve(&p, &x0, &fixed_cfg, &stop, 1);
    let refreshed = ihs::solve(&p, &x0, &refresh_cfg, &stop, 1);
    println!("[1] fixed vs refreshed embeddings (gradient-IHS, m={m}):");
    for (label, r) in [("fixed", &fixed.report), ("refreshed", &refreshed.report)] {
        println!(
            "    {label:<10} iters={:<4} time={:.4}s (sketch+factor {:.4}s) conv={}",
            r.iterations,
            r.wall_time_s,
            r.sketch_time_s + r.factor_time_s,
            r.converged
        );
    }
    assert!(refreshed.report.wall_time_s >= fixed.report.wall_time_s * 0.9);

    // --- 2. adaptive vs Hutchinson baseline ---
    let (hutch, de_hat) =
        ihs::solve_with_estimated_de(&p, &x0, SketchKind::Gaussian, 0.15, 30, &stop, 2);
    let acfg = AdaptiveConfig::new(SketchKind::Gaussian);
    let ada = adaptive::solve(&p, &x0, &acfg, &stop, 3).unwrap();
    println!("\n[2] adaptive vs hutchinson-estimate ([31]) — d_e = {d_e:.1}, estimate {de_hat:.1}:");
    println!(
        "    hutchinson iters={:<4} m={:<5} time={:.4}s conv={}",
        hutch.report.iterations, hutch.report.peak_m, hutch.report.wall_time_s, hutch.report.converged
    );
    println!(
        "    adaptive   iters={:<4} m={:<5} time={:.4}s conv={}",
        ada.report.iterations, ada.report.peak_m, ada.report.wall_time_s, ada.report.converged
    );

    // --- 3. Polyak-first vs gradient-only (SRHT) ---
    println!("\n[3] Polyak-first vs gradient-only (SRHT):");
    for variant in [AdaptiveVariant::PolyakFirst, AdaptiveVariant::GradientOnly] {
        let mut cfg = AdaptiveConfig::new(SketchKind::Srht);
        cfg.variant = variant;
        let sol = adaptive::solve(&p, &x0, &cfg, &stop, 4).unwrap();
        println!(
            "    {:<24} iters={:<4} rejected={:<4} time={:.4}s conv={}",
            format!("{variant:?}"),
            sol.report.iterations,
            sol.report.rejections,
            sol.report.wall_time_s,
            sol.report.converged
        );
    }
}
