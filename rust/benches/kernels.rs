//! Microbenchmarks of the native hot-path kernels (the §Perf targets):
//! blocked GEMM, FWHT, ridge gradient, Woodbury factor + apply.

use effdim::bench_harness::bench;
use effdim::linalg::Matrix;
use effdim::rng::Xoshiro256;
use effdim::sketch::srht::fwht_rows;
use effdim::sketch::{gaussian::GaussianSketch, srht::SrhtSketch, Sketch};
use effdim::solvers::woodbury::WoodburyCache;
use effdim::solvers::RidgeProblem;

fn main() {
    let mut rng = Xoshiro256::seed_from_u64(1);
    let (n, d, m) = (2048usize, 256usize, 128usize);
    let a = Matrix::from_fn(n, d, |_, _| rng.next_gaussian());
    let b: Vec<f64> = (0..n).map(|i| (i as f64 * 0.01).sin()).collect();
    let problem = RidgeProblem::new(a.clone(), b, 0.5);
    let x: Vec<f64> = (0..d).map(|i| (i as f64 * 0.02).cos()).collect();

    println!("native kernel benches (n={n}, d={d}, m={m})\n");

    // GEMM flops: 2 m n d.
    let gs = GaussianSketch::sample(m, n, &mut rng);
    let r = bench("gaussian sketch S*A (GEMM)", 1, 5, || gs.apply(&a));
    let gflops = 2.0 * (m * n * d) as f64 / r.summary.mean / 1e9;
    println!("{}   [{:.2} GFLOP/s]", r.report_line(), gflops);

    let hs = SrhtSketch::sample(m, n, &mut rng);
    let r = bench("SRHT sketch S*A (FWHT path)", 1, 5, || hs.apply(&a));
    println!("{}", r.report_line());

    let mut work = Matrix::from_fn(n, d, |_, _| 1.0);
    let r = bench("FWHT rows (2048 x 256)", 1, 5, || fwht_rows(&mut work));
    let fwht_flops = (n as f64) * (n as f64).log2() * d as f64;
    println!("{}   [{:.2} GFLOP/s]", r.report_line(), fwht_flops / r.summary.mean / 1e9);

    let r = bench("ridge gradient A^T(Ax-b)+nu^2 x", 2, 10, || problem.gradient(&x));
    let grad_flops = 4.0 * (n * d) as f64;
    println!("{}   [{:.2} GFLOP/s]", r.report_line(), grad_flops / r.summary.mean / 1e9);

    let sa = gs.apply(&a);
    let r = bench("woodbury factor (m x m chol)", 1, 5, || WoodburyCache::new(sa.clone(), 0.5));
    println!("{}", r.report_line());

    let cache = WoodburyCache::new(sa, 0.5);
    let g = problem.gradient(&x);
    let r = bench("woodbury apply H_S^-1 g", 2, 20, || cache.apply_inverse(&g));
    println!("{}", r.report_line());

    // Remark 4.1 fast path: O(nnz) CountSketch on CSR data. Time should
    // scale with density, not with n*d.
    use effdim::linalg::sparse::CsrMatrix;
    use effdim::sketch::sparse::SparseSketch;
    println!();
    let mut prev = f64::INFINITY;
    for density in [0.01, 0.1, 1.0] {
        let dense = Matrix::from_fn(n, d, |_, _| {
            if rng.next_f64() < density { rng.next_gaussian() } else { 0.0 }
        });
        let csr = CsrMatrix::from_dense(&dense);
        let cs = SparseSketch::sample(m, n, &mut rng);
        let r = bench(
            &format!("countsketch CSR apply (density {density})"),
            1,
            5,
            || cs.apply_csr(&csr),
        );
        println!("{}   [nnz = {}]", r.report_line(), csr.nnz());
        if density <= 0.1 {
            prev = r.summary.mean;
        } else {
            assert!(prev < r.summary.mean, "O(nnz): sparser must be faster");
        }
    }
}
