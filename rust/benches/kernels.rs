//! Microbenchmarks of the native hot-path kernels, covering the §Perf and
//! §Sparse targets (EXPERIMENTS.md): blocked GEMM (single- vs
//! multi-threaded), FWHT, sketch apply, *incremental sketch growth* vs
//! from-scratch resampling, Woodbury factor growth, ridge gradient, and a
//! dense-vs-CSR density sweep (sketch apply, sketch growth, CG matvec,
//! adaptive end-to-end solve).
//!
//! Emits `BENCH_kernels.json` at the repository root (falling back to the
//! working directory) so the perf trajectory of the incremental-growth and
//! sparse-operand work is recorded run over run. Key derived ratios:
//!
//! * `gemm_parallel_speedup_*` — multi-threaded over single-threaded GEMM;
//! * `srht_grow_speedup_*` / `gaussian_grow_speedup_*` — per-growth sketch
//!   time of the cached engine path over from-scratch resample+apply at
//!   the same target size (the adaptive solver's rejection-round cost);
//! * `woodbury_grow_speedup_*` — incremental factor growth over a full
//!   rebuild;
//! * `csr_speedup_*` — the dense-path time over the CSR-path time for the
//!   same operation on the same matrix at a given density (sketch apply /
//!   sketch grow / CG matvec / `adaptive-sparse` end-to-end solve).
//!
//! * `block_rhs_speedup_k{8,64}` — `k` alternate right-hand sides served
//!   as one `solve_block` (BLAS-3 block iteration) over `k` looped
//!   `solve_rhs` calls against the same cached session sketch.
//!
//! * `append_speedup_{gaussian,srht,sparse}` — streaming `dn` new rows
//!   into a warmed session (`ModelSession::append`: sketch only the delta,
//!   refactor, warm-started re-solve) over a full re-register + cold query
//!   of the concatenated data. For `dn << n` these must land above 1.
//!
//! * `frozen_solve_speedup_t{2,8}` — T threads solving *distinct
//!   uncached* `nu` against one model: the frozen read lane
//!   (`SessionSnapshot::solve_frozen`, no session lock) over the mutex
//!   writer lane (every solve serialized on the session lock). Lock-free
//!   scaling reads as ~T; a hidden lock reads as ~1.
//!
//! * `recovery_replay_speedup` — restart cost (§Durability acceptance):
//!   recovering a crashed durable model (snapshot decode + sketch replay
//!   from the compact header + WAL tail replay + first warm query) over
//!   a cold re-register + first query of the same final data. Must land
//!   above 1: replay restores the grown sketch and warm start directly
//!   instead of re-paying the adaptive growth ladder.
//!
//! `cargo bench --bench kernels -- --smoke` runs a seconds-scale variant
//! (shrunken shapes, fewer repeats) so CI *executes* every kernel path on
//! each PR instead of merely compiling it.

use effdim::bench_harness::bench;
use effdim::data::synthetic;
use effdim::linalg::sparse::CsrMatrix;
use effdim::linalg::{threads, Matrix, Operand};
use effdim::rng::Xoshiro256;
use effdim::sketch::engine::SketchEngine;
use effdim::sketch::srht::fwht_rows;
use effdim::sketch::{gaussian::GaussianSketch, sparse::SparseSketch, srht::SrhtSketch, Sketch, SketchKind};
use effdim::solvers::session::{AppendRefresh, ModelSession};
use effdim::solvers::woodbury::WoodburyCache;
use effdim::solvers::{RidgeProblem, Solver as _, SolverSpec, StopRule};
use effdim::util::json::Json;
use effdim::util::stats::summarize;
use std::sync::Arc;
use std::time::Instant;

/// One benchmark case destined for the JSON report.
struct Case {
    name: String,
    n: usize,
    d: usize,
    m: usize,
    threads: usize,
    mean_s: f64,
    min_s: f64,
}

impl Case {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::from(self.name.clone())),
            ("n", Json::from(self.n)),
            ("d", Json::from(self.d)),
            ("m", Json::from(self.m)),
            ("threads", Json::from(self.threads)),
            ("mean_s", Json::from(self.mean_s)),
            ("min_s", Json::from(self.min_s)),
        ])
    }
}

/// Time `f` (after one warmup) and record a case.
fn timed(
    cases: &mut Vec<Case>,
    name: &str,
    (n, d, m): (usize, usize, usize),
    thread_count: usize,
    iters: usize,
    mut f: impl FnMut(),
) -> f64 {
    let mut run = || {
        std::hint::black_box(f());
    };
    run(); // warmup
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        run();
        times.push(t0.elapsed().as_secs_f64());
    }
    let s = summarize(&times);
    println!(
        "{name:<44} {:>10.3} ms (min {:>10.3} ms, n={n}, d={d}, m={m}, threads={thread_count})",
        s.mean * 1e3,
        s.min * 1e3
    );
    cases.push(Case {
        name: name.into(),
        n,
        d,
        m,
        threads: thread_count,
        mean_s: s.mean,
        min_s: s.min,
    });
    s.mean
}

fn main() {
    // `-- --smoke`: CI fast path — every kernel executes, nothing at scale.
    let smoke = std::env::args().any(|a| a == "--smoke");
    let default_threads = threads::current();
    println!(
        "native kernel benches (default threads = {default_threads}{})\n",
        if smoke { ", SMOKE mode" } else { "" }
    );

    let mut cases: Vec<Case> = Vec::new();
    let mut derived: Vec<(String, Json)> = Vec::new();

    let dense_shapes: &[(usize, usize)] = if smoke {
        &[(512, 64)]
    } else {
        &[(1024, 128), (4096, 256), (8192, 256)]
    };
    for &(n, d) in dense_shapes {
        let m = d / 2; // adaptive regime: m <= d
        let mut rng = Xoshiro256::seed_from_u64(1);
        let a = Matrix::from_fn(n, d, |_, _| rng.next_gaussian());
        println!("--- n = {n}, d = {d}, m = {m} ---");

        // GEMM (gaussian sketch apply): single- vs multi-threaded.
        let gs = GaussianSketch::sample(m, n, &mut rng);
        let t1 = timed(&mut cases, "gemm S*A", (n, d, m), 1, 3, || {
            threads::with_threads(1, || std::hint::black_box(gs.apply(&a)));
        });
        let tp = timed(&mut cases, "gemm S*A parallel", (n, d, m), default_threads, 3, || {
            std::hint::black_box(gs.apply(&a));
        });
        derived.push((format!("gemm_parallel_speedup_n{n}"), Json::from(t1 / tp)));
        println!("    gemm parallel speedup: {:.2}x", t1 / tp);

        // FWHT over the padded row dimension.
        let mut work = Matrix::from_fn(n, d, |_, _| 1.0);
        timed(&mut cases, "fwht rows", (n, d, 0), default_threads, 3, || {
            fwht_rows(std::hint::black_box(&mut work));
        });

        // SRHT resample + apply from scratch (what a non-incremental
        // growth pays: a fresh FWHT over all of A).
        let t_scratch = timed(&mut cases, "srht resample+apply (scratch)", (n, d, m), default_threads, 3, || {
            let mut srng = Xoshiro256::seed_from_u64(17);
            let hs = SrhtSketch::sample(m, n, &mut srng);
            std::hint::black_box(hs.apply(&a));
        });

        // SRHT growth m/2 -> m through the cached engine: per-growth cost
        // is row selection only. Engines are rebuilt outside the timer.
        let t_grow = {
            let mut times = Vec::new();
            for i in 0..5 {
                let mut erng = Xoshiro256::seed_from_u64(10 + i);
                let mut engine = SketchEngine::new(SketchKind::Srht, m / 2, &a, &mut erng);
                let t0 = Instant::now();
                std::hint::black_box(engine.grow(m, &a, &mut erng).unwrap());
                times.push(t0.elapsed().as_secs_f64());
            }
            let s = summarize(&times);
            println!(
                "{:<44} {:>10.3} ms (min {:>10.3} ms)",
                "srht grow m/2 -> m (cached)",
                s.mean * 1e3,
                s.min * 1e3
            );
            cases.push(Case {
                name: "srht grow m/2 -> m (cached)".into(),
                n,
                d,
                m,
                threads: default_threads,
                mean_s: s.mean,
                min_s: s.min,
            });
            s.mean
        };
        derived.push((format!("srht_grow_speedup_n{n}"), Json::from(t_scratch / t_grow)));
        println!("    srht cached-growth speedup vs scratch: {:.1}x", t_scratch / t_grow);

        // Gaussian growth m/2 -> m: pays only the appended-row GEMM.
        let t_gauss_scratch = timed(&mut cases, "gaussian resample+apply (scratch)", (n, d, m), default_threads, 3, || {
            let mut srng = Xoshiro256::seed_from_u64(33);
            let s = GaussianSketch::sample(m, n, &mut srng);
            std::hint::black_box(s.apply(&a));
        });
        let t_gauss_grow = {
            let mut times = Vec::new();
            for i in 0..3 {
                let mut erng = Xoshiro256::seed_from_u64(20 + i);
                let mut engine = SketchEngine::new(SketchKind::Gaussian, m / 2, &a, &mut erng);
                let t0 = Instant::now();
                std::hint::black_box(engine.grow(m, &a, &mut erng).unwrap());
                times.push(t0.elapsed().as_secs_f64());
            }
            let s = summarize(&times);
            cases.push(Case {
                name: "gaussian grow m/2 -> m (cached)".into(),
                n,
                d,
                m,
                threads: default_threads,
                mean_s: s.mean,
                min_s: s.min,
            });
            println!(
                "{:<44} {:>10.3} ms",
                "gaussian grow m/2 -> m (cached)",
                s.mean * 1e3
            );
            s.mean
        };
        derived.push((
            format!("gaussian_grow_speedup_n{n}"),
            Json::from(t_gauss_scratch / t_gauss_grow),
        ));

        // Woodbury factor growth vs full rebuild at the same final size.
        let mut erng = Xoshiro256::seed_from_u64(44);
        let engine_full = SketchEngine::new(SketchKind::Gaussian, m, &a, &mut erng);
        let sa_full = engine_full.sa_unnormalized().clone();
        let half_rows = Matrix::from_fn(m / 2, d, |i, j| sa_full.get(i, j));
        let new_rows = Matrix::from_fn(m - m / 2, d, |i, j| sa_full.get(m / 2 + i, j));
        let scale_half = 1.0 / ((m / 2) as f64).sqrt();
        let scale_full = 1.0 / (m as f64).sqrt();
        let t_factor_full = timed(&mut cases, "woodbury factor (full rebuild)", (n, d, m), default_threads, 3, || {
            std::hint::black_box(WoodburyCache::new_scaled(sa_full.clone(), 0.5, scale_full).unwrap());
        });
        let t_factor_grow = {
            let mut times = Vec::new();
            for _ in 0..5 {
                let mut cache =
                    WoodburyCache::new_scaled(half_rows.clone(), 0.5, scale_half).unwrap();
                let t0 = Instant::now();
                cache.grow(&new_rows, scale_full).unwrap();
                std::hint::black_box(&cache);
                times.push(t0.elapsed().as_secs_f64());
            }
            let s = summarize(&times);
            cases.push(Case {
                name: "woodbury grow m/2 -> m".into(),
                n,
                d,
                m,
                threads: default_threads,
                mean_s: s.mean,
                min_s: s.min,
            });
            println!("{:<44} {:>10.3} ms", "woodbury grow m/2 -> m", s.mean * 1e3);
            s.mean
        };
        derived.push((
            format!("woodbury_grow_speedup_n{n}"),
            Json::from(t_factor_full / t_factor_grow),
        ));
        println!();
    }

    // Ridge gradient (memory-bound fused kernel) at one mid size.
    {
        let (n, d) = if smoke { (512usize, 64usize) } else { (4096usize, 256usize) };
        let mut rng = Xoshiro256::seed_from_u64(2);
        let a = Matrix::from_fn(n, d, |_, _| rng.next_gaussian());
        let b: Vec<f64> = (0..n).map(|i| (i as f64 * 0.01).sin()).collect();
        let problem = RidgeProblem::new(a, b, 0.5);
        let x: Vec<f64> = (0..d).map(|i| (i as f64 * 0.02).cos()).collect();
        let r = bench("ridge gradient A^T(Ax-b)+nu^2 x", 2, 10, || problem.gradient(&x));
        println!("{}", r.report_line());
        cases.push(Case {
            name: "ridge gradient".into(),
            n,
            d,
            m: 0,
            threads: 1,
            mean_s: r.summary.mean,
            min_s: r.summary.min,
        });
    }

    // Dense-vs-CSR density sweep (§Sparse acceptance): the same matrix at
    // 1% / 5% / 20% / 100% fill, stored both ways, through CountSketch
    // apply, sparse sketch *growth*, the CG matvec (Hessian product), and
    // an `adaptive-sparse` end-to-end solve. `csr_speedup_*` = dense-path
    // time / CSR-path time. The whole sweep is pinned to ONE thread so
    // the ratios measure storage (O(nnz) vs O(n d)) and nothing else —
    // the CSR kernels would otherwise go row-parallel above the threading
    // threshold while the dense GEMV baseline stays serial, inflating the
    // ratios by up to the core count. O(nnz) predicts ~1/density.
    for density in [0.01, 0.05, 0.2, 1.0] {
        threads::with_threads(1, || {
            let (n, d, m) = if smoke { (512usize, 64usize, 32usize) } else { (4096, 512, 256) };
            let reps = if smoke { 2 } else { 5 };
            let pct = density.to_string();
            let mut rng = Xoshiro256::seed_from_u64(3);
            let dense = Matrix::from_fn(n, d, |_, _| {
                if rng.next_f64() < density { rng.next_gaussian() } else { 0.0 }
            });
            let csr = CsrMatrix::from_dense(&dense);
            let nnz = csr.nnz();
            let op_dense = Operand::Dense(dense);
            let op_csr = Operand::Sparse(csr);
            println!("--- density {density} (n = {n}, d = {d}, nnz = {nnz}) ---");

            // CountSketch apply: dense scatter vs O(nnz) CSR scatter.
            let cs = SparseSketch::sample(m, n, &mut rng);
            let t_dense = timed(
                &mut cases,
                &format!("countsketch apply dense (density {pct})"),
                (n, d, m),
                1,
                reps,
                || {
                    std::hint::black_box(cs.apply_operand(&op_dense));
                },
            );
            let t_csr = timed(
                &mut cases,
                &format!("countsketch apply csr (density {pct})"),
                (n, d, m),
                1,
                reps,
                || {
                    std::hint::black_box(cs.apply_operand(&op_csr));
                },
            );
            derived.push((
                format!("csr_speedup_sketch_apply_density{pct}"),
                Json::from(t_dense / t_csr),
            ));

            // Sparse sketch growth m/2 -> m through the engine, per operand.
            let grow_time = |op: &Operand| {
                let mut times = Vec::new();
                for i in 0..reps {
                    let mut erng = Xoshiro256::seed_from_u64(40 + i as u64);
                    let mut engine = SketchEngine::new(SketchKind::Sparse, m / 2, op, &mut erng);
                    let t0 = Instant::now();
                    std::hint::black_box(engine.grow(m, op, &mut erng).unwrap());
                    times.push(t0.elapsed().as_secs_f64());
                }
                summarize(&times).mean
            };
            let tg_dense = grow_time(&op_dense);
            let tg_csr = grow_time(&op_csr);
            println!(
                "{:<44} {:>10.3} ms dense vs {:>10.3} ms csr",
                "sparse sketch grow m/2 -> m",
                tg_dense * 1e3,
                tg_csr * 1e3
            );
            derived.push((
                format!("csr_speedup_sketch_grow_density{pct}"),
                Json::from(tg_dense / tg_csr),
            ));

            // CG matvec: the Hessian product (A^T A + nu^2 I) v.
            let b: Vec<f64> = (0..n).map(|i| (i as f64 * 0.01).sin()).collect();
            let p_dense = RidgeProblem::new(op_dense.clone(), b.clone(), 0.5);
            let p_csr = RidgeProblem::new(op_csr.clone(), b.clone(), 0.5);
            let v: Vec<f64> = (0..d).map(|i| (i as f64 * 0.02).cos()).collect();
            let tm_dense = timed(
                &mut cases,
                &format!("cg matvec dense (density {pct})"),
                (n, d, 0),
                1,
                reps * 3,
                || {
                    std::hint::black_box(p_dense.hessian_vec(&v));
                },
            );
            let tm_csr = timed(
                &mut cases,
                &format!("cg matvec csr (density {pct})"),
                (n, d, 0),
                1,
                reps * 3,
                || {
                    std::hint::black_box(p_csr.hessian_vec(&v));
                },
            );
            derived.push((
                format!("csr_speedup_matvec_density{pct}"),
                Json::from(tm_dense / tm_csr),
            ));

            // End-to-end: adaptive-sparse solve on both storages (cheap
            // gradient-norm stop — no oracle solve in the timing).
            let spec: SolverSpec = "adaptive-sparse".parse().unwrap();
            let stop = StopRule::GradientNorm { tol: 1e-8 };
            let x0 = vec![0.0; d];
            let solve_time = |p: &RidgeProblem| {
                let mut times = Vec::new();
                for i in 0..reps {
                    let solver = spec.build(60 + i as u64);
                    let t0 = Instant::now();
                    let sol = solver.solve(p, &x0, &stop);
                    times.push(t0.elapsed().as_secs_f64());
                    assert!(sol.report.converged, "adaptive-sparse must converge in the bench");
                }
                summarize(&times)
            };
            let ts_dense = solve_time(&p_dense);
            let ts_csr = solve_time(&p_csr);
            println!(
                "{:<44} {:>10.3} ms dense vs {:>10.3} ms csr",
                "adaptive-sparse end-to-end solve",
                ts_dense.mean * 1e3,
                ts_csr.mean * 1e3
            );
            cases.push(Case {
                name: format!("adaptive-sparse solve dense (density {pct})"),
                n,
                d,
                m: 0,
                threads: 1,
                mean_s: ts_dense.mean,
                min_s: ts_dense.min,
            });
            cases.push(Case {
                name: format!("adaptive-sparse solve csr (density {pct})"),
                n,
                d,
                m: 0,
                threads: 1,
                mean_s: ts_csr.mean,
                min_s: ts_csr.min,
            });
            derived.push((
                format!("csr_speedup_adaptive_solve_density{pct}"),
                Json::from(ts_dense.mean / ts_csr.mean),
            ));
            println!();
        });
    }

    // Block multi-RHS serving throughput (§Serving acceptance): k
    // alternate right-hand sides against one registered model — k looped
    // `solve_rhs` calls (matvec / BLAS-2 intensity) vs one `solve_block`
    // (GEMM over the d x k panel / BLAS-3). Both paths resume the SAME
    // grown session sketch (one warmup solve builds it), so the ratio
    // isolates the iteration arithmetic intensity, not sketch growth.
    {
        let (n, d) = if smoke { (512usize, 64usize) } else { (4096usize, 256usize) };
        let reps = if smoke { 2 } else { 5 };
        let ds = synthetic::exponential_decay(n, d, 5);
        let (nu, eps) = (0.5, 1e-8);
        println!("--- block multi-RHS (n = {n}, d = {d}) ---");
        for &k in &[8usize, 64] {
            let bs: Vec<Vec<f64>> = (0..k)
                .map(|j| {
                    (0..n).map(|i| ((i as f64 * 0.013 + j as f64) * 0.37).sin()).collect()
                })
                .collect();
            let mut sess =
                ModelSession::new(Arc::new(ds.a.clone()), ds.b.clone(), SketchKind::Gaussian, 7)
                    .unwrap();
            sess.solve(nu, eps).unwrap(); // grow the shared sketch once
            let m = sess.m();
            let t_loop = timed(
                &mut cases,
                &format!("rhs looped solve_rhs (k={k})"),
                (n, d, m),
                default_threads,
                reps,
                || {
                    for b in &bs {
                        std::hint::black_box(sess.solve_rhs(nu, b, eps).unwrap());
                    }
                },
            );
            let t_block = timed(
                &mut cases,
                &format!("rhs block solve_block (k={k})"),
                (n, d, m),
                default_threads,
                reps,
                || {
                    let sols = sess.solve_block(nu, &bs, eps).unwrap();
                    assert!(
                        sols.iter().all(|s| s.report.converged),
                        "block solve must converge in the bench"
                    );
                    std::hint::black_box(sols);
                },
            );
            derived.push((format!("block_rhs_speedup_k{k}"), Json::from(t_loop / t_block)));
            println!("    block multi-RHS speedup (k={k}): {:.2}x", t_loop / t_block);
        }
        println!();
    }

    // Streaming-append serving cost (§Streaming acceptance): `dn` new
    // rows arrive at a warmed model. The append path pays sketch-the-delta
    // + factor refresh + a warm-started re-solve; the scratch path pays a
    // full re-register (operand copy, sketch grown from m = 1) + cold
    // query of the concatenated data. For dn << n the ratio must exceed 1
    // for every sketch family (CI greps the derived columns). Sessions
    // are built and warmed OUTSIDE the append timer so it measures the
    // incremental update, never the initial growth.
    {
        let (n, d, dn) = if smoke { (512usize, 64usize, 32usize) } else { (8192, 256, 64) };
        let reps = if smoke { 2 } else { 5 };
        let (nu, eps) = (0.5, 1e-8);
        let mut rng = Xoshiro256::seed_from_u64(6);
        let full = Matrix::from_fn(n + dn, d, |_, _| rng.next_gaussian());
        let b_full: Vec<f64> = (0..n + dn).map(|i| (i as f64 * 0.011).sin()).collect();
        let base = Matrix::from_fn(n, d, |i, j| full.get(i, j));
        let delta = Matrix::from_fn(dn, d, |i, j| full.get(n + i, j));
        let b_base = b_full[..n].to_vec();
        let b_delta = b_full[n..].to_vec();
        println!("--- streaming append (n = {n}, d = {d}, dn = {dn}) ---");
        for kind in [SketchKind::Gaussian, SketchKind::Srht, SketchKind::Sparse] {
            let t_append = {
                let mut times = Vec::new();
                for i in 0..reps {
                    let mut sess = ModelSession::new(
                        Arc::new(Operand::Dense(base.clone())),
                        b_base.clone(),
                        kind,
                        70 + i as u64,
                    )
                    .unwrap();
                    sess.solve(nu, eps).unwrap(); // warm: grow the sketch once
                    let t0 = Instant::now();
                    sess.append(
                        Operand::Dense(delta.clone()),
                        b_delta.clone(),
                        AppendRefresh::Eager,
                    )
                    .unwrap();
                    std::hint::black_box(sess.solve(nu, eps).unwrap());
                    times.push(t0.elapsed().as_secs_f64());
                }
                let s = summarize(&times);
                cases.push(Case {
                    name: format!("append {dn} rows + query ({kind})"),
                    n,
                    d,
                    m: 0,
                    threads: default_threads,
                    mean_s: s.mean,
                    min_s: s.min,
                });
                println!(
                    "{:<44} {:>10.3} ms",
                    format!("append {dn} rows + query ({kind})"),
                    s.mean * 1e3
                );
                s.mean
            };
            let t_scratch = {
                let mut times = Vec::new();
                for i in 0..reps {
                    let t0 = Instant::now();
                    let mut sess = ModelSession::new(
                        Arc::new(Operand::Dense(full.clone())),
                        b_full.clone(),
                        kind,
                        70 + i as u64,
                    )
                    .unwrap();
                    std::hint::black_box(sess.solve(nu, eps).unwrap());
                    times.push(t0.elapsed().as_secs_f64());
                }
                let s = summarize(&times);
                cases.push(Case {
                    name: format!("re-register + query ({kind})"),
                    n: n + dn,
                    d,
                    m: 0,
                    threads: default_threads,
                    mean_s: s.mean,
                    min_s: s.min,
                });
                println!(
                    "{:<44} {:>10.3} ms",
                    format!("re-register + query ({kind})"),
                    s.mean * 1e3
                );
                s.mean
            };
            derived.push((format!("append_speedup_{kind}"), Json::from(t_scratch / t_append)));
            println!("    append speedup ({kind}): {:.2}x", t_scratch / t_append);
        }
        println!();
    }

    // Degraded-mode serving overhead (§Robustness acceptance): the same
    // re-key query answered once through the healthy path and once with
    // an injected factor breakdown, so the recovery ladder's re-sketch
    // rung carries the solve. `degraded_solve_overhead` = degraded mean /
    // clean mean — the price of answering through the ladder instead of
    // failing the query (CI greps the column; benches are single-
    // threaded, so arming the process-global failpoint here is safe).
    {
        use effdim::solvers::error::RecoveryRung;
        use effdim::util::failpoint::{self, Action};
        let (n, d) = if smoke { (512usize, 64usize) } else { (2048usize, 256usize) };
        let reps = if smoke { 2 } else { 5 };
        let ds = synthetic::exponential_decay(n, d, 8);
        let (nu0, nu1, eps) = (0.5, 1.0, 1e-8);
        println!("--- degraded-mode overhead (n = {n}, d = {d}) ---");
        let mut rekey_time = |degraded: bool, label: &str| {
            let mut times = Vec::new();
            for i in 0..reps {
                let mut sess = ModelSession::new(
                    Arc::new(ds.a.clone()),
                    ds.b.clone(),
                    SketchKind::Gaussian,
                    80 + i as u64,
                )
                .unwrap();
                sess.solve(nu0, eps).unwrap(); // grow the shared sketch once
                if degraded {
                    failpoint::arm("woodbury.factor", Action::Error, 1);
                }
                let t0 = Instant::now();
                let sol = sess.solve(nu1, eps).unwrap();
                times.push(t0.elapsed().as_secs_f64());
                let want = if degraded { RecoveryRung::Resketch } else { RecoveryRung::None };
                assert_eq!(
                    sol.report.recovery, want,
                    "degraded-mode bench must exercise the intended ladder rung"
                );
            }
            failpoint::disarm_all();
            let s = summarize(&times);
            cases.push(Case {
                name: label.into(),
                n,
                d,
                m: 0,
                threads: default_threads,
                mean_s: s.mean,
                min_s: s.min,
            });
            println!("{label:<44} {:>10.3} ms", s.mean * 1e3);
            s.mean
        };
        let t_clean = rekey_time(false, "re-key query (healthy)");
        let t_degraded = rekey_time(true, "re-key query (injected breakdown, resketch)");
        derived.push(("degraded_solve_overhead".to_string(), Json::from(t_degraded / t_clean)));
        println!(
            "    degraded_solve_overhead (resketch vs healthy re-key): {:.2}x\n",
            t_degraded / t_clean
        );
    }

    // Crash-recovery replay cost (§Durability acceptance): a durable
    // model — warmed snapshot plus a WAL tail of streamed appends — is
    // recovered (snapshot decode, sketch replay from the compact header,
    // WAL replay, first warm query) and raced against a cold re-register
    // + first query of the same final data. The snapshot stores only the
    // sketch's replay header, so recovery re-derives `S~A` — but at the
    // final m in one pass, with the warm start and solver state restored,
    // instead of re-paying the adaptive growth ladder and cold
    // iterations. `recovery_replay_speedup` = cold mean / recovery mean.
    {
        use effdim::coordinator::registry::{Registry, DEFAULT_BYTE_BUDGET};
        use effdim::persist::{DurabilityPolicy, Store};
        let (n, d, dn) = if smoke { (512usize, 64usize, 16usize) } else { (8192, 256, 64) };
        let reps = if smoke { 2 } else { 5 };
        let (nu, eps) = (0.5, 1e-8);
        let mut rng = Xoshiro256::seed_from_u64(9);
        let total = n + 4 * dn;
        let full = Matrix::from_fn(total, d, |_, _| rng.next_gaussian());
        let b_full: Vec<f64> = (0..total).map(|i| (i as f64 * 0.011).sin()).collect();
        let base = Matrix::from_fn(n, d, |i, j| full.get(i, j));
        let b_base = b_full[..n].to_vec();
        println!("--- crash-recovery replay (n = {n}, d = {d}, 4 x {dn} WAL appends) ---");
        let t_recover = {
            let mut times = Vec::new();
            for i in 0..reps {
                let dir = std::env::temp_dir()
                    .join(format!("effdim-bench-recovery-{}-{i}", std::process::id()));
                let _ = std::fs::remove_dir_all(&dir);
                // Untimed setup: register, warm, snapshot, stream the WAL
                // tail, crash (drop without a closing snapshot).
                let id = {
                    let store = Arc::new(Store::open(&dir, DurabilityPolicy::Strict).unwrap());
                    let reg = Registry::with_store(DEFAULT_BYTE_BUDGET, Arc::clone(&store));
                    let entry = reg
                        .register(
                            "bench".into(),
                            Operand::Dense(base.clone()),
                            b_base.clone(),
                            SketchKind::Gaussian,
                            90 + i as u64,
                        )
                        .unwrap();
                    {
                        let mut s = entry.session.lock().unwrap();
                        s.solve(nu, eps).unwrap(); // grow the sketch once
                    }
                    reg.persist_all(Some(entry.id)).unwrap();
                    for k in 0..4 {
                        let lo = n + k * dn;
                        let da = Matrix::from_fn(dn, d, |r, c| full.get(lo + r, c));
                        let db = b_full[lo..lo + dn].to_vec();
                        let mut s = entry.session.lock().unwrap();
                        store
                            .append_record(entry.id, &Operand::Dense(da.clone()), &db, true)
                            .unwrap();
                        s.append(Operand::Dense(da), db, AppendRefresh::Eager).unwrap();
                    }
                    entry.id
                };
                let t0 = Instant::now();
                let store = Arc::new(Store::open(&dir, DurabilityPolicy::Strict).unwrap());
                let reg = Registry::with_store(DEFAULT_BYTE_BUDGET, store);
                assert_eq!(reg.recover().unwrap(), 1, "bench model must recover");
                let entry = reg.touch(id).unwrap();
                let sol = entry.session.lock().unwrap().solve(nu, eps).unwrap();
                times.push(t0.elapsed().as_secs_f64());
                assert!(sol.report.converged, "recovered model must converge");
                let _ = std::fs::remove_dir_all(&dir);
            }
            let s = summarize(&times);
            cases.push(Case {
                name: "recover crashed model + query".into(),
                n: total,
                d,
                m: 0,
                threads: default_threads,
                mean_s: s.mean,
                min_s: s.min,
            });
            println!("{:<44} {:>10.3} ms", "recover crashed model + query", s.mean * 1e3);
            s.mean
        };
        let t_cold = {
            let mut times = Vec::new();
            for i in 0..reps {
                let t0 = Instant::now();
                let mut sess = ModelSession::new(
                    Arc::new(Operand::Dense(full.clone())),
                    b_full.clone(),
                    SketchKind::Gaussian,
                    90 + i as u64,
                )
                .unwrap();
                std::hint::black_box(sess.solve(nu, eps).unwrap());
                times.push(t0.elapsed().as_secs_f64());
            }
            let s = summarize(&times);
            cases.push(Case {
                name: "cold re-register + query".into(),
                n: total,
                d,
                m: 0,
                threads: default_threads,
                mean_s: s.mean,
                min_s: s.min,
            });
            println!("{:<44} {:>10.3} ms", "cold re-register + query", s.mean * 1e3);
            s.mean
        };
        derived.push(("recovery_replay_speedup".to_string(), Json::from(t_cold / t_recover)));
        println!("    recovery replay speedup vs cold re-register: {:.2}x\n", t_cold / t_recover);
    }

    // Concurrent lock-free serving reads (§Serving acceptance): one
    // registered model with a warmed, published snapshot; T threads split
    // a fixed budget of repeat-`nu` queries, each answered entirely from
    // the snapshot handle (`entry.snapshot().cached(..)`) — the same path
    // the server's fast lane takes, session mutex never touched.
    // `concurrent_query_speedup_tT` = mean(1 thread) / mean(T threads)
    // over the same total work, so ideal scaling reads as ~T and a
    // serialized read path would read as ~1.
    {
        use effdim::coordinator::registry::{Registry, DEFAULT_BYTE_BUDGET};
        let (n, d) = if smoke { (512usize, 64usize) } else { (4096usize, 256usize) };
        let total_queries = if smoke { 20_000usize } else { 200_000usize };
        let reps = if smoke { 2 } else { 5 };
        let (nu, eps) = (0.5, 1e-8);
        let ds = synthetic::exponential_decay(n, d, 23);
        let reg = Registry::new(DEFAULT_BYTE_BUDGET);
        let entry = reg
            .register("bench".into(), ds.a, ds.b, SketchKind::Gaussian, 23)
            .unwrap();
        {
            let mut s = entry.session.lock().unwrap();
            s.solve(nu, eps).unwrap();
            entry.publish(&mut s).unwrap();
        }
        println!(
            "--- concurrent snapshot queries (n = {n}, d = {d}, {total_queries} repeat-nu reads) ---"
        );
        let mut t1 = f64::NAN;
        for t in [1usize, 2, 8] {
            let per_thread = total_queries / t;
            let mean = timed(
                &mut cases,
                &format!("snapshot cached query x{total_queries} (t={t})"),
                (n, d, 0),
                t,
                reps,
                || {
                    std::thread::scope(|scope| {
                        for _ in 0..t {
                            scope.spawn(|| {
                                for _ in 0..per_thread {
                                    let snap = entry.snapshot();
                                    let sol =
                                        snap.cached(nu, eps).expect("warmed solution published");
                                    std::hint::black_box(sol.x[0]);
                                }
                            });
                        }
                    });
                },
            );
            if t == 1 {
                t1 = mean;
            } else {
                derived.push((format!("concurrent_query_speedup_t{t}"), Json::from(t1 / mean)));
                println!("    concurrent_query_speedup_t{t}: {:.2}x", t1 / mean);
            }
        }
        println!();
    }

    // Frozen-lane uncached solve throughput (§Serving acceptance): T
    // threads each solving *distinct uncached* `nu` against one
    // registered model. The writer (mutex) lane serializes every solve
    // on the session lock; the frozen lane answers from the published
    // snapshot's pinned artifacts (`SessionSnapshot::solve_frozen`) with
    // no lock at all. `frozen_solve_speedup_tT` = mutex-lane wall time /
    // frozen-lane wall time over the same per-thread work, so lock-free
    // scaling reads as ~T and a hidden lock reads as ~1. Every query
    // draws a fresh `nu` above the warm point (smaller effective
    // dimension), so nothing is ever cached, the frozen m always
    // suffices, and both lanes pay a real gradient-IHS solve per call.
    {
        use effdim::coordinator::registry::{Registry, DEFAULT_BYTE_BUDGET};
        use effdim::solvers::adaptive::FrozenOutcome;
        use std::sync::atomic::{AtomicU64, Ordering};
        let (n, d) = if smoke { (512usize, 64usize) } else { (2048usize, 128usize) };
        let per_thread = if smoke { 4usize } else { 8 };
        let reps = if smoke { 2 } else { 5 };
        let (warm_nu, eps) = (0.5, 1e-8);
        let ds = synthetic::exponential_decay(n, d, 29);
        let reg = Registry::new(DEFAULT_BYTE_BUDGET);
        let entry = reg
            .register("bench".into(), ds.a, ds.b, SketchKind::Gaussian, 29)
            .unwrap();
        {
            let mut s = entry.session.lock().unwrap();
            s.solve(warm_nu, eps).unwrap();
            entry.publish(&mut s).unwrap();
        }
        let ticket = AtomicU64::new(0);
        let fresh_nu =
            |ticket: &AtomicU64| 0.6 + 0.003 * ticket.fetch_add(1, Ordering::Relaxed) as f64;
        println!(
            "--- frozen-lane uncached solves (n = {n}, d = {d}, {per_thread} distinct nus/thread) ---"
        );
        for t in [2usize, 8] {
            let t_mutex = timed(
                &mut cases,
                &format!("uncached solve mutex lane (t={t})"),
                (n, d, 0),
                t,
                reps,
                || {
                    std::thread::scope(|scope| {
                        for _ in 0..t {
                            scope.spawn(|| {
                                for _ in 0..per_thread {
                                    let nu = fresh_nu(&ticket);
                                    let sol = entry.session.lock().unwrap().solve(nu, eps).unwrap();
                                    assert!(sol.report.converged, "mutex-lane solve must converge");
                                    std::hint::black_box(sol.x[0]);
                                }
                            });
                        }
                    });
                },
            );
            let t_frozen = timed(
                &mut cases,
                &format!("uncached solve frozen lane (t={t})"),
                (n, d, 0),
                t,
                reps,
                || {
                    std::thread::scope(|scope| {
                        for _ in 0..t {
                            scope.spawn(|| {
                                let snap = entry.snapshot();
                                for _ in 0..per_thread {
                                    let nu = fresh_nu(&ticket);
                                    match snap
                                        .solve_frozen(nu, eps, None)
                                        .expect("snapshot has state")
                                        .unwrap()
                                    {
                                        FrozenOutcome::Solved(sol) => {
                                            std::hint::black_box(sol.x[0]);
                                        }
                                        FrozenOutcome::NeedsGrowth { .. } => {
                                            panic!("nu {nu} must fit the frozen m")
                                        }
                                    }
                                }
                            });
                        }
                    });
                },
            );
            derived.push((format!("frozen_solve_speedup_t{t}"), Json::from(t_mutex / t_frozen)));
            println!("    frozen_solve_speedup_t{t}: {:.2}x", t_mutex / t_frozen);
        }
        println!();
    }

    // Emit the JSON trajectory at the repo root (benches run from rust/).
    let out = Json::obj(vec![
        ("generated_by", Json::from("cargo bench --bench kernels")),
        ("threads_default", Json::from(default_threads)),
        ("cases", Json::Arr(cases.iter().map(Case::to_json).collect())),
        ("derived", Json::Obj(derived.into_iter().collect())),
    ]);
    let path = if std::path::Path::new("../ROADMAP.md").exists() {
        "../BENCH_kernels.json"
    } else {
        "BENCH_kernels.json"
    };
    std::fs::write(path, out.to_string()).expect("write BENCH_kernels.json");
    println!("\nwrote {path}");
}
