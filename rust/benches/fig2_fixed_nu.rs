//! Figure 2 (quick mode): fixed nu = 10 comparison.
//! Full runs: `cargo run --release --bin bench_figures -- fig2`.

use effdim::bench_harness::figures::{self, FigureConfig};

fn main() {
    let cfg = FigureConfig { n: 512, d: 64, trials: 3, eps: 1e-8, seed: 2 };
    let series = figures::fig2(&cfg);
    println!("{}", figures::render_table(&series));
    assert!(series.iter().all(|s| s.all_converged));
    // At nu = 10, d_e is small: adaptive sketch sizes must be far below
    // pCG's d log d / rho prescription.
    for s in &series {
        if s.solver.starts_with("adaptive") {
            let pcg_m = series
                .iter()
                .find(|t| t.dataset == s.dataset && t.solver.starts_with("pcg"))
                .unwrap()
                .m_mean[0];
            println!(
                "{} {}: m = {:.0} (pcg m = {:.0}, d_e = {:.1})",
                s.dataset, s.solver, s.m_mean[0], pcg_m, s.d_e[0]
            );
            assert!(s.m_mean[0] <= pcg_m, "adaptive must not out-size pCG at small d_e");
        }
    }
}
