//! Figure 3 (quick mode): synthetic exponential / polynomial decays.
//! Full runs: `cargo run --release --bin bench_figures -- fig3`.

use effdim::bench_harness::figures::{self, FigureConfig};

fn main() {
    let cfg = FigureConfig { n: 512, d: 64, trials: 2, eps: 1e-8, seed: 3 };
    let series = figures::fig3(&cfg);
    println!("{}", figures::render_table(&series));
    assert!(series.iter().all(|s| s.all_converged));
    // Appendix A.1's qualitative claim: on polynomial decay the Gaussian
    // adaptive variant pays for dense sketching; SRHT stays competitive.
    let poly_srht = series
        .iter()
        .find(|s| s.dataset == "synthetic-poly" && s.solver == "adaptive-srht")
        .unwrap();
    let poly_gauss = series
        .iter()
        .find(|s| s.dataset == "synthetic-poly" && s.solver == "adaptive-gaussian")
        .unwrap();
    println!(
        "poly decay: srht {:.3}s vs gaussian {:.3}s",
        poly_srht.cum_time_mean.last().unwrap(),
        poly_gauss.cum_time_mean.last().unwrap()
    );
}
