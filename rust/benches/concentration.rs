//! Theorems 3-4 (quick mode): empirical C_S eigenvalue brackets.
//! Full runs: `cargo run --release --bin bench_figures -- concentration`.

use effdim::bench_harness::concentration::{self, ConcentrationConfig};
use effdim::sketch::SketchKind;

fn main() {
    let cfg = ConcentrationConfig { n: 512, d: 32, nu: 0.5, trials: 10, seed: 4 };
    let mut rows = concentration::run(SketchKind::Gaussian, &[0.18, 0.1, 0.05], &cfg);
    rows.extend(concentration::run(SketchKind::Srht, &[0.5, 0.25], &cfg));
    println!("{}", concentration::render_table(&rows));
    // The brackets must hold for the overwhelming majority of draws.
    for r in &rows {
        assert!(
            r.inside_frac >= 0.8,
            "{} rho={} bracket violated too often: {}",
            r.kind,
            r.rho,
            r.inside_frac
        );
    }
    println!("all brackets hold (>= 80% of draws inside)");
}
