//! Runtime bridging L3 (Rust coordinator) to the AOT-compiled L2/L1
//! artifacts.
//!
//! Python (JAX + Pallas) runs exactly once, at build time: `make artifacts`
//! lowers the model's jitted functions to HLO *text* under `artifacts/`.
//! This module loads those files, compiles them on the PJRT CPU client
//! (`xla` crate) and executes them from the solve hot path — Python is
//! never on the request path.
//!
//! Two engines implement the per-iteration gradient oracle:
//! * [`native`] — pure-Rust, any shape (the default; also the reference
//!   the conformance tests compare against);
//! * [`pjrt`] (feature `xla-runtime`) — the AOT artifact, shape-specialized
//!   to the configured `(n, d)`, with `A` and `b` kept device-resident
//!   across iterations so each call only uploads the length-`d` iterate.

pub mod native;
#[cfg(feature = "xla-runtime")]
pub mod pjrt;

pub use native::NativeGradient;
#[cfg(feature = "xla-runtime")]
pub use pjrt::{ArtifactManifest, PjrtRuntime, XlaGradient};

/// Default artifacts directory (relative to the repo root).
pub const DEFAULT_ARTIFACTS_DIR: &str = "artifacts";

/// A gradient oracle: computes `∇f(x) = A^T A x + nu^2 x - A^T b`.
pub trait GradientOracle {
    /// Evaluate the gradient at `x`.
    fn gradient(&self, x: &[f64]) -> Vec<f64>;
    /// Human-readable backend label for reports.
    fn backend(&self) -> &'static str;
}
