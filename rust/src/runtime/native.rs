//! Native (pure-Rust) gradient engine — the shape-generic reference
//! implementation of the per-iteration hot op.

use super::GradientOracle;
use crate::solvers::RidgeProblem;

/// Wraps a [`RidgeProblem`]'s own gradient as a [`GradientOracle`].
pub struct NativeGradient<'p> {
    problem: &'p RidgeProblem,
}

impl<'p> NativeGradient<'p> {
    /// Wrap a problem's gradient as an oracle.
    pub fn new(problem: &'p RidgeProblem) -> Self {
        Self { problem }
    }
}

impl GradientOracle for NativeGradient<'_> {
    fn gradient(&self, x: &[f64]) -> Vec<f64> {
        self.problem.gradient(x)
    }

    fn backend(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn oracle_matches_problem_gradient() {
        let ds = synthetic::exponential_decay(64, 8, 1);
        let p = RidgeProblem::new(ds.a, ds.b, 0.5);
        let oracle = NativeGradient::new(&p);
        let x: Vec<f64> = (0..8).map(|i| (i as f64 * 0.2).sin()).collect();
        assert_eq!(oracle.gradient(&x), p.gradient(&x));
        assert_eq!(oracle.backend(), "native");
    }
}
