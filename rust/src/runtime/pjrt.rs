//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute from
//! the solve hot path.
//!
//! Interchange is HLO *text* (see `python/compile/aot.py`): jax >= 0.5
//! serializes protos with 64-bit instruction ids that xla_extension 0.5.1
//! rejects; `HloModuleProto::from_text_file` reassigns ids and round-trips
//! cleanly.
//!
//! Artifacts are f32 (the Pallas kernels target the TPU MXU); the native
//! solver state is f64. The [`XlaGradient`] oracle downcasts the iterate,
//! runs the fused-gradient module on device-resident `A`/`b` buffers, and
//! upcasts the result — mixed precision that caps achievable relative
//! error around 1e-6, which the end-to-end example accounts for in its
//! stop rule.

use super::GradientOracle;
use crate::solvers::RidgeProblem;
use crate::util::json::{self, Json};
use std::path::{Path, PathBuf};

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct ArtifactManifest {
    /// Problem rows the artifacts were compiled for.
    pub n: usize,
    /// Problem columns the artifacts were compiled for.
    pub d: usize,
    /// Sketch sizes with a compiled artifact.
    pub m_list: Vec<usize>,
    /// Artifact file names, parallel to `m_list`.
    pub artifacts: Vec<String>,
}

impl ArtifactManifest {
    /// Parse the manifest JSON (see `python/` for the generator).
    pub fn parse(text: &str) -> Result<Self, String> {
        let v = json::parse(text).map_err(|e| e.to_string())?;
        let n = v.get("n").and_then(Json::as_usize).ok_or("manifest missing n")?;
        let d = v.get("d").and_then(Json::as_usize).ok_or("manifest missing d")?;
        let m_list = v
            .get("m_list")
            .and_then(Json::as_arr)
            .ok_or("manifest missing m_list")?
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        let artifacts = v
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or("manifest missing artifacts")?
            .iter()
            .filter_map(|a| a.get("name").and_then(Json::as_str).map(String::from))
            .collect();
        Ok(Self { n, d, m_list, artifacts })
    }
}

/// PJRT CPU client plus the artifact directory.
///
/// NOTE: the `xla` crate's client is `Rc`-based, so the runtime (and any
/// oracle built from it) is pinned to one thread; the coordinator keeps
/// XLA-backed solves on the worker that created the runtime.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    dir: PathBuf,
    /// The artifact manifest loaded from the directory.
    pub manifest: ArtifactManifest,
}

impl PjrtRuntime {
    /// Load the manifest from `dir` and create the CPU client.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self, String> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .map_err(|e| format!("cannot read {}: {e} (run `make artifacts`)", manifest_path.display()))?;
        let manifest = ArtifactManifest::parse(&text)?;
        let client = xla::PjRtClient::cpu().map_err(|e| format!("PJRT cpu client: {e:?}"))?;
        Ok(Self { client, dir, manifest })
    }

    /// Whether the given artifact exists in the manifest.
    pub fn has(&self, name: &str) -> bool {
        self.manifest.artifacts.iter().any(|a| a == name)
    }

    /// Load + compile an artifact by name (compilation happens once per
    /// oracle; oracles are long-lived).
    pub fn executable(&self, name: &str) -> Result<xla::PjRtLoadedExecutable, String> {
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .map_err(|e| format!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client.compile(&comp).map_err(|e| format!("compile {name}: {e:?}"))
    }

    /// Build the fused-gradient oracle for `problem`; fails if the
    /// artifact shapes don't match the problem.
    pub fn gradient_oracle(&self, problem: &RidgeProblem) -> Result<XlaGradient, String> {
        let (n, d) = (problem.n(), problem.d());
        if (n, d) != (self.manifest.n, self.manifest.d) {
            return Err(format!(
                "artifact shapes ({}, {}) != problem shapes ({n}, {d}); regenerate with \
                 `make artifacts N={n} D={d}`",
                self.manifest.n, self.manifest.d
            ));
        }
        let name = format!("gradient_n{n}_d{d}");
        let exe = self.executable(&name)?;

        // Device-resident constants: A (f32), b (f32), nu^2. The artifact
        // is a dense kernel, so CSR operands densify once at upload time.
        let to_f32 = |v: &[f64]| v.iter().map(|&x| x as f32).collect::<Vec<f32>>();
        let a32 = to_f32(problem.a.dense().as_slice());
        let b32 = to_f32(problem.b.as_ref().expect("XLA oracle needs raw b"));
        let a_buf = self
            .client
            .buffer_from_host_buffer(&a32, &[n, d], None)
            .map_err(|e| format!("upload A: {e:?}"))?;
        let b_buf = self
            .client
            .buffer_from_host_buffer(&b32, &[n], None)
            .map_err(|e| format!("upload b: {e:?}"))?;
        let nu2 = [(problem.nu * problem.nu) as f32];
        let nu2_buf = self
            .client
            .buffer_from_host_buffer(&nu2, &[1], None)
            .map_err(|e| format!("upload nu2: {e:?}"))?;

        Ok(XlaGradient { client: self.client.clone(), exe, a_buf, b_buf, nu2_buf, d })
    }
}

/// Gradient oracle executing the AOT fused-gradient artifact.
///
/// `A`, `b`, `nu^2` stay device-resident; each call uploads only the
/// length-`d` iterate and downloads the length-`d` gradient.
pub struct XlaGradient {
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
    a_buf: xla::PjRtBuffer,
    b_buf: xla::PjRtBuffer,
    nu2_buf: xla::PjRtBuffer,
    d: usize,
}

impl XlaGradient {
    /// Raw f32 gradient call.
    pub fn gradient_f32(&self, x: &[f32]) -> Result<Vec<f32>, String> {
        assert_eq!(x.len(), self.d);
        let x_buf = self
            .client
            .buffer_from_host_buffer(x, &[self.d], None)
            .map_err(|e| format!("upload x: {e:?}"))?;
        // Lowered with return_tuple=True: unwrap the 1-tuple.
        let out = self
            .exe
            .execute_b(&[&self.a_buf, &x_buf, &self.b_buf, &self.nu2_buf])
            .map_err(|e| format!("execute gradient: {e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| format!("download gradient: {e:?}"))?;
        let lit = lit.to_tuple1().map_err(|e| format!("untuple: {e:?}"))?;
        lit.to_vec::<f32>().map_err(|e| format!("to_vec: {e:?}"))
    }
}

impl GradientOracle for XlaGradient {
    fn gradient(&self, x: &[f64]) -> Vec<f64> {
        let x32: Vec<f32> = x.iter().map(|&v| v as f32).collect();
        let g32 = self.gradient_f32(&x32).expect("XLA gradient execution failed");
        g32.into_iter().map(|v| v as f64).collect()
    }

    fn backend(&self) -> &'static str {
        "pjrt-xla"
    }
}
