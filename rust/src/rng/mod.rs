//! Deterministic random-number substrate.
//!
//! Sketching algorithms are only reproducible if every random draw is
//! seeded and stream-split explicitly, so we implement a small, fully
//! deterministic stack instead of pulling in `rand`:
//!
//! * [`Xoshiro256`] — xoshiro256++ core generator (Blackman & Vigna),
//!   seeded through SplitMix64 so that *any* `u64` seed yields a
//!   well-mixed state.
//! * Gaussian variates via the polar (Marsaglia) method.
//! * Rademacher (±1) variates for SRHT sign flips and Hutchinson probes.

/// SplitMix64 step — used to expand a single `u64` seed into generator
/// state and to derive independent child seeds.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ generator. Period 2^256 − 1, passes BigCrush.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
    /// Cached second Gaussian variate from the polar method.
    gauss_spare: Option<f64>,
}

impl Xoshiro256 {
    /// Create a generator from a `u64` seed (SplitMix64-expanded).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Self { s, gauss_spare: None }
    }

    /// Derive an independent child generator (for per-trial / per-sketch
    /// streams). Uses the current stream to produce a fresh seed, then
    /// SplitMix64-expands it, so children of distinct indices are
    /// decorrelated.
    pub fn split(&mut self, index: u64) -> Xoshiro256 {
        let base = self.next_u64() ^ index.wrapping_mul(0xA24B_AED4_963E_E407);
        Xoshiro256::seed_from_u64(base)
    }

    /// Export the full generator state for persistence
    /// ([`crate::persist`]). The cached polar-method spare is part of the
    /// state: dropping it would shift every subsequent Gaussian draw by
    /// one, breaking bitwise replay of a sketch stream.
    pub fn state(&self) -> ([u64; 4], Option<f64>) {
        (self.s, self.gauss_spare)
    }

    /// Rebuild a generator from an exported [`Self::state`] — the inverse
    /// of `state()`: the restored stream continues draw for draw where the
    /// exported one stopped.
    pub fn from_state(s: [u64; 4], gauss_spare: Option<f64>) -> Self {
        Self { s, gauss_spare }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)` (Lemire rejection, unbiased).
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "bound must be positive");
        // Rejection sampling on the top bits: unbiased and branch-cheap.
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u64();
            let (hi, lo) = {
                let wide = (r as u128) * (bound as u128);
                ((wide >> 64) as u64, wide as u64)
            };
            if lo >= threshold {
                return hi;
            }
        }
    }

    /// Standard Gaussian via the Marsaglia polar method (caches the spare).
    pub fn next_gaussian(&mut self) -> f64 {
        if let Some(g) = self.gauss_spare.take() {
            return g;
        }
        loop {
            let u = 2.0 * self.next_f64() - 1.0;
            let v = 2.0 * self.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.gauss_spare = Some(v * f);
                return u * f;
            }
        }
    }

    /// Rademacher variate: ±1 with equal probability.
    #[inline]
    pub fn next_rademacher(&mut self) -> f64 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Fill a slice with i.i.d. `N(0, sigma^2)` entries.
    pub fn fill_gaussian(&mut self, out: &mut [f64], sigma: f64) {
        for x in out.iter_mut() {
            *x = sigma * self.next_gaussian();
        }
    }

    /// Fill a slice with i.i.d. Rademacher signs.
    pub fn fill_rademacher(&mut self, out: &mut [f64]) {
        for x in out.iter_mut() {
            *x = self.next_rademacher();
        }
    }

    /// Sample `m` distinct indices uniformly from `{0, .., n-1}` via a
    /// partial Fisher–Yates shuffle — O(n) memory, O(m) swaps. Used by the
    /// SRHT row-subsampling step (sampling *without* replacement).
    pub fn sample_without_replacement(&mut self, n: usize, m: usize) -> Vec<usize> {
        assert!(m <= n, "cannot sample {m} of {n} without replacement");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..m {
            let j = i + self.next_below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(m);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Xoshiro256::seed_from_u64(42);
        let mut b = Xoshiro256::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_seeds_differ() {
        let mut a = Xoshiro256::seed_from_u64(1);
        let mut b = Xoshiro256::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Xoshiro256::seed_from_u64(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.next_f64();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 5e-3);
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Xoshiro256::seed_from_u64(11);
        let n = 200_000;
        let (mut m1, mut m2, mut m4) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let g = r.next_gaussian();
            m1 += g;
            m2 += g * g;
            m4 += g * g * g * g;
        }
        let nf = n as f64;
        assert!((m1 / nf).abs() < 0.01, "mean {}", m1 / nf);
        assert!((m2 / nf - 1.0).abs() < 0.02, "var {}", m2 / nf);
        assert!((m4 / nf - 3.0).abs() < 0.1, "kurtosis {}", m4 / nf);
    }

    #[test]
    fn rademacher_balance() {
        let mut r = Xoshiro256::seed_from_u64(3);
        let n = 100_000;
        let sum: f64 = (0..n).map(|_| r.next_rademacher()).sum();
        assert!(sum.abs() / (n as f64) < 0.01);
    }

    #[test]
    fn next_below_unbiased_and_in_range() {
        let mut r = Xoshiro256::seed_from_u64(5);
        let bound = 7u64;
        let mut counts = [0usize; 7];
        let n = 70_000;
        for _ in 0..n {
            let v = r.next_below(bound);
            assert!(v < bound);
            counts[v as usize] += 1;
        }
        let expect = n as f64 / bound as f64;
        for c in counts {
            assert!((c as f64 - expect).abs() < 0.06 * expect, "count {c}");
        }
    }

    #[test]
    fn sample_without_replacement_distinct() {
        let mut r = Xoshiro256::seed_from_u64(9);
        let idx = r.sample_without_replacement(100, 40);
        assert_eq!(idx.len(), 40);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 40, "indices must be distinct");
        assert!(sorted.iter().all(|&i| i < 100));
    }

    #[test]
    fn split_streams_decorrelated() {
        let mut root = Xoshiro256::seed_from_u64(123);
        let mut c1 = root.split(0);
        let mut c2 = root.split(1);
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert_eq!(same, 0);
    }
}
