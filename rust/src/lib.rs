//! # effdim — Effective Dimension Adaptive Sketching for Regularized Least-Squares
//!
//! A production-quality reproduction of *"Effective Dimension Adaptive
//! Sketching Methods for Faster Regularized Least-Squares Optimization"*
//! (Lacotte & Pilanci, NeurIPS 2020), built as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! The library solves
//! ```text
//! x* = argmin_x  1/2 ||A x - b||^2 + nu^2/2 ||x||^2
//! ```
//! via the **adaptive Iterative Hessian Sketch** (Algorithm 1 of the paper):
//! a Polyak/gradient heavy-ball iteration preconditioned by the sketched
//! Hessian `H_S = (SA)^T (SA) + nu^2 I`, whose sketch size `m` starts at 1
//! and doubles only when the *sketched Newton decrement* shows insufficient
//! progress — so `m` never exceeds `O(d_e)` where
//! `d_e = trace(A (A^T A + nu^2 I)^{-1} A^T)` is the effective dimension.
//!
//! ## Layout
//! * [`linalg`] — dense linear-algebra substrate (blocked GEMM, Cholesky,
//!   Householder QR, Golub–Kahan SVD, triangular solves).
//! * [`rng`] — deterministic xoshiro256++ RNG with Gaussian / Rademacher
//!   streams.
//! * [`sketch`] — Gaussian, SRHT (fast Walsh–Hadamard) and sparse
//!   (CountSketch) embeddings.
//! * [`theory`] — closed-form convergence rates, step sizes and the
//!   concentration bounds of Theorems 3–7.
//! * [`data`] — synthetic workload generators matching the paper's
//!   experimental section (exp/poly spectral decays, MNIST/CIFAR-like
//!   surrogates).
//! * [`solvers`] — direct Cholesky, CG, preconditioned CG, fixed-size IHS,
//!   **adaptive IHS (Algorithm 1)**, dual solver, regularization-path
//!   driver.
//! * [`runtime`] — PJRT executor for AOT-compiled JAX/Pallas artifacts plus
//!   a shape-generic native backend.
//! * [`coordinator`] — the L3 service: job scheduler, solve state machine,
//!   event bus, metrics, tokio TCP server.
//! * [`bench_harness`] — regenerates every figure/table of the paper.

pub mod bench_harness;
pub mod coordinator;
pub mod data;
pub mod linalg;
pub mod rng;
pub mod runtime;
pub mod sketch;
pub mod solvers;
pub mod theory;
pub mod util;

pub use linalg::matrix::Matrix;
pub use solvers::adaptive::{AdaptiveConfig, AdaptiveSolver, AdaptiveVariant};
pub use solvers::{RidgeProblem, SolveReport};
