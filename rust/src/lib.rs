//! # effdim — Effective Dimension Adaptive Sketching for Regularized Least-Squares
//!
//! A production-quality reproduction of *"Effective Dimension Adaptive
//! Sketching Methods for Faster Regularized Least-Squares Optimization"*
//! (Lacotte & Pilanci, NeurIPS 2020), built as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! The library solves
//! ```text
//! x* = argmin_x  1/2 ||A x - b||^2 + nu^2/2 ||x||^2
//! ```
//! via the **adaptive Iterative Hessian Sketch** (Algorithm 1 of the paper):
//! a Polyak/gradient heavy-ball iteration preconditioned by the sketched
//! Hessian `H_S = (SA)^T (SA) + nu^2 I`, whose sketch size `m` starts at 1
//! and doubles only when the *sketched Newton decrement* shows insufficient
//! progress — so `m` never exceeds `O(d_e)` where
//! `d_e = trace(A (A^T A + nu^2 I)^{-1} A^T)` is the effective dimension.
//!
//! ## The unified solver API
//!
//! Every solver — direct Cholesky, CG, preconditioned CG, fixed-size IHS,
//! adaptive IHS, the dual reduction — is named by a
//! [`SolverSpec`](solvers::SolverSpec) string and run through the
//! [`Solver`](solvers::Solver) trait:
//!
//! ```no_run
//! use effdim::solvers::{direct, RidgeProblem, Solver as _, SolverSpec, StopRule};
//! # let (a, b) = (effdim::Matrix::eye(8), vec![1.0; 8]);
//! let problem = RidgeProblem::new(a, b, 0.5);
//! let stop = StopRule::TrueError { x_star: direct::solve(&problem), eps: 1e-10 };
//! let spec: SolverSpec = "adaptive-srht".parse().unwrap();
//! let solution = spec.build(7).solve(&problem, &vec![0.0; problem.d()], &stop);
//! assert!(solution.report.converged);
//! ```
//!
//! Spec strings follow `name[@key=value,...]` — `"cg"`, `"pcg-gaussian"`,
//! `"ihs-sparse@m=256"`, `"dual-adaptive-gaussian"`,
//! `"adaptive-srht@threads=8"` — and round-trip through
//! `Display`/`FromStr`. [`solvers::registry`] lists every entry;
//! the CLI (`effdim solvers`), the coordinator (`{"cmd":"solvers"}`), the
//! regularization-path driver and the bench harness all dispatch through
//! this one surface.
//!
//! ## Sparse operands: `O(nnz)` end to end
//!
//! The data matrix inside a [`RidgeProblem`] is an
//! [`Operand`](linalg::Operand) — dense [`Matrix`] or CSR
//! [`CsrMatrix`](linalg::sparse::CsrMatrix) — and *every* layer
//! dispatches on the variant: gradients / Hessian products / prediction
//! errors, CountSketch application (`O(nnz)`), Gaussian sketching
//! (`O(m·nnz)` sparse row-axpy), SRHT (an `O(nnz)` scatter into the
//! cached FWHT buffer), the incremental growth engine, the CLI
//! (`--profile sparse --density 0.01`, `--data <triplet file>`) and the
//! coordinator (`"profile":"sparse"`, `"density"`, inline `"triplets"`).
//! On a 1%-dense matrix the dominant per-iteration and per-sketch terms
//! drop by ~100x, while dense inputs keep the exact kernels they always
//! had:
//!
//! ```no_run
//! use effdim::data::synthetic;
//! use effdim::solvers::{direct, RidgeProblem, Solver as _, SolverSpec, StopRule};
//! // 1%-dense CSR workload; same API as the dense generators.
//! let ds = synthetic::sparse_gaussian(4096, 512, 0.01, 7);
//! let problem = RidgeProblem::new(ds.a, ds.b, 0.5);
//! let stop = StopRule::GradientNorm { tol: 1e-8 };
//! let spec: SolverSpec = "adaptive-sparse".parse().unwrap();
//! let solution = spec.build(1).solve(&problem, &vec![0.0; problem.d()], &stop);
//! assert!(solution.report.converged);
//! ```
//!
//! See EXPERIMENTS.md §Sparse for the measured dense-vs-CSR speedups
//! (`csr_speedup_*` in `BENCH_kernels.json`).
//!
//! ## Performance: parallel kernels and incremental sketch growth
//!
//! The dense hot paths (GEMM, Gram products, row-FWHT) are row-parallel
//! over `std::thread::scope` workers behind the [`linalg::threads`] knob:
//! per-solve `@threads=k` spec param > [`linalg::threads::set_global_threads`]
//! > `PALLAS_THREADS` env var > hardware parallelism. See
//! `EXPERIMENTS.md` §Perf for the measured numbers (`cargo bench --bench
//! kernels` refreshes `BENCH_kernels.json`).
//!
//! Adaptive sketch growth is *incremental*: [`sketch::engine::SketchEngine`]
//! appends `Δm` rows (Gaussian: fresh rows, `O(Δm n d)`; SRHT: rows of a
//! once-per-problem FWHT buffer, `O(Δm d)`; sparse: a size-weighted
//! CountSketch block, `O(nnz)`) and
//! [`solvers::woodbury::WoodburyCache::grow`] reuses the cached Gram
//! blocks, so a rejection round of Algorithm 1 pays `O(Δm)`-proportional
//! work — the regime Theorem 7's cost decomposition assumes. Grown
//! sketches are prefix-consistent (old rows are never rescaled; the
//! `1/sqrt(m)` normalization is folded into the Woodbury solve).
//!
//! All iterative inner loops (`cg`, `pcg`, `ihs`, `adaptive`) run on
//! preallocated workspace buffers: the solver-level code performs zero
//! heap allocation per steady-state iteration (pinned by the counting-
//! allocator test `tests/alloc_free.rs`). Exceptions, by design: sketch
//! growth rounds, external PJRT oracles, and — above the
//! [`linalg::threads::worth_parallelizing`] threshold — the parallel
//! kernels' internal scratch (scoped-thread stacks and the fixed-chunk
//! reduction partials), which trades a few allocations for the
//! multi-core win on large operands.
//!
//! ## Layout
//! * [`linalg`] — linear-algebra substrate (blocked row-parallel GEMM,
//!   CSR kernels, the [`linalg::Operand`] dense|CSR enum, Cholesky,
//!   Householder QR, Golub–Kahan SVD, triangular solves, the
//!   [`linalg::threads`] knob).
//! * [`rng`] — deterministic xoshiro256++ RNG with Gaussian / Rademacher
//!   streams.
//! * [`sketch`] — Gaussian, SRHT (fast Walsh–Hadamard) and sparse
//!   (CountSketch) embeddings.
//! * [`theory`] — closed-form convergence rates, step sizes and the
//!   concentration bounds of Theorems 3–7.
//! * [`data`] — synthetic workload generators matching the paper's
//!   experimental section (exp/poly spectral decays, MNIST/CIFAR-like
//!   surrogates).
//! * [`solvers`] — the solver implementations plus
//!   [`solvers::api`]: the [`Solver`](solvers::Solver) trait,
//!   [`SolverSpec`](solvers::SolverSpec) strings and the
//!   [`registry`](solvers::registry) every caller dispatches through.
//! * [`runtime`] — PJRT executor for AOT-compiled JAX/Pallas artifacts plus
//!   a shape-generic native backend.
//! * [`coordinator`] — the L3 service: job scheduler, solve state machine,
//!   model registry with cross-request sketch/factorization reuse,
//!   metrics, TCP server speaking line-delimited JSON (wire reference:
//!   `PROTOCOL.md`, rendered as [`coordinator::protocol_doc`]).
//! * [`bench_harness`] — regenerates every figure/table of the paper.
//!
//! ## Serving: register once, query many times
//!
//! [`solvers::session::ModelSession`] keeps the grown sketch, the
//! Woodbury/Cholesky factors and the last solution alive *between*
//! solves: a repeat solve at a new `nu` applies no sketch at all
//! (`sketch_time_s == 0.0`) and warm-starts from the previous solution.
//! Batches of right-hand sides go through
//! [`solve_block`](solvers::session::ModelSession::solve_block) — one
//! BLAS-3 block iteration ([`solvers::block`]) over all `k` columns,
//! with per-column convergence and active-set shrinking — instead of
//! `k` independent matvec-bound solves. The coordinator's
//! [`coordinator::registry::Registry`] exposes both over the wire
//! (`register` / `query` (incl. the `"bs"` batch) / `predict` /
//! `evict`) with LRU byte-budget eviction — see `README.md` (rendered
//! as [`readme`]) and `PROTOCOL.md` for the walkthrough.

// Index-based loops are the house style for the dense kernels (indices
// frequently address two or three buffers in lockstep, and the explicit
// form mirrors the Pallas kernels this crate shadows); div_ceil is avoided
// to hold the 1.70 MSRV.
#![allow(clippy::needless_range_loop, clippy::manual_div_ceil)]
// Docs are a first-class surface: every public item documents itself, and
// CI builds rustdoc with warnings denied (broken links included).
#![warn(missing_docs)]

/// Rendered copy of the repository's top-level `README.md`: project
/// overview, paper → module mapping, architecture diagram, the
/// `SolverSpec` grammar, quickstart, and the registry/serving
/// walkthrough.
#[doc = include_str!("../../README.md")]
pub mod readme {}

pub mod bench_harness;
pub mod coordinator;
pub mod data;
pub mod linalg;
pub mod persist;
pub mod rng;
pub mod runtime;
pub mod sketch;
pub mod solvers;
pub mod theory;
pub mod util;

pub use linalg::matrix::Matrix;
pub use linalg::operand::Operand;
pub use solvers::adaptive::{AdaptiveConfig, AdaptiveSolver, AdaptiveVariant};
pub use solvers::{registry, RidgeProblem, SolveReport, Solver, SolverSpec};
