//! **Algorithm 1: Adaptive Polyak-IHS** — the paper's contribution.
//!
//! The solver never needs the effective dimension `d_e`. It starts with an
//! arbitrary sketch size (`m = 1` by default) and monitors the *sketched
//! Newton decrement* `r_t = 1/2 g_t^T H_S^{-1} g_t` (Lemma 1), which the
//! iteration computes for free since it already forms `H_S^{-1} g_t`:
//!
//! 1. propose a Polyak (heavy-ball) step; accept if the geometric-mean
//!    improvement `(r_p^+ / r_1)^{1/t}` meets the target rate `c_p`;
//! 2. otherwise propose a plain gradient-IHS step; accept if the one-step
//!    ratio `r_gd^+ / r_t` meets `c_gd`;
//! 3. otherwise double `m`, resample `S`, re-factor, and retry the same
//!    iteration.
//!
//! Theorems 5–6 guarantee `m` stops growing at `O(d_e/rho)` (Gaussian) or
//! `O(d_e log d_e / rho)` (SRHT), with at most `O(log(d_e/rho))` rejected
//! rounds, and overall error `delta_t / delta_1 <= O(c_gd(rho)^{t-1})`.
//!
//! Growth is *incremental* (the premise of Theorem 7's cost model): a
//! [`SketchEngine`] appends `Δm` new rows of `S̃A` — `O(Δm n d)` Gaussian,
//! `O(Δm d)` SRHT after a one-time FWHT, `O(nnz)` sparse — and
//! [`WoodburyCache::grow`] reuses the old `(S̃A)(S̃A)^T` block, so a
//! rejection round pays only for the new rows instead of re-sketching and
//! re-factoring from scratch. `sketch_time_s` / `factor_time_s` in the
//! [`SolveReport`] measure exactly this reduced per-growth work.
//!
//! The `GradientOnly` variant (also evaluated in the paper's §5) skips the
//! Polyak candidate — same guarantees, and faster in practice when the
//! Polyak step is frequently rejected (one gradient evaluation per
//! iteration instead of two).
//!
//! # Fault recovery
//!
//! Every fallible numerical step (growth factorizations, `nu` re-keys)
//! runs under the recovery ladder of [`super::error`]: diagonal jitter is
//! already inside [`WoodburyCache`]'s factorizations; when a grow or
//! re-key still fails the solver re-applies a **fresh sketch** of the
//! same size (a new draw continuing the solver's RNG stream), and when
//! that also fails it falls back to the **exact Hessian** — the same
//! at-cap path the algorithm already owns. The highest rung used is
//! recorded in [`SolveReport::recovery`]; only a failure of the exact
//! fallback itself surfaces as [`SolverError::NumericalBreakdown`].

use super::error::{RecoveryRung, SolverError};
use super::woodbury::{GramPanel, WoodburyCache};
use super::{RidgeProblem, Solution, SolveReport, StopRule};
use crate::linalg::{dot, norm2};
use crate::rng::Xoshiro256;
use crate::sketch::engine::{SketchEngine, SketchView};
use crate::sketch::SketchKind;
use crate::theory::rates::IhsParams;
use crate::theory::{gaussian_bounds, srht_bounds};
use crate::util::failpoint;
use std::sync::Arc;
use std::time::Instant;

/// Reusable sketch/factorization state extracted from a finished
/// [`AdaptiveSolver`] run and fed back into the next one
/// ([`AdaptiveSolver::resume`]).
///
/// The sketch rows of `S̃A` depend only on `(A, seed)` — not on `nu` or
/// `b` — so a session that solves the *same* data at many regularization
/// levels (or right-hand sides) can keep the grown [`SketchEngine`] and
/// the [`WoodburyCache`] alive across solves: a resumed solve performs
/// **zero** sketch application (its `SolveReport::sketch_time_s` stays
/// exactly `0.0` unless the new problem forces further growth) and pays
/// only an `O(m^3)` / `O(d^3)` re-factor via [`WoodburyCache::set_nu`]
/// when `nu` changed. This is the state behind
/// [`crate::solvers::session::ModelSession`] and the coordinator's model
/// registry; the observation that one sketch-based preconditioner stays
/// valid across regularization levels is Lacotte & Pilanci's
/// adaptive-preconditioning follow-up (arXiv:2104.14101).
///
/// `Clone` is what makes [`crate::solvers::session::ModelSession`]'s
/// transactional rollback possible: a mutating call snapshots the state
/// and restores it on any error or caught panic.
///
/// The heavy members (sketch panel, factorization) live behind `Arc`s,
/// so `Clone` is **O(1)** — a clone shares the panel and Gram buffers
/// with the original. That is what lets the serving layer publish a
/// state clone inside every
/// [`crate::solvers::session::SessionSnapshot`] for free. Mutation goes
/// through [`AdaptiveSessionState::into_parts`], which unwraps the
/// `Arc`s copy-on-write style: sole owners mutate in place at zero extra
/// cost, while a state shared with a published snapshot deep-copies
/// first — so a reader pinned to an old snapshot keeps bitwise-stable
/// buffers no matter what the writer does next.
#[derive(Clone)]
pub struct AdaptiveSessionState {
    /// Incremental sketch state; `None` once growth hit the cap (the
    /// cache then holds the exact Hessian — see
    /// [`AdaptiveSolver::step`]).
    engine: Option<Arc<SketchEngine>>,
    /// Factorization of the sketched Hessian at the *last solved* `nu`;
    /// re-keyed cheaply on resume.
    cache: Arc<WoodburyCache>,
    /// RNG mid-stream, so future growth rows continue the same draw
    /// sequence a single uninterrupted solve would have used.
    rng: Xoshiro256,
}

impl AdaptiveSessionState {
    /// Current sketch size `m` (the row count future solves start from).
    pub fn m(&self) -> usize {
        self.cache.m()
    }

    /// Whether growth already hit the `next_pow2(n)` cap (the cache holds
    /// the exact Hessian; no engine is retained).
    pub fn at_cap(&self) -> bool {
        self.engine.is_none()
    }

    /// Approximate heap footprint in bytes (engine buffers + cached
    /// factorization) — what registries charge against their byte budget.
    pub fn approx_bytes(&self) -> usize {
        self.engine.as_deref().map_or(0, SketchEngine::approx_bytes) + self.cache.approx_bytes()
    }

    /// Borrow the incremental sketch engine — `None` once growth hit the
    /// cap. Persistence exports its replay header
    /// ([`SketchEngine::replay_state`]) instead of the panel.
    pub fn engine(&self) -> Option<&SketchEngine> {
        self.engine.as_deref()
    }

    /// Borrow the cached factorization — what the lock-free read path
    /// reports `m` and the keyed `nu` from without touching any mutex.
    pub fn cache(&self) -> &WoodburyCache {
        &self.cache
    }

    /// Borrow the mid-stream session RNG (checkpointed so recovered
    /// growth continues the same draw sequence).
    pub fn rng(&self) -> &Xoshiro256 {
        &self.rng
    }

    /// The regularization level the cached factorization is currently
    /// keyed to — what [`AdaptiveSessionState::restore`] re-factors at.
    pub fn cache_nu(&self) -> f64 {
        self.cache.nu()
    }

    /// The shared immutable Gram panel — the frozen read lane's artifact:
    /// clone the `Arc` out of a published snapshot and derive per-`nu`
    /// factorizations from it with zero writer coordination
    /// ([`GramPanel::factor`] is pure).
    pub fn panel(&self) -> &Arc<GramPanel> {
        self.cache.panel()
    }

    /// Freeze the sketch-layer metadata ([`SketchView`]) out of the live
    /// engine at O(1) — `None` once growth hit the cap (the panel then
    /// holds the exact Hessian and the frozen lane's at-cap waiver
    /// applies unconditionally).
    pub fn view(&self) -> Option<SketchView> {
        self.engine.as_deref().map(SketchEngine::view)
    }

    /// Bytes of this state's allocations **not** shared with `live`
    /// (compared allocation-by-allocation via `Arc::ptr_eq`): what a
    /// registry must additionally charge for a published snapshot whose
    /// writer has since re-keyed or grown. A snapshot that still shares
    /// everything with the live state costs 0 extra; after a writer-lane
    /// `set_nu` the snapshot retains its own `NuFactor` (but still shares
    /// the panel); after a grow it retains the whole pre-growth panel and
    /// engine. Passing `None` charges every allocation (nothing left to
    /// share against).
    pub fn bytes_not_shared_with(&self, live: Option<&AdaptiveSessionState>) -> usize {
        let mut extra = 0;
        if let Some(e) = &self.engine {
            let shared = live
                .and_then(|l| l.engine.as_ref())
                .map_or(false, |le| Arc::ptr_eq(e, le));
            if !shared {
                extra += e.approx_bytes();
            }
        }
        let cache_shared = live.map_or(false, |l| Arc::ptr_eq(&self.cache, &l.cache));
        if !cache_shared {
            // The per-nu factor is unshared whenever the cache Arc
            // diverged, but the panel may still be the same allocation
            // (`set_nu` re-keys without copying the panel) — charge it
            // only when the panel pointers differ too.
            extra += self.cache.factor().approx_bytes();
            let panel_shared =
                live.map_or(false, |l| Arc::ptr_eq(self.cache.panel(), l.cache.panel()));
            if !panel_shared {
                extra += self.cache.panel().approx_bytes();
            }
        }
        extra
    }

    /// Rebuild a session state from persisted parts: the restored engine
    /// (or `None` at cap), the factorization's `nu` key, the mid-stream
    /// RNG, and the recovered operand (used only on the at-cap path,
    /// where the cache holds the exact Hessian).
    ///
    /// The rebuilt factorization is **bitwise** the one a live session
    /// holds after an append flush: the session layer always rebuilds its
    /// cache via [`WoodburyCache::new_scaled`] on the engine's panel (see
    /// [`crate::solvers::session::ModelSession`]), so re-running that
    /// constructor on the bitwise-replayed panel reproduces it exactly.
    pub fn restore(
        engine: Option<SketchEngine>,
        nu: f64,
        rng: Xoshiro256,
        a: &crate::linalg::Operand,
    ) -> Result<Self, SolverError> {
        let cache = match &engine {
            Some(e) => {
                WoodburyCache::new_scaled(e.sa_unnormalized().clone(), nu, e.scale())?
            }
            None => WoodburyCache::new(a.dense().into_owned(), nu)?,
        };
        Ok(Self { engine: engine.map(Arc::new), cache: Arc::new(cache), rng })
    }

    /// Decompose into `(engine, cache, rng)` — the block multi-RHS solver
    /// ([`crate::solvers::block`]) drives these directly instead of going
    /// through [`AdaptiveSolver::resume`].
    ///
    /// This is the copy-on-write point: when no published snapshot shares
    /// the `Arc`s, they unwrap for free and the caller mutates the
    /// original buffers in place (bitwise identical to the pre-`Arc`
    /// behavior); when a snapshot does share them, the buffers are
    /// deep-copied here so the snapshot's view stays frozen.
    pub(crate) fn into_parts(self) -> (Option<SketchEngine>, WoodburyCache, Xoshiro256) {
        let engine =
            self.engine.map(|e| Arc::try_unwrap(e).unwrap_or_else(|shared| (*shared).clone()));
        let cache = Arc::try_unwrap(self.cache).unwrap_or_else(|shared| (*shared).clone());
        (engine, cache, self.rng)
    }

    /// Reassemble after a block solve. The engine and cache must describe
    /// the same sketch rows (the block solver grows them in lockstep).
    pub(crate) fn from_parts(
        engine: Option<SketchEngine>,
        cache: WoodburyCache,
        rng: Xoshiro256,
    ) -> Self {
        if let Some(e) = &engine {
            debug_assert_eq!(e.m(), cache.m(), "engine/cache row counts diverged");
        }
        Self { engine: engine.map(Arc::new), cache: Arc::new(cache), rng }
    }
}

/// Which candidate schedule Algorithm 1 runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdaptiveVariant {
    /// Full Algorithm 1: Polyak candidate first, gradient fallback.
    PolyakFirst,
    /// The paper's §5 variant: gradient-IHS candidates only.
    GradientOnly,
}

/// Configuration of the adaptive solver. The stopping rule is not part of
/// the config: it is passed per-solve through the unified
/// [`crate::solvers::api::Solver`] call.
#[derive(Clone, Debug)]
pub struct AdaptiveConfig {
    /// Sketch family to grow.
    pub kind: SketchKind,
    /// Candidate schedule (Polyak-first or gradient-only).
    pub variant: AdaptiveVariant,
    /// Initial sketch size (paper default: 1).
    pub m_initial: usize,
    /// Aspect-ratio target `rho`; sets the acceptance thresholds via
    /// Definition 3.1 (Gaussian, with `eta`) or 3.2 (SRHT).
    pub rho: f64,
    /// Gaussian concentration parameter `eta` (Definition 3.1).
    pub eta: f64,
    /// Growth factor applied on rejection (paper: 2).
    pub growth: usize,
    /// Accepted-iteration cap (safety net; the stop rule fires first).
    pub max_iters: usize,
    /// Cooperative wall deadline: checked once per outer iteration and
    /// once per growth round; when it passes, the solve stops with
    /// [`SolverError::DeadlineExceeded`] (the partial iterate is
    /// discarded by transactional callers). `None` disables the check.
    pub deadline: Option<Instant>,
}

impl AdaptiveConfig {
    /// Paper-default configuration for a sketch family.
    pub fn new(kind: SketchKind) -> Self {
        let rho = match kind {
            SketchKind::Gaussian => 0.1,
            // SRHT/sparse brackets are [1 -/+ sqrt(rho)]: rho = 0.5 keeps
            // the m-threshold reachable at benchmark sizes while the rate
            // c_gd = rho still halves the error per accepted step.
            SketchKind::Srht | SketchKind::Sparse => 0.5,
        };
        Self {
            kind,
            variant: AdaptiveVariant::PolyakFirst,
            m_initial: 1,
            rho,
            eta: 0.01,
            growth: 2,
            max_iters: 10_000,
            deadline: None,
        }
    }

    /// Target rates / step sizes per Definitions 3.1 / 3.2.
    pub fn params(&self) -> IhsParams {
        match self.kind {
            SketchKind::Gaussian => gaussian_bounds(self.rho, self.eta, 1.0).params(),
            SketchKind::Srht | SketchKind::Sparse => srht_bounds(self.rho, 2, 2.0).params(),
        }
    }
}

/// One solver with explicit state — used directly by the coordinator's
/// state machine; [`solve`] is the plain-function wrapper.
///
/// All per-iteration state lives in preallocated buffers (candidate
/// iterate/gradient, Woodbury scratch, gradient scratch): a steady-state
/// [`AdaptiveSolver::step`] performs no heap allocation — only growth
/// rounds (O(log) many) and external oracles allocate
/// (`tests/alloc_free.rs`).
pub struct AdaptiveSolver<'p> {
    problem: &'p RidgeProblem,
    config: AdaptiveConfig,
    stop: StopRule,
    params: IhsParams,
    rng: Xoshiro256,
    /// Gradient oracle writing into a caller buffer. Defaults to the
    /// allocation-free native `problem.gradient_into`; the PJRT runtime
    /// swaps in an AOT-compiled artifact via
    /// [`AdaptiveSolver::set_gradient_fn`] — the `O(nd)` / `O(nnz)`
    /// per-iteration hot op is the only thing that changes backend.
    grad_fn: Box<dyn FnMut(&[f64], &mut Vec<f64>) + 'p>,
    /// Cap on m: padded row count (SRHT cannot exceed it; for the others
    /// growing past n stops helping).
    m_cap: usize,

    /// When construction began — [`AdaptiveSolver::run`]'s wall clock
    /// starts here so the constructor's sketch/factor phases (including a
    /// resume's `set_nu` refactor) are inside the reported wall time and
    /// `iter_time_s = wall - sketch - factor` cannot go negative.
    created: Instant,

    // Iteration state.
    /// Current sketch size (monotone nondecreasing across the solve).
    pub m: usize,
    /// Incremental sketch state; dropped once `m` hits the cap (the cache
    /// then holds the exact Hessian and no further growth is possible).
    engine: Option<SketchEngine>,
    cache: WoodburyCache,
    x_prev: Vec<f64>,
    x: Vec<f64>,
    g: Vec<f64>,
    g_tilde: Vec<f64>,
    // Candidate + scratch buffers (steady-state allocation-free step()).
    x_cand: Vec<f64>,
    g_cand: Vec<f64>,
    gt_cand: Vec<f64>,
    ws_m: Vec<f64>,
    r_t: f64,
    r_1: f64,
    t: usize,

    /// Work/time breakdown, updated as the solve progresses.
    pub report: SolveReport,
}

impl<'p> AdaptiveSolver<'p> {
    /// Initialize at `x0` (both `x_0` and `x_1` per the paper's two-point
    /// heavy-ball initialization).
    pub fn new(
        problem: &'p RidgeProblem,
        x0: &[f64],
        config: AdaptiveConfig,
        stop: StopRule,
        seed: u64,
    ) -> Result<Self, SolverError> {
        Self::build(problem, x0, config, stop, None, Xoshiro256::seed_from_u64(seed))
    }

    /// Initialize from a previous run's [`AdaptiveSessionState`]: the grown
    /// sketch rows are reused verbatim (no sketch application at all) and
    /// the cached factorization is re-keyed to the new problem's `nu`
    /// ([`WoodburyCache::set_nu`], `O(m^3)`/`O(d^3)` from the cached Gram).
    /// The problem must be the *same data* the state was built on (same
    /// `n`; callers are responsible for not mixing operands) and the config
    /// must request the same sketch family.
    pub fn resume(
        problem: &'p RidgeProblem,
        x0: &[f64],
        config: AdaptiveConfig,
        stop: StopRule,
        state: AdaptiveSessionState,
    ) -> Result<Self, SolverError> {
        let (engine, cache, rng) = state.into_parts();
        if let Some(e) = &engine {
            assert_eq!(e.kind(), config.kind, "resume: sketch family changed");
            assert_eq!(e.n(), problem.n(), "resume: problem shape changed");
            assert_eq!(e.m(), cache.m(), "resume: engine/cache row counts diverged");
        }
        assert_eq!(cache.d(), problem.d(), "resume: problem shape changed");
        Self::build(problem, x0, config, stop, Some((engine, cache)), rng)
    }

    fn build(
        problem: &'p RidgeProblem,
        x0: &[f64],
        config: AdaptiveConfig,
        stop: StopRule,
        resume: Option<(Option<SketchEngine>, WoodburyCache)>,
        mut rng: Xoshiro256,
    ) -> Result<Self, SolverError> {
        let created = Instant::now();
        let d = problem.d();
        if x0.len() != d {
            return Err(SolverError::invalid(format!(
                "x0 has {} entries, problem has d = {d}",
                x0.len()
            )));
        }
        if config.m_initial < 1 || config.growth < 2 {
            return Err(SolverError::invalid("adaptive config needs m_initial >= 1, growth >= 2"));
        }
        let params = config.params();
        // Sketch-size cap: the padded row count, further limited by a
        // resumed engine's own sampling capacity (streamed SRHT appends
        // add blocks with finite padded dims — see `SketchEngine::max_m`).
        // Hitting the cap triggers the exact-Hessian fallback either way.
        let mut m_cap = crate::sketch::srht::next_pow2(problem.n());
        if let Some((Some(e), _)) = &resume {
            m_cap = m_cap.min(e.max_m());
        }

        // Canonical spec-string labels (see `solvers::api`): the Polyak
        // variant is the default and carries no infix.
        let mut report = SolveReport::new(match config.variant {
            AdaptiveVariant::PolyakFirst => format!("adaptive-{}", config.kind),
            AdaptiveVariant::GradientOnly => format!("adaptive-gd-{}", config.kind),
        });
        report.m_trace.reserve(config.max_iters.min(65_536));

        let (m, engine, cache) = match resume {
            Some((engine, mut cache)) => {
                // Session resume: zero sketch work. Only the factorization
                // is re-keyed when nu changed (a no-op otherwise). A
                // failed re-key climbs the ladder: fresh sketch at the
                // same m, then the exact Hessian.
                let m = engine.as_ref().map_or(m_cap, SketchEngine::m);
                let t0 = Instant::now();
                let rekeyed = cache.set_nu(problem.nu);
                report.factor_time_s += t0.elapsed().as_secs_f64();
                match rekeyed {
                    Ok(()) => {
                        report.recovery.escalate(cache.recovery());
                        (m, engine, cache)
                    }
                    Err(e @ SolverError::InvalidInput(_)) => return Err(e),
                    Err(_) => match fresh_parts(problem, &config, m, &mut rng, &mut report) {
                        Ok((engine, cache)) => {
                            report.recovery.escalate(RecoveryRung::Resketch);
                            (m, engine, cache)
                        }
                        Err(_) => {
                            let (engine, cache) = exact_parts(problem, &mut report)?;
                            report.recovery.escalate(RecoveryRung::Exact);
                            (m_cap, engine, cache)
                        }
                    },
                }
            }
            None => {
                let m = config.m_initial.min(m_cap);
                match fresh_parts(problem, &config, m, &mut rng, &mut report) {
                    Ok((engine, cache)) => {
                        report.recovery.escalate(cache.recovery());
                        (m, engine, cache)
                    }
                    Err(e @ SolverError::InvalidInput(_)) => return Err(e),
                    Err(_) => {
                        // Initial sketch would not factor even with
                        // jitter: skip straight to the exact Hessian.
                        let (engine, cache) = exact_parts(problem, &mut report)?;
                        report.recovery.escalate(RecoveryRung::Exact);
                        (m_cap, engine, cache)
                    }
                }
            }
        };

        // Native oracle: gradient_into with its own length-n scratch,
        // allocation-free after the first call.
        let mut grad_fn: Box<dyn FnMut(&[f64], &mut Vec<f64>) + 'p> = {
            let mut scratch: Vec<f64> = Vec::new();
            Box::new(move |x, out| {
                out.resize(x.len(), 0.0);
                problem.gradient_into(x, &mut scratch, out);
            })
        };

        let x = x0.to_vec();
        let mut g = vec![0.0; d];
        grad_fn(&x, &mut g);
        let mut ws_m: Vec<f64> = Vec::new();
        let mut g_tilde = vec![0.0; d];
        cache.apply_inverse_into(&g, &mut ws_m, &mut g_tilde);
        let r_1 = 0.5 * dot(&g, &g_tilde);
        report.final_m = m;
        report.peak_m = m;

        Ok(Self {
            problem,
            config,
            stop,
            params,
            rng,
            grad_fn,
            m_cap,
            created,
            m,
            engine,
            cache,
            x_prev: x.clone(),
            x,
            g,
            g_tilde,
            x_cand: vec![0.0; d],
            g_cand: vec![0.0; d],
            gt_cand: vec![0.0; d],
            ws_m,
            r_t: r_1,
            r_1,
            t: 1,
            report,
        })
    }

    /// Replace the gradient oracle (e.g. with a PJRT-executed artifact).
    /// The oracle must compute `A^T A x + nu^2 x - A^T b` for the same
    /// problem; everything else (sketching, factorization, acceptance
    /// logic) is unchanged. External oracles keep the simple
    /// `&[f64] -> Vec<f64>` shape (they allocate per call; the
    /// allocation-free guarantee applies to the native default only).
    pub fn set_gradient_fn(&mut self, f: impl Fn(&[f64]) -> Vec<f64> + 'p) {
        self.grad_fn = Box::new(move |x, out| {
            let g = f(x);
            out.clear();
            out.extend_from_slice(&g);
        });
        // Refresh cached gradient state under the new oracle so mixed
        // precision cannot leave a stale high-precision g.
        (self.grad_fn)(&self.x, &mut self.g);
        self.cache.apply_inverse_into(&self.g, &mut self.ws_m, &mut self.g_tilde);
        self.r_t = 0.5 * dot(&self.g, &self.g_tilde);
        if self.t == 1 {
            self.r_1 = self.r_t;
        }
    }

    /// Current iterate.
    pub fn x(&self) -> &[f64] {
        &self.x
    }

    /// Current sketched Newton decrement `r_t`.
    pub fn newton_decrement(&self) -> f64 {
        self.r_t
    }

    /// Double the sketch size *in place* — append `Δm` rows through the
    /// incremental engine, extend the Woodbury factorization, and refresh
    /// the decrement state (step 14–15 of Algorithm 1). The growth round
    /// costs `O(Δm)`-proportional work (new rows + cross-Gram), not the
    /// from-scratch `O(m)` re-sketch/re-factor.
    ///
    /// A failed incremental growth climbs the recovery ladder (fresh
    /// sketch at the grown size, then the exact Hessian); only exhaustion
    /// of the ladder returns `Err`.
    fn grow_sketch(&mut self) -> Result<(), SolverError> {
        let new_m = (self.m * self.config.growth).min(self.m_cap);
        self.report.doublings += 1;
        self.m = new_m;
        self.report.peak_m = self.report.peak_m.max(new_m);
        self.report.final_m = new_m;

        if new_m >= self.m_cap {
            // At the cap, drop sketching entirely: with S = I the cache
            // holds the exact Hessian (H_S = A^T A + nu^2 I), so forced
            // steps are damped exact-Newton and cannot stall. (An
            // orthogonal SRHT at m = n_pad is exact anyway; a Gaussian
            // sketch at m = n is not, hence the explicit fallback.) CSR
            // operands densify here — at the cap the "sketch" is as large
            // as the data, so the O(n d) copy is already paid for. This is
            // the algorithm's own cap path, not a fault: no rung recorded.
            let (engine, cache) = exact_parts(self.problem, &mut self.report)?;
            self.engine = engine;
            self.cache = cache;
        } else {
            let grown = {
                let engine = self.engine.as_mut().expect("engine lives until the cap");
                let t0 = Instant::now();
                let new_rows = engine.grow(new_m, &*self.problem.a, &mut self.rng);
                self.report.sketch_time_s += t0.elapsed().as_secs_f64();
                let scale = engine.scale();
                new_rows.and_then(|rows| {
                    let t0 = Instant::now();
                    let r = self.cache.grow(&rows, scale);
                    self.report.factor_time_s += t0.elapsed().as_secs_f64();
                    r
                })
            };
            match grown {
                Ok(()) => self.report.recovery.escalate(self.cache.recovery()),
                Err(e @ SolverError::InvalidInput(_)) => return Err(e),
                Err(_) => {
                    // Rung 2: throw the sketch away and re-apply a fresh
                    // draw of the same (grown) size; rung 3: exact.
                    match fresh_parts(
                        self.problem,
                        &self.config,
                        new_m,
                        &mut self.rng,
                        &mut self.report,
                    ) {
                        Ok((engine, cache)) => {
                            self.engine = engine;
                            self.cache = cache;
                            self.report.recovery.escalate(RecoveryRung::Resketch);
                        }
                        Err(_) => {
                            let (engine, cache) = exact_parts(self.problem, &mut self.report)
                                .map_err(|e| {
                                    SolverError::breakdown(format!(
                                        "recovery ladder exhausted: {e}"
                                    ))
                                })?;
                            self.engine = engine;
                            self.cache = cache;
                            self.m = self.m_cap;
                            self.report.peak_m = self.report.peak_m.max(self.m_cap);
                            self.report.final_m = self.m;
                            self.report.recovery.escalate(RecoveryRung::Exact);
                        }
                    }
                }
            }
        }

        // g_t is unchanged; the preconditioned direction and decrement are
        // re-evaluated under the new sketch geometry.
        self.cache.apply_inverse_into(&self.g, &mut self.ws_m, &mut self.g_tilde);
        self.r_t = 0.5 * dot(&self.g, &self.g_tilde);
        if self.t == 1 {
            // No step accepted yet: the reference decrement belongs to the
            // new sketch.
            self.r_1 = self.r_t;
        }
        Ok(())
    }

    /// Cooperative deadline check (see [`AdaptiveConfig::deadline`]).
    fn check_deadline(&self) -> Result<(), SolverError> {
        if let Some(deadline) = self.config.deadline {
            if Instant::now() >= deadline {
                return Err(SolverError::DeadlineExceeded(format!(
                    "solve passed its wall deadline after {} accepted iterations",
                    self.report.iterations
                )));
            }
        }
        Ok(())
    }

    /// Evaluate the candidate sitting in `self.x_cand`: fills
    /// `self.g_cand` / `self.gt_cand` and returns `r^+` — no allocation,
    /// all three buffers are preallocated state.
    fn evaluate_candidate(&mut self) -> f64 {
        (self.grad_fn)(&self.x_cand, &mut self.g_cand);
        self.cache.apply_inverse_into(&self.g_cand, &mut self.ws_m, &mut self.gt_cand);
        0.5 * dot(&self.g_cand, &self.gt_cand)
    }

    /// Accept the candidate in `x_cand`/`g_cand`/`gt_cand` as `x_{t+1}` by
    /// rotating buffers (the displaced buffers become the next scratch).
    fn accept_candidate(&mut self, r_plus: f64) {
        // x_prev <- x, x <- x_cand; the old x_prev lands in x_cand and is
        // fully overwritten at the next candidate formation.
        std::mem::swap(&mut self.x_prev, &mut self.x);
        std::mem::swap(&mut self.x, &mut self.x_cand);
        std::mem::swap(&mut self.g, &mut self.g_cand);
        std::mem::swap(&mut self.g_tilde, &mut self.gt_cand);
        self.r_t = r_plus;
        self.t += 1;
        self.report.iterations += 1;
        self.report.m_trace.push(self.m);
    }

    /// One outer iteration of Algorithm 1 (may internally grow the sketch
    /// several times). When the sketch is already at its cap and neither
    /// candidate passes, the accept thresholds are waived for the final
    /// (exact-Hessian-quality) step.
    ///
    /// `Err` means the iterate could not advance: the recovery ladder was
    /// exhausted ([`SolverError::NumericalBreakdown`]) or the configured
    /// deadline passed ([`SolverError::DeadlineExceeded`]).
    pub fn step(&mut self) -> Result<(), SolverError> {
        failpoint::check("adaptive.iterate").map_err(SolverError::Internal)?;
        let d = self.x.len();
        loop {
            self.check_deadline()?;
            // --- Polyak candidate (steps 4–7) ---
            if self.config.variant == AdaptiveVariant::PolyakFirst {
                for i in 0..d {
                    self.x_cand[i] = self.x[i] - self.params.mu_p * self.g_tilde[i]
                        + self.params.beta_p * (self.x[i] - self.x_prev[i]);
                }
                let r_p = self.evaluate_candidate();
                let c_p_plus = if self.r_1 > 0.0 {
                    (r_p / self.r_1).powf(1.0 / self.t as f64)
                } else {
                    0.0
                };
                if c_p_plus <= self.params.c_p {
                    self.accept_candidate(r_p);
                    return Ok(());
                }
                self.report.rejections += 1;
            }

            // --- Gradient candidate (steps 9–12) ---
            for i in 0..d {
                self.x_cand[i] = self.x[i] - self.params.mu_gd * self.g_tilde[i];
            }
            let r_gd = self.evaluate_candidate();
            let c_gd_plus = if self.r_t > 0.0 { r_gd / self.r_t } else { 0.0 };
            if c_gd_plus <= self.params.c_gd || self.m >= self.m_cap {
                // At the cap H_S is (near-)exact: the step is a damped
                // Newton step and is always productive; accept it so the
                // solver cannot live-lock.
                self.accept_candidate(r_gd);
                return Ok(());
            }
            self.report.rejections += 1;

            // --- Both rejected: grow (steps 14–15) ---
            self.grow_sketch()?;
        }
    }

    /// Run to completion under the stop rule given at construction.
    pub fn run(mut self) -> Result<Solution, SolverError> {
        self.run_inner()?;
        Ok(Solution { x: self.x, report: self.report })
    }

    /// Like [`AdaptiveSolver::run`], additionally handing back the
    /// [`AdaptiveSessionState`] (grown sketch + factorization + RNG) so the
    /// next solve on the same data can [`AdaptiveSolver::resume`] instead
    /// of re-sketching from scratch. On `Err` the partial state is
    /// dropped — transactional callers restore their own snapshot.
    pub fn run_with_state(mut self) -> Result<(Solution, AdaptiveSessionState), SolverError> {
        self.run_inner()?;
        let state = AdaptiveSessionState::from_parts(self.engine, self.cache, self.rng);
        Ok((Solution { x: self.x, report: self.report }, state))
    }

    fn run_inner(&mut self) -> Result<(), SolverError> {
        let g0_norm = norm2(&self.g);
        // Stop-rule scratch, reused across iterations.
        let mut ws_d: Vec<f64> = Vec::new();
        let mut ws_n: Vec<f64> = Vec::new();
        let delta0 = match &self.stop {
            StopRule::TrueError { x_star, .. } => {
                self.problem.prediction_error_ws(&self.x, x_star, &mut ws_d, &mut ws_n)
            }
            _ => 0.0,
        };
        if matches!(self.stop, StopRule::TrueError { .. }) {
            // Shared trace convention: entry t is delta_t / delta_0.
            self.report.error_trace.reserve(self.config.max_iters.min(65_536) + 1);
            self.report.error_trace.push(1.0);
        }

        let max_iters = self.config.max_iters;
        let stop = self.stop.clone();
        while self.report.iterations < max_iters {
            self.step()?;
            let stop_now = match &stop {
                StopRule::TrueError { x_star, eps } => {
                    let delta =
                        self.problem.prediction_error_ws(&self.x, x_star, &mut ws_d, &mut ws_n);
                    let rel = if delta0 > 0.0 { delta / delta0 } else { 0.0 };
                    self.report.error_trace.push(rel);
                    delta <= eps * delta0
                }
                StopRule::GradientNorm { tol } => norm2(&self.g) <= tol * g0_norm,
            };
            if stop_now {
                self.report.converged = true;
                break;
            }
        }

        if let StopRule::TrueError { x_star, eps } = &stop {
            let delta = self.problem.prediction_error(&self.x, x_star);
            let rel = if delta0 > 0.0 { delta / delta0 } else { 0.0 };
            self.report.final_rel_error = Some(rel);
            if delta0 > 0.0 && delta <= eps * delta0 {
                self.report.converged = true;
            }
        }
        // Wall time is measured from construction so the initial (or
        // resumed) sketch/factor phases are included — see `created`.
        let total = self.created.elapsed().as_secs_f64();
        self.report.wall_time_s = total;
        self.report.iter_time_s = total - self.report.sketch_time_s - self.report.factor_time_s;
        Ok(())
    }
}

/// Build a fresh engine + cache at size `m` (the initial sketch, and the
/// ladder's *resketch* rung), charging sketch/factor time to `report`.
fn fresh_parts(
    problem: &RidgeProblem,
    config: &AdaptiveConfig,
    m: usize,
    rng: &mut Xoshiro256,
    report: &mut SolveReport,
) -> Result<(Option<SketchEngine>, WoodburyCache), SolverError> {
    let t0 = Instant::now();
    let engine = SketchEngine::new(config.kind, m, &*problem.a, rng);
    report.sketch_time_s += t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let cache =
        WoodburyCache::new_scaled(engine.sa_unnormalized().clone(), problem.nu, engine.scale());
    report.factor_time_s += t0.elapsed().as_secs_f64();
    Ok((Some(engine), cache?))
}

/// Build the exact-Hessian cache (`S = I`; the at-cap path and the
/// ladder's final rung), charging sketch/factor time to `report`.
fn exact_parts(
    problem: &RidgeProblem,
    report: &mut SolveReport,
) -> Result<(Option<SketchEngine>, WoodburyCache), SolverError> {
    let t0 = Instant::now();
    let sa = problem.a.dense().into_owned();
    report.sketch_time_s += t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let cache = WoodburyCache::new(sa, problem.nu);
    report.factor_time_s += t0.elapsed().as_secs_f64();
    Ok((None, cache?))
}

/// Convenience wrapper: run Algorithm 1 from `x0` with the given seed.
pub fn solve(
    problem: &RidgeProblem,
    x0: &[f64],
    config: &AdaptiveConfig,
    stop: &StopRule,
    seed: u64,
) -> Result<Solution, SolverError> {
    AdaptiveSolver::new(problem, x0, config.clone(), stop.clone(), seed)?.run()
}

/// Typed outcome of a frozen-lane solve ([`solve_frozen`]).
#[derive(Clone, Debug)]
pub enum FrozenOutcome {
    /// Finished against the pinned artifacts (converged, or hit the
    /// iteration cap — exactly when the writer lane would have too).
    Solved(Solution),
    /// Both candidates failed the acceptance tests at a frozen `m` below
    /// the growth cap — precisely the condition under which the writer
    /// lane would grow the sketch (`d_eff(nu)` too large for the pinned
    /// `m`). The read lane cannot grow: the panel is immutable and
    /// shared. Callers fall back to the mutex lane, which owns growth
    /// and the recovery ladder.
    NeedsGrowth {
        /// The frozen sketch size that proved insufficient.
        m: usize,
        /// Which test failed and by how much (diagnostics only).
        reason: String,
    },
}

/// **Frozen-lane solve**: run the gradient-/Polyak-IHS iteration of
/// Algorithm 1 against *pinned immutable artifacts* — a shared
/// [`GramPanel`] and the [`SketchView`] frozen out of the engine — with
/// growth replaced by a typed [`FrozenOutcome::NeedsGrowth`] return.
///
/// This is what makes uncached-`nu` queries embarrassingly parallel: the
/// per-`nu` factorization is derived by the pure [`GramPanel::factor`]
/// (`&panel + nu -> NuFactor`, the cross-`nu` preconditioner reuse of
/// arXiv:2104.14101), and the iteration then runs entirely on local
/// buffers — no lock, no RNG draw, no mutation anywhere. Iterating to
/// convergence with a fixed embedding is the regime analyzed in
/// arXiv:2002.09488.
///
/// # Bitwise-twin contract
///
/// For a query the writer lane ([`AdaptiveSolver::resume`]) would answer
/// *without growing*, this function produces **bit-identical** iterates:
/// it evaluates the same candidate expressions on the same buffers in
/// the same order, the derived factorization is bitwise the one `set_nu`
/// would install (the factor kernels are deterministic in
/// `(Gram, scale2, nu2)`), and the cap arithmetic (`m_cap`, the at-cap
/// exact-Newton waiver) mirrors [`AdaptiveSolver::build`] exactly. Where
/// the writer lane would call `grow_sketch`, this lane returns
/// `NeedsGrowth` instead — so it never returns a *different* answer,
/// only the same answer or a typed deferral.
///
/// # What this lane cannot do
///
/// No recovery ladder: resketch and exact-Hessian rebuilds mutate writer
/// state, so a numerical failure of the per-`nu` re-key also defers to
/// the writer via `NeedsGrowth`. No warm-start or cache population:
/// callers ([`crate::solvers::session::SessionSnapshot::solve_frozen`])
/// treat the result as read-only. Only the oracle-free
/// [`StopRule::GradientNorm`] is supported (the serving criterion).
///
/// `view = None` means the state froze *at the cap* (no engine retained;
/// the panel holds the exact Hessian): the waiver applies unconditionally
/// and `NeedsGrowth` is impossible on the acceptance path.
pub fn solve_frozen(
    problem: &RidgeProblem,
    x0: &[f64],
    config: &AdaptiveConfig,
    stop: &StopRule,
    panel: &GramPanel,
    view: Option<&SketchView>,
) -> Result<FrozenOutcome, SolverError> {
    let created = Instant::now();
    let d = problem.d();
    if x0.len() != d {
        return Err(SolverError::invalid(format!(
            "x0 has {} entries, problem has d = {d}",
            x0.len()
        )));
    }
    if panel.d() != d {
        return Err(SolverError::invalid(format!(
            "frozen panel has d = {}, problem has d = {d}",
            panel.d()
        )));
    }
    let StopRule::GradientNorm { tol } = stop else {
        return Err(SolverError::invalid("frozen solve supports the GradientNorm stop rule only"));
    };
    let tol = *tol;
    let params = config.params();
    // Mirror the writer lane's cap arithmetic exactly
    // (`AdaptiveSolver::build`): m_cap = next_pow2(n), further bounded by
    // a live engine's own cap; with no engine retained the state is at
    // the cap and `m` reads as `m_cap`.
    let mut m_cap = crate::sketch::srht::next_pow2(problem.n());
    if let Some(v) = view {
        m_cap = m_cap.min(v.max_m());
    }
    let m = view.map_or(m_cap, SketchView::m);

    let mut report = SolveReport::new(match config.variant {
        AdaptiveVariant::PolyakFirst => format!("adaptive-{}", config.kind),
        AdaptiveVariant::GradientOnly => format!("adaptive-gd-{}", config.kind),
    });
    // Pure per-nu re-key off the pinned panel — the only factorization
    // this lane ever performs.
    let t0 = Instant::now();
    let factor = match panel.factor(problem.nu) {
        Ok(f) => f,
        Err(e @ SolverError::InvalidInput(_)) => return Err(e),
        Err(e) => {
            return Ok(FrozenOutcome::NeedsGrowth {
                m: panel.m(),
                reason: format!("frozen re-key failed ({e}); writer lane owns recovery"),
            })
        }
    };
    report.factor_time_s += t0.elapsed().as_secs_f64();
    report.recovery.escalate(factor.recovery());
    report.final_m = m;
    report.peak_m = m;
    report.m_trace.reserve(config.max_iters.min(65_536));

    // Identical buffers and arithmetic order to `AdaptiveSolver` — the
    // bitwise-twin contract depends on matching `build`/`step`/`run_inner`
    // operation for operation.
    let mut scratch: Vec<f64> = Vec::new();
    let mut x_prev = x0.to_vec();
    let mut x = x0.to_vec();
    let mut g = vec![0.0; d];
    problem.gradient_into(&x, &mut scratch, &mut g);
    let mut ws_m: Vec<f64> = Vec::new();
    let mut g_tilde = vec![0.0; d];
    factor.apply_inverse_into(panel, &g, &mut ws_m, &mut g_tilde);
    let r_1 = 0.5 * dot(&g, &g_tilde);
    let mut r_t = r_1;
    let mut t = 1usize;
    let g0_norm = norm2(&g);
    let mut x_cand = vec![0.0; d];
    let mut g_cand = vec![0.0; d];
    let mut gt_cand = vec![0.0; d];

    while report.iterations < config.max_iters {
        failpoint::check("adaptive.frozen").map_err(SolverError::Internal)?;
        if let Some(deadline) = config.deadline {
            if Instant::now() >= deadline {
                return Err(SolverError::DeadlineExceeded(format!(
                    "solve passed its wall deadline after {} accepted iterations",
                    report.iterations
                )));
            }
        }
        let r_plus;
        'accept: {
            // --- Polyak candidate (steps 4–7) ---
            if config.variant == AdaptiveVariant::PolyakFirst {
                for i in 0..d {
                    x_cand[i] = x[i] - params.mu_p * g_tilde[i]
                        + params.beta_p * (x[i] - x_prev[i]);
                }
                problem.gradient_into(&x_cand, &mut scratch, &mut g_cand);
                factor.apply_inverse_into(panel, &g_cand, &mut ws_m, &mut gt_cand);
                let r_p = 0.5 * dot(&g_cand, &gt_cand);
                let c_p_plus =
                    if r_1 > 0.0 { (r_p / r_1).powf(1.0 / t as f64) } else { 0.0 };
                if c_p_plus <= params.c_p {
                    r_plus = r_p;
                    break 'accept;
                }
                report.rejections += 1;
            }

            // --- Gradient candidate (steps 9–12) ---
            for i in 0..d {
                x_cand[i] = x[i] - params.mu_gd * g_tilde[i];
            }
            problem.gradient_into(&x_cand, &mut scratch, &mut g_cand);
            factor.apply_inverse_into(panel, &g_cand, &mut ws_m, &mut gt_cand);
            let r_gd = 0.5 * dot(&g_cand, &gt_cand);
            let c_gd_plus = if r_t > 0.0 { r_gd / r_t } else { 0.0 };
            if c_gd_plus <= params.c_gd || m >= m_cap {
                // At the cap H_S is (near-)exact — the writer lane's
                // damped exact-Newton waiver, verbatim.
                r_plus = r_gd;
                break 'accept;
            }
            report.rejections += 1;

            // --- Both rejected: the writer lane would grow here ---
            return Ok(FrozenOutcome::NeedsGrowth {
                m,
                reason: format!(
                    "decrement ratio {c_gd_plus:.3e} > c_gd {:.3e} at frozen m = {m} (cap {m_cap})",
                    params.c_gd
                ),
            });
        }
        // Accept: rotate buffers exactly like `accept_candidate`.
        std::mem::swap(&mut x_prev, &mut x);
        std::mem::swap(&mut x, &mut x_cand);
        std::mem::swap(&mut g, &mut g_cand);
        std::mem::swap(&mut g_tilde, &mut gt_cand);
        r_t = r_plus;
        t += 1;
        report.iterations += 1;
        report.m_trace.push(m);
        if norm2(&g) <= tol * g0_norm {
            report.converged = true;
            break;
        }
    }

    let total = created.elapsed().as_secs_f64();
    report.wall_time_s = total;
    report.iter_time_s = total - report.sketch_time_s - report.factor_time_s;
    Ok(FrozenOutcome::Solved(Solution { x, report }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::direct;
    use crate::solvers::test_util::small_problem;
    use crate::theory::effective_dimension_from_spectrum;

    fn de_of(p: &RidgeProblem) -> f64 {
        let s = crate::linalg::svd::singular_values(&p.a.dense());
        effective_dimension_from_spectrum(&s, p.nu)
    }

    fn stop_for(p: &RidgeProblem, eps: f64) -> StopRule {
        StopRule::TrueError { x_star: direct::solve(p), eps }
    }

    #[test]
    fn converges_from_m_equals_one_gaussian() {
        let p = small_problem(256, 32, 0.5, 1);
        let cfg = AdaptiveConfig::new(SketchKind::Gaussian);
        let sol = solve(&p, &vec![0.0; 32], &cfg, &stop_for(&p, 1e-10), 11).unwrap();
        assert!(sol.report.converged, "adaptive failed: {:?}", sol.report.final_rel_error);
        assert!(sol.report.final_m >= 1);
        assert_eq!(sol.report.solver, "adaptive-gaussian");
    }

    #[test]
    fn converges_from_m_equals_one_srht() {
        let p = small_problem(256, 32, 0.5, 2);
        let cfg = AdaptiveConfig::new(SketchKind::Srht);
        let sol = solve(&p, &vec![0.0; 32], &cfg, &stop_for(&p, 1e-10), 12).unwrap();
        assert!(sol.report.converged);
    }

    #[test]
    fn converges_with_sparse_sketch() {
        let p = small_problem(256, 32, 0.5, 3);
        let cfg = AdaptiveConfig::new(SketchKind::Sparse);
        let sol = solve(&p, &vec![0.0; 32], &cfg, &stop_for(&p, 1e-8), 13).unwrap();
        assert!(sol.report.converged);
    }

    #[test]
    fn sketch_size_bounded_by_theorem_5() {
        // m <= 2 * c0 * d_e / rho with c0 <= 5 (Gaussian), modulo the
        // doubling overshoot already included in the factor 2.
        let p = small_problem(1024, 64, 1.0, 4);
        let d_e = de_of(&p);
        let cfg = AdaptiveConfig::new(SketchKind::Gaussian);
        let sol = solve(&p, &vec![0.0; 64], &cfg, &stop_for(&p, 1e-10), 14).unwrap();
        let bound = crate::theory::bounds::gaussian_sketch_size_bound(cfg.rho, d_e);
        assert!(sol.report.converged);
        assert!(
            (sol.report.peak_m as f64) <= bound.max(2.0),
            "peak m {} exceeds Theorem 5 bound {:.1} (d_e {:.1})",
            sol.report.peak_m,
            bound,
            d_e
        );
    }

    #[test]
    fn rejections_logarithmic() {
        let p = small_problem(512, 64, 0.5, 5);
        let cfg = AdaptiveConfig::new(SketchKind::Gaussian);
        let sol = solve(&p, &vec![0.0; 64], &cfg, &stop_for(&p, 1e-10), 15).unwrap();
        // Doublings from m=1 can't exceed log2(n_pad)+1, and should be
        // far fewer on this easy problem.
        assert!(sol.report.doublings <= 11, "doublings {}", sol.report.doublings);
    }

    #[test]
    fn gradient_only_variant_converges() {
        let p = small_problem(256, 32, 0.3, 6);
        let mut cfg = AdaptiveConfig::new(SketchKind::Srht);
        cfg.variant = AdaptiveVariant::GradientOnly;
        let sol = solve(&p, &vec![0.0; 32], &cfg, &stop_for(&p, 1e-10), 16).unwrap();
        assert!(sol.report.converged);
        assert_eq!(sol.report.solver, "adaptive-gd-srht");
    }

    #[test]
    fn small_de_means_small_final_m() {
        // Large nu => tiny d_e => the adaptive m must stay small even
        // though d = 64.
        let p = small_problem(512, 64, 50.0, 7);
        let d_e = de_of(&p);
        assert!(d_e < 2.0, "test premise: d_e = {d_e}");
        let cfg = AdaptiveConfig::new(SketchKind::Gaussian);
        let sol = solve(&p, &vec![0.0; 64], &cfg, &stop_for(&p, 1e-10), 17).unwrap();
        assert!(sol.report.converged);
        assert!(sol.report.peak_m <= 64, "peak m {} should be << d", sol.report.peak_m);
    }

    #[test]
    fn warm_start_keeps_convergence() {
        let p = small_problem(256, 32, 0.2, 8);
        let x_star = direct::solve(&p);
        let near: Vec<f64> = x_star.iter().map(|v| v * 0.99).collect();
        let cfg = AdaptiveConfig::new(SketchKind::Gaussian);
        let sol = solve(&p, &near, &cfg, &StopRule::TrueError { x_star, eps: 1e-10 }, 18).unwrap();
        assert!(sol.report.converged);
    }

    #[test]
    fn m_trace_monotone_nondecreasing() {
        let p = small_problem(256, 32, 0.1, 9);
        let cfg = AdaptiveConfig::new(SketchKind::Gaussian);
        let sol = solve(&p, &vec![0.0; 32], &cfg, &stop_for(&p, 1e-10), 19).unwrap();
        for w in sol.report.m_trace.windows(2) {
            assert!(w[1] >= w[0], "m_trace must never shrink");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let p = small_problem(128, 16, 0.5, 10);
        let cfg = AdaptiveConfig::new(SketchKind::Srht);
        let stop = stop_for(&p, 1e-9);
        let s1 = solve(&p, &vec![0.0; 16], &cfg, &stop, 77).unwrap();
        let s2 = solve(&p, &vec![0.0; 16], &cfg, &stop, 77).unwrap();
        assert_eq!(s1.x, s2.x);
        assert_eq!(s1.report.iterations, s2.report.iterations);
    }

    #[test]
    fn healthy_solve_reports_no_recovery() {
        let p = small_problem(128, 16, 0.5, 23);
        let cfg = AdaptiveConfig::new(SketchKind::Gaussian);
        let sol = solve(&p, &vec![0.0; 16], &cfg, &stop_for(&p, 1e-9), 24).unwrap();
        assert_eq!(sol.report.recovery, RecoveryRung::None);
        assert_eq!(sol.report.recovery.label(), "none");
    }

    #[test]
    fn expired_deadline_is_a_structured_error() {
        let p = small_problem(128, 16, 0.5, 25);
        let mut cfg = AdaptiveConfig::new(SketchKind::Gaussian);
        // `step` checks the deadline with `>=` before any work, so a
        // deadline of "now" fires on the first iteration.
        cfg.deadline = Some(Instant::now());
        match solve(&p, &vec![0.0; 16], &cfg, &stop_for(&p, 1e-9), 26) {
            Err(SolverError::DeadlineExceeded(_)) => {}
            other => panic!("expected DeadlineExceeded, got {:?}", other.map(|s| s.report)),
        }
    }

    #[test]
    fn invalid_x0_is_a_structured_error() {
        let p = small_problem(64, 8, 0.5, 27);
        let cfg = AdaptiveConfig::new(SketchKind::Gaussian);
        match solve(&p, &vec![0.0; 9], &cfg, &stop_for(&p, 1e-9), 28) {
            Err(SolverError::InvalidInput(_)) => {}
            other => panic!("expected InvalidInput, got {:?}", other.map(|s| s.report)),
        }
    }

    #[test]
    fn resume_reuses_sketch_across_nu() {
        // Solve at nu = 0.3 (grows the sketch), hand the state to a solve
        // at nu = 1.0 on the same data: the resumed run must converge with
        // zero sketch time, no growth, and the same m.
        let ds = crate::data::synthetic::exponential_decay(256, 32, 20);
        let p1 = RidgeProblem::new(ds.a.clone(), ds.b.clone(), 0.3);
        let cfg = AdaptiveConfig::new(SketchKind::Gaussian);
        let stop1 = stop_for(&p1, 1e-9);
        let solver = AdaptiveSolver::new(&p1, &vec![0.0; 32], cfg.clone(), stop1, 21).unwrap();
        let (sol1, state) = solver.run_with_state().unwrap();
        assert!(sol1.report.converged);
        let m1 = state.m();
        assert!(!state.at_cap());
        assert!(state.approx_bytes() > 0);

        let p2 = RidgeProblem::new(ds.a.clone(), ds.b.clone(), 1.0);
        let stop2 = stop_for(&p2, 1e-9);
        let resumed = AdaptiveSolver::resume(&p2, &sol1.x, cfg, stop2, state).unwrap();
        let (sol2, state2) = resumed.run_with_state().unwrap();
        assert!(sol2.report.converged);
        assert_eq!(sol2.report.sketch_time_s, 0.0, "resume must not re-sketch");
        assert_eq!(sol2.report.doublings, 0);
        assert_eq!(state2.m(), m1);

        // And the resumed solution is the true optimum at nu = 1.0.
        let x_star = direct::solve(&p2);
        let rel = p2.prediction_error(&sol2.x, &x_star)
            / p2.prediction_error(&vec![0.0; 32], &x_star);
        assert!(rel < 1e-8, "relative error {rel}");
    }

    // ---- frozen read lane ----

    fn bits(x: &[f64]) -> Vec<u64> {
        x.iter().map(|v| v.to_bits()).collect()
    }

    #[test]
    fn frozen_solve_is_a_bitwise_twin_of_the_mutex_lane() {
        // All three sketch families x dense/CSR: warm a state at nu = 0.5,
        // then solve nu = 1.25 (larger nu => smaller d_eff => no growth)
        // through both lanes. The frozen lane pins the panel Arc + view;
        // the mutex lane resumes the state. Results must agree BITWISE.
        let ds = crate::data::synthetic::exponential_decay(256, 32, 33);
        let dense = ds.a.dense().into_owned();
        let ops = [
            crate::linalg::Operand::Dense(dense.clone()),
            crate::linalg::Operand::Sparse(crate::linalg::sparse::CsrMatrix::from_dense(&dense)),
        ];
        let stop = StopRule::GradientNorm { tol: 1e-8 };
        for op in ops {
            for kind in [SketchKind::Gaussian, SketchKind::Srht, SketchKind::Sparse] {
                let p1 = RidgeProblem::new(op.clone(), ds.b.clone(), 0.5);
                let p2 = RidgeProblem::new(op.clone(), ds.b.clone(), 1.25);
                let cfg = AdaptiveConfig::new(kind);
                let solver =
                    AdaptiveSolver::new(&p1, &vec![0.0; 32], cfg.clone(), stop.clone(), 9)
                        .unwrap();
                let (sol1, state) = solver.run_with_state().unwrap();

                // Read lane: pinned artifacts, pure factor, no mutation.
                let panel = Arc::clone(state.panel());
                let view = state.view();
                let frozen =
                    solve_frozen(&p2, &sol1.x, &cfg, &stop, &panel, view.as_ref()).unwrap();
                let FrozenOutcome::Solved(fsol) = frozen else {
                    panic!("{kind:?}: larger nu must not need growth");
                };

                // Writer lane twin on the same state.
                let resumed =
                    AdaptiveSolver::resume(&p2, &sol1.x, cfg, stop.clone(), state).unwrap();
                let (msol, _) = resumed.run_with_state().unwrap();
                assert_eq!(msol.report.doublings, 0, "{kind:?}: twin premise (no growth)");
                assert_eq!(
                    bits(&fsol.x),
                    bits(&msol.x),
                    "{kind:?}/{}: frozen and mutex lanes diverged",
                    if matches!(op, crate::linalg::Operand::Dense(_)) { "dense" } else { "csr" },
                );
                assert_eq!(fsol.report.iterations, msol.report.iterations);
                assert_eq!(fsol.report.final_m, msol.report.final_m);
                assert_eq!(fsol.report.converged, msol.report.converged);
                assert!(fsol.report.converged);
            }
        }
    }

    #[test]
    fn frozen_solve_reports_needs_growth_exactly_when_the_writer_would_grow() {
        // Warm at nu = 50 (d_eff < 2 => tiny frozen m), then ask for
        // nu = 0.05 (d_eff far above the frozen m): the frozen lane must
        // return the typed NeedsGrowth deferral, and the mutex twin must
        // indeed grow on the same query.
        let ds = crate::data::synthetic::exponential_decay(512, 64, 34);
        let stop = StopRule::GradientNorm { tol: 1e-8 };
        let p1 = RidgeProblem::new(ds.a.clone(), ds.b.clone(), 50.0);
        let p2 = RidgeProblem::new(ds.a.clone(), ds.b.clone(), 0.05);
        let cfg = AdaptiveConfig::new(SketchKind::Gaussian);
        let solver =
            AdaptiveSolver::new(&p1, &vec![0.0; 64], cfg.clone(), stop.clone(), 10).unwrap();
        let (sol1, state) = solver.run_with_state().unwrap();
        let frozen_m = state.m();

        let panel = Arc::clone(state.panel());
        let view = state.view();
        match solve_frozen(&p2, &sol1.x, &cfg, &stop, &panel, view.as_ref()).unwrap() {
            FrozenOutcome::NeedsGrowth { m, reason } => {
                assert_eq!(m, frozen_m);
                assert!(reason.contains("frozen m"), "reason: {reason}");
            }
            FrozenOutcome::Solved(s) => {
                panic!("expected NeedsGrowth at m = {frozen_m}, solved in {} iters", s.report.iterations)
            }
        }
        // The pinned panel is untouched by the deferral.
        assert_eq!(panel.m(), frozen_m);

        // Writer twin grows on exactly this query.
        let resumed = AdaptiveSolver::resume(&p2, &sol1.x, cfg, stop, state).unwrap();
        let (msol, _) = resumed.run_with_state().unwrap();
        assert!(msol.report.doublings >= 1, "twin premise: the writer lane grows here");
    }

    #[test]
    fn frozen_solve_at_cap_takes_the_exact_hessian_waiver() {
        // A state frozen AT the cap (no engine; the panel holds the exact
        // Hessian) can never defer: the at-cap damped-Newton waiver
        // accepts the gradient candidate unconditionally, mirroring the
        // writer lane. Build twin at-cap states via restore (deterministic)
        // and compare bitwise.
        let ds = crate::data::synthetic::exponential_decay(64, 8, 35);
        let a = std::sync::Arc::new(ds.a.clone());
        let stop = StopRule::GradientNorm { tol: 1e-9 };
        let p = RidgeProblem::new(ds.a.clone(), ds.b.clone(), 0.3);
        let cfg = AdaptiveConfig::new(SketchKind::Gaussian);

        let state =
            AdaptiveSessionState::restore(None, 0.7, Xoshiro256::seed_from_u64(1), &a).unwrap();
        assert!(state.at_cap());
        assert!(state.view().is_none());
        let panel = Arc::clone(state.panel());
        let frozen = solve_frozen(&p, &vec![0.0; 8], &cfg, &stop, &panel, None).unwrap();
        let FrozenOutcome::Solved(fsol) = frozen else {
            panic!("at-cap frozen solve must never need growth");
        };
        assert!(fsol.report.converged);

        let twin =
            AdaptiveSessionState::restore(None, 0.7, Xoshiro256::seed_from_u64(1), &a).unwrap();
        let resumed = AdaptiveSolver::resume(&p, &vec![0.0; 8], cfg, stop, twin).unwrap();
        let (msol, _) = resumed.run_with_state().unwrap();
        assert_eq!(bits(&fsol.x), bits(&msol.x));
        assert_eq!(fsol.report.iterations, msol.report.iterations);
    }

    #[test]
    fn snapshot_byte_dedupe_charges_per_allocation() {
        // A freshly published snapshot shares everything with the live
        // state => 0 extra bytes. After a writer set_nu the snapshot
        // retains only its own NuFactor (panel still shared); `None`
        // charges the full footprint.
        let ds = crate::data::synthetic::exponential_decay(128, 16, 36);
        let p = RidgeProblem::new(ds.a.clone(), ds.b.clone(), 0.5);
        let cfg = AdaptiveConfig::new(SketchKind::Gaussian);
        let stop = StopRule::GradientNorm { tol: 1e-8 };
        let solver = AdaptiveSolver::new(&p, &vec![0.0; 16], cfg.clone(), stop.clone(), 11).unwrap();
        let (sol, state) = solver.run_with_state().unwrap();
        let published = state.clone(); // what a SessionSnapshot holds
        assert_eq!(published.bytes_not_shared_with(Some(&state)), 0);
        assert_eq!(published.bytes_not_shared_with(None), published.approx_bytes());

        // Writer re-keys: COW unwraps clone the cache, the panel Arc is
        // carried over — the stale snapshot now retains its factor only.
        let p2 = RidgeProblem::new(ds.a.clone(), ds.b.clone(), 0.9);
        let resumed = AdaptiveSolver::resume(&p2, &sol.x, cfg, stop, state).unwrap();
        let (_, state2) = resumed.run_with_state().unwrap();
        let extra = published.bytes_not_shared_with(Some(&state2));
        assert!(extra > 0, "stale snapshot must charge its own factor");
        assert!(
            extra < published.approx_bytes(),
            "panel/engine still shared must not be double-charged: {extra} vs {}",
            published.approx_bytes()
        );
    }
}
