//! Cached application of `H_S^{-1} = ((SA)^T SA + nu^2 I_d)^{-1}`,
//! growable in place when the adaptive solver appends sketch rows.
//!
//! Theorem 7's cost model hinges on this: with `m <= d` one factors the
//! *small* `m x m` matrix `K = nu^2 I_m + (SA)(SA)^T` once per sketch
//! (`O(m^2 d)`), after which each `H_S^{-1} g` costs `O(m d)` via the
//! Woodbury identity
//! `H_S^{-1} = (1/nu^2) (I - (SA)^T K^{-1} (SA))`.
//! When `m > d` the direct `d x d` factorization is cheaper and we switch
//! automatically.
//!
//! # The immutable/mutable seam
//!
//! The state splits cleanly along what depends on `nu` and what does not:
//!
//! * [`GramPanel`] — the sketch rows `S̃A`, their normalization, and the
//!   cached unnormalized Gram (`(S̃A)(S̃A)^T` or `(S̃A)^T(S̃A)` by branch).
//!   None of it depends on `nu`. The panel is **immutable** and shared
//!   behind an `Arc`: concurrent readers may hold it while a writer grows
//!   its own copy (copy-on-write, see [`WoodburyCache::grow`]).
//! * [`NuFactor`] — the per-`nu` Cholesky. Produced by the *pure*
//!   [`GramPanel::factor`]: `&GramPanel + nu -> NuFactor`, no mutation
//!   anywhere, so any number of readers can derive factors for distinct
//!   `nu` from one shared panel simultaneously. This is the cross-`nu`
//!   preconditioner reuse of arXiv:2104.14101 made lock-free.
//!
//! [`WoodburyCache`] pairs one panel with one factor and keeps the
//! classic mutable API (`set_nu`, `grow`) as thin writer-lane wrappers —
//! existing callers behave bitwise as before the split.
//!
//! # Growth reuse
//!
//! Algorithm 1 grows `m` by appending rows; rebuilding the cache from
//! scratch on every growth re-pays the whole `O(m^2 d)` Gram. Instead the
//! cache accepts the sketch rows *unnormalized* together with the scalar
//! `scale` such that the effective embedding is `scale * S̃` (the sketch
//! engine keeps `1/sqrt(m)` out of the stored rows exactly so prior rows
//! survive growth). [`WoodburyCache::grow`] then:
//!
//! * keeps the cached unnormalized Gram `U = (S̃A)(S̃A)^T` and computes only
//!   the `Δm x m` cross block and `Δm x Δm` corner — `O(Δm m d)` instead of
//!   `O(m^2 d)`;
//! * when the scale is unchanged, extends the Cholesky factor with a
//!   bordered update (`O(Δm m^2)`, [`Cholesky::extend_bordered`]). Note
//!   the adaptive solver's growth always rescales (`1/sqrt(m)` ->
//!   `1/sqrt(m+Δm)`), which shifts the *entire* `K = nu^2 I + c U`
//!   diagonal, so that caller always takes the refactor branch — the
//!   bordered path serves fixed-scale row streaming (pre-normalized rows
//!   appended at `scale = 1`, e.g. mini-batch Gram updates) and is kept
//!   exact under test for that use. When growth rescales, the `m x m`
//!   factor is rebuilt from the cached Gram at `O(m^3)` — still free of
//!   the `O(m^2 d)` Gram term that dominates for `m <= d`;
//! * past `m > d` it maintains the `d x d` inner Gram incrementally
//!   (`O(Δm d^2)` per growth) and refactors at `O(d^3)`.
//!
//! Growth commits through `Arc::make_mut`: a cache whose panel nobody
//! else holds mutates it in place (bitwise the pre-split behavior); a
//! panel shared with a published snapshot is deep-copied first, so
//! readers pinned to the old panel keep answering from it unchanged
//! (snapshot isolation).

//! # Failure semantics
//!
//! Every fallible operation (`new*`, `set_nu`, `grow`) is
//! **transactional**: it stages its new Gram blocks and factorization in
//! locals and commits only after the Cholesky succeeds, so an `Err`
//! leaves the cache exactly as it was — no half-taken Gram, no `nu`
//! re-key without a matching factor. Factorizations retry with
//! escalating diagonal jitter ([`Cholesky::factor_with_jitter`]); the
//! rung used is recorded in [`WoodburyCache::recovery`] so degraded
//! factorizations are visible to the solvers' reports.

use super::error::{RecoveryRung, SolverError};
use crate::linalg::cholesky::Cholesky;
use crate::linalg::{axpy, scale as scale_vec, Matrix};
use crate::util::failpoint;
use std::sync::Arc;

/// Which factorization branch is active.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WoodburyMode {
    /// `m <= d`: factor `nu^2 I_m + (SA)(SA)^T`.
    SmallSketch,
    /// `m > d`: factor `(SA)^T (SA) + nu^2 I_d` directly.
    Direct,
}

/// The `nu`-independent half of the sketched Hessian: sketch rows, their
/// normalization, and the cached unnormalized Gram. Immutable once built
/// — every mutation in the system goes through [`WoodburyCache`], which
/// copies-on-write when the panel is shared.
#[derive(Clone)]
pub struct GramPanel {
    /// Sketch rows as provided — unnormalized when `scale != 1`.
    sa: Matrix,
    /// `scale^2` for the effective embedding `scale * sa`.
    scale2: f64,
    mode: WoodburyMode,
    /// SmallSketch: unnormalized outer Gram `sa sa^T` (`m x m`), kept so
    /// growth only computes the new cross/corner blocks.
    outer_gram: Option<Matrix>,
    /// Direct: unnormalized inner Gram `sa^T sa` (`d x d`), updated by
    /// `O(Δm d^2)` rank-`Δm` additions on growth.
    inner_gram: Option<Matrix>,
}

impl GramPanel {
    /// Build the panel for unnormalized sketch rows `sa` whose effective
    /// embedding is `scale * sa`: pick the branch from `m` vs `d` and
    /// compute the matching Gram (`O(m^2 d)` or `O(m d^2)`). This is the
    /// only expensive, `nu`-free work; everything `nu`-dependent lives in
    /// [`GramPanel::factor`].
    pub fn build(sa: Matrix, scale: f64) -> Result<Self, SolverError> {
        if !(scale > 0.0 && scale.is_finite()) {
            return Err(SolverError::invalid(format!("invalid sketch scale: {scale}")));
        }
        let (m, d) = (sa.rows(), sa.cols());
        let scale2 = scale * scale;
        if m <= d {
            let u = sa.gram_outer(); // unnormalized (S̃A)(S̃A)^T, m x m
            Ok(Self {
                sa,
                scale2,
                mode: WoodburyMode::SmallSketch,
                outer_gram: Some(u),
                inner_gram: None,
            })
        } else {
            let inner = sa.gram(); // unnormalized (S̃A)^T(S̃A), d x d
            Ok(Self {
                sa,
                scale2,
                mode: WoodburyMode::Direct,
                outer_gram: None,
                inner_gram: Some(inner),
            })
        }
    }

    /// Derive the per-`nu` factorization from the cached Gram — **pure**:
    /// `&self` only, so concurrent readers can each factor their own `nu`
    /// from one shared panel with no coordination. Costs `O(m^3)`
    /// (small-sketch) or `O(d^3)` (direct); never recomputes the Gram and
    /// never touches sketch rows. Factorizations retry with escalating
    /// diagonal jitter; the rung used rides in the returned factor.
    pub fn factor(&self, nu: f64) -> Result<NuFactor, SolverError> {
        if !(nu > 0.0 && nu.is_finite()) {
            return Err(SolverError::invalid(format!("invalid nu: {nu}")));
        }
        let nu2 = nu * nu;
        let (chol, recovery) = match self.mode {
            WoodburyMode::SmallSketch => {
                let u = self.outer_gram.as_ref().expect("SmallSketch keeps outer_gram");
                factor_small(u, self.scale2, nu2)?
            }
            WoodburyMode::Direct => {
                let inner = self.inner_gram.as_ref().expect("Direct keeps inner_gram");
                factor_direct(inner, self.scale2, nu2)?
            }
        };
        Ok(NuFactor { nu2, dim: self.factor_dim(), chol, recovery })
    }

    /// Sketch size `m`.
    pub fn m(&self) -> usize {
        self.sa.rows()
    }

    /// Column dimension `d` of the sketched matrix.
    pub fn d(&self) -> usize {
        self.sa.cols()
    }

    /// Active branch.
    pub fn mode(&self) -> WoodburyMode {
        self.mode
    }

    /// Effective embedding scale (`1.0` for pre-normalized rows).
    pub fn scale(&self) -> f64 {
        self.scale2.sqrt()
    }

    /// The stored (unnormalized) sketch rows.
    pub fn sa(&self) -> &Matrix {
        &self.sa
    }

    /// Dimension of the factorization this panel's branch produces.
    fn factor_dim(&self) -> usize {
        match self.mode {
            WoodburyMode::SmallSketch => self.sa.rows(),
            WoodburyMode::Direct => self.sa.cols(),
        }
    }

    /// Approximate heap footprint in bytes (sketch rows + cached Gram).
    /// The panel is shared behind an `Arc`; byte budgets must charge it
    /// **once per allocation**, not per handle — compare `Arc::ptr_eq`
    /// before summing.
    pub fn approx_bytes(&self) -> usize {
        let mat = |m: &Matrix| m.rows() * m.cols() * std::mem::size_of::<f64>();
        let gram = self.outer_gram.as_ref().map_or(0, mat)
            + self.inner_gram.as_ref().map_or(0, mat);
        mat(&self.sa) + gram
    }

    /// Explicit `H_S` at `nu` (tests / diagnostics only).
    pub fn h_s(&self, nu2: f64) -> Matrix {
        let mut h = self.sa.gram();
        scale_vec(self.scale2, h.as_mut_slice());
        h.add_diag(nu2);
        h
    }
}

/// The `nu`-dependent half: one Cholesky factorization of
/// `K = nu^2 I + scale^2 U` (small-sketch) or `H = scale^2 inner + nu^2 I`
/// (direct), applied against the [`GramPanel`] it was derived from.
#[derive(Clone)]
pub struct NuFactor {
    nu2: f64,
    /// Factor dimension (`m` or `d` by branch) — pinned at factor time so
    /// byte accounting and pairing checks need no panel.
    dim: usize,
    chol: Cholesky,
    /// Rung this particular factorization needed (`Jitter` when the
    /// diagonal had to be perturbed).
    recovery: RecoveryRung,
}

impl NuFactor {
    /// Regularization level this factorization is keyed to.
    pub fn nu(&self) -> f64 {
        self.nu2.sqrt()
    }

    /// Recovery rung this factorization needed.
    pub fn recovery(&self) -> RecoveryRung {
        self.recovery
    }

    /// Approximate heap footprint of the factor alone (the panel is
    /// charged separately, once per allocation).
    pub fn approx_bytes(&self) -> usize {
        self.dim * self.dim * std::mem::size_of::<f64>()
    }

    /// Apply `H_S^{-1} g` into `out` (length `d`), allocation-free in the
    /// steady state: `ws_m` is length-`m` scratch resized only when the
    /// sketch grows. Cost: `O(m d + m^2)` (small-sketch branch) or
    /// `O(d^2)` (direct branch). This is the per-iteration primitive of
    /// the IHS solvers' workspace loops. `panel` must be the panel this
    /// factor was derived from.
    pub fn apply_inverse_into(
        &self,
        panel: &GramPanel,
        g: &[f64],
        ws_m: &mut Vec<f64>,
        out: &mut [f64],
    ) {
        assert_eq!(g.len(), panel.sa.cols(), "apply_inverse dimension mismatch");
        assert_eq!(out.len(), panel.sa.cols(), "apply_inverse output mismatch");
        debug_assert_eq!(self.dim, panel.factor_dim(), "factor derived from a different panel");
        match panel.mode {
            WoodburyMode::SmallSketch => {
                // (1/nu^2) (g - scale^2 (S̃A)^T K^{-1} (S̃A) g) with
                // K = nu^2 I + scale^2 (S̃A)(S̃A)^T.
                ws_m.resize(panel.sa.rows(), 0.0);
                panel.sa.matvec_into(g, ws_m);
                self.chol.solve_in_place(ws_m);
                out.copy_from_slice(g);
                // out -= scale^2 (S̃A)^T kinv, fused as per-row axpys.
                for i in 0..panel.sa.rows() {
                    let c = panel.scale2 * ws_m[i];
                    if c != 0.0 {
                        axpy(-c, panel.sa.row(i), out);
                    }
                }
                scale_vec(1.0 / self.nu2, out);
            }
            WoodburyMode::Direct => {
                out.copy_from_slice(g);
                self.chol.solve_in_place(out);
            }
        }
    }

    /// Apply `H_S^{-1} g` (allocating wrapper).
    pub fn apply_inverse(&self, panel: &GramPanel, g: &[f64]) -> Vec<f64> {
        let mut ws_m = Vec::new();
        let mut out = vec![0.0; panel.sa.cols()];
        self.apply_inverse_into(panel, g, &mut ws_m, &mut out);
        out
    }

    /// Apply `H_S^{-1}` to `k` gradients at once: `g` is `d x k` (column
    /// `j` = gradient `j`), the result has the same shape. One BLAS-3
    /// pass replaces `k` BLAS-2 [`NuFactor::apply_inverse`] calls —
    /// `O(m d k + m^2 k)` (small-sketch branch, via GEMM +
    /// [`Cholesky::solve_matrix_in_place`]) or `O(d^2 k)` (direct) — and
    /// inherits the block kernels' thread parallelism. Column `j` agrees
    /// with `apply_inverse(g_j)` to roundoff (the block kernels
    /// accumulate in blocked order, not the vector order). This is the
    /// per-iteration primitive of the block multi-RHS solver
    /// ([`crate::solvers::block`]).
    pub fn apply_inverse_block(&self, panel: &GramPanel, g: &Matrix) -> Matrix {
        assert_eq!(g.rows(), panel.sa.cols(), "apply_inverse_block dimension mismatch");
        debug_assert_eq!(self.dim, panel.factor_dim(), "factor derived from a different panel");
        match panel.mode {
            WoodburyMode::SmallSketch => {
                // (1/nu^2) (G - scale^2 (S̃A)^T K^{-1} (S̃A) G) with
                // K = nu^2 I + scale^2 (S̃A)(S̃A)^T.
                let mut w = panel.sa.matmul(g); // m x k
                self.chol.solve_matrix_in_place(&mut w);
                let mut out = panel.sa.matmul_tn(&w); // d x k
                let inv_nu2 = 1.0 / self.nu2;
                for i in 0..out.rows() {
                    let grow = g.row(i);
                    let orow = out.row_mut(i);
                    for (o, &gv) in orow.iter_mut().zip(grow) {
                        *o = (gv - panel.scale2 * *o) * inv_nu2;
                    }
                }
                out
            }
            WoodburyMode::Direct => {
                let mut out = g.clone();
                self.chol.solve_matrix_in_place(&mut out);
                out
            }
        }
    }
}

/// Cached factorization of the sketched Hessian: one shared [`GramPanel`]
/// paired with the [`NuFactor`] for the currently keyed `nu`. The mutable
/// writer-lane API (`set_nu`, `grow`) lives here; read-lane users take
/// [`WoodburyCache::panel`] and derive their own factors.
#[derive(Clone)]
pub struct WoodburyCache {
    panel: Arc<GramPanel>,
    factor: NuFactor,
    /// Highest recovery rung any factorization of this cache has needed
    /// (`Jitter` when `factor_with_jitter` had to perturb the diagonal).
    recovery: RecoveryRung,
}

impl WoodburyCache {
    /// Factor for an already-normalized sketched matrix `SA` (`m x d`)
    /// and `nu` — the one-shot path used by the fixed-size solvers.
    pub fn new(sa: Matrix, nu: f64) -> Result<Self, SolverError> {
        Self::new_scaled(sa, nu, 1.0)
    }

    /// Factor for unnormalized sketch rows `sa` whose effective embedding
    /// is `scale * sa` (the incremental growth path: the `1/sqrt(m)`
    /// normalization is folded into the solve so growth never rescales
    /// stored rows).
    pub fn new_scaled(sa: Matrix, nu: f64, scale: f64) -> Result<Self, SolverError> {
        if !(nu > 0.0 && nu.is_finite()) {
            return Err(SolverError::invalid(format!("invalid nu: {nu}")));
        }
        let panel = GramPanel::build(sa, scale)?;
        let factor = panel.factor(nu)?;
        let recovery = factor.recovery;
        Ok(Self { panel: Arc::new(panel), factor, recovery })
    }

    /// Sketch size `m`.
    pub fn m(&self) -> usize {
        self.panel.m()
    }

    /// Column dimension `d` of the sketched matrix.
    pub fn d(&self) -> usize {
        self.panel.d()
    }

    /// Active branch.
    pub fn mode(&self) -> WoodburyMode {
        self.panel.mode
    }

    /// Regularization level the current factorization is keyed to.
    pub fn nu(&self) -> f64 {
        self.factor.nu()
    }

    /// Highest recovery rung any factorization of this cache has needed
    /// (solvers escalate this into their [`super::SolveReport`]).
    pub fn recovery(&self) -> RecoveryRung {
        self.recovery
    }

    /// The shared immutable panel — the read lane's entry point: clone
    /// the `Arc` out, derive per-`nu` factors with [`GramPanel::factor`],
    /// and apply them with no further coordination with this cache.
    pub fn panel(&self) -> &Arc<GramPanel> {
        &self.panel
    }

    /// The factor currently keyed (writer lane's `nu`).
    pub fn factor(&self) -> &NuFactor {
        &self.factor
    }

    /// Re-key the cached factorization to a new regularization level.
    ///
    /// The Gram blocks (`(S̃A)(S̃A)^T` or `(S̃A)^T(S̃A)`) do not depend on
    /// `nu`, so switching regularization costs only the `O(m^3)` (small-
    /// sketch) or `O(d^3)` (direct) re-factor — never the `O(m^2 d)` Gram
    /// recompute, and never any sketch work. This is what lets a session
    /// reuse one grown sketch across a whole regularization path
    /// (arXiv:2104.14101's cross-`nu` preconditioner reuse). A no-op when
    /// `nu` is unchanged. The panel is untouched — a snapshot sharing it
    /// keeps sharing it.
    ///
    /// Transactional: the new factorization is staged in a local and
    /// committed together with `nu`, so on `Err` the cache still answers
    /// at its previous regularization level.
    pub fn set_nu(&mut self, nu: f64) -> Result<(), SolverError> {
        if !(nu > 0.0 && nu.is_finite()) {
            return Err(SolverError::invalid(format!("invalid nu: {nu}")));
        }
        let nu2 = nu * nu;
        if nu2 == self.factor.nu2 {
            return Ok(());
        }
        failpoint::check("woodbury.set_nu").map_err(SolverError::Internal)?;
        let factor = self.panel.factor(nu)?;
        self.recovery.escalate(factor.recovery);
        self.factor = factor;
        Ok(())
    }

    /// Approximate heap footprint in bytes (sketch rows + cached Gram +
    /// Cholesky factor) — used by registry byte budgets. Counts the panel
    /// as if owned; callers sharing the panel across handles must dedupe
    /// via [`WoodburyCache::panel`] + `Arc::ptr_eq`.
    pub fn approx_bytes(&self) -> usize {
        self.panel.approx_bytes() + self.factor.approx_bytes()
    }

    /// Effective embedding scale (`1.0` for pre-normalized rows).
    pub fn scale(&self) -> f64 {
        self.panel.scale()
    }

    /// Append `Δm` unnormalized sketch rows and update the factorization,
    /// reusing all previously computed Gram blocks. `new_scale` is the
    /// normalization of the *grown* embedding (`1/sqrt(m + Δm)`); passing
    /// the current scale unchanged takes the bordered-Cholesky fast path
    /// (fixed-scale row streaming — the adaptive solver's `1/sqrt(m)`
    /// rescale always lands in the Gram-reusing refactor branch instead).
    ///
    /// Transactional: new Gram blocks and the new factorization are
    /// staged in locals and committed only after the Cholesky succeeds,
    /// so on `Err` the cache keeps its previous rows and factorization
    /// intact (the old Gram is never `take()`n). The commit goes through
    /// `Arc::make_mut`: a uniquely held panel mutates in place (the
    /// pre-split behavior, bitwise); a panel shared with a snapshot is
    /// deep-copied, leaving the snapshot's readers pinned to the old
    /// rows.
    pub fn grow(&mut self, new_rows: &Matrix, new_scale: f64) -> Result<(), SolverError> {
        if new_rows.cols() != self.panel.sa.cols() {
            return Err(SolverError::invalid(format!(
                "grow: column mismatch ({} vs {})",
                new_rows.cols(),
                self.panel.sa.cols()
            )));
        }
        if !(new_scale > 0.0 && new_scale.is_finite()) {
            return Err(SolverError::invalid(format!("invalid sketch scale: {new_scale}")));
        }
        if new_rows.rows() == 0 {
            return Ok(());
        }
        failpoint::check("woodbury.grow").map_err(SolverError::Internal)?;
        let d = self.panel.sa.cols();
        let m_new = self.panel.sa.rows() + new_rows.rows();
        let new_scale2 = new_scale * new_scale;

        match self.panel.mode {
            WoodburyMode::SmallSketch if m_new <= d => {
                // O(Δm m d) cross + O(Δm^2 d) corner; the old m x m block
                // of U is reused verbatim (read, not taken — a failed
                // factor must leave it in place).
                let cross = new_rows.matmul_nt(&self.panel.sa); // Δm x m
                let corner = new_rows.gram_outer(); // Δm x Δm
                let u_old = self.panel.outer_gram.as_ref().expect("SmallSketch keeps outer_gram");
                let m_old = u_old.rows();
                let dm = cross.rows();
                let mut u = Matrix::zeros(m_new, m_new);
                for i in 0..m_old {
                    u.row_mut(i)[..m_old].copy_from_slice(u_old.row(i));
                    for j in 0..dm {
                        u.row_mut(i)[m_old + j] = cross.get(j, i);
                    }
                }
                for i in 0..dm {
                    u.row_mut(m_old + i)[..m_old].copy_from_slice(cross.row(i));
                    u.row_mut(m_old + i)[m_old..].copy_from_slice(corner.row(i));
                }

                let bordered = if new_scale2 == self.panel.scale2 {
                    // Scale unchanged: K grows by a plain border — extend
                    // the factor in O(Δm m^2). `extend_bordered` leaves
                    // the factor untouched when the border is indefinite.
                    let mut cross_k = cross.clone();
                    scale_vec(self.panel.scale2, cross_k.as_mut_slice());
                    let mut corner_k = corner.clone();
                    scale_vec(self.panel.scale2, corner_k.as_mut_slice());
                    corner_k.add_diag(self.factor.nu2);
                    self.factor.chol.extend_bordered(&cross_k, &corner_k).is_ok()
                } else {
                    false
                };
                if !bordered {
                    // Rescaled (or borderline-indefinite corner): rebuild
                    // K = nu^2 I + scale^2 U from the cached Gram — O(m^3)
                    // factor, but no O(m^2 d) Gram recompute.
                    let (chol, rung) = factor_small(&u, new_scale2, self.factor.nu2)?;
                    self.factor.chol = chol;
                    self.factor.recovery = rung;
                    self.recovery.escalate(rung);
                }
                self.factor.dim = m_new;
                let panel = Arc::make_mut(&mut self.panel);
                panel.outer_gram = Some(u);
                panel.sa.append_rows(new_rows);
                panel.scale2 = new_scale2;
            }
            WoodburyMode::SmallSketch => {
                // Crossing m > d: switch branches. The d x d inner Gram is
                // built once here as (S̃A)^T(S̃A) + ΔA^T ΔA (O(m d^2)) and
                // maintained incrementally afterwards.
                let mut inner = self.panel.sa.gram();
                inner.add_scaled(1.0, &new_rows.gram());
                let (chol, rung) = factor_direct(&inner, new_scale2, self.factor.nu2)?;
                self.factor.chol = chol;
                self.factor.recovery = rung;
                self.factor.dim = d;
                self.recovery.escalate(rung);
                let panel = Arc::make_mut(&mut self.panel);
                panel.sa.append_rows(new_rows);
                panel.scale2 = new_scale2;
                panel.inner_gram = Some(inner);
                panel.outer_gram = None;
                panel.mode = WoodburyMode::Direct;
            }
            WoodburyMode::Direct => {
                // Rank-Δm update of the inner Gram: O(Δm d^2) + O(d^3)
                // refactor, independent of the accumulated m.
                let mut inner =
                    self.panel.inner_gram.as_ref().expect("Direct keeps inner_gram").clone();
                inner.add_scaled(1.0, &new_rows.gram());
                let (chol, rung) = factor_direct(&inner, new_scale2, self.factor.nu2)?;
                self.factor.chol = chol;
                self.factor.recovery = rung;
                self.factor.dim = d;
                self.recovery.escalate(rung);
                let panel = Arc::make_mut(&mut self.panel);
                panel.sa.append_rows(new_rows);
                panel.scale2 = new_scale2;
                panel.inner_gram = Some(inner);
            }
        }
        Ok(())
    }

    /// Apply `H_S^{-1} g` into `out` (see [`NuFactor::apply_inverse_into`]).
    pub fn apply_inverse_into(&self, g: &[f64], ws_m: &mut Vec<f64>, out: &mut [f64]) {
        self.factor.apply_inverse_into(&self.panel, g, ws_m, out);
    }

    /// Apply `H_S^{-1} g` (allocating wrapper).
    pub fn apply_inverse(&self, g: &[f64]) -> Vec<f64> {
        self.factor.apply_inverse(&self.panel, g)
    }

    /// Apply `H_S^{-1}` to `k` gradients at once (see
    /// [`NuFactor::apply_inverse_block`]).
    pub fn apply_inverse_block(&self, g: &Matrix) -> Matrix {
        self.factor.apply_inverse_block(&self.panel, g)
    }

    /// Explicit `H_S` (tests / diagnostics only).
    pub fn h_s(&self) -> Matrix {
        self.panel.h_s(self.factor.nu2)
    }
}

/// Factor `K = nu^2 I + scale2 * U` for the small-sketch branch, with
/// the jitter ladder. Returns the rung used (`Jitter` when the diagonal
/// had to be perturbed) so callers can surface degraded factorizations.
fn factor_small(u: &Matrix, scale2: f64, nu2: f64) -> Result<(Cholesky, RecoveryRung), SolverError> {
    failpoint::check("woodbury.factor").map_err(SolverError::NumericalBreakdown)?;
    let mut k = u.clone();
    scale_vec(scale2, k.as_mut_slice());
    k.add_diag(nu2);
    let (chol, jitter) = Cholesky::factor_with_jitter(&k, 8)
        .map_err(|e| SolverError::breakdown(format!("sketched Gram K: {e}")))?;
    let rung = if jitter > 0.0 { RecoveryRung::Jitter } else { RecoveryRung::None };
    Ok((chol, rung))
}

/// Factor `H = scale2 * inner + nu^2 I` for the direct branch, with the
/// jitter ladder (see [`factor_small`]).
fn factor_direct(
    inner: &Matrix,
    scale2: f64,
    nu2: f64,
) -> Result<(Cholesky, RecoveryRung), SolverError> {
    failpoint::check("woodbury.factor").map_err(SolverError::NumericalBreakdown)?;
    let mut h = inner.clone();
    scale_vec(scale2, h.as_mut_slice());
    h.add_diag(nu2);
    let (chol, jitter) = Cholesky::factor_with_jitter(&h, 8)
        .map_err(|e| SolverError::breakdown(format!("sketched Hessian: {e}")))?;
    let rung = if jitter > 0.0 { RecoveryRung::Jitter } else { RecoveryRung::None };
    Ok((chol, rung))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn random_sa(m: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        Matrix::from_fn(m, d, |_, _| rng.next_gaussian() * 0.7)
    }

    fn check_inverse(cache: &WoodburyCache, d: usize, tol: f64) {
        let g: Vec<f64> = (0..d).map(|i| (i as f64 * 0.31).sin()).collect();
        let z = cache.apply_inverse(&g);
        let hz = cache.h_s().matvec(&z);
        for i in 0..d {
            assert!((hz[i] - g[i]).abs() < tol, "coord {i}: {} vs {}", hz[i], g[i]);
        }
    }

    #[test]
    fn small_sketch_branch_matches_direct_inverse() {
        let sa = random_sa(4, 12, 1);
        let cache = WoodburyCache::new(sa, 0.8).unwrap();
        assert_eq!(cache.mode(), WoodburyMode::SmallSketch);
        check_inverse(&cache, 12, 1e-9);
    }

    #[test]
    fn direct_branch_matches() {
        let sa = random_sa(20, 6, 2);
        let cache = WoodburyCache::new(sa, 0.5).unwrap();
        assert_eq!(cache.mode(), WoodburyMode::Direct);
        check_inverse(&cache, 6, 1e-9);
    }

    #[test]
    fn branches_agree_at_m_equals_d() {
        // m == d sits on the SmallSketch side; cross-check against an
        // explicitly built Direct-branch cache on the same data.
        let sa = random_sa(8, 8, 3);
        let nu = 1.1;
        let small = WoodburyCache::new(sa.clone(), nu).unwrap();
        let mut h = sa.gram();
        h.add_diag(nu * nu);
        let chol = Cholesky::factor(&h).unwrap();
        let g: Vec<f64> = (0..8).map(|i| (i as f64).cos()).collect();
        let z1 = small.apply_inverse(&g);
        let z2 = chol.solve(&g);
        for i in 0..8 {
            assert!((z1[i] - z2[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn m_equals_one_degenerate_sketch() {
        // The adaptive algorithm starts at m = 1; the rank-one Woodbury
        // correction must still be exact.
        let sa = random_sa(1, 10, 4);
        let cache = WoodburyCache::new(sa, 0.3).unwrap();
        let g = vec![1.0; 10];
        let z = cache.apply_inverse(&g);
        let hz = cache.h_s().matvec(&z);
        for i in 0..10 {
            assert!((hz[i] - g[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn newton_decrement_positive() {
        // r = 1/2 g^T H_S^{-1} g > 0 for g != 0 (H_S is PD) — the quantity
        // Algorithm 1 monitors (Lemma 1).
        let sa = random_sa(5, 9, 5);
        let cache = WoodburyCache::new(sa, 0.6).unwrap();
        let g: Vec<f64> = (0..9).map(|i| (i as f64 - 4.0) * 0.1).collect();
        let z = cache.apply_inverse(&g);
        let r = 0.5 * crate::linalg::dot(&g, &z);
        assert!(r > 0.0);
    }

    #[test]
    fn scaled_cache_equals_prenormalized() {
        // new_scaled(S̃A, nu, 1/sqrt(m)) must act exactly like
        // new((1/sqrt(m)) S̃A, nu).
        let m = 6;
        let sa = random_sa(m, 16, 6);
        let scale = 1.0 / (m as f64).sqrt();
        let scaled_rows = {
            let mut s = sa.clone();
            scale_vec(scale, s.as_mut_slice());
            s
        };
        let a = WoodburyCache::new_scaled(sa, 0.7, scale).unwrap();
        let b = WoodburyCache::new(scaled_rows, 0.7).unwrap();
        let g: Vec<f64> = (0..16).map(|i| (i as f64 * 0.2).sin()).collect();
        let za = a.apply_inverse(&g);
        let zb = b.apply_inverse(&g);
        for i in 0..16 {
            assert!((za[i] - zb[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn grow_matches_fresh_cache_small_sketch() {
        // Grow 2 -> 4 -> 8 rows (rescaling each time, like the adaptive
        // solver); every state must agree with a from-scratch cache on the
        // same rows.
        let d = 24;
        let full = random_sa(8, d, 7);
        let rows = |a: usize, b: usize| Matrix::from_fn(b - a, d, |i, j| full.get(a + i, j));
        let nu = 0.9;
        let mut cache = WoodburyCache::new_scaled(rows(0, 2), nu, 1.0 / (2f64).sqrt()).unwrap();
        for &(m0, m1) in &[(2usize, 4usize), (4, 8)] {
            let new_scale = 1.0 / (m1 as f64).sqrt();
            cache.grow(&rows(m0, m1), new_scale).unwrap();
            assert_eq!(cache.m(), m1);
            assert_eq!(cache.mode(), WoodburyMode::SmallSketch);
            let fresh = WoodburyCache::new_scaled(rows(0, m1), nu, new_scale).unwrap();
            let g: Vec<f64> = (0..d).map(|i| (i as f64 * 0.17).cos()).collect();
            let zg = cache.apply_inverse(&g);
            let zf = fresh.apply_inverse(&g);
            for i in 0..d {
                assert!((zg[i] - zf[i]).abs() < 1e-9, "m={m1} coord {i}");
            }
        }
    }

    #[test]
    fn grow_fixed_scale_takes_bordered_path_exactly() {
        // Unchanged scale: the bordered Cholesky must reproduce the fresh
        // factorization to roundoff.
        let d = 20;
        let full = random_sa(10, d, 8);
        let rows = |a: usize, b: usize| Matrix::from_fn(b - a, d, |i, j| full.get(a + i, j));
        let mut cache = WoodburyCache::new_scaled(rows(0, 6), 0.5, 1.0).unwrap();
        cache.grow(&rows(6, 10), 1.0).unwrap();
        let fresh = WoodburyCache::new_scaled(rows(0, 10), 0.5, 1.0).unwrap();
        let g: Vec<f64> = (0..d).map(|i| ((i * i) as f64 * 0.05).sin()).collect();
        let zg = cache.apply_inverse(&g);
        let zf = fresh.apply_inverse(&g);
        for i in 0..d {
            assert!((zg[i] - zf[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn grow_crosses_into_direct_mode_and_keeps_growing() {
        // d = 6: growth 4 -> 8 crosses m > d, then 8 -> 12 exercises the
        // incremental inner-Gram update.
        let d = 6;
        let full = random_sa(12, d, 9);
        let rows = |a: usize, b: usize| Matrix::from_fn(b - a, d, |i, j| full.get(a + i, j));
        let nu = 0.8;
        let mut cache = WoodburyCache::new_scaled(rows(0, 4), nu, 0.5).unwrap();
        assert_eq!(cache.mode(), WoodburyMode::SmallSketch);
        cache.grow(&rows(4, 8), 0.35).unwrap();
        assert_eq!(cache.mode(), WoodburyMode::Direct);
        cache.grow(&rows(8, 12), 0.29).unwrap();
        assert_eq!(cache.m(), 12);
        let fresh = WoodburyCache::new_scaled(rows(0, 12), nu, 0.29).unwrap();
        let g: Vec<f64> = (0..d).map(|i| (i as f64 + 0.5) * 0.3).collect();
        let zg = cache.apply_inverse(&g);
        let zf = fresh.apply_inverse(&g);
        for i in 0..d {
            assert!((zg[i] - zf[i]).abs() < 1e-9);
        }
        check_inverse(&cache, d, 1e-8);
    }

    #[test]
    fn set_nu_matches_fresh_factorization() {
        // Re-keying across nu must agree with a from-scratch cache at the
        // new nu, in both branches, with zero Gram recompute (structural:
        // the cached Gram objects are reused — asserted via agreement).
        for (m, d) in [(5usize, 14usize), (18, 6)] {
            let sa = random_sa(m, d, 21);
            let scale = 0.4;
            let mut cache = WoodburyCache::new_scaled(sa.clone(), 0.9, scale).unwrap();
            let g: Vec<f64> = (0..d).map(|i| (i as f64 * 0.11).cos()).collect();
            for nu in [0.9, 0.3, 2.5, 0.3] {
                cache.set_nu(nu).unwrap();
                assert!((cache.nu() - nu).abs() < 1e-15);
                let fresh = WoodburyCache::new_scaled(sa.clone(), nu, scale).unwrap();
                let za = cache.apply_inverse(&g);
                let zf = fresh.apply_inverse(&g);
                for i in 0..d {
                    assert!((za[i] - zf[i]).abs() < 1e-10, "m={m} nu={nu} coord {i}");
                }
            }
        }
    }

    #[test]
    fn set_nu_then_grow_stays_consistent() {
        let d = 10;
        let full = random_sa(8, d, 22);
        let rows = |a: usize, b: usize| Matrix::from_fn(b - a, d, |i, j| full.get(a + i, j));
        let mut cache = WoodburyCache::new_scaled(rows(0, 4), 1.2, 0.5).unwrap();
        cache.set_nu(0.6).unwrap();
        cache.grow(&rows(4, 8), 0.35).unwrap();
        let fresh = WoodburyCache::new_scaled(rows(0, 8), 0.6, 0.35).unwrap();
        let g: Vec<f64> = (0..d).map(|i| (i as f64 + 1.0) * 0.07).collect();
        let za = cache.apply_inverse(&g);
        let zf = fresh.apply_inverse(&g);
        for i in 0..d {
            assert!((za[i] - zf[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn apply_inverse_block_matches_per_column_in_both_branches() {
        // SmallSketch (m < d), Direct (m > d), and a grown cache all
        // agree column-wise with the vector path to roundoff.
        for (m, d) in [(5usize, 14usize), (18, 6)] {
            let sa = random_sa(m, d, 30);
            let cache = WoodburyCache::new_scaled(sa, 0.7, 0.5).unwrap();
            let g = Matrix::from_fn(d, 4, |i, j| ((i * 4 + j) as f64 * 0.19).sin());
            let blk = cache.apply_inverse_block(&g);
            for j in 0..4 {
                let col: Vec<f64> = (0..d).map(|i| g.get(i, j)).collect();
                let z = cache.apply_inverse(&col);
                for i in 0..d {
                    assert!(
                        (blk.get(i, j) - z[i]).abs() < 1e-12,
                        "m={m} col {j} coord {i}: {} vs {}",
                        blk.get(i, j),
                        z[i]
                    );
                }
            }
        }
    }

    #[test]
    fn apply_inverse_block_consistent_after_growth_and_set_nu() {
        let d = 12;
        let full = random_sa(8, d, 31);
        let rows = |a: usize, b: usize| Matrix::from_fn(b - a, d, |i, j| full.get(a + i, j));
        let mut cache = WoodburyCache::new_scaled(rows(0, 4), 0.9, 0.5).unwrap();
        cache.grow(&rows(4, 8), 0.35).unwrap();
        cache.set_nu(0.4).unwrap();
        let g = Matrix::from_fn(d, 3, |i, j| ((i + j) as f64 * 0.23).cos());
        let blk = cache.apply_inverse_block(&g);
        // H_S * blk must reproduce g column by column.
        let h = cache.h_s();
        for j in 0..3 {
            let col: Vec<f64> = (0..d).map(|i| blk.get(i, j)).collect();
            let hz = h.matvec(&col);
            for i in 0..d {
                assert!((hz[i] - g.get(i, j)).abs() < 1e-8, "col {j} coord {i}");
            }
        }
    }

    #[test]
    fn invalid_inputs_are_structured_errors_and_leave_cache_usable() {
        let sa = random_sa(4, 9, 11);
        let mut cache = WoodburyCache::new(sa, 0.8).unwrap();
        assert_eq!(cache.recovery(), RecoveryRung::None);
        let g: Vec<f64> = (0..9).map(|i| i as f64 * 0.2).collect();
        let before = cache.apply_inverse(&g);
        for nu in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            match cache.set_nu(nu) {
                Err(SolverError::InvalidInput(m)) => assert!(m.contains("invalid nu")),
                other => panic!("nu={nu}: expected InvalidInput, got {other:?}"),
            }
        }
        match cache.grow(&Matrix::zeros(2, 5), 0.5) {
            Err(SolverError::InvalidInput(_)) => {}
            other => panic!("expected InvalidInput, got {other:?}"),
        }
        // The cache still answers exactly as before any rejected call.
        assert_eq!(cache.apply_inverse(&g), before);
        assert!(WoodburyCache::new(random_sa(3, 6, 12), f64::NAN).is_err());
    }

    #[test]
    fn grow_by_zero_rows_is_a_noop() {
        let sa = random_sa(3, 10, 10);
        let mut cache = WoodburyCache::new_scaled(sa, 0.6, 0.5).unwrap();
        let g: Vec<f64> = (0..10).map(|i| i as f64 * 0.1).collect();
        let before = cache.apply_inverse(&g);
        cache.grow(&Matrix::zeros(0, 10), 0.5).unwrap();
        assert_eq!(cache.m(), 3);
        let after = cache.apply_inverse(&g);
        assert_eq!(before, after);
    }

    // ---- panel / factor seam ----

    #[test]
    fn panel_factor_is_pure_and_matches_cache_bitwise() {
        // Deriving a factor from the shared panel is read-only and must
        // reproduce the writer lane's answers *bitwise*: factor_small /
        // factor_direct are deterministic in (Gram, scale2, nu2), so any
        // reader re-keying the same panel at the same nu computes the
        // same factor the cache's own set_nu would.
        for (m, d) in [(5usize, 14usize), (18, 6)] {
            let sa = random_sa(m, d, 40);
            let mut cache = WoodburyCache::new_scaled(sa, 0.9, 0.5).unwrap();
            let panel = Arc::clone(cache.panel());
            let g: Vec<f64> = (0..d).map(|i| (i as f64 * 0.13).sin()).collect();
            for nu in [0.9, 0.3, 2.5] {
                // Reader lane: pure factor off the pinned panel.
                let f1 = panel.factor(nu).unwrap();
                let f2 = panel.factor(nu).unwrap();
                let z1 = f1.apply_inverse(&panel, &g);
                let z2 = f2.apply_inverse(&panel, &g);
                let bits = |x: &[f64]| x.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&z1), bits(&z2), "factor must be deterministic");
                // Writer lane at the same nu: bitwise the same answers.
                cache.set_nu(nu).unwrap();
                assert_eq!(bits(&z1), bits(&cache.apply_inverse(&g)), "m={m} nu={nu}");
            }
        }
    }

    #[test]
    fn grow_copies_on_write_when_panel_is_shared() {
        // A reader pinning the panel Arc must keep answering from the old
        // rows after the writer grows — and the writer's growth must still
        // agree with a from-scratch cache on the grown rows.
        let d = 16;
        let full = random_sa(8, d, 41);
        let rows = |a: usize, b: usize| Matrix::from_fn(b - a, d, |i, j| full.get(a + i, j));
        let mut cache = WoodburyCache::new_scaled(rows(0, 4), 0.7, 0.5).unwrap();
        let pinned = Arc::clone(cache.panel());
        let pinned_factor = pinned.factor(0.7).unwrap();
        let g: Vec<f64> = (0..d).map(|i| (i as f64 * 0.21).cos()).collect();
        let before = pinned_factor.apply_inverse(&pinned, &g);

        cache.grow(&rows(4, 8), 0.35).unwrap();
        assert!(
            !Arc::ptr_eq(&pinned, cache.panel()),
            "shared panel must be copied, not mutated in place"
        );
        assert_eq!(pinned.m(), 4, "pinned panel keeps its pre-growth rows");
        assert_eq!(cache.m(), 8);
        // The pinned reader still gets bitwise the pre-growth answers.
        let after = pinned_factor.apply_inverse(&pinned, &g);
        let bits = |x: &[f64]| x.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&before), bits(&after));
        // And the grown cache matches a fresh build on the full rows.
        let fresh = WoodburyCache::new_scaled(rows(0, 8), 0.7, 0.35).unwrap();
        let zg = cache.apply_inverse(&g);
        let zf = fresh.apply_inverse(&g);
        for i in 0..d {
            assert!((zg[i] - zf[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn unshared_grow_mutates_panel_in_place() {
        // Sole ownership (no snapshot pinning the Arc): make_mut must
        // mutate in place — no allocation-level churn for the common
        // writer-only path. Observable via the Arc's strong count staying
        // 1 and the grown answers matching fresh ones (the bitwise
        // equivalence to the pre-split code path).
        let d = 12;
        let full = random_sa(8, d, 42);
        let rows = |a: usize, b: usize| Matrix::from_fn(b - a, d, |i, j| full.get(a + i, j));
        let mut cache = WoodburyCache::new_scaled(rows(0, 4), 0.8, 0.5).unwrap();
        assert_eq!(Arc::strong_count(cache.panel()), 1);
        cache.grow(&rows(4, 8), 0.35).unwrap();
        assert_eq!(Arc::strong_count(cache.panel()), 1);
        assert_eq!(cache.m(), 8);
    }

    #[test]
    fn factor_rejects_invalid_nu_and_panel_rejects_bad_scale() {
        let panel = GramPanel::build(random_sa(4, 9, 43), 0.5).unwrap();
        for nu in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            match panel.factor(nu) {
                Err(SolverError::InvalidInput(m)) => assert!(m.contains("invalid nu")),
                other => panic!("nu={nu}: expected InvalidInput, got {other:?}"),
            }
        }
        for scale in [0.0, -0.5, f64::NAN] {
            assert!(GramPanel::build(random_sa(2, 4, 44), scale).is_err());
        }
    }

    #[test]
    fn byte_accounting_splits_panel_and_factor() {
        let cache = WoodburyCache::new_scaled(random_sa(5, 14, 45), 0.6, 0.5).unwrap();
        let f64s = std::mem::size_of::<f64>();
        // Panel: sa (5x14) + outer gram (5x5); factor: 5x5 Cholesky.
        assert_eq!(cache.panel().approx_bytes(), (5 * 14 + 5 * 5) * f64s);
        assert_eq!(cache.factor().approx_bytes(), 5 * 5 * f64s);
        assert_eq!(
            cache.approx_bytes(),
            cache.panel().approx_bytes() + cache.factor().approx_bytes()
        );
    }
}
