//! Cached application of `H_S^{-1} = ((SA)^T SA + nu^2 I_d)^{-1}`.
//!
//! Theorem 7's cost model hinges on this: with `m <= d` one factors the
//! *small* `m x m` matrix `K = nu^2 I_m + (SA)(SA)^T` once per sketch
//! (`O(m^2 d)`), after which each `H_S^{-1} g` costs `O(m d)` via the
//! Woodbury identity
//! `H_S^{-1} = (1/nu^2) (I - (SA)^T K^{-1} (SA))`.
//! When `m > d` the direct `d x d` factorization is cheaper and we switch
//! automatically.

use crate::linalg::cholesky::Cholesky;
use crate::linalg::{axpy, Matrix};

/// Which factorization branch is active.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WoodburyMode {
    /// `m <= d`: factor `nu^2 I_m + (SA)(SA)^T`.
    SmallSketch,
    /// `m > d`: factor `(SA)^T (SA) + nu^2 I_d` directly.
    Direct,
}

/// Cached factorization of the sketched Hessian.
pub struct WoodburyCache {
    sa: Matrix,
    nu2: f64,
    mode: WoodburyMode,
    chol: Cholesky,
}

impl WoodburyCache {
    /// Factor for the given sketched matrix `SA` (`m x d`) and `nu`.
    pub fn new(sa: Matrix, nu: f64) -> Self {
        assert!(nu > 0.0);
        let (m, d) = (sa.rows(), sa.cols());
        let nu2 = nu * nu;
        if m <= d {
            let mut k = sa.gram_outer(); // (SA)(SA)^T, m x m
            k.add_diag(nu2);
            let (chol, _) = Cholesky::factor_with_jitter(&k, 8).expect("K = nu^2 I + GG^T is PD");
            Self { sa, nu2, mode: WoodburyMode::SmallSketch, chol }
        } else {
            let mut h = sa.gram(); // (SA)^T(SA), d x d
            h.add_diag(nu2);
            let (chol, _) = Cholesky::factor_with_jitter(&h, 8).expect("H_S is PD");
            Self { sa, nu2, mode: WoodburyMode::Direct, chol }
        }
    }

    /// Sketch size `m`.
    pub fn m(&self) -> usize {
        self.sa.rows()
    }

    /// Active branch.
    pub fn mode(&self) -> WoodburyMode {
        self.mode
    }

    /// Apply `H_S^{-1} g`. Cost: `O(m d + m^2)` (small-sketch branch) or
    /// `O(d^2)` (direct branch).
    pub fn apply_inverse(&self, g: &[f64]) -> Vec<f64> {
        match self.mode {
            WoodburyMode::SmallSketch => {
                // (1/nu^2) (g - (SA)^T K^{-1} (SA) g)
                let sag = self.sa.matvec(g);
                let kinv = self.chol.solve(&sag);
                let mut out = g.to_vec();
                let corr = self.sa.matvec_t(&kinv);
                axpy(-1.0, &corr, &mut out);
                crate::linalg::scale(1.0 / self.nu2, &mut out);
                out
            }
            WoodburyMode::Direct => self.chol.solve(g),
        }
    }

    /// Explicit `H_S` (tests / diagnostics only).
    pub fn h_s(&self) -> Matrix {
        let mut h = self.sa.gram();
        h.add_diag(self.nu2);
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn random_sa(m: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        Matrix::from_fn(m, d, |_, _| rng.next_gaussian() * 0.7)
    }

    #[test]
    fn small_sketch_branch_matches_direct_inverse() {
        let sa = random_sa(4, 12, 1);
        let nu = 0.8;
        let cache = WoodburyCache::new(sa, nu);
        assert_eq!(cache.mode(), WoodburyMode::SmallSketch);
        let g: Vec<f64> = (0..12).map(|i| (i as f64 * 0.31).sin()).collect();
        let z = cache.apply_inverse(&g);
        // Check H_S z == g.
        let hz = cache.h_s().matvec(&z);
        for i in 0..12 {
            assert!((hz[i] - g[i]).abs() < 1e-9, "coord {i}");
        }
    }

    #[test]
    fn direct_branch_matches() {
        let sa = random_sa(20, 6, 2);
        let cache = WoodburyCache::new(sa, 0.5);
        assert_eq!(cache.mode(), WoodburyMode::Direct);
        let g: Vec<f64> = (0..6).map(|i| (i as f64 + 1.0) * 0.2).collect();
        let z = cache.apply_inverse(&g);
        let hz = cache.h_s().matvec(&z);
        for i in 0..6 {
            assert!((hz[i] - g[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn branches_agree_at_m_equals_d() {
        // m == d sits on the SmallSketch side; cross-check against an
        // explicitly built Direct-branch cache on the same data.
        let sa = random_sa(8, 8, 3);
        let nu = 1.1;
        let small = WoodburyCache::new(sa.clone(), nu);
        let mut h = sa.gram();
        h.add_diag(nu * nu);
        let chol = Cholesky::factor(&h).unwrap();
        let g: Vec<f64> = (0..8).map(|i| (i as f64).cos()).collect();
        let z1 = small.apply_inverse(&g);
        let z2 = chol.solve(&g);
        for i in 0..8 {
            assert!((z1[i] - z2[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn m_equals_one_degenerate_sketch() {
        // The adaptive algorithm starts at m = 1; the rank-one Woodbury
        // correction must still be exact.
        let sa = random_sa(1, 10, 4);
        let cache = WoodburyCache::new(sa, 0.3);
        let g = vec![1.0; 10];
        let z = cache.apply_inverse(&g);
        let hz = cache.h_s().matvec(&z);
        for i in 0..10 {
            assert!((hz[i] - g[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn newton_decrement_positive() {
        // r = 1/2 g^T H_S^{-1} g > 0 for g != 0 (H_S is PD) — the quantity
        // Algorithm 1 monitors (Lemma 1).
        let sa = random_sa(5, 9, 5);
        let cache = WoodburyCache::new(sa, 0.6);
        let g: Vec<f64> = (0..9).map(|i| (i as f64 - 4.0) * 0.1).collect();
        let z = cache.apply_inverse(&g);
        let r = 0.5 * crate::linalg::dot(&g, &z);
        assert!(r > 0.0);
    }
}
