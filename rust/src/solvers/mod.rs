//! Solvers for the regularized least-squares problem
//! `min_x 1/2 ||Ax - b||^2 + nu^2/2 ||x||^2`.
//!
//! * [`direct`] — Cholesky on the normal equations (ground truth).
//! * [`cg`] — conjugate gradient on `(A^T A + nu^2 I) x = A^T b` (baseline).
//! * [`pcg`] — randomized-preconditioned CG, Rokhlin–Tygert style
//!   (the state-of-the-art baseline the paper compares against).
//! * [`woodbury`] — cached factorization applying `H_S^{-1}` in
//!   `O(m d)` per iteration (Theorem 7's cost model).
//! * [`ihs`] — fixed-sketch-size gradient-/Polyak-IHS (Theorems 1–2).
//! * [`adaptive`] — **Algorithm 1** and its gradient-only variant.
//! * [`block`] — the block multi-RHS path: `k` systems sharing one `A`
//!   solved jointly through one grown sketch at BLAS-3 intensity, with
//!   per-column convergence tracking and active-set shrinking (the
//!   serving layer's batched-throughput primitive).
//! * [`dual`] — the underdetermined case `d >= n` via the dual problem
//!   (Appendix A.2).
//! * [`path`] — regularization-path driver with warm starts (Figures 1, 3).
//! * [`session`] — cross-solve reuse: a [`session::ModelSession`] keeps the
//!   grown sketch, the factorization cache and the last solution alive
//!   between solves at different regularization levels / right-hand sides
//!   (the state behind the coordinator's model registry).
//! * [`api`] — the unified dispatch surface: the [`api::Solver`] trait,
//!   round-trippable [`api::SolverSpec`] strings, and the solver
//!   [`api::registry`]. New callers should go through this module.
//! * [`error`] — the typed [`error::SolverError`] and the
//!   [`error::RecoveryRung`] ladder accounting behind fault-tolerant
//!   serving (jitter → resketch → exact-Hessian fallback).

pub mod adaptive;
pub mod api;
pub mod block;
pub mod cg;
pub mod direct;
pub mod dual;
pub mod error;
pub mod ihs;
pub mod path;
pub mod pcg;
pub mod session;
pub mod woodbury;

pub use api::{registry, Solver, SolverSpec};
pub use error::{RecoveryRung, SolverError};

use crate::linalg::{axpy, dot, norm2, Operand};
use std::sync::Arc;

/// A ridge-regression problem instance. Owns the data; solvers borrow it.
///
/// The data matrix is an [`Operand`] — dense or CSR — and every method
/// here dispatches on the variant, so a sparse problem pays `O(nnz)`
/// instead of `O(n d)` for gradients, Hessian products and prediction
/// errors. The constructors take `impl Into<Operand>`: a bare `Matrix`,
/// a `CsrMatrix`, or an `Operand` all work.
///
/// Built either from raw observations (`new`) or from the normal-equations
/// right-hand side directly (`from_normal`). The latter is what the dual /
/// underdetermined path (Appendix A.2) uses: the dual objective's gradient
/// is `A A^T z + nu^2 z - b`, i.e. the "observations" `b_hat = A^† b` are
/// never needed — only `A_tilde^T b_hat = b` is.
///
/// The `*_into` / `*_ws` variants write into caller-owned workspace
/// buffers (`&mut Vec<f64>` scratch is resized on first use, then reused)
/// — the iterative solvers call these from their inner loops so a steady-
/// state iteration performs no solver-level heap allocation (above the
/// parallel-kernel threshold, the kernels' own scoped-thread scratch is
/// the one documented exception — see the lib.rs overview).
#[derive(Clone, Debug)]
pub struct RidgeProblem {
    /// Data matrix, `n x d` (overdetermined: `n >= d`), dense or CSR. Held
    /// in an [`Arc`] so sessions and registries can share one operand
    /// across many problems (one per `nu` / right-hand side) without
    /// cloning the data; `RidgeProblem::clone` is correspondingly cheap on
    /// the matrix itself.
    pub a: Arc<Operand>,
    /// Observations, length `n` (absent for normal-form / dual problems).
    pub b: Option<Vec<f64>>,
    /// Precomputed right-hand side `A^T b`, length `d`.
    pub atb: Vec<f64>,
    /// Regularization level `nu` (the objective carries `nu^2/2 ||x||^2`).
    pub nu: f64,
}

impl RidgeProblem {
    /// Build from raw observations; computes `atb = A^T b` once.
    pub fn new(a: impl Into<Operand>, b: Vec<f64>, nu: f64) -> Self {
        Self::new_shared(Arc::new(a.into()), b, nu)
    }

    /// Like [`RidgeProblem::new`] but reusing an already-shared operand —
    /// the per-query constructor of [`session::ModelSession`]: no data
    /// copy, only the `O(nnz)` `A^T b` product.
    pub fn new_shared(a: Arc<Operand>, b: Vec<f64>, nu: f64) -> Self {
        assert_eq!(a.rows(), b.len(), "A and b row mismatch");
        assert!(nu > 0.0, "regularized problem needs nu > 0");
        let atb = a.matvec_t(&b);
        Self { a, b: Some(b), atb, nu }
    }

    /// Build from the normal-equations RHS `atb = A^T b` when `b` itself is
    /// unavailable (dual problems).
    pub fn from_normal(a: impl Into<Operand>, atb: Vec<f64>, nu: f64) -> Self {
        let a = a.into();
        assert_eq!(a.cols(), atb.len(), "A and atb column mismatch");
        assert!(nu > 0.0, "regularized problem needs nu > 0");
        Self { a: Arc::new(a), b: None, atb, nu }
    }

    /// Assemble a problem from precomputed parts: a shared operand, an
    /// already-formed `atb`, and optional raw observations. This is the
    /// zero-recompute path sessions use when `atb` is cached across `nu`
    /// changes (it depends on `(A, b)` only).
    pub fn from_parts(a: Arc<Operand>, b: Option<Vec<f64>>, atb: Vec<f64>, nu: f64) -> Self {
        assert_eq!(a.cols(), atb.len(), "A and atb column mismatch");
        if let Some(b) = &b {
            assert_eq!(a.rows(), b.len(), "A and b row mismatch");
        }
        assert!(nu > 0.0, "regularized problem needs nu > 0");
        Self { a, b, atb, nu }
    }

    /// Row count `n` of the data matrix.
    pub fn n(&self) -> usize {
        self.a.rows()
    }

    /// Column count `d` (the solution dimension).
    pub fn d(&self) -> usize {
        self.a.cols()
    }

    /// Stored entries of the data matrix (`nnz` for CSR, `n*d` dense).
    pub fn nnz(&self) -> usize {
        self.a.nnz()
    }

    /// Objective `f(x) = 1/2 ||Ax - b||^2 + nu^2/2 ||x||^2`. Requires raw
    /// observations; normal-form problems only expose gradients/errors.
    pub fn objective(&self, x: &[f64]) -> f64 {
        let b = self.b.as_ref().expect("objective needs raw observations b");
        let mut r = self.a.matvec(x);
        axpy(-1.0, b, &mut r);
        0.5 * dot(&r, &r) + 0.5 * self.nu * self.nu * dot(x, x)
    }

    /// Gradient `∇f(x) = A^T A x + nu^2 x - A^T b` into `out` (length
    /// `d`), `O(nd)` dense / `O(nnz)` CSR. `ws_n` is length-`n` scratch,
    /// used only by the CSR arm (resized on first use, reused after).
    ///
    /// Dense arm: fused single pass over `A` (mirroring the L1 Pallas
    /// kernel) — each row computes its residual element and immediately
    /// accumulates `A_i^T r_i`, so the 8·n·d bytes of `A` stream through
    /// cache once instead of twice; the op is memory-bound and the fusion
    /// is worth ~1.7x (EXPERIMENTS.md §Perf). The CSR arm instead does
    /// the two-pass `A^T (A x)` at `O(nnz)` each — on sparse data the
    /// matrix fits cache far more often, and the asymptotics dominate.
    pub fn gradient_into(&self, x: &[f64], ws_n: &mut Vec<f64>, out: &mut [f64]) {
        let d = self.d();
        assert_eq!(x.len(), d);
        assert_eq!(out.len(), d);
        // out starts as nu^2 x - A^T b.
        for i in 0..d {
            out[i] = self.nu * self.nu * x[i] - self.atb[i];
        }
        match &*self.a {
            Operand::Dense(a) => {
                // Panel pass: r_i = <a_i, x>; out += r_i * a_i.
                for i in 0..a.rows() {
                    let row = a.row(i);
                    let r = dot(row, x);
                    if r != 0.0 {
                        axpy(r, row, out);
                    }
                }
            }
            Operand::Sparse(c) => {
                ws_n.resize(self.n(), 0.0);
                c.matvec_into(x, ws_n);
                c.matvec_t_add(ws_n, out);
            }
        }
    }

    /// Gradient `∇f(x) = A^T A x + nu^2 x - A^T b` (allocating wrapper).
    pub fn gradient(&self, x: &[f64]) -> Vec<f64> {
        let mut ws_n = Vec::new();
        let mut g = vec![0.0; self.d()];
        self.gradient_into(x, &mut ws_n, &mut g);
        g
    }

    /// Hessian-vector product `(A^T A + nu^2 I) v` into `out` (length
    /// `d`); `ws_n` is length-`n` scratch.
    pub fn hessian_vec_into(&self, v: &[f64], ws_n: &mut Vec<f64>, out: &mut [f64]) {
        assert_eq!(out.len(), self.d());
        ws_n.resize(self.n(), 0.0);
        self.a.matvec_into(v, ws_n);
        self.a.matvec_t_into(ws_n, out);
        axpy(self.nu * self.nu, v, out);
    }

    /// Hessian-vector product `(A^T A + nu^2 I) v`.
    pub fn hessian_vec(&self, v: &[f64]) -> Vec<f64> {
        let mut ws_n = Vec::new();
        let mut hv = vec![0.0; self.d()];
        self.hessian_vec_into(v, &mut ws_n, &mut hv);
        hv
    }

    /// Prediction-norm error with caller scratch (`ws_d` length-`d`,
    /// `ws_n` length-`n`; both resized on first use) — the allocation-free
    /// form the solver loops call on every stop-rule check.
    pub fn prediction_error_ws(
        &self,
        x: &[f64],
        x_star: &[f64],
        ws_d: &mut Vec<f64>,
        ws_n: &mut Vec<f64>,
    ) -> f64 {
        let d = self.d();
        assert_eq!(x.len(), d);
        assert_eq!(x_star.len(), d);
        ws_d.resize(d, 0.0);
        for i in 0..d {
            ws_d[i] = x[i] - x_star[i];
        }
        ws_n.resize(self.n(), 0.0);
        self.a.matvec_into(ws_d, ws_n);
        let (wd, wn) = (ws_d.as_slice(), ws_n.as_slice());
        0.5 * dot(wn, wn) + 0.5 * self.nu * self.nu * dot(wd, wd)
    }

    /// Prediction-norm error `delta = 1/2 ||Abar (x - x*)||^2`
    /// `= 1/2 ||A(x-x*)||^2 + nu^2/2 ||x-x*||^2` — the paper's criterion.
    pub fn prediction_error(&self, x: &[f64], x_star: &[f64]) -> f64 {
        let mut ws_d = Vec::new();
        let mut ws_n = Vec::new();
        self.prediction_error_ws(x, x_star, &mut ws_d, &mut ws_n)
    }
}

/// Stopping rule shared by the iterative solvers.
#[derive(Clone, Debug)]
pub enum StopRule {
    /// Stop when the *true* relative prediction error
    /// `delta_t / delta_0 <= eps` (requires the optimum; experiment mode —
    /// this is exactly how the paper's figures measure precision).
    TrueError { x_star: Vec<f64>, eps: f64 },
    /// Stop when the relative gradient norm `||g_t|| / ||g_0|| <= tol`
    /// (deployment mode; no oracle needed).
    GradientNorm { tol: f64 },
}

impl StopRule {
    /// Evaluate the rule. `delta0` is the initial error for `TrueError`
    /// (computed by the caller on the first call), `g` the current gradient.
    pub fn should_stop(
        &self,
        problem: &RidgeProblem,
        x: &[f64],
        g: &[f64],
        delta0: f64,
        g0_norm: f64,
    ) -> bool {
        match self {
            StopRule::TrueError { x_star, eps } => {
                let delta = problem.prediction_error(x, x_star);
                delta <= eps * delta0
            }
            StopRule::GradientNorm { tol } => norm2(g) <= tol * g0_norm,
        }
    }
}

/// Wall-clock + work breakdown for a solve, the unit every figure plots.
#[derive(Clone, Debug, Default)]
pub struct SolveReport {
    /// Solver label (e.g. "cg", "pcg-srht", "adaptive-gaussian").
    pub solver: String,
    /// Accepted iterations.
    pub iterations: usize,
    /// Rejected candidate updates (adaptive solvers only).
    pub rejections: usize,
    /// Number of sketch-size doublings (adaptive solvers only).
    pub doublings: usize,
    /// Final sketch size `m` (0 for sketch-free solvers).
    pub final_m: usize,
    /// Peak sketch size across the solve.
    pub peak_m: usize,
    /// Total wall time in seconds.
    pub wall_time_s: f64,
    /// Time spent forming `SA` (or the preconditioner sketch). Adaptive
    /// solvers grow incrementally, so each growth adds only the
    /// appended-rows cost here, not a from-scratch re-apply.
    pub sketch_time_s: f64,
    /// Time spent factoring (`Woodbury` / QR / Cholesky). Adaptive growth
    /// adds the cross-Gram + factor-update cost, reusing prior blocks.
    pub factor_time_s: f64,
    /// Time in the iteration loop proper.
    pub iter_time_s: f64,
    /// Final relative error `delta_T / delta_0` if an oracle was available.
    pub final_rel_error: Option<f64>,
    /// Per-iteration relative error trace (oracle mode).
    pub error_trace: Vec<f64>,
    /// Sketch size after each iteration (adaptive solvers).
    pub m_trace: Vec<usize>,
    /// Whether the stop rule was met (vs. iteration cap).
    pub converged: bool,
    /// Highest recovery-ladder rung any step of the solve needed
    /// (`none` on a healthy solve; see [`error::RecoveryRung`]).
    pub recovery: RecoveryRung,
}

impl SolveReport {
    /// Empty report carrying only the solver label.
    pub fn new(solver: impl Into<String>) -> Self {
        Self { solver: solver.into(), ..Default::default() }
    }
}

/// Outcome of a solve: the iterate plus its report.
#[derive(Clone, Debug)]
pub struct Solution {
    /// The final iterate.
    pub x: Vec<f64>,
    /// Work/time breakdown of the solve that produced it.
    pub report: SolveReport,
}

#[cfg(test)]
pub(crate) mod test_util {
    use super::*;
    use crate::data::synthetic;

    /// Small well-conditioned test problem with a known spectrum.
    pub fn small_problem(n: usize, d: usize, nu: f64, seed: u64) -> RidgeProblem {
        let ds = synthetic::exponential_decay(n, d, seed);
        RidgeProblem::new(ds.a, ds.b, nu)
    }
}

#[cfg(test)]
mod tests {
    use super::test_util::small_problem;
    use super::*;

    #[test]
    fn gradient_is_zero_at_optimum() {
        let p = small_problem(64, 8, 0.5, 1);
        let x_star = direct::solve(&p);
        let g = p.gradient(&x_star);
        assert!(norm2(&g) < 1e-10, "gradient at optimum: {}", norm2(&g));
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let p = small_problem(32, 4, 0.7, 2);
        let x: Vec<f64> = (0..4).map(|i| (i as f64 * 0.3).sin()).collect();
        let g = p.gradient(&x);
        let h = 1e-6;
        for i in 0..4 {
            let mut xp = x.clone();
            let mut xm = x.clone();
            xp[i] += h;
            xm[i] -= h;
            let fd = (p.objective(&xp) - p.objective(&xm)) / (2.0 * h);
            assert!((g[i] - fd).abs() < 1e-5, "coord {i}: {} vs {}", g[i], fd);
        }
    }

    #[test]
    fn prediction_error_zero_at_optimum() {
        let p = small_problem(64, 8, 0.5, 3);
        let x_star = direct::solve(&p);
        assert!(p.prediction_error(&x_star, &x_star) == 0.0);
        let x0 = vec![0.0; 8];
        assert!(p.prediction_error(&x0, &x_star) > 0.0);
    }

    #[test]
    fn hessian_vec_consistent_with_gradient() {
        // g(x) - g(0) == H x for a quadratic.
        let p = small_problem(32, 8, 0.4, 4);
        let x: Vec<f64> = (0..8).map(|i| (i as f64 + 1.0) * 0.1).collect();
        let gx = p.gradient(&x);
        let g0 = p.gradient(&vec![0.0; 8]);
        let hx = p.hessian_vec(&x);
        for i in 0..8 {
            assert!((gx[i] - g0[i] - hx[i]).abs() < 1e-10);
        }
    }
}
