//! Fixed-sketch-size IHS: the gradient (`beta = 0`) and Polyak heavy-ball
//! variants of update (2), with the convergence guarantees of Theorems 1–2.
//!
//! These are the building blocks Algorithm 1 adapts; exposed standalone for
//! users who *do* know `d_e` (and for the rate-validation experiments that
//! check `delta_t ~ (d_e/m)^t`).

use super::error::RecoveryRung;
use super::woodbury::WoodburyCache;
use super::{RidgeProblem, Solution, SolveReport, StopRule};
use crate::linalg::{axpy, norm2, Matrix};
use crate::rng::Xoshiro256;
use crate::sketch::{self, SketchKind};
use crate::theory::rates::IhsParams;
use crate::theory::{gaussian_bounds, srht_bounds};
use std::time::Instant;

/// Fixed-size IHS configuration. Stop rule and seed are per-solve
/// arguments of the unified [`crate::solvers::api::Solver`] call.
#[derive(Clone, Debug)]
pub struct IhsConfig {
    /// Sketch family to draw.
    pub kind: SketchKind,
    /// Sketch size `m`.
    pub m: usize,
    /// Step/momentum parameters; `IhsParams` from Definitions 3.1/3.2, or
    /// hand-chosen.
    pub params: IhsParams,
    /// Use the Polyak (heavy-ball) update; `false` = plain gradient-IHS.
    pub momentum: bool,
    /// Resample `S` (and re-factor) at every iteration — the *refreshed*
    /// IHS variant discussed in §1.3. The paper's cited results
    /// ([25, 26]): refreshing does not improve on a fixed embedding
    /// (same Gaussian rate, slower SRHT rate) while paying the full
    /// sketch+factor cost each step; this flag exists to reproduce that
    /// ablation (`benches/ablations`). Deliberately *not* routed through
    /// the incremental `SketchEngine`: a refresh draws an independent
    /// embedding at the same size (nothing to reuse), which is exactly
    /// the cost the ablation measures — though the re-apply itself now
    /// runs on the parallel GEMM/FWHT kernels like everything else.
    pub refresh: bool,
    /// Iteration cap (safety net; the stop rule fires first).
    pub max_iters: usize,
}

impl IhsConfig {
    /// Parameters per Definition 3.1 (Gaussian practical parameters) for a
    /// given aspect ratio `rho` (`eta` fixed at 0.01 as in the paper's
    /// experiments).
    pub fn gaussian(m: usize, rho: f64) -> Self {
        let params = gaussian_bounds(rho, 0.01, 1.0).params();
        Self {
            kind: SketchKind::Gaussian,
            m,
            params,
            momentum: true,
            refresh: false,
            max_iters: 10_000,
        }
    }

    /// Parameters per Definition 3.2 (SRHT practical parameters).
    pub fn srht(m: usize, rho: f64) -> Self {
        let params = srht_bounds(rho, 2, 2.0).params();
        Self {
            kind: SketchKind::Srht,
            m,
            params,
            momentum: true,
            refresh: false,
            max_iters: 10_000,
        }
    }
}

/// Factor the sketched Hessian, falling back to the exact Hessian if the
/// sketch is numerically unusable (the fixed-size method has no growth
/// schedule to retry with, so the ladder here is jitter — inside the
/// factorization — then exact). The rung climbed lands in
/// [`SolveReport::recovery`].
fn factor_or_exact(sa: Matrix, problem: &RidgeProblem, report: &mut SolveReport) -> WoodburyCache {
    match WoodburyCache::new(sa, problem.nu) {
        Ok(cache) => {
            report.recovery.escalate(cache.recovery());
            cache
        }
        Err(_) => {
            report.recovery.escalate(RecoveryRung::Exact);
            WoodburyCache::new(problem.a.dense().into_owned(), problem.nu)
                .expect("recovery ladder exhausted: exact ridge Hessian would not factor")
        }
    }
}

/// Run fixed-size IHS from `x0`; the embedding is drawn from `seed`.
pub fn solve(
    problem: &RidgeProblem,
    x0: &[f64],
    config: &IhsConfig,
    stop: &StopRule,
    seed: u64,
) -> Solution {
    let start = Instant::now();
    let d = problem.d();
    assert_eq!(x0.len(), d);
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let label = if config.momentum { "polyak-ihs" } else { "ihs" };
    let mut report = SolveReport::new(format!("{label}-{}", config.kind));
    report.final_m = config.m;
    report.peak_m = config.m;

    // Sketch + factor once (dense or CSR operand at the family's cost).
    let t0 = Instant::now();
    let s = sketch::sample(config.kind, config.m, problem.n(), &mut rng);
    let sa = s.apply_operand(&problem.a);
    report.sketch_time_s = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let cache = factor_or_exact(sa, problem, &mut report);
    report.factor_time_s = t0.elapsed().as_secs_f64();

    // Inner loop is allocation-free (workspace buffers below); only the
    // `refresh` ablation re-allocates, since it re-sketches wholesale.
    let t_iter = Instant::now();
    let mut x_prev = x0.to_vec();
    let mut x = x0.to_vec();
    let mut x_next = vec![0.0; d];
    let mut g = problem.gradient(&x);
    let mut gt = vec![0.0; d];
    let mut ws_m: Vec<f64> = Vec::new();
    let mut ws_n: Vec<f64> = Vec::new();
    let mut ws_d: Vec<f64> = Vec::new();
    let g0_norm = norm2(&g);
    let delta0 = match stop {
        StopRule::TrueError { x_star, .. } => {
            problem.prediction_error_ws(&x, x_star, &mut ws_d, &mut ws_n)
        }
        _ => 0.0,
    };
    if matches!(stop, StopRule::TrueError { .. }) {
        // Shared trace convention: entry t is delta_t / delta_0.
        report.error_trace.reserve(config.max_iters.min(65_536) + 1);
        report.error_trace.push(1.0);
    }

    let (mu, beta) = if config.momentum {
        (config.params.mu_p, config.params.beta_p)
    } else {
        (config.params.mu_gd, 0.0)
    };

    let mut cache = cache;
    for t in 0..config.max_iters {
        if config.refresh && t > 0 {
            // Refreshed-embedding ablation: new S, new factorization.
            let t0 = Instant::now();
            let s = sketch::sample(config.kind, config.m, problem.n(), &mut rng);
            let sa = s.apply_operand(&problem.a);
            report.sketch_time_s += t0.elapsed().as_secs_f64();
            let t0 = Instant::now();
            cache = factor_or_exact(sa, problem, &mut report);
            report.factor_time_s += t0.elapsed().as_secs_f64();
        }
        cache.apply_inverse_into(&g, &mut ws_m, &mut gt);
        // x_next = x - mu * gt + beta * (x - x_prev)
        x_next.copy_from_slice(&x);
        axpy(-mu, &gt, &mut x_next);
        if beta != 0.0 {
            for i in 0..d {
                x_next[i] += beta * (x[i] - x_prev[i]);
            }
        }
        // Rotate buffers: x_prev <- x, x <- x_next (old x_prev becomes
        // the next x_next scratch — fully overwritten above).
        std::mem::swap(&mut x_prev, &mut x);
        std::mem::swap(&mut x, &mut x_next);
        problem.gradient_into(&x, &mut ws_n, &mut g);
        report.iterations = t + 1;

        let stop_now = match stop {
            StopRule::TrueError { x_star, eps } => {
                let delta = problem.prediction_error_ws(&x, x_star, &mut ws_d, &mut ws_n);
                report.error_trace.push(if delta0 > 0.0 { delta / delta0 } else { 0.0 });
                delta <= eps * delta0
            }
            StopRule::GradientNorm { tol } => norm2(&g) <= tol * g0_norm,
        };
        if stop_now {
            report.converged = true;
            break;
        }
    }

    if let StopRule::TrueError { x_star, eps } = stop {
        let delta = problem.prediction_error(&x, x_star);
        report.final_rel_error = Some(if delta0 > 0.0 { delta / delta0 } else { 0.0 });
        if delta0 > 0.0 && delta <= eps * delta0 {
            report.converged = true;
        }
    }
    report.iter_time_s = t_iter.elapsed().as_secs_f64();
    report.wall_time_s = start.elapsed().as_secs_f64();
    Solution { x, report }
}

/// The \[31\]-style baseline the adaptive method supersedes: estimate `d_e`
/// with a Hutchinson trace estimator (cost: `probes` ridge solves on the
/// Gram matrix, i.e. `O(nd^2 + probes * d^2)` — already more than the
/// adaptive method's whole budget), then run fixed-size IHS with
/// `m = ceil(d_e_hat / rho)`. Exposed for the ablation benches; no
/// accuracy guarantee links `d_e_hat` to the true `d_e`.
pub fn solve_with_estimated_de(
    problem: &RidgeProblem,
    x0: &[f64],
    kind: SketchKind,
    rho: f64,
    probes: usize,
    stop: &StopRule,
    seed: u64,
) -> (Solution, f64) {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let t0 = Instant::now();
    let de_hat = crate::theory::effective_dim::hutchinson_effective_dimension(
        &problem.a.dense(),
        problem.nu,
        probes,
        &mut rng,
    )
    .max(1.0);
    let estimate_time = t0.elapsed().as_secs_f64();
    let m = ((de_hat / rho).ceil() as usize)
        .clamp(1, crate::sketch::srht::next_pow2(problem.n()));
    let mut cfg = match kind {
        SketchKind::Gaussian => IhsConfig::gaussian(m, rho.min(0.18)),
        _ => IhsConfig::srht(m, rho),
    };
    cfg.kind = kind;
    let mut sol = solve(problem, x0, &cfg, stop, seed.wrapping_add(1));
    sol.report.solver = format!("hutchinson-ihs-{kind}");
    // Charge the estimation phase to the factor bucket (it plays the same
    // role: pre-iteration setup).
    sol.report.factor_time_s += estimate_time;
    sol.report.wall_time_s += estimate_time;
    (sol, de_hat)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::direct;
    use crate::solvers::test_util::small_problem;
    use crate::theory::effective_dimension_from_spectrum;

    fn de_of(p: &RidgeProblem) -> f64 {
        let s = crate::linalg::svd::singular_values(&p.a.dense());
        effective_dimension_from_spectrum(&s, p.nu)
    }

    #[test]
    fn gradient_ihs_converges_with_m_near_de() {
        let p = small_problem(256, 32, 0.5, 1);
        let x_star = direct::solve(&p);
        let d_e = de_of(&p);
        let rho = 0.15;
        let m = ((d_e / rho).ceil() as usize).max(8);
        let mut cfg = IhsConfig::gaussian(m, rho);
        cfg.momentum = false;
        let stop = StopRule::TrueError { x_star, eps: 1e-10 };
        let sol = solve(&p, &vec![0.0; 32], &cfg, &stop, 2);
        assert!(sol.report.converged, "gradient-IHS failed (m={m}, d_e={d_e:.1})");
    }

    #[test]
    fn polyak_ihs_converges_and_accelerates() {
        let p = small_problem(512, 32, 0.1, 3);
        let x_star = direct::solve(&p);
        let d_e = de_of(&p);
        let rho = 0.15;
        let m = ((d_e / rho).ceil() as usize).max(8);
        let stop = StopRule::TrueError { x_star, eps: 1e-10 };
        let mut grad_cfg = IhsConfig::gaussian(m, rho);
        grad_cfg.momentum = false;
        let polyak_cfg = IhsConfig::gaussian(m, rho);
        let grad = solve(&p, &vec![0.0; 32], &grad_cfg, &stop, 4);
        let polyak = solve(&p, &vec![0.0; 32], &polyak_cfg, &stop, 4);
        assert!(grad.report.converged && polyak.report.converged);
        assert!(
            polyak.report.iterations <= grad.report.iterations,
            "polyak {} > gradient {}",
            polyak.report.iterations,
            grad.report.iterations
        );
    }

    #[test]
    fn rate_scales_with_aspect_ratio() {
        // Theorem 1: larger m (smaller d_e/m) => faster contraction.
        let p = small_problem(512, 16, 0.3, 5);
        let x_star = direct::solve(&p);
        let stop = StopRule::TrueError { x_star, eps: 1e-9 };
        let d_e = de_of(&p);
        let run = |m: usize, seed: u64| {
            let mut cfg = IhsConfig::gaussian(m, 0.15);
            cfg.momentum = false;
            solve(&p, &vec![0.0; 16], &cfg, &stop, seed).report.iterations
        };
        let m_small = ((d_e / 0.15).ceil() as usize).max(8);
        let iters_small = run(m_small, 6);
        let iters_large = run(4 * m_small, 6);
        assert!(iters_large <= iters_small);
    }

    #[test]
    fn srht_variant_converges() {
        let p = small_problem(256, 32, 0.5, 7);
        let x_star = direct::solve(&p);
        let d_e = de_of(&p);
        let m = ((d_e * 4.0).ceil() as usize).clamp(16, 256);
        let cfg = IhsConfig::srht(m, 0.25);
        let stop = StopRule::TrueError { x_star, eps: 1e-9 };
        let sol = solve(&p, &vec![0.0; 32], &cfg, &stop, 8);
        assert!(sol.report.converged, "SRHT IHS failed with m={m}");
        assert_eq!(sol.report.solver, "polyak-ihs-srht");
    }

    #[test]
    fn tiny_sketch_fails_to_meet_rate() {
        // m = 1 on a problem with d_e >> 1: the fixed-size method stalls —
        // exactly the failure mode the adaptive algorithm exists to fix.
        let p = small_problem(256, 32, 0.05, 9);
        let x_star = direct::solve(&p);
        let mut cfg = IhsConfig::gaussian(1, 0.15);
        cfg.momentum = false;
        cfg.max_iters = 60;
        let stop = StopRule::TrueError { x_star, eps: 1e-10 };
        let sol = solve(&p, &vec![0.0; 32], &cfg, &stop, 10);
        assert!(!sol.report.converged, "m=1 should not converge in 60 iters");
    }

    #[test]
    fn refreshed_variant_converges_but_pays_setup_cost() {
        let p = small_problem(256, 32, 0.5, 11);
        let x_star = direct::solve(&p);
        let d_e = de_of(&p);
        let m = ((d_e / 0.15).ceil() as usize).max(8);
        let stop = StopRule::TrueError { x_star, eps: 1e-9 };
        let mut fixed_cfg = IhsConfig::gaussian(m, 0.15);
        fixed_cfg.momentum = false;
        let mut refresh_cfg = fixed_cfg.clone();
        refresh_cfg.refresh = true;
        let fixed = solve(&p, &vec![0.0; 32], &fixed_cfg, &stop, 12);
        let refreshed = solve(&p, &vec![0.0; 32], &refresh_cfg, &stop, 12);
        assert!(fixed.report.converged && refreshed.report.converged);
        // Section 1.3 ablation: refreshing buys no iteration advantage
        // worth its cost — sketch+factor time must be strictly larger.
        assert!(
            refreshed.report.sketch_time_s + refreshed.report.factor_time_s
                > fixed.report.sketch_time_s + fixed.report.factor_time_s
        );
    }

    #[test]
    fn hutchinson_baseline_converges_with_reasonable_estimate() {
        let p = small_problem(256, 32, 0.5, 13);
        let x_star = direct::solve(&p);
        let d_e = de_of(&p);
        let stop = StopRule::TrueError { x_star, eps: 1e-9 };
        let (sol, de_hat) =
            solve_with_estimated_de(&p, &vec![0.0; 32], SketchKind::Gaussian, 0.15, 50, &stop, 14);
        assert!(sol.report.converged, "hutchinson baseline failed");
        assert!((de_hat - d_e).abs() < 0.5 * d_e.max(2.0), "estimate {de_hat} vs {d_e}");
        assert!(sol.report.solver.starts_with("hutchinson"));
    }
}
