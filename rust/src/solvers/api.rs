//! Unified solver API: one dispatch surface for every solver, every caller.
//!
//! The paper's central claim is comparative — adaptive IHS vs CG, pCG and
//! fixed-size IHS (Figures 1–3) — so the repo needs a single way to *name*
//! and *run* a solver. This module provides it:
//!
//! * [`Solver`] — the object-safe trait every solver implements:
//!   `solve(problem, x0, stop) -> Solution`, plus capability metadata
//!   (`supports_warm_start`, `is_randomized`).
//! * [`SolverSpec`] — a plain-data description of a solver configuration
//!   that is `FromStr`/`Display` round-trippable. Spec strings follow the
//!   grammar `name[@key=value[,key=value...]]`, e.g. `"cg"`,
//!   `"pcg-gaussian"`, `"adaptive-srht"`, `"ihs-sparse@m=256"`,
//!   `"pcg-srht@rho=0.25"`, `"adaptive-srht@threads=8"`. Specs travel
//!   over the wire (coordinator protocol), across the CLI, and through
//!   the bench harness. The `threads` param pins the parallel dense
//!   kernels ([`crate::linalg::threads`]) for the duration of that
//!   solver's `solve` call; without it the kernels use the global /
//!   `PALLAS_THREADS` / hardware default.
//! * [`SolverSpec::build`] — turn a spec plus an explicit `seed` into a
//!   boxed [`Solver`]. Seeding is part of construction; no `&mut rng`
//!   threads through call sites, and a built solver is deterministic:
//!   the same `(spec, seed, problem, x0, stop)` always yields the same
//!   `Solution`.
//! * [`registry`] — every available solver spec, used for CLI help
//!   (`effdim solvers`), server introspection (`{"cmd":"solvers"}`) and
//!   the shared agreement test in `tests/solver_agreement.rs`.
//!
//! Adding a solver family = one `SolverSpec` variant, one wrapper struct,
//! one `registry()` entry — instead of new match arms in the coordinator,
//! the path driver, the CLI and the bench harness.

use super::adaptive::{self, AdaptiveConfig, AdaptiveVariant};
use super::cg::{self, CgConfig};
use super::dual::DualRidge;
use super::error::SolverError;
use super::ihs::{self, IhsConfig};
use super::pcg::{self, PcgConfig};
use super::{direct, RidgeProblem, Solution, SolveReport, StopRule};
use crate::sketch::SketchKind;
use std::fmt;
use std::str::FromStr;
use std::time::Instant;

/// The one interface every solver exposes. Object-safe so callers hold
/// `Box<dyn Solver>` built from a [`SolverSpec`].
pub trait Solver: Send + Sync {
    /// Canonical label — equals the spec string that built this solver,
    /// and the `solver` field of the returned [`SolveReport`].
    fn label(&self) -> String;

    /// Whether a nonzero `x0` helps (regularization-path warm starts).
    /// Solvers that ignore `x0` (direct, dual) return `false`.
    fn supports_warm_start(&self) -> bool;

    /// Whether the solver draws random sketches (and therefore consumed
    /// the seed passed to [`SolverSpec::build`]).
    fn is_randomized(&self) -> bool;

    /// Run from `x0` under `stop`. Deterministic given the builder seed.
    fn solve(&self, problem: &RidgeProblem, x0: &[f64], stop: &StopRule) -> Solution;

    /// [`Solver::solve`] with structured failure: invalid input, wall
    /// deadlines and exhausted numerical recovery come back as
    /// [`SolverError`] values instead of panics. The default wraps
    /// `solve` for solver families whose inputs are pre-validated and
    /// whose numerics cannot break down (direct, CG); fallible families
    /// (adaptive, dual-adaptive) override it.
    fn try_solve(
        &self,
        problem: &RidgeProblem,
        x0: &[f64],
        stop: &StopRule,
    ) -> Result<Solution, SolverError> {
        Ok(self.solve(problem, x0, stop))
    }
}

/// Plain-data description of a solver configuration.
///
/// Round-trips through `Display`/`FromStr`; see the module docs for the
/// string grammar. `PartialEq` makes the round-trip testable.
#[derive(Clone, Debug, PartialEq)]
pub enum SolverSpec {
    /// Cholesky on the normal equations (ground truth; `O(n d^2 + d^3)`).
    Direct,
    /// Conjugate gradient baseline.
    Cg,
    /// Randomized-preconditioned CG (Rokhlin–Tygert style).
    Pcg { kind: SketchKind, rho: f64, threads: Option<usize> },
    /// Fixed-sketch-size IHS (Theorems 1–2). `m = None` defaults to `d`
    /// at solve time — a memory budget matching pCG's minimum, adequate
    /// whenever `d_e << d`. The fixed-size step parameters assume aspect
    /// ratio `d_e/m ~ rho`; when `d_e` approaches `d` (tiny `nu`) pick an
    /// explicit `@m=...` or use an `Adaptive` spec, which needs no `m` at
    /// all. `momentum` selects the Polyak heavy-ball update.
    Ihs { kind: SketchKind, m: Option<usize>, momentum: bool, threads: Option<usize> },
    /// Algorithm 1, the paper's adaptive solver.
    Adaptive { kind: SketchKind, variant: AdaptiveVariant, threads: Option<usize> },
    /// Underdetermined problems (`d >= n`) via the dual reduction
    /// (Appendix A.2), solved with Algorithm 1. The built solver panics
    /// if the problem lacks raw observations `b` (normal-form problems)
    /// or is overdetermined (`n > d`) — the coordinator pre-checks this;
    /// library callers must too.
    DualAdaptive { kind: SketchKind, threads: Option<usize> },
}

/// Run `f` with the dense kernels pinned to `threads` threads (no-op for
/// `None`) — the per-solve hook behind the `@threads=k` spec param.
fn with_spec_threads<R>(threads: Option<usize>, f: impl FnOnce() -> R) -> R {
    match threads {
        Some(k) => crate::linalg::threads::with_threads(k, f),
        None => f(),
    }
}

/// Default aspect-ratio parameter `rho` for pCG preconditioner sizing.
pub const DEFAULT_PCG_RHO: f64 = 0.5;

/// Default `rho` for fixed-size IHS step-size parameters, per sketch
/// family (Definitions 3.1 / 3.2 practical parameters).
pub fn default_ihs_rho(kind: SketchKind) -> f64 {
    match kind {
        SketchKind::Gaussian => 0.15,
        SketchKind::Srht | SketchKind::Sparse => 0.25,
    }
}

impl SolverSpec {
    /// One-line description for CLI help and server introspection.
    pub fn describe(&self) -> &'static str {
        match self {
            SolverSpec::Direct => "Cholesky on the normal equations (exact, O(n d^2))",
            SolverSpec::Cg => "conjugate gradient on (A^T A + nu^2 I) x = A^T b",
            SolverSpec::Pcg { .. } => "randomized-preconditioned CG, m ~ d/rho sketch",
            SolverSpec::Ihs { momentum: false, .. } => "fixed-size gradient-IHS (Theorem 1)",
            SolverSpec::Ihs { momentum: true, .. } => "fixed-size Polyak-IHS (Theorem 2)",
            SolverSpec::Adaptive { variant: AdaptiveVariant::PolyakFirst, .. } => {
                "adaptive Polyak-IHS, Algorithm 1 (m starts at 1, grows to O(d_e))"
            }
            SolverSpec::Adaptive { variant: AdaptiveVariant::GradientOnly, .. } => {
                "adaptive gradient-IHS, Algorithm 1 without the Polyak candidate"
            }
            SolverSpec::DualAdaptive { .. } => {
                "dual reduction for d >= n, solved with adaptive IHS (Appendix A.2)"
            }
        }
    }

    /// Build the paper's `TrueError` stop rule for this spec: the exact
    /// solution at the problem's `nu`, to relative precision `eps`.
    ///
    /// Dual specs skip the primal oracle entirely — an `O(d^3)` Cholesky
    /// that would dominate wide problems — because [`SolverSpec::DualAdaptive`]
    /// solvers build their own (cheap, `n x n`) dual-space oracle and
    /// consult only `eps`; the placeholder `x_star` is never read.
    pub fn true_error_stop(&self, problem: &RidgeProblem, eps: f64) -> StopRule {
        match self {
            SolverSpec::DualAdaptive { .. } => StopRule::TrueError { x_star: Vec::new(), eps },
            _ => StopRule::TrueError { x_star: direct::solve(problem), eps },
        }
    }

    /// Build a runnable solver. `seed` is consumed only by randomized
    /// solvers ([`Solver::is_randomized`]); deterministic ones ignore it.
    pub fn build(&self, seed: u64) -> Box<dyn Solver> {
        match self {
            SolverSpec::Direct => Box::new(DirectSolver),
            SolverSpec::Cg => Box::new(CgSolver { config: CgConfig { max_iters: 200_000 } }),
            SolverSpec::Pcg { kind, rho, threads } => Box::new(PcgSolver {
                config: PcgConfig::new(*kind, *rho),
                label: self.to_string(),
                seed,
                threads: *threads,
            }),
            SolverSpec::Ihs { kind, m, momentum, threads } => Box::new(IhsSolver {
                kind: *kind,
                m: *m,
                momentum: *momentum,
                label: self.to_string(),
                seed,
                threads: *threads,
            }),
            SolverSpec::Adaptive { kind, variant, threads } => {
                let mut config = AdaptiveConfig::new(*kind);
                config.variant = *variant;
                Box::new(AdaptiveIhsSolver {
                    config,
                    label: self.to_string(),
                    seed,
                    threads: *threads,
                })
            }
            SolverSpec::DualAdaptive { kind, threads } => Box::new(DualAdaptiveSolver {
                kind: *kind,
                label: self.to_string(),
                seed,
                threads: *threads,
            }),
        }
    }
}

impl fmt::Display for SolverSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Base name + ordered `key=value` params (only non-defaults are
        // emitted, keeping canonical labels minimal and round-trippable).
        let mut params: Vec<String> = Vec::new();
        match self {
            SolverSpec::Direct => write!(f, "direct")?,
            SolverSpec::Cg => write!(f, "cg")?,
            SolverSpec::Pcg { kind, rho, threads } => {
                write!(f, "pcg-{kind}")?;
                if *rho != DEFAULT_PCG_RHO {
                    params.push(format!("rho={rho}"));
                }
                if let Some(t) = threads {
                    params.push(format!("threads={t}"));
                }
            }
            SolverSpec::Ihs { kind, m, momentum, threads } => {
                if *momentum {
                    write!(f, "polyak-ihs-{kind}")?;
                } else {
                    write!(f, "ihs-{kind}")?;
                }
                if let Some(m) = m {
                    params.push(format!("m={m}"));
                }
                if let Some(t) = threads {
                    params.push(format!("threads={t}"));
                }
            }
            SolverSpec::Adaptive { kind, variant, threads } => {
                match variant {
                    AdaptiveVariant::PolyakFirst => write!(f, "adaptive-{kind}")?,
                    AdaptiveVariant::GradientOnly => write!(f, "adaptive-gd-{kind}")?,
                }
                if let Some(t) = threads {
                    params.push(format!("threads={t}"));
                }
            }
            SolverSpec::DualAdaptive { kind, threads } => {
                write!(f, "dual-adaptive-{kind}")?;
                if let Some(t) = threads {
                    params.push(format!("threads={t}"));
                }
            }
        }
        if !params.is_empty() {
            write!(f, "@{}", params.join(","))?;
        }
        Ok(())
    }
}

impl FromStr for SolverSpec {
    type Err = String;

    /// Parse `name[@key=value[,key=value...]]`. Legacy aliases accepted:
    /// `"adaptive"` (Gaussian, Polyak-first), `"adaptive-gd"` (Gaussian),
    /// `"pcg"` (SRHT), `"dual"` (Gaussian adaptive on the dual).
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let s = s.trim();
        let (base, params) = match s.split_once('@') {
            Some((b, p)) => (b, Some(p)),
            None => (s, None),
        };

        let mut spec = match base {
            "direct" => SolverSpec::Direct,
            "cg" => SolverSpec::Cg,
            "pcg" => {
                SolverSpec::Pcg { kind: SketchKind::Srht, rho: DEFAULT_PCG_RHO, threads: None }
            }
            "adaptive" => SolverSpec::Adaptive {
                kind: SketchKind::Gaussian,
                variant: AdaptiveVariant::PolyakFirst,
                threads: None,
            },
            "adaptive-gd" => SolverSpec::Adaptive {
                kind: SketchKind::Gaussian,
                variant: AdaptiveVariant::GradientOnly,
                threads: None,
            },
            "dual" => SolverSpec::DualAdaptive { kind: SketchKind::Gaussian, threads: None },
            _ => {
                // `<family>-<kind>` with the sketch kind as the last
                // '-'-separated token.
                let (family, kind_str) = base
                    .rsplit_once('-')
                    .ok_or_else(|| format!("unknown solver: {base}"))?;
                let kind: SketchKind = kind_str.parse().map_err(|_| {
                    format!("unknown solver: {base} (bad sketch kind {kind_str:?})")
                })?;
                match family {
                    "pcg" => SolverSpec::Pcg { kind, rho: DEFAULT_PCG_RHO, threads: None },
                    "ihs" => SolverSpec::Ihs { kind, m: None, momentum: false, threads: None },
                    "polyak-ihs" => {
                        SolverSpec::Ihs { kind, m: None, momentum: true, threads: None }
                    }
                    "adaptive" => SolverSpec::Adaptive {
                        kind,
                        variant: AdaptiveVariant::PolyakFirst,
                        threads: None,
                    },
                    "adaptive-gd" => SolverSpec::Adaptive {
                        kind,
                        variant: AdaptiveVariant::GradientOnly,
                        threads: None,
                    },
                    "dual-adaptive" => SolverSpec::DualAdaptive { kind, threads: None },
                    _ => return Err(format!("unknown solver: {base}")),
                }
            }
        };

        if let Some(params) = params {
            for kv in params.split(',') {
                let (key, value) = kv
                    .split_once('=')
                    .ok_or_else(|| format!("bad solver param {kv:?} (want key=value)"))?;
                match (key.trim(), &mut spec) {
                    ("m", SolverSpec::Ihs { m, .. }) => {
                        let v: usize = value
                            .trim()
                            .parse()
                            .map_err(|_| format!("bad m value {value:?}"))?;
                        if v == 0 {
                            return Err("m must be >= 1".into());
                        }
                        *m = Some(v);
                    }
                    ("rho", SolverSpec::Pcg { rho, .. }) => {
                        let v: f64 = value
                            .trim()
                            .parse()
                            .map_err(|_| format!("bad rho value {value:?}"))?;
                        if !v.is_finite() || v <= 0.0 {
                            return Err("rho must be > 0".into());
                        }
                        *rho = v;
                    }
                    (
                        "threads",
                        SolverSpec::Pcg { threads, .. }
                        | SolverSpec::Ihs { threads, .. }
                        | SolverSpec::Adaptive { threads, .. }
                        | SolverSpec::DualAdaptive { threads, .. },
                    ) => {
                        let v: usize = value
                            .trim()
                            .parse()
                            .map_err(|_| format!("bad threads value {value:?}"))?;
                        if v == 0 {
                            return Err("threads must be >= 1".into());
                        }
                        *threads = Some(v);
                    }
                    (other, _) => {
                        return Err(format!("param {other:?} not supported by solver {base:?}"))
                    }
                }
            }
        }
        Ok(spec)
    }
}

/// Every available solver, in display order. The shared agreement test
/// asserts each entry converges to the direct solution; the CLI and the
/// coordinator expose this list verbatim.
pub fn registry() -> Vec<SolverSpec> {
    use AdaptiveVariant::{GradientOnly, PolyakFirst};
    use SketchKind::{Gaussian, Sparse, Srht};
    vec![
        SolverSpec::Direct,
        SolverSpec::Cg,
        SolverSpec::Pcg { kind: Gaussian, rho: DEFAULT_PCG_RHO, threads: None },
        SolverSpec::Pcg { kind: Srht, rho: DEFAULT_PCG_RHO, threads: None },
        SolverSpec::Ihs { kind: Gaussian, m: None, momentum: false, threads: None },
        SolverSpec::Ihs { kind: Srht, m: None, momentum: false, threads: None },
        SolverSpec::Ihs { kind: Sparse, m: None, momentum: false, threads: None },
        SolverSpec::Ihs { kind: Gaussian, m: None, momentum: true, threads: None },
        SolverSpec::Ihs { kind: Srht, m: None, momentum: true, threads: None },
        SolverSpec::Adaptive { kind: Gaussian, variant: PolyakFirst, threads: None },
        SolverSpec::Adaptive { kind: Srht, variant: PolyakFirst, threads: None },
        SolverSpec::Adaptive { kind: Sparse, variant: PolyakFirst, threads: None },
        SolverSpec::Adaptive { kind: Gaussian, variant: GradientOnly, threads: None },
        SolverSpec::Adaptive { kind: Srht, variant: GradientOnly, threads: None },
        SolverSpec::DualAdaptive { kind: Gaussian, threads: None },
    ]
}

// ---------------------------------------------------------------------------
// Wrapper implementations
// ---------------------------------------------------------------------------

/// Relative prediction error under a `TrueError` stop rule, if available.
fn true_rel_error(problem: &RidgeProblem, x0: &[f64], x: &[f64], stop: &StopRule) -> Option<f64> {
    match stop {
        StopRule::TrueError { x_star, .. } => {
            let delta0 = problem.prediction_error(x0, x_star);
            let delta = problem.prediction_error(x, x_star);
            Some(if delta0 > 0.0 { delta / delta0 } else { 0.0 })
        }
        StopRule::GradientNorm { .. } => None,
    }
}

struct DirectSolver;

impl Solver for DirectSolver {
    fn label(&self) -> String {
        "direct".into()
    }

    fn supports_warm_start(&self) -> bool {
        false
    }

    fn is_randomized(&self) -> bool {
        false
    }

    fn solve(&self, problem: &RidgeProblem, x0: &[f64], stop: &StopRule) -> Solution {
        let start = Instant::now();
        let mut report = SolveReport::new(self.label());
        let t0 = Instant::now();
        let x = match stop {
            // TrueError's contract says x_star IS this problem's optimum
            // (the caller already paid the O(n d^2) factorization for the
            // oracle); reuse it rather than factoring twice — but verify
            // stationarity first so a stale oracle can't pass through.
            StopRule::TrueError { x_star, .. } if x_star.len() == problem.d() => {
                let g = problem.gradient(x_star);
                // Problem-relative scale (no absolute floor: on tiny-
                // magnitude data a floored threshold would accept a stale
                // oracle); scale 0 degenerates to always re-solving.
                let scale = crate::linalg::norm2(&problem.atb);
                if crate::linalg::norm2(&g) <= 1e-8 * scale {
                    x_star.clone()
                } else {
                    direct::solve(problem)
                }
            }
            _ => direct::solve(problem),
        };
        report.factor_time_s = t0.elapsed().as_secs_f64();
        report.iterations = 1;
        report.converged = true;
        if let Some(rel) = true_rel_error(problem, x0, &x, stop) {
            report.final_rel_error = Some(rel);
            // Shared trace convention: entry t is delta_t / delta_0,
            // starting from the (trivially 1.0) initial point.
            report.error_trace.push(1.0);
            report.error_trace.push(rel);
        }
        report.wall_time_s = start.elapsed().as_secs_f64();
        Solution { x, report }
    }
}

struct CgSolver {
    config: CgConfig,
}

impl Solver for CgSolver {
    fn label(&self) -> String {
        "cg".into()
    }

    fn supports_warm_start(&self) -> bool {
        true
    }

    fn is_randomized(&self) -> bool {
        false
    }

    fn solve(&self, problem: &RidgeProblem, x0: &[f64], stop: &StopRule) -> Solution {
        cg::solve(problem, x0, &self.config, stop)
    }
}

struct PcgSolver {
    config: PcgConfig,
    label: String,
    seed: u64,
    threads: Option<usize>,
}

impl Solver for PcgSolver {
    fn label(&self) -> String {
        self.label.clone()
    }

    fn supports_warm_start(&self) -> bool {
        true
    }

    fn is_randomized(&self) -> bool {
        true
    }

    fn solve(&self, problem: &RidgeProblem, x0: &[f64], stop: &StopRule) -> Solution {
        let mut sol = with_spec_threads(self.threads, || {
            pcg::solve(problem, x0, &self.config, stop, self.seed)
        });
        sol.report.solver = self.label();
        sol
    }
}

struct IhsSolver {
    kind: SketchKind,
    m: Option<usize>,
    momentum: bool,
    label: String,
    seed: u64,
    threads: Option<usize>,
}

impl Solver for IhsSolver {
    fn label(&self) -> String {
        self.label.clone()
    }

    fn supports_warm_start(&self) -> bool {
        true
    }

    fn is_randomized(&self) -> bool {
        true
    }

    fn solve(&self, problem: &RidgeProblem, x0: &[f64], stop: &StopRule) -> Solution {
        // Without an explicit m the spec defaults to d (always >= d_e).
        // Only SRHT has a hard ceiling (it cannot produce more rows than
        // the padded row count); Gaussian/sparse honor the request as-is.
        let requested = self.m.unwrap_or_else(|| problem.d()).max(1);
        let m = match self.kind {
            SketchKind::Srht => requested.min(crate::sketch::srht::next_pow2(problem.n())),
            SketchKind::Gaussian | SketchKind::Sparse => requested,
        };
        let rho = default_ihs_rho(self.kind);
        let mut config = match self.kind {
            SketchKind::Gaussian => IhsConfig::gaussian(m, rho),
            SketchKind::Srht | SketchKind::Sparse => IhsConfig::srht(m, rho),
        };
        config.kind = self.kind;
        config.momentum = self.momentum;
        let mut sol =
            with_spec_threads(self.threads, || ihs::solve(problem, x0, &config, stop, self.seed));
        // The label is the spec string as requested (the trait invariant
        // callers key results by); when the SRHT ceiling clamped an
        // explicit m, the effective size is what `final_m`/`peak_m`
        // already report.
        sol.report.solver = self.label();
        sol
    }
}

struct AdaptiveIhsSolver {
    config: AdaptiveConfig,
    label: String,
    seed: u64,
    threads: Option<usize>,
}

impl Solver for AdaptiveIhsSolver {
    fn label(&self) -> String {
        self.label.clone()
    }

    fn supports_warm_start(&self) -> bool {
        true
    }

    fn is_randomized(&self) -> bool {
        true
    }

    fn solve(&self, problem: &RidgeProblem, x0: &[f64], stop: &StopRule) -> Solution {
        self.try_solve(problem, x0, stop)
            .unwrap_or_else(|e| panic!("adaptive solve failed: {e}"))
    }

    fn try_solve(
        &self,
        problem: &RidgeProblem,
        x0: &[f64],
        stop: &StopRule,
    ) -> Result<Solution, SolverError> {
        let mut sol = with_spec_threads(self.threads, || {
            adaptive::solve(problem, x0, &self.config, stop, self.seed)
        })?;
        sol.report.solver = self.label();
        Ok(sol)
    }
}

struct DualAdaptiveSolver {
    kind: SketchKind,
    label: String,
    seed: u64,
    threads: Option<usize>,
}

impl Solver for DualAdaptiveSolver {
    fn label(&self) -> String {
        self.label.clone()
    }

    /// The dual iteration lives in `z`-space; a primal `x0` cannot seed it.
    fn supports_warm_start(&self) -> bool {
        false
    }

    fn is_randomized(&self) -> bool {
        true
    }

    fn solve(&self, problem: &RidgeProblem, x0: &[f64], stop: &StopRule) -> Solution {
        self.try_solve(problem, x0, stop)
            .unwrap_or_else(|e| panic!("dual solver: {e}"))
    }

    fn try_solve(
        &self,
        problem: &RidgeProblem,
        _x0: &[f64],
        stop: &StopRule,
    ) -> Result<Solution, SolverError> {
        let b = problem
            .b
            .as_ref()
            .ok_or_else(|| SolverError::invalid("dual solver needs raw observations b"))?
            .clone();
        if problem.n() > problem.a.cols() {
            return Err(SolverError::invalid(
                "dual path is for underdetermined problems (d >= n)",
            ));
        }
        let dr = DualRidge::new_shared(std::sync::Arc::clone(&problem.a), b, problem.nu);
        // Translate the primal stop rule into the dual space: the paper's
        // TrueError criterion needs the dual optimum (one n x n direct
        // solve); the incoming primal `x_star` is never consulted — only
        // `eps` — which is why `true_error_stop` may pass a placeholder.
        // GradientNorm transfers verbatim to the dual gradient.
        let dual_stop = match stop {
            StopRule::TrueError { eps, .. } => {
                StopRule::TrueError { x_star: direct::solve(&dr.dual), eps: *eps }
            }
            StopRule::GradientNorm { tol } => StopRule::GradientNorm { tol: *tol },
        };
        let config = AdaptiveConfig::new(self.kind);
        let mut sol = with_spec_threads(self.threads, || {
            dr.try_solve_adaptive(&config, &dual_stop, self.seed)
        })?;
        sol.report.solver = self.label();
        Ok(sol)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::test_util::small_problem;

    #[test]
    fn registry_specs_roundtrip() {
        for spec in registry() {
            let s = spec.to_string();
            let back: SolverSpec = s.parse().unwrap_or_else(|e| panic!("parse {s:?}: {e}"));
            assert_eq!(back, spec, "round-trip of {s:?}");
        }
    }

    #[test]
    fn param_strings_roundtrip() {
        for s in [
            "ihs-sparse@m=256",
            "polyak-ihs-gaussian@m=32",
            "pcg-srht@rho=0.25",
            "adaptive-srht@threads=8",
            "ihs-sparse@m=256,threads=4",
            "pcg-srht@rho=0.25,threads=2",
            "dual-adaptive-gaussian@threads=3",
        ] {
            let spec: SolverSpec = s.parse().unwrap();
            assert_eq!(spec.to_string(), s);
        }
    }

    #[test]
    fn threads_param_parses_into_spec() {
        match "adaptive-srht@threads=8".parse::<SolverSpec>().unwrap() {
            SolverSpec::Adaptive { threads, .. } => assert_eq!(threads, Some(8)),
            other => panic!("wrong variant {other:?}"),
        }
        // And the built solver still runs (the override is scoped to the
        // solve call, so this must not leak into the ambient config).
        let p = small_problem(64, 8, 0.5, 9);
        let stop = StopRule::TrueError { x_star: direct::solve(&p), eps: 1e-8 };
        let spec: SolverSpec = "adaptive-gaussian@threads=2".parse().unwrap();
        let sol = spec.build(5).solve(&p, &vec![0.0; 8], &stop);
        assert!(sol.report.converged);
        assert_eq!(sol.report.solver, "adaptive-gaussian@threads=2");
    }

    #[test]
    fn legacy_aliases_parse() {
        assert_eq!(
            "adaptive".parse::<SolverSpec>().unwrap(),
            SolverSpec::Adaptive {
                kind: SketchKind::Gaussian,
                variant: AdaptiveVariant::PolyakFirst,
                threads: None
            }
        );
        assert_eq!(
            "adaptive-gd-srht".parse::<SolverSpec>().unwrap(),
            SolverSpec::Adaptive {
                kind: SketchKind::Srht,
                variant: AdaptiveVariant::GradientOnly,
                threads: None
            }
        );
        assert_eq!(
            "pcg".parse::<SolverSpec>().unwrap(),
            SolverSpec::Pcg { kind: SketchKind::Srht, rho: DEFAULT_PCG_RHO, threads: None }
        );
        assert_eq!(
            "dual".parse::<SolverSpec>().unwrap(),
            SolverSpec::DualAdaptive { kind: SketchKind::Gaussian, threads: None }
        );
    }

    #[test]
    fn bad_specs_rejected() {
        for s in [
            "nope",
            "adaptive-fourier",
            "cg@m=3",
            "ihs-srht@m=0",
            "ihs-srht@m",
            "pcg-srht@rho=-1",
            "cg@threads=2",
            "direct@threads=2",
            "adaptive-srht@threads=0",
            "adaptive-srht@threads=x",
        ] {
            assert!(s.parse::<SolverSpec>().is_err(), "{s:?} should not parse");
        }
    }

    #[test]
    fn built_solver_labels_match_spec_strings() {
        for spec in registry() {
            let solver = spec.build(1);
            assert_eq!(solver.label(), spec.to_string());
        }
    }

    #[test]
    fn direct_wrapper_reports_like_everyone_else() {
        let p = small_problem(64, 8, 0.5, 1);
        let x_star = direct::solve(&p);
        let stop = StopRule::TrueError { x_star: x_star.clone(), eps: 1e-10 };
        let sol = SolverSpec::Direct.build(0).solve(&p, &vec![0.0; 8], &stop);
        assert!(sol.report.converged);
        assert_eq!(sol.report.solver, "direct");
        assert!(sol.report.final_rel_error.unwrap() < 1e-12);
        assert!(sol.report.wall_time_s >= 0.0);
        for i in 0..8 {
            assert!((sol.x[i] - x_star[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn randomized_flag_matches_solver_family() {
        assert!(!SolverSpec::Direct.build(0).is_randomized());
        assert!(!SolverSpec::Cg.build(0).is_randomized());
        for spec in registry() {
            let randomized = !matches!(spec, SolverSpec::Direct | SolverSpec::Cg);
            assert_eq!(spec.build(0).is_randomized(), randomized, "{spec}");
        }
    }

    #[test]
    fn same_seed_same_solution() {
        let p = small_problem(128, 16, 0.5, 2);
        let stop = StopRule::TrueError { x_star: direct::solve(&p), eps: 1e-9 };
        let spec: SolverSpec = "adaptive-srht".parse().unwrap();
        let a = spec.build(42).solve(&p, &vec![0.0; 16], &stop);
        let b = spec.build(42).solve(&p, &vec![0.0; 16], &stop);
        assert_eq!(a.x, b.x);
        assert_eq!(a.report.iterations, b.report.iterations);
    }
}
