//! Error types and recovery accounting for the numerical core.
//!
//! The paper's adaptive algorithm is itself a recovery loop (reject →
//! double `m` → re-sketch → retry), and the serving layer extends that
//! philosophy to *faults*: a failed factorization or a corrupted growth
//! step is met with an escalating **recovery ladder** instead of a panic:
//!
//! 1. **jitter** — retry the Cholesky with escalating diagonal jitter
//!    (already built into
//!    [`crate::linalg::cholesky::Cholesky::factor_with_jitter`]);
//! 2. **resketch** — throw away the offending sketch block and re-apply a
//!    fresh sketch of the same size (a new draw from the solver's RNG
//!    stream);
//! 3. **exact** — fall back to the exact (unsketched) Hessian, the same
//!    path the adaptive solver takes when `m` reaches its cap.
//!
//! The rung that ultimately produced the factorization is recorded in
//! [`crate::solvers::SolveReport::recovery`] and surfaced on the wire, so
//! degraded solves are visible, not silent. Operations that exhaust the
//! ladder return [`SolverError::NumericalBreakdown`]; sessions roll back
//! to their pre-call state and the server answers a structured error.

use std::fmt;

/// Typed error for the solver/session stack. Converts to `String` for the
/// wire layer; the enum split is what the recovery ladder and the chaos
/// tests dispatch on.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SolverError {
    /// A factorization or growth step failed numerically even after the
    /// recovery ladder (jitter → resketch → exact Hessian) was exhausted.
    NumericalBreakdown(String),
    /// The caller passed invalid data (non-positive `nu`, shape mismatch,
    /// non-finite entries, an unsorted path, ...). The operation did not
    /// start; no state was touched.
    InvalidInput(String),
    /// A structural capacity limit was hit (e.g. an SRHT sketch cannot
    /// grow past its padded block dimension).
    Capacity(String),
    /// The per-request wall deadline expired mid-solve. The session rolls
    /// back; the partial iterate is discarded.
    DeadlineExceeded(String),
    /// A panic was caught and converted (fault injection, or a genuine
    /// bug); the session state was restored or rebuilt before returning.
    Internal(String),
}

impl SolverError {
    /// Invalid-input constructor (the most common variant at validation
    /// boundaries).
    pub fn invalid(msg: impl Into<String>) -> Self {
        SolverError::InvalidInput(msg.into())
    }

    /// Numerical-breakdown constructor.
    pub fn breakdown(msg: impl Into<String>) -> Self {
        SolverError::NumericalBreakdown(msg.into())
    }

    /// Build from a caught panic payload (shared by the scheduler's
    /// worker loop, the server's request isolation, and the sessions'
    /// transactional rollback).
    pub fn from_panic(panic: &(dyn std::any::Any + Send)) -> Self {
        SolverError::Internal(panic_message(panic))
    }
}

impl fmt::Display for SolverError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SolverError::NumericalBreakdown(m) => write!(f, "numerical breakdown: {m}"),
            SolverError::InvalidInput(m) => write!(f, "{m}"),
            SolverError::Capacity(m) => write!(f, "capacity: {m}"),
            SolverError::DeadlineExceeded(m) => write!(f, "deadline exceeded: {m}"),
            SolverError::Internal(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for SolverError {}

impl From<SolverError> for String {
    fn from(e: SolverError) -> String {
        e.to_string()
    }
}

impl From<String> for SolverError {
    /// Untyped session/validation errors flow into the typed world as
    /// invalid input (they are all produced by validation boundaries).
    fn from(msg: String) -> Self {
        SolverError::InvalidInput(msg)
    }
}

/// Human-readable payload of a caught panic. `"panic: ..."` prefixed so
/// injected and genuine panics are distinguishable from ordinary errors
/// in logs and wire responses.
pub fn panic_message(panic: &(dyn std::any::Any + Send)) -> String {
    let msg = panic
        .downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| panic.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "worker panicked".into());
    format!("panic: {msg}")
}

/// Which rung of the recovery ladder a solve ultimately used. Ordered:
/// `None < Jitter < Resketch < Exact`, and a report carries the *highest*
/// rung any step of the solve needed.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
pub enum RecoveryRung {
    /// No recovery needed (every factorization succeeded outright).
    #[default]
    None,
    /// A factorization needed nonzero diagonal jitter.
    Jitter,
    /// A sketch block had to be re-applied from a fresh draw.
    Resketch,
    /// The solve fell back to the exact (unsketched) Hessian.
    Exact,
}

impl RecoveryRung {
    /// Wire/report label.
    pub fn label(self) -> &'static str {
        match self {
            RecoveryRung::None => "none",
            RecoveryRung::Jitter => "jitter",
            RecoveryRung::Resketch => "resketch",
            RecoveryRung::Exact => "exact",
        }
    }

    /// Merge: keep the most severe rung seen so far.
    pub fn escalate(&mut self, other: RecoveryRung) {
        if other > *self {
            *self = other;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_shapes() {
        assert_eq!(
            SolverError::breakdown("K not PD").to_string(),
            "numerical breakdown: K not PD"
        );
        assert_eq!(SolverError::invalid("bad nu").to_string(), "bad nu");
        let s: String = SolverError::Capacity("srht cap".into()).into();
        assert!(s.contains("capacity"));
    }

    #[test]
    fn rung_ordering_and_escalation() {
        assert!(RecoveryRung::None < RecoveryRung::Jitter);
        assert!(RecoveryRung::Jitter < RecoveryRung::Resketch);
        assert!(RecoveryRung::Resketch < RecoveryRung::Exact);
        let mut r = RecoveryRung::Jitter;
        r.escalate(RecoveryRung::None);
        assert_eq!(r, RecoveryRung::Jitter);
        r.escalate(RecoveryRung::Exact);
        assert_eq!(r, RecoveryRung::Exact);
        assert_eq!(r.label(), "exact");
    }

    #[test]
    fn panic_payloads_format() {
        let p = std::panic::catch_unwind(|| panic!("boom {}", 7)).unwrap_err();
        assert_eq!(panic_message(&*p), "panic: boom 7");
        assert!(matches!(SolverError::from_panic(&*p), SolverError::Internal(_)));
    }
}
