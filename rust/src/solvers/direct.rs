//! Direct ridge solver: Cholesky on the normal equations
//! `(A^T A + nu^2 I) x = A^T b` — `O(n d^2 + d^3)`.
//!
//! This is the `O(n d^2)` method the paper's introduction rules out at
//! scale; here it provides the ground-truth `x*` every experiment measures
//! errors against, and the small-`d` fallback inside the Woodbury cache.

use super::RidgeProblem;
use crate::linalg::cholesky::Cholesky;

/// Solve exactly. Panics only if the Gram matrix is numerically indefinite
/// even after jitter, which cannot happen for `nu > 0` and finite data.
pub fn solve(problem: &RidgeProblem) -> Vec<f64> {
    let mut gram = problem.a.gram();
    gram.add_diag(problem.nu * problem.nu);
    let (chol, _jitter) =
        Cholesky::factor_with_jitter(&gram, 8).expect("ridge normal equations must be PD");
    chol.solve(&problem.atb)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{norm2, Matrix};
    use crate::solvers::test_util::small_problem;
    use crate::solvers::RidgeProblem;

    #[test]
    fn optimality_conditions() {
        let p = small_problem(128, 16, 0.3, 1);
        let x = solve(&p);
        assert!(norm2(&p.gradient(&x)) < 1e-9);
    }

    #[test]
    fn known_solution_identity_design() {
        // A = I (4x4), b arbitrary: x* = b / (1 + nu^2).
        let a = Matrix::eye(4);
        let b = vec![1.0, -2.0, 3.0, 0.5];
        let nu = 2.0f64;
        let p = RidgeProblem::new(a, b.clone(), nu);
        let x = solve(&p);
        for i in 0..4 {
            assert!((x[i] - b[i] / (1.0 + nu * nu)).abs() < 1e-12);
        }
    }

    #[test]
    fn shrinks_with_regularization() {
        let p1 = small_problem(64, 8, 0.1, 2);
        let mut p2 = p1.clone();
        p2.nu = 10.0;
        let x1 = solve(&p1);
        let x2 = solve(&p2);
        assert!(norm2(&x2) < norm2(&x1));
    }
}
