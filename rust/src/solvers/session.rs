//! Cross-solve sessions: register a problem once, query it many times.
//!
//! The paper's economy — build one effective-dimension-sized sketch, then
//! amortize it over the whole solve — extends across *solves*: the sketch
//! rows `S̃A` depend only on `(A, seed)`, never on the regularization
//! level `nu` or the observations `b`. Lacotte & Pilanci's
//! adaptive-preconditioning follow-up (arXiv:2104.14101) makes this
//! explicit for regularization paths, and the SRHT analysis of Lacotte &
//! Dobriban (arXiv:2002.00864) shows the step-size/quality parameters
//! depend only on `(n, d, m)`. [`ModelSession`] is that reuse as an API:
//!
//! * the data operand lives in one [`Arc<Operand>`] shared by every
//!   per-query [`RidgeProblem`] (no data clone per solve);
//! * the grown [`SketchEngine`](crate::sketch::engine::SketchEngine) and
//!   [`WoodburyCache`](crate::solvers::woodbury::WoodburyCache) survive
//!   between solves as an [`AdaptiveSessionState`]: a repeat query at a
//!   new `nu` performs **zero** sketch application (`sketch_time_s ==
//!   0.0` unless the smaller `nu` forces further growth) and pays only
//!   the `O(m^3)`/`O(d^3)` re-factor of
//!   [`WoodburyCache::set_nu`](crate::solvers::woodbury::WoodburyCache::set_nu);
//! * solves warm-start from the previous solution, batched
//!   regularization paths and alternate right-hand sides reuse the same
//!   state, and exact-repeat queries are answered from a bounded
//!   per-session solution cache (which also makes concurrent identical
//!   queries bitwise-identical);
//! * `A^T b` is computed once at construction and reused for every `nu`.
//!
//! Sessions use the oracle-free [`StopRule::GradientNorm`] criterion —
//! a serving layer cannot afford the `O(n d^2)` exact solve per query
//! that the paper's experimental `TrueError` protocol pays. The `eps`
//! of every query is cold-referenced (`||g|| <= eps * ||A^T b||`), so
//! the convergence target does not depend on where the warm start
//! happened to land (see `run_adaptive`).
//!
//! The coordinator's model registry
//! ([`crate::coordinator::registry::Registry`]) wraps one `ModelSession`
//! per registered model behind a mutex and adds LRU byte-budget eviction.
//!
//! # Transactional semantics
//!
//! Every mutating session call — [`ModelSession::solve`],
//! [`ModelSession::solve_rhs`], [`ModelSession::solve_block`],
//! [`ModelSession::append`] and the pending-row flush — is
//! all-or-nothing. On success the new sketch/factorization state, warm
//! start and caches are committed together; on *any* failure (invalid
//! input, numerical-recovery exhaustion, an expired deadline, or a
//! caught panic) the session is restored to its exact pre-call state,
//! so the next query answers bitwise-identically to a session that
//! never saw the failed call. Failed calls therefore cannot poison a
//! registered model: errors are reported, state is not corrupted.
//! Only the query counters advance on a failed call (failures are
//! still work the session performed).
//!
//! # Snapshots and the lock-free read path
//!
//! [`ModelSession::snapshot`] freezes everything a read-only query needs
//! into an immutable [`SessionSnapshot`]: the shared operand, `A^T b`,
//! the solver state (sketch panel + factorization, shared
//! copy-on-write — see [`AdaptiveSessionState`]), the warm start, and
//! the solution cache, stamped with a monotonically increasing
//! **generation**. The serving layer publishes one snapshot per model
//! through an RCU cell ([`crate::util::rcu::RcuCell`]), so unlimited
//! concurrent readers answer exact-repeat and predict queries without
//! ever touching the session mutex, while writers keep mutating the
//! session under the lock and republish on success. Because the
//! snapshot is built *after* a mutation commits (and never on failure —
//! the transactional rollback above restores the pre-call state, which
//! is exactly what is already published), a half-applied mutation can
//! never be observed through a snapshot: readers see the old generation
//! or the new one, nothing in between.

use super::adaptive::{
    self, AdaptiveConfig, AdaptiveSessionState, AdaptiveSolver, FrozenOutcome,
};
use super::block;
use super::error::{panic_message, SolverError};
use super::woodbury::{GramPanel, WoodburyCache};
use super::{RidgeProblem, Solution, SolveReport, StopRule};
use crate::linalg::{Matrix, Operand};
use crate::sketch::engine::SketchView;
use crate::sketch::SketchKind;
use crate::util::failpoint;
use std::borrow::Cow;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;
use std::time::Instant;

/// Maximum number of `(nu, eps) -> solution` entries retained per session
/// (evicted least-recently-used; each entry is one length-`d` vector plus
/// its report).
pub const SOLUTION_CACHE_CAP: usize = 32;

/// One cached solve keyed by the exact `(nu, eps)` bit patterns. Stored
/// behind an `Arc` so a published [`SessionSnapshot`] shares the vectors
/// with the live cache instead of copying them per publish.
struct CachedSolution {
    nu_bits: u64,
    eps_bits: u64,
    x: Vec<f64>,
    report: SolveReport,
}

/// Staleness policy for [`ModelSession::append`]: when the incremental
/// sketch/factorization update is paid.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AppendRefresh {
    /// Update the sketch and refresh the factorization inside the append
    /// call — queries after the append pay nothing extra.
    Eager,
    /// Defer the update: appended rows accumulate in a pending buffer and
    /// are streamed into the sketch right before the next solve (still
    /// incrementally — retained rows are never re-sketched). Amortizes
    /// the `O(m^3)` factorization refresh across a burst of appends.
    Lazy,
}

/// What [`ModelSession::append`] did.
#[derive(Clone, Copy, Debug)]
pub struct AppendOutcome {
    /// Rows streamed in by this call.
    pub rows_added: usize,
    /// Total rows `n` after the append.
    pub n: usize,
    /// Sketch size `m` (unchanged by appends; 0 before the first solve).
    pub m: usize,
    /// Whether the sketch/factorization was updated inside this call
    /// (eager policy with live state). `false` means the work is deferred
    /// to the next solve — or that there is no state to refresh yet.
    pub refreshed: bool,
}

/// A registered problem plus everything reusable across queries.
///
/// See the [module docs](self) for the reuse contract. A session is
/// single-threaded by design (solves mutate the sketch state); wrap it in
/// a mutex — as [`crate::coordinator::registry::Registry`] does — to
/// serve it from multiple connections.
pub struct ModelSession {
    a: Arc<Operand>,
    b: Vec<f64>,
    /// `A^T b`, computed once — independent of `nu`.
    atb: Vec<f64>,
    config: AdaptiveConfig,
    seed: u64,
    /// Grown sketch + factorization + RNG; `None` until the first solve.
    state: Option<AdaptiveSessionState>,
    /// Rows appended under [`AppendRefresh::Lazy`] that the sketch has
    /// not absorbed yet; flushed incrementally before the next solve.
    /// Only ever `Some` while `state` is `Some` (with no state, a fresh
    /// sketch covers the whole operand anyway).
    pending: Option<Operand>,
    /// Last primary-RHS solution, used to warm-start the next solve.
    warm: Option<Vec<f64>>,
    /// Bounded exact-repeat cache, most recently used last.
    solutions: Vec<Arc<CachedSolution>>,
    /// Snapshot generation: bumped by every [`ModelSession::snapshot`]
    /// call, so each published snapshot carries a strictly increasing
    /// stamp. Per-process only (restarts reset it); persistence and WAL
    /// replay do not carry it.
    generation: u64,
    /// Total solves answered (cache hits included).
    queries: u64,
    /// Queries answered from the solution cache.
    cache_hits: u64,
    /// Counter of state mutations that a WAL replay does **not**
    /// reproduce — bumped by every successful solver run (uncached solve,
    /// alternate-RHS solve, block solve), which consumes RNG draws and
    /// rewrites the warm start. Appends do *not* bump it: an append is
    /// fully captured by the WAL and its replay is bitwise
    /// ([`crate::persist`] snapshots a model again only when `epoch`
    /// moved past the persisted one).
    epoch: u64,
}

impl ModelSession {
    /// Register `(A, b)` with an adaptive solver of the given sketch
    /// family. Fails on underdetermined data (`n < d`) — the dual
    /// reduction has no session path yet — and on shape mismatches.
    pub fn new(
        a: Arc<Operand>,
        b: Vec<f64>,
        kind: SketchKind,
        seed: u64,
    ) -> Result<Self, String> {
        if a.rows() < a.cols() {
            return Err(format!(
                "session needs an overdetermined problem (n {} < d {}); \
                 use a dual-adaptive solve job instead",
                a.rows(),
                a.cols()
            ));
        }
        if a.rows() != b.len() {
            return Err(format!("A has {} rows but b has {} entries", a.rows(), b.len()));
        }
        if b.iter().any(|v| !v.is_finite()) {
            return Err("non-finite entry in b".into());
        }
        let atb = a.matvec_t(&b);
        Ok(Self {
            a,
            b,
            atb,
            config: AdaptiveConfig::new(kind),
            seed,
            state: None,
            pending: None,
            warm: None,
            solutions: Vec::new(),
            generation: 0,
            queries: 0,
            cache_hits: 0,
            epoch: 0,
        })
    }

    /// Rebuild a session from persisted parts ([`crate::persist`]): the
    /// recovered operand/observations/`A^T b`, the sketch family and
    /// solver seed, the replayed solver state (engine + factorization +
    /// RNG, or `None` if the model was snapshotted before its first
    /// solve), the warm-start vector, and the persisted query/epoch
    /// counters. The solution cache starts empty — recovered sessions
    /// answer fresh queries bitwise-identically to the live twin, and
    /// exact-repeat hits re-accumulate from there.
    #[allow(clippy::too_many_arguments)]
    pub fn restore(
        a: Arc<Operand>,
        b: Vec<f64>,
        atb: Vec<f64>,
        kind: SketchKind,
        seed: u64,
        state: Option<AdaptiveSessionState>,
        warm: Option<Vec<f64>>,
        queries: u64,
        epoch: u64,
    ) -> Result<Self, String> {
        let (n, d) = (a.rows(), a.cols());
        if n < d {
            return Err(format!("restored operand is underdetermined (n {n} < d {d})"));
        }
        if b.len() != n {
            return Err(format!("restored b has {} entries, expected n = {n}", b.len()));
        }
        if atb.len() != d {
            return Err(format!("restored atb has {} entries, expected d = {d}", atb.len()));
        }
        if let Some(w) = &warm {
            if w.len() != d {
                return Err(format!("restored warm start has {} entries, expected d = {d}", w.len()));
            }
        }
        Ok(Self {
            a,
            b,
            atb,
            config: AdaptiveConfig::new(kind),
            seed,
            state,
            pending: None,
            warm,
            solutions: Vec::new(),
            generation: 0,
            queries,
            cache_hits: 0,
            epoch,
        })
    }

    /// Stream `Δn` new observations `(delta_a, delta_b)` into the model.
    ///
    /// Everything downstream updates *incrementally* — no retained row is
    /// ever re-sketched and no full re-registration happens:
    ///
    /// * the operand grows by row append (dense stack / CSR concatenation,
    ///   [`Operand::append_rows`]) and the cached `A^T b` is updated at
    ///   `O(Δn d)` (`atb += ΔA^T Δb`);
    /// * the grown sketch absorbs the new rows through
    ///   [`SketchEngine::append_rows`](crate::sketch::engine::SketchEngine::append_rows)
    ///   and the Woodbury factorization is rebuilt from the updated rows
    ///   at the session's last `nu` — either inside this call
    ///   ([`AppendRefresh::Eager`]) or right before the next solve
    ///   ([`AppendRefresh::Lazy`]);
    /// * at the sketch-size cap (exact-Hessian fallback, no engine) the
    ///   cache grows by the `O(Δn d^2)` incremental inner-Gram update
    ///   instead;
    /// * cached solutions are dropped (they answered the old problem),
    ///   while the warm-start vector is kept — the old optimum is a good
    ///   initial iterate after a small append, so the next solve converges
    ///   in fewer iterations than a cold start.
    ///
    /// Counts as an ingest, not a query, in [`ModelSession::query_stats`].
    pub fn append(
        &mut self,
        delta_a: Operand,
        delta_b: Vec<f64>,
        refresh: AppendRefresh,
    ) -> Result<AppendOutcome, String> {
        let dn = delta_a.rows();
        if dn == 0 {
            return Err("append needs at least one new row".into());
        }
        if delta_a.cols() != self.d() {
            return Err(format!(
                "appended rows have {} columns, expected d = {}",
                delta_a.cols(),
                self.d()
            ));
        }
        if delta_b.len() != dn {
            return Err(format!(
                "append has {} rows but {} b entries",
                dn,
                delta_b.len()
            ));
        }
        if delta_b.iter().any(|v| !v.is_finite()) {
            return Err("non-finite entry in appended b".into());
        }
        let finite = match &delta_a {
            Operand::Dense(m) => (0..dn).all(|i| m.row(i).iter().all(|v| v.is_finite())),
            Operand::Sparse(c) => (0..dn).all(|i| c.row(i).1.iter().all(|v| v.is_finite())),
        };
        if !finite {
            return Err("non-finite entry in appended rows".into());
        }

        // All-or-nothing from here: snapshot everything the mutation
        // touches, run the mutating body under an unwind guard, and roll
        // back on any error or panic — a failed append leaves the model
        // exactly as it was.
        let n0 = self.n();
        let atb_snapshot = self.atb.clone();
        let pending_snapshot = self.pending.clone();
        let state_snapshot = self.state.clone();
        let solutions_saved = std::mem::take(&mut self.solutions);
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            self.append_commit(&delta_a, &delta_b, refresh)
        }));
        match outcome {
            Ok(Ok(refreshed)) => {
                Ok(AppendOutcome { rows_added: dn, n: self.n(), m: self.m(), refreshed })
            }
            Ok(Err(e)) => {
                self.rollback_append(n0, atb_snapshot, pending_snapshot, state_snapshot);
                self.solutions = solutions_saved;
                Err(e.into())
            }
            Err(panic) => {
                self.rollback_append(n0, atb_snapshot, pending_snapshot, state_snapshot);
                self.solutions = solutions_saved;
                Err(SolverError::Internal(panic_message(&*panic)).into())
            }
        }
    }

    /// The mutating body of [`ModelSession::append`]; inputs are already
    /// validated and the caller holds the rollback snapshot.
    fn append_commit(
        &mut self,
        delta_a: &Operand,
        delta_b: &[f64],
        refresh: AppendRefresh,
    ) -> Result<bool, SolverError> {
        // Normalize the delta to the operand's storage kind before ANY
        // consumer sees it. The operand merge converts on append anyway
        // ([`Operand::append_rows`] follows the receiver), but the sketch
        // engine's bitwise-replay contract
        // ([`crate::sketch::engine::SketchEngine::from_replay`]) re-derives
        // `S̃A` by slicing rows back out of the *stored* operand — so the
        // live engine must consume the delta in the stored kind too, or
        // the dense-GEMM and CSR-axpy accumulation orders diverge and
        // recovery is no longer bitwise.
        let delta_a: Cow<'_, Operand> = match (&*self.a, delta_a) {
            (Operand::Dense(_), Operand::Sparse(dc)) => {
                Cow::Owned(Operand::Dense(dc.to_dense()))
            }
            (Operand::Sparse(_), Operand::Dense(dm)) => {
                Cow::Owned(Operand::Sparse(crate::linalg::sparse::CsrMatrix::from_dense(dm)))
            }
            _ => Cow::Borrowed(delta_a),
        };
        let delta_a: &Operand = &delta_a;
        // O(Δn d) bookkeeping: atb += ΔA^T Δb, then grow the operand and
        // observations in place.
        delta_a.matvec_t_add(delta_b, &mut self.atb);
        self.b.extend_from_slice(delta_b);
        // Queue the delta for the sketch before growing the operand (the
        // engine needs exactly the new rows). With no solver state yet
        // there is nothing to refresh — the first solve sketches the full
        // grown operand from scratch.
        if self.state.is_some() {
            match &mut self.pending {
                Some(p) => p.append_rows(delta_a),
                None => self.pending = Some(delta_a.clone()),
            }
        }
        Arc::make_mut(&mut self.a).append_rows(delta_a);
        // (Cached solutions answered the pre-append problem; the caller
        // already moved them out and drops them on success.)
        failpoint::check("session.append").map_err(SolverError::Internal)?;
        if refresh == AppendRefresh::Eager {
            self.flush_pending()?;
        }
        Ok(refresh == AppendRefresh::Eager && self.state.is_some() && self.pending.is_none())
    }

    /// Undo the mutations of a failed [`ModelSession::append_commit`]:
    /// shrink the operand and observations back to `n0` rows
    /// ([`Operand::truncate_rows`] is the bitwise-exact inverse of the
    /// append) and restore the cached `A^T b`, pending buffer and solver
    /// state from the pre-call snapshot.
    fn rollback_append(
        &mut self,
        n0: usize,
        atb: Vec<f64>,
        pending: Option<Operand>,
        state: Option<AdaptiveSessionState>,
    ) {
        if self.n() > n0 {
            Arc::make_mut(&mut self.a).truncate_rows(n0);
        }
        self.b.truncate(n0);
        self.atb = atb;
        self.pending = pending;
        self.state = state;
    }

    /// Absorb pending appended rows into the sketch/factorization —
    /// incrementally: the engine streams only the pending `Δn` rows
    /// ([`SketchEngine::append_rows`](crate::sketch::engine::SketchEngine::append_rows)),
    /// then the Woodbury cache is rebuilt from the updated `S̃A` at the
    /// cached `nu` (every entry changed additively, so the old Gram is
    /// not reusable — but no sketch application is repeated). At the cap
    /// (no engine) the exact-Hessian cache takes the `O(Δn d^2)`
    /// incremental grow instead.
    /// Transactional: the new state is staged from clones and committed
    /// together with clearing the pending buffer, so a failure (or caught
    /// panic) leaves both exactly as they were. A *numerical* failure of
    /// the incremental absorb takes the session-level recovery rung
    /// instead of erroring: the resumable state is dropped and the next
    /// solve re-sketches the grown operand from scratch (the appended
    /// rows already live in the operand, so no data is lost). Injected
    /// (`Internal`) and invalid-input failures propagate un-laddered.
    fn flush_pending(&mut self) -> Result<(), SolverError> {
        if self.pending.is_none() {
            return Ok(());
        }
        if self.state.is_none() {
            // No live sketch: the next solve sketches the full grown
            // operand, delta included.
            self.pending = None;
            return Ok(());
        }
        failpoint::check("session.flush").map_err(SolverError::Internal)?;
        let staged = self.state.clone().expect("checked above");
        let delta = self.pending.clone().expect("checked above");
        let outcome = catch_unwind(AssertUnwindSafe(
            || -> Result<AdaptiveSessionState, SolverError> {
                let (engine, cache, mut rng) = staged.into_parts();
                match engine {
                    Some(mut e) => {
                        e.append_rows(&delta, &mut rng)?;
                        let cache = WoodburyCache::new_scaled(
                            e.sa_unnormalized().clone(),
                            cache.nu(),
                            e.scale(),
                        )?;
                        Ok(AdaptiveSessionState::from_parts(Some(e), cache, rng))
                    }
                    None => {
                        // Exact-Hessian fallback: the cache rows are A
                        // itself at scale 1 — append the new rows through
                        // the incremental inner-Gram grow.
                        let mut cache = cache;
                        cache.grow(&delta.dense().into_owned(), 1.0)?;
                        Ok(AdaptiveSessionState::from_parts(None, cache, rng))
                    }
                }
            },
        ));
        match outcome {
            Ok(Ok(new_state)) => {
                self.state = Some(new_state);
                self.pending = None;
                Ok(())
            }
            Ok(Err(e @ (SolverError::InvalidInput(_) | SolverError::Internal(_)))) => Err(e),
            Ok(Err(_)) | Err(_) => {
                // Session-level re-sketch rung: drop the resumable state;
                // the rows are safe in the operand and the next solve
                // rebuilds the sketch over all of them.
                self.state = None;
                self.pending = None;
                Ok(())
            }
        }
    }

    /// The shared data operand.
    pub fn operand(&self) -> &Arc<Operand> {
        &self.a
    }

    /// Rows `n` of the registered data.
    pub fn n(&self) -> usize {
        self.a.rows()
    }

    /// Columns `d` of the registered data.
    pub fn d(&self) -> usize {
        self.a.cols()
    }

    /// Current cached sketch size (0 before the first solve).
    pub fn m(&self) -> usize {
        self.state.as_ref().map_or(0, AdaptiveSessionState::m)
    }

    /// Sketch family this session grows.
    pub fn kind(&self) -> SketchKind {
        self.config.kind
    }

    /// Total solves answered, and how many came from the solution cache.
    pub fn query_stats(&self) -> (u64, u64) {
        (self.queries, self.cache_hits)
    }

    /// The registered observations `b` (grown by appends).
    pub fn b(&self) -> &[f64] {
        &self.b
    }

    /// The cached `A^T b` — accumulated incrementally across appends, so
    /// its exact bit pattern is history-dependent; persistence stores the
    /// bytes verbatim rather than recomputing
    /// ([`crate::persist`]).
    pub fn atb(&self) -> &[f64] {
        &self.atb
    }

    /// The solver seed the session was registered with.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// The warm-start vector left by the last primary-RHS solve.
    pub fn warm(&self) -> Option<&[f64]> {
        self.warm.as_deref()
    }

    /// The live solver state (sketch engine + factorization + RNG), if
    /// the session has solved at least once.
    pub fn state(&self) -> Option<&AdaptiveSessionState> {
        self.state.as_ref()
    }

    /// The `(nu, eps)` bit-pattern keys of the cached solutions, least
    /// recently used first. Snapshots persist the keys (not the vectors):
    /// they are cheap, and a recovering server can see which operating
    /// points the model served without carrying stale answers across a
    /// restart.
    pub fn solution_keys(&self) -> Vec<(u64, u64)> {
        self.solutions.iter().map(|s| (s.nu_bits, s.eps_bits)).collect()
    }

    /// Mutation epoch: how many solver runs (not appends) have changed
    /// state that only a fresh snapshot can capture. A model is *dirty*
    /// when its epoch is ahead of the last persisted one; appends leave
    /// the epoch alone because the WAL replays them bitwise.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Snapshot generation of the *next* [`ModelSession::snapshot`] call
    /// minus one — i.e. how many snapshots this session has produced.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Freeze the session's current read-only view into an immutable
    /// [`SessionSnapshot`], bumping the generation stamp.
    ///
    /// The snapshot is **O(1)** in the heavy state: the operand, sketch
    /// panel, factorization and cached solution vectors are shared via
    /// `Arc` (copy-on-write on the session side — see
    /// [`AdaptiveSessionState`]), so publishing after every committed
    /// mutation is cheap. Only `A^T b` and the warm start (two length-`d`
    /// vectors) are copied.
    ///
    /// Callers publish the returned `Arc` through
    /// [`crate::util::rcu::RcuCell`] *after* the mutation that produced
    /// it commits; the transactional rollback contract (module docs)
    /// then guarantees no partial state is ever published.
    pub fn snapshot(&mut self) -> Arc<SessionSnapshot> {
        self.generation += 1;
        Arc::new(SessionSnapshot {
            generation: self.generation,
            config: self.config.clone(),
            a: Arc::clone(&self.a),
            atb: self.atb.clone(),
            state: self.state.clone(),
            warm: self.warm.clone(),
            solutions: self.solutions.clone(),
            pending: self.pending.is_some(),
        })
    }

    /// Absorb any lazily appended rows into the sketch/factorization now
    /// — the public hook used before snapshotting or spilling a model
    /// ([`crate::persist`]). Bitwise-neutral with respect to a twin that
    /// flushed at its next solve instead: the engine consumes the same
    /// pending rows with the same RNG state either way, so flushing early
    /// never forks the stream.
    pub fn flush_appended(&mut self) -> Result<(), String> {
        self.flush_pending().map_err(|e| e.into())
    }

    /// Set (or clear) the wall-clock deadline for subsequent solves on
    /// this session. The deadline is cooperative: the adaptive and block
    /// solvers check it between accepted iterations and growth rounds
    /// and return a structured `deadline exceeded` error once past it —
    /// with the session state rolled back exactly as for any other
    /// failed call. Cache hits are unaffected (they run no solver).
    pub fn set_deadline(&mut self, deadline: Option<Instant>) {
        self.config.deadline = deadline;
    }

    /// Approximate heap footprint in bytes: operand + observations +
    /// warm-start vector + session sketch/factor state + cached
    /// solutions (each charged for its vectors *and* the fixed scalar
    /// footprint of its entry — key bits plus the inline [`SolveReport`]
    /// counters/label). Registries charge this against their byte
    /// budget; undercounting here means the LRU budget admits more live
    /// state than the operator configured.
    pub fn approx_bytes(&self) -> usize {
        let f64s = std::mem::size_of::<f64>();
        let operand = operand_bytes(&self.a)
            + self.pending.as_ref().map_or(0, operand_bytes);
        let cached: usize =
            self.solutions.iter().map(|s| cached_entry_bytes(s)).sum();
        operand
            + (self.b.len() + self.atb.len()) * f64s
            + self.warm.as_ref().map_or(0, |w| w.len() * f64s)
            + self.state.as_ref().map_or(0, AdaptiveSessionState::approx_bytes)
            + cached
    }

    /// Solve at `nu` to gradient-norm tolerance `eps`, reusing the grown
    /// sketch, the factorization cache, and the previous solution as a
    /// warm start. Exact repeats (same `(nu, eps)` bit patterns) are
    /// answered from the solution cache without running the solver at
    /// all, so they are bitwise-reproducible.
    pub fn solve(&mut self, nu: f64, eps: f64) -> Result<Solution, String> {
        check_nu_eps(nu, eps)?;
        self.queries += 1;
        if let Some(idx) = self
            .solutions
            .iter()
            .position(|s| s.nu_bits == nu.to_bits() && s.eps_bits == eps.to_bits())
        {
            // Refresh LRU position and answer from the cache.
            let hit = self.solutions.remove(idx);
            let sol = Solution { x: hit.x.clone(), report: hit.report.clone() };
            self.solutions.push(hit);
            self.cache_hits += 1;
            return Ok(sol);
        }

        let problem =
            RidgeProblem::from_parts(Arc::clone(&self.a), None, self.atb.clone(), nu);
        let x0 = self.warm.clone().unwrap_or_else(|| vec![0.0; problem.d()]);
        let sol = self.run_adaptive(&problem, &x0, eps)?;

        self.warm = Some(sol.x.clone());
        self.solutions.push(Arc::new(CachedSolution {
            nu_bits: nu.to_bits(),
            eps_bits: eps.to_bits(),
            x: sol.x.clone(),
            report: sol.report.clone(),
        }));
        if self.solutions.len() > SOLUTION_CACHE_CAP {
            self.solutions.remove(0);
        }
        Ok(sol)
    }

    /// Batched regularization path: one warm-started solve per `nu`
    /// (strictly decreasing, matching [`crate::solvers::path`]'s
    /// convention), all through the same cached sketch state.
    pub fn solve_path(&mut self, nus: &[f64], eps: f64) -> Result<Vec<Solution>, String> {
        if nus.is_empty() {
            return Err("empty nu list".into());
        }
        // Validate the whole request before ANY solve runs: a NaN slips
        // past the pairwise ordering check below (`w[0] <= w[1]` is false
        // when either side is NaN) and would otherwise only fail inside
        // `check_nu_eps` mid-path, after earlier points already mutated
        // the session's sketch/warm-start state.
        for &nu in nus {
            check_nu_eps(nu, eps)?;
        }
        for w in nus.windows(2) {
            if w[0] <= w[1] {
                return Err("path nus must be strictly decreasing".into());
            }
        }
        nus.iter().map(|&nu| self.solve(nu, eps)).collect()
    }

    /// Solve at `nu` against an alternate right-hand side. The sketch and
    /// factorization caches apply unchanged (they depend only on `A`);
    /// the warm start and solution cache do not (different objective), so
    /// the solve starts from zero and is not cached.
    pub fn solve_rhs(&mut self, nu: f64, b: &[f64], eps: f64) -> Result<Solution, String> {
        check_nu_eps(nu, eps)?;
        if b.len() != self.n() {
            return Err(format!("b has {} entries, expected n = {}", b.len(), self.n()));
        }
        if b.iter().any(|v| !v.is_finite()) {
            return Err("non-finite entry in b".into());
        }
        self.queries += 1;
        let atb = self.a.matvec_t(b);
        let problem = RidgeProblem::from_parts(Arc::clone(&self.a), None, atb, nu);
        let x0 = vec![0.0; problem.d()];
        Ok(self.run_adaptive(&problem, &x0, eps)?)
    }

    /// Solve at `nu` against a *batch* of `k` alternate right-hand sides
    /// in one block pass ([`crate::solvers::block`]): the gradient and
    /// preconditioner applications run as `d x k` block products (GEMM /
    /// SpMM) instead of `k` matvec sweeps, all columns share the
    /// session's grown sketch (a resumed batch applies **zero** fresh
    /// sketch — `sketch_time_s == 0.0` unless growth was forced), and
    /// each column stops at the same cold-referenced criterion a
    /// [`ModelSession::solve_rhs`] call would use
    /// (`||g_j|| <= eps * ||A^T b_j||`). Converged columns retire from
    /// the iteration immediately. Returns one [`Solution`] per input, in
    /// order; like `solve_rhs`, the batch starts from zero and is not
    /// cached (the warm start and solution cache belong to the
    /// registered `b`). Counts `k` solves in [`ModelSession::query_stats`].
    pub fn solve_block(
        &mut self,
        nu: f64,
        bs: &[Vec<f64>],
        eps: f64,
    ) -> Result<Vec<Solution>, String> {
        check_nu_eps(nu, eps)?;
        if bs.is_empty() {
            return Err("empty right-hand-side batch".into());
        }
        let n = self.n();
        for (j, b) in bs.iter().enumerate() {
            if b.len() != n {
                return Err(format!("rhs {j} has {} entries, expected n = {n}", b.len()));
            }
            if b.iter().any(|v| !v.is_finite()) {
                return Err(format!("non-finite entry in rhs {j}"));
            }
        }
        self.queries += bs.len() as u64;
        // Lazily appended rows must be in the sketch before the state can
        // resume (same contract as `run_adaptive`).
        self.flush_pending()?;
        // One SpMM forms every A^T b_j at once; column j then feeds
        // column j's cold-referenced stop target.
        let k = bs.len();
        let mut bmat = Matrix::zeros(n, k);
        for (j, b) in bs.iter().enumerate() {
            for (i, &v) in b.iter().enumerate() {
                bmat.set(i, j, v);
            }
        }
        let atb = self.a.matmul_t(&bmat);
        // Transactional: snapshot the resumable state; on any failure
        // (structured error or caught panic) restore it, so a failed
        // batch cannot poison the model — the next query resumes the
        // exact pre-call sketch/factorization.
        let snapshot = self.state.clone();
        let taken = self.state.take();
        let config = self.config.clone();
        let seed = self.seed;
        let a = &self.a;
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            block::solve_block(a, nu, &atb, eps, &config, taken, seed)
        }));
        match outcome {
            Ok(Ok(out)) => {
                self.state = Some(out.state);
                self.epoch += 1;
                Ok(out.solutions)
            }
            Ok(Err(e)) => {
                self.state = snapshot;
                Err(e.into())
            }
            Err(panic) => {
                self.state = snapshot;
                Err(SolverError::Internal(panic_message(&*panic)).into())
            }
        }
    }

    /// Predict on new rows (each of length `d`): returns `row · x(nu)`
    /// per row, solving at `(nu, eps)` first if that solution is not
    /// already cached.
    pub fn predict(
        &mut self,
        nu: f64,
        rows: &[Vec<f64>],
        eps: f64,
    ) -> Result<Vec<f64>, String> {
        let d = self.d();
        for (i, row) in rows.iter().enumerate() {
            if row.len() != d {
                return Err(format!("predict row {i} has {} entries, expected d = {d}", row.len()));
            }
            if row.iter().any(|v| !v.is_finite()) {
                return Err(format!("non-finite entry in predict row {i}"));
            }
        }
        let sol = self.solve(nu, eps)?;
        Ok(rows.iter().map(|row| crate::linalg::dot(row, &sol.x)).collect())
    }

    /// Run one adaptive solve, resuming from (and then re-depositing) the
    /// session state.
    ///
    /// The gradient-norm stop is *cold-referenced*: `eps` always means
    /// `||g|| <= eps * ||A^T b||` — the criterion a from-zero solve with
    /// `GradientNorm { tol: eps }` would use — regardless of the warm
    /// start. The raw rule measures `||g||` relative to the gradient at
    /// `x0`; warm starts sit near an optimum where that gradient is
    /// almost zero, which would make the target history-dependent and
    /// effectively unattainable (the solver would grow to the cap and
    /// spin to `max_iters`). Rescaling the tolerance by
    /// `||A^T b|| / ||g(x0)||` pins the absolute target instead.
    fn run_adaptive(
        &mut self,
        problem: &RidgeProblem,
        x0: &[f64],
        eps: f64,
    ) -> Result<Solution, SolverError> {
        // Lazily appended rows must be in the sketch before the state can
        // resume (the engine's n must match the grown problem).
        self.flush_pending()?;
        // Cold starts need no rescale: g(0) = -A^T b, so the raw relative
        // rule already measures against `cold_scale` and the extra O(nnz)
        // gradient pass is skipped. Warm starts pay one extra gradient to
        // pin the absolute target — cheap next to the solve itself.
        let tol = if x0.iter().all(|&v| v == 0.0) {
            eps
        } else {
            let g0_norm = crate::linalg::norm2(&problem.gradient(x0));
            let cold_scale = crate::linalg::norm2(&problem.atb);
            if g0_norm > 0.0 && cold_scale > 0.0 {
                eps * cold_scale / g0_norm
            } else {
                // g(x0) == 0: x0 is already optimal and any tolerance
                // stops immediately; degenerate atb keeps the plain
                // relative rule.
                eps
            }
        };
        let stop = StopRule::GradientNorm { tol };
        // Transactional: snapshot the resumable state before the solver
        // consumes it; restore on any failure (structured error or
        // caught panic) so the next query resumes the exact pre-call
        // sketch/factorization instead of a possibly-inconsistent pair.
        let snapshot = self.state.clone();
        let taken = self.state.take();
        let config = self.config.clone();
        let seed = self.seed;
        let outcome = catch_unwind(AssertUnwindSafe(
            || -> Result<(Solution, AdaptiveSessionState), SolverError> {
                let solver = match taken {
                    Some(state) => AdaptiveSolver::resume(problem, x0, config, stop, state)?,
                    None => AdaptiveSolver::new(problem, x0, config, stop, seed)?,
                };
                solver.run_with_state()
            },
        ));
        match outcome {
            Ok(Ok((sol, state))) => {
                self.state = Some(state);
                self.epoch += 1;
                Ok(sol)
            }
            Ok(Err(e)) => {
                self.state = snapshot;
                Err(e)
            }
            Err(panic) => {
                self.state = snapshot;
                Err(SolverError::Internal(panic_message(&*panic)))
            }
        }
    }
}

/// An immutable, shareable view of a [`ModelSession`] at one committed
/// point in time — what the serving layer publishes through
/// [`crate::util::rcu::RcuCell`] so readers answer without the session
/// mutex.
///
/// A snapshot never mutates: its answers are exactly the answers the
/// session would have given at the generation it was taken (bitwise —
/// the cached vectors are the very `Arc`s the session holds), and they
/// stay that way no matter how far the live session moves on. Queries it
/// cannot answer read-only (an uncached `(nu, eps)`, an alternate RHS, a
/// batch, anything that must run the solver) return `None`; the caller
/// falls back to the locked writer path.
pub struct SessionSnapshot {
    generation: u64,
    /// The session's solver configuration at publish time — the frozen
    /// lane reruns the *same* adaptive iteration the writer would, so it
    /// needs the identical parameters, not just the sketch family.
    config: AdaptiveConfig,
    a: Arc<Operand>,
    /// `A^T b` as of this generation (appends change it).
    atb: Vec<f64>,
    /// Solver state sharing the sketch panel + factorization with the
    /// session copy-on-write (see [`AdaptiveSessionState`]).
    state: Option<AdaptiveSessionState>,
    warm: Option<Vec<f64>>,
    /// The exact-repeat cache as of this generation, LRU order. Entries
    /// are shared with the live session; no vector is copied at publish.
    solutions: Vec<Arc<CachedSolution>>,
    /// Whether lazily appended rows were awaiting a flush at publish
    /// time. A pending snapshot cannot run the frozen lane: the panel it
    /// pins does not cover those rows, so a frozen answer would diverge
    /// from the writer lane (which flushes before solving).
    pending: bool,
}

impl SessionSnapshot {
    /// The strictly increasing stamp [`ModelSession::snapshot`] assigned.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Rows `n` of the data as of this generation.
    pub fn n(&self) -> usize {
        self.a.rows()
    }

    /// Columns `d` of the data.
    pub fn d(&self) -> usize {
        self.a.cols()
    }

    /// Sketch size `m` as of this generation (0 before the first solve).
    pub fn m(&self) -> usize {
        self.state.as_ref().map_or(0, AdaptiveSessionState::m)
    }

    /// Sketch family of the underlying session.
    pub fn kind(&self) -> SketchKind {
        self.config.kind
    }

    /// Whether lazily appended rows were awaiting a flush at publish
    /// time (the frozen lane refuses such snapshots — see
    /// [`SessionSnapshot::solve_frozen`]).
    pub fn pending(&self) -> bool {
        self.pending
    }

    /// The pinned immutable Gram panel, if the session had solved by
    /// this generation — the artifact concurrent readers derive per-`nu`
    /// factorizations from ([`GramPanel::factor`] is pure).
    pub fn panel(&self) -> Option<&Arc<GramPanel>> {
        self.state.as_ref().map(AdaptiveSessionState::panel)
    }

    /// The frozen sketch-layer metadata, if the session had solved by
    /// this generation and growth had not yet hit the cap (at cap the
    /// panel holds the exact Hessian and no view exists).
    pub fn view(&self) -> Option<SketchView> {
        self.state.as_ref().and_then(AdaptiveSessionState::view)
    }

    /// `A^T b` as of this generation.
    pub fn atb(&self) -> &[f64] {
        &self.atb
    }

    /// The frozen solver state, if the session had solved by this
    /// generation.
    pub fn state(&self) -> Option<&AdaptiveSessionState> {
        self.state.as_ref()
    }

    /// The warm-start vector as of this generation.
    pub fn warm(&self) -> Option<&[f64]> {
        self.warm.as_deref()
    }

    /// `(nu, eps)` bit-pattern keys cached at this generation, LRU first.
    pub fn solution_keys(&self) -> Vec<(u64, u64)> {
        self.solutions.iter().map(|s| (s.nu_bits, s.eps_bits)).collect()
    }

    /// Answer an exact-repeat query (`(nu, eps)` bitwise equal to a
    /// cached solve) without any lock or solver run. `None` means this
    /// generation has no cached answer — fall back to the writer path.
    ///
    /// Unlike [`ModelSession::solve`]'s hit path this does not reorder
    /// the LRU or bump session counters (the snapshot is immutable);
    /// the serving layer counts snapshot hits on its own atomics.
    pub fn cached(&self, nu: f64, eps: f64) -> Option<Solution> {
        // Iterate newest-first: identical keys cannot coexist in the
        // cache, so order only matters for mechanical sympathy (recent
        // keys are the likely repeats).
        self.solutions
            .iter()
            .rev()
            .find(|s| s.nu_bits == nu.to_bits() && s.eps_bits == eps.to_bits())
            .map(|s| Solution { x: s.x.clone(), report: s.report.clone() })
    }

    /// Answer a predict query from the cached solution at `(nu, eps)`.
    ///
    /// `None` means the solution is not cached at this generation (the
    /// caller must take the writer path, which solves first). `Some(Err)`
    /// is a definitive input error — the same row validation
    /// [`ModelSession::predict`] performs, so falling through to the
    /// writer path would produce the identical message.
    pub fn predict_cached(
        &self,
        nu: f64,
        rows: &[Vec<f64>],
        eps: f64,
    ) -> Option<Result<Vec<f64>, String>> {
        let sol = self.cached(nu, eps)?;
        let d = self.d();
        for (i, row) in rows.iter().enumerate() {
            if row.len() != d {
                return Some(Err(format!(
                    "predict row {i} has {} entries, expected d = {d}",
                    row.len()
                )));
            }
            if row.iter().any(|v| !v.is_finite()) {
                return Some(Err(format!("non-finite entry in predict row {i}")));
            }
        }
        Some(Ok(rows.iter().map(|row| crate::linalg::dot(row, &sol.x)).collect()))
    }

    /// Run a full *uncached* solve at `(nu, eps)` against this
    /// snapshot's pinned artifacts — no lock, no mutation, no growth.
    ///
    /// This is the frozen read lane: the panel `Arc` and [`SketchView`]
    /// are immutable, [`GramPanel::factor`] is pure, and the iteration
    /// ([`adaptive::solve_frozen`]) replicates the writer lane's
    /// arithmetic operation-for-operation — including the
    /// cold-referenced tolerance rescale of `ModelSession::run_adaptive`
    /// and the warm start as of this generation — so a frozen answer is
    /// **bitwise** the answer the mutex lane would have produced from
    /// the same generation. Results are NOT inserted into the solution
    /// cache and the warm start is NOT advanced: the writer lane owns
    /// all cache/warm-start mutation; the serving layer counts frozen
    /// solves on its own atomics.
    ///
    /// Returns:
    /// * `None` — this snapshot cannot serve the frozen lane at all: no
    ///   solver state yet (the sketch does not exist before the first
    ///   solve) or lazily appended rows were pending at publish time
    ///   (the pinned panel does not cover them). Take the writer path.
    /// * `Some(Err(msg))` — definitive input error, byte-identical to
    ///   the message the writer path would produce (invalid `nu`/`eps`,
    ///   or an expired `deadline`); falling through would duplicate
    ///   work for the same answer.
    /// * `Some(Ok(FrozenOutcome::Solved(sol)))` — done, lock-free.
    /// * `Some(Ok(FrozenOutcome::NeedsGrowth { .. }))` — the frozen `m`
    ///   is insufficient for this `nu`'s effective dimension (or the
    ///   pure re-key failed and the recovery ladder is needed); fall
    ///   back to the mutex lane, which owns growth and recovery.
    pub fn solve_frozen(
        &self,
        nu: f64,
        eps: f64,
        deadline: Option<Instant>,
    ) -> Option<Result<FrozenOutcome, String>> {
        if self.pending {
            return None;
        }
        let state = self.state.as_ref()?;
        if let Err(e) = check_nu_eps(nu, eps) {
            return Some(Err(e));
        }
        let problem =
            RidgeProblem::from_parts(Arc::clone(&self.a), None, self.atb.clone(), nu);
        let x0 = self.warm.clone().unwrap_or_else(|| vec![0.0; problem.d()]);
        // Mirror `ModelSession::run_adaptive`'s cold-referenced rescale
        // exactly: `eps` always means `||g|| <= eps * ||A^T b||`.
        let tol = if x0.iter().all(|&v| v == 0.0) {
            eps
        } else {
            let g0_norm = crate::linalg::norm2(&problem.gradient(&x0));
            let cold_scale = crate::linalg::norm2(&problem.atb);
            if g0_norm > 0.0 && cold_scale > 0.0 {
                eps * cold_scale / g0_norm
            } else {
                eps
            }
        };
        let stop = StopRule::GradientNorm { tol };
        let mut config = self.config.clone();
        config.deadline = deadline;
        let view = state.view();
        let outcome = adaptive::solve_frozen(
            &problem,
            &x0,
            &config,
            &stop,
            state.panel(),
            view.as_ref(),
        );
        Some(match outcome {
            Ok(out) => Ok(out),
            // Definitive errors the writer lane would reproduce verbatim
            // (same inputs, same deadline) — surface them directly.
            Err(e @ SolverError::InvalidInput(_))
            | Err(e @ SolverError::DeadlineExceeded(_)) => Err(e.into()),
            // Anything else (numerical breakdown, injected faults) defers
            // to the writer lane, which owns the recovery ladder.
            Err(e) => Ok(FrozenOutcome::NeedsGrowth {
                m: state.m(),
                reason: format!("frozen solve failed ({e}); writer lane owns recovery"),
            }),
        })
    }

    /// Bytes of this snapshot's allocations **not** shared with the live
    /// session, compared allocation-by-allocation (`Arc::ptr_eq`): the
    /// extra footprint a registry must charge for keeping this snapshot
    /// published after the writer moved on. A snapshot taken from the
    /// current session state costs only its two owned length-`d` vectors
    /// (`A^T b` + warm start); after a writer-lane re-key it additionally
    /// retains its own `NuFactor`; after a grow, the whole pre-growth
    /// panel and engine; after cache eviction, the evicted solution
    /// vectors. Each allocation is charged once no matter how many `Arc`
    /// clones of it exist.
    pub fn retained_bytes(&self, live: &ModelSession) -> usize {
        let f64s = std::mem::size_of::<f64>();
        let mut extra = self.atb.len() * f64s
            + self.warm.as_ref().map_or(0, |w| w.len() * f64s);
        if !Arc::ptr_eq(&self.a, &live.a) {
            extra += operand_bytes(&self.a);
        }
        if let Some(state) = &self.state {
            extra += state.bytes_not_shared_with(live.state.as_ref());
        }
        for s in &self.solutions {
            let shared = live.solutions.iter().any(|l| Arc::ptr_eq(s, l));
            if !shared {
                extra += cached_entry_bytes(s);
            }
        }
        extra
    }
}

/// Heap bytes of one solution-cache entry: its vectors plus the fixed
/// scalar footprint (key bits + inline [`SolveReport`] counters/label).
/// Shared by [`ModelSession::approx_bytes`] and
/// [`SessionSnapshot::retained_bytes`] so live and retained entries are
/// charged by the same formula.
fn cached_entry_bytes(s: &CachedSolution) -> usize {
    let f64s = std::mem::size_of::<f64>();
    (s.x.len() + s.report.error_trace.len()) * f64s
        + s.report.m_trace.len() * std::mem::size_of::<usize>()
        + s.report.solver.len()
        + std::mem::size_of::<CachedSolution>()
}

/// Heap bytes of an operand's storage (dense entries, or CSR values +
/// column indices + row pointers).
fn operand_bytes(op: &Operand) -> usize {
    let f64s = std::mem::size_of::<f64>();
    match op {
        Operand::Dense(m) => m.rows() * m.cols() * f64s,
        Operand::Sparse(c) => c.nnz() * (f64s + 4) + (c.rows() + 1) * f64s,
    }
}

fn check_nu_eps(nu: f64, eps: f64) -> Result<(), String> {
    if !(nu > 0.0 && nu.is_finite()) {
        return Err(format!("nu must be positive and finite, got {nu}"));
    }
    if !(eps > 0.0 && eps.is_finite()) {
        return Err(format!("eps must be positive and finite, got {eps}"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::solvers::direct;

    fn session(n: usize, d: usize, seed: u64) -> ModelSession {
        let ds = synthetic::exponential_decay(n, d, seed);
        ModelSession::new(Arc::new(ds.a), ds.b, SketchKind::Gaussian, 7).unwrap()
    }

    fn exact(sess: &ModelSession, nu: f64) -> Vec<f64> {
        let p = RidgeProblem::from_parts(
            Arc::clone(sess.operand()),
            None,
            sess.operand().matvec_t(&sess.b),
            nu,
        );
        direct::solve(&p)
    }

    #[test]
    fn repeat_nu_query_reuses_sketch_without_reapplying() {
        let mut s = session(256, 32, 1);
        let first = s.solve(0.5, 1e-9).unwrap();
        assert!(first.report.converged);
        let m_after_first = s.m();
        assert!(m_after_first >= 1);
        // Second query at a *larger* nu (smaller effective dimension): the
        // cached m suffices, so no sketch work at all and no growth.
        let second = s.solve(1.0, 1e-9).unwrap();
        assert!(second.report.converged);
        assert_eq!(second.report.sketch_time_s, 0.0, "resumed solve re-applied the sketch");
        assert_eq!(second.report.doublings, 0);
        assert_eq!(s.m(), m_after_first, "cached rows must be reused in full");
    }

    #[test]
    fn session_solutions_match_direct() {
        let mut s = session(192, 24, 2);
        for nu in [2.0, 0.7, 0.2] {
            let sol = s.solve(nu, 1e-10).unwrap();
            let x_star = exact(&s, nu);
            let p = RidgeProblem::from_parts(
                Arc::clone(s.operand()),
                None,
                s.operand().matvec_t(&s.b),
                nu,
            );
            let rel = p.prediction_error(&sol.x, &x_star)
                / p.prediction_error(&vec![0.0; 24], &x_star);
            assert!(rel < 1e-6, "nu {nu}: relative error {rel}");
        }
    }

    #[test]
    fn exact_repeat_is_bitwise_identical_via_cache() {
        let mut s = session(128, 16, 3);
        let a = s.solve(0.5, 1e-8).unwrap();
        let (q0, h0) = s.query_stats();
        let b = s.solve(0.5, 1e-8).unwrap();
        let (q1, h1) = s.query_stats();
        assert_eq!(a.x, b.x);
        assert_eq!(q1, q0 + 1);
        assert_eq!(h1, h0 + 1, "exact repeat must come from the solution cache");
    }

    #[test]
    fn path_and_rhs_queries_share_state() {
        let mut s = session(128, 16, 4);
        let sols = s.solve_path(&[1.0, 0.5, 0.1], 1e-8).unwrap();
        assert_eq!(sols.len(), 3);
        assert!(sols.iter().all(|x| x.report.converged));
        let m_after_path = s.m();
        // Alternate RHS at a known nu: no sketch work either.
        let b2: Vec<f64> = (0..128).map(|i| (i as f64 * 0.1).sin()).collect();
        let alt = s.solve_rhs(0.5, &b2, 1e-8).unwrap();
        assert!(alt.report.converged);
        assert_eq!(alt.report.sketch_time_s, 0.0);
        assert!(s.m() >= m_after_path);
        // And the alternate solution actually solves the alternate system.
        let p = RidgeProblem::new_shared(Arc::clone(s.operand()), b2, 0.5);
        let g = p.gradient(&alt.x);
        let scale = crate::linalg::norm2(&p.atb);
        assert!(crate::linalg::norm2(&g) <= 1e-6 * scale);
        // Unsorted paths are rejected.
        assert!(s.solve_path(&[0.1, 1.0], 1e-8).is_err());
    }

    #[test]
    fn predict_matches_manual_dot() {
        let mut s = session(96, 12, 5);
        let rows: Vec<Vec<f64>> =
            (0..3).map(|r| (0..12).map(|j| ((r * 12 + j) as f64 * 0.17).cos()).collect()).collect();
        let y = s.predict(0.8, &rows, 1e-9).unwrap();
        let x = s.solve(0.8, 1e-9).unwrap().x; // cache hit: identical x
        for (i, row) in rows.iter().enumerate() {
            let expect = crate::linalg::dot(row, &x);
            assert!((y[i] - expect).abs() < 1e-12);
        }
        // Wrong-width rows are a clean error.
        assert!(s.predict(0.8, &[vec![1.0; 5]], 1e-9).is_err());
    }

    #[test]
    fn rejects_bad_inputs() {
        let ds = synthetic::exponential_decay(16, 8, 6);
        // Underdetermined registration refused.
        let wide = ds.a.transpose();
        let err = ModelSession::new(Arc::new(wide), ds.b[..8].to_vec(), SketchKind::Srht, 1)
            .unwrap_err();
        assert!(err.contains("overdetermined"), "{err}");
        // Bad query parameters refused.
        let mut s = session(64, 8, 7);
        assert!(s.solve(0.0, 1e-8).is_err());
        assert!(s.solve(1.0, 0.0).is_err());
        assert!(s.solve_rhs(1.0, &[1.0; 3], 1e-8).is_err());
    }

    #[test]
    fn solution_cache_is_bounded() {
        let mut s = session(64, 8, 8);
        for i in 0..(SOLUTION_CACHE_CAP + 10) {
            s.solve(10.0 / (i as f64 + 1.0), 1e-6).unwrap();
        }
        assert!(s.solutions.len() <= SOLUTION_CACHE_CAP);
        assert!(s.approx_bytes() > 0);
    }

    #[test]
    fn approx_bytes_counts_warm_start_and_cached_report_footprint() {
        // Regression for the registry byte-budget undercount: after one
        // solve the session holds the warm-start vector (d f64s), one
        // cached solution vector (d f64s), that entry's fixed scalar
        // footprint, and the grown sketch state — all of which must be
        // charged against the LRU budget.
        let mut s = session(64, 8, 11);
        let before = s.approx_bytes();
        s.solve(0.5, 1e-8).unwrap();
        let after = s.approx_bytes();
        let d_bytes = 8 * std::mem::size_of::<f64>();
        let floor = 2 * d_bytes + std::mem::size_of::<CachedSolution>();
        assert!(
            after >= before + floor,
            "post-solve footprint {after} must grow by at least warm + cached-x + \
             fixed report footprint ({floor}) over {before}"
        );
    }

    #[test]
    fn path_nan_is_rejected_up_front_without_mutating_state() {
        let mut s = session(64, 8, 12);
        // NaN in the middle: the old pairwise check let this through
        // (`w[0] <= w[1]` is false for NaN) and failed mid-path after the
        // first solve had already grown the session.
        let err = s.solve_path(&[1.0, f64::NAN, 0.1], 1e-8).unwrap_err();
        assert!(err.contains("nu"), "{err}");
        assert_eq!(s.m(), 0, "no solve may run before the path validates");
        assert_eq!(s.query_stats().0, 0);
        // Leading and trailing NaN / infinities are rejected identically.
        assert!(s.solve_path(&[f64::NAN, 1.0], 1e-8).is_err());
        assert!(s.solve_path(&[f64::INFINITY, 1.0], 1e-8).is_err());
        assert!(s.solve_path(&[1.0, 0.5, f64::NAN], 1e-8).is_err());
        assert_eq!(s.m(), 0);
        // A valid path still works afterwards.
        assert!(s.solve_path(&[1.0, 0.5], 1e-8).is_ok());
    }

    #[test]
    fn solve_block_matches_looped_solve_rhs() {
        let ds = synthetic::exponential_decay(192, 24, 13);
        let bs: Vec<Vec<f64>> = (0..5)
            .map(|j| (0..192).map(|i| ((i as f64 + 1.0) * (j as f64 + 0.6) * 0.07).sin()).collect())
            .collect();
        let mk = || {
            ModelSession::new(Arc::new(ds.a.clone()), ds.b.clone(), SketchKind::Gaussian, 9)
                .unwrap()
        };
        let mut s_block = mk();
        let sols = s_block.solve_block(0.5, &bs, 1e-12).unwrap();
        assert_eq!(sols.len(), 5);
        let mut s_loop = mk();
        for (j, b) in bs.iter().enumerate() {
            let lone = s_loop.solve_rhs(0.5, b, 1e-12).unwrap();
            assert!(lone.report.converged && sols[j].report.converged, "col {j}");
            let scale = lone.x.iter().fold(1.0f64, |m, v| m.max(v.abs()));
            for i in 0..24 {
                assert!(
                    (sols[j].x[i] - lone.x[i]).abs() <= 1e-10 * scale,
                    "col {j} coord {i}: {} vs {}",
                    sols[j].x[i],
                    lone.x[i]
                );
            }
        }
        // The batch counts k solves.
        assert_eq!(s_block.query_stats().0, 5);
    }

    fn split_last(a: &Matrix, b: &[f64], dn: usize) -> (Matrix, Vec<f64>, Matrix, Vec<f64>) {
        let n = a.rows();
        let base = Matrix::from_fn(n - dn, a.cols(), |i, j| a.get(i, j));
        let delta = Matrix::from_fn(dn, a.cols(), |i, j| a.get(n - dn + i, j));
        (base, b[..n - dn].to_vec(), delta, b[n - dn..].to_vec())
    }

    #[test]
    fn append_matches_fresh_register_of_concatenated_data() {
        // Stream the last Δn rows into a grown session; the answer must
        // match a fresh registration of the full data to solver tolerance.
        let ds = synthetic::exponential_decay(200, 24, 30);
        let full = ds.a.dense().into_owned();
        let (base, b_base, delta, b_delta) = split_last(&full, &ds.b, 8);
        for refresh in [AppendRefresh::Eager, AppendRefresh::Lazy] {
            let mut grown = ModelSession::new(
                Arc::new(Operand::from(base.clone())),
                b_base.clone(),
                SketchKind::Gaussian,
                31,
            )
            .unwrap();
            grown.solve(0.5, 1e-8).unwrap(); // grow the sketch pre-append
            let out = grown
                .append(Operand::from(delta.clone()), b_delta.clone(), refresh)
                .unwrap();
            assert_eq!((out.rows_added, out.n), (8, 200));
            assert_eq!(out.refreshed, refresh == AppendRefresh::Eager);
            let appended = grown.solve(0.5, 1e-12).unwrap();
            assert!(appended.report.converged);

            let mut fresh = ModelSession::new(
                Arc::new(Operand::from(full.clone())),
                ds.b.clone(),
                SketchKind::Gaussian,
                31,
            )
            .unwrap();
            let reregistered = fresh.solve(0.5, 1e-12).unwrap();
            let scale = reregistered.x.iter().fold(1.0f64, |m, v| m.max(v.abs()));
            for i in 0..24 {
                assert!(
                    (appended.x[i] - reregistered.x[i]).abs() <= 1e-10 * scale,
                    "{refresh:?} coord {i}: {} vs {}",
                    appended.x[i],
                    reregistered.x[i]
                );
            }
        }
    }

    #[test]
    fn append_never_resketches_retained_rows() {
        let ds = synthetic::exponential_decay(192, 16, 32);
        let full = ds.a.dense().into_owned();
        let (base, b_base, delta, b_delta) = split_last(&full, &ds.b, 4);
        let mut s = ModelSession::new(
            Arc::new(Operand::from(base)),
            b_base,
            SketchKind::Gaussian,
            33,
        )
        .unwrap();
        s.solve(0.8, 1e-9).unwrap();
        let m_before = s.m();
        s.append(Operand::from(delta), b_delta, AppendRefresh::Eager).unwrap();
        assert_eq!(s.m(), m_before, "append must not change the sketch size");
        let sol = s.solve(0.8, 1e-9).unwrap();
        assert!(sol.report.converged);
        // The resumed solve applies zero fresh sketch unless it *grew* —
        // appended models never pay a full re-sketch of retained rows.
        assert!(
            sol.report.sketch_time_s == 0.0 || sol.report.doublings > 0,
            "sketch work without growth: {}s over {} doublings",
            sol.report.sketch_time_s,
            sol.report.doublings
        );
    }

    #[test]
    fn lazy_append_defers_and_flushes_before_next_solve() {
        let ds = synthetic::exponential_decay(160, 12, 34);
        let full = ds.a.dense().into_owned();
        let (base, b_base, delta, b_delta) = split_last(&full, &ds.b, 6);
        let mut s = ModelSession::new(
            Arc::new(Operand::from(base)),
            b_base,
            SketchKind::Gaussian,
            35,
        )
        .unwrap();
        s.solve(0.6, 1e-8).unwrap();
        let bytes_before = s.approx_bytes();
        let out = s.append(Operand::from(delta), b_delta, AppendRefresh::Lazy).unwrap();
        assert!(!out.refreshed, "lazy append must defer the refresh");
        assert!(s.pending.is_some(), "delta must sit in the pending buffer");
        // The grown operand and pending delta are charged immediately.
        assert!(s.approx_bytes() > bytes_before);
        let sol = s.solve(0.6, 1e-10).unwrap();
        assert!(sol.report.converged);
        assert!(s.pending.is_none(), "solve must flush the pending rows");
        // And the answer solves the grown problem.
        let x_star = exact(&s, 0.6);
        let p = RidgeProblem::from_parts(
            Arc::clone(s.operand()),
            None,
            s.operand().matvec_t(&s.b),
            0.6,
        );
        let rel = p.prediction_error(&sol.x, &x_star)
            / p.prediction_error(&vec![0.0; 12], &x_star);
        assert!(rel < 1e-6, "relative error {rel}");
    }

    #[test]
    fn append_warm_start_cuts_iterations_vs_cold_reregister() {
        let ds = synthetic::exponential_decay(256, 32, 36);
        let full = ds.a.dense().into_owned();
        let (base, b_base, delta, b_delta) = split_last(&full, &ds.b, 4);
        let mut grown = ModelSession::new(
            Arc::new(Operand::from(base)),
            b_base,
            SketchKind::Gaussian,
            37,
        )
        .unwrap();
        grown.solve(0.5, 1e-10).unwrap();
        grown.append(Operand::from(delta), b_delta, AppendRefresh::Eager).unwrap();
        let warm = grown.solve(0.5, 1e-10).unwrap();
        let mut fresh = ModelSession::new(
            Arc::new(Operand::from(full)),
            ds.b.clone(),
            SketchKind::Gaussian,
            37,
        )
        .unwrap();
        let cold = fresh.solve(0.5, 1e-10).unwrap();
        assert!(warm.report.converged && cold.report.converged);
        assert!(
            warm.report.iterations <= cold.report.iterations,
            "warm post-append solve took {} iterations, cold re-register {}",
            warm.report.iterations,
            cold.report.iterations
        );
    }

    #[test]
    fn append_invalidates_solution_cache_but_counts_no_query() {
        let ds = synthetic::exponential_decay(128, 16, 38);
        let full = ds.a.dense().into_owned();
        let (base, b_base, delta, b_delta) = split_last(&full, &ds.b, 2);
        let mut s = ModelSession::new(
            Arc::new(Operand::from(base)),
            b_base,
            SketchKind::Gaussian,
            39,
        )
        .unwrap();
        let before = s.solve(0.5, 1e-8).unwrap();
        let (q0, h0) = s.query_stats();
        s.append(Operand::from(delta), b_delta, AppendRefresh::Eager).unwrap();
        assert_eq!(s.query_stats(), (q0, h0), "append must not count as a query");
        let after = s.solve(0.5, 1e-8).unwrap();
        let (_, h1) = s.query_stats();
        assert_eq!(h1, h0, "post-append repeat must NOT hit the stale cache");
        assert_ne!(before.x, after.x, "the grown problem has a different optimum");
    }

    #[test]
    fn append_rejects_bad_inputs_without_mutating() {
        let mut s = session(64, 8, 40);
        s.solve(0.5, 1e-8).unwrap();
        let (n0, bytes0) = (s.n(), s.approx_bytes());
        let row = |v: f64| Operand::from(Matrix::from_fn(1, 8, |_, _| v));
        // Wrong width, wrong b length, non-finite entries, empty append.
        assert!(s
            .append(Operand::from(Matrix::zeros(1, 5)), vec![1.0], AppendRefresh::Eager)
            .is_err());
        assert!(s.append(row(1.0), vec![1.0, 2.0], AppendRefresh::Eager).is_err());
        assert!(s.append(row(f64::NAN), vec![1.0], AppendRefresh::Eager).is_err());
        assert!(s.append(row(1.0), vec![f64::NAN], AppendRefresh::Eager).is_err());
        assert!(s
            .append(Operand::from(Matrix::zeros(0, 8)), vec![], AppendRefresh::Eager)
            .is_err());
        assert_eq!((s.n(), s.approx_bytes()), (n0, bytes0), "rejected appends must not mutate");
    }

    #[test]
    fn append_before_first_solve_just_grows_the_data() {
        let ds = synthetic::exponential_decay(96, 8, 41);
        let full = ds.a.dense().into_owned();
        let (base, b_base, delta, b_delta) = split_last(&full, &ds.b, 3);
        let mut s = ModelSession::new(
            Arc::new(Operand::from(base)),
            b_base,
            SketchKind::Srht,
            42,
        )
        .unwrap();
        let out = s.append(Operand::from(delta), b_delta, AppendRefresh::Eager).unwrap();
        assert_eq!((out.n, out.m, out.refreshed), (96, 0, false));
        assert!(s.pending.is_none(), "no state, nothing to defer");
        let sol = s.solve(0.7, 1e-9).unwrap();
        assert!(sol.report.converged);
        let x_star = exact(&s, 0.7);
        let p = RidgeProblem::from_parts(
            Arc::clone(s.operand()),
            None,
            s.operand().matvec_t(&s.b),
            0.7,
        );
        let rel = p.prediction_error(&sol.x, &x_star)
            / p.prediction_error(&vec![0.0; 8], &x_star);
        assert!(rel < 1e-6, "relative error {rel}");
    }

    #[test]
    fn solve_block_rejects_bad_batches() {
        let mut s = session(64, 8, 14);
        assert!(s.solve_block(0.5, &[], 1e-8).is_err(), "empty batch");
        assert!(s.solve_block(0.5, &[vec![1.0; 3]], 1e-8).is_err(), "short rhs");
        assert!(
            s.solve_block(0.5, &[vec![f64::NAN; 64]], 1e-8).is_err(),
            "non-finite rhs"
        );
        assert!(s.solve_block(f64::NAN, &[vec![1.0; 64]], 1e-8).is_err());
        assert_eq!(s.m(), 0, "rejected batches must not touch session state");
    }

    #[test]
    fn expired_deadline_rolls_back_and_leaves_session_usable() {
        let mut s = session(128, 16, 50);
        let clean = s.solve(0.5, 1e-8).unwrap();
        let m0 = s.m();
        // An already-expired deadline fails the very next (uncached)
        // solve with a structured error...
        s.set_deadline(Some(Instant::now()));
        let err = s.solve(0.25, 1e-10).unwrap_err();
        assert!(err.contains("deadline"), "{err}");
        assert_eq!(s.m(), m0, "failed solve must not mutate the sketch state");
        // ...while cache hits never run the solver and still answer.
        let hit = s.solve(0.5, 1e-8).unwrap();
        assert_eq!(hit.x, clean.x);
        // Clearing the deadline restores full service — bitwise the same
        // state as before the failed call.
        s.set_deadline(None);
        let fresh = s.solve(0.25, 1e-10).unwrap();
        assert!(fresh.report.converged);
    }

    #[test]
    fn expired_deadline_fails_block_solves_without_poisoning_state() {
        let mut s = session(128, 16, 51);
        s.solve(0.5, 1e-8).unwrap();
        let m0 = s.m();
        let bs: Vec<Vec<f64>> =
            (0..3).map(|j| (0..128).map(|i| ((i + j) as f64 * 0.09).sin()).collect()).collect();
        s.set_deadline(Some(Instant::now()));
        let err = s.solve_block(0.4, &bs, 1e-10).unwrap_err();
        assert!(err.contains("deadline"), "{err}");
        assert_eq!(s.m(), m0, "failed batch must not mutate the sketch state");
        s.set_deadline(None);
        let sols = s.solve_block(0.4, &bs, 1e-10).unwrap();
        assert!(sols.iter().all(|x| x.report.converged));
    }

    #[test]
    fn healthy_session_reports_no_recovery_rung() {
        use crate::solvers::error::RecoveryRung;
        let mut s = session(128, 16, 52);
        let sol = s.solve(0.5, 1e-9).unwrap();
        assert_eq!(sol.report.recovery, RecoveryRung::None);
        assert_eq!(sol.report.recovery.label(), "none");
    }

    #[test]
    fn append_delta_is_normalized_to_operand_storage_kind() {
        use crate::linalg::sparse::CsrMatrix;
        let ds = synthetic::exponential_decay(96, 8, 61);
        let full = ds.a.dense().into_owned();
        let (base, b_base, delta, b_delta) = split_last(&full, &ds.b, 4);
        let mut s = ModelSession::new(
            Arc::new(Operand::from(base)),
            b_base,
            SketchKind::Gaussian,
            62,
        )
        .unwrap();
        s.solve(0.5, 1e-8).unwrap();
        // A CSR delta streamed into a dense model must be densified
        // *before* it reaches the pending buffer — the engine has to see
        // the same storage kind a replay would slice out of the operand.
        let sparse_delta = Operand::Sparse(CsrMatrix::from_dense(&delta));
        s.append(sparse_delta, b_delta, AppendRefresh::Lazy).unwrap();
        assert!(
            matches!(s.pending.as_ref().unwrap(), Operand::Dense(_)),
            "pending delta must carry the operand's (dense) storage kind"
        );
        assert!(matches!(&*s.a, Operand::Dense(_)));
        let sol = s.solve(0.5, 1e-9).unwrap();
        assert!(sol.report.converged);
    }

    #[test]
    fn epoch_counts_solver_runs_not_appends() {
        let ds = synthetic::exponential_decay(128, 16, 63);
        let full = ds.a.dense().into_owned();
        let (base, b_base, delta, b_delta) = split_last(&full, &ds.b, 4);
        let mut s = ModelSession::new(
            Arc::new(Operand::from(base)),
            b_base,
            SketchKind::Gaussian,
            64,
        )
        .unwrap();
        assert_eq!(s.epoch(), 0);
        s.solve(0.5, 1e-8).unwrap();
        assert_eq!(s.epoch(), 1, "an uncached solve mutates solver state");
        s.solve(0.5, 1e-8).unwrap();
        assert_eq!(s.epoch(), 1, "a cache hit runs no solver");
        s.append(Operand::from(delta), b_delta, AppendRefresh::Eager).unwrap();
        assert_eq!(s.epoch(), 1, "appends are WAL-covered, not dirty");
        s.solve(0.5, 1e-8).unwrap();
        assert_eq!(s.epoch(), 2);
        // Failed solves roll back without bumping.
        assert!(s.solve(0.0, 1e-8).is_err());
        assert_eq!(s.epoch(), 2);
    }

    #[test]
    fn restore_rebuilds_a_bitwise_equivalent_session() {
        let mut live = session(128, 16, 65);
        live.solve(0.5, 1e-8).unwrap();
        let mut restored = ModelSession::restore(
            Arc::clone(live.operand()),
            live.b().to_vec(),
            live.atb().to_vec(),
            live.kind(),
            live.seed(),
            live.state().cloned(),
            live.warm().map(<[f64]>::to_vec),
            live.query_stats().0,
            live.epoch(),
        )
        .unwrap();
        assert_eq!(restored.epoch(), live.epoch());
        assert_eq!(restored.query_stats().0, live.query_stats().0);
        // A fresh (uncached in both) query consumes the same RNG stream
        // from the same state — bitwise-identical answers.
        let a = live.solve(0.25, 1e-9).unwrap();
        let b = restored.solve(0.25, 1e-9).unwrap();
        let bits = |x: &[f64]| x.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a.x), bits(&b.x));
        // Shape validation rejects inconsistent parts.
        assert!(ModelSession::restore(
            Arc::clone(live.operand()),
            vec![1.0; 3],
            live.atb().to_vec(),
            live.kind(),
            live.seed(),
            None,
            None,
            0,
            0,
        )
        .is_err());
    }

    // ---- frozen read lane (snapshot-level) ----

    fn bits(x: &[f64]) -> Vec<u64> {
        x.iter().map(|v| v.to_bits()).collect()
    }

    #[test]
    fn snapshot_solve_frozen_is_bitwise_the_writer_answer_and_populates_nothing() {
        // Two twin sessions (same data, same seed). One warms state and
        // publishes a snapshot; the frozen solve at an uncached nu off
        // that snapshot must match — bitwise — the writer-lane solve the
        // twin performs from the same generation, while mutating nothing.
        let mut writer = session(256, 32, 40);
        let mut twin = session(256, 32, 40);
        writer.solve(0.5, 1e-6).unwrap();
        twin.solve(0.5, 1e-6).unwrap();

        let snap = writer.snapshot();
        assert!(snap.panel().is_some());
        assert!(!snap.pending());
        assert!(snap.cached(0.9, 1e-6).is_none(), "premise: uncached nu");
        let keys_before = writer.solution_keys();

        let frozen = snap.solve_frozen(0.9, 1e-6, None).unwrap().unwrap();
        let FrozenOutcome::Solved(fsol) = frozen else {
            panic!("larger nu than the warm solve must not need growth");
        };
        let msol = twin.solve(0.9, 1e-6).unwrap();
        assert_eq!(bits(&fsol.x), bits(&msol.x), "frozen and writer lanes diverged");
        assert_eq!(fsol.report.iterations, msol.report.iterations);
        assert_eq!(fsol.report.final_m, msol.report.final_m);

        // Read-only: no cache entry, no warm-start advance, no counters.
        assert_eq!(writer.solution_keys(), keys_before);
        assert!(snap.cached(0.9, 1e-6).is_none());
        assert_eq!(writer.query_stats().0, twin.query_stats().0 - 1);

        // The writer keeps working off the untouched state: its own solve
        // at the same nu still answers bitwise-identically.
        let wsol = writer.solve(0.9, 1e-6).unwrap();
        assert_eq!(bits(&wsol.x), bits(&fsol.x));
    }

    #[test]
    fn snapshot_solve_frozen_refuses_stateless_and_pending_snapshots() {
        // Before the first solve there is no panel to pin.
        let mut s = session(96, 12, 41);
        assert!(s.snapshot().solve_frozen(0.5, 1e-6, None).is_none());

        // A lazily appended row leaves the pinned panel stale — the
        // frozen lane must defer to the writer (which flushes first).
        s.solve(0.5, 1e-6).unwrap();
        let extra = synthetic::exponential_decay(96, 12, 42);
        let row = extra.a.dense().into_owned().row(0).to_vec();
        let delta = Operand::from(Matrix::from_vec(1, 12, row));
        s.append(delta, vec![1.0], AppendRefresh::Lazy).unwrap();
        let snap = s.snapshot();
        assert!(snap.pending());
        assert!(snap.solve_frozen(0.5, 1e-6, None).is_none());
    }

    #[test]
    fn snapshot_solve_frozen_surfaces_writer_identical_input_errors() {
        let mut s = session(96, 12, 43);
        s.solve(0.5, 1e-6).unwrap();
        let snap = s.snapshot();
        let frozen_err = snap.solve_frozen(-1.0, 1e-6, None).unwrap().unwrap_err();
        let writer_err = s.solve(-1.0, 1e-6).unwrap_err();
        assert_eq!(frozen_err, writer_err);
    }

    #[test]
    fn snapshot_needs_growth_defers_then_next_generation_serves_frozen() {
        // Warm at a large nu (tiny frozen m); a much smaller nu needs a
        // bigger sketch: the frozen lane defers with NeedsGrowth, the
        // writer lane grows and re-publishes, and the *next* snapshot
        // serves that same nu frozen — the serving layer's fallback
        // contract end to end at the session level.
        let mut s = session(512, 64, 44);
        s.solve(50.0, 1e-6).unwrap();
        let snap1 = s.snapshot();
        let gen1 = snap1.generation();
        match snap1.solve_frozen(0.05, 1e-6, None).unwrap().unwrap() {
            FrozenOutcome::NeedsGrowth { m, .. } => assert_eq!(m, snap1.m()),
            FrozenOutcome::Solved(_) => panic!("tiny frozen m must defer"),
        }

        let wsol = s.solve(0.05, 1e-6).unwrap();
        assert!(wsol.report.doublings >= 1, "premise: the writer grows here");
        let snap2 = s.snapshot();
        assert!(snap2.generation() > gen1);
        // Same nu, *different* eps => not a cache hit; a genuinely
        // uncached frozen solve against the grown panel succeeds now.
        match snap2.solve_frozen(0.05, 2e-6, None).unwrap().unwrap() {
            FrozenOutcome::Solved(sol) => assert!(sol.report.converged),
            FrozenOutcome::NeedsGrowth { reason, .. } => {
                panic!("grown panel must serve this nu frozen: {reason}")
            }
        }
    }

    #[test]
    fn retained_bytes_charges_only_unshared_allocations() {
        let mut s = session(256, 32, 45);
        s.solve(0.5, 1e-6).unwrap();
        let snap = s.snapshot();
        // Fresh snapshot: everything heavy is shared; only the two owned
        // length-d/n vectors (atb + warm) are charged.
        let f64s = std::mem::size_of::<f64>();
        let owned = s.atb().len() * f64s + s.warm().unwrap().len() * f64s;
        assert_eq!(snap.retained_bytes(&s), owned);

        // A writer-lane solve at a new nu re-keys (and may grow): the
        // stale snapshot now retains its own factor — and, if growth
        // happened, the whole pre-growth panel — but never the shared
        // operand.
        s.solve(0.1, 1e-6).unwrap();
        let extra = snap.retained_bytes(&s);
        assert!(extra > owned, "stale snapshot must charge unshared solver state");
        let full = operand_bytes(s.operand())
            + s.atb().len() * f64s
            + s.approx_bytes();
        assert!(extra < full, "shared operand must not be double-charged");
    }
}
