//! Underdetermined case `d >= n` via the dual problem (Appendix A.2).
//!
//! The dual of `min_x 1/2 ||Ax - b||^2 + nu^2/2 ||x||^2` is
//! `min_z 1/2 ||A^T z||^2 + nu^2/2 ||z||^2 - b^T z`, which is itself an
//! overdetermined ridge problem with data matrix `A^T in R^{d x n}` and
//! normal-equations RHS equal to `b` directly — no pseudo-inverse `A^† b`
//! is ever formed (the paper's key observation:
//! `∇g(z) = A A^T z + nu^2 z - b`). The primal solution is recovered as
//! `x* = A^T z*`.

use super::adaptive::{self, AdaptiveConfig};
use super::error::SolverError;
use super::{RidgeProblem, Solution, StopRule};
use crate::linalg::{Operand, OperandRef};
use std::sync::Arc;

/// An underdetermined ridge instance (`d >= n`) and its dual reduction.
pub struct DualRidge {
    /// The dual, overdetermined problem in `z in R^n` with data `A^T`.
    pub dual: RidgeProblem,
    /// Original data matrix (`n x d`, dense or CSR), kept for the primal
    /// map; shared (not cloned) when the caller already holds an `Arc`.
    a: Arc<Operand>,
}

impl DualRidge {
    /// Build the dual reduction of `(A, b, nu)` with `A: n x d`, `d >= n`.
    /// `A` may be dense or CSR; the CSR transpose costs `O(nnz)` and the
    /// dual solve inherits every sparse fast path.
    pub fn new(a: impl Into<Operand>, b: Vec<f64>, nu: f64) -> Self {
        Self::new_shared(Arc::new(a.into()), b, nu)
    }

    /// [`DualRidge::new`] for an operand that is already shared — avoids
    /// cloning the data when the primal problem keeps using it.
    pub fn new_shared(a: Arc<Operand>, b: Vec<f64>, nu: f64) -> Self {
        assert!(a.cols() >= a.rows(), "dual path is for underdetermined problems (d >= n)");
        assert_eq!(a.rows(), b.len());
        let dual = RidgeProblem::from_normal(a.transpose(), b, nu);
        Self { dual, a }
    }

    /// Map a dual iterate to the primal space: `x = A^T z`.
    pub fn primal(&self, z: &[f64]) -> Vec<f64> {
        self.a.matvec_t(z)
    }

    /// Solve via the adaptive algorithm on the dual, returning the primal
    /// solution. `stop` is evaluated in the *dual* space (see
    /// [`dual_stop`]). Guarantees of Theorems 5–7 carry over verbatim
    /// (Appendix A.2).
    pub fn solve_adaptive(&self, config: &AdaptiveConfig, stop: &StopRule, seed: u64) -> Solution {
        self.try_solve_adaptive(config, stop, seed)
            .unwrap_or_else(|e| panic!("dual adaptive solve failed: {e}"))
    }

    /// [`DualRidge::solve_adaptive`] with structured errors instead of a
    /// panic: invalid input, deadline expiry and exhausted numerical
    /// recovery come back as [`SolverError`] values.
    pub fn try_solve_adaptive(
        &self,
        config: &AdaptiveConfig,
        stop: &StopRule,
        seed: u64,
    ) -> Result<Solution, SolverError> {
        let n = self.dual.d();
        let z0 = vec![0.0; n];
        let mut sol = adaptive::solve(&self.dual, &z0, config, stop, seed)?;
        sol.x = self.primal(&sol.x);
        sol.report.solver = format!("dual-{}", sol.report.solver);
        Ok(sol)
    }
}

/// Exact primal solution of an underdetermined ridge problem through the
/// dual normal equations (`(A A^T + nu^2 I_n) z = b`, `x = A^T z`) —
/// `O(d n^2)`, the ground truth for the dual experiments. Accepts
/// `&Matrix`, `&CsrMatrix`, or `&Operand`.
pub fn solve_direct<'a>(a: impl Into<OperandRef<'a>>, b: &[f64], nu: f64) -> Vec<f64> {
    use crate::linalg::cholesky::Cholesky;
    let a: OperandRef<'a> = a.into();
    let mut k = a.gram_outer(); // A A^T, n x n
    k.add_diag(nu * nu);
    let chol = Cholesky::factor(&k).expect("A A^T + nu^2 I is PD");
    let z = chol.solve(b);
    a.matvec_t(&z)
}

/// Dual stop rule helper: build a `TrueError` rule in the *dual* space
/// from the known dual optimum.
pub fn dual_stop(dual: &RidgeProblem, eps: f64) -> StopRule {
    StopRule::TrueError { x_star: super::direct::solve(dual), eps }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;
    use crate::sketch::SketchKind;

    /// Wide random matrix (d >= n) with decaying row space.
    fn wide_problem(n: usize, d: usize, seed: u64) -> (Operand, Vec<f64>) {
        // Transpose of an overdetermined synthetic dataset.
        let ds = crate::data::synthetic::exponential_decay(d, n, seed);
        let a = ds.a.transpose(); // n x d
        let mut rng = Xoshiro256::seed_from_u64(seed + 1);
        let mut b = vec![0.0; n];
        rng.fill_gaussian(&mut b, 1.0);
        (a, b)
    }

    #[test]
    fn dual_direct_satisfies_primal_optimality() {
        let (a, b) = wide_problem(16, 64, 1);
        let nu = 0.5;
        let x = solve_direct(&a, &b, nu);
        // Primal optimality: A^T (A x - b) + nu^2 x = 0.
        let p = RidgeProblem::new(a, b, nu);
        let g = p.gradient(&x);
        assert!(crate::linalg::norm2(&g) < 1e-9, "gradient norm {}", crate::linalg::norm2(&g));
    }

    #[test]
    fn adaptive_dual_matches_direct() {
        let (a, b) = wide_problem(16, 64, 2);
        let nu = 0.5;
        let x_direct = solve_direct(&a, &b, nu);
        let dr = DualRidge::new(a, b, nu);
        let cfg = AdaptiveConfig::new(SketchKind::Gaussian);
        let sol = dr.solve_adaptive(&cfg, &dual_stop(&dr.dual, 1e-12), 3);
        assert!(sol.report.converged);
        for i in 0..x_direct.len() {
            assert!(
                (sol.x[i] - x_direct[i]).abs() < 1e-5,
                "coord {i}: {} vs {}",
                sol.x[i],
                x_direct[i]
            );
        }
    }

    #[test]
    fn dual_gradient_needs_no_pseudoinverse() {
        // ∇g(z) computed by the machinery == A A^T z + nu^2 z - b.
        let (a, b) = wide_problem(8, 32, 4);
        let nu = 0.7;
        let dr = DualRidge::new(a.clone(), b.clone(), nu);
        let z: Vec<f64> = (0..8).map(|i| (i as f64 * 0.4).sin()).collect();
        let g = dr.dual.gradient(&z);
        let aaz = a.matvec(&a.matvec_t(&z));
        for i in 0..8 {
            let expect = aaz[i] + nu * nu * z[i] - b[i];
            assert!((g[i] - expect).abs() < 1e-10);
        }
    }

    #[test]
    fn srht_dual_converges() {
        let (a, b) = wide_problem(16, 128, 5);
        let dr = DualRidge::new(a, b, 1.0);
        let cfg = AdaptiveConfig::new(SketchKind::Srht);
        let sol = dr.solve_adaptive(&cfg, &dual_stop(&dr.dual, 1e-10), 6);
        assert!(sol.report.converged);
        assert_eq!(sol.report.solver, "dual-adaptive-srht");
    }

    #[test]
    #[should_panic(expected = "underdetermined")]
    fn rejects_tall_input() {
        let (a, b) = wide_problem(8, 32, 7);
        DualRidge::new(a.transpose(), b[..4].to_vec(), 0.5);
    }
}
