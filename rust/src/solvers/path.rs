//! Regularization-path driver (Figures 1 and 3).
//!
//! Computes the ridge solutions for a decreasing sequence of `nu` values,
//! warm-starting each solve at the previous solution — the workload the
//! paper argues is the practically relevant one (model selection /
//! inverse problems). Every solver runs the same protocol so cumulative
//! times are comparable.
//!
//! Solvers are named by [`SolverSpec`] — the same spec strings the CLI,
//! the coordinator and the bench harness use — and dispatched through the
//! unified [`Solver`](crate::solvers::api::Solver) trait; there is no
//! path-specific solver enumeration.

use super::api::{Solver as _, SolverSpec};
use super::{RidgeProblem, SolveReport};
use crate::linalg::Operand;

/// Result of one path point.
#[derive(Clone, Debug)]
pub struct PathPoint {
    /// Regularization level of this point.
    pub nu: f64,
    /// The solve's work/time breakdown.
    pub report: SolveReport,
    /// Cumulative wall time up to and including this point.
    pub cumulative_time_s: f64,
}

/// Full path result.
#[derive(Clone, Debug)]
pub struct PathResult {
    /// Canonical spec string of the solver that ran the path.
    pub solver: String,
    /// One entry per `nu`, in solve order.
    pub points: Vec<PathPoint>,
}

impl PathResult {
    /// Total wall time across the path (the last cumulative time).
    pub fn total_time_s(&self) -> f64 {
        self.points.last().map(|p| p.cumulative_time_s).unwrap_or(0.0)
    }

    /// Largest sketch size any point reached.
    pub fn peak_m(&self) -> usize {
        self.points.iter().map(|p| p.report.peak_m).max().unwrap_or(0)
    }
}

/// Run a regularization path on `(a, b)` over `nus` (must be decreasing) to
/// relative precision `eps` per point (measured against the exact solution,
/// as in the paper's figures).
///
/// Randomized solvers draw independent sketches per path point
/// (`seed + i`); warm starts carry the previous solution into solvers
/// whose spec [`supports_warm_start`](crate::solvers::api::Solver::supports_warm_start).
pub fn run_path(
    a: &Operand,
    b: &[f64],
    nus: &[f64],
    eps: f64,
    spec: &SolverSpec,
    seed: u64,
) -> PathResult {
    assert!(!nus.is_empty());
    for w in nus.windows(2) {
        assert!(w[0] > w[1], "nu sequence must be strictly decreasing");
    }
    let d = a.cols();
    let mut x = vec![0.0; d];
    let mut points = Vec::with_capacity(nus.len());
    let mut cumulative = 0.0;
    // One shared operand — and one A^T b — for the whole path: each
    // per-nu problem clones the Arc and the length-d right-hand side,
    // not the data or the O(nnz) product.
    let shared = std::sync::Arc::new(a.clone());
    let atb = shared.matvec_t(b);

    for (i, &nu) in nus.iter().enumerate() {
        let problem = RidgeProblem::from_parts(
            std::sync::Arc::clone(&shared),
            Some(b.to_vec()),
            atb.clone(),
            nu,
        );
        // Oracle for the stop rule: exact solution at this nu (excluded
        // from timing — the paper measures solver time only; dual specs
        // substitute their own dual-space oracle).
        let stop = spec.true_error_stop(&problem, eps);

        let solver = spec.build(seed.wrapping_add(i as u64));
        let x0 = if solver.supports_warm_start() { x.clone() } else { vec![0.0; d] };
        let solution = solver.solve(&problem, &x0, &stop);

        cumulative += solution.report.wall_time_s;
        points.push(PathPoint { nu, report: solution.report, cumulative_time_s: cumulative });
        x = solution.x;
    }

    PathResult { solver: spec.to_string(), points }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::sketch::SketchKind;
    use crate::solvers::adaptive::AdaptiveVariant;

    fn small_path_data() -> (Operand, Vec<f64>) {
        let ds = synthetic::exponential_decay(256, 32, 1);
        (ds.a, ds.b)
    }

    #[test]
    fn cg_path_converges_everywhere() {
        let (a, b) = small_path_data();
        let nus = [1.0, 0.1, 0.01];
        let res = run_path(&a, &b, &nus, 1e-8, &SolverSpec::Cg, 1);
        assert_eq!(res.points.len(), 3);
        assert!(res.points.iter().all(|p| p.report.converged));
    }

    #[test]
    fn adaptive_path_converges_and_reuses_growth() {
        let (a, b) = small_path_data();
        let nus = [1.0, 0.1, 0.01];
        let spec = SolverSpec::Adaptive {
            kind: SketchKind::Gaussian,
            variant: AdaptiveVariant::PolyakFirst,
            threads: None,
        };
        let res = run_path(&a, &b, &nus, 1e-8, &spec, 2);
        assert!(res.points.iter().all(|p| p.report.converged));
        // d_e grows as nu shrinks: peak m should be nondecreasing in i
        // *typically*; at minimum the final point must have m >= 1.
        assert!(res.peak_m() >= 1);
    }

    #[test]
    fn cumulative_time_monotone() {
        let (a, b) = small_path_data();
        let nus = [10.0, 1.0, 0.1];
        let res = run_path(&a, &b, &nus, 1e-6, &SolverSpec::Cg, 3);
        for w in res.points.windows(2) {
            assert!(w[1].cumulative_time_s >= w[0].cumulative_time_s);
        }
        assert!((res.total_time_s() - res.points.last().unwrap().cumulative_time_s).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "strictly decreasing")]
    fn rejects_unsorted_path() {
        let (a, b) = small_path_data();
        run_path(&a, &b, &[0.1, 1.0], 1e-6, &SolverSpec::Cg, 4);
    }

    #[test]
    fn pcg_path_converges() {
        let (a, b) = small_path_data();
        let nus = [1.0, 0.1];
        let spec: SolverSpec = "pcg-srht".parse().unwrap();
        let res = run_path(&a, &b, &nus, 1e-8, &spec, 5);
        assert!(res.points.iter().all(|p| p.report.converged));
    }

    #[test]
    fn any_registry_spec_runs_a_path() {
        // The path driver must accept every solver the registry exposes
        // that applies to overdetermined data (i.e. all but the dual).
        let (a, b) = small_path_data();
        let nus = [10.0, 1.0];
        for spec in crate::solvers::api::registry() {
            if matches!(spec, SolverSpec::DualAdaptive { .. }) {
                continue;
            }
            let res = run_path(&a, &b, &nus, 1e-6, &spec, 6);
            assert!(
                res.points.iter().all(|p| p.report.converged),
                "{spec} failed on the path"
            );
            assert_eq!(res.solver, spec.to_string());
        }
    }

    #[test]
    fn labels_are_spec_strings() {
        let (a, b) = small_path_data();
        let spec: SolverSpec = "adaptive-gd-srht".parse().unwrap();
        let res = run_path(&a, &b, &[1.0], 1e-6, &spec, 7);
        assert_eq!(res.solver, "adaptive-gd-srht");
        assert_eq!(res.points[0].report.solver, "adaptive-gd-srht");
    }
}
