//! Regularization-path driver (Figures 1 and 3).
//!
//! Computes the ridge solutions for a decreasing sequence of `nu` values,
//! warm-starting each solve at the previous solution — the workload the
//! paper argues is the practically relevant one (model selection /
//! inverse problems). Every solver runs the same protocol so cumulative
//! times are comparable.

use super::adaptive::{self, AdaptiveConfig, AdaptiveVariant};
use super::cg::{self, CgConfig};
use super::pcg::{self, PcgConfig};
use super::{direct, RidgeProblem, SolveReport, StopRule};
use crate::linalg::Matrix;
use crate::rng::Xoshiro256;
use crate::sketch::SketchKind;

/// Which algorithm runs the path.
#[derive(Clone, Debug, PartialEq)]
pub enum PathSolver {
    Cg,
    Pcg { kind: SketchKind, rho: f64 },
    Adaptive { kind: SketchKind, variant: AdaptiveVariant },
}

impl PathSolver {
    pub fn label(&self) -> String {
        match self {
            PathSolver::Cg => "cg".into(),
            PathSolver::Pcg { kind, .. } => format!("pcg-{kind}"),
            PathSolver::Adaptive { kind, variant } => format!(
                "adaptive-{}-{kind}",
                match variant {
                    AdaptiveVariant::PolyakFirst => "polyak",
                    AdaptiveVariant::GradientOnly => "gd",
                }
            ),
        }
    }
}

/// Result of one path point.
#[derive(Clone, Debug)]
pub struct PathPoint {
    pub nu: f64,
    pub report: SolveReport,
    /// Cumulative wall time up to and including this point.
    pub cumulative_time_s: f64,
}

/// Full path result.
#[derive(Clone, Debug)]
pub struct PathResult {
    pub solver: String,
    pub points: Vec<PathPoint>,
}

impl PathResult {
    pub fn total_time_s(&self) -> f64 {
        self.points.last().map(|p| p.cumulative_time_s).unwrap_or(0.0)
    }

    pub fn peak_m(&self) -> usize {
        self.points.iter().map(|p| p.report.peak_m).max().unwrap_or(0)
    }
}

/// Run a regularization path on `(a, b)` over `nus` (must be decreasing) to
/// relative precision `eps` per point (measured against the exact solution,
/// as in the paper's figures).
pub fn run_path(
    a: &Matrix,
    b: &[f64],
    nus: &[f64],
    eps: f64,
    solver: &PathSolver,
    seed: u64,
) -> PathResult {
    assert!(!nus.is_empty());
    for w in nus.windows(2) {
        assert!(w[0] > w[1], "nu sequence must be strictly decreasing");
    }
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let d = a.cols();
    let mut x = vec![0.0; d];
    let mut points = Vec::with_capacity(nus.len());
    let mut cumulative = 0.0;

    for (i, &nu) in nus.iter().enumerate() {
        let problem = RidgeProblem::new(a.clone(), b.to_vec(), nu);
        // Oracle for the stop rule: exact solution at this nu (excluded
        // from timing — the paper measures solver time only).
        let x_star = direct::solve(&problem);
        let stop = StopRule::TrueError { x_star, eps };

        let solution = match solver {
            PathSolver::Cg => cg::solve(&problem, &x, &CgConfig { max_iters: 100_000, stop }),
            PathSolver::Pcg { kind, rho } => {
                let cfg = PcgConfig::new(*kind, *rho, stop);
                pcg::solve(&problem, &x, &cfg, &mut rng)
            }
            PathSolver::Adaptive { kind, variant } => {
                let mut cfg = AdaptiveConfig::new(*kind, stop);
                cfg.variant = *variant;
                adaptive::solve(&problem, &x, &cfg, seed.wrapping_add(i as u64))
            }
        };

        cumulative += solution.report.wall_time_s;
        points.push(PathPoint { nu, report: solution.report, cumulative_time_s: cumulative });
        x = solution.x;
    }

    PathResult { solver: solver.label(), points }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    fn small_path_data() -> (Matrix, Vec<f64>) {
        let ds = synthetic::exponential_decay(256, 32, 1);
        (ds.a, ds.b)
    }

    #[test]
    fn cg_path_converges_everywhere() {
        let (a, b) = small_path_data();
        let nus = [1.0, 0.1, 0.01];
        let res = run_path(&a, &b, &nus, 1e-8, &PathSolver::Cg, 1);
        assert_eq!(res.points.len(), 3);
        assert!(res.points.iter().all(|p| p.report.converged));
    }

    #[test]
    fn adaptive_path_converges_and_reuses_growth() {
        let (a, b) = small_path_data();
        let nus = [1.0, 0.1, 0.01];
        let solver = PathSolver::Adaptive {
            kind: SketchKind::Gaussian,
            variant: AdaptiveVariant::PolyakFirst,
        };
        let res = run_path(&a, &b, &nus, 1e-8, &solver, 2);
        assert!(res.points.iter().all(|p| p.report.converged));
        // d_e grows as nu shrinks: peak m should be nondecreasing in i
        // *typically*; at minimum the final point must have m >= 1.
        assert!(res.peak_m() >= 1);
    }

    #[test]
    fn cumulative_time_monotone() {
        let (a, b) = small_path_data();
        let nus = [10.0, 1.0, 0.1];
        let res = run_path(&a, &b, &nus, 1e-6, &PathSolver::Cg, 3);
        for w in res.points.windows(2) {
            assert!(w[1].cumulative_time_s >= w[0].cumulative_time_s);
        }
        assert!((res.total_time_s() - res.points.last().unwrap().cumulative_time_s).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "strictly decreasing")]
    fn rejects_unsorted_path() {
        let (a, b) = small_path_data();
        run_path(&a, &b, &[0.1, 1.0], 1e-6, &PathSolver::Cg, 4);
    }

    #[test]
    fn pcg_path_converges() {
        let (a, b) = small_path_data();
        let nus = [1.0, 0.1];
        let solver = PathSolver::Pcg { kind: SketchKind::Srht, rho: 0.5 };
        let res = run_path(&a, &b, &nus, 1e-8, &solver, 5);
        assert!(res.points.iter().all(|p| p.report.converged));
    }

    #[test]
    fn labels_stable() {
        assert_eq!(PathSolver::Cg.label(), "cg");
        let s = PathSolver::Adaptive {
            kind: SketchKind::Srht,
            variant: AdaptiveVariant::GradientOnly,
        };
        assert_eq!(s.label(), "adaptive-gd-srht");
    }
}
