//! Randomized preconditioned conjugate gradient (pCG) — the
//! Rokhlin–Tygert-style baseline \[37\] the paper compares against.
//!
//! Pipeline: sketch the augmented matrix (`M = [SA; nu I]` with
//! `m ≈ d/rho` Gaussian or `m ≈ d log d / rho` SRHT rows — the
//! `d`-proportional sizes the paper notes pCG must use absent knowledge of
//! `d_e`), QR-factor `M`, then run CG on the normal equations
//! preconditioned by `P = R^T R`. The `O(m d^2)` factor cost and `O(d^2)`
//! memory are exactly what the adaptive method avoids.

use super::{RidgeProblem, Solution, SolveReport, StopRule};
use crate::linalg::qr::QR;
use crate::linalg::triangular::{solve_upper_in_place, solve_upper_transpose_in_place};
use crate::linalg::{axpy, dot, norm2, Matrix};
use crate::rng::Xoshiro256;
use crate::sketch::{self, SketchKind};
use std::time::Instant;

/// pCG configuration. Stop rule and seed are per-solve arguments of the
/// unified [`crate::solvers::api::Solver`] call.
#[derive(Clone, Debug)]
pub struct PcgConfig {
    /// Iteration cap (safety net; the stop rule fires first).
    pub max_iters: usize,
    /// Sketch family for the preconditioner.
    pub kind: SketchKind,
    /// Aspect-ratio parameter `rho`; the preconditioner sketch size is
    /// `d/rho` (Gaussian) or `d log d / rho` (SRHT), capped at `n`.
    pub rho: f64,
}

impl PcgConfig {
    /// Config with the default iteration cap.
    pub fn new(kind: SketchKind, rho: f64) -> Self {
        Self { max_iters: 10_000, kind, rho }
    }
}

/// Preconditioner sketch size prescribed for pCG (paper §5).
pub fn pcg_sketch_size(kind: SketchKind, n: usize, d: usize, rho: f64) -> usize {
    let df = d as f64;
    let m = match kind {
        SketchKind::Gaussian => df / rho,
        SketchKind::Srht | SketchKind::Sparse => df * df.max(2.0).ln() / rho,
    };
    (m.ceil() as usize).clamp(d, n.max(d))
}

/// Run pCG from `x0`; the preconditioner sketch is drawn from `seed`.
pub fn solve(
    problem: &RidgeProblem,
    x0: &[f64],
    config: &PcgConfig,
    stop: &StopRule,
    seed: u64,
) -> Solution {
    let start = Instant::now();
    let (n, d) = (problem.n(), problem.d());
    assert_eq!(x0.len(), d);
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut report = SolveReport::new(format!("pcg-{}", config.kind));

    // --- Sketch (dense or CSR operand at the family's sparse cost) ---
    let m = pcg_sketch_size(config.kind, n, d, config.rho);
    let t0 = Instant::now();
    let s = sketch::sample(config.kind, m, n, &mut rng);
    let sa = s.apply_operand(&problem.a);
    report.sketch_time_s = t0.elapsed().as_secs_f64();
    report.final_m = m;
    report.peak_m = m;

    // --- Factor: QR of [SA; nu I] ---
    let t0 = Instant::now();
    let mut aug = Matrix::zeros(m + d, d);
    for i in 0..m {
        aug.row_mut(i).copy_from_slice(sa.row(i));
    }
    for j in 0..d {
        aug.set(m + j, j, problem.nu);
    }
    let qr = QR::factor(aug);
    let r = qr.r();
    report.factor_time_s = t0.elapsed().as_secs_f64();

    // --- Preconditioned CG on H x = A^T b with P = R^T R ---
    // Inner loop is allocation-free: Hessian products, preconditioner
    // solves and stop checks reuse the workspace buffers below.
    let t_iter = Instant::now();
    let mut x = x0.to_vec();
    let mut res = problem.gradient(&x);
    crate::linalg::scale(-1.0, &mut res);
    let g0_norm = norm2(&res);
    let mut ws_n: Vec<f64> = Vec::new();
    let mut ws_d: Vec<f64> = Vec::new();
    let mut hp = vec![0.0; d];
    let delta0 = match stop {
        StopRule::TrueError { x_star, .. } => {
            problem.prediction_error_ws(&x, x_star, &mut ws_d, &mut ws_n)
        }
        _ => 0.0,
    };
    if matches!(stop, StopRule::TrueError { .. }) {
        // Shared trace convention: entry t is delta_t / delta_0.
        report.error_trace.reserve(config.max_iters.min(65_536) + 1);
        report.error_trace.push(1.0);
    }

    // P^{-1} v = R^{-1} R^{-T} v, in place on `z`.
    let apply_pinv = |v: &[f64], z: &mut [f64]| {
        z.copy_from_slice(v);
        solve_upper_transpose_in_place(&r, z);
        solve_upper_in_place(&r, z);
    };

    let mut z = vec![0.0; d];
    apply_pinv(&res, &mut z);
    let mut p = z.clone();
    let mut rz_old = dot(&res, &z);

    for t in 0..config.max_iters {
        if rz_old.abs() == 0.0 {
            report.converged = true;
            break;
        }
        problem.hessian_vec_into(&p, &mut ws_n, &mut hp);
        let alpha = rz_old / dot(&p, &hp);
        axpy(alpha, &p, &mut x);
        axpy(-alpha, &hp, &mut res);
        report.iterations = t + 1;

        let stop_now = match stop {
            StopRule::TrueError { x_star, eps } => {
                let delta = problem.prediction_error_ws(&x, x_star, &mut ws_d, &mut ws_n);
                report.error_trace.push(if delta0 > 0.0 { delta / delta0 } else { 0.0 });
                delta <= eps * delta0
            }
            StopRule::GradientNorm { tol } => norm2(&res) <= tol * g0_norm,
        };
        if stop_now {
            report.converged = true;
            break;
        }

        apply_pinv(&res, &mut z);
        let rz_new = dot(&res, &z);
        let beta = rz_new / rz_old;
        for i in 0..d {
            p[i] = z[i] + beta * p[i];
        }
        rz_old = rz_new;
    }

    if let StopRule::TrueError { x_star, eps } = stop {
        let delta = problem.prediction_error(&x, x_star);
        report.final_rel_error = Some(if delta0 > 0.0 { delta / delta0 } else { 0.0 });
        if delta0 > 0.0 && delta <= eps * delta0 {
            report.converged = true;
        }
    }
    report.iter_time_s = t_iter.elapsed().as_secs_f64();
    report.wall_time_s = start.elapsed().as_secs_f64();
    Solution { x, report }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::cg::{self, CgConfig};
    use crate::solvers::direct;
    use crate::solvers::test_util::small_problem;

    #[test]
    fn converges_to_direct_solution() {
        let p = small_problem(256, 16, 0.3, 1);
        let x_star = direct::solve(&p);
        let cfg = PcgConfig::new(SketchKind::Srht, 0.5);
        let stop = StopRule::TrueError { x_star: x_star.clone(), eps: 1e-10 };
        let sol = solve(&p, &vec![0.0; 16], &cfg, &stop, 1);
        assert!(sol.report.converged, "pcg failed to converge");
        assert!(sol.report.final_rel_error.unwrap() <= 1e-10);
    }

    #[test]
    fn fewer_iterations_than_cg_when_ill_conditioned() {
        let p = small_problem(512, 64, 1e-3, 2);
        let x_star = direct::solve(&p);
        let stop = StopRule::TrueError { x_star: x_star.clone(), eps: 1e-10 };
        let cg_sol = cg::solve(&p, &vec![0.0; 64], &CgConfig { max_iters: 5000 }, &stop);
        let pcg_cfg = PcgConfig::new(SketchKind::Srht, 0.5);
        let pcg_sol = solve(&p, &vec![0.0; 64], &pcg_cfg, &stop, 3);
        assert!(
            pcg_sol.report.iterations < cg_sol.report.iterations,
            "pcg {} vs cg {}",
            pcg_sol.report.iterations,
            cg_sol.report.iterations
        );
    }

    #[test]
    fn sketch_size_prescriptions() {
        // Gaussian: d/rho. SRHT: d log d / rho. Both capped at n.
        assert_eq!(pcg_sketch_size(SketchKind::Gaussian, 100_000, 100, 0.5), 200);
        let srht = pcg_sketch_size(SketchKind::Srht, 100_000, 100, 0.5);
        assert!(srht > 800 && srht < 1000, "srht m {srht}");
        assert_eq!(pcg_sketch_size(SketchKind::Gaussian, 150, 100, 0.1), 150);
    }

    #[test]
    fn gaussian_preconditioner_also_works() {
        let p = small_problem(256, 32, 0.1, 4);
        let x_star = direct::solve(&p);
        let cfg = PcgConfig::new(SketchKind::Gaussian, 0.5);
        let stop = StopRule::TrueError { x_star, eps: 1e-9 };
        let sol = solve(&p, &vec![0.0; 32], &cfg, &stop, 5);
        assert!(sol.report.converged);
    }

    #[test]
    fn reports_time_breakdown() {
        let p = small_problem(128, 16, 0.5, 6);
        let cfg = PcgConfig::new(SketchKind::Srht, 0.5);
        let stop = StopRule::GradientNorm { tol: 1e-10 };
        let sol = solve(&p, &vec![0.0; 16], &cfg, &stop, 7);
        let r = &sol.report;
        assert!(r.sketch_time_s >= 0.0 && r.factor_time_s > 0.0 && r.wall_time_s > 0.0);
        assert!(r.final_m >= 16);
    }

    #[test]
    fn deterministic_given_seed() {
        let p = small_problem(128, 16, 0.5, 7);
        let cfg = PcgConfig::new(SketchKind::Gaussian, 0.5);
        let stop = StopRule::GradientNorm { tol: 1e-10 };
        let a = solve(&p, &vec![0.0; 16], &cfg, &stop, 11);
        let b = solve(&p, &vec![0.0; 16], &cfg, &stop, 11);
        assert_eq!(a.x, b.x);
    }
}
