//! Conjugate gradient on the ridge normal equations (baseline).
//!
//! Per-iteration cost `O(nd)` dense / `O(nnz)` CSR (one `A` and one `A^T`
//! matvec through the [`crate::linalg::Operand`] dispatch); iteration
//! count scales with `sqrt(kappa)` of the augmented matrix — this is the
//! solver the paper beats except at very large `nu` (Figures 1–3).

use super::{RidgeProblem, Solution, SolveReport, StopRule};
use crate::linalg::{axpy, dot, norm2};
use std::time::Instant;

/// CG configuration. The stopping rule is not part of the config: it is
/// passed per-solve through the unified [`crate::solvers::api::Solver`]
/// call.
#[derive(Clone, Debug)]
pub struct CgConfig {
    /// Iteration cap (safety net; the stop rule fires first).
    pub max_iters: usize,
}

impl Default for CgConfig {
    fn default() -> Self {
        Self { max_iters: 10_000 }
    }
}

/// Run CG from `x0` on `(A^T A + nu^2 I) x = A^T b`.
///
/// The inner loop is allocation-free: the Hessian product, the stop-rule
/// prediction error and the direction update all write into workspace
/// buffers allocated once before the loop (`tests/alloc_free.rs` pins
/// this with a counting allocator).
pub fn solve(problem: &RidgeProblem, x0: &[f64], config: &CgConfig, stop: &StopRule) -> Solution {
    let start = Instant::now();
    let d = problem.d();
    assert_eq!(x0.len(), d);
    let mut report = SolveReport::new("cg");

    let mut x = x0.to_vec();
    // Residual of the linear system: r = A^T b - H x = -gradient(x).
    let mut r = problem.gradient(&x);
    crate::linalg::scale(-1.0, &mut r);
    let g0_norm = norm2(&r);
    // Workspace buffers reused across iterations.
    let mut ws_n: Vec<f64> = Vec::new();
    let mut ws_d: Vec<f64> = Vec::new();
    let mut hp = vec![0.0; d];
    let delta0 = match stop {
        StopRule::TrueError { x_star, .. } => {
            problem.prediction_error_ws(&x, x_star, &mut ws_d, &mut ws_n)
        }
        _ => 0.0,
    };
    if matches!(stop, StopRule::TrueError { .. }) {
        // Trace convention shared with the sketching solvers: entry t is
        // delta_t / delta_0, starting at the (trivially 1.0) initial point.
        report.error_trace.reserve(config.max_iters.min(65_536) + 1);
        report.error_trace.push(1.0);
    }

    let mut p = r.clone();
    let mut rs_old = dot(&r, &r);

    for t in 0..config.max_iters {
        if rs_old == 0.0 {
            report.converged = true;
            break;
        }
        problem.hessian_vec_into(&p, &mut ws_n, &mut hp);
        let alpha = rs_old / dot(&p, &hp);
        axpy(alpha, &p, &mut x);
        axpy(-alpha, &hp, &mut r);
        let rs_new = dot(&r, &r);
        report.iterations = t + 1;

        // Stop checks (negated residual == gradient up to sign).
        let stop_now = match stop {
            StopRule::TrueError { x_star, eps } => {
                let delta = problem.prediction_error_ws(&x, x_star, &mut ws_d, &mut ws_n);
                report.error_trace.push(if delta0 > 0.0 { delta / delta0 } else { 0.0 });
                delta <= eps * delta0
            }
            StopRule::GradientNorm { tol } => rs_new.sqrt() <= tol * g0_norm,
        };
        if stop_now {
            report.converged = true;
            break;
        }

        let beta = rs_new / rs_old;
        // p = r + beta p
        for i in 0..d {
            p[i] = r[i] + beta * p[i];
        }
        rs_old = rs_new;
    }

    if let StopRule::TrueError { x_star, eps } = stop {
        let delta = problem.prediction_error(&x, x_star);
        report.final_rel_error = Some(if delta0 > 0.0 { delta / delta0 } else { 0.0 });
        if delta0 > 0.0 && delta <= eps * delta0 {
            report.converged = true;
        }
    }
    let total = start.elapsed().as_secs_f64();
    report.wall_time_s = total;
    report.iter_time_s = total;
    Solution { x, report }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::direct;
    use crate::solvers::test_util::small_problem;

    #[test]
    fn converges_to_direct_solution() {
        let p = small_problem(128, 16, 0.5, 1);
        let x_star = direct::solve(&p);
        let stop = StopRule::GradientNorm { tol: 1e-12 };
        let sol = solve(&p, &vec![0.0; 16], &CgConfig::default(), &stop);
        assert!(sol.report.converged);
        for i in 0..16 {
            assert!((sol.x[i] - x_star[i]).abs() < 1e-7, "coord {i}");
        }
    }

    #[test]
    fn exact_in_d_iterations() {
        // CG on a d-dimensional quadratic terminates in <= d steps
        // (exact arithmetic; allow small slack).
        let p = small_problem(64, 8, 1.0, 2);
        let stop = StopRule::GradientNorm { tol: 1e-12 };
        let sol = solve(&p, &vec![0.0; 8], &CgConfig::default(), &stop);
        assert!(sol.report.iterations <= 10, "iters {}", sol.report.iterations);
    }

    #[test]
    fn true_error_stop_rule_records_full_trace() {
        let p = small_problem(128, 16, 0.2, 3);
        let x_star = direct::solve(&p);
        let stop = StopRule::TrueError { x_star: x_star.clone(), eps: 1e-8 };
        let sol = solve(&p, &vec![0.0; 16], &CgConfig { max_iters: 500 }, &stop);
        assert!(sol.report.converged);
        assert!(sol.report.final_rel_error.unwrap() <= 1e-8);
        // One relative error per iteration, plus the 1.0 at the start.
        let tr = &sol.report.error_trace;
        assert_eq!(tr.len(), sol.report.iterations + 1);
        assert_eq!(tr[0], 1.0);
        assert!(tr.last().unwrap() < &1e-8);
    }

    #[test]
    fn warm_start_faster_than_cold() {
        let p = small_problem(128, 32, 0.05, 4);
        let x_star = direct::solve(&p);
        let near: Vec<f64> = x_star.iter().map(|v| v * 0.999).collect();
        let stop = StopRule::TrueError { x_star: x_star.clone(), eps: 1e-9 };
        let cfg = CgConfig { max_iters: 1000 };
        let cold = solve(&p, &vec![0.0; 32], &cfg, &stop);
        let warm = solve(&p, &near, &cfg, &stop);
        assert!(warm.report.iterations <= cold.report.iterations);
    }

    #[test]
    fn ill_conditioning_slows_cg() {
        // Smaller nu => larger kappa => more iterations.
        let mk = |nu: f64, seed: u64| {
            let p = small_problem(256, 64, nu, seed);
            let x_star = direct::solve(&p);
            let stop = StopRule::TrueError { x_star, eps: 1e-10 };
            solve(&p, &vec![0.0; 64], &CgConfig { max_iters: 5000 }, &stop).report.iterations
        };
        let hard = mk(1e-3, 5);
        let easy = mk(10.0, 5);
        assert!(hard > easy, "hard {hard} <= easy {easy}");
    }
}
