//! Block multi-RHS IHS: solve `k` ridge systems that share one `A` (and
//! one `nu`) through a single BLAS-3 iteration.
//!
//! The sketched Hessian `H_S = (S̃A)^T (S̃A) + nu^2 I` depends only on
//! `(A, seed, nu)` — never on the right-hand side — so `k` systems
//! `H x_j = A^T b_j` can share one grown
//! [`SketchEngine`](crate::sketch::engine::SketchEngine) and one
//! [`WoodburyCache`]. Solving them jointly moves every hot operation
//! from matvec arithmetic intensity to a block product over a `d x k`
//! (or `n x k`) panel:
//!
//! * the gradient block `G = A^T (A X) + nu^2 X - A^T B` is two
//!   [`Operand::matmul`]/[`Operand::matmul_t`] calls (GEMM dense,
//!   `O(nnz k)` SpMM on CSR) instead of `k` GEMV sweeps;
//! * the preconditioned direction is one
//!   [`WoodburyCache::apply_inverse_block`] (GEMM + multi-column
//!   Cholesky solve) instead of `k` vector applies.
//!
//! The iteration is the gradient-IHS schedule (the paper's §5
//! gradient-only variant — per-column Polyak histories would need
//! per-column geometric-mean bookkeeping for no measured gain in the
//! serving regime): every active column takes `x_j <- x_j - mu_gd g̃_j`,
//! and the sketched Newton decrement `r_j = 1/2 g_j^T H_S^{-1} g_j` is
//! monitored **per column**. When any active column misses the `c_gd`
//! one-step target the shared sketch grows (all columns benefit from the
//! extra rows; at the `next_pow2(n)` cap the cache holds the exact
//! Hessian and steps are damped Newton, so the block cannot live-lock).
//! Convergence is tracked per column with the same *cold-referenced*
//! gradient-norm stop the session's single-RHS path uses
//! (`||g_j|| <= eps * ||A^T b_j||`); converged columns are retired from
//! the active set immediately — they drop out of every subsequent GEMM —
//! so a batch with a few hard columns narrows instead of paying `k`-wide
//! iterations to the end.
//!
//! Amortizing one factorization across many solves is the regime of
//! Lacotte & Pilanci's adaptive sketching preconditioners
//! (arXiv:2104.14101); reusing a single embedding across all columns is
//! justified by the SRHT analysis of Lacotte, Dobriban & Liu
//! (arXiv:2002.00864), whose quality parameters depend only on
//! `(n, d, m)`, not on the right-hand side.
//!
//! Surfaced as [`ModelSession::solve_block`] and, over the wire, as the
//! `query` command's `"bs"` batch (PROTOCOL.md).
//!
//! # Writer lane only
//!
//! Block solves always run on the **writer lane** (under the session
//! lock): they grow the shared sketch and are not covered by the frozen
//! read lane ([`solve_frozen`](super::adaptive::solve_frozen) /
//! `SessionSnapshot::solve_frozen`), which serves single-`nu`,
//! model-`b` queries from pinned immutable artifacts. The two lanes
//! compose safely through the copy-on-write seam:
//! `AdaptiveSessionState::into_parts` hands this module an *owned*
//! [`WoodburyCache`] (cloning the panel only if a published snapshot
//! still shares it), so block-wide growth here never mutates a
//! [`GramPanel`](super::woodbury::GramPanel) that a concurrent frozen
//! solve is reading.
//!
//! # Failure semantics
//!
//! [`solve_block`] never panics on bad input or numerical breakdown: it
//! returns a structured [`SolverError`] instead. Malformed arguments
//! (non-positive or non-finite `nu`/`eps`, shape mismatches, stale
//! resume state) are [`SolverError::InvalidInput`] and are rejected
//! before any work happens. Numerical breakdown mid-solve climbs the
//! same recovery ladder as the single-RHS adaptive solver — retry with
//! jitter (inside the Cholesky), re-sketch the offending block fresh,
//! fall back to the exact Hessian — and the highest rung climbed is
//! recorded in every per-column [`SolveReport::recovery`]. Only when the
//! exact fallback itself fails does the solve return
//! [`SolverError::NumericalBreakdown`].
//!
//! [`ModelSession::solve_block`]: crate::solvers::session::ModelSession::solve_block

use super::adaptive::{AdaptiveConfig, AdaptiveSessionState};
use super::error::{RecoveryRung, SolverError};
use super::woodbury::WoodburyCache;
use super::{Solution, SolveReport};
use crate::linalg::{Matrix, Operand};
use crate::rng::Xoshiro256;
use crate::sketch::engine::SketchEngine;
use crate::util::failpoint;
use std::time::Instant;

/// Result of a block solve: one [`Solution`] per right-hand-side column
/// (input order) plus the possibly-grown session state, handed back so
/// the next solve on the same data resumes instead of re-sketching.
pub struct BlockOutcome {
    /// Per-column solutions, in input column order.
    pub solutions: Vec<Solution>,
    /// Sketch / factorization / RNG state for the next resumed solve.
    pub state: AdaptiveSessionState,
}

/// Per-column dot products of two equal-shape row-major blocks:
/// `out[j] = sum_i a[i][j] * b[i][j]` — one cache-friendly pass over the
/// rows accumulates all `k` column dots at once.
fn column_dots(a: &Matrix, b: &Matrix) -> Vec<f64> {
    debug_assert_eq!((a.rows(), a.cols()), (b.rows(), b.cols()));
    let k = a.cols();
    let mut out = vec![0.0; k];
    for i in 0..a.rows() {
        let (ra, rb) = (a.row(i), b.row(i));
        for j in 0..k {
            out[j] += ra[j] * rb[j];
        }
    }
    out
}

/// Copy the selected columns of `src` into a fresh (narrower) block.
fn gather_columns(src: &Matrix, cols: &[usize]) -> Matrix {
    Matrix::from_fn(src.rows(), cols.len(), |i, jj| src.get(i, cols[jj]))
}

/// Block ridge gradient `G = A^T (A X) + nu^2 X - AtB` over the active
/// `d x k` panel: two block products plus one fused row pass.
fn block_gradient(a: &Operand, nu2: f64, x: &Matrix, atb: &Matrix) -> Matrix {
    let r = a.matmul(x); // n x k
    let mut g = a.matmul_t(&r); // d x k
    for i in 0..g.rows() {
        let xr = x.row(i);
        let br = atb.row(i);
        let gr = g.row_mut(i);
        for j in 0..gr.len() {
            gr[j] += nu2 * xr[j] - br[j];
        }
    }
    g
}

/// Build a fresh sketch engine + factored cache at `m` rows — the
/// cold-start path and the ladder's re-sketch rung share this.
fn fresh_parts(
    config: &AdaptiveConfig,
    m: usize,
    a: &Operand,
    nu: f64,
    rng: &mut Xoshiro256,
    sketch_time: &mut f64,
    factor_time: &mut f64,
) -> Result<(Option<SketchEngine>, WoodburyCache), SolverError> {
    let t0 = Instant::now();
    let engine = SketchEngine::new(config.kind, m, a, rng);
    *sketch_time += t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let cache =
        WoodburyCache::new_scaled(engine.sa_unnormalized().clone(), nu, engine.scale())?;
    *factor_time += t0.elapsed().as_secs_f64();
    Ok((Some(engine), cache))
}

/// Drop sketching entirely: factor the exact Hessian. Used both as the
/// algorithm's own at-cap path and as the ladder's last rung.
fn exact_parts(
    a: &Operand,
    nu: f64,
    sketch_time: &mut f64,
    factor_time: &mut f64,
) -> Result<(Option<SketchEngine>, WoodburyCache), SolverError> {
    let t0 = Instant::now();
    let sa = a.dense().into_owned();
    *sketch_time += t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let cache = WoodburyCache::new(sa, nu)?;
    *factor_time += t0.elapsed().as_secs_f64();
    Ok((None, cache))
}

/// Solve the `k` systems `(A^T A + nu^2 I) x_j = atb_j` (columns of the
/// `d x k` block `atb`) jointly, from zero starts, to the cold-referenced
/// per-column tolerance `||g_j|| <= eps * ||atb_j||`.
///
/// `state` resumes a previous solve's sketch (zero sketch application;
/// only [`WoodburyCache::set_nu`]'s re-factor is paid when `nu` changed);
/// `None` builds a fresh engine at `config.m_initial` from `seed`. The
/// returned per-column [`SolveReport`]s share the block's sketch/factor/
/// wall time buckets (the work is genuinely shared — the buckets are not
/// additive across columns) while `iterations`, `rejections`,
/// `doublings` and `converged` are tracked per column.
pub fn solve_block(
    a: &Operand,
    nu: f64,
    atb: &Matrix,
    eps: f64,
    config: &AdaptiveConfig,
    state: Option<AdaptiveSessionState>,
    seed: u64,
) -> Result<BlockOutcome, SolverError> {
    let created = Instant::now();
    let d = a.cols();
    let k = atb.cols();
    if atb.rows() != d {
        return Err(SolverError::invalid(format!(
            "atb block must be d x k: got {} rows for d = {d}",
            atb.rows()
        )));
    }
    if !(nu > 0.0 && nu.is_finite()) {
        return Err(SolverError::invalid(format!("invalid nu: {nu}")));
    }
    if !(eps > 0.0 && eps.is_finite()) {
        return Err(SolverError::invalid(format!("invalid eps: {eps}")));
    }
    let nu2 = nu * nu;
    let params = config.params();
    let mut m_cap = crate::sketch::srht::next_pow2(a.rows());

    let mut sketch_time = 0.0f64;
    let mut factor_time = 0.0f64;
    let mut recovery = RecoveryRung::None;

    let (mut engine, mut cache, mut rng, mut m) = match state {
        Some(st) => {
            let (mut engine, mut cache, mut rng) = st.into_parts();
            // A resumed engine may carry its own sampling capacity
            // (streamed SRHT appends): cap growth at its max_m, with the
            // same exact-Hessian fallback at the cap.
            if let Some(e) = &engine {
                m_cap = m_cap.min(e.max_m());
            }
            if let Some(e) = &engine {
                if e.kind() != config.kind {
                    return Err(SolverError::invalid("resume: sketch family changed"));
                }
                if e.n() != a.rows() {
                    return Err(SolverError::invalid("resume: problem shape changed"));
                }
                if e.m() != cache.m() {
                    return Err(SolverError::invalid(
                        "resume: engine/cache row counts diverged",
                    ));
                }
            }
            if cache.d() != d {
                return Err(SolverError::invalid("resume: problem shape changed"));
            }
            let mut m = engine.as_ref().map_or(m_cap, SketchEngine::m);
            let t0 = Instant::now();
            let rekeyed = cache.set_nu(nu);
            factor_time += t0.elapsed().as_secs_f64();
            match rekeyed {
                Ok(()) => recovery.escalate(cache.recovery()),
                Err(e @ SolverError::InvalidInput(_)) => return Err(e),
                Err(_) => {
                    // Ladder: the resumed factorization broke — re-sketch
                    // the block fresh at the same m, else go exact.
                    match fresh_parts(
                        config,
                        m,
                        a,
                        nu,
                        &mut rng,
                        &mut sketch_time,
                        &mut factor_time,
                    ) {
                        Ok((e2, c2)) => {
                            engine = e2;
                            cache = c2;
                            recovery.escalate(RecoveryRung::Resketch);
                        }
                        Err(e @ SolverError::InvalidInput(_)) => return Err(e),
                        Err(_) => {
                            let (e2, c2) =
                                exact_parts(a, nu, &mut sketch_time, &mut factor_time)
                                    .map_err(|err| {
                                        SolverError::breakdown(format!(
                                            "recovery ladder exhausted: {err}"
                                        ))
                                    })?;
                            engine = e2;
                            cache = c2;
                            m = m_cap;
                            recovery.escalate(RecoveryRung::Exact);
                        }
                    }
                }
            }
            (engine, cache, rng, m)
        }
        None => {
            let mut rng = Xoshiro256::seed_from_u64(seed);
            let m = config.m_initial.min(m_cap);
            match fresh_parts(config, m, a, nu, &mut rng, &mut sketch_time, &mut factor_time)
            {
                Ok((engine, cache)) => {
                    recovery.escalate(cache.recovery());
                    (engine, cache, rng, m)
                }
                Err(e @ SolverError::InvalidInput(_)) => return Err(e),
                Err(_) => {
                    let (engine, cache) =
                        exact_parts(a, nu, &mut sketch_time, &mut factor_time).map_err(
                            |err| {
                                SolverError::breakdown(format!(
                                    "recovery ladder exhausted: {err}"
                                ))
                            },
                        )?;
                    recovery.escalate(RecoveryRung::Exact);
                    (engine, cache, rng, m_cap)
                }
            }
        }
    };

    let label = format!("block-adaptive-{}", config.kind);
    let mut reports: Vec<SolveReport> =
        (0..k).map(|_| SolveReport::new(label.clone())).collect();
    // Final iterates; column j is written when it retires (or at the cap).
    let mut x_full = Matrix::zeros(d, k);

    // Cold-referenced per-column targets: ||g_j|| <= eps * ||atb_j|| — the
    // criterion a from-zero single-RHS session solve uses (g(0) = -atb).
    let atb_norms: Vec<f64> = column_dots(atb, atb).iter().map(|v| v.sqrt()).collect();
    let tols: Vec<f64> = atb_norms.iter().map(|&v| eps * v).collect();

    // Columns whose gradient at zero already meets the target (b_j with
    // A^T b_j = 0, or eps >= 1) are optimal at x = 0 and never enter the
    // active set.
    let mut active: Vec<usize> = Vec::new();
    for (j, (&norm, &tol)) in atb_norms.iter().zip(&tols).enumerate() {
        if norm <= tol {
            reports[j].converged = true;
        } else {
            active.push(j);
        }
    }

    // Active-panel state (gathered columns of the full problem).
    let mut x_act = Matrix::zeros(d, active.len());
    let mut atb_act = gather_columns(atb, &active);
    // g(0) = -atb.
    let mut g_act = {
        let mut g = atb_act.clone();
        crate::linalg::scale(-1.0, g.as_mut_slice());
        g
    };
    let mut gt_act = cache.apply_inverse_block(&g_act);
    let mut r_act: Vec<f64> =
        column_dots(&g_act, &gt_act).iter().map(|v| 0.5 * v).collect();

    let mut iter = 0usize;
    while !active.is_empty() && iter < config.max_iters {
        failpoint::check("block.iterate").map_err(SolverError::Internal)?;
        if let Some(deadline) = config.deadline {
            if Instant::now() >= deadline {
                return Err(SolverError::DeadlineExceeded(format!(
                    "block solve passed its wall deadline after {iter} accepted iterations"
                )));
            }
        }
        // --- gradient-IHS candidate over the whole active panel ---
        let mut x_cand = x_act.clone();
        x_cand.add_scaled(-params.mu_gd, &gt_act);
        let mut g_cand = block_gradient(a, nu2, &x_cand, &atb_act);
        let mut gt_cand = cache.apply_inverse_block(&g_cand);
        let mut r_cand: Vec<f64> =
            column_dots(&g_cand, &gt_cand).iter().map(|v| 0.5 * v).collect();
        let gnorm_cand: Vec<f64> =
            column_dots(&g_cand, &g_cand).iter().map(|v| v.sqrt()).collect();

        // --- retire columns whose candidate already meets its target:
        // they accept their step immediately (per-column acceptance) and
        // drop out of every subsequent block product — including any
        // growth re-evaluation and retried candidate below, which they
        // must neither pay for nor be billed rejections/doublings for.
        let keep_local: Vec<usize> = {
            let mut keep = Vec::with_capacity(active.len());
            for (jj, &j) in active.iter().enumerate() {
                if gnorm_cand[jj] <= tols[j] {
                    reports[j].converged = true;
                    reports[j].iterations += 1;
                    for i in 0..d {
                        x_full.set(i, j, x_cand.get(i, jj));
                    }
                } else {
                    keep.push(jj);
                }
            }
            keep
        };
        if keep_local.len() != active.len() {
            active = keep_local.iter().map(|&jj| active[jj]).collect();
            if active.is_empty() {
                break;
            }
            x_act = gather_columns(&x_act, &keep_local);
            x_cand = gather_columns(&x_cand, &keep_local);
            g_act = gather_columns(&g_act, &keep_local);
            g_cand = gather_columns(&g_cand, &keep_local);
            gt_cand = gather_columns(&gt_cand, &keep_local);
            atb_act = gather_columns(&atb_act, &keep_local);
            r_act = keep_local.iter().map(|&jj| r_act[jj]).collect();
            r_cand = keep_local.iter().map(|&jj| r_cand[jj]).collect();
            // gt_act is not regathered: the accept path replaces it with
            // gt_cand and the grow path recomputes it from g_act.
        }

        // --- acceptance over the surviving panel: every column's
        // one-step decrement ratio must meet c_gd (a decrement at
        // floating-point zero passes trivially) ---
        let all_pass = (0..active.len())
            .all(|jj| r_act[jj] <= 0.0 || r_cand[jj] <= params.c_gd * r_act[jj]);
        if !(all_pass || m >= m_cap) {
            // --- grow the shared sketch (steps 14-15, block-wide) ---
            for &j in &active {
                reports[j].rejections += 1;
                reports[j].doublings += 1;
            }
            let new_m = (m * config.growth).min(m_cap);
            if new_m >= m_cap {
                // At the cap, drop sketching: the cache holds the exact
                // Hessian and forced steps are damped exact-Newton (same
                // fallback as the single-RHS adaptive solver). This is
                // the algorithm's own path, not a fault — no rung.
                let (e2, c2) = exact_parts(a, nu, &mut sketch_time, &mut factor_time)?;
                engine = e2;
                cache = c2;
                m = new_m;
            } else {
                let grown: Result<(), SolverError> = (|| {
                    let e = engine.as_mut().ok_or_else(|| {
                        SolverError::breakdown("sketch engine dropped before the cap")
                    })?;
                    let t0 = Instant::now();
                    let rows = e.grow(new_m, a, &mut rng)?;
                    sketch_time += t0.elapsed().as_secs_f64();
                    let t0 = Instant::now();
                    cache.grow(&rows, e.scale())?;
                    factor_time += t0.elapsed().as_secs_f64();
                    Ok(())
                })();
                match grown {
                    Ok(()) => {
                        recovery.escalate(cache.recovery());
                        m = new_m;
                    }
                    Err(e @ SolverError::InvalidInput(_)) => return Err(e),
                    Err(_) => {
                        // Ladder: the grown sketch (or its bordered
                        // re-factor) broke — re-sketch fresh at the
                        // target size, else go exact. Either way the
                        // engine/cache pair is rebuilt consistently.
                        match fresh_parts(
                            config,
                            new_m,
                            a,
                            nu,
                            &mut rng,
                            &mut sketch_time,
                            &mut factor_time,
                        ) {
                            Ok((e2, c2)) => {
                                engine = e2;
                                cache = c2;
                                recovery.escalate(RecoveryRung::Resketch);
                                m = new_m;
                            }
                            Err(e @ SolverError::InvalidInput(_)) => return Err(e),
                            Err(_) => {
                                let (e2, c2) =
                                    exact_parts(a, nu, &mut sketch_time, &mut factor_time)
                                        .map_err(|err| {
                                            SolverError::breakdown(format!(
                                                "recovery ladder exhausted: {err}"
                                            ))
                                        })?;
                                engine = e2;
                                cache = c2;
                                recovery.escalate(RecoveryRung::Exact);
                                m = m_cap;
                            }
                        }
                    }
                }
            }
            // Unchanged gradients, new geometry: re-evaluate the
            // preconditioned panel and retry the same iteration.
            gt_act = cache.apply_inverse_block(&g_act);
            r_act = column_dots(&g_act, &gt_act).iter().map(|v| 0.5 * v).collect();
            continue;
        }

        // --- accept the block step for the remaining columns ---
        iter += 1;
        x_act = x_cand;
        g_act = g_cand;
        gt_act = gt_cand;
        r_act = r_cand;
        for &j in &active {
            reports[j].iterations += 1;
        }
    }

    // Iteration-cap leftovers: record the current iterates, unconverged.
    for (jj, &j) in active.iter().enumerate() {
        for i in 0..d {
            x_full.set(i, j, x_act.get(i, jj));
        }
    }

    let wall = created.elapsed().as_secs_f64();
    for rep in &mut reports {
        rep.final_m = m;
        rep.peak_m = m;
        rep.recovery = recovery;
        rep.sketch_time_s = sketch_time;
        rep.factor_time_s = factor_time;
        rep.wall_time_s = wall;
        rep.iter_time_s = wall - sketch_time - factor_time;
    }

    let solutions = reports
        .into_iter()
        .enumerate()
        .map(|(j, report)| Solution {
            x: (0..d).map(|i| x_full.get(i, j)).collect(),
            report,
        })
        .collect();

    Ok(BlockOutcome {
        solutions,
        state: AdaptiveSessionState::from_parts(engine, cache, rng),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::sketch::SketchKind;
    use crate::solvers::{direct, RidgeProblem};

    fn batch(n: usize, k: usize) -> (Matrix, Vec<Vec<f64>>) {
        let bs: Vec<Vec<f64>> = (0..k)
            .map(|j| (0..n).map(|i| ((i as f64 + 1.0) * (j as f64 + 0.7) * 0.11).sin()).collect())
            .collect();
        let mut bmat = Matrix::zeros(n, k);
        for (j, b) in bs.iter().enumerate() {
            for (i, &v) in b.iter().enumerate() {
                bmat.set(i, j, v);
            }
        }
        (bmat, bs)
    }

    #[test]
    fn cold_block_solve_matches_direct_per_column() {
        let ds = synthetic::exponential_decay(256, 32, 1);
        let a = Operand::from(ds.a.dense().into_owned());
        let (bmat, bs) = batch(256, 4);
        let atb = a.matmul_t(&bmat);
        let cfg = AdaptiveConfig::new(SketchKind::Gaussian);
        let out = solve_block(&a, 0.5, &atb, 1e-10, &cfg, None, 3).unwrap();
        assert_eq!(out.solutions.len(), 4);
        for (j, sol) in out.solutions.iter().enumerate() {
            assert!(sol.report.converged, "column {j} did not converge");
            assert_eq!(sol.report.solver, "block-adaptive-gaussian");
            assert_eq!(sol.report.recovery, RecoveryRung::None);
            let p = RidgeProblem::new(a.clone(), bs[j].clone(), 0.5);
            let x_star = direct::solve(&p);
            let rel = p.prediction_error(&sol.x, &x_star)
                / p.prediction_error(&vec![0.0; 32], &x_star);
            assert!(rel < 1e-8, "column {j}: relative error {rel}");
        }
        assert!(out.state.m() >= 1);
    }

    #[test]
    fn zero_rhs_column_is_immediately_optimal() {
        let ds = synthetic::exponential_decay(128, 16, 2);
        let a = Operand::from(ds.a.dense().into_owned());
        let (mut bmat, _) = batch(128, 3);
        for i in 0..128 {
            bmat.set(i, 1, 0.0); // middle column: b = 0 -> x* = 0
        }
        let atb = a.matmul_t(&bmat);
        let cfg = AdaptiveConfig::new(SketchKind::Gaussian);
        let out = solve_block(&a, 0.8, &atb, 1e-9, &cfg, None, 5).unwrap();
        assert!(out.solutions[1].report.converged);
        assert_eq!(out.solutions[1].report.iterations, 0);
        assert!(out.solutions[1].x.iter().all(|&v| v == 0.0));
        assert!(out.solutions[0].report.converged && out.solutions[2].report.converged);
    }

    #[test]
    fn resumed_block_solve_applies_zero_sketch() {
        let ds = synthetic::exponential_decay(256, 32, 4);
        let a = Operand::from(ds.a.dense().into_owned());
        let (bmat, _) = batch(256, 3);
        let atb = a.matmul_t(&bmat);
        let cfg = AdaptiveConfig::new(SketchKind::Gaussian);
        // First block solve grows the sketch from m_initial.
        let first = solve_block(&a, 0.3, &atb, 1e-9, &cfg, None, 7).unwrap();
        assert!(first.solutions.iter().all(|s| s.report.converged));
        let m1 = first.state.m();
        // Resume at a larger nu: cached rows suffice — zero sketch work.
        let second = solve_block(&a, 1.0, &atb, 1e-9, &cfg, Some(first.state), 7).unwrap();
        for sol in &second.solutions {
            assert!(sol.report.converged);
            assert_eq!(sol.report.sketch_time_s, 0.0, "resume must not re-sketch");
            assert_eq!(sol.report.doublings, 0);
        }
        assert_eq!(second.state.m(), m1);
    }

    #[test]
    fn invalid_inputs_are_structured_errors() {
        let ds = synthetic::exponential_decay(64, 8, 9);
        let a = Operand::from(ds.a.dense().into_owned());
        let (bmat, _) = batch(64, 2);
        let atb = a.matmul_t(&bmat);
        let cfg = AdaptiveConfig::new(SketchKind::Gaussian);
        for bad_nu in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            let err = solve_block(&a, bad_nu, &atb, 1e-9, &cfg, None, 3).unwrap_err();
            assert!(
                matches!(err, SolverError::InvalidInput(_)),
                "nu = {bad_nu} gave {err}"
            );
        }
        for bad_eps in [0.0, -1e-9, f64::NAN] {
            let err = solve_block(&a, 0.5, &atb, bad_eps, &cfg, None, 3).unwrap_err();
            assert!(matches!(err, SolverError::InvalidInput(_)));
        }
        let wide = Matrix::zeros(7, 2); // wrong row count for d = 8
        let err = solve_block(&a, 0.5, &wide, 1e-9, &cfg, None, 3).unwrap_err();
        assert!(matches!(err, SolverError::InvalidInput(_)));
    }

    #[test]
    fn expired_deadline_is_a_structured_error() {
        let ds = synthetic::exponential_decay(128, 16, 11);
        let a = Operand::from(ds.a.dense().into_owned());
        let (bmat, _) = batch(128, 2);
        let atb = a.matmul_t(&bmat);
        let mut cfg = AdaptiveConfig::new(SketchKind::Gaussian);
        cfg.deadline = Some(Instant::now());
        let err = solve_block(&a, 0.5, &atb, 1e-9, &cfg, None, 3).unwrap_err();
        assert!(matches!(err, SolverError::DeadlineExceeded(_)), "got {err}");
    }
}
