//! Random embeddings `S in R^{m x n}`.
//!
//! Three families, matching the paper:
//! * [`gaussian`] — i.i.d. `N(0, 1/m)` entries (§3.1, Theorem 3). `SA`
//!   costs `O(m n d)` via GEMM.
//! * [`srht`] — Subsampled Randomized Hadamard Transform (§3.2, Theorem 4):
//!   `S = R H diag(eps)` with `H` the normalized Walsh–Hadamard transform.
//!   `SA` costs `O(n d log n)` through the in-place FWHT.
//! * [`sparse`] — CountSketch / SJLT (Remark 4.1, listed as future work in
//!   the paper): `SA` costs `O(nnz(A))`.
//!
//! All embeddings implement [`Sketch`], which exposes the only operation
//! the solvers need — *apply to a matrix* — plus metadata. Sketches are
//! deterministic given an RNG stream, so experiments are reproducible.

pub mod gaussian;
pub mod sparse;
pub mod srht;

use crate::linalg::Matrix;
use crate::rng::Xoshiro256;

/// Which embedding family to use. Mirrors the paper's two analyzed sketches
/// plus the sparse extension.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SketchKind {
    Gaussian,
    Srht,
    Sparse,
}

impl std::fmt::Display for SketchKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SketchKind::Gaussian => write!(f, "gaussian"),
            SketchKind::Srht => write!(f, "srht"),
            SketchKind::Sparse => write!(f, "sparse"),
        }
    }
}

impl std::str::FromStr for SketchKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "gaussian" | "g" => Ok(SketchKind::Gaussian),
            "srht" | "hadamard" | "h" => Ok(SketchKind::Srht),
            "sparse" | "countsketch" | "sjlt" => Ok(SketchKind::Sparse),
            other => Err(format!("unknown sketch kind: {other}")),
        }
    }
}

/// A sampled random embedding `S in R^{m x n}`.
pub trait Sketch {
    /// Sketch dimension `m`.
    fn m(&self) -> usize;
    /// Ambient dimension `n`.
    fn n(&self) -> usize;
    /// Compute `S * a` for an `n x d` matrix `a`.
    fn apply(&self, a: &Matrix) -> Matrix;
    /// Materialize `S` as a dense matrix (tests / diagnostics only).
    fn to_dense(&self) -> Matrix {
        self.apply(&Matrix::eye(self.n()))
    }
}

/// Sample a sketch of the given family. `rng` is advanced.
pub fn sample(kind: SketchKind, m: usize, n: usize, rng: &mut Xoshiro256) -> Box<dyn Sketch + Send + Sync> {
    match kind {
        SketchKind::Gaussian => Box::new(gaussian::GaussianSketch::sample(m, n, rng)),
        SketchKind::Srht => Box::new(srht::SrhtSketch::sample(m, n, rng)),
        SketchKind::Sparse => Box::new(sparse::SparseSketch::sample(m, n, rng)),
    }
}

/// Flop-count model for forming `SA` (used by the complexity harness,
/// Theorem 7): Gaussian `2mnd`, SRHT `nd log2(n~) + md`, sparse
/// `2 nnz(A)`. The sparse model needs the input's nonzero count; pass
/// `nnz = None` for dense data (where `nnz(A) = n d`).
pub fn sketch_cost_flops(kind: SketchKind, m: usize, n: usize, d: usize, nnz: Option<usize>) -> f64 {
    let (mf, nf, df) = (m as f64, n as f64, d as f64);
    match kind {
        SketchKind::Gaussian => 2.0 * mf * nf * df,
        SketchKind::Srht => {
            let np = (n.max(2) as f64).log2().ceil();
            nf * df * np + mf * df
        }
        SketchKind::Sparse => 2.0 * nnz.map(|z| z as f64).unwrap_or(nf * df),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_roundtrip_display_parse() {
        for k in [SketchKind::Gaussian, SketchKind::Srht, SketchKind::Sparse] {
            let s = k.to_string();
            assert_eq!(s.parse::<SketchKind>().unwrap(), k);
        }
        assert!("bogus".parse::<SketchKind>().is_err());
    }

    #[test]
    fn sample_dispatch_shapes() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        for k in [SketchKind::Gaussian, SketchKind::Srht, SketchKind::Sparse] {
            let s = sample(k, 4, 16, &mut rng);
            assert_eq!(s.m(), 4);
            assert_eq!(s.n(), 16);
            let a = Matrix::eye(16);
            let sa = s.apply(&a);
            assert_eq!((sa.rows(), sa.cols()), (4, 16));
        }
    }

    #[test]
    fn cost_model_orderings() {
        // SRHT must beat Gaussian for large m, sparse beats both.
        let (m, n, d) = (512, 4096, 256);
        let g = sketch_cost_flops(SketchKind::Gaussian, m, n, d, None);
        let h = sketch_cost_flops(SketchKind::Srht, m, n, d, None);
        let s = sketch_cost_flops(SketchKind::Sparse, m, n, d, None);
        assert!(h < g);
        assert!(s < h);
    }

    #[test]
    fn sparse_cost_scales_with_nnz() {
        // 2 * nnz(A), not 2 * n * d: a 1%-dense matrix must cost 1% of
        // the dense fallback.
        let (m, n, d) = (512, 4096, 256);
        let dense = sketch_cost_flops(SketchKind::Sparse, m, n, d, None);
        let sparse = sketch_cost_flops(SketchKind::Sparse, m, n, d, Some(n * d / 100));
        assert_eq!(dense, 2.0 * (n * d) as f64);
        assert_eq!(sparse, 2.0 * (n * d / 100) as f64);
        // nnz does not affect the dense-data families.
        let g = sketch_cost_flops(SketchKind::Gaussian, m, n, d, Some(1));
        assert_eq!(g, 2.0 * (m * n * d) as f64);
    }
}
