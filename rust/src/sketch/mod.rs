//! Random embeddings `S in R^{m x n}`.
//!
//! Three families, matching the paper:
//! * [`gaussian`] — i.i.d. `N(0, 1/m)` entries (§3.1, Theorem 3). `SA`
//!   costs `O(m n d)` via GEMM.
//! * [`srht`] — Subsampled Randomized Hadamard Transform (§3.2, Theorem 4):
//!   `S = R H diag(eps)` with `H` the normalized Walsh–Hadamard transform.
//!   `SA` costs `O(n d log n)` through the in-place FWHT.
//! * [`sparse`] — CountSketch / SJLT (Remark 4.1, listed as future work in
//!   the paper): `SA` costs `O(nnz(A))`.
//!
//! All embeddings implement [`Sketch`], which exposes the only operation
//! the solvers need — *apply to an operand* (dense `O(mnd)` /
//! `O(ñ d log ñ)` / `O(nd)` per family, CSR `O(m nnz)` /
//! `O(nnz + ñ d log ñ)` / `O(nnz)`) — plus metadata. Sketches are
//! deterministic given an RNG stream, so experiments are reproducible,
//! and the dense and CSR paths of one sampled sketch agree to roundoff.
//!
//! # Incremental growth
//!
//! The adaptive solver grows `m` by doubling; re-sampling and re-applying
//! `S` from scratch on every growth would cost `O(m n d)` / `O(ñ d log ñ)`
//! per rejection round. [`engine::SketchEngine`] instead keeps per-problem
//! state (Gaussian RNG block snapshots, the FWHT'd SRHT work buffer, the
//! CountSketch blocks) and appends only `Δm` rows per growth. Its contract: stored
//! rows of `S̃A` are *unnormalized* and append-only (a grown sketch agrees
//! bitwise with its own pre-growth prefix), while the `1/sqrt(m)`-style
//! normalization is reported separately via `SketchEngine::scale` and
//! folded into the Woodbury solve. See the engine docs for the per-family
//! growth costs and distribution guarantees.

pub mod engine;
pub mod gaussian;
pub mod sparse;
pub mod srht;

use crate::linalg::sparse::CsrMatrix;
use crate::linalg::{Matrix, Operand};
use crate::rng::Xoshiro256;

/// Which embedding family to use. Mirrors the paper's two analyzed sketches
/// plus the sparse extension.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SketchKind {
    /// i.i.d. `N(0, 1/m)` entries (§3.1, Theorem 3).
    Gaussian,
    /// Subsampled Randomized Hadamard Transform (§3.2, Theorem 4).
    Srht,
    /// CountSketch / SJLT (Remark 4.1).
    Sparse,
}

impl std::fmt::Display for SketchKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SketchKind::Gaussian => write!(f, "gaussian"),
            SketchKind::Srht => write!(f, "srht"),
            SketchKind::Sparse => write!(f, "sparse"),
        }
    }
}

impl std::str::FromStr for SketchKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "gaussian" | "g" => Ok(SketchKind::Gaussian),
            "srht" | "hadamard" | "h" => Ok(SketchKind::Srht),
            "sparse" | "countsketch" | "sjlt" => Ok(SketchKind::Sparse),
            other => Err(format!("unknown sketch kind: {other}")),
        }
    }
}

/// A sampled random embedding `S in R^{m x n}`.
pub trait Sketch {
    /// Sketch dimension `m`.
    fn m(&self) -> usize;
    /// Ambient dimension `n`.
    fn n(&self) -> usize;
    /// Compute `S * a` for an `n x d` matrix `a`.
    fn apply(&self, a: &Matrix) -> Matrix;
    /// Compute `S * a` for CSR input at the family's sparse cost:
    /// `O(m * nnz)` Gaussian (sparse row-axpy), `O(nnz + ñ d log ñ)` SRHT
    /// (scatter the sign-flipped rows once, then the usual FWHT),
    /// `O(nnz)` CountSketch. Never densifies the operand.
    fn apply_csr(&self, a: &CsrMatrix) -> Matrix;
    /// Dispatch on the operand variant — what the solvers call.
    fn apply_operand(&self, a: &Operand) -> Matrix {
        match a {
            Operand::Dense(m) => self.apply(m),
            Operand::Sparse(c) => self.apply_csr(c),
        }
    }
    /// Materialize `S` as a dense matrix (tests / diagnostics only).
    fn to_dense(&self) -> Matrix {
        self.apply(&Matrix::eye(self.n()))
    }
}

/// Sample a sketch of the given family. `rng` is advanced.
pub fn sample(kind: SketchKind, m: usize, n: usize, rng: &mut Xoshiro256) -> Box<dyn Sketch + Send + Sync> {
    match kind {
        SketchKind::Gaussian => Box::new(gaussian::GaussianSketch::sample(m, n, rng)),
        SketchKind::Srht => Box::new(srht::SrhtSketch::sample(m, n, rng)),
        SketchKind::Sparse => Box::new(sparse::SparseSketch::sample(m, n, rng)),
    }
}

/// Flop-count model for forming `SA` from scratch (used by the complexity
/// harness, Theorem 7): Gaussian `2mnd`, SRHT `ñ d log2(ñ) + m d` with
/// `ñ = next_pow2(n)` (the FWHT runs over the *padded* row dimension — a
/// non-power-of-two `n` pays for the zero-padded transform), sparse
/// `2 nnz(A)`. The sparse model needs the input's nonzero count; pass
/// `nnz = None` for dense data (where `nnz(A) = n d`).
pub fn sketch_cost_flops(kind: SketchKind, m: usize, n: usize, d: usize, nnz: Option<usize>) -> f64 {
    let (mf, nf, df) = (m as f64, n as f64, d as f64);
    match kind {
        SketchKind::Gaussian => 2.0 * mf * nf * df,
        SketchKind::Srht => {
            let n_pad = srht::next_pow2(n.max(2)) as f64;
            n_pad * df * n_pad.log2() + mf * df
        }
        SketchKind::Sparse => 2.0 * nnz.map(|z| z as f64).unwrap_or(nf * df),
    }
}

/// Flop-count model for building `SA` *incrementally* up to size `m`
/// through [`engine::SketchEngine`] growth (the cached path the adaptive
/// solver takes), over `growth_steps` growth rounds:
///
/// * Gaussian — appended rows sum to `m`, so the total equals the
///   one-shot cost `2 m n d` (but each *round* paid only for its `Δm`);
/// * SRHT — the FWHT work buffer is paid once (`ñ d log2 ñ`), growth
///   rounds only select rows: `+ m d` total;
/// * sparse — one `2 nnz(A)` scatter per block (`growth_steps + 1`
///   blocks).
pub fn incremental_sketch_cost_flops(
    kind: SketchKind,
    m: usize,
    n: usize,
    d: usize,
    nnz: Option<usize>,
    growth_steps: usize,
) -> f64 {
    let (mf, nf, df) = (m as f64, n as f64, d as f64);
    match kind {
        SketchKind::Gaussian => 2.0 * mf * nf * df,
        SketchKind::Srht => {
            let n_pad = srht::next_pow2(n.max(2)) as f64;
            n_pad * df * n_pad.log2() + mf * df
        }
        SketchKind::Sparse => {
            2.0 * nnz.map(|z| z as f64).unwrap_or(nf * df) * (growth_steps + 1) as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_roundtrip_display_parse() {
        for k in [SketchKind::Gaussian, SketchKind::Srht, SketchKind::Sparse] {
            let s = k.to_string();
            assert_eq!(s.parse::<SketchKind>().unwrap(), k);
        }
        assert!("bogus".parse::<SketchKind>().is_err());
    }

    #[test]
    fn sample_dispatch_shapes() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        for k in [SketchKind::Gaussian, SketchKind::Srht, SketchKind::Sparse] {
            let s = sample(k, 4, 16, &mut rng);
            assert_eq!(s.m(), 4);
            assert_eq!(s.n(), 16);
            let a = Matrix::eye(16);
            let sa = s.apply(&a);
            assert_eq!((sa.rows(), sa.cols()), (4, 16));
        }
    }

    #[test]
    fn cost_model_orderings() {
        // SRHT must beat Gaussian for large m, sparse beats both.
        let (m, n, d) = (512, 4096, 256);
        let g = sketch_cost_flops(SketchKind::Gaussian, m, n, d, None);
        let h = sketch_cost_flops(SketchKind::Srht, m, n, d, None);
        let s = sketch_cost_flops(SketchKind::Sparse, m, n, d, None);
        assert!(h < g);
        assert!(s < h);
    }

    #[test]
    fn srht_cost_uses_padded_dimension() {
        // n = 4097 pads to 8192: the FWHT term must jump accordingly, not
        // track the raw n.
        let (m, d) = (128, 64);
        let at_pow2 = sketch_cost_flops(SketchKind::Srht, m, 4096, d, None);
        let just_past = sketch_cost_flops(SketchKind::Srht, m, 4097, d, None);
        let expect_past = 8192.0 * d as f64 * 13.0 + (m * d) as f64;
        assert_eq!(just_past, expect_past);
        assert!(just_past > 1.9 * at_pow2, "padding to 2n doubles the FWHT term");
        // Same padded cost across the whole bracket.
        assert_eq!(just_past, sketch_cost_flops(SketchKind::Srht, m, 8192, d, None));
    }

    #[test]
    fn incremental_cost_beats_cumulative_regrow_for_srht() {
        // Doubling 1 -> 512 with re-apply pays the FWHT ~10 times; the
        // cached path pays it once.
        let (n, d) = (4096usize, 256usize);
        let schedule: Vec<usize> = (0..10).map(|i| 1usize << i).collect();
        let regrow: f64 =
            schedule.iter().map(|&m| sketch_cost_flops(SketchKind::Srht, m, n, d, None)).sum();
        let incremental =
            incremental_sketch_cost_flops(SketchKind::Srht, 512, n, d, None, schedule.len() - 1);
        assert!(
            incremental * 5.0 < regrow,
            "incremental {incremental:.3e} should be >= 5x below regrow {regrow:.3e}"
        );
    }

    #[test]
    fn incremental_gaussian_totals_one_shot() {
        // Appended Gaussian rows sum to m: total flops equal the one-shot
        // application at the final size, regardless of the growth count.
        let g1 = incremental_sketch_cost_flops(SketchKind::Gaussian, 256, 2048, 64, None, 8);
        let g2 = sketch_cost_flops(SketchKind::Gaussian, 256, 2048, 64, None);
        assert_eq!(g1, g2);
        // Sparse pays one scatter per block.
        let s = incremental_sketch_cost_flops(SketchKind::Sparse, 256, 2048, 64, Some(1000), 3);
        assert_eq!(s, 2.0 * 1000.0 * 4.0);
    }

    #[test]
    fn sparse_cost_scales_with_nnz() {
        // 2 * nnz(A), not 2 * n * d: a 1%-dense matrix must cost 1% of
        // the dense fallback.
        let (m, n, d) = (512, 4096, 256);
        let dense = sketch_cost_flops(SketchKind::Sparse, m, n, d, None);
        let sparse = sketch_cost_flops(SketchKind::Sparse, m, n, d, Some(n * d / 100));
        assert_eq!(dense, 2.0 * (n * d) as f64);
        assert_eq!(sparse, 2.0 * (n * d / 100) as f64);
        // nnz does not affect the dense-data families.
        let g = sketch_cost_flops(SketchKind::Gaussian, m, n, d, Some(1));
        assert_eq!(g, 2.0 * (m * n * d) as f64);
    }
}
