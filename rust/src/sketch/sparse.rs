//! Sparse embedding (CountSketch / SJLT with one nonzero per column).
//!
//! The paper's Remark 4.1 points to `O(nnz(A))`-time embeddings as the
//! natural extension of the adaptive method to sparse data; we implement
//! the classic CountSketch: each ambient coordinate `j` is hashed to a
//! single row `h(j)` with a random sign `s(j)`, so
//! `(S x)_r = sum_{j: h(j)=r} s(j) x_j` and `E[S^T S] = I`.

use super::Sketch;
use crate::linalg::Matrix;
use crate::rng::Xoshiro256;

/// CountSketch embedding: one (row, sign) pair per ambient coordinate.
#[derive(Clone, Debug)]
pub struct SparseSketch {
    m: usize,
    /// Target row per coordinate, length `n`.
    hash: Vec<u32>,
    /// Sign per coordinate, length `n`.
    signs: Vec<f64>,
}

impl SparseSketch {
    /// Sample an `m x n` CountSketch.
    pub fn sample(m: usize, n: usize, rng: &mut Xoshiro256) -> Self {
        assert!(m > 0 && n > 0);
        let mut hash = Vec::with_capacity(n);
        let mut signs = vec![0.0; n];
        for _ in 0..n {
            hash.push(rng.next_below(m as u64) as u32);
        }
        rng.fill_rademacher(&mut signs);
        Self { m, hash, signs }
    }
}

impl Sketch for SparseSketch {
    fn m(&self) -> usize {
        self.m
    }

    fn n(&self) -> usize {
        self.hash.len()
    }

    fn apply(&self, a: &Matrix) -> Matrix {
        assert_eq!(a.rows(), self.n(), "sketch/matrix dimension mismatch");
        let d = a.cols();
        let mut out = Matrix::zeros(self.m, d);
        // Single pass over A's rows: scatter-add into the target row.
        for j in 0..self.n() {
            let r = self.hash[j] as usize;
            let s = self.signs[j];
            let src = a.row(j);
            let dst = out.row_mut(r);
            for k in 0..d {
                dst[k] += s * src[k];
            }
        }
        out
    }

    /// `S * A` for CSR input in `O(nnz(A))` — the Remark 4.1 fast path:
    /// each stored entry is visited once and scatter-added into its hashed
    /// output row.
    fn apply_csr(&self, a: &crate::linalg::sparse::CsrMatrix) -> Matrix {
        assert_eq!(a.rows(), self.n(), "sketch/matrix dimension mismatch");
        let d = a.cols();
        let mut out = Matrix::zeros(self.m, d);
        for j in 0..self.n() {
            let r = self.hash[j] as usize;
            let s = self.signs[j];
            let (cols, vals) = a.row(j);
            let dst = out.row_mut(r);
            for (&c, &v) in cols.iter().zip(vals) {
                dst[c as usize] += s * v;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_nonzero_per_column() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let sk = SparseSketch::sample(5, 20, &mut rng);
        let dense = sk.to_dense();
        for j in 0..20 {
            let nnz = (0..5).filter(|&i| dense.get(i, j) != 0.0).count();
            assert_eq!(nnz, 1, "column {j}");
            let sum_abs: f64 = (0..5).map(|i| dense.get(i, j).abs()).sum();
            assert!((sum_abs - 1.0).abs() < 1e-15);
        }
    }

    #[test]
    fn isometry_in_expectation() {
        let mut rng = Xoshiro256::seed_from_u64(2);
        let n = 64;
        let x: Vec<f64> = (0..n).map(|i| ((i as f64) * 0.21).sin()).collect();
        let xn2: f64 = x.iter().map(|v| v * v).sum();
        let mut acc = 0.0;
        let trials = 500;
        for _ in 0..trials {
            let sk = SparseSketch::sample(32, n, &mut rng);
            let sx = sk.apply(&Matrix::from_vec(n, 1, x.clone()));
            acc += sx.as_slice().iter().map(|v| v * v).sum::<f64>();
        }
        let mean = acc / trials as f64;
        assert!((mean - xn2).abs() < 0.1 * xn2, "mean {mean} vs {xn2}");
    }

    #[test]
    fn apply_matches_dense() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let sk = SparseSketch::sample(4, 11, &mut rng);
        let a = Matrix::from_fn(11, 3, |i, j| (i + 2 * j) as f64 * 0.1);
        assert!(sk.apply(&a).max_abs_diff(&sk.to_dense().matmul(&a)) < 1e-12);
    }

    #[test]
    fn apply_csr_matches_dense_apply() {
        let mut rng = Xoshiro256::seed_from_u64(4);
        let n = 40;
        let dense = Matrix::from_fn(n, 6, |_, _| {
            if rng.next_f64() < 0.2 { rng.next_gaussian() } else { 0.0 }
        });
        let csr = crate::linalg::sparse::CsrMatrix::from_dense(&dense);
        let sk = SparseSketch::sample(8, n, &mut rng);
        let via_csr = sk.apply_csr(&csr);
        let via_dense = sk.apply(&dense);
        assert!(via_csr.max_abs_diff(&via_dense) < 1e-12);
    }
}
