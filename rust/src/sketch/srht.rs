//! Subsampled Randomized Hadamard Transform (paper §3.2, Theorem 4).
//!
//! `S = sqrt(n~/m) * R * H * diag(eps)` where `eps` is a Rademacher vector,
//! `H` the normalized Walsh–Hadamard transform of size `n~` (ambient
//! dimension zero-padded to the next power of two) and `R` a uniform
//! without-replacement row-subsampling — the sampling model under which the
//! paper's matrix-Bernstein argument (Theorem 10, Gross–Nesme) is stated.
//!
//! Applying `S` to an `n x d` matrix costs `O(n~ d log n~)`: the FWHT runs
//! over the *row* dimension so each butterfly is a pair of contiguous
//! length-`d` row operations — the same access pattern the L1 Pallas kernel
//! uses on TPU (stage-by-stage stride halving over a VMEM-resident block).

use super::Sketch;
use crate::linalg::{Matrix, OperandRef};
use crate::rng::Xoshiro256;

/// Sign-flipped, zero-padded SRHT work buffer (`n_pad x d`, pre-FWHT):
/// an `O(n d)` dense copy or an `O(nnz)` CSR scatter. Shared by the
/// one-shot [`SrhtSketch`] application and the incremental
/// [`super::engine::SketchEngine`], so the two paths cannot drift.
pub(crate) fn signed_work(a: OperandRef<'_>, signs: &[f64], n_pad: usize) -> Matrix {
    let (n, d) = (a.rows(), a.cols());
    let mut work = Matrix::zeros(n_pad, d);
    match a {
        OperandRef::Dense(am) => {
            for i in 0..n {
                let sign = signs[i];
                let src = am.row(i);
                let dst = work.row_mut(i);
                for k in 0..d {
                    dst[k] = sign * src[k];
                }
            }
        }
        OperandRef::Sparse(c) => {
            for i in 0..n {
                let sign = signs[i];
                let (cols, vals) = c.row(i);
                let dst = work.row_mut(i);
                for (&cc, &v) in cols.iter().zip(vals) {
                    dst[cc as usize] = sign * v;
                }
            }
        }
    }
    work
}

/// SRHT embedding: stores only the sign vector and the selected rows.
#[derive(Clone, Debug)]
pub struct SrhtSketch {
    n: usize,
    /// Padded dimension (next power of two >= n).
    n_pad: usize,
    /// Rademacher signs, length `n`.
    signs: Vec<f64>,
    /// Selected Hadamard rows (without replacement), length `m`.
    rows: Vec<usize>,
}

/// Next power of two >= `n`.
pub fn next_pow2(n: usize) -> usize {
    n.next_power_of_two()
}

/// Entry `(i, j)` of the *unnormalized* Sylvester Hadamard matrix:
/// `(-1)^popcount(i & j)`. Used to materialize SRHT rows without running
/// a transform (tests / `SketchEngine::to_dense`).
pub fn hadamard_entry(i: usize, j: usize) -> f64 {
    if (i & j).count_ones() % 2 == 0 {
        1.0
    } else {
        -1.0
    }
}

/// In-place *unnormalized* fast Walsh–Hadamard transform over the row
/// dimension of an `n_pad x d` matrix (each butterfly operates on whole
/// rows, so the inner loops stream contiguous memory).
///
/// Large transforms run stage-by-stage on scoped threads (the
/// [`crate::linalg::threads`] knob): at each stage the `n/2` butterfly
/// row pairs are disjoint, so the matrix splits into equal-length
/// `(lo, hi)` half-slices processed independently. Every element sees the
/// same `(u+v, u-v)` update regardless of the partition, so results are
/// bitwise identical at any thread count.
pub fn fwht_rows(work: &mut Matrix) {
    let n = work.rows();
    assert!(n.is_power_of_two(), "FWHT needs power-of-two rows");
    let d = work.cols();
    if n <= 1 {
        return;
    }
    let stages = n.trailing_zeros() as f64;
    let flops = 2.0 * n as f64 * d as f64 * stages;
    let threads = if crate::linalg::threads::worth_parallelizing(flops) {
        crate::linalg::threads::current()
    } else {
        1
    };
    // Aim for a few jobs per thread so the round-robin deal stays balanced
    // even when group boundaries leave ragged tails.
    let pair_rows_per_job = if threads > 1 {
        ((n / 2 + 4 * threads - 1) / (4 * threads)).max(1)
    } else {
        n / 2
    };
    let data = work.as_mut_slice();
    let mut len = 1;
    while len < n {
        let stride = len * 2;
        let mut jobs: Vec<(&mut [f64], &mut [f64])> =
            Vec::with_capacity(n / 2 / pair_rows_per_job + 1);
        for group in data.chunks_mut(stride * d) {
            // Rows [0, len) of the group pair with rows [len, stride).
            let (lo, hi) = group.split_at_mut(len * d);
            let per = pair_rows_per_job.min(len) * d;
            jobs.extend(lo.chunks_mut(per).zip(hi.chunks_mut(per)));
        }
        crate::linalg::threads::run_jobs(threads, jobs, |(lo, hi)| {
            for k in 0..lo.len() {
                let u = lo[k];
                let v = hi[k];
                lo[k] = u + v;
                hi[k] = u - v;
            }
        });
        len = stride;
    }
}

/// In-place unnormalized FWHT of a single vector (power-of-two length).
pub fn fwht_vec(x: &mut [f64]) {
    let n = x.len();
    assert!(n.is_power_of_two());
    let mut len = 1;
    while len < n {
        let stride = len * 2;
        for base in (0..n).step_by(stride) {
            for i in base..base + len {
                let j = i + len;
                let u = x[i];
                let v = x[j];
                x[i] = u + v;
                x[j] = u - v;
            }
        }
        len = stride;
    }
}

impl SrhtSketch {
    /// Sample an `m x n` SRHT.
    pub fn sample(m: usize, n: usize, rng: &mut Xoshiro256) -> Self {
        assert!(m > 0 && n > 0);
        let n_pad = next_pow2(n);
        assert!(m <= n_pad, "SRHT sketch size {m} exceeds padded dim {n_pad}");
        let mut signs = vec![0.0; n];
        rng.fill_rademacher(&mut signs);
        let rows = rng.sample_without_replacement(n_pad, m);
        Self { n, n_pad, signs, rows }
    }

    /// Padded (power-of-two) ambient dimension.
    pub fn n_pad(&self) -> usize {
        self.n_pad
    }
}

impl SrhtSketch {
    /// FWHT the sign-flipped work buffer, select the sampled rows and
    /// apply the net scaling: normalized H contributes 1/sqrt(n_pad), the
    /// sqrt(n_pad/m) embedding scale cancels it to 1/sqrt(m) on the
    /// unnormalized transform output.
    fn transform_and_select(&self, mut work: Matrix) -> Matrix {
        let d = work.cols();
        fwht_rows(&mut work);
        let scale = 1.0 / (self.rows.len() as f64).sqrt();
        let mut out = Matrix::zeros(self.rows.len(), d);
        for (oi, &ri) in self.rows.iter().enumerate() {
            let src = work.row(ri);
            let dst = out.row_mut(oi);
            for k in 0..d {
                dst[k] = scale * src[k];
            }
        }
        out
    }
}

impl Sketch for SrhtSketch {
    fn m(&self) -> usize {
        self.rows.len()
    }

    fn n(&self) -> usize {
        self.n
    }

    fn apply(&self, a: &Matrix) -> Matrix {
        assert_eq!(a.rows(), self.n, "sketch/matrix dimension mismatch");
        let work = signed_work(OperandRef::Dense(a), &self.signs, self.n_pad);
        self.transform_and_select(work)
    }

    /// `S * A` for CSR input: the sign-flipped work buffer is built with an
    /// `O(nnz)` scatter (the padding rows stay untouched zeros), then the
    /// usual `O(ñ d log ñ)` FWHT + row selection run on it.
    fn apply_csr(&self, a: &crate::linalg::sparse::CsrMatrix) -> Matrix {
        assert_eq!(a.rows(), self.n, "sketch/matrix dimension mismatch");
        let work = signed_work(OperandRef::Sparse(a), &self.signs, self.n_pad);
        self.transform_and_select(work)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::norm2;

    #[test]
    fn fwht_matches_hadamard_matrix() {
        // H_4 (unnormalized, Sylvester construction).
        let h4 = [
            [1.0, 1.0, 1.0, 1.0],
            [1.0, -1.0, 1.0, -1.0],
            [1.0, 1.0, -1.0, -1.0],
            [1.0, -1.0, -1.0, 1.0],
        ];
        let x = [0.5, -1.0, 2.0, 3.0];
        let mut y = x;
        fwht_vec(&mut y);
        for i in 0..4 {
            let expect: f64 = (0..4).map(|j| h4[i][j] * x[j]).sum();
            assert!((y[i] - expect).abs() < 1e-12, "row {i}");
        }
    }

    #[test]
    fn hadamard_entry_matches_fwht() {
        // FWHT of the i-th unit vector is the i-th Hadamard row.
        for i in 0..8 {
            let mut e = vec![0.0; 8];
            e[i] = 1.0;
            fwht_vec(&mut e);
            for j in 0..8 {
                assert_eq!(e[j], hadamard_entry(i, j), "H[{i},{j}]");
            }
        }
    }

    #[test]
    fn fwht_rows_parallel_bitwise_matches_serial() {
        let mut rng = Xoshiro256::seed_from_u64(7);
        // 512 x 128 crosses the parallel threshold (512*128*9*2 ~ 1.2e6).
        let m0 = Matrix::from_fn(512, 128, |_, _| rng.next_gaussian());
        let mut serial = m0.clone();
        crate::linalg::threads::with_threads(1, || fwht_rows(&mut serial));
        for t in [2, 4] {
            let mut par = m0.clone();
            crate::linalg::threads::with_threads(t, || fwht_rows(&mut par));
            assert_eq!(par, serial, "threads={t}");
        }
    }

    #[test]
    fn fwht_rows_matches_vec_per_column() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let mut m = Matrix::from_fn(8, 3, |_, _| rng.next_gaussian());
        let orig = m.clone();
        fwht_rows(&mut m);
        for j in 0..3 {
            let mut col: Vec<f64> = (0..8).map(|i| orig.get(i, j)).collect();
            fwht_vec(&mut col);
            for i in 0..8 {
                assert!((m.get(i, j) - col[i]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn fwht_involution_up_to_n() {
        let mut rng = Xoshiro256::seed_from_u64(2);
        let x0: Vec<f64> = (0..16).map(|_| rng.next_gaussian()).collect();
        let mut x = x0.clone();
        fwht_vec(&mut x);
        fwht_vec(&mut x);
        for i in 0..16 {
            assert!((x[i] / 16.0 - x0[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn full_srht_is_orthogonal() {
        // m == n_pad, n power of two: S is orthogonal (up to scaling making
        // S^T S = (n/m) * I = I) -> exact isometry.
        let mut rng = Xoshiro256::seed_from_u64(3);
        let n = 16;
        let sk = SrhtSketch::sample(n, n, &mut rng);
        let s = sk.to_dense();
        let sts = s.gram();
        assert!(sts.max_abs_diff(&Matrix::eye(n)) < 1e-10);
    }

    #[test]
    fn isometry_in_expectation_padded() {
        // Non-power-of-two n: E ||S x||^2 = ||x||^2 over subsample draws.
        let mut rng = Xoshiro256::seed_from_u64(4);
        let n = 24; // pads to 32
        let x: Vec<f64> = (0..n).map(|i| ((i as f64) * 0.37).cos()).collect();
        let xn2 = norm2(&x).powi(2);
        let mut acc = 0.0;
        let trials = 300;
        for _ in 0..trials {
            let sk = SrhtSketch::sample(8, n, &mut rng);
            let a = Matrix::from_vec(n, 1, x.clone());
            let sx = sk.apply(&a);
            acc += sx.as_slice().iter().map(|v| v * v).sum::<f64>();
        }
        let mean = acc / trials as f64;
        assert!((mean - xn2).abs() < 0.05 * xn2, "mean {mean} vs {xn2}");
    }

    #[test]
    fn rows_distinct_without_replacement() {
        let mut rng = Xoshiro256::seed_from_u64(5);
        let sk = SrhtSketch::sample(20, 30, &mut rng);
        let mut rows = sk.rows.clone();
        rows.sort_unstable();
        rows.dedup();
        assert_eq!(rows.len(), 20);
        assert!(rows.iter().all(|&r| r < sk.n_pad()));
    }

    #[test]
    fn apply_matches_dense_composition() {
        let mut rng = Xoshiro256::seed_from_u64(6);
        let n = 10; // pads to 16
        let sk = SrhtSketch::sample(4, n, &mut rng);
        let a = Matrix::from_fn(n, 3, |i, j| (i as f64 - j as f64) * 0.2);
        let sa = sk.apply(&a);
        let sa2 = sk.to_dense().matmul(&a);
        assert!(sa.max_abs_diff(&sa2) < 1e-10);
    }
}
