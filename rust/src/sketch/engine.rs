//! Incremental sketch-growth engine: grow `m` by paying only for the new
//! rows.
//!
//! Algorithm 1's whole point is that `m` grows from 1 toward the
//! effective dimension, yet resampling `S` and re-applying it to all of
//! `A` on every rejection costs `O(m n d)` (Gaussian) or
//! `O(ñ d log ñ)` (SRHT) *per growth* — re-doing work the previous sketch
//! already paid for. [`SketchEngine`] owns per-problem cached state so a
//! growth step costs only `O(Δm)` worth of new work:
//!
//! * **Gaussian** — appends `Δm` fresh i.i.d. rows and multiplies only
//!   those against `A`: `O(Δm n d)` instead of `O(m n d)` — or
//!   `O(Δm nnz)` on a CSR operand via sparse row-axpy.
//! * **SRHT** — computes the FWHT'd, sign-flipped buffer
//!   `H · diag(eps) · A` *once* per problem (`O(ñ d log ñ)`, where
//!   `ñ = next_pow2(n)`; building the pre-FWHT buffer is an `O(nnz)`
//!   scatter on CSR operands); growing is then just continuing the
//!   without-replacement row sample and copying `Δm` cached rows:
//!   `O(Δm d)`. Extending a partial Fisher–Yates shuffle keeps the
//!   selected row set a uniform without-replacement sample at every size,
//!   so each grown sketch is distributed exactly like a fresh SRHT of
//!   that size.
//! * **Sparse** — appends an independent CountSketch block of `Δm` rows
//!   (`O(nnz(A))` scatter per growth — on CSR operands this touches only
//!   the stored entries, the headline Remark 4.1 cost). Block `i` carries
//!   the fixed weight `sqrt(m_i)` baked into its unnormalized rows, so
//!   the effective embedding
//!   `(1/sqrt(m)) * [sqrt(m_1) Ŝ_1; ...; sqrt(m_k) Ŝ_k]`
//!   satisfies `E[S^T S] = (1/m) Σ m_i I = I` with the *same* `O(d/m)`
//!   Gram variance as a fresh size-`m` CountSketch (size-weighting is
//!   what keeps the early tiny blocks from dominating); per-column
//!   sparsity is one entry per block — an SJLT.
//!
//! The engine takes its problem matrix as an [`OperandRef`] — `&Matrix`,
//! `&CsrMatrix`, or `&Operand` all work — and every family has an exact
//! sparse arm: the dense and CSR paths of the same RNG stream produce the
//! same `S̃A` up to roundoff.
//!
//! # Normalization contract
//!
//! Stored rows are **unnormalized**: the effective embedding is
//! `scale() * sa_unnormalized()` with `scale = 1/sqrt(m)` for every
//! family. Keeping the `1/sqrt(m)` factor out of the stored rows is what
//! makes growth append-only — previously computed rows of `S̃A` are never
//! rescaled or moved (prefix consistency), and the scale is folded into
//! the solve by
//! [`crate::solvers::woodbury::WoodburyCache::new_scaled`].
//!
//! The engine consumes the RNG in exactly the order
//! [`super::sample`] does, so the *initial* sketch (before any growth)
//! reproduces the one-shot sampling path draw for draw.
//!
//! # Row append (streaming ingest)
//!
//! [`SketchEngine::append_rows`] is the dual of [`SketchEngine::grow`]:
//! `grow` adds sketch rows (`Δm`), `append_rows` adds *data* rows (`Δn`)
//! without re-sketching any retained row of `A`:
//!
//! * **Gaussian** — `S̃ A' = S̃ [A; ΔA] = [S̃_old  G_new] [A; ΔA]
//!   = S̃_old A + G_new ΔA`: draw the `m x Δn` column extension `G_new`
//!   and add `G_new ΔA` into the existing rows — `O(m Δn d)` /
//!   `O(m nnz(ΔA))`, independent of `n`. Each growth block keeps a list
//!   of per-append RNG snapshots ("column segments") so
//!   [`SketchEngine::to_dense`] can replay the full `m x n` embedding.
//! * **SRHT** — the documented per-block stacked variant: the new rows
//!   get their own independent signed-Hadamard block (padded to at least
//!   twice the current `m` for growth headroom), FWHT'd over only the
//!   `Δn` new rows; its without-replacement row sample is drawn to the
//!   current depth `m` and added into `S̃A`. Per block
//!   `E[s s^T] = I` on its row range and cross-block terms vanish in
//!   expectation (independent signs), so the stacked embedding keeps
//!   `E[S^T S] = I`. Appends bound future growth by the smallest block's
//!   padded dimension — [`SketchEngine::max_m`] reports the cap and the
//!   solvers fall back to the exact Hessian beyond it.
//! * **Sparse** — each CountSketch block extends its `(row, sign)` pair
//!   arrays by `Δn` and scatter-adds the new rows: `O(nnz(ΔA))` per
//!   block, the same Remark 4.1 cost as construction.
//!
//! In every family the retained entries of `S̃A` change only by `+=` of
//! new-row contributions and `m` is unchanged, so the normalization
//! contract (append-only rows, scale folded into the solve) survives;
//! the caller refreshes the factorization from the updated rows.

use super::srht::{fwht_rows, hadamard_entry, next_pow2, signed_work};
use super::SketchKind;
use crate::linalg::sparse::CsrMatrix;
use crate::linalg::{Matrix, Operand, OperandRef};
use crate::rng::Xoshiro256;
use crate::solvers::error::SolverError;
use crate::util::failpoint;

/// Read-only metadata frozen out of a [`SketchEngine`] at O(1) —
/// the sketch-layer half of a pinned-snapshot solve. A view is `Copy`
/// (five scalars); the applied rows themselves travel separately as the
/// solver's shared `Arc<GramPanel>`
/// ([`crate::solvers::woodbury::GramPanel`]), so cloning a view out of a
/// live engine never touches the `m x d` panel or the per-family growth
/// buffers. Obtained via [`SketchEngine::view`].
#[derive(Clone, Copy, Debug)]
pub struct SketchView {
    kind: SketchKind,
    n: usize,
    m: usize,
    max_m: usize,
    scale: f64,
}

impl SketchView {
    /// Embedding family.
    pub fn kind(&self) -> SketchKind {
        self.kind
    }

    /// Ambient dimension `n` at freeze time.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Sketch depth `m` at freeze time.
    pub fn m(&self) -> usize {
        self.m
    }

    /// Growth cap the live engine was subject to (`usize::MAX` unless
    /// SRHT padded blocks bound it) — what decides whether a frozen
    /// solve may take the at-cap exact-Hessian waiver instead of
    /// reporting `NeedsGrowth`.
    pub fn max_m(&self) -> usize {
        self.max_m
    }

    /// Effective embedding normalization (`1/sqrt(m)`).
    pub fn scale(&self) -> f64 {
        self.scale
    }
}

/// Per-problem incremental sketch state plus the unnormalized applied
/// sketch `S̃A`.
#[derive(Clone)]
pub struct SketchEngine {
    kind: SketchKind,
    n: usize,
    /// Unnormalized applied sketch (`m x d`), grown append-only.
    sa: Matrix,
    state: State,
}

#[derive(Clone)]
enum State {
    Gaussian {
        /// One entry per *growth* block (a run of sketch rows), stacked
        /// top to bottom.
        blocks: Vec<GaussianBlock>,
    },
    Srht {
        /// One signed-Hadamard block per data segment: the original
        /// problem rows plus one block per [`SketchEngine::append_rows`],
        /// stacked left to right over the ambient coordinates.
        blocks: Vec<SrhtBlock>,
        /// Selection depth shared by every block: block `b`'s
        /// `order[..taken]` are its selected Hadamard rows, in the
        /// engine-wide selection order (sketch row `k` reads entry
        /// `order[k]` of every block).
        taken: usize,
    },
    Sparse {
        /// Independent CountSketch blocks, stacked top to bottom.
        blocks: Vec<SparseBlock>,
    },
}

/// One Gaussian growth block: a run of `rows` sketch rows whose entries
/// were drawn in column segments — one segment for the rows of `A`
/// present at the block's creation, plus one per later data append. `S̃`
/// itself is never retained (it would double the solver's memory at
/// `m x n`); [`SketchEngine::to_dense`] replays the snapshots instead.
#[derive(Clone)]
struct GaussianBlock {
    rows: usize,
    /// `(RNG snapshot before the draw, column count)` per segment; the
    /// segment's entries are drawn row-major over `rows x cols`.
    segments: Vec<(Xoshiro256, usize)>,
}

/// One SRHT block covering ambient rows
/// `row_offset..row_offset + n_rows`.
#[derive(Clone)]
struct SrhtBlock {
    /// First ambient coordinate this block covers.
    row_offset: usize,
    /// Data rows covered (before padding).
    n_rows: usize,
    /// Rademacher signs, length `n_rows`.
    signs: Vec<f64>,
    /// Cached `H · diag(signs) · A_block` (`ñ_b x d`, unnormalized
    /// FWHT) — computed once; growth only reads more of its rows.
    work: Matrix,
    /// Partial Fisher–Yates state over `0..ñ_b`; the shared engine
    /// `taken` counts how many of its entries are selected.
    order: Vec<usize>,
}

/// One CountSketch block: one (row, sign) pair per ambient coordinate,
/// with the size weight `sqrt(rows)` baked into its unnormalized output
/// (fixed at creation — growth never revisits it).
#[derive(Clone)]
struct SparseBlock {
    rows: usize,
    hash: Vec<u32>,
    signs: Vec<f64>,
    /// `sqrt(rows)` — cancels the engine-level `1/sqrt(m)` down to the
    /// size-weighted block scale `sqrt(rows/m)`.
    weight: f64,
}

impl SparseBlock {
    fn sample(rows: usize, n: usize, rng: &mut Xoshiro256) -> Self {
        let mut hash = Vec::with_capacity(n);
        let mut signs = vec![0.0; n];
        for _ in 0..n {
            hash.push(rng.next_below(rows as u64) as u32);
        }
        rng.fill_rademacher(&mut signs);
        Self { rows, hash, signs, weight: (rows as f64).sqrt() }
    }

    /// Unnormalized (weighted) scatter-apply: `O(n d)` dense, `O(nnz)` CSR.
    fn apply(&self, a: OperandRef<'_>) -> Matrix {
        let d = a.cols();
        let mut out = Matrix::zeros(self.rows, d);
        match a {
            OperandRef::Dense(am) => {
                for j in 0..am.rows() {
                    let r = self.hash[j] as usize;
                    let s = self.weight * self.signs[j];
                    let src = am.row(j);
                    let dst = out.row_mut(r);
                    for k in 0..d {
                        dst[k] += s * src[k];
                    }
                }
            }
            OperandRef::Sparse(c) => {
                for j in 0..c.rows() {
                    let r = self.hash[j] as usize;
                    let s = self.weight * self.signs[j];
                    let (cols, vals) = c.row(j);
                    let dst = out.row_mut(r);
                    for (&cc, &v) in cols.iter().zip(vals) {
                        dst[cc as usize] += s * v;
                    }
                }
            }
        }
        out
    }
}

/// `g * a` for a dense block `g` (`p x n`): blocked GEMM on dense
/// operands, `O(p * nnz)` sparse row-axpy on CSR.
fn dense_block_times(g: &Matrix, a: OperandRef<'_>) -> Matrix {
    match a {
        OperandRef::Dense(am) => g.matmul(am),
        OperandRef::Sparse(c) => c.left_mul(g),
    }
}

impl SketchEngine {
    /// Build the engine at initial size `m`, applying the sketch to the
    /// operand `a` (`n x d`, dense or CSR). `rng` is advanced exactly as
    /// [`super::sample`] would.
    pub fn new<'a>(
        kind: SketchKind,
        m: usize,
        a: impl Into<OperandRef<'a>>,
        rng: &mut Xoshiro256,
    ) -> Self {
        let a: OperandRef<'a> = a.into();
        let n = a.rows();
        assert!(m > 0 && n > 0);
        match kind {
            SketchKind::Gaussian => {
                let snapshot = rng.clone();
                let mut s = Matrix::zeros(m, n);
                rng.fill_gaussian(s.as_mut_slice(), 1.0);
                let sa = dense_block_times(&s, a);
                let block = GaussianBlock { rows: m, segments: vec![(snapshot, n)] };
                Self { kind, n, sa, state: State::Gaussian { blocks: vec![block] } }
            }
            SketchKind::Srht => {
                let n_pad = next_pow2(n);
                assert!(m <= n_pad, "SRHT sketch size {m} exceeds padded dim {n_pad}");
                let mut signs = vec![0.0; n];
                rng.fill_rademacher(&mut signs);
                let mut work = signed_work(a, &signs, n_pad);
                fwht_rows(&mut work);
                let mut block = SrhtBlock {
                    row_offset: 0,
                    n_rows: n,
                    signs,
                    work,
                    order: (0..n_pad).collect(),
                };
                let mut taken = 0;
                let rows = take_without_replacement(&mut block.order, &mut taken, m, rng);
                let sa = copy_rows(&block.work, rows);
                Self { kind, n, sa, state: State::Srht { blocks: vec![block], taken } }
            }
            SketchKind::Sparse => {
                let block = SparseBlock::sample(m, n, rng);
                let sa = block.apply(a);
                Self { kind, n, sa, state: State::Sparse { blocks: vec![block] } }
            }
        }
    }

    /// Grow to `new_m` rows, appending only `Δm = new_m - m` rows of new
    /// work (`O(Δm n d)` / `O(Δm nnz)` Gaussian, `O(Δm d)` SRHT,
    /// `O(nnz(A))` sparse). Returns the appended *unnormalized* rows of
    /// `S̃A` (what [`crate::solvers::woodbury::WoodburyCache::grow`]
    /// consumes); the existing prefix of [`Self::sa_unnormalized`] is
    /// untouched.
    ///
    /// Errors ([`SolverError::InvalidInput`] on shape/size misuse,
    /// [`SolverError::Capacity`] past an SRHT padded-block cap) are
    /// returned *before* any state is mutated, so a failed grow leaves
    /// the engine exactly as it was.
    pub fn grow<'a>(
        &mut self,
        new_m: usize,
        a: impl Into<OperandRef<'a>>,
        rng: &mut Xoshiro256,
    ) -> Result<Matrix, SolverError> {
        let a: OperandRef<'a> = a.into();
        let m_old = self.m();
        if new_m <= m_old {
            return Err(SolverError::invalid(format!("grow needs new_m {new_m} > m {m_old}")));
        }
        if a.rows() != self.n {
            return Err(SolverError::invalid(format!(
                "grow must reuse the engine's problem matrix (got {} rows, engine has {})",
                a.rows(),
                self.n
            )));
        }
        if new_m > self.max_m() {
            return Err(SolverError::Capacity(format!(
                "SRHT sketch size {new_m} exceeds padded block dim {}",
                self.max_m()
            )));
        }
        failpoint::check("sketch.grow").map_err(SolverError::Internal)?;
        let dm = new_m - m_old;
        let new_rows = match &mut self.state {
            State::Gaussian { blocks } => {
                blocks.push(GaussianBlock { rows: dm, segments: vec![(rng.clone(), self.n)] });
                let mut g_new = Matrix::zeros(dm, self.n);
                rng.fill_gaussian(g_new.as_mut_slice(), 1.0);
                dense_block_times(&g_new, a)
            }
            State::Srht { blocks, taken } => {
                // Deepen every block's without-replacement sample to the
                // new depth; sketch row `k` sums entry `order[k]` of each
                // block, so the blocks advance in lockstep from the
                // shared `taken`.
                let start = *taken;
                let mut new_rows: Option<Matrix> = None;
                for block in blocks.iter_mut() {
                    let mut t = start;
                    let rows = take_without_replacement(&mut block.order, &mut t, dm, rng);
                    match &mut new_rows {
                        None => new_rows = Some(copy_rows(&block.work, rows)),
                        Some(acc) => add_rows(acc, &block.work, rows),
                    }
                }
                *taken = start + dm;
                new_rows.expect("SRHT engine always has at least one block")
            }
            State::Sparse { blocks } => {
                let block = SparseBlock::sample(dm, self.n, rng);
                let rows = block.apply(a);
                blocks.push(block);
                rows
            }
        };
        self.sa.append_rows(&new_rows);
        Ok(new_rows)
    }

    /// Stream `Δn` new data rows into the sketch without re-sketching any
    /// retained row: every entry of `S̃A` is updated by `+=` of new-row
    /// contributions only (`O(m Δn d)` Gaussian, `O(Δn d log ñ_b + m d)`
    /// SRHT, `O(nnz(ΔA))` per sparse block), `m` is unchanged, and the
    /// stored rows stay append-only under later [`Self::grow`] calls. The
    /// caller owns refreshing the downstream factorization from
    /// [`Self::sa_unnormalized`].
    ///
    /// Errors are returned *before* any state is mutated, so a failed
    /// append leaves the engine exactly as it was.
    pub fn append_rows<'a>(
        &mut self,
        delta: impl Into<OperandRef<'a>>,
        rng: &mut Xoshiro256,
    ) -> Result<(), SolverError> {
        let delta: OperandRef<'a> = delta.into();
        let dn = delta.rows();
        if dn == 0 {
            return Err(SolverError::invalid("append_rows needs at least one new row"));
        }
        if delta.cols() != self.sa.cols() {
            return Err(SolverError::invalid(format!(
                "append_rows column mismatch: delta has {} columns, engine has {}",
                delta.cols(),
                self.sa.cols()
            )));
        }
        failpoint::check("sketch.append").map_err(SolverError::Internal)?;
        let d = self.sa.cols();
        match &mut self.state {
            State::Gaussian { blocks } => {
                // S̃ [A; ΔA] = S̃_old A + G_new ΔA, one fresh m_b x Δn
                // column segment per growth block.
                let mut r0 = 0;
                for block in blocks.iter_mut() {
                    block.segments.push((rng.clone(), dn));
                    let mut g_new = Matrix::zeros(block.rows, dn);
                    rng.fill_gaussian(g_new.as_mut_slice(), 1.0);
                    let contrib = dense_block_times(&g_new, delta);
                    for i in 0..block.rows {
                        crate::linalg::axpy(1.0, contrib.row(i), self.sa.row_mut(r0 + i));
                    }
                    r0 += block.rows;
                }
            }
            State::Srht { blocks, taken } => {
                // Stacked variant: the new rows get their own independent
                // signed-Hadamard block, padded far enough to serve both
                // the current selection depth and future growth.
                let n_pad = next_pow2(dn).max(next_pow2(2 * *taken));
                let mut signs = vec![0.0; dn];
                rng.fill_rademacher(&mut signs);
                let mut work = signed_work(delta, &signs, n_pad);
                fwht_rows(&mut work);
                let mut order: Vec<usize> = (0..n_pad).collect();
                let mut t = 0;
                let rows = take_without_replacement(&mut order, &mut t, *taken, rng);
                for (k, &ri) in rows.iter().enumerate() {
                    crate::linalg::axpy(1.0, work.row(ri), self.sa.row_mut(k));
                }
                blocks.push(SrhtBlock {
                    row_offset: self.n,
                    n_rows: dn,
                    signs,
                    work,
                    order,
                });
            }
            State::Sparse { blocks } => {
                // Extend each block's per-coordinate (row, sign) arrays
                // and scatter-add only the new data rows.
                let mut r0 = 0;
                for block in blocks.iter_mut() {
                    let start = block.hash.len();
                    for _ in 0..dn {
                        block.hash.push(rng.next_below(block.rows as u64) as u32);
                    }
                    let mut new_signs = vec![0.0; dn];
                    rng.fill_rademacher(&mut new_signs);
                    block.signs.extend_from_slice(&new_signs);
                    match delta {
                        OperandRef::Dense(am) => {
                            for j in 0..dn {
                                let r = block.hash[start + j] as usize;
                                let s = block.weight * block.signs[start + j];
                                let src = am.row(j);
                                let dst = self.sa.row_mut(r0 + r);
                                for k in 0..d {
                                    dst[k] += s * src[k];
                                }
                            }
                        }
                        OperandRef::Sparse(c) => {
                            for j in 0..dn {
                                let r = block.hash[start + j] as usize;
                                let s = block.weight * block.signs[start + j];
                                let (cols, vals) = c.row(j);
                                let dst = self.sa.row_mut(r0 + r);
                                for (&cc, &v) in cols.iter().zip(vals) {
                                    dst[cc as usize] += s * v;
                                }
                            }
                        }
                    }
                    r0 += block.rows;
                }
            }
        }
        self.n += dn;
        Ok(())
    }

    /// Largest sketch size this engine can grow to. Unbounded for
    /// Gaussian and sparse; for SRHT it is the smallest padded block
    /// dimension — appends add blocks padded to `max(2^⌈lg Δn⌉, 2m)`, so
    /// small appends can cap growth below `next_pow2(n)` and the solvers
    /// must take the min (falling back to the exact Hessian at the cap).
    pub fn max_m(&self) -> usize {
        match &self.state {
            State::Srht { blocks, .. } => {
                blocks.iter().map(|b| b.order.len()).min().unwrap_or(usize::MAX)
            }
            _ => usize::MAX,
        }
    }

    /// Current sketch size `m`.
    pub fn m(&self) -> usize {
        self.sa.rows()
    }

    /// Ambient dimension `n`.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Embedding family.
    pub fn kind(&self) -> SketchKind {
        self.kind
    }

    /// The unnormalized applied sketch `S̃A` (`m x d`). Rows are
    /// append-only across [`Self::grow`] calls.
    pub fn sa_unnormalized(&self) -> &Matrix {
        &self.sa
    }

    /// Approximate heap footprint in bytes: the applied sketch `S̃A` plus
    /// the per-family growth state (SRHT's cached FWHT work buffer is the
    /// dominant term, `ñ x d`). Used by registry byte budgets; excludes
    /// the problem operand itself, which the engine never owns.
    pub fn approx_bytes(&self) -> usize {
        let f64s = std::mem::size_of::<f64>();
        let mat = |m: &Matrix| m.rows() * m.cols() * f64s;
        let state = match &self.state {
            State::Gaussian { blocks } => blocks
                .iter()
                .map(|b| b.segments.len() * (std::mem::size_of::<Xoshiro256>() + 8))
                .sum(),
            State::Srht { blocks, .. } => blocks
                .iter()
                .map(|b| {
                    b.signs.len() * f64s
                        + mat(&b.work)
                        + b.order.len() * std::mem::size_of::<usize>()
                })
                .sum(),
            State::Sparse { blocks } => blocks
                .iter()
                .map(|b| b.hash.len() * 4 + b.signs.len() * f64s)
                .sum(),
        };
        mat(&self.sa) + state
    }

    /// Normalization of the effective embedding `scale * S̃`:
    /// `1/sqrt(m)` for every family (sparse blocks carry their
    /// `sqrt(m_i)` size weight in the stored rows).
    pub fn scale(&self) -> f64 {
        1.0 / (self.m() as f64).sqrt()
    }

    /// Freeze the engine's read-only metadata into a [`SketchView`] —
    /// O(1), no buffer is touched. Together with the solver's shared
    /// `Arc<GramPanel>` (which already carries the applied rows), a view
    /// is everything a frozen no-growth solve needs from the sketch
    /// layer: the family, the depth `m`, the growth cap `max_m`, and the
    /// normalization. See
    /// [`crate::solvers::adaptive::solve_frozen`].
    pub fn view(&self) -> SketchView {
        SketchView {
            kind: self.kind,
            n: self.n,
            m: self.m(),
            max_m: self.max_m(),
            scale: self.scale(),
        }
    }

    /// Materialize the effective (normalized) `m x n` embedding — tests
    /// and diagnostics only.
    pub fn to_dense(&self) -> Matrix {
        let scale = self.scale();
        match &self.state {
            State::Gaussian { blocks } => {
                let mut out = Matrix::zeros(self.m(), self.n);
                let mut r0 = 0;
                for block in blocks {
                    let mut c0 = 0;
                    for (snapshot, cols) in &block.segments {
                        let mut rng = snapshot.clone();
                        let mut seg = Matrix::zeros(block.rows, *cols);
                        rng.fill_gaussian(seg.as_mut_slice(), 1.0);
                        for i in 0..block.rows {
                            out.row_mut(r0 + i)[c0..c0 + cols].copy_from_slice(seg.row(i));
                        }
                        c0 += cols;
                    }
                    r0 += block.rows;
                }
                crate::linalg::scale(scale, out.as_mut_slice());
                out
            }
            State::Srht { blocks, taken } => {
                let mut out = Matrix::zeros(*taken, self.n);
                for block in blocks {
                    for r in 0..*taken {
                        let hr = block.order[r];
                        let row = out.row_mut(r);
                        for j in 0..block.n_rows {
                            row[block.row_offset + j] =
                                scale * block.signs[j] * hadamard_entry(hr, j);
                        }
                    }
                }
                out
            }
            State::Sparse { blocks } => {
                let mut out = Matrix::zeros(self.m(), self.n);
                let mut r0 = 0;
                for block in blocks {
                    for j in 0..self.n {
                        out.set(
                            r0 + block.hash[j] as usize,
                            j,
                            scale * block.weight * block.signs[j],
                        );
                    }
                    r0 += block.rows;
                }
                out
            }
        }
    }
    /// Export the engine's *structural* growth state — everything needed
    /// to re-derive `S̃A` bitwise from the problem operand, **without** the
    /// `m x d` applied panel itself (or SRHT's `ñ x d` FWHT work buffer,
    /// which [`Self::from_replay`] recomputes). This is what the
    /// persistence layer ([`crate::persist`]) checkpoints: per-block RNG
    /// snapshots and padding/selection structure are tiny next to the
    /// panels they regenerate.
    pub fn replay_state(&self) -> EngineReplay {
        let state = match &self.state {
            State::Gaussian { blocks } => ReplayState::Gaussian {
                blocks: blocks
                    .iter()
                    .map(|b| GaussianReplay { rows: b.rows, segments: b.segments.clone() })
                    .collect(),
            },
            State::Srht { blocks, taken } => ReplayState::Srht {
                blocks: blocks
                    .iter()
                    .map(|b| SrhtReplay {
                        row_offset: b.row_offset,
                        n_rows: b.n_rows,
                        signs: b.signs.clone(),
                        order: b.order.clone(),
                    })
                    .collect(),
                taken: *taken,
            },
            State::Sparse { blocks } => ReplayState::Sparse {
                blocks: blocks
                    .iter()
                    .map(|b| SparseReplay {
                        rows: b.rows,
                        hash: b.hash.clone(),
                        signs: b.signs.clone(),
                    })
                    .collect(),
            },
        };
        EngineReplay { kind: self.kind, n: self.n, state }
    }

    /// Rebuild an engine from an exported [`Self::replay_state`] and the
    /// problem operand, re-deriving `S̃A` **bitwise** identical to the
    /// exporting engine's panel.
    ///
    /// Bitwise equality holds because the replay repeats the exporting
    /// engine's arithmetic in its exact accumulation order: per-segment
    /// Gaussian draws restart from their stored RNG snapshots and multiply
    /// the same operand row ranges; SRHT blocks recompute their FWHT work
    /// buffers from the stored signs and re-read the same selected rows in
    /// block-index order; CountSketch blocks rescatter the operand in
    /// ascending row order (creation + appends visited rows in exactly
    /// that order). The caller must pass the operand rows the engine had
    /// consumed when the state was exported, **in the same storage form**
    /// (dense vs CSR kernels round differently) — the session layer
    /// guarantees this by normalizing append deltas to the operand's
    /// storage kind before they reach the engine.
    pub fn from_replay<'a>(
        replay: EngineReplay,
        a: impl Into<OperandRef<'a>>,
    ) -> Result<Self, SolverError> {
        let a: OperandRef<'a> = a.into();
        let EngineReplay { kind, n, state } = replay;
        if a.rows() != n {
            return Err(SolverError::invalid(format!(
                "replay expects the engine's {} operand rows, got {}",
                n,
                a.rows()
            )));
        }
        if n == 0 {
            return Err(SolverError::invalid("replay needs a non-empty operand"));
        }
        match state {
            ReplayState::Gaussian { blocks } => {
                if blocks.is_empty() {
                    return Err(SolverError::invalid("gaussian replay needs >= 1 block"));
                }
                let mut sa: Option<Matrix> = None;
                let mut rebuilt = Vec::with_capacity(blocks.len());
                for b in blocks {
                    let covered: usize = b.segments.iter().map(|(_, c)| *c).sum();
                    if covered != n || b.rows == 0 {
                        return Err(SolverError::invalid(format!(
                            "gaussian replay block covers {covered} of {n} operand rows"
                        )));
                    }
                    let mut block_sa: Option<Matrix> = None;
                    let mut c0 = 0;
                    for (snapshot, cols) in &b.segments {
                        let mut rng = snapshot.clone();
                        let mut g = Matrix::zeros(b.rows, *cols);
                        rng.fill_gaussian(g.as_mut_slice(), 1.0);
                        let seg = slice_rows(a, c0, c0 + cols);
                        let contrib = dense_block_times(&g, seg.as_ref());
                        match &mut block_sa {
                            None => block_sa = Some(contrib),
                            Some(acc) => {
                                for i in 0..b.rows {
                                    crate::linalg::axpy(1.0, contrib.row(i), acc.row_mut(i));
                                }
                            }
                        }
                        c0 += cols;
                    }
                    let block_sa = block_sa.expect("segments verified non-empty by cover check");
                    match &mut sa {
                        None => sa = Some(block_sa),
                        Some(acc) => acc.append_rows(&block_sa),
                    }
                    rebuilt.push(GaussianBlock { rows: b.rows, segments: b.segments });
                }
                Ok(Self {
                    kind,
                    n,
                    sa: sa.expect("blocks verified non-empty"),
                    state: State::Gaussian { blocks: rebuilt },
                })
            }
            ReplayState::Srht { blocks, taken } => {
                if blocks.is_empty() {
                    return Err(SolverError::invalid("srht replay needs >= 1 block"));
                }
                let mut rebuilt = Vec::with_capacity(blocks.len());
                for b in blocks {
                    if taken > b.order.len()
                        || b.signs.len() != b.n_rows
                        || b.row_offset + b.n_rows > n
                    {
                        return Err(SolverError::invalid("inconsistent srht replay block"));
                    }
                    let seg = slice_rows(a, b.row_offset, b.row_offset + b.n_rows);
                    let mut work = signed_work(seg.as_ref(), &b.signs, b.order.len());
                    fwht_rows(&mut work);
                    rebuilt.push(SrhtBlock {
                        row_offset: b.row_offset,
                        n_rows: b.n_rows,
                        signs: b.signs,
                        work,
                        order: b.order,
                    });
                }
                let mut sa = copy_rows(&rebuilt[0].work, &rebuilt[0].order[..taken]);
                for block in &rebuilt[1..] {
                    add_rows(&mut sa, &block.work, &block.order[..taken]);
                }
                Ok(Self { kind, n, sa, state: State::Srht { blocks: rebuilt, taken } })
            }
            ReplayState::Sparse { blocks } => {
                if blocks.is_empty() {
                    return Err(SolverError::invalid("sparse replay needs >= 1 block"));
                }
                let mut sa: Option<Matrix> = None;
                let mut rebuilt = Vec::with_capacity(blocks.len());
                for b in blocks {
                    if b.hash.len() != n || b.signs.len() != n || b.rows == 0 {
                        return Err(SolverError::invalid("inconsistent sparse replay block"));
                    }
                    let block = SparseBlock {
                        rows: b.rows,
                        hash: b.hash,
                        signs: b.signs,
                        weight: (b.rows as f64).sqrt(),
                    };
                    let rows = block.apply(a);
                    match &mut sa {
                        None => sa = Some(rows),
                        Some(acc) => acc.append_rows(&rows),
                    }
                    rebuilt.push(block);
                }
                Ok(Self {
                    kind,
                    n,
                    sa: sa.expect("blocks verified non-empty"),
                    state: State::Sparse { blocks: rebuilt },
                })
            }
        }
    }
}

/// Serializable structural state of a [`SketchEngine`] — the replay
/// header a durable snapshot stores instead of the `m x d` panel. See
/// [`SketchEngine::replay_state`] / [`SketchEngine::from_replay`].
#[derive(Clone)]
pub struct EngineReplay {
    /// Embedding family.
    pub kind: SketchKind,
    /// Ambient (data) row count the exporting engine had consumed.
    pub n: usize,
    /// Per-family block structure.
    pub state: ReplayState,
}

/// Per-family replay payload of an [`EngineReplay`].
#[derive(Clone)]
pub enum ReplayState {
    /// Gaussian growth blocks (per-segment RNG snapshots).
    Gaussian {
        /// One entry per growth block, stacked top to bottom.
        blocks: Vec<GaussianReplay>,
    },
    /// Stacked signed-Hadamard blocks plus the shared selection depth.
    Srht {
        /// One entry per data segment, left to right over the ambient
        /// coordinates.
        blocks: Vec<SrhtReplay>,
        /// Shared without-replacement selection depth (`m`).
        taken: usize,
    },
    /// Size-weighted CountSketch blocks.
    Sparse {
        /// One entry per growth block, stacked top to bottom.
        blocks: Vec<SparseReplay>,
    },
}

/// Replay form of a Gaussian growth block: the per-segment RNG snapshots
/// regenerate `S̃`'s entries; the panel is recomputed against the operand.
#[derive(Clone)]
pub struct GaussianReplay {
    /// Sketch rows in this block.
    pub rows: usize,
    /// `(RNG snapshot before the draw, operand-row count)` per column
    /// segment, in draw order.
    pub segments: Vec<(Xoshiro256, usize)>,
}

/// Replay form of an SRHT block — everything except the `ñ_b x d` FWHT
/// work buffer, which [`SketchEngine::from_replay`] recomputes.
#[derive(Clone)]
pub struct SrhtReplay {
    /// First ambient coordinate this block covers.
    pub row_offset: usize,
    /// Data rows covered (before padding).
    pub n_rows: usize,
    /// Rademacher signs, length `n_rows`.
    pub signs: Vec<f64>,
    /// Partial Fisher–Yates permutation over `0..ñ_b` (its length is the
    /// block's padded dimension).
    pub order: Vec<usize>,
}

/// Replay form of a CountSketch block; the `sqrt(rows)` size weight is
/// recomputed (bitwise) on restore.
#[derive(Clone)]
pub struct SparseReplay {
    /// Sketch rows in this block.
    pub rows: usize,
    /// Target sketch row per ambient coordinate.
    pub hash: Vec<u32>,
    /// Rademacher sign per ambient coordinate.
    pub signs: Vec<f64>,
}

/// Materialize operand rows `r0..r1` as an owned operand of the *same*
/// storage kind — replay must re-run each segment through the exact
/// kernel (dense GEMM vs CSR row-axpy) the live engine used, since the
/// two accumulate in different orders.
fn slice_rows(a: OperandRef<'_>, r0: usize, r1: usize) -> Operand {
    match a {
        OperandRef::Dense(m) => {
            let mut out = Matrix::zeros(r1 - r0, m.cols());
            for i in r0..r1 {
                out.row_mut(i - r0).copy_from_slice(m.row(i));
            }
            Operand::Dense(out)
        }
        OperandRef::Sparse(c) => {
            let mut trips = Vec::new();
            for i in r0..r1 {
                let (cols, vals) = c.row(i);
                for (&cc, &v) in cols.iter().zip(vals) {
                    trips.push((i - r0, cc as usize, v));
                }
            }
            Operand::Sparse(CsrMatrix::from_triplets(r1 - r0, c.cols(), &trips))
        }
    }
}

/// Continue a partial Fisher–Yates shuffle: select `k` more indices
/// without replacement, returning the newly selected slice. Consuming the
/// RNG exactly like [`Xoshiro256::sample_without_replacement`] does, the
/// first `m` selections of an incrementally grown sample match a one-shot
/// sample of size `m` draw for draw.
fn take_without_replacement<'a>(
    order: &'a mut [usize],
    taken: &mut usize,
    k: usize,
    rng: &mut Xoshiro256,
) -> &'a [usize] {
    let n = order.len();
    let start = *taken;
    assert!(start + k <= n, "cannot select {k} more of {n} without replacement");
    for i in start..start + k {
        let j = i + rng.next_below((n - i) as u64) as usize;
        order.swap(i, j);
    }
    *taken += k;
    &order[start..*taken]
}

/// Copy the given rows of `src` into a fresh matrix, preserving order.
fn copy_rows(src: &Matrix, rows: &[usize]) -> Matrix {
    let d = src.cols();
    let mut out = Matrix::zeros(rows.len(), d);
    for (oi, &ri) in rows.iter().enumerate() {
        out.row_mut(oi).copy_from_slice(src.row(ri));
    }
    out
}

/// Add the given rows of `src` into `dst`'s rows, in order (the stacked
/// SRHT accumulation: sketch row `k` sums one work row per block).
fn add_rows(dst: &mut Matrix, src: &Matrix, rows: &[usize]) {
    for (oi, &ri) in rows.iter().enumerate() {
        crate::linalg::axpy(1.0, src.row(ri), dst.row_mut(oi));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::sparse::CsrMatrix;
    use crate::sketch::{self, Sketch as _};

    fn test_a(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        Matrix::from_fn(n, d, |_, _| rng.next_gaussian())
    }

    const KINDS: [SketchKind; 3] = [SketchKind::Gaussian, SketchKind::Srht, SketchKind::Sparse];

    #[test]
    fn initial_sketch_matches_one_shot_sampling() {
        // Same seed, same draws: the engine's effective embedding equals
        // the non-incremental sample before any growth.
        let a = test_a(24, 5, 1);
        for kind in KINDS {
            let mut r1 = Xoshiro256::seed_from_u64(42);
            let mut r2 = Xoshiro256::seed_from_u64(42);
            let engine = SketchEngine::new(kind, 6, &a, &mut r1);
            let one_shot = sketch::sample(kind, 6, 24, &mut r2);
            assert!(
                engine.to_dense().max_abs_diff(&one_shot.to_dense()) < 1e-12,
                "{kind} initial mismatch"
            );
        }
    }

    #[test]
    fn grow_keeps_prefix_bitwise_identical() {
        let a = test_a(30, 7, 2);
        for kind in KINDS {
            let mut rng = Xoshiro256::seed_from_u64(3);
            let mut engine = SketchEngine::new(kind, 4, &a, &mut rng);
            let before = engine.sa_unnormalized().clone();
            engine.grow(11, &a, &mut rng).unwrap();
            assert_eq!(engine.m(), 11);
            for i in 0..4 {
                assert_eq!(
                    engine.sa_unnormalized().row(i),
                    before.row(i),
                    "{kind} row {i} changed under growth"
                );
            }
        }
    }

    #[test]
    fn grown_sketch_matches_dense_composition() {
        // scale * S̃A == to_dense() * A after multiple growths.
        let a = test_a(20, 6, 4); // n = 20 pads to 32
        for kind in KINDS {
            let mut rng = Xoshiro256::seed_from_u64(5);
            let mut engine = SketchEngine::new(kind, 2, &a, &mut rng);
            engine.grow(5, &a, &mut rng).unwrap();
            engine.grow(13, &a, &mut rng).unwrap();
            let mut sa = engine.sa_unnormalized().clone();
            crate::linalg::scale(engine.scale(), sa.as_mut_slice());
            let composed = engine.to_dense().matmul(&a);
            assert!(sa.max_abs_diff(&composed) < 1e-10, "{kind} grow/apply drift");
        }
    }

    #[test]
    fn csr_operand_matches_dense_operand() {
        // Same RNG stream, same matrix stored two ways: the engine's S̃A
        // must agree through construction and growth for every family.
        let mut rng0 = Xoshiro256::seed_from_u64(21);
        let dense = Matrix::from_fn(26, 6, |_, _| {
            if rng0.next_f64() < 0.3 { rng0.next_gaussian() } else { 0.0 }
        });
        let csr = CsrMatrix::from_dense(&dense);
        for kind in KINDS {
            let mut ra = Xoshiro256::seed_from_u64(22);
            let mut rb = Xoshiro256::seed_from_u64(22);
            let mut ed = SketchEngine::new(kind, 3, &dense, &mut ra);
            let mut es = SketchEngine::new(kind, 3, &csr, &mut rb);
            assert!(
                ed.sa_unnormalized().max_abs_diff(es.sa_unnormalized()) < 1e-10,
                "{kind} initial dense/CSR drift"
            );
            ed.grow(9, &dense, &mut ra).unwrap();
            es.grow(9, &csr, &mut rb).unwrap();
            assert!(
                ed.sa_unnormalized().max_abs_diff(es.sa_unnormalized()) < 1e-10,
                "{kind} grown dense/CSR drift"
            );
        }
    }

    #[test]
    fn grow_returns_exactly_the_appended_rows() {
        let a = test_a(16, 4, 6);
        for kind in KINDS {
            let mut rng = Xoshiro256::seed_from_u64(7);
            let mut engine = SketchEngine::new(kind, 3, &a, &mut rng);
            let new_rows = engine.grow(8, &a, &mut rng).unwrap();
            assert_eq!((new_rows.rows(), new_rows.cols()), (5, 4), "{kind}");
            for i in 0..5 {
                assert_eq!(new_rows.row(i), engine.sa_unnormalized().row(3 + i), "{kind}");
            }
        }
    }

    #[test]
    fn srht_rows_stay_distinct_across_growth() {
        let a = test_a(24, 3, 8); // pads to 32
        let mut rng = Xoshiro256::seed_from_u64(9);
        let mut engine = SketchEngine::new(SketchKind::Srht, 8, &a, &mut rng);
        engine.grow(20, &a, &mut rng).unwrap();
        engine.grow(32, &a, &mut rng).unwrap(); // full padded dimension
        match &engine.state {
            State::Srht { blocks, taken } => {
                let mut sel = blocks[0].order[..*taken].to_vec();
                sel.sort_unstable();
                sel.dedup();
                assert_eq!(sel.len(), 32, "rows must be without replacement");
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn append_matches_dense_composition() {
        // After streaming Δn rows, scale * S̃A == to_dense() * [A; ΔA]
        // for every family — the appended columns of the embedding act on
        // exactly the new rows.
        let a = test_a(20, 6, 40);
        let delta = test_a(7, 6, 41);
        let mut full = a.clone();
        full.append_rows(&delta);
        for kind in KINDS {
            let mut rng = Xoshiro256::seed_from_u64(42);
            let mut engine = SketchEngine::new(kind, 3, &a, &mut rng);
            engine.grow(6, &a, &mut rng).unwrap();
            engine.append_rows(&delta, &mut rng).unwrap();
            assert_eq!((engine.m(), engine.n()), (6, 27), "{kind}");
            let mut sa = engine.sa_unnormalized().clone();
            crate::linalg::scale(engine.scale(), sa.as_mut_slice());
            let composed = engine.to_dense().matmul(&full);
            assert!(sa.max_abs_diff(&composed) < 1e-10, "{kind} append/apply drift");
        }
    }

    #[test]
    fn append_then_grow_keeps_prefix_and_composition() {
        // Growth after an append must stay append-only over the
        // post-append rows and keep the embedding consistent.
        let a = test_a(24, 5, 43);
        let delta = test_a(9, 5, 44);
        let mut full = a.clone();
        full.append_rows(&delta);
        for kind in KINDS {
            let mut rng = Xoshiro256::seed_from_u64(45);
            let mut engine = SketchEngine::new(kind, 4, &a, &mut rng);
            engine.append_rows(&delta, &mut rng).unwrap();
            let before = engine.sa_unnormalized().clone();
            let new_rows = engine.grow(10, &full, &mut rng).unwrap();
            assert_eq!(engine.m(), 10, "{kind}");
            assert_eq!(new_rows.rows(), 6, "{kind}");
            for i in 0..4 {
                assert_eq!(
                    engine.sa_unnormalized().row(i),
                    before.row(i),
                    "{kind} row {i} changed by growth after append"
                );
            }
            let mut sa = engine.sa_unnormalized().clone();
            crate::linalg::scale(engine.scale(), sa.as_mut_slice());
            let composed = engine.to_dense().matmul(&full);
            assert!(sa.max_abs_diff(&composed) < 1e-10, "{kind} post-append grow drift");
        }
    }

    #[test]
    fn append_csr_matches_dense_delta() {
        // Same RNG stream, same delta stored two ways: identical updates.
        let mut rng0 = Xoshiro256::seed_from_u64(46);
        let a = test_a(22, 6, 47);
        let ddense = Matrix::from_fn(5, 6, |_, _| {
            if rng0.next_f64() < 0.4 { rng0.next_gaussian() } else { 0.0 }
        });
        let dcsr = CsrMatrix::from_dense(&ddense);
        for kind in KINDS {
            let mut ra = Xoshiro256::seed_from_u64(48);
            let mut rb = Xoshiro256::seed_from_u64(48);
            let mut ed = SketchEngine::new(kind, 5, &a, &mut ra);
            let mut es = SketchEngine::new(kind, 5, &a, &mut rb);
            ed.append_rows(&ddense, &mut ra).unwrap();
            es.append_rows(&dcsr, &mut rb).unwrap();
            assert!(
                ed.sa_unnormalized().max_abs_diff(es.sa_unnormalized()) < 1e-10,
                "{kind} dense/CSR append drift"
            );
        }
    }

    #[test]
    fn srht_append_caps_growth_at_smallest_block() {
        let a = test_a(24, 4, 49); // pads to 32
        let mut rng = Xoshiro256::seed_from_u64(50);
        let mut engine = SketchEngine::new(SketchKind::Srht, 6, &a, &mut rng);
        assert_eq!(engine.max_m(), 32);
        let delta = test_a(3, 4, 51);
        engine.append_rows(&delta, &mut rng).unwrap();
        // New block pads to max(next_pow2(3), next_pow2(2*6)) = 16.
        assert_eq!(engine.max_m(), 16);
        // Growth up to the cap works; beyond it is a structured Capacity
        // error that leaves the engine untouched (solvers stop at max_m
        // and fall back to the exact Hessian).
        let mut full = a.clone();
        full.append_rows(&delta);
        engine.grow(16, &full, &mut rng).unwrap();
        assert_eq!(engine.m(), 16);
        let before = engine.sa_unnormalized().clone();
        match engine.grow(17, &full, &mut rng) {
            Err(SolverError::Capacity(_)) => {}
            other => panic!("expected Capacity error, got {other:?}"),
        }
        assert_eq!(engine.m(), 16);
        assert_eq!(engine.sa_unnormalized(), &before);
        // Gaussian/sparse appends leave growth unbounded.
        let mut rng2 = Xoshiro256::seed_from_u64(52);
        for kind in [SketchKind::Gaussian, SketchKind::Sparse] {
            let mut e = SketchEngine::new(kind, 2, &a, &mut rng2);
            e.append_rows(&delta, &mut rng2).unwrap();
            assert_eq!(e.max_m(), usize::MAX, "{kind}");
        }
    }

    #[test]
    fn append_never_touches_sketch_size_or_scale() {
        let a = test_a(16, 4, 53);
        let delta = test_a(2, 4, 54);
        for kind in KINDS {
            let mut rng = Xoshiro256::seed_from_u64(55);
            let mut engine = SketchEngine::new(kind, 5, &a, &mut rng);
            let scale = engine.scale();
            let bytes = engine.approx_bytes();
            engine.append_rows(&delta, &mut rng).unwrap();
            assert_eq!(engine.m(), 5, "{kind}");
            assert_eq!(engine.n(), 18, "{kind}");
            assert_eq!(engine.scale(), scale, "{kind}");
            // State grew (segments / stacked block / extended hashes).
            assert!(engine.approx_bytes() >= bytes, "{kind}");
        }
    }

    #[test]
    fn sparse_growth_stacks_size_weighted_blocks() {
        let a = test_a(18, 4, 10);
        let mut rng = Xoshiro256::seed_from_u64(11);
        let mut engine = SketchEngine::new(SketchKind::Sparse, 3, &a, &mut rng);
        engine.grow(6, &a, &mut rng).unwrap();
        engine.grow(10, &a, &mut rng).unwrap();
        assert!((engine.scale() - 1.0 / 10f64.sqrt()).abs() < 1e-15);
        // Each column of the dense embedding has one entry per block,
        // with magnitude sqrt(m_i / m) — the size weighting that keeps
        // E[S^T S] = I with fresh-CountSketch variance.
        let dense = engine.to_dense();
        for j in 0..18 {
            let mags: Vec<f64> = (0..10).map(|i| dense.get(i, j).abs()).filter(|&v| v != 0.0).collect();
            assert_eq!(mags.len(), 3, "column {j}: one entry per block");
            let mut expect: Vec<f64> =
                [3f64, 3.0, 4.0].iter().map(|mi| (mi / 10.0).sqrt()).collect();
            let mut got = mags.clone();
            expect.sort_by(|a, b| a.partial_cmp(b).unwrap());
            got.sort_by(|a, b| a.partial_cmp(b).unwrap());
            for (g, e) in got.iter().zip(&expect) {
                assert!((g - e).abs() < 1e-12, "column {j}: {got:?} vs {expect:?}");
            }
        }
        // E[S^T S] = I structurally: column norms are exactly 1.
        for j in 0..18 {
            let norm2: f64 = (0..10).map(|i| dense.get(i, j).powi(2)).sum();
            assert!((norm2 - 1.0).abs() < 1e-12, "column {j} norm {norm2}");
        }
    }

    #[test]
    fn gaussian_scale_tracks_m() {
        let a = test_a(12, 3, 12);
        let mut rng = Xoshiro256::seed_from_u64(13);
        let mut engine = SketchEngine::new(SketchKind::Gaussian, 2, &a, &mut rng);
        assert!((engine.scale() - 1.0 / 2f64.sqrt()).abs() < 1e-15);
        engine.grow(9, &a, &mut rng).unwrap();
        assert!((engine.scale() - 1.0 / 3.0).abs() < 1e-15);
    }

    #[test]
    fn replay_roundtrip_is_bitwise_after_grow_and_append() {
        // Export the structural state after growth + streamed appends and
        // re-derive S̃A from the final operand: every entry must be
        // bit-for-bit identical — the property durable snapshots rely on.
        let a = test_a(24, 5, 60);
        let d1 = test_a(6, 5, 61);
        let d2 = test_a(3, 5, 62);
        let mut full = a.clone();
        full.append_rows(&d1);
        full.append_rows(&d2);
        for kind in KINDS {
            let mut rng = Xoshiro256::seed_from_u64(63);
            let mut engine = SketchEngine::new(kind, 2, &a, &mut rng);
            engine.grow(5, &a, &mut rng).unwrap();
            engine.append_rows(&d1, &mut rng).unwrap();
            let mut mid = a.clone();
            mid.append_rows(&d1);
            engine.grow(9, &mid, &mut rng).unwrap();
            engine.append_rows(&d2, &mut rng).unwrap();
            let restored = SketchEngine::from_replay(engine.replay_state(), &full).unwrap();
            assert_eq!(restored.m(), engine.m(), "{kind}");
            assert_eq!(restored.n(), engine.n(), "{kind}");
            assert_eq!(restored.kind(), kind);
            assert_eq!(restored.max_m(), engine.max_m(), "{kind}");
            assert_eq!(
                restored.sa_unnormalized(),
                engine.sa_unnormalized(),
                "{kind} replay is not bitwise"
            );
            // The restored engine keeps working: grow both in lockstep
            // from clones of the same RNG and stay bitwise twins.
            let mut e1 = engine.clone();
            let mut e2 = restored;
            let mut r1 = rng.clone();
            let mut r2 = rng.clone();
            if e1.max_m() >= 12 {
                e1.grow(12, &full, &mut r1).unwrap();
                e2.grow(12, &full, &mut r2).unwrap();
                assert_eq!(e1.sa_unnormalized(), e2.sa_unnormalized(), "{kind} post-replay grow");
            }
        }
    }

    #[test]
    fn replay_roundtrip_csr_operand() {
        // CSR-stored problems replay through the sparse kernels and stay
        // bitwise too (storage kind changes the accumulation order, so
        // the slice helper must preserve it).
        let mut rng0 = Xoshiro256::seed_from_u64(64);
        let dense = Matrix::from_fn(26, 6, |_, _| {
            if rng0.next_f64() < 0.3 { rng0.next_gaussian() } else { 0.0 }
        });
        let csr = CsrMatrix::from_dense(&dense);
        let ddense = Matrix::from_fn(4, 6, |_, _| {
            if rng0.next_f64() < 0.4 { rng0.next_gaussian() } else { 0.0 }
        });
        let dcsr = CsrMatrix::from_dense(&ddense);
        let mut full = csr.clone();
        full.append_rows(&dcsr);
        for kind in KINDS {
            let mut rng = Xoshiro256::seed_from_u64(65);
            let mut engine = SketchEngine::new(kind, 3, &csr, &mut rng);
            engine.append_rows(&dcsr, &mut rng).unwrap();
            let restored = SketchEngine::from_replay(engine.replay_state(), &full).unwrap();
            assert_eq!(
                restored.sa_unnormalized(),
                engine.sa_unnormalized(),
                "{kind} CSR replay is not bitwise"
            );
        }
    }

    #[test]
    fn replay_rejects_mismatched_operand() {
        let a = test_a(16, 4, 66);
        let mut rng = Xoshiro256::seed_from_u64(67);
        let engine = SketchEngine::new(SketchKind::Gaussian, 3, &a, &mut rng);
        let wrong = test_a(15, 4, 68);
        assert!(SketchEngine::from_replay(engine.replay_state(), &wrong).is_err());
    }

    #[test]
    fn deterministic_given_stream() {
        let a = test_a(20, 5, 14);
        for kind in KINDS {
            let run = || {
                let mut rng = Xoshiro256::seed_from_u64(15);
                let mut e = SketchEngine::new(kind, 3, &a, &mut rng);
                e.grow(7, &a, &mut rng).unwrap();
                e.sa_unnormalized().clone()
            };
            let (s1, s2) = (run(), run());
            assert_eq!(s1, s2, "{kind}");
        }
    }
}
