//! Gaussian embedding: `S` with i.i.d. `N(0, 1/m)` entries (paper §3.1).
//!
//! The sketch is stored densely; `S A` is a blocked GEMM. The `1/sqrt(m)`
//! scaling makes `E[S^T S] = I_n`, which is the normalization assumed by
//! Theorem 3's bounds on the eigenvalues of `C_S`.

use super::Sketch;
use crate::linalg::Matrix;
use crate::rng::Xoshiro256;

/// Dense Gaussian sketching matrix.
#[derive(Clone, Debug)]
pub struct GaussianSketch {
    s: Matrix,
}

impl GaussianSketch {
    /// Sample an `m x n` sketch with entries `N(0, 1/m)`.
    pub fn sample(m: usize, n: usize, rng: &mut Xoshiro256) -> Self {
        assert!(m > 0 && n > 0);
        let sigma = 1.0 / (m as f64).sqrt();
        let mut s = Matrix::zeros(m, n);
        rng.fill_gaussian(s.as_mut_slice(), sigma);
        Self { s }
    }

    /// Access the dense sketch.
    pub fn matrix(&self) -> &Matrix {
        &self.s
    }
}

impl Sketch for GaussianSketch {
    fn m(&self) -> usize {
        self.s.rows()
    }

    fn n(&self) -> usize {
        self.s.cols()
    }

    fn apply(&self, a: &Matrix) -> Matrix {
        assert_eq!(a.rows(), self.n(), "sketch/matrix dimension mismatch");
        self.s.matmul(a)
    }

    /// `S * A` for CSR input in `O(m * nnz)` via sparse row-axpy
    /// (each stored entry of `A` is touched once per sketch row).
    fn apply_csr(&self, a: &crate::linalg::sparse::CsrMatrix) -> Matrix {
        assert_eq!(a.rows(), self.n(), "sketch/matrix dimension mismatch");
        a.left_mul(&self.s)
    }

    fn to_dense(&self) -> Matrix {
        self.s.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entry_variance_is_one_over_m() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let m = 64;
        let sk = GaussianSketch::sample(m, 512, &mut rng);
        let data = sk.matrix().as_slice();
        let var: f64 = data.iter().map(|x| x * x).sum::<f64>() / data.len() as f64;
        assert!((var - 1.0 / m as f64).abs() < 0.1 / m as f64, "var {var}");
    }

    #[test]
    fn isometry_in_expectation() {
        // ||Sx||^2 concentrates around ||x||^2.
        let mut rng = Xoshiro256::seed_from_u64(2);
        let n = 256;
        let x: Vec<f64> = (0..n).map(|i| ((i as f64) * 0.1).sin()).collect();
        let x_norm2: f64 = x.iter().map(|v| v * v).sum();
        let mut acc = 0.0;
        let trials = 30;
        for _ in 0..trials {
            let sk = GaussianSketch::sample(128, n, &mut rng);
            let sx = sk.matrix().matvec(&x);
            acc += sx.iter().map(|v| v * v).sum::<f64>();
        }
        let mean = acc / trials as f64;
        assert!((mean - x_norm2).abs() < 0.1 * x_norm2, "mean {mean} vs {x_norm2}");
    }

    #[test]
    fn apply_matches_dense_matmul() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let sk = GaussianSketch::sample(5, 17, &mut rng);
        let a = Matrix::from_fn(17, 4, |i, j| (i * 4 + j) as f64 * 0.01);
        let sa = sk.apply(&a);
        let sa2 = sk.to_dense().matmul(&a);
        assert!(sa.max_abs_diff(&sa2) < 1e-12);
    }

    #[test]
    fn deterministic_given_stream() {
        let mut r1 = Xoshiro256::seed_from_u64(7);
        let mut r2 = Xoshiro256::seed_from_u64(7);
        let s1 = GaussianSketch::sample(3, 9, &mut r1);
        let s2 = GaussianSketch::sample(3, 9, &mut r2);
        assert!(s1.matrix().max_abs_diff(s2.matrix()) == 0.0);
    }
}
