//! Figures 1–3: regularization-path and fixed-`nu` comparisons of
//! CG / pCG / Algorithm 1 / Algorithm 1 (gradient-only).
//!
//! The harness reproduces the *series* the paper plots: per-`nu` and
//! cumulative wall time, and per-`nu` sketch size, mean ± std over
//! independent trials. Absolute times differ from the paper's 512 GB
//! desktop; the orderings and crossovers are the reproduction target
//! (EXPERIMENTS.md records both).

use super::write_csv;
use crate::data::synthetic::Dataset;
use crate::data::{cifar_like, mnist_like, synthetic};
use crate::sketch::SketchKind;
use crate::solvers::adaptive::AdaptiveVariant;
use crate::solvers::api::SolverSpec;
use crate::solvers::path::{run_path, PathResult};
use crate::util::stats::summarize;

/// Experiment scale. `quick` keeps CI runtimes sane; `paper` matches the
/// paper's protocol (eps 1e-10, 30 trials) at surrogate sizes.
#[derive(Clone, Copy, Debug)]
pub struct FigureConfig {
    /// Workload rows.
    pub n: usize,
    /// Workload columns.
    pub d: usize,
    /// Independent trials per series.
    pub trials: usize,
    /// Relative precision target per path point.
    pub eps: f64,
    /// Base seed (trials offset it).
    pub seed: u64,
}

impl FigureConfig {
    /// Seconds-scale configuration for CI-sized runs.
    pub fn quick() -> Self {
        Self { n: 1024, d: 128, trials: 3, eps: 1e-8, seed: 1 }
    }

    /// Paper-protocol configuration (eps 1e-10, 30 trials).
    pub fn paper() -> Self {
        Self { n: 8192, d: 512, trials: 30, eps: 1e-10, seed: 1 }
    }
}

/// One (dataset, solver) series over a nu-path, aggregated over trials.
#[derive(Clone, Debug)]
pub struct PathSeries {
    /// Dataset name.
    pub dataset: String,
    /// Canonical solver spec string.
    pub solver: String,
    /// The nu-path swept (decreasing).
    pub nus: Vec<f64>,
    /// Mean cumulative time at each nu.
    pub cum_time_mean: Vec<f64>,
    /// Std of cumulative time at each nu.
    pub cum_time_std: Vec<f64>,
    /// Mean sketch size at each nu (0 for CG).
    pub m_mean: Vec<f64>,
    /// Effective dimension at each nu (dataset property, for context).
    pub d_e: Vec<f64>,
    /// Whether every trial converged at every point.
    pub all_converged: bool,
}

/// The four solvers the paper's figures compare, as registry spec strings.
pub fn figure_solvers() -> Vec<SolverSpec> {
    vec![
        SolverSpec::Cg,
        SolverSpec::Pcg { kind: SketchKind::Srht, rho: 0.5, threads: None },
        SolverSpec::Adaptive {
            kind: SketchKind::Srht,
            variant: AdaptiveVariant::PolyakFirst,
            threads: None,
        },
        SolverSpec::Adaptive {
            kind: SketchKind::Srht,
            variant: AdaptiveVariant::GradientOnly,
            threads: None,
        },
    ]
}

/// Run one dataset x solver x path over `trials` seeds.
pub fn run_series(
    ds: &Dataset,
    nus: &[f64],
    eps: f64,
    spec: &SolverSpec,
    trials: usize,
    seed: u64,
) -> PathSeries {
    let mut cum: Vec<Vec<f64>> = vec![Vec::new(); nus.len()];
    let mut ms: Vec<Vec<f64>> = vec![Vec::new(); nus.len()];
    let mut all_converged = true;
    for trial in 0..trials {
        let res: PathResult = run_path(&ds.a, &ds.b, nus, eps, spec, seed + 1000 * trial as u64);
        for (i, p) in res.points.iter().enumerate() {
            cum[i].push(p.cumulative_time_s);
            ms[i].push(p.report.peak_m as f64);
            all_converged &= p.report.converged;
        }
    }
    let summaries: Vec<_> = cum.iter().map(|v| summarize(v)).collect();
    PathSeries {
        dataset: ds.name.clone(),
        solver: spec.to_string(),
        nus: nus.to_vec(),
        cum_time_mean: summaries.iter().map(|s| s.mean).collect(),
        cum_time_std: summaries.iter().map(|s| s.std).collect(),
        m_mean: ms.iter().map(|v| summarize(v).mean).collect(),
        d_e: nus.iter().map(|&nu| ds.effective_dimension(nu)).collect(),
        all_converged,
    }
}

/// Figure 1: regularization path `nu in {10^4 .. 10^-2}` on the MNIST-like
/// and CIFAR-like surrogates, all four solvers.
pub fn fig1(cfg: &FigureConfig) -> Vec<PathSeries> {
    let nus: Vec<f64> = (-2..=4).rev().map(|j| 10f64.powi(j)).collect();
    let datasets = [mnist_like(cfg.n, cfg.d, cfg.seed), cifar_like(cfg.n, cfg.d, cfg.seed + 1)];
    let mut out = Vec::new();
    for ds in &datasets {
        for spec in figure_solvers() {
            out.push(run_series(ds, &nus, cfg.eps, &spec, cfg.trials, cfg.seed));
        }
    }
    out
}

/// Figure 2: fixed `nu = 10`, same datasets and solvers (single-point
/// "path" so the same plumbing applies).
pub fn fig2(cfg: &FigureConfig) -> Vec<PathSeries> {
    let nus = [10.0];
    let datasets = [mnist_like(cfg.n, cfg.d, cfg.seed), cifar_like(cfg.n, cfg.d, cfg.seed + 1)];
    let mut out = Vec::new();
    for ds in &datasets {
        for spec in figure_solvers() {
            out.push(run_series(ds, &nus, cfg.eps, &spec, cfg.trials, cfg.seed));
        }
    }
    out
}

/// Figure 3: synthetic exponential (`0.95^j`) and polynomial (`1/j`)
/// decays, path `nu in {10^0 .. 10^-4}`, Gaussian *and* SRHT adaptive
/// variants (the paper's Appendix A.1 compares both embeddings here).
pub fn fig3(cfg: &FigureConfig) -> Vec<PathSeries> {
    let nus: Vec<f64> = (-4..=0).rev().map(|j| 10f64.powi(j)).collect();
    let datasets = [
        synthetic::exponential_decay(cfg.n, cfg.d, cfg.seed),
        synthetic::polynomial_decay(cfg.n, cfg.d, cfg.seed + 1),
    ];
    let mut solvers = figure_solvers();
    solvers.push(SolverSpec::Adaptive {
        kind: SketchKind::Gaussian,
        variant: AdaptiveVariant::PolyakFirst,
        threads: None,
    });
    solvers.push(SolverSpec::Pcg { kind: SketchKind::Gaussian, rho: 0.5, threads: None });
    let mut out = Vec::new();
    for ds in &datasets {
        for spec in &solvers {
            out.push(run_series(ds, &nus, cfg.eps, spec, cfg.trials, cfg.seed));
        }
    }
    out
}

/// Render series as an aligned text table (one block per dataset).
pub fn render_table(series: &[PathSeries]) -> String {
    let mut out = String::new();
    let mut datasets: Vec<&str> = series.iter().map(|s| s.dataset.as_str()).collect();
    datasets.dedup();
    for ds in datasets {
        out.push_str(&format!("\n== {ds} ==\n"));
        let group: Vec<&PathSeries> = series.iter().filter(|s| s.dataset == ds).collect();
        let nus = &group[0].nus;
        out.push_str(&format!("{:<10}", "nu"));
        out.push_str(&format!("{:>10}", "d_e"));
        for s in &group {
            out.push_str(&format!("{:>22}", format!("{} t(s)", s.solver)));
            out.push_str(&format!("{:>14}", format!("{} m", s.solver)));
        }
        out.push('\n');
        for (i, &nu) in nus.iter().enumerate() {
            out.push_str(&format!("{:<10.1e}", nu));
            out.push_str(&format!("{:>10.1}", group[0].d_e[i]));
            for s in &group {
                out.push_str(&format!(
                    "{:>22}",
                    format!("{:.3} ±{:.3}", s.cum_time_mean[i], s.cum_time_std[i])
                ));
                out.push_str(&format!("{:>14.0}", s.m_mean[i]));
            }
            out.push('\n');
        }
    }
    out
}

/// Dump series to `results/<name>.csv`.
pub fn dump_csv(name: &str, series: &[PathSeries]) -> std::io::Result<()> {
    let mut rows = Vec::new();
    for s in series {
        for i in 0..s.nus.len() {
            rows.push(format!(
                "{},{},{:e},{},{},{},{},{}",
                s.dataset,
                s.solver,
                s.nus[i],
                s.d_e[i],
                s.cum_time_mean[i],
                s.cum_time_std[i],
                s.m_mean[i],
                s.all_converged
            ));
        }
    }
    write_csv(
        format!("results/{name}.csv"),
        "dataset,solver,nu,d_e,cum_time_mean_s,cum_time_std_s,m_mean,all_converged",
        &rows,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_fig2_runs_and_converges() {
        let cfg = FigureConfig { n: 256, d: 32, trials: 1, eps: 1e-6, seed: 1 };
        let series = fig2(&cfg);
        assert_eq!(series.len(), 8); // 2 datasets x 4 solvers
        assert!(series.iter().all(|s| s.all_converged));
        // Adaptive must use m << pcg's m on these spectra at nu = 10.
        let pcg = series.iter().find(|s| s.solver.starts_with("pcg")).unwrap();
        let ada = series.iter().find(|s| s.solver == "adaptive-srht").unwrap();
        assert!(ada.m_mean[0] < pcg.m_mean[0]);
    }

    #[test]
    fn table_renders_all_solvers() {
        let cfg = FigureConfig { n: 128, d: 16, trials: 1, eps: 1e-6, seed: 2 };
        let series = fig2(&cfg);
        let table = render_table(&series);
        for s in &series {
            assert!(table.contains(&s.solver), "missing {}", s.solver);
        }
    }
}
