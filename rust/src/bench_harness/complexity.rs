//! Theorem 7: time-complexity decomposition and the adaptive-vs-pCG
//! crossover as `d_e/d` varies.
//!
//! The paper's claim: total cost splits into sketch + factor + iterate,
//! with the adaptive method's factor term scaling in `d_e` (not `d`), so
//! it wins exactly when `d_e << d`. The harness measures the three phases
//! directly from the solver reports and sweeps `nu` (hence `d_e`) to show
//! the crossover.

use super::write_csv;
use crate::data::synthetic;
use crate::sketch::{self, SketchKind};
use crate::solvers::adaptive::AdaptiveVariant;
use crate::solvers::api::{Solver as _, SolverSpec, DEFAULT_PCG_RHO};
use crate::solvers::{direct, RidgeProblem, StopRule};

/// One sweep point.
#[derive(Clone, Debug)]
pub struct ComplexityRow {
    /// Regularization level.
    pub nu: f64,
    /// Exact effective dimension at `nu`.
    pub d_e: f64,
    /// `d_e / d` — the regime axis of the crossover.
    pub de_over_d: f64,
    /// Adaptive: measured sketch-phase seconds.
    pub ada_sketch_s: f64,
    /// Adaptive: measured factorization seconds.
    pub ada_factor_s: f64,
    /// Adaptive: measured iteration-loop seconds.
    pub ada_iter_s: f64,
    /// Adaptive: total wall seconds.
    pub ada_total_s: f64,
    /// Adaptive: peak sketch size.
    pub ada_m: usize,
    /// Modeled flops for forming `SA` at the peak sketch size
    /// ([`crate::sketch::sketch_cost_flops`], Theorem 7's sketch term).
    pub ada_sketch_flops: f64,
    /// Modeled *cumulative* sketch flops if every growth re-applied `S`
    /// from scratch (the pre-incremental behavior: one full application
    /// per doubling along the observed schedule).
    pub ada_sketch_flops_regrow: f64,
    /// Modeled cumulative sketch flops down the incremental growth path
    /// actually taken ([`crate::sketch::incremental_sketch_cost_flops`]):
    /// FWHT once + row selection for SRHT, appended rows only for
    /// Gaussian.
    pub ada_sketch_flops_incremental: f64,
    /// pCG: measured sketch-phase seconds.
    pub pcg_sketch_s: f64,
    /// pCG: measured factorization (QR) seconds.
    pub pcg_factor_s: f64,
    /// pCG: measured iteration-loop seconds.
    pub pcg_iter_s: f64,
    /// pCG: total wall seconds.
    pub pcg_total_s: f64,
    /// pCG: preconditioner sketch size.
    pub pcg_m: usize,
    /// Modeled flops for pCG's preconditioner sketch.
    pub pcg_sketch_flops: f64,
    /// Whether the adaptive total beat pCG's at this point.
    pub adaptive_wins: bool,
    /// Stored entries of the data operand (`n*d` dense, `nnz` CSR).
    pub nnz: usize,
    /// Modeled flops for a CountSketch application at the adaptive peak
    /// size with the operand's actual `nnz` — the Remark 4.1 sparse-path
    /// cost the dense families are compared against.
    pub sparse_sketch_flops: f64,
}

/// Config.
#[derive(Clone, Copy, Debug)]
pub struct ComplexityConfig {
    /// Workload rows.
    pub n: usize,
    /// Workload columns.
    pub d: usize,
    /// Relative precision target.
    pub eps: f64,
    /// Workload + sketch seed.
    pub seed: u64,
}

impl ComplexityConfig {
    /// Seconds-scale configuration for CI-sized runs.
    pub fn quick() -> Self {
        Self { n: 1024, d: 128, eps: 1e-8, seed: 11 }
    }

    /// Paper-scale configuration (§5 shapes).
    pub fn paper() -> Self {
        Self { n: 8192, d: 512, eps: 1e-10, seed: 11 }
    }
}

/// Sweep `nu` (each value induces a different `d_e`) and measure both
/// solvers' phase decomposition. Both contenders run through the unified
/// [`SolverSpec`] dispatch, exactly as CLI / coordinator callers would.
pub fn run(cfg: &ComplexityConfig, nus: &[f64]) -> Vec<ComplexityRow> {
    let ds = synthetic::exponential_decay(cfg.n, cfg.d, cfg.seed);
    let ada_spec = SolverSpec::Adaptive {
        kind: SketchKind::Srht,
        variant: AdaptiveVariant::PolyakFirst,
        threads: None,
    };
    let pcg_spec = SolverSpec::Pcg { kind: SketchKind::Srht, rho: DEFAULT_PCG_RHO, threads: None };
    let mut rows = Vec::new();
    for &nu in nus {
        let problem = RidgeProblem::new(ds.a.clone(), ds.b.clone(), nu);
        let d_e = ds.effective_dimension(nu);
        let x_star = direct::solve(&problem);
        let stop = StopRule::TrueError { x_star, eps: cfg.eps };

        let ada = ada_spec.build(cfg.seed).solve(&problem, &vec![0.0; cfg.d], &stop);
        let pcg_sol = pcg_spec.build(cfg.seed + 1).solve(&problem, &vec![0.0; cfg.d], &stop);

        // Theorem 7 cost model alongside the measured times. The operand's
        // stored-entry count feeds the nnz-aware columns (n*d here — the
        // sweep data is dense — but CSR workloads thread their true nnz).
        let nnz = problem.nnz();
        let kind = SketchKind::Srht;
        let ada_sketch_flops =
            sketch::sketch_cost_flops(kind, ada.report.peak_m, cfg.n, cfg.d, None);
        let sparse_sketch_flops = sketch::sketch_cost_flops(
            SketchKind::Sparse,
            ada.report.peak_m,
            cfg.n,
            cfg.d,
            Some(nnz),
        );
        let ada_sketch_flops_regrow =
            cumulative_regrow_flops(kind, &ada.report, cfg.n, cfg.d, None);
        let ada_sketch_flops_incremental = sketch::incremental_sketch_cost_flops(
            kind,
            ada.report.peak_m,
            cfg.n,
            cfg.d,
            None,
            ada.report.doublings,
        );
        let pcg_sketch_flops =
            sketch::sketch_cost_flops(kind, pcg_sol.report.peak_m, cfg.n, cfg.d, None);

        rows.push(ComplexityRow {
            nu,
            d_e,
            de_over_d: d_e / cfg.d as f64,
            ada_sketch_s: ada.report.sketch_time_s,
            ada_factor_s: ada.report.factor_time_s,
            ada_iter_s: ada.report.iter_time_s,
            ada_total_s: ada.report.wall_time_s,
            ada_m: ada.report.peak_m,
            ada_sketch_flops,
            ada_sketch_flops_regrow,
            ada_sketch_flops_incremental,
            pcg_sketch_s: pcg_sol.report.sketch_time_s,
            pcg_factor_s: pcg_sol.report.factor_time_s,
            pcg_iter_s: pcg_sol.report.iter_time_s,
            pcg_total_s: pcg_sol.report.wall_time_s,
            pcg_m: pcg_sol.report.peak_m,
            pcg_sketch_flops,
            adaptive_wins: ada.report.wall_time_s < pcg_sol.report.wall_time_s,
            nnz,
            sparse_sketch_flops,
        });
    }
    rows
}

/// Modeled cumulative sketch flops if each doubling re-applied `S` from
/// scratch: one full application per size along the observed growth
/// schedule `m_0 * 2^i` up to `peak_m` (the report's `doublings` fixes the
/// schedule length).
fn cumulative_regrow_flops(
    kind: SketchKind,
    report: &crate::solvers::SolveReport,
    n: usize,
    d: usize,
    nnz: Option<usize>,
) -> f64 {
    let mut total = 0.0;
    let mut m = report.peak_m;
    for _ in 0..=report.doublings {
        total += sketch::sketch_cost_flops(kind, m.max(1), n, d, nnz);
        m /= 2;
    }
    total
}

/// Text table.
pub fn render_table(rows: &[ComplexityRow]) -> String {
    let mut out = String::from(
        "nu        d_e/d    adaptive: sketch+factor+iter = total (m)      pcg: sketch+factor+iter = total (m)     winner\n",
    );
    for r in rows {
        out.push_str(&format!(
            "{:<9.1e} {:>6.3}   {:>7.3}+{:>6.3}+{:>6.3} = {:>7.3} ({:>5})   {:>7.3}+{:>6.3}+{:>6.3} = {:>7.3} ({:>5})   {}\n",
            r.nu,
            r.de_over_d,
            r.ada_sketch_s,
            r.ada_factor_s,
            r.ada_iter_s,
            r.ada_total_s,
            r.ada_m,
            r.pcg_sketch_s,
            r.pcg_factor_s,
            r.pcg_iter_s,
            r.pcg_total_s,
            r.pcg_m,
            if r.adaptive_wins { "adaptive" } else { "pcg" }
        ));
    }
    out
}

/// Dump to CSV.
pub fn dump_csv(name: &str, rows: &[ComplexityRow]) -> std::io::Result<()> {
    let lines: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{}",
                r.nu, r.d_e, r.de_over_d, r.ada_sketch_s, r.ada_factor_s, r.ada_iter_s,
                r.ada_total_s, r.ada_m, r.ada_sketch_flops, r.ada_sketch_flops_regrow,
                r.ada_sketch_flops_incremental, r.pcg_sketch_s, r.pcg_factor_s, r.pcg_iter_s,
                r.pcg_total_s, r.pcg_m, r.pcg_sketch_flops, r.adaptive_wins, r.nnz,
                r.sparse_sketch_flops
            )
        })
        .collect();
    write_csv(
        format!("results/{name}.csv"),
        "nu,d_e,de_over_d,ada_sketch_s,ada_factor_s,ada_iter_s,ada_total_s,ada_m,ada_sketch_flops,ada_sketch_flops_regrow,ada_sketch_flops_incremental,pcg_sketch_s,pcg_factor_s,pcg_iter_s,pcg_total_s,pcg_m,pcg_sketch_flops,adaptive_wins,nnz,sparse_sketch_flops",
        &lines,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decomposition_sums_are_consistent() {
        let cfg = ComplexityConfig { n: 256, d: 32, eps: 1e-6, seed: 1 };
        let rows = run(&cfg, &[1.0]);
        let r = &rows[0];
        // Phases must not exceed the total (within timer noise).
        assert!(r.ada_sketch_s + r.ada_factor_s <= r.ada_total_s + 0.05);
        assert!(r.pcg_factor_s > 0.0, "pcg always factors");
        // nnz-aware columns: dense sweep data stores n*d entries, and the
        // CountSketch model is 2*nnz regardless of m.
        assert_eq!(r.nnz, 256 * 32);
        assert_eq!(r.sparse_sketch_flops, 2.0 * (256 * 32) as f64);
    }

    #[test]
    fn adaptive_uses_smaller_m_when_de_small() {
        let cfg = ComplexityConfig { n: 512, d: 64, eps: 1e-6, seed: 2 };
        let rows = run(&cfg, &[10.0]);
        let r = &rows[0];
        assert!(r.d_e < 5.0, "premise: d_e small, got {}", r.d_e);
        assert!(r.ada_m < r.pcg_m, "adaptive m {} !< pcg m {}", r.ada_m, r.pcg_m);
        // The Theorem-7 cost model must order with m (same kind, same n/d).
        assert!(r.ada_sketch_flops <= r.pcg_sketch_flops);
        assert!(r.ada_sketch_flops > 0.0);
    }

    #[test]
    fn incremental_model_never_exceeds_regrow() {
        let cfg = ComplexityConfig { n: 512, d: 64, eps: 1e-6, seed: 3 };
        let rows = run(&cfg, &[1.0, 0.1]);
        for r in &rows {
            assert!(
                r.ada_sketch_flops_incremental <= r.ada_sketch_flops_regrow,
                "incremental {:.3e} must not exceed regrow {:.3e}",
                r.ada_sketch_flops_incremental,
                r.ada_sketch_flops_regrow
            );
            // With at least one doubling, re-applying from scratch pays
            // the FWHT multiple times; the cached path pays it once.
            if r.ada_m > 1 {
                assert!(r.ada_sketch_flops_incremental < r.ada_sketch_flops_regrow);
            }
        }
    }
}
