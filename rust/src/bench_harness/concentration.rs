//! Empirical verification of the deviation bounds (Theorems 3 and 4).
//!
//! For a matrix with known spectrum and a sweep of aspect ratios `rho`,
//! sample many sketches, measure the extreme eigenvalues of
//! `C_S = D (U^T S^T S U - I) D + I`, and compare against the closed-form
//! brackets. The reproduction target: the measured eigenvalues stay inside
//! the theoretical bracket (whp) and tighten as `sqrt(rho)` — the
//! Marchenko–Pastur-edge behaviour Remark 3.1 calls tight.

use super::write_csv;
use crate::data::synthetic;
use crate::rng::Xoshiro256;
use crate::sketch::{self, SketchKind};
use crate::theory::bounds::{gaussian_bounds, srht_bounds};
use crate::theory::effective_dim::{c_s_matrix, extreme_eigenvalues};
use crate::theory::effective_dimension_from_spectrum;
use crate::util::stats::summarize;

/// One row of the concentration experiment.
#[derive(Clone, Debug)]
pub struct ConcentrationRow {
    /// Sketch family sampled.
    pub kind: SketchKind,
    /// Aspect ratio `d_e / m` of this point.
    pub rho: f64,
    /// Sketch size.
    pub m: usize,
    /// Effective dimension of the test matrix.
    pub d_e: f64,
    /// Mean measured smallest eigenvalue over trials.
    pub gamma_min_mean: f64,
    /// Mean measured largest eigenvalue over trials.
    pub gamma_max_mean: f64,
    /// Worst-case (smallest) measured minimum over trials.
    pub gamma_min_worst: f64,
    /// Worst-case (largest) measured maximum over trials.
    pub gamma_max_worst: f64,
    /// Theoretical lower bound (Definition 3.1 / 3.2, `||D|| <= 1` form).
    pub lambda_bound: f64,
    /// Theoretical upper bound.
    pub big_lambda_bound: f64,
    /// Fraction of trials inside the bracket.
    pub inside_frac: f64,
}

/// Configuration of the sweep.
#[derive(Clone, Copy, Debug)]
pub struct ConcentrationConfig {
    /// Test-matrix rows.
    pub n: usize,
    /// Test-matrix columns.
    pub d: usize,
    /// Regularization level (sets `d_e`).
    pub nu: f64,
    /// Independent sketch draws per point.
    pub trials: usize,
    /// Base RNG seed.
    pub seed: u64,
}

impl ConcentrationConfig {
    /// Seconds-scale configuration for CI-sized runs.
    pub fn quick() -> Self {
        Self { n: 512, d: 32, nu: 0.5, trials: 10, seed: 3 }
    }

    /// Paper-scale configuration.
    pub fn paper() -> Self {
        Self { n: 2048, d: 64, nu: 0.5, trials: 50, seed: 3 }
    }
}

/// Run the sweep for one sketch family over `rhos`.
pub fn run(kind: SketchKind, rhos: &[f64], cfg: &ConcentrationConfig) -> Vec<ConcentrationRow> {
    let ds = synthetic::exponential_decay(cfg.n, cfg.d, cfg.seed);
    let d_e = effective_dimension_from_spectrum(&ds.sigma, cfg.nu);
    let mut rng = Xoshiro256::seed_from_u64(cfg.seed);
    let mut rows = Vec::new();

    for &rho in rhos {
        // Theorem 3/4 prescriptions for the sketch size at this rho.
        let (m, lambda, big_lambda) = match kind {
            SketchKind::Gaussian => {
                let b = gaussian_bounds(rho.min(0.18), 0.01, d_e);
                ((d_e / rho).ceil() as usize, b.lambda, b.big_lambda)
            }
            SketchKind::Srht | SketchKind::Sparse => {
                let b = srht_bounds(rho, cfg.n, d_e);
                // Theorem 4's threshold C(n,d_e) d_e log d_e / rho easily
                // exceeds n at small scale; measure at the capped size and
                // record the bracket for reference.
                ((b.m_threshold.ceil() as usize).min(cfg.n), b.lambda, b.big_lambda)
            }
        };
        let m = m.clamp(1, crate::sketch::srht::next_pow2(cfg.n));

        let mut mins = Vec::new();
        let mut maxs = Vec::new();
        let mut inside = 0usize;
        for _ in 0..cfg.trials {
            let s = sketch::sample(kind, m, cfg.n, &mut rng);
            let cs = c_s_matrix(&ds.a.dense(), cfg.nu, s.as_ref());
            let (lo, hi) = extreme_eigenvalues(&cs);
            if lo >= lambda - 1e-9 && hi <= big_lambda + 1e-9 {
                inside += 1;
            }
            mins.push(lo);
            maxs.push(hi);
        }
        rows.push(ConcentrationRow {
            kind,
            rho,
            m,
            d_e,
            gamma_min_mean: summarize(&mins).mean,
            gamma_max_mean: summarize(&maxs).mean,
            gamma_min_worst: mins.iter().cloned().fold(f64::INFINITY, f64::min),
            gamma_max_worst: maxs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
            lambda_bound: lambda,
            big_lambda_bound: big_lambda,
            inside_frac: inside as f64 / cfg.trials as f64,
        });
    }
    rows
}

/// Text table.
pub fn render_table(rows: &[ConcentrationRow]) -> String {
    let mut out = String::from(
        "kind      rho     m      d_e    gamma_min(mean/worst)  gamma_max(mean/worst)  [lambda, Lambda]        inside\n",
    );
    for r in rows {
        out.push_str(&format!(
            "{:<8} {:>5.2} {:>6} {:>7.1}   {:>8.3} / {:>8.3}    {:>8.3} / {:>8.3}   [{:.3}, {:.3}]   {:>5.0}%\n",
            r.kind.to_string(),
            r.rho,
            r.m,
            r.d_e,
            r.gamma_min_mean,
            r.gamma_min_worst,
            r.gamma_max_mean,
            r.gamma_max_worst,
            r.lambda_bound,
            r.big_lambda_bound,
            100.0 * r.inside_frac
        ));
    }
    out
}

/// Dump rows to CSV.
pub fn dump_csv(name: &str, rows: &[ConcentrationRow]) -> std::io::Result<()> {
    let lines: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{},{},{},{},{},{},{},{},{},{},{}",
                r.kind, r.rho, r.m, r.d_e, r.gamma_min_mean, r.gamma_max_mean,
                r.gamma_min_worst, r.gamma_max_worst, r.lambda_bound, r.big_lambda_bound,
                r.inside_frac
            )
        })
        .collect();
    write_csv(
        format!("results/{name}.csv"),
        "kind,rho,m,d_e,gmin_mean,gmax_mean,gmin_worst,gmax_worst,lambda,Lambda,inside_frac",
        &lines,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gaussian_bracket_holds_empirically() {
        let cfg = ConcentrationConfig { n: 256, d: 16, nu: 0.5, trials: 5, seed: 1 };
        let rows = run(SketchKind::Gaussian, &[0.1], &cfg);
        assert_eq!(rows.len(), 1);
        let r = &rows[0];
        // Theorem 3: bracket holds with overwhelming probability at this m.
        assert!(r.inside_frac >= 0.8, "inside {}", r.inside_frac);
        assert!(r.gamma_min_worst > 0.0, "C_S must be PD");
    }

    #[test]
    fn brackets_tighten_with_smaller_rho() {
        let cfg = ConcentrationConfig { n: 256, d: 16, nu: 0.5, trials: 3, seed: 2 };
        let rows = run(SketchKind::Gaussian, &[0.18, 0.05], &cfg);
        let spread = |r: &ConcentrationRow| r.gamma_max_mean - r.gamma_min_mean;
        assert!(spread(&rows[1]) <= spread(&rows[0]) + 0.05);
    }

    #[test]
    fn srht_rows_render() {
        let cfg = ConcentrationConfig { n: 128, d: 8, nu: 1.0, trials: 3, seed: 3 };
        let rows = run(SketchKind::Srht, &[0.5], &cfg);
        let table = render_table(&rows);
        assert!(table.contains("srht"));
    }
}
