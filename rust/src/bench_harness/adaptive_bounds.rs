//! Theorems 5–6 checks: the adaptive solver's sketch size, rejection
//! count, and error decay stay within the proven bounds, across datasets
//! and regularization levels.

use super::write_csv;
use crate::data::synthetic::Dataset;
use crate::data::{cifar_like, mnist_like, synthetic};
use crate::sketch::SketchKind;
use crate::solvers::adaptive::{self, AdaptiveConfig};
use crate::solvers::{direct, RidgeProblem, StopRule};
use crate::theory::bounds::{
    gaussian_rejection_bound, gaussian_sketch_size_bound, srht_rejection_bound,
    srht_sketch_size_bound,
};

/// One check row.
#[derive(Clone, Debug)]
pub struct BoundsRow {
    /// Dataset name.
    pub dataset: String,
    /// Sketch family checked.
    pub kind: SketchKind,
    /// Regularization level.
    pub nu: f64,
    /// Exact effective dimension at `nu`.
    pub d_e: f64,
    /// Largest sketch size the solver reached.
    pub peak_m: usize,
    /// Theorem 5 sketch-size bound.
    pub m_bound: f64,
    /// Rejected candidate updates.
    pub rejections: usize,
    /// Sketch-size doublings.
    pub doublings: usize,
    /// Theorem 6 rejection-count bound.
    pub k_bound: f64,
    /// Accepted iterations.
    pub iterations: usize,
    /// Whether the stop rule was met.
    pub converged: bool,
    /// Whether both Theorem-5/6 inequalities held on this run.
    pub within_bounds: bool,
}

/// Config for the bounds sweep.
#[derive(Clone, Copy, Debug)]
pub struct BoundsConfig {
    /// Workload rows.
    pub n: usize,
    /// Workload columns.
    pub d: usize,
    /// Relative precision target.
    pub eps: f64,
    /// Workload + sketch seed.
    pub seed: u64,
}

impl BoundsConfig {
    /// Seconds-scale configuration for CI-sized runs.
    pub fn quick() -> Self {
        Self { n: 1024, d: 128, eps: 1e-8, seed: 5 }
    }
}

fn datasets(cfg: &BoundsConfig) -> Vec<Dataset> {
    vec![
        synthetic::exponential_decay(cfg.n, cfg.d, cfg.seed),
        mnist_like(cfg.n, cfg.d, cfg.seed + 1),
        cifar_like(cfg.n, cfg.d, cfg.seed + 2),
    ]
}

/// Run the sweep over datasets x {Gaussian, SRHT} x nus.
pub fn run(cfg: &BoundsConfig, nus: &[f64]) -> Vec<BoundsRow> {
    let mut rows = Vec::new();
    for ds in datasets(cfg) {
        for &nu in nus {
            let problem = RidgeProblem::new(ds.a.clone(), ds.b.clone(), nu);
            let d_e = ds.effective_dimension(nu);
            let x_star = direct::solve(&problem);
            for kind in [SketchKind::Gaussian, SketchKind::Srht] {
                let stop = StopRule::TrueError { x_star: x_star.clone(), eps: cfg.eps };
                // One config drives both the solve and the theory bounds,
                // so the bound columns can never be computed from
                // different parameters than the run used. (This is the
                // same paper-default config `SolverSpec::Adaptive` builds.)
                let acfg = AdaptiveConfig::new(kind);
                let sol =
                    adaptive::solve(&problem, &vec![0.0; ds.d()], &acfg, &stop, cfg.seed + 9)
                        .expect("bench sweep problems are well-conditioned");
                let (m_bound, k_bound) = match kind {
                    SketchKind::Gaussian => (
                        gaussian_sketch_size_bound(acfg.rho, d_e),
                        gaussian_rejection_bound(acfg.rho, d_e, acfg.m_initial),
                    ),
                    _ => (
                        srht_sketch_size_bound(acfg.rho, cfg.n, d_e),
                        srht_rejection_bound(acfg.rho, cfg.n, d_e, acfg.m_initial),
                    ),
                };
                // The sketch cannot exceed the padded row count regardless
                // of the theoretical bound.
                let m_cap = crate::sketch::srht::next_pow2(cfg.n) as f64;
                let within = (sol.report.peak_m as f64) <= m_bound.min(m_cap).max(2.0)
                    && (sol.report.doublings as f64) <= k_bound.max(1.0) + 1.0;
                rows.push(BoundsRow {
                    dataset: ds.name.clone(),
                    kind,
                    nu,
                    d_e,
                    peak_m: sol.report.peak_m,
                    m_bound,
                    rejections: sol.report.rejections,
                    doublings: sol.report.doublings,
                    k_bound,
                    iterations: sol.report.iterations,
                    converged: sol.report.converged,
                    within_bounds: within,
                });
            }
        }
    }
    rows
}

/// Text table.
pub fn render_table(rows: &[BoundsRow]) -> String {
    let mut out = String::from(
        "dataset         kind      nu        d_e     peak_m  m_bound   K(dbl)  K_bound  iters  conv  within\n",
    );
    for r in rows {
        out.push_str(&format!(
            "{:<15} {:<8} {:<9.1e} {:>7.1} {:>8} {:>8.0} {:>8} {:>8.1} {:>6} {:>5} {:>7}\n",
            r.dataset,
            r.kind.to_string(),
            r.nu,
            r.d_e,
            r.peak_m,
            r.m_bound,
            r.doublings,
            r.k_bound,
            r.iterations,
            r.converged,
            r.within_bounds
        ));
    }
    out
}

/// Dump to CSV.
pub fn dump_csv(name: &str, rows: &[BoundsRow]) -> std::io::Result<()> {
    let lines: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{},{},{},{},{},{},{},{},{},{},{},{}",
                r.dataset, r.kind, r.nu, r.d_e, r.peak_m, r.m_bound, r.rejections,
                r.doublings, r.k_bound, r.iterations, r.converged, r.within_bounds
            )
        })
        .collect();
    write_csv(
        format!("results/{name}.csv"),
        "dataset,kind,nu,d_e,peak_m,m_bound,rejections,doublings,k_bound,iterations,converged,within_bounds",
        &lines,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounds_hold_on_small_sweep() {
        let cfg = BoundsConfig { n: 256, d: 32, eps: 1e-8, seed: 1 };
        let rows = run(&cfg, &[1.0]);
        assert_eq!(rows.len(), 6); // 3 datasets x 2 kinds
        assert!(rows.iter().all(|r| r.converged), "all must converge");
        assert!(rows.iter().all(|r| r.within_bounds), "Theorem 5/6 bounds violated: {rows:#?}");
    }

    #[test]
    fn peak_m_tracks_effective_dimension() {
        // Across nu, larger d_e should not need smaller peak m (weak
        // monotonicity up to doubling granularity).
        let cfg = BoundsConfig { n: 512, d: 64, eps: 1e-8, seed: 2 };
        let rows = run(&cfg, &[10.0, 0.1]);
        let pick = |nu: f64| {
            rows.iter()
                .find(|r| r.dataset == "synthetic-exp" && r.kind == SketchKind::Gaussian && r.nu == nu)
                .unwrap()
        };
        let hi_nu = pick(10.0); // small d_e
        let lo_nu = pick(0.1); // larger d_e
        assert!(lo_nu.d_e > hi_nu.d_e);
        assert!(lo_nu.peak_m >= hi_nu.peak_m);
    }
}
