//! Benchmark harness regenerating every figure/table of the paper.
//!
//! Each experiment module exposes a `run(cfg) -> Vec<Row>`-style API used
//! both by the `bench_figures` binary (full reproduction runs, text tables
//! + CSV under `results/`) and by `cargo bench` (quick spot checks via
//! [`framework`]).
//!
//! | paper artifact | module |
//! |----------------|--------|
//! | Figure 1 (reg-path, MNIST/CIFAR surrogates) | [`figures`] `fig1` |
//! | Figure 2 (fixed nu)                          | [`figures`] `fig2` |
//! | Figure 3 (synthetic exp/poly decays)         | [`figures`] `fig3` |
//! | Theorem 3/4 concentration checks             | [`concentration`] |
//! | Theorem 5/6 adaptive bounds                  | [`adaptive_bounds`] |
//! | Theorem 7 complexity decomposition           | [`complexity`] |

pub mod adaptive_bounds;
pub mod complexity;
pub mod concentration;
pub mod figures;
pub mod framework;

pub use framework::{bench, BenchResult};

use std::io::Write as _;
use std::path::Path;

/// Write CSV rows (with header) under `results/`.
pub fn write_csv(path: impl AsRef<Path>, header: &str, rows: &[String]) -> std::io::Result<()> {
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{header}")?;
    for row in rows {
        writeln!(f, "{row}")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    #[test]
    fn write_csv_creates_file() {
        let dir = std::env::temp_dir().join("effdim-csv-test");
        let path = dir.join("t.csv");
        super::write_csv(&path, "a,b", &["1,2".into(), "3,4".into()]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 3);
        let _ = std::fs::remove_dir_all(dir);
    }
}
