//! Minimal benchmarking framework (criterion replacement for the offline
//! build): warmup, timed iterations, summary statistics.

use crate::util::stats::{fmt_time, summarize, Summary};
use std::time::Instant;

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Case label.
    pub name: String,
    /// Timing statistics over the measured iterations.
    pub summary: Summary,
}

impl BenchResult {
    /// One aligned human-readable table line.
    pub fn report_line(&self) -> String {
        let s = &self.summary;
        format!(
            "{:<44} {:>12} (±{:>10}, min {:>10}, n={})",
            self.name,
            fmt_time(s.mean),
            fmt_time(s.std),
            fmt_time(s.min),
            s.n
        )
    }
}

/// Run `f` for `iters` timed iterations after `warmup` untimed ones.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    BenchResult { name: name.to_string(), summary: summarize(&times) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let r = bench("noop", 1, 5, || 42);
        assert_eq!(r.summary.n, 5);
        assert!(r.report_line().contains("noop"));
    }

    #[test]
    fn bench_measures_work() {
        let fast = bench("fast", 0, 3, || 1 + 1);
        let slow = bench("slow", 0, 3, || {
            // Feed black_box input so the loop cannot be const-folded.
            let mut s = std::hint::black_box(0u64);
            for i in 0..200_000 {
                s = s.wrapping_add(std::hint::black_box(i));
            }
            s
        });
        assert!(slow.summary.mean > fast.summary.mean);
    }
}
