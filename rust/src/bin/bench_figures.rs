//! Full reproduction runs for every paper figure/table.
//!
//! ```text
//! bench_figures fig1 [--paper]      # Figure 1: reg-path, MNIST/CIFAR-like
//! bench_figures fig2 [--paper]      # Figure 2: fixed nu = 10
//! bench_figures fig3 [--paper]      # Figure 3: synthetic exp/poly decays
//! bench_figures concentration       # Theorems 3-4 eigenvalue brackets
//! bench_figures adaptive_bounds     # Theorems 5-6 m/K bounds
//! bench_figures complexity          # Theorem 7 phase decomposition
//! bench_figures all [--paper]
//! ```
//!
//! Text tables go to stdout; CSVs land under `results/`.

use effdim::bench_harness::{adaptive_bounds, complexity, concentration, figures};
use effdim::sketch::SketchKind;
use effdim::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let what = args.subcommand.clone().unwrap_or_else(|| "all".to_string());
    let paper = args.has("paper");

    let fig_cfg = if paper { figures::FigureConfig::paper() } else { figures::FigureConfig::quick() };
    let mut ran_any = false;

    if matches!(what.as_str(), "fig1" | "all") {
        ran_any = true;
        println!("=== Figure 1: regularization path (nu 1e4 .. 1e-2) ===");
        let series = figures::fig1(&fig_cfg);
        println!("{}", figures::render_table(&series));
        figures::dump_csv("fig1_regpath", &series).expect("write csv");
        println!("-> results/fig1_regpath.csv");
    }
    if matches!(what.as_str(), "fig2" | "all") {
        ran_any = true;
        println!("=== Figure 2: fixed nu = 10 ===");
        let series = figures::fig2(&fig_cfg);
        println!("{}", figures::render_table(&series));
        figures::dump_csv("fig2_fixed_nu", &series).expect("write csv");
        println!("-> results/fig2_fixed_nu.csv");
    }
    if matches!(what.as_str(), "fig3" | "all") {
        ran_any = true;
        println!("=== Figure 3: synthetic spectral decays (nu 1e0 .. 1e-4) ===");
        let series = figures::fig3(&fig_cfg);
        println!("{}", figures::render_table(&series));
        figures::dump_csv("fig3_synthetic", &series).expect("write csv");
        println!("-> results/fig3_synthetic.csv");
    }
    if matches!(what.as_str(), "concentration" | "all") {
        ran_any = true;
        println!("=== Theorems 3-4: C_S eigenvalue concentration ===");
        let cfg = if paper {
            concentration::ConcentrationConfig::paper()
        } else {
            concentration::ConcentrationConfig::quick()
        };
        let mut rows = concentration::run(SketchKind::Gaussian, &[0.18, 0.1, 0.05], &cfg);
        rows.extend(concentration::run(SketchKind::Srht, &[0.5, 0.25, 0.1], &cfg));
        println!("{}", concentration::render_table(&rows));
        concentration::dump_csv("concentration", &rows).expect("write csv");
        println!("-> results/concentration.csv");
    }
    if matches!(what.as_str(), "adaptive_bounds" | "all") {
        ran_any = true;
        println!("=== Theorems 5-6: adaptive sketch-size / rejection bounds ===");
        let cfg = adaptive_bounds::BoundsConfig::quick();
        let rows = adaptive_bounds::run(&cfg, &[10.0, 1.0, 0.1]);
        println!("{}", adaptive_bounds::render_table(&rows));
        adaptive_bounds::dump_csv("adaptive_bounds", &rows).expect("write csv");
        println!("-> results/adaptive_bounds.csv");
    }
    if matches!(what.as_str(), "complexity" | "all") {
        ran_any = true;
        println!("=== Theorem 7: complexity decomposition & crossover ===");
        let cfg = if paper { complexity::ComplexityConfig::paper() } else { complexity::ComplexityConfig::quick() };
        let rows = complexity::run(&cfg, &[100.0, 10.0, 1.0, 0.1, 0.01]);
        println!("{}", complexity::render_table(&rows));
        complexity::dump_csv("complexity", &rows).expect("write csv");
        println!("-> results/complexity.csv");
    }

    if !ran_any {
        eprintln!("unknown experiment: {what}");
        std::process::exit(2);
    }
}
