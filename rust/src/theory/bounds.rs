//! Concentration bounds of Theorems 3–6 and the practical parameter
//! choices of Definitions 3.1 / 3.2.
//!
//! The adaptive algorithm never *measures* eigenvalues of `C_S`; it trusts
//! these closed-form brackets, which hold with high probability once the
//! sketch size crosses the (unknown) effective-dimension threshold. The
//! benchmark harness separately *verifies* the brackets empirically
//! (`bench_harness::concentration`).

use super::rates::{IhsParams, Rates};

/// A probabilistic eigenvalue bracket for `C_S` together with the sketch
/// size threshold at which it activates.
#[derive(Clone, Copy, Debug)]
pub struct EigenBounds {
    /// Lower bound `lambda` on the smallest eigenvalue.
    pub lambda: f64,
    /// Upper bound `Lambda` on the largest eigenvalue.
    pub big_lambda: f64,
    /// Sketch size at which the bracket holds w.h.p. (`m >= threshold`).
    pub m_threshold: f64,
    /// Failure probability of the bracket at `m == threshold`.
    pub failure_prob: f64,
}

/// `c_eta = (1 + 3 sqrt(eta))^2` from Theorem 3.
pub fn c_eta(eta: f64) -> f64 {
    let r = 1.0 + 3.0 * eta.sqrt();
    r * r
}

/// Definition 3.1 (Gaussian practical parameters, `||D||_2` replaced by 1):
/// `lambda = (1 - sqrt(c_eta rho))^2`, `Lambda = (1 + sqrt(c_eta rho))^2`,
/// valid for `rho <= 0.18`, `eta <= 0.01`; bracket holds w.p.
/// `>= 1 - 8 exp(-m rho eta / 2)` once `m >= d_e / rho` (Theorem 3).
pub fn gaussian_bounds(rho: f64, eta: f64, d_e: f64) -> EigenBounds {
    assert!(rho > 0.0 && rho <= 0.18, "Theorem 3 requires rho in (0, 0.18], got {rho}");
    assert!(eta > 0.0 && eta <= 0.01, "Theorem 3 requires eta in (0, 0.01], got {eta}");
    let s = (c_eta(eta) * rho).sqrt();
    let m_threshold = d_e / rho;
    EigenBounds {
        lambda: (1.0 - s) * (1.0 - s),
        big_lambda: (1.0 + s) * (1.0 + s),
        m_threshold,
        failure_prob: 8.0 * (-m_threshold * rho * eta / 2.0).exp(),
    }
}

/// Oversampling factor `C(n, d_e) = 16/3 (1 + sqrt(8 log(d_e n) / d_e))^2`
/// from §3.2.
pub fn srht_oversampling(n: usize, d_e: f64) -> f64 {
    let de = d_e.max(1.0);
    let arg = (de * n as f64).max(2.0);
    let r = 1.0 + (8.0 * arg.ln() / de).sqrt();
    16.0 / 3.0 * r * r
}

/// Definition 3.2 (SRHT practical parameters): `lambda = 1 - sqrt(rho)`,
/// `Lambda = 1 + sqrt(rho)`; bracket holds w.p. `>= 1 - 9/d_e` once
/// `m >= C(n, d_e) d_e log(d_e) / rho` (Theorem 4).
pub fn srht_bounds(rho: f64, n: usize, d_e: f64) -> EigenBounds {
    assert!(rho > 0.0 && rho < 1.0, "Theorem 4 requires rho in (0,1), got {rho}");
    let s = rho.sqrt();
    let de = d_e.max(2.0);
    let m_threshold = srht_oversampling(n, d_e) * de * de.ln() / rho;
    EigenBounds {
        lambda: 1.0 - s,
        big_lambda: 1.0 + s,
        m_threshold,
        failure_prob: 9.0 / de,
    }
}

impl EigenBounds {
    /// Derive the Algorithm-1 parameters from the bracket.
    pub fn params(&self) -> IhsParams {
        Rates::new(self.lambda, self.big_lambda).params()
    }
}

/// Theorem 5 sketch-size bound for Gaussian embeddings:
/// `m <= 2 c0 d_e / rho` with `c0 <= 5`.
pub fn gaussian_sketch_size_bound(rho: f64, d_e: f64) -> f64 {
    2.0 * 5.0 * d_e / rho
}

/// Theorem 5 bound on the number of rejected updates (Gaussian):
/// `K <= log2(c0 d_e / (m_init rho)) + 1`.
pub fn gaussian_rejection_bound(rho: f64, d_e: f64, m_initial: usize) -> f64 {
    let arg = (5.0 * d_e / (m_initial as f64 * rho)).max(1.0);
    arg.log2() + 1.0
}

/// `a_rho = (1 + sqrt(rho)) / (1 - sqrt(rho))` from Theorem 6.
pub fn a_rho(rho: f64) -> f64 {
    (1.0 + rho.sqrt()) / (1.0 - rho.sqrt())
}

/// Theorem 6 sketch-size bound for the SRHT:
/// `m <= 2 a_rho C(n, d_e) d_e log(d_e) / rho`.
pub fn srht_sketch_size_bound(rho: f64, n: usize, d_e: f64) -> f64 {
    let de = d_e.max(2.0);
    2.0 * a_rho(rho) * srht_oversampling(n, d_e) * de * de.ln() / rho
}

/// Theorem 6 rejection bound (SRHT).
pub fn srht_rejection_bound(rho: f64, n: usize, d_e: f64, m_initial: usize) -> f64 {
    let de = d_e.max(2.0);
    let arg = (a_rho(rho) * srht_oversampling(n, d_e) * de * de.ln() / (m_initial as f64 * rho)).max(1.0);
    arg.log2() + 1.0
}

/// Theorem 5 relative-error bound prefactor (Gaussian):
/// `delta_t/delta_1 <= 9 (1 + sigma1^2/nu^2) max(1, d_e/m_init) c_gd^{t-1}`.
pub fn gaussian_error_prefactor(sigma1: f64, nu: f64, d_e: f64, m_initial: usize) -> f64 {
    9.0 * (1.0 + sigma1 * sigma1 / (nu * nu)) * (d_e / m_initial as f64).max(1.0)
}

/// Theorem 6 relative-error bound prefactor (SRHT):
/// `delta_t/delta_1 <= 2 (1 + sigma1^2/nu^2) c_gd^{t-1}`.
pub fn srht_error_prefactor(sigma1: f64, nu: f64) -> f64 {
    2.0 * (1.0 + sigma1 * sigma1 / (nu * nu))
}

/// Theorem 7 iteration count `T = O(log(1/eps) / log(1/rho))` — the exact
/// ceiling from the proof (Appendix B.4).
pub fn srht_iterations_to_eps(eps: f64, rho: f64, sigma1: f64, nu: f64) -> usize {
    assert!(eps > 0.0 && eps < 1.0 && rho > 0.0 && rho < 1.0);
    let num = (2.0f64).ln() + (1.0 + sigma1 * sigma1 / (nu * nu)).ln() + (1.0 / eps).ln();
    (num / (1.0 / rho).ln()).ceil() as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gaussian_bracket_symmetric_around_one() {
        let b = gaussian_bounds(0.1, 0.01, 100.0);
        // (1±s)^2 bracket: geometric mean is 1 - s^2... check containment.
        assert!(b.lambda > 0.0 && b.lambda < 1.0);
        assert!(b.big_lambda > 1.0);
        assert!((b.lambda.sqrt() + b.big_lambda.sqrt() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn srht_bracket_matches_definition() {
        let b = srht_bounds(0.25, 4096, 50.0);
        assert!((b.lambda - 0.5).abs() < 1e-12);
        assert!((b.big_lambda - 1.5).abs() < 1e-12);
    }

    #[test]
    fn srht_c_gd_equals_rho() {
        // Core identity used in Theorem 7's proof.
        let b = srht_bounds(0.3, 1024, 20.0);
        assert!((b.params().c_gd - 0.3).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "Theorem 3 requires rho")]
    fn gaussian_rejects_large_rho() {
        gaussian_bounds(0.5, 0.01, 10.0);
    }

    #[test]
    fn thresholds_scale_with_effective_dimension() {
        let b1 = gaussian_bounds(0.1, 0.01, 10.0);
        let b2 = gaussian_bounds(0.1, 0.01, 100.0);
        assert!((b2.m_threshold / b1.m_threshold - 10.0).abs() < 1e-9);
        let s1 = srht_bounds(0.1, 1 << 12, 10.0);
        let s2 = srht_bounds(0.1, 1 << 12, 100.0);
        assert!(s2.m_threshold > s1.m_threshold);
    }

    #[test]
    fn srht_needs_log_oversampling_vs_gaussian() {
        // For equal (rho, d_e), the SRHT threshold must exceed the Gaussian
        // one by (at least) the log d_e factor.
        let d_e = 200.0;
        let g = gaussian_bounds(0.1, 0.01, d_e);
        let h = srht_bounds(0.1, 1 << 14, d_e);
        assert!(h.m_threshold > g.m_threshold * d_e.ln() / 2.0);
    }

    #[test]
    fn a_rho_monotone_and_above_one() {
        assert!(a_rho(0.01) > 1.0);
        assert!(a_rho(0.5) > a_rho(0.1));
    }

    #[test]
    fn rejection_bounds_logarithmic() {
        let k1 = gaussian_rejection_bound(0.1, 100.0, 1);
        let k2 = gaussian_rejection_bound(0.1, 200.0, 1);
        assert!((k2 - k1 - 1.0).abs() < 1e-9, "doubling d_e adds one rejection");
        let ks = srht_rejection_bound(0.1, 4096, 100.0, 1);
        assert!(ks > k1, "SRHT rejects more (log d_e oversampling)");
    }

    #[test]
    fn iterations_to_eps_scales_logarithmically() {
        let t1 = srht_iterations_to_eps(1e-4, 0.1, 10.0, 1.0);
        let t2 = srht_iterations_to_eps(1e-8, 0.1, 10.0, 1.0);
        assert!(t2 > t1 && t2 < 3 * t1);
    }

    #[test]
    fn error_prefactors_positive_and_ordered() {
        // Gaussian prefactor with m_init=1 dominates the SRHT one (paper's
        // discussion after Theorem 6).
        let g = gaussian_error_prefactor(10.0, 1.0, 50.0, 1);
        let s = srht_error_prefactor(10.0, 1.0);
        assert!(g > s);
        assert!(s > 0.0);
    }
}
