//! Effective dimension and the deviation matrix `C_S`.
//!
//! `d_e := trace(A (A^T A + nu^2 I)^{-1} A^T) = sum_i sigma_i^2 / (sigma_i^2 + nu^2)`
//! is the quantity the whole paper revolves around: it is the sketch size
//! at which the eigenvalues of
//! `C_S = D (U^T S^T S U - I) D + I` concentrate around 1.
//!
//! This module computes `d_e` exactly from a spectrum (or a matrix, via the
//! Jacobi SVD), builds `D` and `C_S` for the concentration experiments, and
//! provides a Hutchinson-type randomized trace estimator — the heuristic
//! the paper cites from \[31\] as the alternative its adaptive method makes
//! unnecessary.

use crate::linalg::cholesky::Cholesky;
use crate::linalg::matrix::Matrix;
use crate::linalg::svd::{singular_values, svd};
use crate::rng::Xoshiro256;
use crate::sketch::Sketch;

/// `d_e` from the singular values of `A` at regularization `nu`.
///
/// Total: a zero singular value contributes 0 even at `nu = 0` (the
/// term is `0/0` termwise, but `lim_{s->0} s^2/(s^2) = 0` is the only
/// consistent continuation — a zero mode never adds effective
/// dimension), and an invalid `nu` (negative or non-finite) yields NaN
/// instead of panicking. Callers holding wire- or CLI-provided input
/// should prefer [`try_effective_dimension_from_spectrum`] and surface
/// the error.
pub fn effective_dimension_from_spectrum(sigma: &[f64], nu: f64) -> f64 {
    try_effective_dimension_from_spectrum(sigma, nu).unwrap_or(f64::NAN)
}

/// Validating form of [`effective_dimension_from_spectrum`]: errors on a
/// negative or non-finite `nu` (server-reachable input must produce a
/// clean error, not an assertion panic); zero singular values contribute
/// 0 (total at `sigma_i = nu = 0`).
pub fn try_effective_dimension_from_spectrum(sigma: &[f64], nu: f64) -> Result<f64, String> {
    if !nu.is_finite() || nu < 0.0 {
        return Err(format!("effective dimension needs a finite nu >= 0, got {nu}"));
    }
    Ok(sigma
        .iter()
        .map(|&s| {
            let s2 = s * s;
            if s2 > 0.0 {
                s2 / (s2 + nu * nu)
            } else {
                0.0
            }
        })
        .sum())
}

/// `d_e` computed exactly from `A` (Jacobi SVD; test/diagnostic use).
pub fn effective_dimension(a: &Matrix, nu: f64) -> f64 {
    effective_dimension_from_spectrum(&singular_values(a), nu)
}

/// The diagonal of `D = diag(sigma_i / sqrt(sigma_i^2 + nu^2))`.
///
/// Total: a zero singular value maps to 0 even at `nu = 0` (otherwise a
/// `0/0` NaN — the deviation matrix `C_S` treats a zero mode as
/// contributing nothing, matching [`effective_dimension_from_spectrum`]).
pub fn d_diagonal(sigma: &[f64], nu: f64) -> Vec<f64> {
    sigma
        .iter()
        .map(|&s| if s == 0.0 { 0.0 } else { s / (s * s + nu * nu).sqrt() })
        .collect()
}

/// Hutchinson trace estimator for
/// `d_e = trace((A^T A)(A^T A + nu^2 I)^{-1})` using `probes` Rademacher
/// probes: `d_e ≈ mean_z z^T G (G + nu^2 I)^{-1} z`, `G = A^T A`.
/// This is the \[31\]-style heuristic; the adaptive method exists precisely
/// so you never need it, but we ship it for comparison experiments.
pub fn hutchinson_effective_dimension(a: &Matrix, nu: f64, probes: usize, rng: &mut Xoshiro256) -> f64 {
    let d = a.cols();
    let mut gram = a.gram();
    let g = gram.clone();
    gram.add_diag(nu * nu);
    let chol = Cholesky::factor(&gram).expect("ridge Gram is PD");
    let mut z = vec![0.0; d];
    let mut acc = 0.0;
    for _ in 0..probes.max(1) {
        rng.fill_rademacher(&mut z);
        // z^T G (G + nu^2 I)^{-1} z
        let w = chol.solve(&z);
        let gw = g.matvec(&w);
        acc += crate::linalg::dot(&z, &gw);
    }
    acc / probes.max(1) as f64
}

/// Empirical `C_S = D (U^T S^T S U - I) D + I` for a given problem matrix
/// and sketch. Used by the concentration harness (Theorems 3–4 checks);
/// never on the solve path.
pub fn c_s_matrix(a: &Matrix, nu: f64, sketch: &dyn Sketch) -> Matrix {
    let f = svd(a);
    let d_diag = d_diagonal(&f.s, nu);
    let su = sketch.apply(&f.u); // m x d
    let mut dev = su.gram(); // U^T S^T S U
    let d = a.cols();
    // dev <- D (dev - I) D + I
    for i in 0..d {
        for j in 0..d {
            let delta = if i == j { 1.0 } else { 0.0 };
            let v = d_diag[i] * (dev.get(i, j) - delta) * d_diag[j] + delta;
            dev.set(i, j, v);
        }
    }
    dev
}

/// Extreme eigenvalues `(gamma_d, gamma_1)` of a symmetric PSD matrix via
/// its (Jacobi) singular values — for symmetric PSD these coincide with the
/// eigenvalues.
pub fn extreme_eigenvalues(sym: &Matrix) -> (f64, f64) {
    let s = singular_values(sym);
    (*s.last().unwrap(), s[0])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::{gaussian::GaussianSketch, srht::SrhtSketch};

    fn decaying_matrix(n: usize, d: usize, seed: u64) -> Matrix {
        // A = U diag(sigma) V^T with exponentially decaying sigma.
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let g1 = Matrix::from_fn(n, d, |_, _| rng.next_gaussian());
        let g2 = Matrix::from_fn(d, d, |_, _| rng.next_gaussian());
        let u = crate::linalg::qr::QR::factor(g1).q_thin();
        let v = crate::linalg::qr::QR::factor(g2).q_thin();
        let sigma: Vec<f64> = (0..d).map(|j| 0.8f64.powi(j as i32)).collect();
        u.matmul(&Matrix::diag(&sigma)).matmul(&v.transpose())
    }

    #[test]
    fn de_limits() {
        let sigma = vec![1.0, 1.0, 1.0];
        // nu -> 0: d_e -> rank; nu -> inf: d_e -> 0.
        assert!((effective_dimension_from_spectrum(&sigma, 0.0) - 3.0).abs() < 1e-12);
        assert!(effective_dimension_from_spectrum(&sigma, 1e6) < 1e-9);
    }

    #[test]
    fn degenerate_spectrum_terms_are_total() {
        // sigma_i = 0 at nu = 0 used to be 0/0 = NaN; a zero mode must
        // contribute zero effective dimension (d_e -> rank, not NaN).
        let sigma = vec![2.0, 1.0, 0.0];
        let de = effective_dimension_from_spectrum(&sigma, 0.0);
        assert!((de - 2.0).abs() < 1e-12, "d_e at nu=0 must equal the rank, got {de}");
        // And the D diagonal's 0/0 term is likewise pinned to 0.
        let d = d_diagonal(&sigma, 0.0);
        assert_eq!(d[2], 0.0);
        assert!((d[0] - 1.0).abs() < 1e-12 && (d[1] - 1.0).abs() < 1e-12);
        // s = 0 with nu > 0 stays 0 (was already well-defined).
        assert_eq!(d_diagonal(&[0.0], 0.5)[0], 0.0);
    }

    #[test]
    fn invalid_nu_errors_instead_of_panicking() {
        let sigma = vec![1.0, 0.5];
        // The plain form is total: NaN, never a panic.
        assert!(effective_dimension_from_spectrum(&sigma, -1.0).is_nan());
        assert!(effective_dimension_from_spectrum(&sigma, f64::NAN).is_nan());
        assert!(effective_dimension_from_spectrum(&sigma, f64::INFINITY).is_nan());
        // The validating form names the problem.
        let err = try_effective_dimension_from_spectrum(&sigma, -1.0).unwrap_err();
        assert!(err.contains("nu"), "{err}");
        assert!(try_effective_dimension_from_spectrum(&sigma, f64::NAN).is_err());
        let ok = try_effective_dimension_from_spectrum(&sigma, 0.5).unwrap();
        assert!((ok - effective_dimension_from_spectrum(&sigma, 0.5)).abs() == 0.0);
    }

    #[test]
    fn de_monotone_in_nu() {
        let sigma: Vec<f64> = (0..20).map(|j| 0.9f64.powi(j)).collect();
        let d1 = effective_dimension_from_spectrum(&sigma, 0.1);
        let d2 = effective_dimension_from_spectrum(&sigma, 1.0);
        assert!(d1 > d2);
    }

    #[test]
    fn de_from_matrix_matches_spectrum() {
        let a = decaying_matrix(24, 8, 1);
        let s = singular_values(&a);
        let d1 = effective_dimension(&a, 0.5);
        let d2 = effective_dimension_from_spectrum(&s, 0.5);
        assert!((d1 - d2).abs() < 1e-9);
    }

    #[test]
    fn hutchinson_close_to_exact() {
        let a = decaying_matrix(30, 10, 2);
        let exact = effective_dimension(&a, 0.3);
        let mut rng = Xoshiro256::seed_from_u64(3);
        let est = hutchinson_effective_dimension(&a, 0.3, 200, &mut rng);
        assert!((est - exact).abs() < 0.15 * exact.max(1.0), "est {est} exact {exact}");
    }

    #[test]
    fn d_diagonal_in_unit_interval() {
        let sigma = vec![5.0, 1.0, 0.1];
        for v in d_diagonal(&sigma, 0.5) {
            assert!(v > 0.0 && v < 1.0);
        }
    }

    #[test]
    fn c_s_identity_for_orthogonal_full_sketch() {
        // With m = n_pad and SRHT, S is a (scaled) orthogonal matrix, so
        // U^T S^T S U = I and C_S = I exactly.
        let a = decaying_matrix(16, 4, 4);
        let mut rng = Xoshiro256::seed_from_u64(5);
        let sk = SrhtSketch::sample(16, 16, &mut rng);
        let cs = c_s_matrix(&a, 0.5, &sk);
        assert!(cs.max_abs_diff(&Matrix::eye(4)) < 1e-8);
    }

    #[test]
    fn c_s_eigenvalues_concentrate_with_m() {
        let a = decaying_matrix(32, 6, 6);
        let mut rng = Xoshiro256::seed_from_u64(7);
        let small = GaussianSketch::sample(8, 32, &mut rng);
        let large = GaussianSketch::sample(256, 32, &mut rng);
        let (lo_s, hi_s) = extreme_eigenvalues(&c_s_matrix(&a, 0.5, &small));
        let (lo_l, hi_l) = extreme_eigenvalues(&c_s_matrix(&a, 0.5, &large));
        // Larger sketch => tighter bracket around 1.
        assert!((hi_l - 1.0).abs() < (hi_s - 1.0).abs() + 0.05);
        assert!((1.0 - lo_l) < (1.0 - lo_s) + 0.05);
        assert!(lo_s > 0.0, "C_S is positive definite (paper §2)");
    }
}
