//! Convergence rates and step sizes of Theorems 1–2.
//!
//! Given eigenvalue bounds `0 < lambda <= eig(C_S) <= Lambda`:
//!
//! * gradient-IHS (Theorem 1): step `mu_gd = 2 / (1/lambda + 1/Lambda)`,
//!   per-step rate `c_gd = ((Lambda - lambda) / (Lambda + lambda))^2`;
//! * Polyak-IHS (Theorem 2): step
//!   `mu_p = 4 / (1/sqrt(lambda) + 1/sqrt(Lambda))^2`, momentum
//!   `beta_p = ((sqrt(Lambda) - sqrt(lambda)) / (sqrt(Lambda) + sqrt(lambda)))^2`,
//!   asymptotic rate `c_p = beta_p`.

/// Eigenvalue bracket `[lambda, Lambda]` for `C_S`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Rates {
    /// Lower eigenvalue bound `lambda`.
    pub lambda: f64,
    /// Upper eigenvalue bound `Lambda`.
    pub big_lambda: f64,
}

/// Full set of algorithmic parameters derived from a bracket — the inputs
/// of Algorithm 1.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct IhsParams {
    /// Gradient-IHS step size `mu_gd`.
    pub mu_gd: f64,
    /// Polyak-IHS step size `mu_p`.
    pub mu_p: f64,
    /// Polyak momentum `beta_p`.
    pub beta_p: f64,
    /// Target per-step rate for gradient-IHS acceptance, `c_gd`.
    pub c_gd: f64,
    /// Target geometric-mean rate for Polyak-IHS acceptance, `c_p`.
    pub c_p: f64,
}

impl Rates {
    /// Build a bracket; panics unless `0 < lambda <= Lambda`.
    pub fn new(lambda: f64, big_lambda: f64) -> Self {
        assert!(
            lambda > 0.0 && big_lambda >= lambda,
            "invalid eigenvalue bracket [{lambda}, {big_lambda}]"
        );
        Self { lambda, big_lambda }
    }

    /// Theorem 1 step size.
    pub fn mu_gd(&self) -> f64 {
        2.0 / (1.0 / self.lambda + 1.0 / self.big_lambda)
    }

    /// Theorem 1 per-iteration contraction factor.
    pub fn c_gd(&self) -> f64 {
        let r = (self.big_lambda - self.lambda) / (self.big_lambda + self.lambda);
        r * r
    }

    /// Theorem 2 step size.
    pub fn mu_p(&self) -> f64 {
        let s = 1.0 / self.lambda.sqrt() + 1.0 / self.big_lambda.sqrt();
        4.0 / (s * s)
    }

    /// Theorem 2 momentum parameter.
    pub fn beta_p(&self) -> f64 {
        let num = self.big_lambda.sqrt() - self.lambda.sqrt();
        let den = self.big_lambda.sqrt() + self.lambda.sqrt();
        let r = num / den;
        r * r
    }

    /// Theorem 2 asymptotic rate (equals `beta_p`).
    pub fn c_p(&self) -> f64 {
        self.beta_p()
    }

    /// Bundle everything into [`IhsParams`].
    pub fn params(&self) -> IhsParams {
        IhsParams {
            mu_gd: self.mu_gd(),
            mu_p: self.mu_p(),
            beta_p: self.beta_p(),
            c_gd: self.c_gd(),
            c_p: self.c_p(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn symmetric_bracket_identity() {
        // lambda == Lambda == 1: exact Newton, rate 0, step 1.
        let r = Rates::new(1.0, 1.0);
        assert!((r.mu_gd() - 1.0).abs() < 1e-15);
        assert!(r.c_gd().abs() < 1e-15);
        assert!((r.mu_p() - 1.0).abs() < 1e-15);
        assert!(r.beta_p().abs() < 1e-15);
    }

    #[test]
    fn srht_practical_rate_is_rho() {
        // Definition 3.2: lambda = 1 - sqrt(rho), Lambda = 1 + sqrt(rho)
        // => c_gd = rho exactly (used in the proof of Theorem 7).
        for rho in [0.01f64, 0.1, 0.25, 0.5, 0.9] {
            let r = Rates::new(1.0 - rho.sqrt(), 1.0 + rho.sqrt());
            assert!((r.c_gd() - rho).abs() < 1e-12, "rho={rho}");
        }
    }

    #[test]
    fn polyak_accelerates_over_gradient() {
        // c_p = sqrt-conditioning rate must beat c_gd for any nontrivial
        // bracket.
        let r = Rates::new(0.4, 1.6);
        assert!(r.c_p() < r.c_gd());
    }

    #[test]
    fn rates_in_unit_interval() {
        let r = Rates::new(0.05, 3.0);
        for v in [r.c_gd(), r.c_p(), r.beta_p()] {
            assert!((0.0..1.0).contains(&v));
        }
        assert!(r.mu_gd() > 0.0 && r.mu_p() > 0.0);
    }

    #[test]
    #[should_panic(expected = "invalid eigenvalue bracket")]
    fn rejects_nonpositive_lambda() {
        Rates::new(0.0, 1.0);
    }

    #[test]
    fn params_bundle_consistent() {
        let r = Rates::new(0.3, 1.9);
        let p = r.params();
        assert_eq!(p.mu_gd, r.mu_gd());
        assert_eq!(p.c_p, r.c_p());
    }
}
