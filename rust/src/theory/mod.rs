//! Closed-form theory of the paper: convergence rates, algorithmic
//! parameters, effective dimension, and the concentration bounds of
//! Theorems 3–7.
//!
//! Everything the adaptive algorithm needs at run time — step sizes,
//! momentum, target improvement ratios — is a pure function of
//! `(lambda, Lambda)` eigenvalue bounds for `C_S`, which in turn are pure
//! functions of the aspect ratio `rho` (and `eta` for Gaussian sketches).
//! Keeping these as plain functions makes the parameter plumbing in
//! [`crate::solvers::adaptive`] exactly mirror Definitions 3.1 / 3.2.

pub mod bounds;
pub mod effective_dim;
pub mod rates;

pub use bounds::{gaussian_bounds, srht_bounds, EigenBounds};
pub use effective_dim::{
    effective_dimension, effective_dimension_from_spectrum, try_effective_dimension_from_spectrum,
};
pub use rates::{IhsParams, Rates};
