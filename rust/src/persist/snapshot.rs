//! Checksummed model snapshots.
//!
//! A snapshot captures everything needed to rebuild a
//! [`ModelSession`] that answers **bitwise-identically** to the live
//! one, while staying compact: the operand and observations are stored
//! verbatim, but the sketch is stored as its *replay header*
//! ([`EngineReplay`] — per-block RNG snapshots and padding/selection
//! structure), never the `m x d` applied panel, which recovery
//! re-derives from the operand
//! ([`SketchEngine::from_replay`](crate::sketch::engine::SketchEngine::from_replay)).
//! `A^T b` is accumulated incrementally across appends, so its exact bit
//! pattern is history-dependent: the snapshot stores its bytes inline
//! plus a CRC digest that recovery re-verifies against the decoded
//! vector ([`ModelSnapshot::verify_atb_digest`]).
//!
//! The whole file carries a trailing CRC-32 over every preceding byte;
//! decode rejects magic/version/CRC mismatches with a structured error
//! (never a panic), so a half-written or bit-flipped snapshot surfaces
//! as "recover from the previous one", not a crash loop.
//!
//! Writes go through [`write_atomic`]: write `<file>.tmp`, fsync, rename
//! over the final name, fsync the directory — a crash at any point
//! leaves either the old snapshot or the new one, never a torn hybrid.

use super::codec::{self, Cursor};
use crate::linalg::Operand;
use crate::rng::Xoshiro256;
use crate::sketch::engine::{EngineReplay, GaussianReplay, ReplayState, SparseReplay, SrhtReplay};
use crate::sketch::SketchKind;
use crate::solvers::session::ModelSession;
use crate::util::failpoint;
use std::fs::File;
use std::io::{self, Write};
use std::path::Path;

/// Snapshot file magic: `"EFDS"` little-endian.
pub const SNAPSHOT_MAGIC: u32 = 0x5344_4645;
/// Current snapshot format version.
pub const SNAPSHOT_VERSION: u32 = 1;

/// Decoded persistent state of one model.
pub struct ModelSnapshot {
    /// Registered model name.
    pub name: String,
    /// Sketch family the session grows.
    pub kind: SketchKind,
    /// Solver seed.
    pub seed: u64,
    /// The data operand, storage kind preserved.
    pub a: Operand,
    /// Observations `b`.
    pub b: Vec<f64>,
    /// The incrementally accumulated `A^T b`, bytes verbatim.
    pub atb: Vec<f64>,
    /// Stored CRC digest of the `atb` bit patterns.
    pub atb_digest: u32,
    /// Solver state, if the session had solved at least once.
    pub state: Option<SolverStateSnapshot>,
    /// Warm-start vector from the last primary-RHS solve.
    pub warm: Option<Vec<f64>>,
    /// `(nu_bits, eps_bits)` keys the solution cache held (the vectors
    /// are not persisted — recovered sessions re-answer from state).
    pub cache_keys: Vec<(u64, u64)>,
    /// Lifetime query counter at snapshot time.
    pub queries: u64,
    /// Mutation epoch at snapshot time.
    pub epoch: u64,
}

/// Persistent form of an
/// [`AdaptiveSessionState`](crate::solvers::adaptive::AdaptiveSessionState).
pub struct SolverStateSnapshot {
    /// Sketch replay header, or `None` at the exact-Hessian cap.
    pub engine: Option<EngineReplay>,
    /// Regularization level the Woodbury factorization was built at.
    pub cache_nu: f64,
    /// Session RNG state (core words plus the cached polar spare).
    pub rng_state: ([u64; 4], Option<f64>),
}

impl ModelSnapshot {
    /// Re-verify the stored `A^T b` digest against the decoded vector.
    /// Decode already checks this; recovery calls it once more after any
    /// further handling as defense in depth.
    pub fn verify_atb_digest(&self) -> Result<(), String> {
        let got = atb_digest(&self.atb);
        if got != self.atb_digest {
            return Err(format!(
                "A^T b digest mismatch: stored {:#010x}, computed {got:#010x}",
                self.atb_digest
            ));
        }
        Ok(())
    }
}

/// CRC digest of an `A^T b` vector's bit patterns.
pub fn atb_digest(atb: &[f64]) -> u32 {
    let mut buf = Vec::with_capacity(8 + atb.len() * 8);
    codec::put_f64_slice(&mut buf, atb);
    codec::crc32(&buf)
}

fn kind_tag(kind: SketchKind) -> u8 {
    match kind {
        SketchKind::Gaussian => 0,
        SketchKind::Srht => 1,
        SketchKind::Sparse => 2,
    }
}

fn kind_from_tag(tag: u8) -> Result<SketchKind, String> {
    match tag {
        0 => Ok(SketchKind::Gaussian),
        1 => Ok(SketchKind::Srht),
        2 => Ok(SketchKind::Sparse),
        t => Err(format!("bad sketch-kind tag {t}")),
    }
}

fn put_rng_state(out: &mut Vec<u8>, state: &([u64; 4], Option<f64>)) {
    for w in state.0 {
        codec::put_u64(out, w);
    }
    codec::put_opt_f64(out, state.1);
}

fn take_rng_state(c: &mut Cursor<'_>) -> Result<([u64; 4], Option<f64>), String> {
    let mut s = [0u64; 4];
    for w in &mut s {
        *w = c.take_u64()?;
    }
    Ok((s, c.take_opt_f64()?))
}

fn put_engine(out: &mut Vec<u8>, r: &EngineReplay) {
    codec::put_u8(out, kind_tag(r.kind));
    codec::put_usize(out, r.n);
    match &r.state {
        ReplayState::Gaussian { blocks } => {
            codec::put_u8(out, 0);
            codec::put_usize(out, blocks.len());
            for b in blocks {
                codec::put_usize(out, b.rows);
                codec::put_usize(out, b.segments.len());
                for (rng, cols) in &b.segments {
                    put_rng_state(out, &rng.state());
                    codec::put_usize(out, *cols);
                }
            }
        }
        ReplayState::Srht { blocks, taken } => {
            codec::put_u8(out, 1);
            codec::put_usize(out, *taken);
            codec::put_usize(out, blocks.len());
            for b in blocks {
                codec::put_usize(out, b.row_offset);
                codec::put_usize(out, b.n_rows);
                codec::put_f64_slice(out, &b.signs);
                codec::put_usize_slice(out, &b.order);
            }
        }
        ReplayState::Sparse { blocks } => {
            codec::put_u8(out, 2);
            codec::put_usize(out, blocks.len());
            for b in blocks {
                codec::put_usize(out, b.rows);
                codec::put_u32_slice(out, &b.hash);
                codec::put_f64_slice(out, &b.signs);
            }
        }
    }
}

fn take_engine(c: &mut Cursor<'_>) -> Result<EngineReplay, String> {
    let kind = kind_from_tag(c.take_u8()?)?;
    let n = c.take_usize()?;
    let state = match c.take_u8()? {
        0 => {
            let nb = c.take_usize()?;
            let mut blocks = Vec::with_capacity(nb.min(1024));
            for _ in 0..nb {
                let rows = c.take_usize()?;
                let ns = c.take_usize()?;
                let mut segments = Vec::with_capacity(ns.min(1024));
                for _ in 0..ns {
                    let (s, spare) = take_rng_state(c)?;
                    let cols = c.take_usize()?;
                    segments.push((Xoshiro256::from_state(s, spare), cols));
                }
                blocks.push(GaussianReplay { rows, segments });
            }
            ReplayState::Gaussian { blocks }
        }
        1 => {
            let taken = c.take_usize()?;
            let nb = c.take_usize()?;
            let mut blocks = Vec::with_capacity(nb.min(1024));
            for _ in 0..nb {
                blocks.push(SrhtReplay {
                    row_offset: c.take_usize()?,
                    n_rows: c.take_usize()?,
                    signs: c.take_f64_vec()?,
                    order: c.take_usize_vec()?,
                });
            }
            ReplayState::Srht { blocks, taken }
        }
        2 => {
            let nb = c.take_usize()?;
            let mut blocks = Vec::with_capacity(nb.min(1024));
            for _ in 0..nb {
                blocks.push(SparseReplay {
                    rows: c.take_usize()?,
                    hash: c.take_u32_vec()?,
                    signs: c.take_f64_vec()?,
                });
            }
            ReplayState::Sparse { blocks }
        }
        t => return Err(format!("bad replay-state tag {t}")),
    };
    Ok(EngineReplay { kind, n, state })
}

/// Serialize a session to snapshot bytes. Flushes lazily appended rows
/// first (bitwise-neutral — see
/// [`ModelSession::flush_appended`]) so the replay header
/// covers exactly the stored operand.
pub fn encode_session(name: &str, session: &mut ModelSession) -> Result<Vec<u8>, String> {
    session.flush_appended()?;
    let mut out = Vec::new();
    codec::put_u32(&mut out, SNAPSHOT_MAGIC);
    codec::put_u32(&mut out, SNAPSHOT_VERSION);
    codec::put_str(&mut out, name);
    codec::put_u8(&mut out, kind_tag(session.kind()));
    codec::put_u64(&mut out, session.seed());
    codec::put_operand(&mut out, session.operand());
    codec::put_f64_slice(&mut out, session.b());
    codec::put_f64_slice(&mut out, session.atb());
    codec::put_u32(&mut out, atb_digest(session.atb()));
    match session.state() {
        None => codec::put_u8(&mut out, 0),
        Some(st) => {
            codec::put_u8(&mut out, 1);
            match st.engine() {
                None => codec::put_u8(&mut out, 0),
                Some(e) => {
                    codec::put_u8(&mut out, 1);
                    put_engine(&mut out, &e.replay_state());
                }
            }
            codec::put_f64(&mut out, st.cache_nu());
            put_rng_state(&mut out, &st.rng().state());
        }
    }
    match session.warm() {
        None => codec::put_u8(&mut out, 0),
        Some(w) => {
            codec::put_u8(&mut out, 1);
            codec::put_f64_slice(&mut out, w);
        }
    }
    let keys = session.solution_keys();
    codec::put_usize(&mut out, keys.len());
    for (nu_bits, eps_bits) in keys {
        codec::put_u64(&mut out, nu_bits);
        codec::put_u64(&mut out, eps_bits);
    }
    let (queries, _) = session.query_stats();
    codec::put_u64(&mut out, queries);
    codec::put_u64(&mut out, session.epoch());
    let crc = codec::crc32(&out);
    codec::put_u32(&mut out, crc);
    Ok(out)
}

/// Decode and fully verify snapshot bytes: magic, version, trailing
/// file CRC, then the stored `A^T b` digest.
pub fn decode(bytes: &[u8]) -> Result<ModelSnapshot, String> {
    if bytes.len() < 12 {
        return Err(format!("snapshot too short ({} bytes)", bytes.len()));
    }
    let body = &bytes[..bytes.len() - 4];
    let stored_crc = {
        let t = &bytes[bytes.len() - 4..];
        u32::from_le_bytes([t[0], t[1], t[2], t[3]])
    };
    let computed = codec::crc32(body);
    if computed != stored_crc {
        return Err(format!(
            "snapshot checksum mismatch: stored {stored_crc:#010x}, computed {computed:#010x}"
        ));
    }
    let mut c = Cursor::new(body);
    let magic = c.take_u32()?;
    if magic != SNAPSHOT_MAGIC {
        return Err(format!("bad snapshot magic {magic:#010x}"));
    }
    let version = c.take_u32()?;
    if version != SNAPSHOT_VERSION {
        return Err(format!(
            "unsupported snapshot version {version} (this build reads {SNAPSHOT_VERSION})"
        ));
    }
    let name = c.take_str()?;
    let kind = kind_from_tag(c.take_u8()?)?;
    let seed = c.take_u64()?;
    let a = c.take_operand()?;
    let b = c.take_f64_vec()?;
    let atb = c.take_f64_vec()?;
    let atb_digest = c.take_u32()?;
    let state = match c.take_u8()? {
        0 => None,
        1 => {
            let engine = match c.take_u8()? {
                0 => None,
                1 => Some(take_engine(&mut c)?),
                t => return Err(format!("bad engine tag {t}")),
            };
            let cache_nu = c.take_f64()?;
            let rng_state = take_rng_state(&mut c)?;
            Some(SolverStateSnapshot { engine, cache_nu, rng_state })
        }
        t => return Err(format!("bad state tag {t}")),
    };
    let warm = match c.take_u8()? {
        0 => None,
        1 => Some(c.take_f64_vec()?),
        t => return Err(format!("bad warm tag {t}")),
    };
    let nk = c.take_usize()?;
    let mut cache_keys = Vec::with_capacity(nk.min(1024));
    for _ in 0..nk {
        cache_keys.push((c.take_u64()?, c.take_u64()?));
    }
    let queries = c.take_u64()?;
    let epoch = c.take_u64()?;
    if c.remaining() != 0 {
        return Err(format!("{} trailing bytes after snapshot body", c.remaining()));
    }
    let snap = ModelSnapshot {
        name,
        kind,
        seed,
        a,
        b,
        atb,
        atb_digest,
        state,
        warm,
        cache_keys,
        queries,
        epoch,
    };
    snap.verify_atb_digest()?;
    Ok(snap)
}

/// Durably replace the file at `path` with `bytes`: write `path.tmp`,
/// fsync it, rename over `path`, then fsync the parent directory so the
/// rename itself is durable. A crash anywhere in the sequence leaves the
/// previous snapshot (or nothing) — never a partial file under the final
/// name. The `persist.snapshot` failpoint fires before any byte lands.
pub fn write_atomic(path: &Path, bytes: &[u8]) -> Result<(), String> {
    failpoint::check("persist.snapshot")?;
    let tmp = path.with_extension("tmp");
    let write = || -> io::Result<()> {
        {
            let mut f = File::create(&tmp)?;
            f.write_all(bytes)?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, path)?;
        if let Some(parent) = path.parent() {
            // Directory fsync makes the rename durable; best-effort on
            // filesystems that refuse to open directories.
            if let Ok(d) = File::open(parent) {
                let _ = d.sync_all();
            }
        }
        Ok(())
    };
    write().map_err(|e| format!("snapshot write to {} failed: {e}", path.display()))
}

/// Read and decode a snapshot file.
pub fn load(path: &Path) -> Result<ModelSnapshot, String> {
    let bytes = std::fs::read(path)
        .map_err(|e| format!("snapshot read from {} failed: {e}", path.display()))?;
    decode(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use std::sync::Arc;

    fn grown_session(kind: SketchKind) -> ModelSession {
        let ds = synthetic::exponential_decay(96, 12, 77);
        let mut s = ModelSession::new(Arc::new(ds.a), ds.b, kind, 7).unwrap();
        s.solve(0.5, 1e-8).unwrap();
        s
    }

    #[test]
    fn encode_decode_round_trips_all_families() {
        for kind in [SketchKind::Gaussian, SketchKind::Srht, SketchKind::Sparse] {
            let mut s = grown_session(kind);
            let bytes = encode_session("my-model", &mut s).unwrap();
            let snap = decode(&bytes).unwrap();
            assert_eq!(snap.name, "my-model");
            assert_eq!(snap.kind, kind);
            assert_eq!(snap.seed, 7);
            assert_eq!(snap.a.rows(), 96);
            assert_eq!(snap.b.len(), 96);
            let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&snap.atb), bits(s.atb()));
            assert_eq!(bits(snap.warm.as_deref().unwrap()), bits(s.warm().unwrap()));
            assert_eq!(snap.cache_keys, s.solution_keys());
            assert_eq!(snap.queries, 1);
            assert_eq!(snap.epoch, 1);
            let st = snap.state.expect("solved session has state");
            assert!(st.engine.is_some());
            assert_eq!(st.cache_nu.to_bits(), s.state().unwrap().cache_nu().to_bits());
            snap_verifies(&bytes);
        }
    }

    fn snap_verifies(bytes: &[u8]) {
        decode(bytes).unwrap().verify_atb_digest().unwrap();
    }

    #[test]
    fn unsolved_session_snapshot_has_no_state() {
        let ds = synthetic::exponential_decay(48, 6, 78);
        let mut s =
            ModelSession::new(Arc::new(ds.a), ds.b, SketchKind::Gaussian, 5).unwrap();
        let bytes = encode_session("cold", &mut s).unwrap();
        let snap = decode(&bytes).unwrap();
        assert!(snap.state.is_none());
        assert!(snap.warm.is_none());
        assert!(snap.cache_keys.is_empty());
        assert_eq!((snap.queries, snap.epoch), (0, 0));
    }

    #[test]
    fn every_single_byte_flip_is_detected() {
        let mut s = grown_session(SketchKind::Gaussian);
        let bytes = encode_session("flip", &mut s).unwrap();
        // Exhaustive over a prefix and a suffix (the file is a few KB;
        // stride the middle to keep the test fast while still crossing
        // every field).
        let len = bytes.len();
        let positions: Vec<usize> = (0..len.min(64))
            .chain((64..len).step_by(97))
            .chain(len.saturating_sub(16)..len)
            .collect();
        for pos in positions {
            let mut bad = bytes.clone();
            bad[pos] ^= 0x01;
            assert!(decode(&bad).is_err(), "flip at byte {pos} went undetected");
        }
        // Truncation at any length is also rejected.
        for cut in 0..len {
            assert!(decode(&bytes[..cut]).is_err(), "truncation to {cut} decoded");
        }
    }

    #[test]
    fn stored_atb_digest_is_verified_independently_of_the_file_crc() {
        let ds = synthetic::exponential_decay(8, 2, 79);
        let mut s =
            ModelSession::new(Arc::new(ds.a), ds.b, SketchKind::Gaussian, 3).unwrap();
        assert!(
            matches!(&**s.operand(), Operand::Dense(_)),
            "offset arithmetic below assumes a dense operand"
        );
        let mut bytes = encode_session("x", &mut s).unwrap();
        // Locate the digest field from the fixed layout: magic+version,
        // name, kind, seed, dense operand (tag+rows+cols+entries), b,
        // atb — the digest is the next 4 bytes.
        let off = 4 + 4 // magic + version
            + 8 + 1 // name "x"
            + 1 // kind tag
            + 8 // seed
            + 1 + 8 + 8 + 8 * 2 * 8 // dense operand 8x2
            + 8 + 8 * 8 // b
            + 8 + 2 * 8; // atb
        bytes[off] ^= 0xFF; // corrupt the stored digest...
        let body_len = bytes.len() - 4;
        let crc = codec::crc32(&bytes[..body_len]);
        bytes[body_len..].copy_from_slice(&crc.to_le_bytes()); // ...and re-seal the file CRC
        let err = decode(&bytes).unwrap_err();
        assert!(err.contains("digest"), "want the digest check to fire, got: {err}");
    }

    #[test]
    fn wrong_magic_and_version_are_structured_errors() {
        let mut s = grown_session(SketchKind::Srht);
        let bytes = encode_session("v", &mut s).unwrap();
        let reseal = |mut b: Vec<u8>| -> Vec<u8> {
            let body = b.len() - 4;
            let crc = codec::crc32(&b[..body]);
            b[body..].copy_from_slice(&crc.to_le_bytes());
            b
        };
        let mut wrong_magic = bytes.clone();
        wrong_magic[0] ^= 0xAA;
        let err = decode(&reseal(wrong_magic)).unwrap_err();
        assert!(err.contains("magic"), "{err}");
        let mut wrong_version = bytes.clone();
        wrong_version[4] = 0xEE;
        let err = decode(&reseal(wrong_version)).unwrap_err();
        assert!(err.contains("version"), "{err}");
    }

    #[test]
    fn write_atomic_replaces_and_never_leaves_tmp() {
        let dir = std::env::temp_dir().join(format!("effdim-snap-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.snap");
        write_atomic(&path, b"first").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"first");
        write_atomic(&path, b"second").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"second");
        assert!(!path.with_extension("tmp").exists(), "tmp file must not survive");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn snapshot_is_much_smaller_than_the_applied_panel() {
        // The replay header stores RNG snapshots + structure, not S̃A.
        // For a Gaussian sketch the panel would be m*d f64s; the header
        // must stay well under the operand-dominated budget.
        let mut s = grown_session(SketchKind::Gaussian);
        let m = s.m();
        assert!(m > 0);
        let bytes = encode_session("sz", &mut s).unwrap();
        let operand_bytes = 96 * 12 * 8;
        let panel_bytes = m * 12 * 8;
        assert!(
            bytes.len() < operand_bytes + panel_bytes / 2 + 4096,
            "snapshot {} bytes; operand {} + panel {}",
            bytes.len(),
            operand_bytes,
            panel_bytes
        );
    }
}
