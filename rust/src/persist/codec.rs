//! Byte-level encoding primitives shared by the WAL record format and
//! the snapshot format: little-endian integers, bit-exact `f64` vectors
//! (serialized via [`f64::to_bits`] so a decode→encode round trip is the
//! identity on every value, NaN payloads and signed zeros included), a
//! storage-kind-preserving [`Operand`] codec, and a table-driven CRC-32
//! (IEEE 802.3 polynomial — no external crate).
//!
//! Every decoder goes through [`Cursor`], which bounds-checks each read
//! and returns a structured error instead of panicking: a torn or
//! bit-flipped file must surface as a recoverable decode failure, never
//! as an index-out-of-bounds abort of the recovering server.

use crate::linalg::sparse::CsrMatrix;
use crate::linalg::{Matrix, Operand};

// ---------------------------------------------------------------------
// CRC-32 (IEEE), table built at compile time.

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC32_TABLE: [u32; 256] = crc32_table();

/// CRC-32 (IEEE 802.3 / zlib polynomial) of `data`.
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = CRC32_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// ---------------------------------------------------------------------
// Writers.

/// Append a `u8`.
pub fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

/// Append a little-endian `u32`.
pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Append a little-endian `u64`.
pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Append a `usize` as a little-endian `u64` (portable across word
/// sizes; the decoder rejects values that do not fit the host `usize`).
pub fn put_usize(out: &mut Vec<u8>, v: usize) {
    put_u64(out, v as u64);
}

/// Append an `f64` by bit pattern.
pub fn put_f64(out: &mut Vec<u8>, v: f64) {
    put_u64(out, v.to_bits());
}

/// Append a length-prefixed `f64` vector, bit-exact.
pub fn put_f64_slice(out: &mut Vec<u8>, v: &[f64]) {
    put_usize(out, v.len());
    for &x in v {
        put_f64(out, x);
    }
}

/// Append a length-prefixed `u32` vector.
pub fn put_u32_slice(out: &mut Vec<u8>, v: &[u32]) {
    put_usize(out, v.len());
    for &x in v {
        put_u32(out, x);
    }
}

/// Append a length-prefixed `usize` vector (as `u64`s).
pub fn put_usize_slice(out: &mut Vec<u8>, v: &[usize]) {
    put_usize(out, v.len());
    for &x in v {
        put_usize(out, x);
    }
}

/// Append a length-prefixed UTF-8 string.
pub fn put_str(out: &mut Vec<u8>, s: &str) {
    put_usize(out, s.len());
    out.extend_from_slice(s.as_bytes());
}

/// Append an `Option<f64>` as a presence tag plus the bit pattern.
pub fn put_opt_f64(out: &mut Vec<u8>, v: Option<f64>) {
    match v {
        None => put_u8(out, 0),
        Some(x) => {
            put_u8(out, 1);
            put_f64(out, x);
        }
    }
}

/// Append an [`Operand`] preserving its storage kind: dense matrices as
/// their row-major entry slab, CSR matrices as a per-row
/// `(count, cols, values)` walk. Both directions are bitwise round-trip
/// safe — the CSR walk yields already-sorted, duplicate-free triplets,
/// which [`CsrMatrix::from_triplets`] reassembles verbatim.
pub fn put_operand(out: &mut Vec<u8>, op: &Operand) {
    match op {
        Operand::Dense(m) => {
            put_u8(out, 0);
            put_usize(out, m.rows());
            put_usize(out, m.cols());
            for &x in m.as_slice() {
                put_f64(out, x);
            }
        }
        Operand::Sparse(c) => {
            put_u8(out, 1);
            put_usize(out, c.rows());
            put_usize(out, c.cols());
            for i in 0..c.rows() {
                let (cols, vals) = c.row(i);
                put_usize(out, cols.len());
                for &cc in cols {
                    put_u32(out, cc);
                }
                for &v in vals {
                    put_f64(out, v);
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// Reader.

/// Cap on any single decoded length prefix. A corrupt length field must
/// fail fast, not drive a multi-gigabyte allocation before the CRC (or
/// a bounds check) catches it.
const MAX_DECODE_LEN: u64 = 1 << 33;

/// Bounds-checked sequential reader over an encoded byte buffer.
pub struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    /// Start reading at the beginning of `buf`.
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.remaining() < n {
            return Err(format!(
                "truncated record: wanted {n} bytes at offset {}, have {}",
                self.pos,
                self.remaining()
            ));
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    /// Read a `u8`.
    pub fn take_u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    /// Read a little-endian `u32`.
    pub fn take_u32(&mut self) -> Result<u32, String> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// Read a little-endian `u64`.
    pub fn take_u64(&mut self) -> Result<u64, String> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    /// Read a `usize` (stored as `u64`; rejects implausible lengths).
    pub fn take_usize(&mut self) -> Result<usize, String> {
        let v = self.take_u64()?;
        if v > MAX_DECODE_LEN {
            return Err(format!("implausible length field {v}"));
        }
        Ok(v as usize)
    }

    /// Read an `f64` by bit pattern.
    pub fn take_f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_bits(self.take_u64()?))
    }

    /// Read a length-prefixed `f64` vector.
    pub fn take_f64_vec(&mut self) -> Result<Vec<f64>, String> {
        let n = self.take_usize()?;
        if self.remaining() < n.saturating_mul(8) {
            return Err(format!("truncated f64 vector: {n} entries past end"));
        }
        (0..n).map(|_| self.take_f64()).collect()
    }

    /// Read a length-prefixed `u32` vector.
    pub fn take_u32_vec(&mut self) -> Result<Vec<u32>, String> {
        let n = self.take_usize()?;
        if self.remaining() < n.saturating_mul(4) {
            return Err(format!("truncated u32 vector: {n} entries past end"));
        }
        (0..n).map(|_| self.take_u32()).collect()
    }

    /// Read a length-prefixed `usize` vector.
    pub fn take_usize_vec(&mut self) -> Result<Vec<usize>, String> {
        let n = self.take_usize()?;
        if self.remaining() < n.saturating_mul(8) {
            return Err(format!("truncated usize vector: {n} entries past end"));
        }
        (0..n).map(|_| self.take_usize()).collect()
    }

    /// Read a length-prefixed UTF-8 string.
    pub fn take_str(&mut self) -> Result<String, String> {
        let n = self.take_usize()?;
        let b = self.take(n)?;
        String::from_utf8(b.to_vec()).map_err(|_| "invalid UTF-8 in string field".into())
    }

    /// Read an `Option<f64>`.
    pub fn take_opt_f64(&mut self) -> Result<Option<f64>, String> {
        match self.take_u8()? {
            0 => Ok(None),
            1 => Ok(Some(self.take_f64()?)),
            t => Err(format!("bad option tag {t}")),
        }
    }

    /// Read an [`Operand`] written by [`put_operand`].
    pub fn take_operand(&mut self) -> Result<Operand, String> {
        let tag = self.take_u8()?;
        let rows = self.take_usize()?;
        let cols = self.take_usize()?;
        match tag {
            0 => {
                let want = rows.saturating_mul(cols);
                if self.remaining() < want.saturating_mul(8) {
                    return Err("truncated dense operand".into());
                }
                let mut data = Vec::with_capacity(want);
                for _ in 0..want {
                    data.push(self.take_f64()?);
                }
                Ok(Operand::Dense(Matrix::from_vec(rows, cols, data)))
            }
            1 => {
                let mut trips: Vec<(usize, usize, f64)> = Vec::new();
                for i in 0..rows {
                    let nnz = self.take_usize()?;
                    if self.remaining() < nnz.saturating_mul(12) {
                        return Err(format!("truncated CSR row {i}"));
                    }
                    let mut row_cols = Vec::with_capacity(nnz);
                    for _ in 0..nnz {
                        let cc = self.take_u32()? as usize;
                        if cc >= cols {
                            return Err(format!("CSR column {cc} out of range (< {cols})"));
                        }
                        row_cols.push(cc);
                    }
                    for &cc in &row_cols {
                        trips.push((i, cc, 0.0));
                    }
                    let base = trips.len() - nnz;
                    for k in 0..nnz {
                        trips[base + k].2 = self.take_f64()?;
                    }
                }
                Ok(Operand::Sparse(CsrMatrix::from_triplets(rows, cols, &trips)))
            }
            t => Err(format!("bad operand tag {t}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vectors() {
        // Canonical IEEE CRC-32 check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_ne!(crc32(b"a"), crc32(b"b"));
    }

    #[test]
    fn primitives_round_trip_bitwise() {
        let mut buf = Vec::new();
        put_u8(&mut buf, 7);
        put_u32(&mut buf, 0xDEAD_BEEF);
        put_u64(&mut buf, u64::MAX - 3);
        put_f64(&mut buf, -0.0);
        put_f64(&mut buf, f64::from_bits(0x7FF8_0000_0000_1234)); // NaN payload
        put_f64_slice(&mut buf, &[1.5, f64::MIN_POSITIVE, -3.25]);
        put_u32_slice(&mut buf, &[0, 1, u32::MAX]);
        put_usize_slice(&mut buf, &[42, 0]);
        put_str(&mut buf, "modèle");
        put_opt_f64(&mut buf, None);
        put_opt_f64(&mut buf, Some(2.5));

        let mut c = Cursor::new(&buf);
        assert_eq!(c.take_u8().unwrap(), 7);
        assert_eq!(c.take_u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(c.take_u64().unwrap(), u64::MAX - 3);
        assert_eq!(c.take_f64().unwrap().to_bits(), (-0.0f64).to_bits());
        assert_eq!(c.take_f64().unwrap().to_bits(), 0x7FF8_0000_0000_1234);
        let v = c.take_f64_vec().unwrap();
        assert_eq!(v, vec![1.5, f64::MIN_POSITIVE, -3.25]);
        assert_eq!(c.take_u32_vec().unwrap(), vec![0, 1, u32::MAX]);
        assert_eq!(c.take_usize_vec().unwrap(), vec![42, 0]);
        assert_eq!(c.take_str().unwrap(), "modèle");
        assert_eq!(c.take_opt_f64().unwrap(), None);
        assert_eq!(c.take_opt_f64().unwrap(), Some(2.5));
        assert_eq!(c.remaining(), 0);
    }

    #[test]
    fn operand_round_trip_preserves_kind_and_bits() {
        let dense = Operand::Dense(Matrix::from_vec(
            2,
            3,
            vec![1.0, -0.0, 2.5, f64::MAX, 1e-300, -7.25],
        ));
        let sparse = Operand::Sparse(CsrMatrix::from_triplets(
            3,
            4,
            &[(0, 1, 1.5), (0, 3, -2.0), (2, 0, 0.125)],
        ));
        for op in [&dense, &sparse] {
            let mut buf = Vec::new();
            put_operand(&mut buf, op);
            let back = Cursor::new(&buf).take_operand().unwrap();
            assert_eq!(back.rows(), op.rows());
            assert_eq!(back.cols(), op.cols());
            match (op, &back) {
                (Operand::Dense(a), Operand::Dense(b)) => {
                    let bits = |m: &Matrix| {
                        m.as_slice().iter().map(|v| v.to_bits()).collect::<Vec<_>>()
                    };
                    assert_eq!(bits(a), bits(b));
                }
                (Operand::Sparse(a), Operand::Sparse(b)) => {
                    for i in 0..a.rows() {
                        let (ca, va) = a.row(i);
                        let (cb, vb) = b.row(i);
                        assert_eq!(ca, cb);
                        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                        assert_eq!(bits(va), bits(vb));
                    }
                }
                _ => panic!("storage kind changed across round trip"),
            }
        }
    }

    #[test]
    fn truncated_reads_error_cleanly_at_every_offset() {
        let mut buf = Vec::new();
        put_str(&mut buf, "name");
        put_f64_slice(&mut buf, &[1.0, 2.0]);
        put_operand(&mut buf, &Operand::Dense(Matrix::from_vec(1, 2, vec![3.0, 4.0])));
        for cut in 0..buf.len() {
            let mut c = Cursor::new(&buf[..cut]);
            // Some prefixes decode partially; none may panic and the full
            // sequence must fail before completing.
            let r = c
                .take_str()
                .and_then(|_| c.take_f64_vec())
                .and_then(|_| c.take_operand());
            assert!(r.is_err(), "cut at {cut} still decoded fully");
        }
    }

    #[test]
    fn implausible_lengths_are_rejected_without_allocating() {
        let mut buf = Vec::new();
        put_u64(&mut buf, u64::MAX); // absurd length prefix
        assert!(Cursor::new(&buf).take_f64_vec().is_err());
        assert!(Cursor::new(&buf).take_usize().is_err());
    }

    #[test]
    fn csr_decode_rejects_out_of_range_columns() {
        let mut buf = Vec::new();
        put_u8(&mut buf, 1); // sparse tag
        put_usize(&mut buf, 1); // rows
        put_usize(&mut buf, 2); // cols
        put_usize(&mut buf, 1); // nnz in row 0
        put_u32(&mut buf, 9); // column out of range
        put_f64(&mut buf, 1.0);
        assert!(Cursor::new(&buf).take_operand().is_err());
    }
}
