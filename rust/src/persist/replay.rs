//! Session reconstruction: snapshot + WAL tail → a live [`ModelSession`].
//!
//! Recovery composes the two persistence artifacts in commit order:
//! first the snapshot is decoded and the sketch is **re-derived** from
//! its replay header against the recovered operand
//! ([`SketchEngine::from_replay`]) — bitwise-identical to the panel the
//! exporting server held — then every intact WAL record is re-applied
//! through the ordinary [`ModelSession::append`] path with its original
//! eager/lazy flag, so the recovered session consumes RNG draws in
//! exactly the sequence the dead server did. When the only mutations
//! after the last snapshot were appends (the WAL-covered case), the
//! recovered session answers **bitwise-identically** to a never-killed
//! twin; after un-snapshotted *solves* (a dirty model) recovery is still
//! correct and lossless — the operand, observations and `A^T b` replay
//! exactly — but the solver state legitimately differs until the next
//! snapshot.
//!
//! **Recovery vs. lock-free publication.** Replay interacts with the
//! serving layer's RCU snapshots (`SessionSnapshot` /
//! `ModelEntry::publish`) only at one point: `Registry::recover`
//! constructs each `ModelEntry` — and therefore publishes its *first*
//! read snapshot — strictly **after** [`rebuild_session`] and
//! [`apply_wal`] have both returned `Ok`. A recovery that fails anywhere
//! in rebuild or WAL replay produces no entry and hence no snapshot;
//! readers can never observe a half-replayed model. The WAL-before-apply
//! invariant composes the same way it did pre-snapshots: appends are
//! logged before the session mutates, the session mutates before
//! `publish` is called, and `publish` swaps one complete, immutable
//! snapshot — so every snapshot any reader ever holds corresponds to a
//! prefix of the durable history. Session `generation` numbers are
//! per-process bookkeeping and intentionally **not** persisted: a
//! recovered session restarts at generation 0 with an empty solution
//! cache, and its first published snapshot simply misses on
//! `cached(..)`, routing readers to the (bitwise-replayed) solve path.

use super::snapshot::ModelSnapshot;
use super::wal;
use crate::linalg::Operand;
use crate::rng::Xoshiro256;
use crate::sketch::engine::SketchEngine;
use crate::solvers::adaptive::AdaptiveSessionState;
use crate::solvers::session::{AppendRefresh, ModelSession};
use crate::util::failpoint;
use std::sync::Arc;

/// Rebuild a session from a decoded snapshot: re-derive the sketch
/// panel from the replay header, restore the factorization at the
/// persisted `nu`, and reattach the RNG mid-stream. The
/// `persist.recover` failpoint fires before any reconstruction work.
pub fn rebuild_session(snap: ModelSnapshot) -> Result<ModelSession, String> {
    failpoint::check("persist.recover")?;
    snap.verify_atb_digest()?;
    let a = Arc::new(snap.a);
    let state = match snap.state {
        None => None,
        Some(st) => {
            let engine = match st.engine {
                None => None,
                Some(replay) => {
                    let aref: &Operand = &a;
                    Some(
                        SketchEngine::from_replay(replay, aref.as_ref())
                            .map_err(|e| format!("sketch replay failed: {e}"))?,
                    )
                }
            };
            let rng = Xoshiro256::from_state(st.rng_state.0, st.rng_state.1);
            Some(
                AdaptiveSessionState::restore(engine, st.cache_nu, rng, &a)
                    .map_err(|e| format!("factorization restore failed: {e}"))?,
            )
        }
    };
    ModelSession::restore(
        a,
        snap.b,
        snap.atb,
        snap.kind,
        snap.seed,
        state,
        snap.warm,
        snap.queries,
        snap.epoch,
    )
}

/// Re-apply intact WAL payloads (in log order) to a rebuilt session
/// through the ordinary append path, preserving each record's original
/// eager/lazy refresh flag. Returns the number of records applied.
pub fn apply_wal(session: &mut ModelSession, records: &[Vec<u8>]) -> Result<usize, String> {
    for (i, payload) in records.iter().enumerate() {
        let rec = wal::decode_append(payload)
            .map_err(|e| format!("WAL record {i} undecodable: {e}"))?;
        let refresh = if rec.eager { AppendRefresh::Eager } else { AppendRefresh::Lazy };
        session
            .append(rec.a, rec.b, refresh)
            .map_err(|e| format!("WAL record {i} failed to apply: {e}"))?;
    }
    Ok(records.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::linalg::Matrix;
    use crate::persist::snapshot::{decode, encode_session};
    use crate::sketch::SketchKind;

    fn bits(v: &[f64]) -> Vec<u64> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    /// Full (n, d) dataset split into a base session's rows plus two
    /// append deltas of `dn` rows each.
    #[allow(clippy::type_complexity)]
    fn staged(
        n: usize,
        d: usize,
        dn: usize,
        seed: u64,
    ) -> (Matrix, Vec<f64>, Vec<(Matrix, Vec<f64>)>) {
        let ds = synthetic::exponential_decay(n, d, seed);
        let full = ds.a.dense().into_owned();
        let base_rows = n - 2 * dn;
        let base = Matrix::from_fn(base_rows, d, |i, j| full.get(i, j));
        let mut deltas = Vec::new();
        for k in 0..2 {
            let r0 = base_rows + k * dn;
            let delta = Matrix::from_fn(dn, d, |i, j| full.get(r0 + i, j));
            deltas.push((delta, ds.b[r0..r0 + dn].to_vec()));
        }
        (base, ds.b[..base_rows].to_vec(), deltas)
    }

    #[test]
    fn rebuilt_sessions_answer_bitwise_for_all_families() {
        for kind in [SketchKind::Gaussian, SketchKind::Srht, SketchKind::Sparse] {
            let ds = synthetic::exponential_decay(96, 12, 80);
            let mut live =
                ModelSession::new(Arc::new(ds.a), ds.b, kind, 9).unwrap();
            live.solve(0.5, 1e-8).unwrap();
            let snap = decode(&encode_session("m", &mut live).unwrap()).unwrap();
            let mut rebuilt = rebuild_session(snap).unwrap();
            assert_eq!(rebuilt.m(), live.m(), "{kind:?}: replayed sketch size differs");
            // Fresh (uncached in both) queries must agree to the bit.
            let a = live.solve(0.3, 1e-9).unwrap();
            let b = rebuilt.solve(0.3, 1e-9).unwrap();
            assert_eq!(bits(&a.x), bits(&b.x), "{kind:?}");
        }
    }

    #[test]
    fn snapshot_plus_wal_replay_matches_never_killed_twin_bitwise() {
        for kind in [SketchKind::Gaussian, SketchKind::Srht, SketchKind::Sparse] {
            let (base, b_base, deltas) = staged(120, 10, 5, 81);
            let mut live = ModelSession::new(
                Arc::new(Operand::from(base)),
                b_base,
                kind,
                11,
            )
            .unwrap();
            live.solve(0.6, 1e-8).unwrap();
            // Snapshot, then stream two appends that only the WAL covers
            // (one lazy, one eager — the flag must replay too).
            let snapshot_bytes = encode_session("twin", &mut live).unwrap();
            let mut wal_payloads = Vec::new();
            for (k, (delta, db)) in deltas.iter().enumerate() {
                let eager = k == 1;
                wal_payloads.push(wal::encode_append(
                    &Operand::from(delta.clone()),
                    db,
                    eager,
                ));
                let refresh =
                    if eager { AppendRefresh::Eager } else { AppendRefresh::Lazy };
                live.append(Operand::from(delta.clone()), db.clone(), refresh).unwrap();
            }
            // "Crash": rebuild purely from the persisted artifacts.
            let mut recovered = rebuild_session(decode(&snapshot_bytes).unwrap()).unwrap();
            let applied = apply_wal(&mut recovered, &wal_payloads).unwrap();
            assert_eq!(applied, 2);
            assert_eq!(recovered.n(), live.n());
            assert_eq!(bits(recovered.atb()), bits(live.atb()), "{kind:?}: atb diverged");
            // The never-killed twin and the recovered server must answer a
            // fresh query identically to the bit.
            let lx = live.solve(0.45, 1e-9).unwrap();
            let rx = recovered.solve(0.45, 1e-9).unwrap();
            assert_eq!(bits(&lx.x), bits(&rx.x), "{kind:?}");
        }
    }

    #[test]
    fn unsolved_snapshot_round_trips_and_first_solve_matches() {
        let ds = synthetic::exponential_decay(64, 8, 82);
        let mut live =
            ModelSession::new(Arc::new(ds.a), ds.b, SketchKind::Gaussian, 13).unwrap();
        let snap = decode(&encode_session("cold", &mut live).unwrap()).unwrap();
        let mut rebuilt = rebuild_session(snap).unwrap();
        let a = live.solve(0.8, 1e-8).unwrap();
        let b = rebuilt.solve(0.8, 1e-8).unwrap();
        assert_eq!(bits(&a.x), bits(&b.x));
    }

    #[test]
    fn recovered_sessions_publish_complete_snapshots() {
        // A recovered session starts with an empty solution cache: its
        // first snapshot must miss on cached() (routing readers to the
        // replayed solve path), and after one solve its snapshot must
        // serve that answer bitwise-identically to a never-killed twin.
        let ds = synthetic::exponential_decay(96, 12, 84);
        let mut live =
            ModelSession::new(Arc::new(ds.a), ds.b, SketchKind::Gaussian, 17).unwrap();
        live.solve(0.5, 1e-8).unwrap();
        let snap_bytes = encode_session("pub", &mut live).unwrap();
        let mut recovered = rebuild_session(decode(&snap_bytes).unwrap()).unwrap();
        assert_eq!(recovered.generation(), 0, "generation must not persist");
        let first = recovered.snapshot();
        assert_eq!(first.generation(), 1);
        assert!(first.solution_keys().is_empty(), "recovered cache must start empty");
        assert!(first.cached(0.5, 1e-8).is_none());
        // One solve each; the snapshot then serves the recovered answer.
        let lx = live.solve(0.35, 1e-9).unwrap();
        let rx = recovered.solve(0.35, 1e-9).unwrap();
        assert_eq!(bits(&lx.x), bits(&rx.x));
        let second = recovered.snapshot();
        assert!(second.generation() > first.generation(), "generations are monotone");
        let hit = second.cached(0.35, 1e-9).expect("solved nu must be cached");
        assert_eq!(bits(&hit.x), bits(&lx.x), "snapshot answer diverged from twin");
        // The first (pinned) snapshot still answers what *it* implies:
        // nothing — old handles never grow new solutions.
        assert!(first.cached(0.35, 1e-9).is_none());
    }

    #[test]
    fn bad_wal_records_are_structured_errors() {
        let ds = synthetic::exponential_decay(64, 8, 83);
        let mut s =
            ModelSession::new(Arc::new(ds.a), ds.b, SketchKind::Gaussian, 13).unwrap();
        let err = apply_wal(&mut s, &[vec![0xFF, 0x00]]).unwrap_err();
        assert!(err.contains("record 0"), "{err}");
        // A wrong-width append fails to apply but never panics.
        let bad = wal::encode_append(
            &Operand::from(Matrix::zeros(1, 3)),
            &[1.0],
            false,
        );
        let err = apply_wal(&mut s, &[bad]).unwrap_err();
        assert!(err.contains("failed to apply"), "{err}");
    }
}
