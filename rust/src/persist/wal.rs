//! Per-model append write-ahead log.
//!
//! Every wire `append` is logged as one framed record **before** it is
//! applied to the in-RAM session, so streamed rows survive a crash that
//! happens after the client's ack. The frame is
//!
//! ```text
//! [magic u32][payload_len u32][crc32(payload) u32][payload ...]
//! ```
//!
//! all little-endian. Recovery scans the file front to back and stops at
//! the first frame that is short, mis-tagged, or fails its CRC — the
//! *torn-tail rule*: everything before the bad frame is intact (each
//! record's CRC proved it), everything from it on is discarded by
//! truncating the file, with a logged warning and never a panic. A crash
//! half-way through a frame write therefore loses at most the one record
//! that was never acked durable.
//!
//! The fsync policy trades durability for append latency (the
//! `--durability` serve flag): [`DurabilityPolicy::Strict`] fsyncs every
//! record, `Batch` defers the fsync to the next snapshot/shutdown
//! [`Wal::sync`], `Off` never fsyncs (the OS page cache is the only
//! durability). The *format* is identical in all three — only the crash
//! window differs.

use super::codec::{self, Cursor};
use super::DurabilityPolicy;
use crate::linalg::Operand;
use crate::util::failpoint;
use std::fs::{File, OpenOptions};
use std::io::{self, Seek, SeekFrom, Write};
use std::path::Path;

/// Frame magic: `"WALR"` little-endian.
pub const RECORD_MAGIC: u32 = 0x524C_4157;
/// Frame header bytes preceding each payload.
pub const HEADER_BYTES: u64 = 12;

/// Payload of one logged wire `append`.
pub struct AppendRecord {
    /// The appended rows, in the storage kind the client sent (the
    /// session normalizes on apply, so replay converges regardless).
    pub a: Operand,
    /// The appended observations.
    pub b: Vec<f64>,
    /// Whether the client asked for an eager refresh.
    pub eager: bool,
}

/// Record-type tag for [`AppendRecord`] payloads (room for future kinds).
const TAG_APPEND: u8 = 1;

/// Encode an append into a WAL payload.
pub fn encode_append(a: &Operand, b: &[f64], eager: bool) -> Vec<u8> {
    let mut out = Vec::new();
    codec::put_u8(&mut out, TAG_APPEND);
    codec::put_u8(&mut out, u8::from(eager));
    codec::put_operand(&mut out, a);
    codec::put_f64_slice(&mut out, b);
    out
}

/// Decode a WAL payload back into an append.
pub fn decode_append(payload: &[u8]) -> Result<AppendRecord, String> {
    let mut c = Cursor::new(payload);
    let tag = c.take_u8()?;
    if tag != TAG_APPEND {
        return Err(format!("unknown WAL record tag {tag}"));
    }
    let eager = match c.take_u8()? {
        0 => false,
        1 => true,
        v => return Err(format!("bad eager flag {v}")),
    };
    let a = c.take_operand()?;
    let b = c.take_f64_vec()?;
    if c.remaining() != 0 {
        return Err(format!("{} trailing bytes after WAL record", c.remaining()));
    }
    Ok(AppendRecord { a, b, eager })
}

/// What a front-to-back scan of a WAL file found.
pub struct WalScan {
    /// Payloads of every intact record, in log order.
    pub records: Vec<Vec<u8>>,
    /// Byte length of the intact prefix (where an appender must resume).
    pub valid_len: u64,
    /// Whether a torn or corrupt tail was found past `valid_len`.
    pub truncated_tail: bool,
}

/// Scan `path` and return every intact record plus the valid prefix
/// length. A missing file is an empty log; a torn or corrupt tail is
/// reported, not an error — the caller truncates and carries on.
pub fn scan(path: &Path) -> io::Result<WalScan> {
    let data = match std::fs::read(path) {
        Ok(d) => d,
        Err(e) if e.kind() == io::ErrorKind::NotFound => {
            return Ok(WalScan { records: Vec::new(), valid_len: 0, truncated_tail: false })
        }
        Err(e) => return Err(e),
    };
    let mut records = Vec::new();
    let mut pos = 0usize;
    loop {
        let rest = data.len() - pos;
        if rest == 0 {
            break;
        }
        if rest < HEADER_BYTES as usize {
            break; // torn header
        }
        let hdr = &data[pos..pos + HEADER_BYTES as usize];
        let magic = u32::from_le_bytes([hdr[0], hdr[1], hdr[2], hdr[3]]);
        let len = u32::from_le_bytes([hdr[4], hdr[5], hdr[6], hdr[7]]) as usize;
        let crc = u32::from_le_bytes([hdr[8], hdr[9], hdr[10], hdr[11]]);
        if magic != RECORD_MAGIC || rest - HEADER_BYTES as usize < len {
            break; // mis-tagged frame or torn payload
        }
        let payload = &data[pos + HEADER_BYTES as usize..pos + HEADER_BYTES as usize + len];
        if codec::crc32(payload) != crc {
            break; // bit-flipped payload
        }
        records.push(payload.to_vec());
        pos += HEADER_BYTES as usize + len;
    }
    Ok(WalScan {
        records,
        valid_len: pos as u64,
        truncated_tail: pos < data.len(),
    })
}

/// An open, appendable WAL file.
pub struct Wal {
    file: File,
    len: u64,
    policy: DurabilityPolicy,
}

impl Wal {
    /// Open (creating if absent) the log at `path`, truncate it to the
    /// scanned `valid_len` — dropping any torn tail — and position for
    /// appends.
    pub fn open(path: &Path, policy: DurabilityPolicy, valid_len: u64) -> io::Result<Self> {
        let file = OpenOptions::new().create(true).read(true).write(true).open(path)?;
        file.set_len(valid_len)?;
        let mut wal = Self { file, len: valid_len, policy };
        wal.file.seek(SeekFrom::Start(valid_len))?;
        Ok(wal)
    }

    /// Bytes of intact log (the offset the next record lands at).
    pub fn len(&self) -> u64 {
        self.len
    }

    /// Whether the log holds no records.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Append one framed record and apply the fsync policy. Returns the
    /// byte offset *before* the record — the rollback point if applying
    /// the logged operation to the session subsequently fails. The
    /// `persist.wal_append` failpoint fires before any byte is written.
    pub fn append(&mut self, payload: &[u8]) -> Result<u64, String> {
        failpoint::check("persist.wal_append")?;
        let before = self.len;
        let mut frame = Vec::with_capacity(HEADER_BYTES as usize + payload.len());
        codec::put_u32(&mut frame, RECORD_MAGIC);
        codec::put_u32(&mut frame, payload.len() as u32);
        codec::put_u32(&mut frame, codec::crc32(payload));
        frame.extend_from_slice(payload);
        self.file
            .write_all(&frame)
            .map_err(|e| format!("WAL write failed: {e}"))?;
        self.len += frame.len() as u64;
        if self.policy == DurabilityPolicy::Strict {
            self.file
                .sync_data()
                .map_err(|e| format!("WAL fsync failed: {e}"))?;
        }
        Ok(before)
    }

    /// Roll the log back to `len` bytes — used when the session rejected
    /// the operation a record describes (the record must not replay on
    /// recovery) and after a snapshot absorbs the log (`len = 0`).
    pub fn truncate_to(&mut self, len: u64) -> Result<(), String> {
        self.file
            .set_len(len)
            .and_then(|()| self.file.seek(SeekFrom::Start(len)).map(|_| ()))
            .map_err(|e| format!("WAL truncate failed: {e}"))?;
        self.len = len;
        if self.policy == DurabilityPolicy::Strict {
            self.file
                .sync_data()
                .map_err(|e| format!("WAL fsync failed: {e}"))?;
        }
        Ok(())
    }

    /// Force written records to stable storage (no-op under
    /// [`DurabilityPolicy::Off`]; the batch policy calls this at
    /// snapshot/shutdown barriers).
    pub fn sync(&mut self) -> Result<(), String> {
        if self.policy == DurabilityPolicy::Off {
            return Ok(());
        }
        self.file.sync_data().map_err(|e| format!("WAL fsync failed: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::sparse::CsrMatrix;
    use crate::linalg::Matrix;
    use std::path::PathBuf;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn tmp(tag: &str) -> PathBuf {
        static SEQ: AtomicUsize = AtomicUsize::new(0);
        let dir = std::env::temp_dir().join(format!(
            "effdim-wal-{}-{}-{}",
            std::process::id(),
            tag,
            SEQ.fetch_add(1, Ordering::SeqCst)
        ));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn append_and_scan_round_trip() {
        let dir = tmp("roundtrip");
        let path = dir.join("wal.log");
        for policy in [DurabilityPolicy::Strict, DurabilityPolicy::Batch, DurabilityPolicy::Off] {
            let _ = std::fs::remove_file(&path);
            let mut wal = Wal::open(&path, policy, 0).unwrap();
            let payloads: Vec<Vec<u8>> =
                (0u8..5).map(|i| vec![i; 3 + i as usize * 7]).collect();
            for p in &payloads {
                wal.append(p).unwrap();
            }
            wal.sync().unwrap();
            let scan = scan(&path).unwrap();
            assert_eq!(scan.records, payloads);
            assert!(!scan.truncated_tail);
            assert_eq!(scan.valid_len, wal.len());
        }
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn missing_file_scans_as_empty_log() {
        let dir = tmp("missing");
        let scan = scan(&dir.join("nope.log")).unwrap();
        assert!(scan.records.is_empty());
        assert_eq!(scan.valid_len, 0);
        assert!(!scan.truncated_tail);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn truncation_at_every_byte_offset_keeps_the_intact_prefix() {
        let dir = tmp("tear");
        let path = dir.join("wal.log");
        let mut wal = Wal::open(&path, DurabilityPolicy::Off, 0).unwrap();
        let payloads: Vec<Vec<u8>> = vec![vec![1; 9], vec![2; 17], vec![3; 4]];
        let mut offsets = vec![0u64];
        for p in &payloads {
            wal.append(p).unwrap();
            offsets.push(wal.len());
        }
        let full = std::fs::read(&path).unwrap();
        let torn = dir.join("torn.log");
        for cut in 0..=full.len() {
            std::fs::write(&torn, &full[..cut]).unwrap();
            let s = scan(&torn).unwrap();
            // Expected record count: whole frames that fit in `cut` bytes.
            let k = offsets.iter().filter(|&&o| o > 0 && o <= cut as u64).count();
            assert_eq!(s.records.len(), k, "cut at {cut}");
            assert_eq!(s.records, payloads[..k].to_vec(), "cut at {cut}");
            assert_eq!(s.valid_len, offsets[k], "cut at {cut}");
            assert_eq!(s.truncated_tail, (cut as u64) > offsets[k], "cut at {cut}");
        }
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn bit_flip_stops_scan_at_last_good_record() {
        let dir = tmp("flip");
        let path = dir.join("wal.log");
        let mut wal = Wal::open(&path, DurabilityPolicy::Off, 0).unwrap();
        let first_end = {
            wal.append(&[10; 20]).unwrap();
            wal.len()
        };
        wal.append(&[20; 20]).unwrap();
        drop(wal);
        let mut data = std::fs::read(&path).unwrap();
        // Flip one payload bit in the SECOND record.
        let idx = first_end as usize + HEADER_BYTES as usize + 5;
        data[idx] ^= 0x40;
        std::fs::write(&path, &data).unwrap();
        let s = scan(&path).unwrap();
        assert_eq!(s.records.len(), 1, "scan must stop before the corrupt record");
        assert_eq!(s.records[0], vec![10; 20]);
        assert_eq!(s.valid_len, first_end);
        assert!(s.truncated_tail);
        // Re-opening at the valid length drops the corrupt tail for good.
        let wal = Wal::open(&path, DurabilityPolicy::Off, s.valid_len).unwrap();
        assert_eq!(wal.len(), first_end);
        assert_eq!(std::fs::metadata(&path).unwrap().len(), first_end);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn rollback_removes_the_unapplied_record() {
        let dir = tmp("rollback");
        let path = dir.join("wal.log");
        let mut wal = Wal::open(&path, DurabilityPolicy::Strict, 0).unwrap();
        wal.append(b"keep").unwrap();
        let before = wal.append(b"reject-me").unwrap();
        wal.truncate_to(before).unwrap();
        let s = scan(&path).unwrap();
        assert_eq!(s.records, vec![b"keep".to_vec()]);
        assert!(!s.truncated_tail);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn append_record_codec_round_trips_both_kinds() {
        let dense = Operand::Dense(Matrix::from_vec(2, 2, vec![1.0, -0.0, 3.5, 4.25]));
        let sparse = Operand::Sparse(CsrMatrix::from_triplets(
            2,
            3,
            &[(0, 2, -1.5), (1, 0, 2.0)],
        ));
        for (op, eager) in [(&dense, true), (&sparse, false)] {
            let b = vec![0.5, -2.0];
            let payload = encode_append(op, &b, eager);
            let rec = decode_append(&payload).unwrap();
            assert_eq!(rec.eager, eager);
            assert_eq!(rec.b, b);
            assert_eq!(rec.a.rows(), op.rows());
            assert_eq!(rec.a.cols(), op.cols());
            assert_eq!(
                matches!(rec.a, Operand::Dense(_)),
                matches!(op, Operand::Dense(_))
            );
        }
        // Trailing garbage and unknown tags are rejected.
        let mut payload = encode_append(&dense, &[1.0, 2.0], false);
        payload.push(0);
        assert!(decode_append(&payload).is_err());
        assert!(decode_append(&[99, 0]).is_err());
    }

    #[test]
    fn wal_append_failpoint_fires_before_writing() {
        let _serial = crate::persist::tests_serial();
        let dir = tmp("failpoint");
        let path = dir.join("wal.log");
        let mut wal = Wal::open(&path, DurabilityPolicy::Strict, 0).unwrap();
        failpoint::arm("persist.wal_append", failpoint::Action::Error, 1);
        let err = wal.append(b"never-lands").unwrap_err();
        assert!(err.contains("persist.wal_append"), "{err}");
        assert_eq!(wal.len(), 0);
        assert_eq!(std::fs::metadata(&path).unwrap().len(), 0);
        failpoint::disarm_all();
        let _ = std::fs::remove_dir_all(dir);
    }
}
