//! Durable model state: checksummed snapshots + per-model append WAL.
//!
//! The serving stack keeps every model in RAM; this module makes that
//! state survive a crash. Two artifacts per model, under
//! `<state-dir>/<model-id>/`:
//!
//! * **`snapshot.snap`** — a checksummed point-in-time image of the
//!   session ([`snapshot`]): operand, observations, `A^T b` with its own
//!   digest, the sketch-engine *replay header* (seeds and per-block RNG
//!   states, **not** the `S̃A` panel), warm start, and solution-cache
//!   keys. Written via write-temp + fsync + atomic-rename, so a crash
//!   mid-snapshot leaves the previous snapshot intact.
//! * **`wal.log`** — an append-only log of every wire `append` since the
//!   last snapshot ([`wal`]): length-prefixed, CRC-checksummed records,
//!   fsynced per [`DurabilityPolicy`].
//!
//! Recovery ([`replay`]) loads the snapshot, re-derives the sketch panel
//! from the replay header, and re-applies the intact WAL tail through
//! the ordinary append path — bitwise-identical answers when all
//! post-snapshot mutations were WAL-covered appends. A torn or corrupt
//! WAL tail is truncated with a logged warning, never a panic.
//!
//! The [`Store`] below owns the directory layout and the open WAL
//! handles, and is what the coordinator's registry talks to.

pub mod codec;
pub mod replay;
pub mod snapshot;
pub mod wal;

use crate::solvers::session::ModelSession;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// When WAL records (and snapshot resets) are forced to stable storage.
/// The on-disk *format* is identical across policies — only the crash
/// window differs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DurabilityPolicy {
    /// fsync every WAL record before acking the append (largest safety,
    /// highest append latency). The default.
    Strict,
    /// Defer fsyncs to snapshot/shutdown barriers ([`Store::sync_all`]);
    /// a crash between barriers can lose acked-but-unsynced appends.
    Batch,
    /// Never fsync; the OS page cache is the only durability. For tests
    /// and throwaway servers.
    Off,
}

impl Default for DurabilityPolicy {
    fn default() -> Self {
        Self::Strict
    }
}

impl std::fmt::Display for DurabilityPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Self::Strict => "strict",
            Self::Batch => "batch",
            Self::Off => "off",
        })
    }
}

impl std::str::FromStr for DurabilityPolicy {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "strict" => Ok(Self::Strict),
            "batch" => Ok(Self::Batch),
            "off" => Ok(Self::Off),
            other => Err(format!(
                "unknown durability policy {other:?} (expected strict, batch, or off)"
            )),
        }
    }
}

/// Per-model persistence bookkeeping the store keeps in RAM.
struct ModelMeta {
    /// The open append log (positioned at its intact length).
    wal: wal::Wal,
    /// Session epoch captured by the last snapshot (solver runs bump the
    /// live epoch; live > persisted means the model is *dirty* — its
    /// solver state would not recover bitwise until the next snapshot).
    persisted_epoch: u64,
    /// When the last snapshot was written (or the model recovered).
    last_snapshot: Instant,
}

/// A model recovered from disk at startup.
pub struct RecoveredModel {
    /// The directory's model id (ids stay stable across restarts).
    pub id: u64,
    /// The display name persisted in the snapshot.
    pub name: String,
    /// The rebuilt session, WAL tail already re-applied.
    pub session: ModelSession,
}

/// The durable side of the model registry: owns the state directory, one
/// open WAL per model, and the persistence counters surfaced by
/// `metrics`. Thread-safe behind `&self` (one mutex over the per-model
/// map; snapshot/WAL I/O for *different* models still serializes here,
/// which is fine — appends are far cheaper than the solves they ride
/// with).
pub struct Store {
    root: PathBuf,
    policy: DurabilityPolicy,
    models: Mutex<HashMap<u64, ModelMeta>>,
    /// Snapshots written over the store's lifetime.
    pub snapshots_written: AtomicU64,
    /// WAL records appended over the store's lifetime.
    pub wal_records: AtomicU64,
    /// Torn/corrupt WAL tails truncated during recovery.
    pub truncated_tails: AtomicU64,
    /// Models successfully recovered at startup.
    pub recovered_models: AtomicU64,
    /// Models whose on-disk state was dropped (purged) on evict.
    pub purged: AtomicU64,
    /// Models spilled to disk (evicted from RAM, state kept on disk).
    pub spills: AtomicU64,
    /// Spilled models reloaded on demand.
    pub reloads: AtomicU64,
}

impl Store {
    /// Open (creating if absent) a state directory.
    pub fn open(root: &Path, policy: DurabilityPolicy) -> Result<Self, String> {
        std::fs::create_dir_all(root)
            .map_err(|e| format!("cannot create state dir {}: {e}", root.display()))?;
        Ok(Self {
            root: root.to_path_buf(),
            policy,
            models: Mutex::new(HashMap::new()),
            snapshots_written: AtomicU64::new(0),
            wal_records: AtomicU64::new(0),
            truncated_tails: AtomicU64::new(0),
            recovered_models: AtomicU64::new(0),
            purged: AtomicU64::new(0),
            spills: AtomicU64::new(0),
            reloads: AtomicU64::new(0),
        })
    }

    /// The configured fsync policy.
    pub fn policy(&self) -> DurabilityPolicy {
        self.policy
    }

    fn model_dir(&self, id: u64) -> PathBuf {
        self.root.join(id.to_string())
    }

    fn snapshot_path(&self, id: u64) -> PathBuf {
        self.model_dir(id).join("snapshot.snap")
    }

    fn wal_path(&self, id: u64) -> PathBuf {
        self.model_dir(id).join("wal.log")
    }

    /// Recover every model the state directory holds: decode each
    /// snapshot, re-derive its sketch, re-apply the intact WAL tail
    /// (truncating torn/corrupt tails with a logged warning), and leave
    /// the WAL open for further appends. A model whose artifacts are
    /// damaged beyond its WAL tail is **skipped with a warning**, never a
    /// panic — one bad model must not take down the whole server.
    /// Returns the survivors sorted by id.
    pub fn recover_all(&self) -> Result<Vec<RecoveredModel>, String> {
        let mut ids = Vec::new();
        let entries = std::fs::read_dir(&self.root)
            .map_err(|e| format!("cannot read state dir {}: {e}", self.root.display()))?;
        for entry in entries.flatten() {
            let name = entry.file_name();
            if let Some(id) = name.to_str().and_then(|s| s.parse::<u64>().ok()) {
                if entry.path().is_dir() {
                    ids.push(id);
                }
            }
        }
        ids.sort_unstable();
        let mut out = Vec::new();
        for id in ids {
            match self.recover_one(id) {
                Ok(model) => {
                    self.recovered_models.fetch_add(1, Ordering::Relaxed);
                    out.push(model);
                }
                Err(e) => {
                    eprintln!("warning: skipping model {id} during recovery: {e}");
                }
            }
        }
        Ok(out)
    }

    /// Recover one model directory and register its meta (open WAL,
    /// persisted epoch) with the store.
    fn recover_one(&self, id: u64) -> Result<RecoveredModel, String> {
        let snap = snapshot::load(&self.snapshot_path(id))?;
        let name = snap.name.clone();
        let persisted_epoch = snap.epoch;
        let mut session = replay::rebuild_session(snap)?;
        let wal_path = self.wal_path(id);
        let scan = wal::scan(&wal_path).map_err(|e| format!("WAL scan failed: {e}"))?;
        if scan.truncated_tail {
            self.truncated_tails.fetch_add(1, Ordering::Relaxed);
            eprintln!(
                "warning: model {id}: torn or corrupt WAL tail past byte {} — truncating \
                 ({} intact records kept)",
                scan.valid_len,
                scan.records.len()
            );
        }
        replay::apply_wal(&mut session, &scan.records)?;
        let wal = wal::Wal::open(&wal_path, self.policy, scan.valid_len)
            .map_err(|e| format!("cannot reopen WAL: {e}"))?;
        self.models.lock().unwrap().insert(
            id,
            ModelMeta { wal, persisted_epoch, last_snapshot: Instant::now() },
        );
        Ok(RecoveredModel { id, name, session })
    }

    /// Write a fresh snapshot of `session` (flushing any pending lazy
    /// append first) and reset the model's WAL — the snapshot absorbs
    /// everything the log covered. Creates the model's directory and WAL
    /// on first call (i.e. at `register`).
    pub fn persist_model(
        &self,
        id: u64,
        name: &str,
        session: &mut ModelSession,
    ) -> Result<(), String> {
        let bytes = snapshot::encode_session(name, session)?;
        let dir = self.model_dir(id);
        std::fs::create_dir_all(&dir)
            .map_err(|e| format!("cannot create model dir {}: {e}", dir.display()))?;
        snapshot::write_atomic(&self.snapshot_path(id), &bytes)?;
        let mut models = self.models.lock().unwrap();
        let meta = match models.entry(id) {
            std::collections::hash_map::Entry::Occupied(o) => o.into_mut(),
            std::collections::hash_map::Entry::Vacant(v) => {
                let wal = wal::Wal::open(&self.wal_path(id), self.policy, 0)
                    .map_err(|e| format!("cannot open WAL: {e}"))?;
                v.insert(ModelMeta { wal, persisted_epoch: 0, last_snapshot: Instant::now() })
            }
        };
        meta.wal.truncate_to(0)?;
        meta.persisted_epoch = session.epoch();
        meta.last_snapshot = Instant::now();
        drop(models);
        self.snapshots_written.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Log one wire `append` **before** it is applied to the session.
    /// Returns the rollback offset to hand to [`Store::rollback_append`]
    /// if the session subsequently rejects the delta.
    pub fn append_record(
        &self,
        id: u64,
        a: &crate::linalg::Operand,
        b: &[f64],
        eager: bool,
    ) -> Result<u64, String> {
        let payload = wal::encode_append(a, b, eager);
        let mut models = self.models.lock().unwrap();
        let meta = models.get_mut(&id).ok_or_else(|| format!("model {id} has no WAL"))?;
        let offset = meta.wal.append(&payload)?;
        self.wal_records.fetch_add(1, Ordering::Relaxed);
        Ok(offset)
    }

    /// Remove a logged append the session rejected — the record must not
    /// replay on recovery.
    pub fn rollback_append(&self, id: u64, offset: u64) -> Result<(), String> {
        let mut models = self.models.lock().unwrap();
        let meta = models.get_mut(&id).ok_or_else(|| format!("model {id} has no WAL"))?;
        meta.wal.truncate_to(offset)
    }

    /// Forget a model. With `purge` the on-disk state is deleted too
    /// (explicit `evict`); without it the files stay for a later
    /// [`Store::load_model`] (LRU spill).
    pub fn drop_model(&self, id: u64, purge: bool) {
        self.models.lock().unwrap().remove(&id);
        if purge {
            let _ = std::fs::remove_dir_all(self.model_dir(id));
            self.purged.fetch_add(1, Ordering::Relaxed);
        } else {
            self.spills.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Whether a spilled model's state is still on disk.
    pub fn has_spilled(&self, id: u64) -> bool {
        !self.models.lock().unwrap().contains_key(&id)
            && self.snapshot_path(id).is_file()
    }

    /// Reload a spilled model from disk (recovery path, counted as a
    /// reload). Fails if the model was purged or never persisted.
    pub fn load_model(&self, id: u64) -> Result<RecoveredModel, String> {
        if self.models.lock().unwrap().contains_key(&id) {
            return Err(format!("model {id} is already live"));
        }
        let model = self.recover_one(id)?;
        self.reloads.fetch_add(1, Ordering::Relaxed);
        Ok(model)
    }

    /// Epoch the model's last snapshot captured (`None` if the model has
    /// no persisted state). A live session whose epoch is greater is
    /// *dirty*: recovery would be lossless but not solver-state-bitwise
    /// until the next snapshot.
    pub fn persisted_epoch(&self, id: u64) -> Option<u64> {
        self.models.lock().unwrap().get(&id).map(|m| m.persisted_epoch)
    }

    /// Total bytes of WAL not yet absorbed by a snapshot, across all live
    /// models — the replay debt a crash right now would incur.
    pub fn wal_lag_bytes(&self) -> u64 {
        self.models.lock().unwrap().values().map(|m| m.wal.len()).sum()
    }

    /// Age in seconds of the *oldest* live snapshot (`None` when no model
    /// is persisted) — the staleness bound on recovery.
    pub fn last_snapshot_age_s(&self) -> Option<f64> {
        self.models
            .lock()
            .unwrap()
            .values()
            .map(|m| m.last_snapshot.elapsed().as_secs_f64())
            .fold(None, |acc, x| Some(acc.map_or(x, |a: f64| a.max(x))))
    }

    /// Force every model's WAL to stable storage — the batch policy's
    /// barrier (graceful shutdown, periodic checkpoints).
    pub fn sync_all(&self) -> Result<(), String> {
        for meta in self.models.lock().unwrap().values_mut() {
            meta.wal.sync()?;
        }
        Ok(())
    }
}

/// Serializes tests that arm process-global failpoints against tests
/// that would otherwise trip them (failpoint state is shared across the
/// whole test binary). Recovers from poisoning so one failing test does
/// not cascade.
#[cfg(test)]
pub(crate) fn tests_serial() -> std::sync::MutexGuard<'static, ()> {
    use std::sync::OnceLock;
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|p| p.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::linalg::Operand;
    use crate::sketch::SketchKind;
    use crate::solvers::session::AppendRefresh;
    use std::path::PathBuf;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Arc;

    fn tmp(tag: &str) -> PathBuf {
        static SEQ: AtomicUsize = AtomicUsize::new(0);
        let dir = std::env::temp_dir().join(format!(
            "effdim-store-{}-{}-{}",
            std::process::id(),
            tag,
            SEQ.fetch_add(1, Ordering::SeqCst)
        ));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn bits(v: &[f64]) -> Vec<u64> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    fn fresh_session(n: usize, d: usize, seed: u64) -> ModelSession {
        let ds = synthetic::exponential_decay(n, d, seed);
        ModelSession::new(Arc::new(ds.a), ds.b, SketchKind::Gaussian, 7).unwrap()
    }

    #[test]
    fn durability_policy_parses_and_displays() {
        for (s, p) in [
            ("strict", DurabilityPolicy::Strict),
            ("batch", DurabilityPolicy::Batch),
            ("off", DurabilityPolicy::Off),
        ] {
            assert_eq!(s.parse::<DurabilityPolicy>().unwrap(), p);
            assert_eq!(p.to_string(), s);
        }
        assert!("eventually".parse::<DurabilityPolicy>().is_err());
        assert_eq!(DurabilityPolicy::default(), DurabilityPolicy::Strict);
    }

    #[test]
    fn store_round_trip_snapshot_wal_recover() {
        let root = tmp("roundtrip");
        let delta = synthetic::exponential_decay(96, 12, 41);
        let (live_sol, live_atb) = {
            let store = Store::open(&root, DurabilityPolicy::Strict).unwrap();
            let mut live = fresh_session(96, 12, 40);
            live.solve(0.5, 1e-8).unwrap();
            store.persist_model(3, "demo", &mut live).unwrap();
            // One WAL-covered append after the snapshot.
            let a = Operand::from(delta.a.dense().into_owned());
            store.append_record(3, &a, &delta.b, false).unwrap();
            live.append(a, delta.b.clone(), AppendRefresh::Lazy).unwrap();
            assert!(store.wal_lag_bytes() > 0);
            assert_eq!(store.persisted_epoch(3), Some(live.epoch()));
            (live.solve(0.25, 1e-9).unwrap(), live.atb().to_vec())
        };
        // "Crash": a fresh store over the same directory recovers the
        // model with the WAL tail applied, bitwise.
        let store = Store::open(&root, DurabilityPolicy::Strict).unwrap();
        let mut recovered = store.recover_all().unwrap();
        assert_eq!(recovered.len(), 1);
        let rec = &mut recovered[0];
        assert_eq!((rec.id, rec.name.as_str()), (3, "demo"));
        assert_eq!(bits(rec.session.atb()), bits(&live_atb));
        let sol = rec.session.solve(0.25, 1e-9).unwrap();
        assert_eq!(bits(&sol.x), bits(&live_sol.x));
        assert_eq!(store.recovered_models.load(Ordering::Relaxed), 1);
        assert_eq!(store.truncated_tails.load(Ordering::Relaxed), 0);
        let _ = std::fs::remove_dir_all(root);
    }

    #[test]
    fn torn_wal_tail_is_truncated_not_fatal() {
        let root = tmp("torn");
        {
            let store = Store::open(&root, DurabilityPolicy::Off).unwrap();
            let mut s = fresh_session(64, 8, 50);
            store.persist_model(1, "torn", &mut s).unwrap();
            let d = synthetic::exponential_decay(4, 8, 51);
            let a = Operand::from(d.a.dense().into_owned());
            store.append_record(1, &a, &d.b, true).unwrap();
        }
        // Tear the last 5 bytes off the WAL.
        let wal_path = root.join("1").join("wal.log");
        let data = std::fs::read(&wal_path).unwrap();
        std::fs::write(&wal_path, &data[..data.len() - 5]).unwrap();
        let store = Store::open(&root, DurabilityPolicy::Off).unwrap();
        let recovered = store.recover_all().unwrap();
        assert_eq!(recovered.len(), 1, "model survives a torn tail");
        assert_eq!(recovered[0].session.n(), 64, "torn append dropped");
        assert_eq!(store.truncated_tails.load(Ordering::Relaxed), 1);
        // The reopened WAL accepts fresh appends after the truncation.
        let d = synthetic::exponential_decay(2, 8, 52);
        let a = Operand::from(d.a.dense().into_owned());
        store.append_record(1, &a, &d.b, true).unwrap();
        let _ = std::fs::remove_dir_all(root);
    }

    #[test]
    fn damaged_snapshot_skips_model_with_warning_not_panic() {
        let root = tmp("damaged");
        {
            let store = Store::open(&root, DurabilityPolicy::Off).unwrap();
            let mut good = fresh_session(64, 8, 60);
            store.persist_model(1, "good", &mut good).unwrap();
            let mut bad = fresh_session(64, 8, 61);
            store.persist_model(2, "bad", &mut bad).unwrap();
        }
        // Corrupt model 2's snapshot body.
        let snap_path = root.join("2").join("snapshot.snap");
        let mut data = std::fs::read(&snap_path).unwrap();
        let mid = data.len() / 2;
        data[mid] ^= 0xFF;
        std::fs::write(&snap_path, &data).unwrap();
        let store = Store::open(&root, DurabilityPolicy::Off).unwrap();
        let recovered = store.recover_all().unwrap();
        assert_eq!(recovered.len(), 1, "only the intact model recovers");
        assert_eq!(recovered[0].id, 1);
        let _ = std::fs::remove_dir_all(root);
    }

    #[test]
    fn rollback_append_removes_the_rejected_record() {
        let root = tmp("rollback");
        let store = Store::open(&root, DurabilityPolicy::Strict).unwrap();
        let mut s = fresh_session(64, 8, 70);
        store.persist_model(1, "rb", &mut s).unwrap();
        // A wrong-width delta: logged, rejected by the session, rolled
        // back — it must not replay on recovery.
        let bad = Operand::from(crate::linalg::Matrix::zeros(1, 3));
        let off = store.append_record(1, &bad, &[1.0], true).unwrap();
        assert!(s.append(bad, vec![1.0], AppendRefresh::Eager).is_err());
        store.rollback_append(1, off).unwrap();
        assert_eq!(store.wal_lag_bytes(), 0);
        drop(store);
        let store = Store::open(&root, DurabilityPolicy::Strict).unwrap();
        let recovered = store.recover_all().unwrap();
        assert_eq!(recovered.len(), 1);
        assert_eq!(recovered[0].session.n(), 64, "rolled-back record did not replay");
        let _ = std::fs::remove_dir_all(root);
    }

    #[test]
    fn spill_keeps_state_purge_removes_it() {
        let root = tmp("spill");
        let store = Store::open(&root, DurabilityPolicy::Off).unwrap();
        let mut s = fresh_session(64, 8, 80);
        s.solve(0.5, 1e-8).unwrap();
        let sol_live = s.solve(0.3, 1e-9).unwrap();
        store.persist_model(5, "spilled", &mut s).unwrap();
        store.drop_model(5, false);
        assert!(store.has_spilled(5));
        // Reload on demand: bitwise the same answers.
        let mut back = store.load_model(5).unwrap();
        assert_eq!(back.name, "spilled");
        let sol_back = back.session.solve(0.3, 1e-9).unwrap();
        assert_eq!(bits(&sol_back.x), bits(&sol_live.x));
        assert_eq!(store.reloads.load(Ordering::Relaxed), 1);
        // Purge deletes the files for good.
        store.drop_model(5, true);
        assert!(!store.has_spilled(5));
        assert!(store.load_model(5).is_err());
        assert_eq!(store.purged.load(Ordering::Relaxed), 1);
        let _ = std::fs::remove_dir_all(root);
    }

    #[test]
    fn snapshot_resets_the_wal_and_epoch_tracking() {
        let root = tmp("reset");
        let store = Store::open(&root, DurabilityPolicy::Batch).unwrap();
        let mut s = fresh_session(64, 8, 90);
        store.persist_model(1, "m", &mut s).unwrap();
        let d = synthetic::exponential_decay(4, 8, 91);
        let a = Operand::from(d.a.dense().into_owned());
        store.append_record(1, &a, &d.b, true).unwrap();
        s.append(a, d.b.clone(), AppendRefresh::Eager).unwrap();
        assert!(store.wal_lag_bytes() > 0);
        s.solve(0.5, 1e-8).unwrap(); // dirty: live epoch moved past snapshot
        assert!(s.epoch() > store.persisted_epoch(1).unwrap());
        store.persist_model(1, "m", &mut s).unwrap();
        assert_eq!(store.wal_lag_bytes(), 0, "snapshot absorbs the log");
        assert_eq!(store.persisted_epoch(1), Some(s.epoch()));
        assert_eq!(store.snapshots_written.load(Ordering::Relaxed), 2);
        assert!(store.last_snapshot_age_s().unwrap() >= 0.0);
        store.sync_all().unwrap();
        let _ = std::fs::remove_dir_all(root);
    }
}
