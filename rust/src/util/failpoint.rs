//! Deterministic fault injection for the chaos/robustness test suite.
//!
//! A *failpoint* is a named hook compiled into fallible paths of the
//! numerical core and the serving stack (sketch apply/grow, factorization,
//! session append/flush, server I/O). In normal operation every hook is a
//! single relaxed atomic load — nothing is armed and nothing fires. Tests
//! and benches arm sites explicitly through [`arm`] / [`arm_spec`], or via
//! the `EFFDIM_FAILPOINTS` environment variable (parsed by
//! [`arm_from_env`], which the server calls at bind time so external chaos
//! drivers can inject faults into a running process).
//!
//! Arming is **deterministic**: a site fires on its `hit_at`-th hit
//! (1-based, counted per arming) and then disarms itself, so a test can
//! express "the *second* factorization fails" and rerun it bitwise-
//! reproducibly. There is no randomness and no time dependence.
//!
//! Spec grammar (env var and [`arm_spec`]):
//!
//! ```text
//! EFFDIM_FAILPOINTS="site=action[:hit][,site=action[:hit]...]"
//! action ∈ { error | panic | sleep-<millis> }
//! ```
//!
//! e.g. `EFFDIM_FAILPOINTS="woodbury.factor=error:2,session.flush=panic"`.
//!
//! This module is a test/bench facility: production code never arms it,
//! and an unarmed process pays one atomic load per hook.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};

/// What an armed failpoint does when it fires.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Action {
    /// Return an injected error from the instrumented operation.
    Error,
    /// Panic inside the instrumented operation (exercises unwind safety).
    Panic,
    /// Sleep for the given number of milliseconds (exercises deadlines
    /// and slow-path shedding), then continue normally.
    Sleep(u64),
}

struct Armed {
    action: Action,
    /// Fires on the `hit_at`-th hit (1-based); decremented per hit.
    remaining: u64,
}

static ANY_ARMED: AtomicBool = AtomicBool::new(false);

fn registry() -> &'static Mutex<HashMap<String, Armed>> {
    // OnceLock rather than a const-initialized Mutex: HashMap::new() is
    // not const on the 1.70 MSRV.
    static REGISTRY: OnceLock<Mutex<HashMap<String, Armed>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Arm `site` to perform `action` on its `hit_at`-th hit (1-based), then
/// disarm itself. Re-arming a site replaces the previous arming.
pub fn arm(site: &str, action: Action, hit_at: u64) {
    let mut reg = registry().lock().unwrap();
    reg.insert(site.to_string(), Armed { action, remaining: hit_at.max(1) });
    ANY_ARMED.store(true, Ordering::SeqCst);
}

/// Disarm every site (tests call this in cleanup so armings cannot leak
/// across tests sharing the process).
pub fn disarm_all() {
    let mut reg = registry().lock().unwrap();
    reg.clear();
    ANY_ARMED.store(false, Ordering::SeqCst);
}

/// Parse and arm one `site=action[:hit]` spec. Unknown actions are
/// reported, not silently ignored — a typo'd chaos spec must not turn
/// into a vacuous test.
pub fn arm_spec(spec: &str) -> Result<(), String> {
    let (site, rest) = spec
        .split_once('=')
        .ok_or_else(|| format!("bad failpoint spec {spec:?} (want site=action[:hit])"))?;
    let (action_str, hit) = match rest.split_once(':') {
        Some((a, h)) => {
            let h: u64 = h
                .trim()
                .parse()
                .map_err(|_| format!("bad failpoint hit count in {spec:?}"))?;
            (a.trim(), h)
        }
        None => (rest.trim(), 1),
    };
    let action = match action_str {
        "error" => Action::Error,
        "panic" => Action::Panic,
        other => match other.strip_prefix("sleep-") {
            Some(ms) => Action::Sleep(
                ms.parse().map_err(|_| format!("bad failpoint sleep millis in {spec:?}"))?,
            ),
            None => return Err(format!("unknown failpoint action {action_str:?} in {spec:?}")),
        },
    };
    arm(site.trim(), action, hit);
    Ok(())
}

/// Arm every spec in the `EFFDIM_FAILPOINTS` environment variable (no-op
/// when unset or empty). Returns an error for malformed specs.
pub fn arm_from_env() -> Result<(), String> {
    let Ok(raw) = std::env::var("EFFDIM_FAILPOINTS") else { return Ok(()) };
    for spec in raw.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        arm_spec(spec)?;
    }
    Ok(())
}

/// The hook itself. Returns `Ok(())` when the site is unarmed or not yet
/// at its firing hit; returns `Err` for an [`Action::Error`] firing;
/// panics for [`Action::Panic`]; sleeps then returns `Ok(())` for
/// [`Action::Sleep`]. Call sites convert the `Err` into their own error
/// type.
pub fn check(site: &str) -> Result<(), String> {
    // Fast path: nothing armed anywhere in the process.
    if !ANY_ARMED.load(Ordering::Relaxed) {
        return Ok(());
    }
    let fired = {
        let mut reg = registry().lock().unwrap();
        let fired = match reg.get_mut(site) {
            None => None,
            Some(armed) => {
                armed.remaining -= 1;
                if armed.remaining == 0 {
                    Some(armed.action.clone())
                } else {
                    None
                }
            }
        };
        if fired.is_some() {
            reg.remove(site);
            if reg.is_empty() {
                ANY_ARMED.store(false, Ordering::SeqCst);
            }
        }
        fired
    };
    match fired {
        None => Ok(()),
        Some(Action::Error) => Err(format!("injected fault at failpoint {site:?}")),
        Some(Action::Panic) => panic!("injected panic at failpoint {site:?}"),
        Some(Action::Sleep(ms)) => {
            std::thread::sleep(std::time::Duration::from_millis(ms));
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Failpoint state is process-global; tests in this module serialize
    // on the registry by always starting from disarm_all() and using
    // unique site names.

    #[test]
    fn unarmed_sites_never_fire() {
        assert!(check("fp.test.unarmed").is_ok());
    }

    #[test]
    fn error_fires_on_nth_hit_then_disarms() {
        arm("fp.test.nth", Action::Error, 3);
        assert!(check("fp.test.nth").is_ok());
        assert!(check("fp.test.nth").is_ok());
        let err = check("fp.test.nth").unwrap_err();
        assert!(err.contains("fp.test.nth"), "{err}");
        assert!(check("fp.test.nth").is_ok(), "fired failpoints disarm themselves");
    }

    #[test]
    fn panic_action_panics() {
        arm("fp.test.panic", Action::Panic, 1);
        let r = std::panic::catch_unwind(|| check("fp.test.panic"));
        assert!(r.is_err());
    }

    #[test]
    fn specs_parse_and_reject() {
        arm_spec("fp.test.spec=error:2").unwrap();
        assert!(check("fp.test.spec").is_ok());
        assert!(check("fp.test.spec").is_err());
        arm_spec("fp.test.sleep=sleep-1").unwrap();
        assert!(check("fp.test.sleep").is_ok(), "sleep actions continue normally");
        assert!(arm_spec("no-equals").is_err());
        assert!(arm_spec("site=explode").is_err());
        assert!(arm_spec("site=error:x").is_err());
        assert!(arm_spec("site=sleep-x").is_err());
    }
}
