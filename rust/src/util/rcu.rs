//! A minimal RCU-style publication cell for `Arc` snapshots, in pure std.
//!
//! [`RcuCell`] holds one published `Arc<T>` and lets **unlimited
//! concurrent readers** clone it without taking any mutex, while writers
//! build the next value out-of-line and swap it in atomically. This is
//! the primitive behind the serving stack's lock-free read path
//! ([`crate::solvers::session::SessionSnapshot`] published per model by
//! [`crate::coordinator::registry::ModelEntry`]): a reader either sees
//! the old snapshot or the new one, never a mix, and a reader that
//! already pinned an old snapshot keeps a fully consistent `Arc` to it
//! for as long as it likes.
//!
//! # Design
//!
//! `std` has no atomic `Arc` swap, so the cell uses the classic
//! **two-slot pin-count** scheme:
//!
//! - Two slots each hold an `Arc<T>`; an atomic `active` index says
//!   which slot is current.
//! - A reader loads `active`, increments that slot's **pin count**, then
//!   re-checks `active`. If it still matches, the slot cannot be
//!   overwritten while pinned, so cloning the `Arc` inside is safe; the
//!   reader then unpins and returns the clone. If `active` moved, the
//!   reader unpins and retries (at most once per concurrent publish).
//! - A writer (serialized by an internal mutex that **readers never
//!   touch**) targets the *inactive* slot, waits for its pin count to
//!   drain to zero, overwrites the slot, and only then flips `active`.
//!
//! All atomics use `SeqCst`: the publication protocol is a Dekker-style
//! store→load handshake (reader: pin then re-check `active`; writer:
//! observe zero pins then overwrite), and `SeqCst` gives the single total
//! order that makes the interleaving argument airtight. The read path
//! costs two atomic RMWs and an `Arc` clone — no mutex, no syscall — and
//! a reader can only retry while a publish is in flight, so reads are
//! lock-free in the strict sense: some reader always completes.
//!
//! Writers may briefly spin waiting for stragglers pinned to the slot
//! they are about to reuse; pins are held only across an `Arc` clone
//! (nanoseconds), so the wait is bounded and tiny. Writers block each
//! other on the internal mutex — exactly the "writers serialize, readers
//! never block" contract the serving layer wants.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Atomically-swappable `Arc<T>` holder with mutex-free reads.
///
/// See the [module docs](self) for the protocol and memory-ordering
/// argument. `T` is typically an immutable snapshot; the cell itself
/// never hands out `&mut T`.
pub struct RcuCell<T> {
    /// Index (0 or 1) of the slot readers should pin.
    active: AtomicUsize,
    /// Per-slot count of readers currently between pin and unpin.
    pins: [AtomicUsize; 2],
    /// The two published values. A slot is only written while it is
    /// inactive *and* its pin count is zero, under the writer mutex.
    slots: [UnsafeCell<Arc<T>>; 2],
    /// Serializes writers. Readers never lock this.
    writers: Mutex<()>,
}

// SAFETY: the pin/re-check handshake (see module docs) guarantees a slot
// is never overwritten while any thread may dereference it, and writers
// are serialized by `writers`; with that protocol upheld, sharing the
// cell across threads is sound whenever `Arc<T>` itself is sendable.
unsafe impl<T: Send + Sync> Send for RcuCell<T> {}
// SAFETY: as above — all cross-thread access to `slots` is mediated by
// the SeqCst pin-count protocol.
unsafe impl<T: Send + Sync> Sync for RcuCell<T> {}

impl<T> RcuCell<T> {
    /// Create a cell publishing `value`.
    pub fn new(value: Arc<T>) -> Self {
        Self {
            active: AtomicUsize::new(0),
            pins: [AtomicUsize::new(0), AtomicUsize::new(0)],
            // Both slots start with the same Arc so the inactive slot is
            // never in a "poison" state needing special casing.
            slots: [UnsafeCell::new(Arc::clone(&value)), UnsafeCell::new(value)],
            writers: Mutex::new(()),
        }
    }

    /// Clone the currently published `Arc` without taking any lock.
    ///
    /// The returned handle stays valid (and immutable) no matter how many
    /// publishes happen afterwards — a pinned-to-the-past reader simply
    /// keeps the old snapshot alive through its own refcount.
    pub fn load(&self) -> Arc<T> {
        loop {
            let s = self.active.load(Ordering::SeqCst);
            self.pins[s].fetch_add(1, Ordering::SeqCst);
            if self.active.load(Ordering::SeqCst) == s {
                // SAFETY: `active == s` *after* our pin landed means (in
                // the SeqCst total order) any writer that will overwrite
                // slot `s` must first flip `active` away from `s` and
                // then observe `pins[s] == 0` — it cannot have done
                // either yet, so the slot's contents are stable while we
                // hold the pin.
                let out = unsafe { (*self.slots[s].get()).clone() };
                self.pins[s].fetch_sub(1, Ordering::SeqCst);
                return out;
            }
            // A publish landed between our load and our pin; the slot we
            // pinned may be the writer's next target. Back off and retry.
            self.pins[s].fetch_sub(1, Ordering::SeqCst);
        }
    }

    /// Publish `value`, making it the snapshot all future [`RcuCell::load`]
    /// calls return. Existing handles from earlier loads are untouched.
    ///
    /// Concurrent writers serialize on an internal mutex; the swap itself
    /// is a single atomic store, so readers observe either the old value
    /// or the new one in full — never a partial state.
    pub fn store(&self, value: Arc<T>) {
        let _writer = self.writers.lock().unwrap_or_else(|e| e.into_inner());
        let cur = self.active.load(Ordering::SeqCst);
        let idx = 1 - cur;
        // Drain stragglers still pinned to the retired slot. Pins only
        // span an Arc clone, so this resolves in nanoseconds; yield if a
        // reader got preempted mid-clone.
        let mut spins = 0u32;
        while self.pins[idx].load(Ordering::SeqCst) != 0 {
            spins += 1;
            if spins < 64 {
                std::hint::spin_loop();
            } else {
                std::thread::yield_now();
            }
        }
        // SAFETY: `idx` is the inactive slot (readers re-checking
        // `active` will not pin it and keep it pinned), its pin count
        // drained to zero after it became inactive, and we hold the
        // writer mutex — no other thread can touch the slot's contents.
        unsafe {
            *self.slots[idx].get() = value;
        }
        // The publish point: readers that load `active` from here on pin
        // the new slot; readers mid-protocol on the old index are still
        // reading the old (intact) slot.
        self.active.store(idx, Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;

    #[test]
    fn load_returns_the_initial_value() {
        let cell = RcuCell::new(Arc::new(41usize));
        assert_eq!(*cell.load(), 41);
        assert_eq!(*cell.load(), 41);
    }

    #[test]
    fn store_publishes_and_old_handles_survive() {
        let cell = RcuCell::new(Arc::new(1usize));
        let pinned = cell.load();
        cell.store(Arc::new(2));
        cell.store(Arc::new(3));
        assert_eq!(*pinned, 1, "pinned reader must keep its snapshot");
        assert_eq!(*cell.load(), 3);
    }

    /// Torn-read hunt: the published value is a pair that must stay
    /// internally consistent (`.1 == .0 * 2`). Readers hammer `load`
    /// while a writer republishes; any mix of two generations would
    /// break the invariant.
    #[test]
    fn concurrent_loads_never_observe_a_torn_pair() {
        let cell = Arc::new(RcuCell::new(Arc::new((0u64, 0u64))));
        let stop = Arc::new(AtomicBool::new(false));
        let mut readers = Vec::new();
        for _ in 0..4 {
            let cell = Arc::clone(&cell);
            let stop = Arc::clone(&stop);
            readers.push(std::thread::spawn(move || {
                let mut last = 0u64;
                while !stop.load(Ordering::SeqCst) {
                    let pair = cell.load();
                    assert_eq!(pair.1, pair.0 * 2, "torn snapshot observed");
                    assert!(pair.0 >= last, "snapshot generation went backwards");
                    last = pair.0;
                }
            }));
        }
        for k in 1..=2000u64 {
            cell.store(Arc::new((k, k * 2)));
        }
        stop.store(true, Ordering::SeqCst);
        for r in readers {
            r.join().expect("reader panicked");
        }
        let last = cell.load();
        assert_eq!(*last, (2000, 4000));
    }

    /// Writers serialize but never lose a publish: after all writers
    /// join, the cell holds one of the final values and every
    /// intermediate load was some writer's exact publication.
    #[test]
    fn concurrent_stores_always_leave_a_published_value() {
        let cell = Arc::new(RcuCell::new(Arc::new(0u64)));
        let mut writers = Vec::new();
        for w in 0..4u64 {
            let cell = Arc::clone(&cell);
            writers.push(std::thread::spawn(move || {
                for i in 0..500u64 {
                    cell.store(Arc::new(w * 1_000_000 + i));
                }
            }));
        }
        for w in writers {
            w.join().expect("writer panicked");
        }
        let v = *cell.load();
        assert_eq!(v % 1_000_000, 499, "final value must be some writer's last publish");
    }
}
