//! Summary statistics for benchmark trials (mean / std / min / median),
//! replacing criterion's aggregation in the offline build.

/// Aggregate of a set of measurements.
#[derive(Clone, Copy, Debug, Default)]
pub struct Summary {
    /// Number of measurements.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Sample standard deviation (0 for fewer than two measurements).
    pub std: f64,
    /// Smallest measurement.
    pub min: f64,
    /// Largest measurement.
    pub max: f64,
    /// Median (midpoint average for even `n`).
    pub median: f64,
}

/// Compute summary statistics. Empty input yields all zeros.
pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary::default();
    }
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = if n > 1 {
        xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
    } else {
        0.0
    };
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = if n % 2 == 1 {
        sorted[n / 2]
    } else {
        0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
    };
    Summary { n, mean, std: var.sqrt(), min: sorted[0], max: sorted[n - 1], median }
}

/// Format seconds human-readably (ns/us/ms/s).
pub fn fmt_time(seconds: f64) -> String {
    let abs = seconds.abs();
    if abs < 1e-6 {
        format!("{:.1} ns", seconds * 1e9)
    } else if abs < 1e-3 {
        format!("{:.2} µs", seconds * 1e6)
    } else if abs < 1.0 {
        format!("{:.2} ms", seconds * 1e3)
    } else {
        format!("{:.3} s", seconds)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_values() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.median - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        // Sample std of 1..4 = sqrt(5/3).
        assert!((s.std - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn odd_median() {
        assert_eq!(summarize(&[3.0, 1.0, 2.0]).median, 2.0);
    }

    #[test]
    fn empty_is_zeros() {
        let s = summarize(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn time_formatting_ranges() {
        assert!(fmt_time(2.5e-9).contains("ns"));
        assert!(fmt_time(2.5e-6).contains("µs"));
        assert!(fmt_time(2.5e-3).contains("ms"));
        assert!(fmt_time(2.5).contains(" s"));
    }
}
