//! Minimal JSON: a recursive-descent parser and a writer.
//!
//! Used for the artifact manifest (`artifacts/manifest.json`), the
//! coordinator's line-delimited wire protocol, and results export. Covers
//! the full JSON grammar except `\u` surrogate pairs (non-BMP escapes are
//! replaced); numbers are f64.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (always f64 — exact for integers below `2^53`).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys for stable output).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// The number, if this is a `Num`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The number truncated to `usize`, if this is a `Num`.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    /// The string, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The boolean, if this is a `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, if this is an `Arr`.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Object field lookup (`None` for non-objects and missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Serialize to a compact string.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null"); // JSON has no inf/nan
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}

impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}

impl From<u64> for Json {
    /// Numbers are f64 on the wire: exact for values below `2^53`, which
    /// covers the coordinator's monotonic job/model ids. Unlike a
    /// `usize` round-trip, this is independent of the target's pointer
    /// width (a `u64` id must not be narrowed through `usize` on 32-bit
    /// targets).
    fn from(x: u64) -> Self {
        Json::Num(x as f64)
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}

impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    /// Byte offset into the input where parsing failed.
    pub offset: usize,
    /// What went wrong.
    pub message: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

/// Parse a JSON document (must consume the full input up to whitespace).
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let bytes = input.as_bytes();
    let mut pos = 0usize;
    let value = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        return Err(err(pos, "trailing data"));
    }
    Ok(value)
}

fn err(offset: usize, message: &str) -> ParseError {
    ParseError { offset, message: message.to_string() }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, ParseError> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        return Err(err(*pos, "unexpected end of input"));
    }
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'n' => parse_lit(b, pos, "null", Json::Null),
        _ => parse_num(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Json) -> Result<Json, ParseError> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(v)
    } else {
        Err(err(*pos, "invalid literal"))
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json, ParseError> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos]).map_err(|_| err(start, "bad utf8"))?;
    s.parse::<f64>().map(Json::Num).map_err(|_| err(start, "invalid number"))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, ParseError> {
    debug_assert_eq!(b[*pos], b'"');
    *pos += 1;
    let mut out = String::new();
    loop {
        if *pos >= b.len() {
            return Err(err(*pos, "unterminated string"));
        }
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                if *pos >= b.len() {
                    return Err(err(*pos, "bad escape"));
                }
                match b[*pos] {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        if *pos + 4 >= b.len() {
                            return Err(err(*pos, "bad unicode escape"));
                        }
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])
                            .map_err(|_| err(*pos, "bad utf8 in escape"))?;
                        let code = u32::from_str_radix(hex, 16)
                            .map_err(|_| err(*pos, "bad hex in escape"))?;
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        *pos += 4;
                    }
                    _ => return Err(err(*pos, "unknown escape")),
                }
                *pos += 1;
            }
            _ => {
                // Copy a run of plain bytes (UTF-8 passes through intact).
                let start = *pos;
                while *pos < b.len() && b[*pos] != b'"' && b[*pos] != b'\\' {
                    *pos += 1;
                }
                out.push_str(
                    std::str::from_utf8(&b[start..*pos]).map_err(|_| err(start, "bad utf8"))?,
                );
            }
        }
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json, ParseError> {
    *pos += 1; // '['
    let mut items = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            _ => return Err(err(*pos, "expected ',' or ']'")),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json, ParseError> {
    *pos += 1; // '{'
    let mut map = BTreeMap::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(Json::Obj(map));
    }
    loop {
        skip_ws(b, pos);
        if *pos >= b.len() || b[*pos] != b'"' {
            return Err(err(*pos, "expected object key"));
        }
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b':') {
            return Err(err(*pos, "expected ':'"));
        }
        *pos += 1;
        let value = parse_value(b, pos)?;
        map.insert(key, value);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(map));
            }
            _ => return Err(err(*pos, "expected ',' or '}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for (s, v) in [
            ("null", Json::Null),
            ("true", Json::Bool(true)),
            ("false", Json::Bool(false)),
            ("42", Json::Num(42.0)),
            ("-3.25", Json::Num(-3.25)),
            ("\"hi\"", Json::Str("hi".into())),
        ] {
            assert_eq!(parse(s).unwrap(), v, "{s}");
        }
    }

    #[test]
    fn parses_nested_structures() {
        let doc = r#"{"a": [1, 2, {"b": "x"}], "c": null, "d": 1e-3}"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("c"), Some(&Json::Null));
        assert!((v.get("d").unwrap().as_f64().unwrap() - 1e-3).abs() < 1e-18);
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#""line\nfeed \"quoted\" A""#).unwrap();
        assert_eq!(v.as_str(), Some("line\nfeed \"quoted\" A"));
    }

    #[test]
    fn serialization_roundtrip() {
        let v = Json::obj(vec![
            ("name", "solver \"x\"".into()),
            ("iters", 42usize.into()),
            ("err", 1.5e-10.into()),
            ("ok", true.into()),
            ("trace", Json::Arr(vec![1.0.into(), 0.5.into()])),
        ]);
        let text = v.to_string();
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "\"unterminated", "{\"a\" 1}", "123abc... no"] {
            assert!(parse(bad).is_err(), "{bad:?}");
        }
        assert!(parse("1 2").is_err(), "trailing data");
    }

    #[test]
    fn integer_formatting_is_compact() {
        assert_eq!(Json::Num(7.0).to_string(), "7");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
