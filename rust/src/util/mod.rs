//! Small self-contained utilities (the build is fully offline, so there is
//! no serde/clap/criterion — these modules cover exactly what the rest of
//! the crate needs).

pub mod cli;
pub mod failpoint;
pub mod json;
pub mod rcu;
pub mod stats;
