//! Tiny `--flag value` argument parser for the binaries (offline build has
//! no clap). Supports `--key value`, `--key=value`, boolean `--key`, and a
//! positional subcommand.

use std::collections::BTreeMap;

/// Parsed arguments: one optional subcommand plus flags.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// First positional argument (`effdim <subcommand> ...`).
    pub subcommand: Option<String>,
    flags: BTreeMap<String, String>,
    /// Flags present without a value (`--paper`).
    switches: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv\[0\]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Self {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = iter.next().unwrap();
                    out.flags.insert(stripped.to_string(), v);
                } else {
                    out.switches.push(stripped.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(arg);
            } else {
                // Extra positional: treat as a switch for forgiveness.
                out.switches.push(arg);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Raw value of `--key value` / `--key=value`, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// [`Args::get`] with a default.
    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// Parse a `usize` flag; unparseable or absent values yield `default`.
    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Parse an `f64` flag; unparseable or absent values yield `default`.
    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Parse a `u64` flag; unparseable or absent values yield `default`.
    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Boolean switch presence (`--paper`).
    pub fn has(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key) || self.flags.contains_key(key)
    }

    /// Comma-separated list of f64 (`--nus 1e4,1e3,1`).
    pub fn get_f64_list(&self, key: &str, default: &[f64]) -> Vec<f64> {
        match self.get(key) {
            Some(v) => v.split(',').filter_map(|x| x.trim().parse().ok()).collect(),
            None => default.to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(words: &[&str]) -> Args {
        Args::parse(words.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["solve", "--n", "1024", "--rho=0.1", "--paper"]);
        assert_eq!(a.subcommand.as_deref(), Some("solve"));
        assert_eq!(a.get_usize("n", 0), 1024);
        assert!((a.get_f64("rho", 0.0) - 0.1).abs() < 1e-15);
        assert!(a.has("paper"));
        assert!(!a.has("absent"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]);
        assert_eq!(a.subcommand, None);
        assert_eq!(a.get_or("x", "d"), "d");
        assert_eq!(a.get_usize("n", 7), 7);
    }

    #[test]
    fn f64_list() {
        let a = parse(&["--nus", "1e2, 10,1"]);
        assert_eq!(a.get_f64_list("nus", &[]), vec![100.0, 10.0, 1.0]);
        assert_eq!(a.get_f64_list("other", &[5.0]), vec![5.0]);
    }

    #[test]
    fn negative_number_values() {
        // `--shift -3` — the value starts with '-' but not '--'.
        let a = parse(&["--shift", "-3"]);
        assert_eq!(a.get_f64("shift", 0.0), -3.0);
    }
}
