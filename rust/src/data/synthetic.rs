//! Synthetic ridge-regression workloads with controlled spectra, plus
//! density-controlled sparse workloads (CSR-backed [`Operand`]s) for the
//! `O(nnz)` fast paths.

use crate::linalg::sparse::CsrMatrix;
use crate::linalg::{Matrix, Operand};
use crate::rng::Xoshiro256;
use crate::sketch::srht::{fwht_rows, next_pow2};
use crate::theory::effective_dimension_from_spectrum;

/// Singular-value profile of the generated data matrix.
#[derive(Clone, Debug, PartialEq)]
pub enum SpectrumProfile {
    /// `sigma_j = rate^j`, `j = 0..d` — Appendix A.1's exponential decay
    /// (paper uses `rate = 0.95`).
    Exponential { rate: f64 },
    /// `sigma_j = 1/(j+1)` — Appendix A.1's polynomial decay.
    Polynomial,
    /// `sigma_j = scale * (exp(-j/tau) + floor)` — image-dataset surrogate:
    /// a steep head (dominant PCA directions) over a flat tail (pixel
    /// noise floor), the shape of MNIST/CIFAR gram spectra.
    ExponentialWithFloor { tau: f64, floor: f64, scale: f64 },
    /// Explicit singular values (tests, custom experiments).
    Explicit(Vec<f64>),
}

impl SpectrumProfile {
    /// Materialize the `d` singular values, descending.
    pub fn singular_values(&self, d: usize) -> Vec<f64> {
        let mut s: Vec<f64> = match self {
            SpectrumProfile::Exponential { rate } => {
                (0..d).map(|j| rate.powi(j as i32)).collect()
            }
            SpectrumProfile::Polynomial => (0..d).map(|j| 1.0 / (j as f64 + 1.0)).collect(),
            SpectrumProfile::ExponentialWithFloor { tau, floor, scale } => (0..d)
                .map(|j| scale * ((-(j as f64) / tau).exp() + floor))
                .collect(),
            SpectrumProfile::Explicit(v) => {
                assert_eq!(v.len(), d, "explicit spectrum length mismatch");
                v.clone()
            }
        };
        s.sort_by(|a, b| b.partial_cmp(a).unwrap());
        assert!(*s.last().unwrap() > 0.0, "spectrum must be positive (rank(A) = d)");
        s
    }
}

/// A generated ridge workload. The data matrix is an [`Operand`] — the
/// spectral generators produce dense matrices, the [`sparse_gaussian`]
/// family produces CSR — so every downstream consumer (solvers, sketch
/// engine, CLI, coordinator) gets the storage-appropriate kernels.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Data matrix, `n x d` (dense or CSR).
    pub a: Operand,
    /// Observations, length `n`.
    pub b: Vec<f64>,
    /// Exact singular values of `a` (descending) — free `d_e`
    /// computation. Empty for workloads without a constructed spectrum
    /// (the sparse generators): spectrum-derived quantities return NaN.
    pub sigma: Vec<f64>,
    /// Human-readable name for reports.
    pub name: String,
}

impl Dataset {
    /// Row count `n`.
    pub fn n(&self) -> usize {
        self.a.rows()
    }

    /// Column count `d`.
    pub fn d(&self) -> usize {
        self.a.cols()
    }

    /// Effective dimension at regularization `nu` (exact, from the stored
    /// spectrum; NaN when no spectrum was constructed — sparse workloads).
    pub fn effective_dimension(&self, nu: f64) -> f64 {
        if self.sigma.is_empty() {
            return f64::NAN;
        }
        effective_dimension_from_spectrum(&self.sigma, nu)
    }

    /// Condition number of the augmented matrix `[A; nu I]` (NaN when no
    /// spectrum was constructed).
    pub fn condition_number(&self, nu: f64) -> f64 {
        if self.sigma.is_empty() {
            return f64::NAN;
        }
        let s1 = self.sigma[0];
        let sd = *self.sigma.last().unwrap();
        ((s1 * s1 + nu * nu) / (sd * sd + nu * nu)).sqrt()
    }
}

/// Draw an implicit random orthonormal `n x d` factor applied to `w`:
/// returns `Q w` where `Q = H_n diag(eps) P_rows` is a randomized Hadamard
/// basis (exactly orthogonal columns). `w` is `d x d`; the result embeds
/// `w`'s rows at random distinct positions, sign-flips, and mixes with the
/// FWHT — `O(n d log n)`.
fn random_orthonormal_apply(n: usize, w: &Matrix, rng: &mut Xoshiro256) -> Matrix {
    let d = w.cols();
    assert!(w.rows() == d && d <= n);
    let n_pad = next_pow2(n);
    // Scatter the rows of w into d random distinct rows of the padded
    // buffer (this is P^T w), then sign-flip and FWHT.
    let positions = rng.sample_without_replacement(n_pad, d);
    let mut work = Matrix::zeros(n_pad, d);
    for (r, &pos) in positions.iter().enumerate() {
        let sign = rng.next_rademacher();
        let src = w.row(r);
        let dst = work.row_mut(pos);
        for k in 0..d {
            dst[k] = sign * src[k];
        }
    }
    fwht_rows(&mut work);
    // Normalized Hadamard: scale 1/sqrt(n_pad). Restricting H diag(eps) P
    // to the first n rows of n_pad is NOT orthogonal when n < n_pad, so we
    // require n == n_pad for exact orthogonality; otherwise fall back to
    // keeping all n_pad rows conceptually and subsampling would break the
    // spectrum. We therefore demand power-of-two n at generation time.
    assert_eq!(n, n_pad, "dataset n must be a power of two (got {n})");
    let scale = 1.0 / (n_pad as f64).sqrt();
    let mut out = Matrix::zeros(n, d);
    for i in 0..n {
        let src = work.row(i);
        let dst = out.row_mut(i);
        for k in 0..d {
            dst[k] = scale * src[k];
        }
    }
    out
}

/// Generate `A = U diag(sigma) V^T` (`U`: randomized Hadamard basis in
/// `R^{n x d}`, `V`: randomized Hadamard basis in `R^{d x d}`) plus planted
/// observations. `n` and `d` must be powers of two.
pub fn generate(n: usize, d: usize, profile: &SpectrumProfile, seed: u64, name: &str) -> Dataset {
    assert!(n >= d, "overdetermined generator needs n >= d");
    assert!(d.is_power_of_two(), "dataset d must be a power of two (got {d})");
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let sigma = profile.singular_values(d);

    // w = diag(sigma) V^T where V^T = (H_d diag(eps))/sqrt(d) row-permuted.
    let mut vt = Matrix::zeros(d, d);
    {
        let perm = rng.sample_without_replacement(d, d);
        for (i, &p) in perm.iter().enumerate() {
            vt.set(i, p, rng.next_rademacher());
        }
        fwht_rows(&mut vt);
        let scale = 1.0 / (d as f64).sqrt();
        for x in vt.as_mut_slice() {
            *x *= scale;
        }
    }
    let mut w = vt;
    for i in 0..d {
        let s = sigma[i];
        for x in w.row_mut(i) {
            *x *= s;
        }
    }

    let a = random_orthonormal_apply(n, &w, &mut rng);

    // b = A x_planted + noise  (Appendix A.1).
    let mut x_pl = vec![0.0; d];
    rng.fill_gaussian(&mut x_pl, 1.0 / (d as f64).sqrt());
    let mut b = a.matvec(&x_pl);
    let noise_sigma = 1.0 / (n as f64).sqrt();
    for bi in b.iter_mut() {
        *bi += noise_sigma * rng.next_gaussian();
    }

    Dataset { a: Operand::Dense(a), b, sigma, name: name.to_string() }
}

/// Shared draw sequence for the sparse twins: Bernoulli(`density`) mask
/// with `N(0, 1)` values, then planted observations as in [`generate`].
/// Built directly as triplets — `O(nnz)` memory; only the dense *twin*
/// ever materializes the `n x d` matrix — and the observations are
/// computed from the CSR form in both variants, so
/// [`sparse_gaussian`] and [`sparse_gaussian_dense`] at the same seed are
/// the *same problem* bit for bit (the dense-vs-CSR agreement tests and
/// the benchmark twins rely on this).
fn sparse_parts(n: usize, d: usize, density: f64, seed: u64) -> (CsrMatrix, Vec<f64>) {
    assert!(n > 0 && d > 0);
    assert!(
        density > 0.0 && density <= 1.0,
        "density must be in (0, 1], got {density}"
    );
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut triplets = Vec::new();
    for i in 0..n {
        for j in 0..d {
            if rng.next_f64() < density {
                triplets.push((i, j, rng.next_gaussian()));
            }
        }
    }
    let a = CsrMatrix::from_triplets(n, d, &triplets);
    let mut x_pl = vec![0.0; d];
    rng.fill_gaussian(&mut x_pl, 1.0 / (d as f64).sqrt());
    let mut b = a.matvec(&x_pl);
    let noise_sigma = 1.0 / (n as f64).sqrt();
    for bi in b.iter_mut() {
        *bi += noise_sigma * rng.next_gaussian();
    }
    (a, b)
}

/// Density-controlled sparse workload (rcv1-style bag-of-words regime):
/// each entry is nonzero with probability `density`, values `N(0, 1)`,
/// built and stored CSR (`O(nnz)` memory) so the whole pipeline runs its
/// `O(nnz)` paths. Unlike the spectral generators, `n`/`d` need not be
/// powers of two and no exact spectrum is recorded (`sigma` is empty).
pub fn sparse_gaussian(n: usize, d: usize, density: f64, seed: u64) -> Dataset {
    let (a, b) = sparse_parts(n, d, density, seed);
    Dataset {
        a: Operand::Sparse(a),
        b,
        sigma: Vec::new(),
        name: format!("sparse-{density}"),
    }
}

/// Dense-storage twin of [`sparse_gaussian`]: same seed ⇒ entrywise
/// identical matrix and bitwise-identical observations, stored densely —
/// the "before" side of every dense-vs-CSR benchmark and agreement test.
/// (This one does pay the `O(n d)` densification; that is its purpose.)
pub fn sparse_gaussian_dense(n: usize, d: usize, density: f64, seed: u64) -> Dataset {
    let (a, b) = sparse_parts(n, d, density, seed);
    Dataset {
        a: Operand::Dense(a.to_dense()),
        b,
        sigma: Vec::new(),
        name: format!("sparse-dense-{density}"),
    }
}

/// Appendix A.1 exponential-decay workload (`sigma_j = 0.95^j`).
pub fn exponential_decay(n: usize, d: usize, seed: u64) -> Dataset {
    generate(n, d, &SpectrumProfile::Exponential { rate: 0.95 }, seed, "synthetic-exp")
}

/// Appendix A.1 polynomial-decay workload (`sigma_j = 1/j`).
pub fn polynomial_decay(n: usize, d: usize, seed: u64) -> Dataset {
    generate(n, d, &SpectrumProfile::Polynomial, seed, "synthetic-poly")
}

/// MNIST-like surrogate: steep spectral head with a small tail floor,
/// mirroring the gram spectrum of centered MNIST pixels (a few dominant
/// stroke directions, fast decay, tiny pixel-noise floor). Defaults:
/// `n = 8192`, `d = 512`.
pub fn mnist_like(n: usize, d: usize, seed: u64) -> Dataset {
    let profile = SpectrumProfile::ExponentialWithFloor { tau: d as f64 / 24.0, floor: 1e-4, scale: 40.0 };
    generate(n, d, &profile, seed, "mnist-like")
}

/// CIFAR-like surrogate: slower decay and a heavier tail than MNIST
/// (natural-image statistics keep more directions alive), so `d_e` is
/// larger at equal `nu`. Defaults: `n = 8192`, `d = 1024`.
pub fn cifar_like(n: usize, d: usize, seed: u64) -> Dataset {
    let profile = SpectrumProfile::ExponentialWithFloor { tau: d as f64 / 10.0, floor: 3e-4, scale: 60.0 };
    generate(n, d, &profile, seed, "cifar-like")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::svd::singular_values;

    #[test]
    fn generated_spectrum_matches_request() {
        let ds = exponential_decay(64, 16, 1);
        let measured = singular_values(&ds.a.dense());
        for (m, e) in measured.iter().zip(&ds.sigma) {
            assert!((m - e).abs() < 1e-9, "measured {m} expected {e}");
        }
    }

    #[test]
    fn polynomial_spectrum_matches() {
        let ds = polynomial_decay(64, 8, 2);
        let measured = singular_values(&ds.a.dense());
        for (j, m) in measured.iter().enumerate() {
            assert!((m - 1.0 / (j as f64 + 1.0)).abs() < 1e-9);
        }
    }

    #[test]
    fn observations_have_planted_signal() {
        // ||b|| should be dominated by the signal, not the noise.
        let ds = exponential_decay(256, 32, 3);
        let b_norm = crate::linalg::norm2(&ds.b);
        assert!(b_norm > 0.1, "b looks like pure noise: {b_norm}");
        assert_eq!(ds.b.len(), 256);
    }

    #[test]
    fn effective_dimension_sane() {
        let ds = mnist_like(1024, 128, 4);
        let de_small_nu = ds.effective_dimension(1e-3);
        let de_large_nu = ds.effective_dimension(10.0);
        assert!(de_small_nu <= 128.0 + 1e-9);
        assert!(de_large_nu < de_small_nu);
        assert!(de_large_nu > 0.0);
    }

    #[test]
    fn mnist_like_has_smaller_de_than_cifar_like() {
        // The substitution preserves the paper's regime: CIFAR's heavier
        // tail keeps more effective directions at moderate nu.
        let m = mnist_like(1024, 256, 5);
        let c = cifar_like(1024, 256, 6);
        let nu = 1.0;
        assert!(m.effective_dimension(nu) < c.effective_dimension(nu));
    }

    #[test]
    fn condition_number_improves_with_regularization() {
        let ds = polynomial_decay(128, 32, 7);
        assert!(ds.condition_number(1.0) < ds.condition_number(0.01));
    }

    #[test]
    fn deterministic_given_seed() {
        let d1 = exponential_decay(64, 8, 42);
        let d2 = exponential_decay(64, 8, 42);
        assert_eq!(d1.a, d2.a);
        assert_eq!(d1.b, d2.b);
    }

    #[test]
    fn sparse_twins_are_the_same_problem() {
        let s = sparse_gaussian(50, 12, 0.2, 7);
        let d = sparse_gaussian_dense(50, 12, 0.2, 7);
        assert_eq!(s.b, d.b);
        assert!(s.a.is_sparse() && !d.a.is_sparse());
        assert!(s.a.dense().max_abs_diff(&d.a.dense()) == 0.0);
        // Density lands in the right ballpark and the spectrum is absent.
        let dens = s.a.density();
        assert!(dens > 0.05 && dens < 0.4, "density {dens}");
        assert!(s.effective_dimension(1.0).is_nan());
        assert!(s.condition_number(1.0).is_nan());
    }

    #[test]
    #[should_panic(expected = "density must be in (0, 1]")]
    fn sparse_rejects_bad_density() {
        sparse_gaussian(8, 4, 0.0, 1);
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn rejects_non_power_of_two_n() {
        exponential_decay(100, 8, 1);
    }
}
