//! Workload generators reproducing the paper's experimental datasets,
//! plus the sparse/triplet input surface.
//!
//! The paper evaluates on (i) synthetic matrices with exponential
//! (`sigma_j = 0.95^j`) and polynomial (`sigma_j = 1/j`) spectral decay
//! (Appendix A.1 / Figure 3) and (ii) one-vs-all MNIST and CIFAR-10
//! classification (Figures 1–2). The real image datasets are not available
//! in this environment, so [`mnist_like`] and [`cifar_like`] generate
//! surrogates that match the *spectral profile* of the corresponding ridge
//! problems — which is the only property of `A` the solvers are sensitive
//! to (it determines `d_e`, the conditioning, and hence every algorithmic
//! decision; see DESIGN.md §6 for the substitution argument).
//!
//! All spectral generators build `A = U diag(sigma) V^T` with *implicitly
//! orthogonal* factors (randomized Hadamard bases applied via the FWHT), so
//! constructing an `8192 x 1024` workload costs `O(n d log n)` instead of
//! the `O(n d^2)` a QR-based construction would need. Labels follow
//! Appendix A.1: `b = A x_planted + noise` with
//! `x_planted ~ N(0, I/d)`, `noise ~ N(0, I/n)`.
//!
//! For the Remark 4.1 sparse regime, [`synthetic::sparse_gaussian`]
//! generates density-controlled CSR workloads (with a dense twin for
//! benchmarking), and [`parse_triplet_problem`] reads real sparse data in
//! a plain-text triplet format (`effdim solve --data <file>`, see below).

pub mod synthetic;

pub use synthetic::{
    cifar_like, mnist_like, sparse_gaussian, sparse_gaussian_dense, Dataset, SpectrumProfile,
};

use crate::linalg::sparse::CsrMatrix;

/// Parse a sparse ridge problem from the plain-text triplet format:
///
/// ```text
/// # comments and blank lines are ignored
/// n d nnz          <- header: rows, cols, triplet count
/// i j v            <- nnz lines: 0-based row, 0-based col, value
/// ...
/// b_0              <- n lines: observations
/// ...
/// ```
///
/// Duplicate `(i, j)` entries are summed (CSR triplet semantics). This is
/// the CLI's `--data <file>` format and the reference encoding for the
/// coordinator's inline `"triplets"` requests.
pub fn parse_triplet_problem(text: &str) -> Result<(CsrMatrix, Vec<f64>), String> {
    fn take<'a>(toks: &[&'a str], pos: &mut usize, what: &str) -> Result<&'a str, String> {
        if *pos >= toks.len() {
            return Err(format!("triplet file ended early: expected {what}"));
        }
        let t = toks[*pos];
        *pos += 1;
        Ok(t)
    }
    let toks: Vec<&str> = text
        .lines()
        .map(|l| l.trim())
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .flat_map(|l| l.split_whitespace())
        .collect();
    let mut pos = 0usize;
    let n: usize =
        take(&toks, &mut pos, "n")?.parse().map_err(|_| "bad n in triplet header".to_string())?;
    let d: usize =
        take(&toks, &mut pos, "d")?.parse().map_err(|_| "bad d in triplet header".to_string())?;
    let nnz: usize = take(&toks, &mut pos, "nnz")?
        .parse()
        .map_err(|_| "bad nnz in triplet header".to_string())?;
    if n == 0 || d == 0 {
        return Err("triplet header needs n > 0 and d > 0".into());
    }
    // Capacity clamped by the actual token supply: a bogus huge header
    // count must yield the graceful "ended early" Err below, not an
    // allocator abort.
    let remaining = toks.len().saturating_sub(pos);
    let mut triplets = Vec::with_capacity(nnz.min(remaining / 3));
    for k in 0..nnz {
        let i: usize = take(&toks, &mut pos, "triplet row")?
            .parse()
            .map_err(|_| format!("bad row index in triplet {k}"))?;
        let j: usize = take(&toks, &mut pos, "triplet col")?
            .parse()
            .map_err(|_| format!("bad col index in triplet {k}"))?;
        let v: f64 = take(&toks, &mut pos, "triplet value")?
            .parse()
            .map_err(|_| format!("bad value in triplet {k}"))?;
        if i >= n || j >= d {
            return Err(format!("triplet {k} ({i},{j}) out of bounds for {n} x {d}"));
        }
        if !v.is_finite() {
            return Err(format!("triplet {k} has non-finite value"));
        }
        triplets.push((i, j, v));
    }
    let mut b = Vec::with_capacity(n.min(toks.len().saturating_sub(pos)));
    for k in 0..n {
        let v: f64 = take(&toks, &mut pos, "observation")?
            .parse()
            .map_err(|_| format!("bad observation b[{k}]"))?;
        if !v.is_finite() {
            return Err(format!("observation b[{k}] is non-finite"));
        }
        b.push(v);
    }
    if pos != toks.len() {
        return Err("trailing tokens after observations in triplet file".into());
    }
    Ok((CsrMatrix::from_triplets(n, d, &triplets), b))
}

/// Render a problem in the [`parse_triplet_problem`] format (round-trip
/// helper for tests and for exporting generated workloads).
pub fn format_triplet_problem(a: &CsrMatrix, b: &[f64]) -> String {
    assert_eq!(a.rows(), b.len());
    let mut out = String::new();
    out.push_str(&format!("{} {} {}\n", a.rows(), a.cols(), a.nnz()));
    for i in 0..a.rows() {
        let (cols, vals) = a.row(i);
        for (&c, &v) in cols.iter().zip(vals) {
            out.push_str(&format!("{i} {c} {v:e}\n"));
        }
    }
    for bi in b {
        out.push_str(&format!("{bi:e}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn triplet_roundtrip() {
        let csr = CsrMatrix::from_triplets(
            3,
            4,
            &[(0, 1, 2.5), (1, 0, -1.0), (2, 3, 0.125), (2, 0, 7.0)],
        );
        let b = vec![1.0, -2.0, 0.5];
        let text = format_triplet_problem(&csr, &b);
        let (back, b_back) = parse_triplet_problem(&text).unwrap();
        assert_eq!(back, csr);
        assert_eq!(b_back, b);
    }

    #[test]
    fn triplet_parser_accepts_comments_and_merges_duplicates() {
        let text = "# sparse problem\n2 2 3\n0 0 1.0\n# dup below\n0 0 2.0\n1 1 -3.0\n0.5\n1.5\n";
        let (a, b) = parse_triplet_problem(text).unwrap();
        assert_eq!(a.nnz(), 2);
        assert_eq!(a.to_dense().get(0, 0), 3.0);
        assert_eq!(b, vec![0.5, 1.5]);
    }

    #[test]
    fn triplet_parser_rejects_malformed_input() {
        assert!(parse_triplet_problem("").is_err());
        assert!(parse_triplet_problem("2 2 1\n5 0 1.0\n0.0\n0.0").is_err(), "out of bounds");
        assert!(parse_triplet_problem("2 2 1\n0 0 1.0\n0.0").is_err(), "missing b");
        assert!(parse_triplet_problem("2 2 0\n0.0\n0.0\nextra").is_err(), "trailing");
        assert!(parse_triplet_problem("2 2 1\n0 0 nan\n0.0\n0.0").is_err(), "non-finite");
        assert!(parse_triplet_problem("1 1 1\n0 0 1.0\ninf").is_err(), "non-finite b");
        // A bogus huge header count must error gracefully, not abort on a
        // capacity pre-reservation.
        assert!(
            parse_triplet_problem("1 1 18446744073709551\n0 0 1.0\n0.5").is_err(),
            "huge nnz header"
        );
    }
}
