//! Workload generators reproducing the paper's experimental datasets.
//!
//! The paper evaluates on (i) synthetic matrices with exponential
//! (`sigma_j = 0.95^j`) and polynomial (`sigma_j = 1/j`) spectral decay
//! (Appendix A.1 / Figure 3) and (ii) one-vs-all MNIST and CIFAR-10
//! classification (Figures 1–2). The real image datasets are not available
//! in this environment, so [`mnist_like`] and [`cifar_like`] generate
//! surrogates that match the *spectral profile* of the corresponding ridge
//! problems — which is the only property of `A` the solvers are sensitive
//! to (it determines `d_e`, the conditioning, and hence every algorithmic
//! decision; see DESIGN.md §6 for the substitution argument).
//!
//! All generators build `A = U diag(sigma) V^T` with *implicitly
//! orthogonal* factors (randomized Hadamard bases applied via the FWHT), so
//! constructing an `8192 x 1024` workload costs `O(n d log n)` instead of
//! the `O(n d^2)` a QR-based construction would need. Labels follow
//! Appendix A.1: `b = A x_planted + noise` with
//! `x_planted ~ N(0, I/d)`, `noise ~ N(0, I/n)`.

pub mod synthetic;

pub use synthetic::{cifar_like, mnist_like, Dataset, SpectrumProfile};
