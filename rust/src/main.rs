//! `effdim` CLI — the L3 entrypoint.
//!
//! ```text
//! effdim solve   --profile mnist-like --n 1024 --d 128 --nu 1.0 \
//!                --solver adaptive-srht --eps 1e-8 --seed 7
//! effdim path    --profile exp --n 1024 --d 128 --nus 1e2,1e1,1,0.1 \
//!                --solver adaptive-srht --eps 1e-8
//! effdim serve   --addr 127.0.0.1:7199 --workers 2 --model-budget-mb 512
//! effdim request --addr 127.0.0.1:7199 --json '{"cmd":"ping"}'
//! effdim client register --addr 127.0.0.1:7199 --profile exp --n 4096 --d 256 \
//!                --sketch srht --name exp-4k
//! effdim client query   --addr 127.0.0.1:7199 --model 1 --nu 0.5 --include-x
//! effdim client query   --addr 127.0.0.1:7199 --model 1 --nus 10,1,0.1
//! effdim client query   --addr 127.0.0.1:7199 --model 1 --nu 0.5 --rhs-file batch.txt
//! effdim client predict --addr 127.0.0.1:7199 --model 1 --nu 0.5 --row 0.1,0.2,...
//! effdim client append  --addr 127.0.0.1:7199 --model 1 --data delta.txt \
//!                --refresh lazy
//! effdim client evict   --addr 127.0.0.1:7199 --model 1 [--purge]
//! effdim client snapshot --addr 127.0.0.1:7199 [--model 1]
//! effdim client models  --addr 127.0.0.1:7199
//! effdim info    --profile cifar-like --n 1024 --d 128 --nu 1.0
//! effdim solvers
//! ```
//!
//! `effdim client` builds registry requests (see `PROTOCOL.md`) from
//! flags: register a problem once, then issue many cheap queries that
//! reuse the server-side cached sketch/factorization state.
//!
//! Every `--solver` value is a spec string parsed by
//! [`SolverSpec`](effdim::solvers::SolverSpec) with the grammar
//!
//! ```text
//! spec      := name [ "@" param ( "," param )* ]
//! name      := "direct" | "cg" | "pcg-<kind>" | "ihs-<kind>"
//!            | "polyak-ihs-<kind>" | "adaptive-<kind>"
//!            | "adaptive-gd-<kind>" | "dual-adaptive-<kind>"
//! kind      := "gaussian" | "srht" | "sparse"
//! param     := "m=<usize>"       (ihs sketch size)
//!            | "rho=<f64>"       (pcg preconditioner aspect ratio)
//!            | "threads=<usize>" (pin the parallel dense kernels)
//! ```
//!
//! e.g. `cg`, `pcg-gaussian`, `adaptive-srht`, `ihs-sparse@m=256`,
//! `pcg-srht@rho=0.25`, `adaptive-srht@threads=8`. `effdim solvers`
//! prints the full registry. `--threads k` (or `PALLAS_THREADS`) pins
//! the kernels for the whole command instead of one solver.
//!
//! Sparse inputs: `--profile sparse --density 0.01` generates a
//! density-controlled CSR workload (the whole pipeline then runs its
//! `O(nnz)` paths), and `--data <file>` loads a real problem from the
//! plain-text triplet format (header `n d nnz`, `nnz` lines of
//! `row col value`, then `n` observation lines; `#` comments allowed —
//! see [`effdim::data::parse_triplet_problem`]).

use effdim::coordinator::job::{self, JobSpec, Workload, DEFAULT_SPARSE_DENSITY};
use effdim::coordinator::server::{Client, Server};
use effdim::data::synthetic::{self, Dataset};
use effdim::linalg::Operand;
use effdim::solvers::path::run_path;
use effdim::solvers::{Solver as _, SolverSpec};
use effdim::util::cli::Args;
use effdim::util::json::Json;

const USAGE: &str = "usage: effdim <solve|path|serve|request|client|info|solvers> [--flags]
  client <register|query|predict|append|evict|models> drives a server's
    model registry: --model id, --nu x | --nus a,b,c, --eps x, --include-x,
    --sketch gaussian|srht|sparse, --name s, --row v1,v2,... (predict);
    query --rhs-file f sends a batched block multi-RHS query: one
    right-hand side per line (comma/space separated, # comments), all
    solved jointly against the model's cached sketch;
    register accepts the same workload flags as solve (--profile/--data);
    append streams --data <triplet-file> rows into a registered model
    (the file's d must match the model; --refresh eager|lazy picks when
    the cached sketch/factorization is updated, default eager)
  --solver takes a spec string: name[@key=value,...]
    names : direct | cg | pcg-<kind> | ihs-<kind> | polyak-ihs-<kind>
            | adaptive-<kind> | adaptive-gd-<kind> | dual-adaptive-<kind>
    kinds : gaussian | srht | sparse
    params: m=<usize> (ihs), rho=<f64> (pcg), threads=<usize> (any randomized)
    bare aliases 'adaptive', 'adaptive-gd', 'dual' default to gaussian;
    'pcg' defaults to srht — name the kind explicitly in scripts
  --profile exp|poly|mnist-like|cifar-like|exp:<rate>|sparse|sparse:<density>
    (sparse profiles are CSR-backed; pair with --density)
  --density x sets the sparse profile's fill fraction (requires --profile sparse)
  --data file loads a CSR problem from triplet text (n d nnz / i j v / b lines)
  serve hardening: --max-request-mb n caps one request line (default 16),
    --request-timeout-s x sets a default wall deadline per registry request
    (wire \"deadline_s\" overrides per request), --max-conns n bounds
    concurrent connections (excess accepts answer
    {\"ok\":false,\"error\":\"overloaded\",\"retry_after_s\":..})
  serve durability: --state-dir <dir> persists models (checksummed
    snapshots + per-model append WAL) and recovers them at startup;
    --durability strict|batch|off picks the WAL fsync policy (default
    strict; requires --state-dir)
  client/request retries: --retries n retries overload sheds and transport
    errors with exponential backoff + jitter, honoring the server's
    retry_after_s hint (default 0 = fail fast); --max-backoff-s x caps one
    backoff sleep (default 30)
  --threads k pins the parallel dense kernels for the whole command
    (default: PALLAS_THREADS env var, else all hardware threads)
  run `effdim solvers` for the registry; see rust/src/main.rs docs for flags";

fn main() {
    let args = Args::from_env();
    let code = match args.subcommand.as_deref() {
        Some("solve") => cmd_solve(&args),
        Some("path") => cmd_path(&args),
        Some("serve") => cmd_serve(&args),
        Some("request") => cmd_request(&args),
        Some("client") => cmd_client(&args),
        Some("info") => cmd_info(&args),
        Some("solvers") => cmd_solvers(),
        _ => {
            eprintln!("{USAGE}");
            2
        }
    };
    std::process::exit(code);
}

/// Resolve `--profile` + `--density` into the profile string the
/// coordinator's workload layer understands (`sparse` -> `sparse:<d>`).
fn profile_from(args: &Args) -> Result<String, i32> {
    let profile = args.get_or("profile", "exp").to_string();
    match args.get("density") {
        None => Ok(profile),
        Some(v) => {
            if profile != "sparse" {
                eprintln!("--density requires --profile sparse (got {profile:?})");
                return Err(2);
            }
            match v.trim().parse::<f64>() {
                Ok(dens) if dens > 0.0 && dens <= 1.0 => Ok(format!("sparse:{dens}")),
                _ => {
                    eprintln!("--density must be in (0, 1], got {v:?}");
                    Err(2)
                }
            }
        }
    }
}

fn workload_from(args: &Args) -> Result<Workload, i32> {
    if let Some(path) = args.get("data") {
        let text = std::fs::read_to_string(path).map_err(|e| {
            eprintln!("cannot read {path}: {e}");
            2
        })?;
        let (a, b) = effdim::data::parse_triplet_problem(&text).map_err(|e| {
            eprintln!("{path}: {e}");
            2
        })?;
        return Ok(Workload::Inline { a: Operand::Sparse(a), b });
    }
    Ok(Workload::Synthetic {
        profile: profile_from(args)?,
        n: args.get_usize("n", 1024),
        d: args.get_usize("d", 128),
        seed: args.get_u64("seed", 1),
    })
}

fn parse_solver(args: &Args, default: &str) -> Result<SolverSpec, i32> {
    match args.get_or("solver", default).parse() {
        Ok(spec) => Ok(spec),
        Err(e) => {
            eprintln!("{e}");
            eprintln!("{USAGE}");
            Err(2)
        }
    }
}

/// `--threads k` with the same validation as the wire protocol and the
/// `@threads=k` spec param: present means a positive integer, anything
/// else is a usage error (exit code via `Err`).
fn threads_flag(args: &Args) -> Result<Option<usize>, i32> {
    match args.get("threads") {
        None => Ok(None),
        Some(v) => match v.trim().parse::<usize>() {
            Ok(k) if k >= 1 => Ok(Some(k)),
            _ => {
                eprintln!("--threads must be a positive integer, got {v:?}");
                Err(2)
            }
        },
    }
}

fn cmd_solve(args: &Args) -> i32 {
    let workload = match workload_from(args) {
        Ok(w) => w,
        Err(code) => return code,
    };
    let spec = JobSpec {
        workload,
        nu: args.get_f64("nu", 1.0),
        solver: match parse_solver(args, "adaptive-srht") {
            Ok(s) => s,
            Err(code) => return code,
        },
        eps: args.get_f64("eps", 1e-8),
        seed: args.get_u64("seed", 1),
        path_nus: match strict_f64_list(args, "path-nus") {
            Ok(nus) => nus.unwrap_or_default(),
            Err(code) => return code,
        },
        threads: match threads_flag(args) {
            Ok(t) => t,
            Err(code) => return code,
        },
    };
    match job::execute(&spec) {
        Ok(outcome) => {
            println!("{}", outcome.to_json(args.has("include-x")).to_string());
            if outcome.report.converged {
                0
            } else {
                1
            }
        }
        Err(e) => {
            eprintln!("solve failed: {e}");
            1
        }
    }
}

/// Build a dataset from a resolved profile string (the `path` subcommand
/// needs the `Dataset` itself for the per-point `d_e` column; sparse
/// profiles have no stored spectrum, so that column prints NaN).
fn dataset_for(profile: &str, n: usize, d: usize, seed: u64) -> Result<Dataset, String> {
    match profile {
        "exp" => Ok(synthetic::exponential_decay(n, d, seed)),
        "poly" => Ok(synthetic::polynomial_decay(n, d, seed)),
        "mnist-like" => Ok(synthetic::mnist_like(n, d, seed)),
        "cifar-like" => Ok(synthetic::cifar_like(n, d, seed)),
        "sparse" => Ok(synthetic::sparse_gaussian(n, d, DEFAULT_SPARSE_DENSITY, seed)),
        other => {
            if let Some(rate) = other.strip_prefix("exp:") {
                let rate: f64 = rate.parse().map_err(|_| format!("bad rate in {other}"))?;
                Ok(synthetic::generate(
                    n,
                    d,
                    &effdim::data::SpectrumProfile::Exponential { rate },
                    seed,
                    other,
                ))
            } else if let Some(dens) = other.strip_prefix("sparse:") {
                let dens: f64 = dens.parse().map_err(|_| format!("bad density in {other}"))?;
                if !(dens > 0.0 && dens <= 1.0) {
                    return Err(format!("density must be in (0, 1], got {dens}"));
                }
                Ok(synthetic::sparse_gaussian(n, d, dens, seed))
            } else {
                Err(format!("unknown profile {other}"))
            }
        }
    }
}

fn cmd_path(args: &Args) -> i32 {
    let n = args.get_usize("n", 1024);
    let d = args.get_usize("d", 128);
    let seed = args.get_u64("seed", 1);
    // `--data` drives the path on a triplet file (d_e column prints NaN
    // — no spectrum is known for external data); otherwise a profile.
    let ds = if let Some(path) = args.get("data") {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("cannot read {path}: {e}");
                return 2;
            }
        };
        match effdim::data::parse_triplet_problem(&text) {
            Ok((a, b)) => Dataset {
                a: Operand::Sparse(a),
                b,
                sigma: Vec::new(),
                name: path.to_string(),
            },
            Err(e) => {
                eprintln!("{path}: {e}");
                return 2;
            }
        }
    } else {
        let profile = match profile_from(args) {
            Ok(p) => p,
            Err(code) => return code,
        };
        match dataset_for(&profile, n, d, seed) {
            Ok(ds) => ds,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        }
    };
    let nus = match strict_f64_list(args, "nus") {
        Ok(nus) => nus.unwrap_or_else(|| vec![100.0, 10.0, 1.0, 0.1, 0.01]),
        Err(code) => return code,
    };
    let spec = match parse_solver(args, "adaptive-srht") {
        Ok(s) => s,
        Err(code) => return code,
    };
    let eps = args.get_f64("eps", 1e-8);
    let res = match threads_flag(args) {
        Ok(Some(k)) => effdim::linalg::threads::with_threads(k, || {
            run_path(&ds.a, &ds.b, &nus, eps, &spec, seed)
        }),
        Ok(None) => run_path(&ds.a, &ds.b, &nus, eps, &spec, seed),
        Err(code) => return code,
    };
    println!("solver: {}", res.solver);
    println!(
        "{:<12} {:>10} {:>12} {:>10} {:>8} {:>6}",
        "nu", "d_e", "cum_time_s", "iters", "m", "conv"
    );
    for p in &res.points {
        println!(
            "{:<12.3e} {:>10.1} {:>12.4} {:>10} {:>8} {:>6}",
            p.nu,
            ds.effective_dimension(p.nu),
            p.cumulative_time_s,
            p.report.iterations,
            p.report.peak_m,
            p.report.converged
        );
    }
    if res.points.iter().all(|p| p.report.converged) {
        0
    } else {
        1
    }
}

fn cmd_serve(args: &Args) -> i32 {
    let addr = args.get_or("addr", "127.0.0.1:7199");
    let workers = args.get_usize("workers", 2);
    // Model-registry byte budget (LRU eviction threshold), in MiB.
    // Saturating: an absurd flag value caps at usize::MAX bytes instead
    // of overflowing the shift into a tiny (evict-everything) budget.
    let budget_mb = args.get_usize(
        "model-budget-mb",
        effdim::coordinator::registry::DEFAULT_BYTE_BUDGET >> 20,
    );
    // Hardening knobs: request-line cap, default per-request wall
    // deadline, concurrent-connection bound.
    let max_request_mb =
        args.get_usize("max-request-mb", effdim::coordinator::server::DEFAULT_MAX_LINE_BYTES >> 20);
    if max_request_mb == 0 {
        eprintln!("--max-request-mb must be >= 1");
        return 2;
    }
    let request_timeout = if args.has("request-timeout-s") {
        let s = args.get_f64("request-timeout-s", 0.0);
        if !(s.is_finite() && s > 0.0) {
            eprintln!("--request-timeout-s must be positive and finite");
            return 2;
        }
        Some(std::time::Duration::from_secs_f64(s))
    } else {
        None
    };
    let max_conns = args.get_usize("max-conns", effdim::coordinator::server::DEFAULT_MAX_CONNS);
    if max_conns == 0 {
        eprintln!("--max-conns must be >= 1");
        return 2;
    }
    // Durability: a state dir turns on snapshots + WAL + recovery; the
    // fsync policy only means something with one.
    let state_dir = args.get("state-dir").map(std::path::PathBuf::from);
    let durability = match args.get("durability") {
        None => effdim::persist::DurabilityPolicy::Strict,
        Some(v) => {
            if state_dir.is_none() {
                eprintln!("--durability requires --state-dir");
                return 2;
            }
            match v.parse() {
                Ok(p) => p,
                Err(e) => {
                    eprintln!("{e}");
                    return 2;
                }
            }
        }
    };
    let config = effdim::coordinator::server::ServerConfig {
        workers,
        model_byte_budget: budget_mb.saturating_mul(1 << 20),
        max_line_bytes: max_request_mb.saturating_mul(1 << 20),
        request_timeout,
        max_conns,
        max_pipeline: effdim::coordinator::server::DEFAULT_MAX_PIPELINE,
        state_dir,
        durability,
    };
    match Server::bind_with_config(addr, config) {
        Ok(server) => {
            println!("effdim coordinator listening on {}", server.local_addr());
            server.run();
            println!("coordinator stopped");
            0
        }
        Err(e) => {
            eprintln!("bind {addr}: {e}");
            1
        }
    }
}

/// `effdim client <register|query|predict|evict|snapshot|models>` — build
/// a model-registry request (PROTOCOL.md) from flags, send it, print the
/// JSON response. Exit code 1 when the server answered `"ok":false`.
fn cmd_client(args: &Args) -> i32 {
    let action = ["register", "query", "predict", "append", "evict", "snapshot", "models"]
        .into_iter()
        .find(|a| args.has(a));
    let Some(action) = action else {
        eprintln!(
            "client needs one of: register | query | predict | append | evict | snapshot | models"
        );
        eprintln!("{USAGE}");
        return 2;
    };
    let payload = match build_client_request(args, action) {
        Ok(p) => p,
        Err(code) => return code,
    };
    let addr = args.get_or("addr", "127.0.0.1:7199");
    let addr: std::net::SocketAddr = match addr.parse() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("bad addr {addr}: {e}");
            return 2;
        }
    };
    let (retries, max_backoff_s) = match retry_flags(args) {
        Ok(r) => r,
        Err(code) => return code,
    };
    match call_with_retries(addr, &payload, retries, max_backoff_s) {
        Ok(resp) => {
            println!("{}", resp.to_string());
            if resp.get("ok").and_then(Json::as_bool) == Some(true) {
                0
            } else {
                1
            }
        }
        Err(e) => {
            eprintln!("{e}");
            1
        }
    }
}

/// Parse the shared `--retries` / `--max-backoff-s` client flags.
fn retry_flags(args: &Args) -> Result<(u32, f64), i32> {
    let retries = args.get_usize("retries", 0) as u32;
    let max_backoff_s = args.get_f64("max-backoff-s", 30.0);
    if !max_backoff_s.is_finite() || max_backoff_s < 0.0 {
        eprintln!("--max-backoff-s must be a finite non-negative number");
        return Err(2);
    }
    Ok((retries, max_backoff_s))
}

/// One backoff sleep for the client retry loop: an exponential base
/// (50 ms, doubling per attempt) scaled by a deterministic
/// multiplicative jitter in `[0.5, 1.0)`, floored by the server's
/// `retry_after_s` hint when one was sent, capped at `max_backoff_s`.
/// `state` is an LCG register advanced once per call, so concurrent
/// clients seeded differently (e.g. by pid) desynchronize instead of
/// retrying in lockstep.
fn backoff_delay_s(attempt: u32, hint_s: Option<f64>, max_backoff_s: f64, state: &mut u64) -> f64 {
    let base = 0.05 * f64::from(1u32 << attempt.min(16));
    *state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
    let jitter = 0.5 + 0.5 * ((*state >> 33) as f64 / (1u64 << 31) as f64);
    let mut delay = base * jitter;
    if let Some(h) = hint_s {
        if h.is_finite() && h > 0.0 {
            delay = delay.max(h);
        }
    }
    delay.min(max_backoff_s)
}

/// Connect + send with bounded retries. Retryable outcomes are transport
/// failures (connect/IO errors) and `{"ok":false,"error":"overloaded"}`
/// sheds — whose `retry_after_s` hint floors the backoff. Any other
/// server answer (including semantic errors like "unknown model") is
/// final: retrying it cannot change the result. When the budget runs
/// out the last outcome is returned as-is.
fn call_with_retries(
    addr: std::net::SocketAddr,
    payload: &str,
    retries: u32,
    max_backoff_s: f64,
) -> Result<Json, String> {
    let mut state = u64::from(std::process::id()) ^ 0x9E37_79B9_7F4A_7C15;
    for attempt in 0..=retries {
        let outcome = Client::connect(addr)
            .map_err(|e| format!("connect {addr}: {e}"))
            .and_then(|mut client| {
                client.call(payload).map_err(|e| format!("request failed: {e}"))
            });
        let hint_s = match &outcome {
            Ok(resp) => {
                let shed = resp.get("ok").and_then(Json::as_bool) == Some(false)
                    && resp.get("error").and_then(Json::as_str) == Some("overloaded");
                if !shed {
                    return outcome;
                }
                resp.get("retry_after_s").and_then(Json::as_f64)
            }
            Err(_) => None,
        };
        if attempt == retries {
            return outcome;
        }
        let delay = backoff_delay_s(attempt, hint_s, max_backoff_s, &mut state);
        if delay > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(delay));
        }
    }
    unreachable!("the loop returns on its final attempt");
}

/// Strict comma-list parse for values that go on the wire: any
/// unparseable or non-finite entry is a usage error (the server-side
/// decoder is strict too — a silently shortened list would change the
/// request's meaning, e.g. a dropped path point or a shorter predict
/// row). Returns `None` when the flag is absent.
fn strict_f64_list(args: &Args, key: &str) -> Result<Option<Vec<f64>>, i32> {
    let Some(raw) = args.get(key) else { return Ok(None) };
    let mut out = Vec::new();
    for tok in raw.split(',') {
        match tok.trim().parse::<f64>() {
            Ok(v) if v.is_finite() => out.push(v),
            _ => {
                eprintln!("--{key} has a bad entry {:?} (want comma-separated numbers)", tok.trim());
                return Err(2);
            }
        }
    }
    Ok(Some(out))
}

/// Parse a `--rhs-file` batch: one right-hand side per non-empty line,
/// entries separated by commas and/or whitespace, `#` starts a comment.
/// Strict like the wire decoder: any unparseable or non-finite entry is
/// an error (a silently shortened right-hand side would solve a
/// different system than the caller named).
fn parse_rhs_file(text: &str) -> Result<Vec<Vec<f64>>, String> {
    let mut rows = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut row = Vec::new();
        for tok in line.split(|c: char| c == ',' || c.is_whitespace()).filter(|t| !t.is_empty()) {
            match tok.parse::<f64>() {
                Ok(v) if v.is_finite() => row.push(v),
                _ => {
                    return Err(format!(
                        "line {}: bad entry {tok:?} (want finite numbers)",
                        lineno + 1
                    ))
                }
            }
        }
        if row.is_empty() {
            // A non-empty line of bare separators (e.g. a stray ",")
            // must fail here with file context, not as a server-side
            // zero-length-rhs rejection.
            return Err(format!("line {}: no entries", lineno + 1));
        }
        rows.push(row);
    }
    if rows.is_empty() {
        return Err("no right-hand sides in file".into());
    }
    Ok(rows)
}

/// Assemble the JSON line for one client action.
fn build_client_request(args: &Args, action: &str) -> Result<String, i32> {
    let mut fields: Vec<(&str, Json)> = vec![("cmd", Json::from(action))];
    let model = || {
        args.get("model").and_then(|v| v.trim().parse::<u64>().ok()).ok_or_else(|| {
            eprintln!("--model <id> is required (from a register response)");
            2
        })
    };
    match action {
        "register" => {
            match workload_from(args)? {
                Workload::Synthetic { profile, n, d, seed } => {
                    fields.push(("profile", Json::from(profile)));
                    fields.push(("n", Json::from(n)));
                    fields.push(("d", Json::from(d)));
                    fields.push(("seed", Json::from(seed)));
                }
                Workload::Inline { a, b } => {
                    push_inline_payload(&mut fields, &a, &b);
                    // Inline workloads carry no seed of their own, but the
                    // model's sketch stream still needs one.
                    fields.push(("seed", Json::from(args.get_u64("seed", 0))));
                }
            }
            if let Some(kind) = args.get("sketch") {
                fields.push(("sketch", Json::from(kind)));
            }
            if let Some(name) = args.get("name") {
                fields.push(("name", Json::from(name)));
            }
        }
        "query" => {
            fields.push(("model", Json::from(model()?)));
            let rhs_batch = match args.get("rhs-file") {
                Some(path) => {
                    if args.get("nus").is_some() {
                        eprintln!("--rhs-file cannot be combined with --nus (the block batch solves at one nu)");
                        return Err(2);
                    }
                    let text = std::fs::read_to_string(path).map_err(|e| {
                        eprintln!("cannot read {path}: {e}");
                        2
                    })?;
                    let rows = parse_rhs_file(&text).map_err(|e| {
                        eprintln!("{path}: {e}");
                        2
                    })?;
                    Some(rows)
                }
                None => None,
            };
            match rhs_batch {
                Some(rows) => {
                    // Block multi-RHS query: one nu, k right-hand sides.
                    fields.push(("nu", Json::from(args.get_f64("nu", 1.0))));
                    fields.push((
                        "bs",
                        Json::Arr(
                            rows.into_iter()
                                .map(|r| Json::Arr(r.into_iter().map(Json::from).collect()))
                                .collect(),
                        ),
                    ));
                }
                None => match strict_f64_list(args, "nus")? {
                    Some(nus) if !nus.is_empty() => {
                        fields.push(("nus", Json::Arr(nus.into_iter().map(Json::from).collect())));
                    }
                    _ => fields.push(("nu", Json::from(args.get_f64("nu", 1.0)))),
                },
            }
            fields.push(("eps", Json::from(args.get_f64("eps", 1e-8))));
            if args.has("include-x") {
                fields.push(("include_x", Json::from(true)));
            }
        }
        "predict" => {
            fields.push(("model", Json::from(model()?)));
            fields.push(("nu", Json::from(args.get_f64("nu", 1.0))));
            fields.push(("eps", Json::from(args.get_f64("eps", 1e-8))));
            let Some(row) = strict_f64_list(args, "row")? else {
                eprintln!("--row v1,v2,... is required for predict");
                return Err(2);
            };
            fields.push((
                "rows",
                Json::Arr(vec![Json::Arr(row.into_iter().map(Json::from).collect())]),
            ));
        }
        "append" => {
            fields.push(("model", Json::from(model()?)));
            // The delta rows ship in the same triplet text format --data
            // loads everywhere else; d must match the registered model.
            let Some(path) = args.get("data") else {
                eprintln!("--data <triplet-file> is required for append (the delta rows)");
                return Err(2);
            };
            let text = std::fs::read_to_string(path).map_err(|e| {
                eprintln!("cannot read {path}: {e}");
                2
            })?;
            let (a, b) = effdim::data::parse_triplet_problem(&text).map_err(|e| {
                eprintln!("{path}: {e}");
                2
            })?;
            push_inline_payload(&mut fields, &Operand::Sparse(a), &b);
            match args.get("refresh") {
                None => {}
                Some(policy @ ("eager" | "lazy")) => {
                    fields.push(("refresh", Json::from(policy)));
                }
                Some(other) => {
                    eprintln!("--refresh must be eager or lazy, got {other:?}");
                    return Err(2);
                }
            }
        }
        "evict" => {
            fields.push(("model", Json::from(model()?)));
            if args.has("purge") {
                // Without --purge an evict on a durable server spills to
                // disk (reload-on-demand); --purge deletes the disk state.
                fields.push(("purge", Json::from(true)));
            }
        }
        "snapshot" => {
            // Bare snapshot flushes every model; --model narrows to one.
            if args.get("model").is_some() {
                fields.push(("model", Json::from(model()?)));
            }
        }
        "models" => {}
        _ => unreachable!("validated above"),
    }
    Ok(Json::obj(fields).to_string())
}

/// Re-encode a loaded triplet problem as the inline CSR payload the wire
/// protocol accepts (shared by `client register --data` and
/// `client append --data`).
fn push_inline_payload(fields: &mut Vec<(&str, Json)>, a: &Operand, b: &[f64]) {
    let c = a.as_csr().expect("--data loads CSR");
    let mut trips = Vec::with_capacity(c.nnz());
    for i in 0..c.rows() {
        let (cols, vals) = c.row(i);
        for (&j, &v) in cols.iter().zip(vals) {
            trips.push(Json::Arr(vec![
                Json::from(i),
                Json::from(j as usize),
                Json::from(v),
            ]));
        }
    }
    fields.push(("rows", Json::from(a.rows())));
    fields.push(("cols", Json::from(a.cols())));
    fields.push(("triplets", Json::Arr(trips)));
    fields.push(("b", Json::Arr(b.iter().map(|&v| Json::from(v)).collect())));
}

fn cmd_request(args: &Args) -> i32 {
    let addr = args.get_or("addr", "127.0.0.1:7199");
    let payload = args.get_or("json", r#"{"cmd":"ping"}"#);
    let addr: std::net::SocketAddr = match addr.parse() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("bad addr {addr}: {e}");
            return 2;
        }
    };
    let (retries, max_backoff_s) = match retry_flags(args) {
        Ok(r) => r,
        Err(code) => return code,
    };
    match call_with_retries(addr, payload, retries, max_backoff_s) {
        Ok(resp) => {
            println!("{}", resp.to_string());
            0
        }
        Err(e) => {
            eprintln!("{e}");
            1
        }
    }
}

fn cmd_info(args: &Args) -> i32 {
    let workload = match workload_from(args) {
        Ok(w) => w,
        Err(code) => return code,
    };
    let (a, _b) = match workload.materialize() {
        Ok(x) => x,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let nu = args.get_f64("nu", 1.0);
    // Exact spectrum via SVD — densifies CSR operands (info is an
    // offline diagnostic; the solve path never does this).
    let sigma = effdim::linalg::svd::singular_values(&a.dense());
    // User-provided nu: validate instead of printing NaN columns.
    let d_e = match effdim::theory::try_effective_dimension_from_spectrum(&sigma, nu) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    println!("n = {}, d = {}, nnz = {} (density {:.4})", a.rows(), a.cols(), a.nnz(), a.density());
    println!("sigma_1 = {:.4e}, sigma_d = {:.4e}", sigma[0], sigma.last().unwrap());
    println!("nu = {nu:.3e}");
    println!("effective dimension d_e = {d_e:.2}  (d_e/d = {:.3})", d_e / a.cols() as f64);
    println!(
        "condition number of [A; nu I] = {:.3e}",
        ((sigma[0] * sigma[0] + nu * nu) / (sigma.last().unwrap().powi(2) + nu * nu)).sqrt()
    );
    0
}

/// Print the solver registry — the same list the coordinator serves for
/// `{"cmd":"solvers"}` and the agreement tests iterate.
fn cmd_solvers() -> i32 {
    println!("{:<28} {:>5} {:>7}  description", "spec", "warm", "random");
    for spec in effdim::solvers::registry() {
        let solver = spec.build(0);
        println!(
            "{:<28} {:>5} {:>7}  {}",
            spec.to_string(),
            if solver.supports_warm_start() { "yes" } else { "no" },
            if solver.is_randomized() { "yes" } else { "no" },
            spec.describe()
        );
    }
    println!(
        "\nspec grammar: name[@key=value,...]  (m=<usize> for ihs, rho=<f64> for pcg, threads=<usize> for any randomized solver)"
    );
    0
}

#[cfg(test)]
mod tests {
    use super::*;
    use effdim::coordinator::server::ServerConfig;
    use std::sync::atomic::Ordering;
    use std::time::Duration;

    #[test]
    fn backoff_is_bounded_jittered_and_honors_the_hint() {
        let mut state = 42u64;
        // No hint: each delay lands in [0.5, 1.0) x 50ms x 2^attempt.
        for attempt in 0..6 {
            let base = 0.05 * f64::from(1u32 << attempt);
            let d = backoff_delay_s(attempt, None, 30.0, &mut state);
            assert!(d >= 0.5 * base && d < base, "attempt {attempt}: {d}");
        }
        // The server hint floors the delay...
        assert!(backoff_delay_s(0, Some(2.5), 30.0, &mut state) >= 2.5);
        // ...the cap wins over the hint, and bad hints are ignored.
        assert_eq!(backoff_delay_s(0, Some(10.0), 0.2, &mut state), 0.2);
        assert!(backoff_delay_s(0, Some(f64::NAN), 30.0, &mut state) < 0.05);
        assert!(backoff_delay_s(0, Some(f64::INFINITY), 30.0, &mut state) < 0.05);
        // Deep attempts stay capped instead of overflowing the shift.
        assert!(backoff_delay_s(63, None, 0.75, &mut state) <= 0.75);
    }

    #[test]
    fn jitter_stream_desynchronizes_but_is_deterministic_per_seed() {
        let (mut a, mut b, mut c) = (7u64, 7u64, 8u64);
        let da = backoff_delay_s(3, None, 30.0, &mut a);
        let db = backoff_delay_s(3, None, 30.0, &mut b);
        let dc = backoff_delay_s(3, None, 30.0, &mut c);
        assert_eq!(da, db, "same seed, same delay");
        assert_ne!(da, dc, "different seeds desynchronize");
    }

    #[test]
    fn retries_ride_out_an_overload_shed() {
        let server = effdim::coordinator::server::Server::bind_with_config(
            "127.0.0.1:0",
            ServerConfig { max_conns: 1, ..ServerConfig::default() },
        )
        .unwrap();
        let addr = server.local_addr();
        let stop = server.stop_handle();
        let handle = std::thread::spawn(move || server.run());
        // Occupy the only connection slot.
        let mut hog = Client::connect(addr).unwrap();
        let pong = hog.call(r#"{"cmd":"ping"}"#).unwrap();
        assert_eq!(pong.get("ok").and_then(Json::as_bool), Some(true), "{pong:?}");
        // Fail-fast (--retries 0) surfaces the shed as the final answer.
        let shed = call_with_retries(addr, r#"{"cmd":"ping"}"#, 0, 0.05).unwrap();
        assert_eq!(shed.get("error").and_then(Json::as_str), Some("overloaded"), "{shed:?}");
        // Release the slot shortly; a retrying client rides the shed out.
        let release = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(300));
            drop(hog);
        });
        let resp = call_with_retries(addr, r#"{"cmd":"ping"}"#, 60, 0.25).unwrap();
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{resp:?}");
        release.join().unwrap();
        stop.store(true, Ordering::SeqCst);
        handle.join().unwrap();
    }
}
