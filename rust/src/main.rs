//! `effdim` CLI — the L3 entrypoint.
//!
//! ```text
//! effdim solve   --profile mnist-like --n 1024 --d 128 --nu 1.0 \
//!                --solver adaptive-srht --eps 1e-8 --seed 7
//! effdim path    --profile exp --n 1024 --d 128 --nus 1e2,1e1,1,0.1 \
//!                --solver adaptive-srht --eps 1e-8
//! effdim serve   --addr 127.0.0.1:7199 --workers 2
//! effdim request --addr 127.0.0.1:7199 --json '{"cmd":"ping"}'
//! effdim info    --profile cifar-like --n 1024 --d 128 --nu 1.0
//! effdim solvers
//! ```
//!
//! Every `--solver` value is a spec string parsed by
//! [`SolverSpec`](effdim::solvers::SolverSpec) with the grammar
//!
//! ```text
//! spec      := name [ "@" param ( "," param )* ]
//! name      := "direct" | "cg" | "pcg-<kind>" | "ihs-<kind>"
//!            | "polyak-ihs-<kind>" | "adaptive-<kind>"
//!            | "adaptive-gd-<kind>" | "dual-adaptive-<kind>"
//! kind      := "gaussian" | "srht" | "sparse"
//! param     := "m=<usize>"       (ihs sketch size)
//!            | "rho=<f64>"       (pcg preconditioner aspect ratio)
//!            | "threads=<usize>" (pin the parallel dense kernels)
//! ```
//!
//! e.g. `cg`, `pcg-gaussian`, `adaptive-srht`, `ihs-sparse@m=256`,
//! `pcg-srht@rho=0.25`, `adaptive-srht@threads=8`. `effdim solvers`
//! prints the full registry. `--threads k` (or `PALLAS_THREADS`) pins
//! the kernels for the whole command instead of one solver.

use effdim::coordinator::job::{self, JobSpec, Workload};
use effdim::coordinator::server::{Client, Server};
use effdim::data::synthetic;
use effdim::solvers::path::run_path;
use effdim::solvers::{Solver as _, SolverSpec};
use effdim::util::cli::Args;

const USAGE: &str = "usage: effdim <solve|path|serve|request|info|solvers> [--flags]
  --solver takes a spec string: name[@key=value,...]
    names : direct | cg | pcg-<kind> | ihs-<kind> | polyak-ihs-<kind>
            | adaptive-<kind> | adaptive-gd-<kind> | dual-adaptive-<kind>
    kinds : gaussian | srht | sparse
    params: m=<usize> (ihs), rho=<f64> (pcg), threads=<usize> (any randomized)
    bare aliases 'adaptive', 'adaptive-gd', 'dual' default to gaussian;
    'pcg' defaults to srht — name the kind explicitly in scripts
  --threads k pins the parallel dense kernels for the whole command
    (default: PALLAS_THREADS env var, else all hardware threads)
  run `effdim solvers` for the registry; see rust/src/main.rs docs for flags";

fn main() {
    let args = Args::from_env();
    let code = match args.subcommand.as_deref() {
        Some("solve") => cmd_solve(&args),
        Some("path") => cmd_path(&args),
        Some("serve") => cmd_serve(&args),
        Some("request") => cmd_request(&args),
        Some("info") => cmd_info(&args),
        Some("solvers") => cmd_solvers(),
        _ => {
            eprintln!("{USAGE}");
            2
        }
    };
    std::process::exit(code);
}

fn workload_from(args: &Args) -> Workload {
    Workload::Synthetic {
        profile: args.get_or("profile", "exp").to_string(),
        n: args.get_usize("n", 1024),
        d: args.get_usize("d", 128),
        seed: args.get_u64("seed", 1),
    }
}

fn parse_solver(args: &Args, default: &str) -> Result<SolverSpec, i32> {
    match args.get_or("solver", default).parse() {
        Ok(spec) => Ok(spec),
        Err(e) => {
            eprintln!("{e}");
            eprintln!("{USAGE}");
            Err(2)
        }
    }
}

/// `--threads k` with the same validation as the wire protocol and the
/// `@threads=k` spec param: present means a positive integer, anything
/// else is a usage error (exit code via `Err`).
fn threads_flag(args: &Args) -> Result<Option<usize>, i32> {
    match args.get("threads") {
        None => Ok(None),
        Some(v) => match v.trim().parse::<usize>() {
            Ok(k) if k >= 1 => Ok(Some(k)),
            _ => {
                eprintln!("--threads must be a positive integer, got {v:?}");
                Err(2)
            }
        },
    }
}

fn cmd_solve(args: &Args) -> i32 {
    let spec = JobSpec {
        workload: workload_from(args),
        nu: args.get_f64("nu", 1.0),
        solver: match parse_solver(args, "adaptive-srht") {
            Ok(s) => s,
            Err(code) => return code,
        },
        eps: args.get_f64("eps", 1e-8),
        seed: args.get_u64("seed", 1),
        path_nus: args.get_f64_list("path-nus", &[]),
        threads: match threads_flag(args) {
            Ok(t) => t,
            Err(code) => return code,
        },
    };
    match job::execute(&spec) {
        Ok(outcome) => {
            println!("{}", outcome.to_json(args.has("include-x")).to_string());
            if outcome.report.converged {
                0
            } else {
                1
            }
        }
        Err(e) => {
            eprintln!("solve failed: {e}");
            1
        }
    }
}

fn cmd_path(args: &Args) -> i32 {
    let n = args.get_usize("n", 1024);
    let d = args.get_usize("d", 128);
    let seed = args.get_u64("seed", 1);
    let profile = args.get_or("profile", "exp");
    let ds = match profile {
        "exp" => synthetic::exponential_decay(n, d, seed),
        "poly" => synthetic::polynomial_decay(n, d, seed),
        "mnist-like" => synthetic::mnist_like(n, d, seed),
        "cifar-like" => synthetic::cifar_like(n, d, seed),
        other => {
            eprintln!("unknown profile {other}");
            return 2;
        }
    };
    let nus = args.get_f64_list("nus", &[100.0, 10.0, 1.0, 0.1, 0.01]);
    let spec = match parse_solver(args, "adaptive-srht") {
        Ok(s) => s,
        Err(code) => return code,
    };
    let eps = args.get_f64("eps", 1e-8);
    let res = match threads_flag(args) {
        Ok(Some(k)) => effdim::linalg::threads::with_threads(k, || {
            run_path(&ds.a, &ds.b, &nus, eps, &spec, seed)
        }),
        Ok(None) => run_path(&ds.a, &ds.b, &nus, eps, &spec, seed),
        Err(code) => return code,
    };
    println!("solver: {}", res.solver);
    println!(
        "{:<12} {:>10} {:>12} {:>10} {:>8} {:>6}",
        "nu", "d_e", "cum_time_s", "iters", "m", "conv"
    );
    for p in &res.points {
        println!(
            "{:<12.3e} {:>10.1} {:>12.4} {:>10} {:>8} {:>6}",
            p.nu,
            ds.effective_dimension(p.nu),
            p.cumulative_time_s,
            p.report.iterations,
            p.report.peak_m,
            p.report.converged
        );
    }
    if res.points.iter().all(|p| p.report.converged) {
        0
    } else {
        1
    }
}

fn cmd_serve(args: &Args) -> i32 {
    let addr = args.get_or("addr", "127.0.0.1:7199");
    let workers = args.get_usize("workers", 2);
    match Server::bind(addr, workers) {
        Ok(server) => {
            println!("effdim coordinator listening on {}", server.local_addr());
            server.run();
            println!("coordinator stopped");
            0
        }
        Err(e) => {
            eprintln!("bind {addr}: {e}");
            1
        }
    }
}

fn cmd_request(args: &Args) -> i32 {
    let addr = args.get_or("addr", "127.0.0.1:7199");
    let payload = args.get_or("json", r#"{"cmd":"ping"}"#);
    let addr: std::net::SocketAddr = match addr.parse() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("bad addr {addr}: {e}");
            return 2;
        }
    };
    match Client::connect(addr) {
        Ok(mut client) => match client.call(payload) {
            Ok(resp) => {
                println!("{}", resp.to_string());
                0
            }
            Err(e) => {
                eprintln!("request failed: {e}");
                1
            }
        },
        Err(e) => {
            eprintln!("connect {addr}: {e}");
            1
        }
    }
}

fn cmd_info(args: &Args) -> i32 {
    let workload = workload_from(args);
    let (a, _b) = match workload.materialize() {
        Ok(x) => x,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let nu = args.get_f64("nu", 1.0);
    let sigma = effdim::linalg::svd::singular_values(&a);
    let d_e = effdim::theory::effective_dimension_from_spectrum(&sigma, nu);
    println!("n = {}, d = {}", a.rows(), a.cols());
    println!("sigma_1 = {:.4e}, sigma_d = {:.4e}", sigma[0], sigma.last().unwrap());
    println!("nu = {nu:.3e}");
    println!("effective dimension d_e = {d_e:.2}  (d_e/d = {:.3})", d_e / a.cols() as f64);
    println!(
        "condition number of [A; nu I] = {:.3e}",
        ((sigma[0] * sigma[0] + nu * nu) / (sigma.last().unwrap().powi(2) + nu * nu)).sqrt()
    );
    0
}

/// Print the solver registry — the same list the coordinator serves for
/// `{"cmd":"solvers"}` and the agreement tests iterate.
fn cmd_solvers() -> i32 {
    println!("{:<28} {:>5} {:>7}  description", "spec", "warm", "random");
    for spec in effdim::solvers::registry() {
        let solver = spec.build(0);
        println!(
            "{:<28} {:>5} {:>7}  {}",
            spec.to_string(),
            if solver.supports_warm_start() { "yes" } else { "no" },
            if solver.is_randomized() { "yes" } else { "no" },
            spec.describe()
        );
    }
    println!(
        "\nspec grammar: name[@key=value,...]  (m=<usize> for ihs, rho=<f64> for pcg, threads=<usize> for any randomized solver)"
    );
    0
}
