//! One-sided Jacobi SVD.
//!
//! Singular values drive everything in this paper: the effective dimension
//! `d_e = sum sigma_i^2/(sigma_i^2 + nu^2)`, the diagonal matrix `D`, the
//! condition number of the augmented matrix, and the eigenvalues of the
//! deviation matrix `C_S` checked against Theorems 3–4. One-sided Jacobi is
//! slow (O(n^2 m) per sweep) but simple and accurate to near machine
//! precision, which is exactly what an oracle needs. It is never on the
//! solve hot path.

use super::matrix::Matrix;
use super::{dot, norm2};

/// Thin SVD result: `a = u * diag(s) * vt` with `u: m x k`, `vt: k x n`,
/// `k = min(m, n)`, singular values descending.
#[derive(Clone, Debug)]
pub struct Svd {
    /// Left singular vectors, `m x k`.
    pub u: Matrix,
    /// Singular values, descending.
    pub s: Vec<f64>,
    /// Right singular vectors transposed, `k x n`.
    pub vt: Matrix,
}

/// Compute the thin SVD of `a` by one-sided Jacobi rotations.
pub fn svd(a: &Matrix) -> Svd {
    if a.rows() >= a.cols() {
        svd_tall(a)
    } else {
        // SVD of the transpose and swap factors.
        let t = svd_tall(&a.transpose());
        Svd { u: t.vt.transpose(), s: t.s, vt: t.u.transpose() }
    }
}

/// Singular values only (descending). Cheaper in memory (V not accumulated
/// into an explicit U), same rotations.
pub fn singular_values(a: &Matrix) -> Vec<f64> {
    let work = if a.rows() >= a.cols() { a.clone() } else { a.transpose() };
    let (w, _v) = jacobi_sweeps(work, false);
    let n = w.cols();
    let mut s: Vec<f64> = (0..n)
        .map(|j| {
            let col: Vec<f64> = (0..w.rows()).map(|i| w.get(i, j)).collect();
            norm2(&col)
        })
        .collect();
    s.sort_by(|x, y| y.partial_cmp(x).unwrap());
    s
}

fn svd_tall(a: &Matrix) -> Svd {
    let (m, n) = (a.rows(), a.cols());
    let (w, v) = jacobi_sweeps(a.clone(), true);
    let v = v.expect("V accumulated");
    // Column norms are the singular values; normalize columns into U.
    let mut entries: Vec<(f64, usize)> = (0..n)
        .map(|j| {
            let mut s = 0.0;
            for i in 0..m {
                s += w.get(i, j) * w.get(i, j);
            }
            (s.sqrt(), j)
        })
        .collect();
    entries.sort_by(|x, y| y.0.partial_cmp(&x.0).unwrap());

    let mut u = Matrix::zeros(m, n);
    let mut vt = Matrix::zeros(n, n);
    let mut s = Vec::with_capacity(n);
    for (out_j, &(sig, j)) in entries.iter().enumerate() {
        s.push(sig);
        let inv = if sig > 0.0 { 1.0 / sig } else { 0.0 };
        for i in 0..m {
            u.set(i, out_j, w.get(i, j) * inv);
        }
        for i in 0..n {
            vt.set(out_j, i, v.get(i, j));
        }
    }
    Svd { u, s, vt }
}

/// Run Jacobi sweeps on the columns of `w` until off-diagonal Gram entries
/// are negligible. Returns the rotated matrix and (optionally) the
/// accumulated right-rotation matrix V.
fn jacobi_sweeps(mut w: Matrix, want_v: bool) -> (Matrix, Option<Matrix>) {
    let (m, n) = (w.rows(), w.cols());
    let mut v = if want_v { Some(Matrix::eye(n)) } else { None };
    // Column-major scratch: one-sided Jacobi touches column pairs, so keep
    // the working matrix transposed (rows = original columns) for locality.
    let mut wt = w.transpose();
    let eps = 1e-14;
    let max_sweeps = 60;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in p + 1..n {
                let (alpha, beta, gamma) = {
                    let cp = wt.row(p);
                    let cq = wt.row(q);
                    (dot(cp, cp), dot(cq, cq), dot(cp, cq))
                };
                let denom = (alpha * beta).sqrt();
                if denom == 0.0 {
                    continue;
                }
                let ratio = gamma.abs() / denom;
                off = off.max(ratio);
                if ratio <= eps {
                    continue;
                }
                // Jacobi rotation zeroing the (p,q) Gram entry.
                let zeta = (beta - alpha) / (2.0 * gamma);
                let t = zeta.signum() / (zeta.abs() + (1.0 + zeta * zeta).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                // Rotate the column pair (rows p, q of wt).
                rotate_rows(&mut wt, p, q, c, s, m);
                if let Some(vm) = v.as_mut() {
                    // V columns rotate identically; V is n x n, stored
                    // row-major, rotate columns p,q.
                    for i in 0..n {
                        let vip = vm.get(i, p);
                        let viq = vm.get(i, q);
                        vm.set(i, p, c * vip - s * viq);
                        vm.set(i, q, s * vip + c * viq);
                    }
                }
            }
        }
        if off <= eps {
            break;
        }
    }
    w = wt.transpose();
    (w, v)
}

#[inline]
fn rotate_rows(wt: &mut Matrix, p: usize, q: usize, c: f64, s: f64, len: usize) {
    // Rows p and q are disjoint slices of the backing vector.
    let cols = wt.cols();
    debug_assert_eq!(cols, len);
    let (lo, hi) = if p < q { (p, q) } else { (q, p) };
    let data = wt.as_mut_slice();
    let (head, tail) = data.split_at_mut(hi * cols);
    let row_lo = &mut head[lo * cols..lo * cols + cols];
    let row_hi = &mut tail[..cols];
    if p < q {
        for i in 0..len {
            let wp = row_lo[i];
            let wq = row_hi[i];
            row_lo[i] = c * wp - s * wq;
            row_hi[i] = s * wp + c * wq;
        }
    } else {
        for i in 0..len {
            let wp = row_hi[i];
            let wq = row_lo[i];
            row_hi[i] = c * wp - s * wq;
            row_lo[i] = s * wp + c * wq;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn test_mat(m: usize, n: usize, seed: u64) -> Matrix {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        Matrix::from_fn(m, n, |_, _| rng.next_gaussian())
    }

    #[test]
    fn reconstruction_tall() {
        let a = test_mat(14, 6, 1);
        let f = svd(&a);
        let rec = f.u.matmul(&Matrix::diag(&f.s)).matmul(&f.vt);
        assert!(rec.max_abs_diff(&a) < 1e-9);
    }

    #[test]
    fn reconstruction_wide() {
        let a = test_mat(5, 11, 2);
        let f = svd(&a);
        let rec = f.u.matmul(&Matrix::diag(&f.s)).matmul(&f.vt);
        assert!(rec.max_abs_diff(&a) < 1e-9);
    }

    #[test]
    fn factors_orthonormal() {
        let a = test_mat(12, 5, 3);
        let f = svd(&a);
        assert!(f.u.gram().max_abs_diff(&Matrix::eye(5)) < 1e-9);
        assert!(f.vt.gram_outer().max_abs_diff(&Matrix::eye(5)) < 1e-9);
    }

    #[test]
    fn values_descending_nonnegative() {
        let a = test_mat(20, 8, 4);
        let s = singular_values(&a);
        for w in s.windows(2) {
            assert!(w[0] >= w[1] - 1e-12);
        }
        assert!(s.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn known_diagonal_spectrum() {
        let a = Matrix::diag(&[3.0, 1.0, 2.0]);
        let s = singular_values(&a);
        assert!((s[0] - 3.0).abs() < 1e-12);
        assert!((s[1] - 2.0).abs() < 1e-12);
        assert!((s[2] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn frobenius_identity() {
        // sum sigma_i^2 == ||A||_F^2
        let a = test_mat(17, 9, 5);
        let s = singular_values(&a);
        let sum_sq: f64 = s.iter().map(|x| x * x).sum();
        let fro2 = a.fro_norm().powi(2);
        assert!((sum_sq - fro2).abs() < 1e-8 * fro2);
    }

    #[test]
    fn rank_deficient_has_zero_singular_value() {
        // Two identical columns.
        let a = Matrix::from_fn(6, 3, |i, j| if j == 2 { i as f64 } else { (i + j) as f64 });
        // col2 = col0 + something? Make exact dependence: col1 = 2*col0.
        let a = {
            let mut m = a;
            for i in 0..6 {
                let v = m.get(i, 0);
                m.set(i, 1, 2.0 * v);
            }
            m
        };
        let s = singular_values(&a);
        assert!(s[2] < 1e-10, "smallest singular value should vanish, got {}", s[2]);
    }
}
