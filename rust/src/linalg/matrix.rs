//! Row-major dense matrix with cache-blocked, row-parallel multiplication.
//!
//! The hot operations in this repository are `S * A` (sketching),
//! `A^T (A x - b)` (ridge gradient) and small Gram products
//! `(SA)(SA)^T`; all of them reduce to the GEMM / GEMV kernels here.
//! The GEMM and Gram kernels split their output rows across scoped
//! threads when the operation is large enough to amortize the spawns;
//! the thread count comes from [`super::threads`] (solver `@threads=k`
//! override, `PALLAS_THREADS`, or the hardware default).

use super::{axpy, dot, threads};

/// Dense row-major `rows x cols` matrix of `f64`.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

/// GEMM blocking parameters, tuned for ~32 KiB L1 / 1 MiB L2 caches.
/// `MC x KC` panel of the packed left operand plus a `KC x NC` slab of the
/// right operand stay cache-resident during the inner loops.
const MC: usize = 64;
const KC: usize = 256;
const NC: usize = 512;

impl Matrix {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix of size `n`.
    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// Build from a row-major data vector.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "data length mismatch");
        Self { rows, cols, data }
    }

    /// Build from a function of `(row, col)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Self { rows, cols, data }
    }

    /// Diagonal matrix from a slice.
    pub fn diag(d: &[f64]) -> Self {
        let n = d.len();
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = d[i];
        }
        m
    }

    /// Row count.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Column count.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Element `(i, j)` (bounds-checked in debug builds only).
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    /// Set element `(i, j)` (bounds-checked in debug builds only).
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    /// Immutable view of row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable view of row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Raw row-major data.
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Raw mutable row-major data.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Explicit transpose.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness.
        const B: usize = 32;
        for i0 in (0..self.rows).step_by(B) {
            for j0 in (0..self.cols).step_by(B) {
                for i in i0..(i0 + B).min(self.rows) {
                    for j in j0..(j0 + B).min(self.cols) {
                        t.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        t
    }

    /// `y = self * x` (GEMV) into a caller buffer — the allocation-free
    /// primitive behind the iterative solvers' workspace loops.
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols, "matvec dimension mismatch");
        assert_eq!(y.len(), self.rows, "matvec output length mismatch");
        for i in 0..self.rows {
            y[i] = dot(self.row(i), x);
        }
    }

    /// `y = self * x` (GEMV). Row-major layout makes this a stream of dots.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.rows];
        self.matvec_into(x, &mut y);
        y
    }

    /// `y += self^T * x` without forming the transpose (axpy over rows).
    pub fn matvec_t_add(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.rows, "matvec_t dimension mismatch");
        assert_eq!(y.len(), self.cols, "matvec_t output length mismatch");
        for i in 0..self.rows {
            axpy(x[i], self.row(i), y);
        }
    }

    /// `y = self^T * x` into a caller buffer.
    pub fn matvec_t_into(&self, x: &[f64], y: &mut [f64]) {
        y.fill(0.0);
        self.matvec_t_add(x, y);
    }

    /// `y = self^T * x` without forming the transpose (axpy over rows).
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.cols];
        self.matvec_t_add(x, &mut y);
        y
    }

    /// Blocked GEMM: `C = self * other`. Output rows are split across
    /// scoped threads for large products; every element is computed with
    /// the same operation order as the serial kernel, so the result is
    /// bitwise identical at any thread count.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul dimension mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut c = Matrix::zeros(m, n);
        if m == 0 || n == 0 {
            return c;
        }
        let flops = 2.0 * m as f64 * k as f64 * n as f64;
        let t = if threads::worth_parallelizing(flops) { threads::current().min(m) } else { 1 };
        if t <= 1 {
            self.matmul_rows_into(other, 0, &mut c.data);
            return c;
        }
        // Contiguous row chunks: GEMM work is uniform per row.
        let chunk_rows = (m + t - 1) / t;
        let jobs: Vec<(usize, &mut [f64])> = c
            .data
            .chunks_mut(chunk_rows * n)
            .enumerate()
            .map(|(i, rows)| (i * chunk_rows, rows))
            .collect();
        threads::run_jobs(t, jobs, |(r0, rows)| self.matmul_rows_into(other, r0, rows));
        c
    }

    /// Serial blocked-GEMM kernel for one output row chunk: writes
    /// `self[r0.., :] * other` into `c_rows` (`c_rows.len() / other.cols()`
    /// rows, row-major, zero-initialized).
    fn matmul_rows_into(&self, other: &Matrix, r0: usize, c_rows: &mut [f64]) {
        let (k, n) = (self.cols, other.cols);
        let m = c_rows.len() / n;
        // Packed panel of A (MC x KC), contiguous by row.
        let mut apack = vec![0.0; MC * KC];
        for jc in (0..n).step_by(NC) {
            let nb = NC.min(n - jc);
            for pc in (0..k).step_by(KC) {
                let kb = KC.min(k - pc);
                for ic in (0..m).step_by(MC) {
                    let mb = MC.min(m - ic);
                    // Pack A[r0+ic..r0+ic+mb, pc..pc+kb].
                    for i in 0..mb {
                        let base = (r0 + ic + i) * k + pc;
                        apack[i * kb..(i + 1) * kb].copy_from_slice(&self.data[base..base + kb]);
                    }
                    // Micro loops: for each packed row of A, stream rows of
                    // B. Eight rank-1 updates are fused per pass so each
                    // C-row element is loaded/stored once per 16 flops
                    // instead of once per 2 (the op would otherwise be
                    // store-bound; see EXPERIMENTS.md §Perf).
                    for i in 0..mb {
                        let arow = &apack[i * kb..(i + 1) * kb];
                        let crow = &mut c_rows[(ic + i) * n + jc..(ic + i) * n + jc + nb];
                        let kq = kb / 8 * 8;
                        let mut p = 0;
                        while p < kq {
                            let (a0, a1, a2, a3) = (arow[p], arow[p + 1], arow[p + 2], arow[p + 3]);
                            let (a4, a5, a6, a7) =
                                (arow[p + 4], arow[p + 5], arow[p + 6], arow[p + 7]);
                            let base = (pc + p) * n + jc;
                            let b0 = &other.data[base..base + nb];
                            let b1 = &other.data[base + n..base + n + nb];
                            let b2 = &other.data[base + 2 * n..base + 2 * n + nb];
                            let b3 = &other.data[base + 3 * n..base + 3 * n + nb];
                            let b4 = &other.data[base + 4 * n..base + 4 * n + nb];
                            let b5 = &other.data[base + 5 * n..base + 5 * n + nb];
                            let b6 = &other.data[base + 6 * n..base + 6 * n + nb];
                            let b7 = &other.data[base + 7 * n..base + 7 * n + nb];
                            for j in 0..nb {
                                let s0 = a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
                                let s1 = a4 * b4[j] + a5 * b5[j] + a6 * b6[j] + a7 * b7[j];
                                crow[j] += s0 + s1;
                            }
                            p += 8;
                        }
                        for (off, &aip) in arow[kq..].iter().enumerate() {
                            if aip == 0.0 {
                                continue;
                            }
                            let base = (pc + kq + off) * n + jc;
                            axpy(aip, &other.data[base..base + nb], crow);
                        }
                    }
                }
            }
        }
    }

    /// `C = self * other^T` without materializing the transpose: both
    /// operands stream row-major and entry `(i, j)` is a single row dot.
    /// Used by the Woodbury growth path for the `Δm x m` cross-Gram.
    pub fn matmul_nt(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "matmul_nt inner dimension mismatch");
        let (p, q, k) = (self.rows, other.rows, self.cols);
        let mut c = Matrix::zeros(p, q);
        if p == 0 || q == 0 {
            return c;
        }
        let flops = 2.0 * p as f64 * q as f64 * k as f64;
        let t = if threads::worth_parallelizing(flops) { threads::current().min(p) } else { 1 };
        let chunk_rows = (p + t - 1) / t;
        let jobs: Vec<(usize, &mut [f64])> = c
            .data
            .chunks_mut(chunk_rows * q)
            .enumerate()
            .map(|(i, rows)| (i * chunk_rows, rows))
            .collect();
        threads::run_jobs(t, jobs, |(r0, rows)| {
            for i in 0..rows.len() / q {
                let ri = self.row(r0 + i);
                for j in 0..q {
                    rows[i * q + j] = dot(ri, other.row(j));
                }
            }
        });
        c
    }

    /// `C = self^T * other` without materializing the transpose: `C` is
    /// accumulated as a sum of per-row outer products (`c += a_i ⊗ b_i`),
    /// streaming both operands row-major. This is the dense arm of the
    /// block kernels (`Operand::matmul_t` over an `n x k` right-hand-side
    /// block; the Woodbury block apply's `(S̃A)^T W` term). Above the
    /// parallel threshold the input rows split into
    /// [`threads::REDUCE_PARTS`] *fixed* chunks whose partial products
    /// reduce in chunk order — the summation tree is a function of the
    /// shapes alone, so the result is bitwise identical at any thread
    /// count (same policy as [`Matrix::gram`]).
    pub fn matmul_tn(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows, "matmul_tn row mismatch");
        let (n, d, k) = (self.rows, self.cols, other.cols);
        let mut c = Matrix::zeros(d, k);
        if n == 0 || d == 0 || k == 0 {
            return c;
        }
        let flops = 2.0 * n as f64 * d as f64 * k as f64;
        let parts = threads::REDUCE_PARTS;
        if !threads::worth_parallelizing(flops) || n < 2 * parts {
            self.tn_rows_into(other, 0, n, &mut c.data);
        } else {
            let chunk = (n + parts - 1) / parts;
            let mut partials = vec![0.0; parts * d * k];
            let jobs: Vec<(usize, &mut [f64])> =
                partials.chunks_mut(d * k).enumerate().collect();
            let t = threads::current().min(parts);
            threads::run_jobs(t, jobs, |(p, buf)| {
                let r0 = (p * chunk).min(n);
                let r1 = (r0 + chunk).min(n);
                self.tn_rows_into(other, r0, r1, buf);
            });
            for p in 0..parts {
                axpy(1.0, &partials[p * d * k..(p + 1) * d * k], &mut c.data);
            }
        }
        c
    }

    /// Accumulate `self[r0..r1, :]^T other[r0..r1, :]` into `c` (`d x k`,
    /// row-major): one length-`k` axpy per element of `self` — no
    /// zero-skip, so the kernel stays exactly equivalent to
    /// `transpose().matmul()` even on non-finite operands.
    fn tn_rows_into(&self, other: &Matrix, r0: usize, r1: usize, c: &mut [f64]) {
        let k = other.cols;
        for i in r0..r1 {
            let a_row = self.row(i);
            let b_row = other.row(i);
            for (j, &aij) in a_row.iter().enumerate() {
                axpy(aij, b_row, &mut c[j * k..(j + 1) * k]);
            }
        }
    }

    /// `C = self^T * self` (Gram matrix), exploiting symmetry: only the
    /// upper triangle is computed, then mirrored. Above the parallel
    /// threshold the rows always split into [`threads::REDUCE_PARTS`]
    /// *fixed* chunks whose partial Grams are reduced in chunk order: the
    /// summation tree is a function of the matrix shape alone, so the
    /// result is bitwise identical at any thread count (the chunks are
    /// merely *executed* by however many threads are configured).
    pub fn gram(&self) -> Matrix {
        let (n, d) = (self.rows, self.cols);
        let mut g = Matrix::zeros(d, d);
        if n == 0 || d == 0 {
            return g;
        }
        let flops = n as f64 * d as f64 * d as f64;
        let parts = threads::REDUCE_PARTS;
        if !threads::worth_parallelizing(flops) || n < 2 * parts {
            self.gram_rows_upper(0, n, &mut g.data);
        } else {
            let chunk = (n + parts - 1) / parts;
            let mut partials = vec![0.0; parts * d * d];
            let jobs: Vec<(usize, &mut [f64])> =
                partials.chunks_mut(d * d).enumerate().collect();
            let t = threads::current().min(parts);
            threads::run_jobs(t, jobs, |(p, buf)| {
                let r0 = (p * chunk).min(n);
                let r1 = (r0 + chunk).min(n);
                self.gram_rows_upper(r0, r1, buf);
            });
            for p in 0..parts {
                axpy(1.0, &partials[p * d * d..(p + 1) * d * d], &mut g.data);
            }
        }
        for a in 0..d {
            for b in 0..a {
                g.data[a * d + b] = g.data[b * d + a];
            }
        }
        g
    }

    /// Accumulate the upper triangle of `self[r0..r1, :]^T self[r0..r1, :]`
    /// into `g` (`d x d`, row-major).
    fn gram_rows_upper(&self, r0: usize, r1: usize, g: &mut [f64]) {
        let d = self.cols;
        // Accumulate rank-1 updates row by row (sequential access to A).
        for i in r0..r1 {
            let r = &self.data[i * d..(i + 1) * d];
            for a in 0..d {
                let ra = r[a];
                if ra == 0.0 {
                    continue;
                }
                let grow = &mut g[a * d..(a + 1) * d];
                for b in a..d {
                    grow[b] += ra * r[b];
                }
            }
        }
    }

    /// `C = self * self^T` (outer Gram), symmetric. Upper-triangle rows
    /// are dealt round-robin across threads (earlier rows carry more
    /// dots), then mirrored; entries are single row dots, so the result
    /// is bitwise identical at any thread count.
    pub fn gram_outer(&self) -> Matrix {
        let n = self.rows;
        let mut g = Matrix::zeros(n, n);
        let flops = n as f64 * n as f64 * self.cols as f64;
        let t = if threads::worth_parallelizing(flops) { threads::current().min(n.max(1)) } else { 1 };
        let jobs: Vec<(usize, &mut [f64])> = g.data.chunks_mut(n.max(1)).enumerate().collect();
        threads::run_jobs(t, jobs, |(i, grow)| {
            let ri = self.row(i);
            for j in i..n {
                grow[j] = dot(ri, self.row(j));
            }
        });
        for i in 0..n {
            for j in 0..i {
                g.data[i * n + j] = g.data[j * n + i];
            }
        }
        g
    }

    /// Append the rows of `other` below `self` — in-place growth, the
    /// primitive the incremental sketch engine and the growable Woodbury
    /// cache build on. Existing rows are never moved or rescaled.
    pub fn append_rows(&mut self, other: &Matrix) {
        assert_eq!(self.cols, other.cols, "append_rows column mismatch");
        self.data.extend_from_slice(&other.data);
        self.rows += other.rows;
    }

    /// Drop every row past `rows` — the exact inverse of
    /// [`Matrix::append_rows`], used by transactional rollback: retained
    /// rows keep their storage bitwise (appends only ever extend the
    /// tail), so truncating back to the pre-append count restores the
    /// pre-append matrix exactly. `O(1)` bookkeeping plus the `Vec`
    /// truncation.
    pub fn truncate_rows(&mut self, rows: usize) {
        assert!(rows <= self.rows, "truncate_rows cannot grow the matrix");
        self.data.truncate(rows * self.cols);
        self.rows = rows;
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        dot(&self.data, &self.data).sqrt()
    }

    /// `self += alpha * other`.
    pub fn add_scaled(&mut self, alpha: f64, other: &Matrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        axpy(alpha, &other.data, &mut self.data);
    }

    /// Add `alpha` to the diagonal (ridge shift).
    pub fn add_diag(&mut self, alpha: f64) {
        let n = self.rows.min(self.cols);
        for i in 0..n {
            self.data[i * self.cols + i] += alpha;
        }
    }

    /// Maximum absolute entry difference against `other`.
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_matmul(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0;
                for p in 0..a.cols() {
                    s += a.get(i, p) * b.get(p, j);
                }
                c.set(i, j, s);
            }
        }
        c
    }

    fn test_mat(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = crate::rng::Xoshiro256::seed_from_u64(seed);
        Matrix::from_fn(rows, cols, |_, _| rng.next_gaussian())
    }

    #[test]
    fn matmul_matches_naive_awkward_shapes() {
        // Shapes straddling the blocking boundaries.
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 2), (65, 257, 33), (70, 300, 513), (128, 64, 17)] {
            let a = test_mat(m, k, 1);
            let b = test_mat(k, n, 2);
            let c = a.matmul(&b);
            let c0 = naive_matmul(&a, &b);
            assert!(c.max_abs_diff(&c0) < 1e-9, "mismatch at ({m},{k},{n})");
        }
    }

    #[test]
    fn matvec_consistent_with_matmul() {
        let a = test_mat(31, 17, 3);
        let x: Vec<f64> = (0..17).map(|i| (i as f64).sin()).collect();
        let xm = Matrix::from_vec(17, 1, x.clone());
        let y = a.matvec(&x);
        let ym = a.matmul(&xm);
        for i in 0..31 {
            assert!((y[i] - ym.get(i, 0)).abs() < 1e-12);
        }
    }

    #[test]
    fn matvec_t_is_transpose_matvec() {
        let a = test_mat(23, 11, 4);
        let x: Vec<f64> = (0..23).map(|i| (i as f64).cos()).collect();
        let y1 = a.matvec_t(&x);
        let y2 = a.transpose().matvec(&x);
        for i in 0..11 {
            assert!((y1[i] - y2[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn gram_matches_explicit() {
        let a = test_mat(19, 7, 5);
        let g = a.gram();
        let g0 = a.transpose().matmul(&a);
        assert!(g.max_abs_diff(&g0) < 1e-10);
    }

    #[test]
    fn gram_outer_matches_explicit() {
        let a = test_mat(9, 13, 6);
        let g = a.gram_outer();
        let g0 = a.matmul(&a.transpose());
        assert!(g.max_abs_diff(&g0) < 1e-10);
    }

    #[test]
    fn transpose_involution() {
        let a = test_mat(12, 29, 7);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn eye_is_identity_under_matmul() {
        let a = test_mat(8, 8, 8);
        let i = Matrix::eye(8);
        assert!(a.matmul(&i).max_abs_diff(&a) < 1e-14);
        assert!(i.matmul(&a).max_abs_diff(&a) < 1e-14);
    }

    #[test]
    fn add_diag_shifts_spectrum() {
        let mut a = Matrix::zeros(3, 3);
        a.add_diag(2.5);
        for i in 0..3 {
            assert_eq!(a.get(i, i), 2.5);
        }
    }

    #[test]
    fn matmul_nt_matches_explicit_transpose() {
        let a = test_mat(9, 21, 10);
        let b = test_mat(14, 21, 11);
        let c = a.matmul_nt(&b);
        let c0 = a.matmul(&b.transpose());
        assert!(c.max_abs_diff(&c0) < 1e-12);
    }

    #[test]
    fn matmul_tn_matches_explicit_transpose() {
        let a = test_mat(23, 9, 20);
        let b = test_mat(23, 6, 21);
        let c = a.matmul_tn(&b);
        let c0 = a.transpose().matmul(&b);
        assert!(c.max_abs_diff(&c0) < 1e-12);
        // Consistency with the column vector op.
        let x: Vec<f64> = (0..23).map(|i| (i as f64 * 0.3).sin()).collect();
        let xm = Matrix::from_vec(23, 1, x.clone());
        let y = a.matvec_t(&x);
        let ym = a.matmul_tn(&xm);
        for j in 0..9 {
            assert!((y[j] - ym.get(j, 0)).abs() < 1e-12);
        }
    }

    #[test]
    fn parallel_matmul_tn_bitwise_matches_any_thread_count() {
        // 2 * 300 * 48 * 16 ~ 4.6e5 crosses the parallel threshold; the
        // fixed-chunk reduction makes every thread count agree bitwise.
        let a = test_mat(300, 48, 22);
        let b = test_mat(300, 16, 23);
        let c1 = crate::linalg::threads::with_threads(1, || a.matmul_tn(&b));
        for t in [2, 3, 4, 8] {
            let ct = crate::linalg::threads::with_threads(t, || a.matmul_tn(&b));
            assert_eq!(c1, ct, "threads={t}");
        }
    }

    #[test]
    fn parallel_matmul_bitwise_matches_serial() {
        // Big enough to cross the parallel threshold.
        let a = test_mat(130, 96, 12);
        let b = test_mat(96, 70, 13);
        let serial = crate::linalg::threads::with_threads(1, || a.matmul(&b));
        for t in [2, 3, 8] {
            let par = crate::linalg::threads::with_threads(t, || a.matmul(&b));
            assert_eq!(par, serial, "threads={t}");
        }
    }

    #[test]
    fn parallel_gram_outer_and_nt_bitwise_match_serial() {
        let a = test_mat(96, 80, 14);
        let go1 = crate::linalg::threads::with_threads(1, || a.gram_outer());
        let go4 = crate::linalg::threads::with_threads(4, || a.gram_outer());
        assert_eq!(go1, go4);
        let b = test_mat(64, 80, 15);
        let nt1 = crate::linalg::threads::with_threads(1, || a.matmul_nt(&b));
        let nt4 = crate::linalg::threads::with_threads(4, || a.matmul_nt(&b));
        assert_eq!(nt1, nt4);
    }

    #[test]
    fn parallel_gram_bitwise_matches_any_thread_count() {
        // gram reduces fixed-chunk partials in chunk order: the summation
        // tree depends on the shape only, so every thread count agrees
        // bitwise (300 * 48 * 48 ~ 6.9e5 crosses the parallel threshold).
        let a = test_mat(300, 48, 16);
        let g1 = crate::linalg::threads::with_threads(1, || a.gram());
        for t in [2, 3, 4, 8] {
            let gt = crate::linalg::threads::with_threads(t, || a.gram());
            assert_eq!(g1, gt, "threads={t}");
        }
        // And symmetric.
        for i in 0..48 {
            for j in 0..i {
                assert_eq!(g1.get(i, j), g1.get(j, i));
            }
        }
    }

    #[test]
    fn append_rows_grows_in_place() {
        let top = test_mat(5, 7, 17);
        let bottom = test_mat(3, 7, 18);
        let mut grown = top.clone();
        grown.append_rows(&bottom);
        assert_eq!((grown.rows(), grown.cols()), (8, 7));
        for i in 0..5 {
            assert_eq!(grown.row(i), top.row(i), "prefix row {i} must be untouched");
        }
        for i in 0..3 {
            assert_eq!(grown.row(5 + i), bottom.row(i));
        }
    }
}
