//! Cholesky factorization of symmetric positive-definite matrices.
//!
//! Used for (i) the direct ridge solver (`A^T A + nu^2 I`), (ii) the cached
//! Woodbury factor `nu^2 I_m + (SA)(SA)^T` at each sketch-size change, and
//! (iii) the pCG baseline's normal-equations fallback. The factorization is
//! the classic row-oriented `L L^T` with an optional diagonal jitter retry
//! for matrices at the edge of positive definiteness.

use super::matrix::Matrix;
use super::threads;
use super::triangular::{self, solve_lower};

/// A lower-triangular Cholesky factor `L` with `L L^T = M`.
#[derive(Clone, Debug)]
pub struct Cholesky {
    l: Matrix,
}

/// Error raised when the input is not (numerically) positive definite.
#[derive(Debug, Clone, PartialEq)]
pub struct NotPositiveDefinite {
    /// Pivot index at which the factorization broke down.
    pub pivot: usize,
    /// Value of the failing diagonal element.
    pub value: f64,
}

impl std::fmt::Display for NotPositiveDefinite {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "matrix not positive definite: pivot {} has value {:.3e}",
            self.pivot, self.value
        )
    }
}

impl std::error::Error for NotPositiveDefinite {}

impl Cholesky {
    /// Factor a symmetric positive-definite matrix. Only the lower triangle
    /// of `m` is read.
    pub fn factor(m: &Matrix) -> Result<Self, NotPositiveDefinite> {
        let n = m.rows();
        assert_eq!(m.cols(), n, "Cholesky needs a square matrix");
        let mut l = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                // s = m[i][j] - sum_k l[i][k] l[j][k]
                let (li, lj) = (l.row(i), l.row(j));
                let s = m.get(i, j) - super::dot(&li[..j], &lj[..j]);
                if i == j {
                    if s <= 0.0 || !s.is_finite() {
                        return Err(NotPositiveDefinite { pivot: i, value: s });
                    }
                    l.set(i, j, s.sqrt());
                } else {
                    l.set(i, j, s / l.get(j, j));
                }
            }
        }
        Ok(Self { l })
    }

    /// Factor with escalating diagonal jitter (`eps * trace/n * 10^k`).
    /// Returns the factor and the jitter actually applied.
    pub fn factor_with_jitter(m: &Matrix, max_tries: usize) -> Result<(Self, f64), NotPositiveDefinite> {
        match Self::factor(m) {
            Ok(c) => return Ok((c, 0.0)),
            Err(e) if max_tries == 0 => return Err(e),
            Err(_) => {}
        }
        let n = m.rows();
        let mean_diag = (0..n).map(|i| m.get(i, i)).sum::<f64>() / n as f64;
        let mut jitter = 1e-12 * mean_diag.abs().max(1e-300);
        let mut last_err = NotPositiveDefinite { pivot: 0, value: 0.0 };
        for _ in 0..max_tries {
            let mut mj = m.clone();
            mj.add_diag(jitter);
            match Self::factor(&mj) {
                Ok(c) => return Ok((c, jitter)),
                Err(e) => last_err = e,
            }
            jitter *= 10.0;
        }
        Err(last_err)
    }

    /// The lower-triangular factor.
    pub fn l(&self) -> &Matrix {
        &self.l
    }

    /// Bordered extension: given the factor `L` of an `n x n` block `K`,
    /// extend it to the factor of
    /// `[[K, C^T], [C, D]]` (`C` is `p x n`, `D` is `p x p` symmetric)
    /// without touching the existing block — `O(p n^2)` instead of the
    /// `O((n+p)^3)` full refactor. This is the growth step the Woodbury
    /// cache takes when appended rows keep the embedding scale unchanged
    /// (fixed-scale streaming; the adaptive solver's `1/sqrt(m)` rescale
    /// shifts the whole diagonal and must refactor instead):
    /// `W = C L^{-T}`, then `L_D = chol(D - W W^T)` and
    /// `L_new = [[L, 0], [W, L_D]]`.
    ///
    /// Fails (leaving `self` unchanged) when the Schur complement
    /// `D - W W^T` is not positive definite; callers fall back to a full
    /// refactor with jitter.
    pub fn extend_bordered(&mut self, c: &Matrix, d_block: &Matrix) -> Result<(), NotPositiveDefinite> {
        let n = self.l.rows();
        let p = c.rows();
        assert_eq!(c.cols(), n, "cross block must have {n} columns");
        assert_eq!((d_block.rows(), d_block.cols()), (p, p), "corner must be {p} x {p}");
        // W = C L^{-T}: row i of W solves L w = c_i.
        let mut w = Matrix::zeros(p, n);
        for i in 0..p {
            let wi = solve_lower(&self.l, c.row(i));
            w.row_mut(i).copy_from_slice(&wi);
        }
        // Schur complement S = D - W W^T, factored in place.
        let mut s = d_block.clone();
        let ww = w.gram_outer();
        s.add_scaled(-1.0, &ww);
        let ls = Cholesky::factor(&s)?;
        // Assemble [[L, 0], [W, L_S]].
        let mut l_new = Matrix::zeros(n + p, n + p);
        for i in 0..n {
            l_new.row_mut(i)[..n].copy_from_slice(self.l.row(i));
        }
        for i in 0..p {
            l_new.row_mut(n + i)[..n].copy_from_slice(w.row(i));
            l_new.row_mut(n + i)[n..].copy_from_slice(ls.l.row(i));
        }
        self.l = l_new;
        Ok(())
    }

    /// Solve `M x = b` in place (`x` holds `b` on entry, the solution on
    /// exit) — the allocation-free primitive the per-iteration hot loops
    /// call ([`crate::solvers::woodbury::WoodburyCache::apply_inverse_into`]).
    pub fn solve_in_place(&self, x: &mut [f64]) {
        super::triangular::solve_lower_in_place(&self.l, x);
        super::triangular::solve_lower_transpose_in_place(&self.l, x);
    }

    /// Solve `M x = b` via the two triangular solves.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let mut x = b.to_vec();
        self.solve_in_place(&mut x);
        x
    }

    /// Multi-column solve `M X = B` in place: `b` is `n x k` row-major
    /// (`B` on entry, `X` on exit). The two triangular passes stream
    /// length-`k` fused row updates (BLAS-3 intensity instead of `k`
    /// BLAS-2 sweeps over `L`); above the parallel threshold the columns
    /// split across scoped threads, each running the exact serial
    /// per-element operation order — bitwise identical at any thread
    /// count and to `k` independent [`Cholesky::solve`] calls. This is
    /// the primitive behind
    /// [`crate::solvers::woodbury::WoodburyCache::apply_inverse_block`].
    pub fn solve_matrix_in_place(&self, b: &mut Matrix) {
        let n = self.l.rows();
        assert_eq!(b.rows(), n, "solve_matrix dimension mismatch");
        let k = b.cols();
        if n == 0 || k == 0 {
            return;
        }
        let flops = 2.0 * n as f64 * n as f64 * k as f64;
        let t = if k > 1 && threads::worth_parallelizing(flops) {
            threads::current().min(k)
        } else {
            1
        };
        if t > 1 {
            // One transpose puts each column contiguous; both triangular
            // solves run fused per column across threads (the column
            // dealing — and its determinism guarantee — lives in
            // `triangular::solve_columns_parallel`).
            triangular::solve_columns_parallel(b, t, |col| {
                triangular::solve_lower_in_place(&self.l, col);
                triangular::solve_lower_transpose_in_place(&self.l, col);
            });
            return;
        }
        triangular::solve_lower_matrix_in_place(&self.l, b);
        triangular::solve_lower_transpose_matrix_in_place(&self.l, b);
    }

    /// Multi-column solve `M X = B` (allocating wrapper around
    /// [`Cholesky::solve_matrix_in_place`]).
    pub fn solve_matrix(&self, b: &Matrix) -> Matrix {
        let mut x = b.clone();
        self.solve_matrix_in_place(&mut x);
        x
    }

    /// log-determinant of `M` (`= 2 sum log L_ii`).
    pub fn log_det(&self) -> f64 {
        (0..self.l.rows()).map(|i| self.l.get(i, i).ln()).sum::<f64>() * 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let a = Matrix::from_fn(n + 3, n, |_, _| rng.next_gaussian());
        let mut g = a.gram();
        g.add_diag(0.5);
        g
    }

    #[test]
    fn factor_reconstructs() {
        let m = spd(12, 1);
        let c = Cholesky::factor(&m).unwrap();
        let rec = c.l().matmul(&c.l().transpose());
        assert!(rec.max_abs_diff(&m) < 1e-9);
    }

    #[test]
    fn solve_matches_residual() {
        let m = spd(15, 2);
        let c = Cholesky::factor(&m).unwrap();
        let b: Vec<f64> = (0..15).map(|i| (i as f64 * 0.11).sin()).collect();
        let x = c.solve(&b);
        let r = m.matvec(&x);
        for i in 0..15 {
            assert!((r[i] - b[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn solve_matrix_columnwise() {
        let m = spd(8, 3);
        let c = Cholesky::factor(&m).unwrap();
        let mut rng = Xoshiro256::seed_from_u64(4);
        let b = Matrix::from_fn(8, 3, |_, _| rng.next_gaussian());
        let x = c.solve_matrix(&b);
        let r = m.matmul(&x);
        assert!(r.max_abs_diff(&b) < 1e-9);
    }

    #[test]
    fn solve_matrix_bitwise_matches_vector_solves() {
        let m = spd(17, 10);
        let c = Cholesky::factor(&m).unwrap();
        let mut rng = Xoshiro256::seed_from_u64(11);
        let b = Matrix::from_fn(17, 6, |_, _| rng.next_gaussian());
        let x = c.solve_matrix(&b);
        for j in 0..6 {
            let col: Vec<f64> = (0..17).map(|i| b.get(i, j)).collect();
            let xv = c.solve(&col);
            for i in 0..17 {
                assert_eq!(x.get(i, j), xv[i], "col {j} row {i}");
            }
        }
    }

    #[test]
    fn solve_matrix_bitwise_thread_invariant() {
        use crate::linalg::threads::with_threads;
        // 2 * 384^2 * 8 ~ 2.4e6 flops crosses the parallel threshold.
        let m = spd(384, 12);
        let c = Cholesky::factor(&m).unwrap();
        let mut rng = Xoshiro256::seed_from_u64(13);
        let b = Matrix::from_fn(384, 8, |_, _| rng.next_gaussian());
        let serial = with_threads(1, || c.solve_matrix(&b));
        for t in [2, 3, 8] {
            let par = with_threads(t, || c.solve_matrix(&b));
            assert_eq!(par, serial, "threads={t}");
        }
    }

    #[test]
    fn rejects_indefinite() {
        let mut m = Matrix::eye(3);
        m.set(2, 2, -1.0);
        assert!(Cholesky::factor(&m).is_err());
    }

    #[test]
    fn jitter_recovers_semidefinite() {
        // Rank-deficient Gram matrix: x x^T.
        let x = Matrix::from_vec(3, 1, vec![1.0, 2.0, 3.0]);
        let m = x.matmul(&x.transpose());
        assert!(Cholesky::factor(&m).is_err());
        let (c, jitter) = Cholesky::factor_with_jitter(&m, 16).unwrap();
        assert!(jitter > 0.0);
        let rec = c.l().matmul(&c.l().transpose());
        assert!(rec.max_abs_diff(&m) < 1e-3);
    }

    #[test]
    fn log_det_identity_is_zero() {
        let c = Cholesky::factor(&Matrix::eye(5)).unwrap();
        assert!(c.log_det().abs() < 1e-14);
    }

    #[test]
    fn extend_bordered_matches_full_factor() {
        // Factor the leading 9x9 block of a 12x12 SPD matrix, extend by
        // the remaining 3 rows, compare against factoring the whole thing.
        let m = spd(12, 8);
        let (n, p) = (9, 3);
        let top = Matrix::from_fn(n, n, |i, j| m.get(i, j));
        let cross = Matrix::from_fn(p, n, |i, j| m.get(n + i, j));
        let corner = Matrix::from_fn(p, p, |i, j| m.get(n + i, n + j));
        let mut c = Cholesky::factor(&top).unwrap();
        c.extend_bordered(&cross, &corner).unwrap();
        let full = Cholesky::factor(&m).unwrap();
        assert!(c.l().max_abs_diff(full.l()) < 1e-9);
        // And it solves the full system.
        let b: Vec<f64> = (0..12).map(|i| (i as f64 * 0.3).cos()).collect();
        let x = c.solve(&b);
        let r = m.matvec(&x);
        for i in 0..12 {
            assert!((r[i] - b[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn extend_bordered_rejects_indefinite_schur_and_keeps_factor() {
        let top = Matrix::eye(2);
        let mut c = Cholesky::factor(&top).unwrap();
        // Corner equal to W W^T - 1: Schur complement is negative.
        let cross = Matrix::from_vec(1, 2, vec![2.0, 0.0]);
        let corner = Matrix::from_vec(1, 1, vec![3.0]);
        assert!(c.extend_bordered(&cross, &corner).is_err());
        assert_eq!(c.l().rows(), 2, "failed extension must leave L intact");
    }
}
