//! Compressed sparse row (CSR) matrices — the substrate for the paper's
//! Remark 4.1: with sparse data, embeddings whose application costs
//! `O(nnz(A))` (CountSketch, [`crate::sketch::sparse`]) replace the dense
//! `O(mnd)` / `O(nd log n)` sketches. This module provides the storage and
//! the `O(nnz)` matvec/sketch building blocks; the deviation analysis for
//! sparse embeddings is future work in the paper and out of scope here.

use super::matrix::Matrix;

/// CSR matrix: `indptr[i]..indptr[i+1]` indexes row `i`'s entries.
#[derive(Clone, Debug, PartialEq)]
pub struct CsrMatrix {
    rows: usize,
    cols: usize,
    indptr: Vec<usize>,
    indices: Vec<u32>,
    values: Vec<f64>,
}

impl CsrMatrix {
    /// Build from (row, col, value) triplets; duplicates are summed.
    pub fn from_triplets(rows: usize, cols: usize, triplets: &[(usize, usize, f64)]) -> Self {
        let mut sorted: Vec<(usize, usize, f64)> = triplets.to_vec();
        sorted.sort_by_key(|&(r, c, _)| (r, c));
        let mut indptr = vec![0usize; rows + 1];
        let mut indices = Vec::with_capacity(sorted.len());
        let mut values: Vec<f64> = Vec::with_capacity(sorted.len());
        for &(r, c, v) in &sorted {
            assert!(r < rows && c < cols, "triplet ({r},{c}) out of bounds");
            if let (Some(&last_c), true) = (indices.last(), indptr[r + 1] > 0) {
                // Same row (indptr counting below) and same column: merge.
                if indptr[r + 1] == indices.len() && last_c == c as u32 {
                    *values.last_mut().unwrap() += v;
                    continue;
                }
            }
            indices.push(c as u32);
            values.push(v);
            indptr[r + 1] = indices.len();
        }
        // Forward-fill row pointers for empty rows.
        for i in 1..=rows {
            if indptr[i] < indptr[i - 1] {
                indptr[i] = indptr[i - 1];
            }
        }
        Self { rows, cols, indptr, indices, values }
    }

    /// Build from a dense matrix, dropping exact zeros.
    pub fn from_dense(a: &Matrix) -> Self {
        let (rows, cols) = (a.rows(), a.cols());
        let mut indptr = Vec::with_capacity(rows + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        for i in 0..rows {
            for (j, &v) in a.row(i).iter().enumerate() {
                if v != 0.0 {
                    indices.push(j as u32);
                    values.push(v);
                }
            }
            indptr.push(indices.len());
        }
        Self { rows, cols, indptr, indices, values }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Number of stored entries.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Density `nnz / (rows * cols)`.
    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    /// Row accessor: `(column indices, values)`.
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let span = self.indptr[i]..self.indptr[i + 1];
        (&self.indices[span.clone()], &self.values[span])
    }

    /// Densify (tests / small matrices only).
    pub fn to_dense(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            let dst = out.row_mut(i);
            for (&c, &v) in cols.iter().zip(vals) {
                dst[c as usize] += v;
            }
        }
        out
    }

    /// `y = A x` in `O(nnz)`.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0; self.rows];
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            let mut s = 0.0;
            for (&c, &v) in cols.iter().zip(vals) {
                s += v * x[c as usize];
            }
            y[i] = s;
        }
        y
    }

    /// `y = A^T x` in `O(nnz)` (scatter over rows).
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows);
        let mut y = vec![0.0; self.cols];
        for i in 0..self.rows {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            let (cols, vals) = self.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                y[c as usize] += v * xi;
            }
        }
        y
    }

    /// Ridge gradient on sparse data: `A^T(Ax - b) + nu^2 x`, `O(nnz)`.
    pub fn ridge_gradient(&self, x: &[f64], b: &[f64], nu: f64) -> Vec<f64> {
        assert_eq!(b.len(), self.rows);
        let mut r = self.matvec(x);
        for (ri, bi) in r.iter_mut().zip(b) {
            *ri -= bi;
        }
        let mut g = self.matvec_t(&r);
        for (gi, xi) in g.iter_mut().zip(x) {
            *gi += nu * nu * xi;
        }
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn random_sparse(rows: usize, cols: usize, density: f64, seed: u64) -> (CsrMatrix, Matrix) {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let dense = Matrix::from_fn(rows, cols, |_, _| {
            if rng.next_f64() < density {
                rng.next_gaussian()
            } else {
                0.0
            }
        });
        (CsrMatrix::from_dense(&dense), dense)
    }

    #[test]
    fn dense_roundtrip() {
        let (csr, dense) = random_sparse(17, 9, 0.2, 1);
        assert!(csr.to_dense().max_abs_diff(&dense) == 0.0);
        assert!(csr.density() < 0.4);
    }

    #[test]
    fn matvec_matches_dense() {
        let (csr, dense) = random_sparse(23, 11, 0.3, 2);
        let x: Vec<f64> = (0..11).map(|i| (i as f64 * 0.4).sin()).collect();
        let ys = csr.matvec(&x);
        let yd = dense.matvec(&x);
        for i in 0..23 {
            assert!((ys[i] - yd[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn matvec_t_matches_dense() {
        let (csr, dense) = random_sparse(15, 21, 0.25, 3);
        let x: Vec<f64> = (0..15).map(|i| (i as f64 * 0.7).cos()).collect();
        let ys = csr.matvec_t(&x);
        let yd = dense.matvec_t(&x);
        for i in 0..21 {
            assert!((ys[i] - yd[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn ridge_gradient_matches_dense_problem() {
        let (csr, dense) = random_sparse(32, 8, 0.3, 4);
        let mut rng = Xoshiro256::seed_from_u64(5);
        let mut b = vec![0.0; 32];
        rng.fill_gaussian(&mut b, 1.0);
        let p = crate::solvers::RidgeProblem::new(dense, b.clone(), 0.6);
        let x: Vec<f64> = (0..8).map(|i| i as f64 * 0.1).collect();
        let gs = csr.ridge_gradient(&x, &b, 0.6);
        let gd = p.gradient(&x);
        for i in 0..8 {
            assert!((gs[i] - gd[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn triplets_merge_duplicates_and_handle_empty_rows() {
        let csr = CsrMatrix::from_triplets(
            4,
            3,
            &[(0, 1, 2.0), (0, 1, 3.0), (2, 0, 1.0), (2, 2, -1.0)],
        );
        assert_eq!(csr.nnz(), 3);
        let d = csr.to_dense();
        assert_eq!(d.get(0, 1), 5.0);
        assert_eq!(d.get(1, 0), 0.0); // empty row
        assert_eq!(d.get(2, 2), -1.0);
    }

    #[test]
    fn empty_matrix_ok() {
        let csr = CsrMatrix::from_triplets(3, 3, &[]);
        assert_eq!(csr.nnz(), 0);
        assert_eq!(csr.matvec(&[1.0, 1.0, 1.0]), vec![0.0; 3]);
    }
}
