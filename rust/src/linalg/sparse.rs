//! Compressed sparse row (CSR) matrices — the substrate for the paper's
//! Remark 4.1: with sparse data, embeddings whose application costs
//! `O(nnz(A))` (CountSketch, [`crate::sketch::sparse`]) replace the dense
//! `O(mnd)` / `O(nd log n)` sketches. This module provides the storage and
//! the `O(nnz)` matvec / gram / sketch building blocks; the deviation
//! analysis for sparse embeddings is future work in the paper and out of
//! scope here.
//!
//! As of the end-to-end sparse operand path
//! ([`crate::linalg::operand::Operand`]), these kernels sit on the solver
//! hot paths, so the large ones are row-parallel over the
//! [`super::threads`] scoped-thread infrastructure:
//!
//! * `matvec` / `left_mul` split independent *output* rows across threads —
//!   bitwise identical at any thread count (each output element keeps its
//!   serial accumulation order).
//! * `matvec_t` / `gram` are reductions: input rows are split into
//!   [`super::threads::REDUCE_PARTS`] *fixed* chunks whose partial results
//!   are combined in chunk order. The partition depends only on the matrix
//!   shape — never on the thread count — so these are bitwise identical at
//!   any thread count too (same policy as the dense [`Matrix::gram`]).
//!
//! Invariant: within each row, column indices are strictly increasing
//! (`from_triplets` sorts and merges; `from_dense` emits in order;
//! `transpose` preserves it). `gram_outer` relies on this for its
//! merge-based row dot products.

use super::matrix::Matrix;
use super::{axpy, threads};

/// CSR matrix: `indptr[i]..indptr[i+1]` indexes row `i`'s entries.
#[derive(Clone, Debug, PartialEq)]
pub struct CsrMatrix {
    rows: usize,
    cols: usize,
    indptr: Vec<usize>,
    indices: Vec<u32>,
    values: Vec<f64>,
}

impl CsrMatrix {
    /// Build from (row, col, value) triplets; duplicates are summed.
    pub fn from_triplets(rows: usize, cols: usize, triplets: &[(usize, usize, f64)]) -> Self {
        let mut sorted: Vec<(usize, usize, f64)> = triplets.to_vec();
        sorted.sort_by_key(|&(r, c, _)| (r, c));
        let mut indptr = vec![0usize; rows + 1];
        let mut indices = Vec::with_capacity(sorted.len());
        let mut values: Vec<f64> = Vec::with_capacity(sorted.len());
        for &(r, c, v) in &sorted {
            assert!(r < rows && c < cols, "triplet ({r},{c}) out of bounds");
            if let (Some(&last_c), true) = (indices.last(), indptr[r + 1] > 0) {
                // Same row (indptr counting below) and same column: merge.
                if indptr[r + 1] == indices.len() && last_c == c as u32 {
                    *values.last_mut().unwrap() += v;
                    continue;
                }
            }
            indices.push(c as u32);
            values.push(v);
            indptr[r + 1] = indices.len();
        }
        // Forward-fill row pointers for empty rows.
        for i in 1..=rows {
            if indptr[i] < indptr[i - 1] {
                indptr[i] = indptr[i - 1];
            }
        }
        Self { rows, cols, indptr, indices, values }
    }

    /// Build from a dense matrix, dropping exact zeros.
    pub fn from_dense(a: &Matrix) -> Self {
        let (rows, cols) = (a.rows(), a.cols());
        let mut indptr = Vec::with_capacity(rows + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        for i in 0..rows {
            for (j, &v) in a.row(i).iter().enumerate() {
                if v != 0.0 {
                    indices.push(j as u32);
                    values.push(v);
                }
            }
            indptr.push(indices.len());
        }
        Self { rows, cols, indptr, indices, values }
    }

    /// Row count.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Column count.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Number of stored entries.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Density `nnz / (rows * cols)`.
    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    /// Row accessor: `(column indices, values)`.
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let span = self.indptr[i]..self.indptr[i + 1];
        (&self.indices[span.clone()], &self.values[span])
    }

    /// Densify (tests / small matrices / oracle paths only).
    pub fn to_dense(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            let dst = out.row_mut(i);
            for (&c, &v) in cols.iter().zip(vals) {
                dst[c as usize] += v;
            }
        }
        out
    }

    /// Append `other`'s rows below the existing ones in `O(nnz(other))`:
    /// pure concatenation of the CSR arrays (row pointers shifted by the
    /// current entry count), so the retained rows' storage — offsets,
    /// column order, values — is untouched. This is the streaming-ingest
    /// primitive: every invariant (`indptr` monotone, strictly increasing
    /// columns within a row) carries over from the two inputs.
    pub fn append_rows(&mut self, other: &CsrMatrix) {
        assert_eq!(self.cols, other.cols, "append_rows column mismatch");
        let base = self.values.len();
        self.indices.extend_from_slice(&other.indices);
        self.values.extend_from_slice(&other.values);
        self.indptr.extend(other.indptr[1..].iter().map(|&p| base + p));
        self.rows += other.rows;
    }

    /// Drop every row past `rows` — the exact inverse of
    /// [`CsrMatrix::append_rows`] for transactional rollback. Appends are
    /// pure tail concatenation, so truncating the three CSR arrays back
    /// to the old row count restores the pre-append matrix bitwise.
    pub fn truncate_rows(&mut self, rows: usize) {
        assert!(rows <= self.rows, "truncate_rows cannot grow the matrix");
        let nnz = self.indptr[rows];
        self.indices.truncate(nnz);
        self.values.truncate(nnz);
        self.indptr.truncate(rows + 1);
        self.rows = rows;
    }

    /// `A^T` in `O(nnz)` via a counting sort over columns. Row-sorted
    /// column order is preserved (ascending original row indices).
    pub fn transpose(&self) -> CsrMatrix {
        let (n, d) = (self.rows, self.cols);
        let nnz = self.nnz();
        let mut indptr = vec![0usize; d + 1];
        for &c in &self.indices {
            indptr[c as usize + 1] += 1;
        }
        for j in 1..=d {
            indptr[j] += indptr[j - 1];
        }
        let mut next = indptr.clone();
        let mut indices = vec![0u32; nnz];
        let mut values = vec![0.0; nnz];
        for i in 0..n {
            let (cols, vals) = self.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                let pos = next[c as usize];
                indices[pos] = i as u32;
                values[pos] = v;
                next[c as usize] += 1;
            }
        }
        CsrMatrix { rows: d, cols: n, indptr, indices, values }
    }

    #[inline]
    fn row_dot(&self, i: usize, x: &[f64]) -> f64 {
        let (cols, vals) = self.row(i);
        let mut s = 0.0;
        for (&c, &v) in cols.iter().zip(vals) {
            s += v * x[c as usize];
        }
        s
    }

    /// `y = A x` in `O(nnz)`, row-parallel (each output element keeps the
    /// serial accumulation order — bitwise identical at any thread count).
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols, "matvec dimension mismatch");
        assert_eq!(y.len(), self.rows, "matvec output length mismatch");
        let flops = 2.0 * self.nnz() as f64;
        let t = if threads::worth_parallelizing(flops) {
            threads::current().min(self.rows.max(1))
        } else {
            1
        };
        if t <= 1 {
            for i in 0..self.rows {
                y[i] = self.row_dot(i, x);
            }
            return;
        }
        let chunk = (self.rows + t - 1) / t;
        let jobs: Vec<(usize, &mut [f64])> = y
            .chunks_mut(chunk)
            .enumerate()
            .map(|(i, c)| (i * chunk, c))
            .collect();
        threads::run_jobs(t, jobs, |(r0, out)| {
            for (k, yi) in out.iter_mut().enumerate() {
                *yi = self.row_dot(r0 + k, x);
            }
        });
    }

    /// `y = A x` in `O(nnz)` (allocating wrapper).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.rows];
        self.matvec_into(x, &mut y);
        y
    }

    /// Scatter rows `r0..r1` of `A^T x` into `y` (`y[c] += v * x[row]`).
    fn scatter_rows_t(&self, r0: usize, r1: usize, x: &[f64], y: &mut [f64]) {
        for i in r0..r1 {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            let (cols, vals) = self.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                y[c as usize] += v * xi;
            }
        }
    }

    /// `y += A^T x` in `O(nnz)`. Above the parallel threshold, rows split
    /// into [`threads::REDUCE_PARTS`] fixed chunks whose partials reduce in
    /// chunk order — the partition depends on the shape only, so the result
    /// is bitwise identical at any thread count.
    pub fn matvec_t_add(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.rows, "matvec_t dimension mismatch");
        assert_eq!(y.len(), self.cols, "matvec_t output length mismatch");
        let flops = 2.0 * self.nnz() as f64;
        let parts = threads::REDUCE_PARTS;
        if !threads::worth_parallelizing(flops) || self.rows < 2 * parts || self.cols == 0 {
            self.scatter_rows_t(0, self.rows, x, y);
            return;
        }
        let d = self.cols;
        let chunk = (self.rows + parts - 1) / parts;
        let mut partials = vec![0.0; parts * d];
        let jobs: Vec<(usize, &mut [f64])> = partials.chunks_mut(d).enumerate().collect();
        let t = threads::current().min(parts);
        threads::run_jobs(t, jobs, |(p, buf)| {
            let r0 = (p * chunk).min(self.rows);
            let r1 = (r0 + chunk).min(self.rows);
            self.scatter_rows_t(r0, r1, x, buf);
        });
        for p in 0..parts {
            axpy(1.0, &partials[p * d..(p + 1) * d], y);
        }
    }

    /// `y = A^T x` in `O(nnz)` into a caller buffer.
    pub fn matvec_t_into(&self, x: &[f64], y: &mut [f64]) {
        y.fill(0.0);
        self.matvec_t_add(x, y);
    }

    /// `y = A^T x` in `O(nnz)` (allocating wrapper).
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.cols];
        self.matvec_t_add(x, &mut y);
        y
    }

    /// Accumulate the upper triangle of the Gram contribution of rows
    /// `r0..r1` into `g` (`d x d`, row-major): `g[c1][c2] += v1 * v2` for
    /// each within-row entry pair with `c1 <= c2`.
    fn gram_rows_upper(&self, r0: usize, r1: usize, g: &mut [f64]) {
        let d = self.cols;
        for i in r0..r1 {
            let (cols, vals) = self.row(i);
            for (p, (&ca, &va)) in cols.iter().zip(vals).enumerate() {
                let base = ca as usize * d;
                for (&cb, &vb) in cols[p..].iter().zip(&vals[p..]) {
                    g[base + cb as usize] += va * vb;
                }
            }
        }
    }

    /// `A^T A` (`d x d`) in `O(sum_i nnz_i^2)` — within-row entry-pair
    /// scatter, upper triangle mirrored. Fixed-chunk partial reduction as
    /// in [`Self::matvec_t_add`] (bitwise thread-count invariant).
    pub fn gram(&self) -> Matrix {
        let d = self.cols;
        let mut g = Matrix::zeros(d, d);
        if d == 0 || self.rows == 0 {
            return g;
        }
        // Work model: average row fill times nnz pair-products.
        let flops = self.nnz() as f64 / self.rows as f64 * self.nnz() as f64;
        let parts = threads::REDUCE_PARTS;
        if !threads::worth_parallelizing(flops) || self.rows < 2 * parts {
            self.gram_rows_upper(0, self.rows, g.as_mut_slice());
        } else {
            let chunk = (self.rows + parts - 1) / parts;
            let mut partials = vec![0.0; parts * d * d];
            let jobs: Vec<(usize, &mut [f64])> =
                partials.chunks_mut(d * d).enumerate().collect();
            let t = threads::current().min(parts);
            threads::run_jobs(t, jobs, |(p, buf)| {
                let r0 = (p * chunk).min(self.rows);
                let r1 = (r0 + chunk).min(self.rows);
                self.gram_rows_upper(r0, r1, buf);
            });
            for p in 0..parts {
                axpy(1.0, &partials[p * d * d..(p + 1) * d * d], g.as_mut_slice());
            }
        }
        for a in 0..d {
            for b in 0..a {
                let v = g.get(b, a);
                g.set(a, b, v);
            }
        }
        g
    }

    /// `A A^T` (`rows x rows`), entry `(i, j)` a merge dot over the two
    /// sorted rows — `O(rows * nnz)` worst case. Oracle/diagnostic path
    /// (dual ground truth); not on the iterative hot loops.
    pub fn gram_outer(&self) -> Matrix {
        let n = self.rows;
        let mut g = Matrix::zeros(n, n);
        for i in 0..n {
            let (ci, vi) = self.row(i);
            for j in i..n {
                let (cj, vj) = self.row(j);
                let (mut p, mut q, mut s) = (0usize, 0usize, 0.0);
                while p < ci.len() && q < cj.len() {
                    match ci[p].cmp(&cj[q]) {
                        std::cmp::Ordering::Less => p += 1,
                        std::cmp::Ordering::Greater => q += 1,
                        std::cmp::Ordering::Equal => {
                            s += vi[p] * vj[q];
                            p += 1;
                            q += 1;
                        }
                    }
                }
                g.set(i, j, s);
                g.set(j, i, s);
            }
        }
        g
    }

    /// SpMM `A * X` for a dense `cols x k` block `X`, in `O(nnz * k)`:
    /// output row `i` accumulates one length-`k` axpy per stored entry of
    /// row `i`, so every loaded CSR element does `2k` flops (BLAS-3
    /// arithmetic intensity — the block-RHS hot path). Row-parallel over
    /// the independent output rows; each output row keeps its serial
    /// accumulation order, so the result is bitwise identical at any
    /// thread count.
    pub fn matmul(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.rows(), self.cols, "matmul dimension mismatch");
        let k = x.cols();
        let mut out = Matrix::zeros(self.rows, k);
        if k == 0 || self.rows == 0 {
            return out;
        }
        let flops = 2.0 * self.nnz() as f64 * k as f64;
        let t = if threads::worth_parallelizing(flops) {
            threads::current().min(self.rows)
        } else {
            1
        };
        let chunk = (self.rows + t - 1) / t;
        let jobs: Vec<(usize, &mut [f64])> = out
            .as_mut_slice()
            .chunks_mut(chunk * k)
            .enumerate()
            .map(|(i, rows)| (i * chunk, rows))
            .collect();
        threads::run_jobs(t, jobs, |(r0, rows)| {
            for (i, orow) in rows.chunks_mut(k).enumerate() {
                let (cols, vals) = self.row(r0 + i);
                for (&c, &v) in cols.iter().zip(vals) {
                    axpy(v, x.row(c as usize), orow);
                }
            }
        });
        out
    }

    /// Scatter rows `r0..r1` of the SpMM `A^T Y` into `out` (`cols x k`
    /// row-major): `out[c][:] += v * y[row][:]` per stored entry.
    fn scatter_rows_t_block(&self, r0: usize, r1: usize, y: &Matrix, out: &mut [f64]) {
        let k = y.cols();
        for i in r0..r1 {
            let yrow = y.row(i);
            let (cols, vals) = self.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                axpy(v, yrow, &mut out[c as usize * k..(c as usize + 1) * k]);
            }
        }
    }

    /// SpMM `A^T * Y` for a dense `rows x k` block `Y`, in `O(nnz * k)`.
    /// A reduction over input rows: above the parallel threshold the rows
    /// split into [`threads::REDUCE_PARTS`] fixed chunks whose partial
    /// blocks reduce in chunk order — bitwise identical at any thread
    /// count (same policy as [`CsrMatrix::matvec_t_add`]).
    pub fn matmul_t(&self, y: &Matrix) -> Matrix {
        assert_eq!(y.rows(), self.rows, "matmul_t dimension mismatch");
        let (d, k) = (self.cols, y.cols());
        let mut out = Matrix::zeros(d, k);
        if d == 0 || k == 0 || self.rows == 0 {
            return out;
        }
        let flops = 2.0 * self.nnz() as f64 * k as f64;
        let parts = threads::REDUCE_PARTS;
        if !threads::worth_parallelizing(flops) || self.rows < 2 * parts {
            self.scatter_rows_t_block(0, self.rows, y, out.as_mut_slice());
            return out;
        }
        let chunk = (self.rows + parts - 1) / parts;
        let mut partials = vec![0.0; parts * d * k];
        let jobs: Vec<(usize, &mut [f64])> = partials.chunks_mut(d * k).enumerate().collect();
        let t = threads::current().min(parts);
        threads::run_jobs(t, jobs, |(p, buf)| {
            let r0 = (p * chunk).min(self.rows);
            let r1 = (r0 + chunk).min(self.rows);
            self.scatter_rows_t_block(r0, r1, y, buf);
        });
        for p in 0..parts {
            axpy(1.0, &partials[p * d * k..(p + 1) * d * k], out.as_mut_slice());
        }
        out
    }

    /// `G * A` for a dense left operand `G` (`p x rows`) in `O(p * nnz)` —
    /// the sparse fast path for applying a dense (Gaussian) sketch block.
    /// Row-parallel over the independent output rows (bitwise thread-count
    /// invariant).
    pub fn left_mul(&self, g: &Matrix) -> Matrix {
        assert_eq!(g.cols(), self.rows, "left_mul dimension mismatch");
        let (p, d) = (g.rows(), self.cols);
        let mut out = Matrix::zeros(p, d);
        if p == 0 || d == 0 {
            return out;
        }
        let flops = 2.0 * p as f64 * self.nnz() as f64;
        let t = if threads::worth_parallelizing(flops) { threads::current().min(p) } else { 1 };
        let chunk = (p + t - 1) / t;
        let jobs: Vec<(usize, &mut [f64])> = out
            .as_mut_slice()
            .chunks_mut(chunk * d)
            .enumerate()
            .map(|(i, rows)| (i * chunk, rows))
            .collect();
        threads::run_jobs(t, jobs, |(g0, rows)| {
            for (k, orow) in rows.chunks_mut(d).enumerate() {
                let grow = g.row(g0 + k);
                for j in 0..self.rows {
                    let coeff = grow[j];
                    if coeff == 0.0 {
                        continue;
                    }
                    let (cols, vals) = self.row(j);
                    for (&c, &v) in cols.iter().zip(vals) {
                        orow[c as usize] += coeff * v;
                    }
                }
            }
        });
        out
    }

    /// Ridge gradient on sparse data: `A^T(Ax - b) + nu^2 x`, `O(nnz)`.
    pub fn ridge_gradient(&self, x: &[f64], b: &[f64], nu: f64) -> Vec<f64> {
        assert_eq!(b.len(), self.rows);
        let mut r = self.matvec(x);
        for (ri, bi) in r.iter_mut().zip(b) {
            *ri -= bi;
        }
        let mut g = self.matvec_t(&r);
        for (gi, xi) in g.iter_mut().zip(x) {
            *gi += nu * nu * xi;
        }
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::threads::with_threads;
    use crate::rng::Xoshiro256;

    fn random_sparse(rows: usize, cols: usize, density: f64, seed: u64) -> (CsrMatrix, Matrix) {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let dense = Matrix::from_fn(rows, cols, |_, _| {
            if rng.next_f64() < density {
                rng.next_gaussian()
            } else {
                0.0
            }
        });
        (CsrMatrix::from_dense(&dense), dense)
    }

    #[test]
    fn dense_roundtrip() {
        let (csr, dense) = random_sparse(17, 9, 0.2, 1);
        assert!(csr.to_dense().max_abs_diff(&dense) == 0.0);
        assert!(csr.density() < 0.4);
    }

    #[test]
    fn matvec_matches_dense() {
        let (csr, dense) = random_sparse(23, 11, 0.3, 2);
        let x: Vec<f64> = (0..11).map(|i| (i as f64 * 0.4).sin()).collect();
        let ys = csr.matvec(&x);
        let yd = dense.matvec(&x);
        for i in 0..23 {
            assert!((ys[i] - yd[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn matvec_t_matches_dense() {
        let (csr, dense) = random_sparse(15, 21, 0.25, 3);
        let x: Vec<f64> = (0..15).map(|i| (i as f64 * 0.7).cos()).collect();
        let ys = csr.matvec_t(&x);
        let yd = dense.matvec_t(&x);
        for i in 0..21 {
            assert!((ys[i] - yd[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn transpose_matches_dense_transpose() {
        let (csr, dense) = random_sparse(19, 13, 0.3, 8);
        let t = csr.transpose();
        assert_eq!((t.rows(), t.cols()), (13, 19));
        assert!(t.to_dense().max_abs_diff(&dense.transpose()) == 0.0);
        // Double transpose is the identity (including the sorted-column
        // invariant).
        assert_eq!(t.transpose(), csr);
    }

    #[test]
    fn gram_matches_dense_gram() {
        let (csr, dense) = random_sparse(40, 12, 0.35, 9);
        assert!(csr.gram().max_abs_diff(&dense.gram()) < 1e-12);
    }

    #[test]
    fn gram_outer_matches_dense() {
        let (csr, dense) = random_sparse(14, 25, 0.3, 10);
        assert!(csr.gram_outer().max_abs_diff(&dense.gram_outer()) < 1e-12);
    }

    #[test]
    fn left_mul_matches_dense_matmul() {
        let (csr, dense) = random_sparse(22, 9, 0.3, 11);
        let mut rng = Xoshiro256::seed_from_u64(12);
        let g = Matrix::from_fn(6, 22, |_, _| rng.next_gaussian());
        assert!(csr.left_mul(&g).max_abs_diff(&g.matmul(&dense)) < 1e-12);
    }

    #[test]
    fn matmul_block_matches_dense() {
        let (csr, dense) = random_sparse(26, 10, 0.3, 20);
        let mut rng = Xoshiro256::seed_from_u64(21);
        let x = Matrix::from_fn(10, 5, |_, _| rng.next_gaussian());
        assert!(csr.matmul(&x).max_abs_diff(&dense.matmul(&x)) < 1e-12);
        // Consistency with the vector kernel on a one-column block.
        let v: Vec<f64> = (0..10).map(|i| (i as f64 * 0.4).cos()).collect();
        let vm = Matrix::from_vec(10, 1, v.clone());
        let y = csr.matvec(&v);
        let ym = csr.matmul(&vm);
        for i in 0..26 {
            assert!((y[i] - ym.get(i, 0)).abs() < 1e-12);
        }
    }

    #[test]
    fn matmul_t_block_matches_dense() {
        let (csr, dense) = random_sparse(24, 8, 0.35, 22);
        let mut rng = Xoshiro256::seed_from_u64(23);
        let y = Matrix::from_fn(24, 4, |_, _| rng.next_gaussian());
        assert!(csr.matmul_t(&y).max_abs_diff(&dense.matmul_tn(&y)) < 1e-12);
        let v: Vec<f64> = (0..24).map(|i| (i as f64 * 0.2).sin()).collect();
        let vm = Matrix::from_vec(24, 1, v.clone());
        let w = csr.matvec_t(&v);
        let wm = csr.matmul_t(&vm);
        for j in 0..8 {
            assert!((w[j] - wm.get(j, 0)).abs() < 1e-12);
        }
    }

    #[test]
    fn block_kernels_bitwise_thread_invariant() {
        // 2 * nnz * k ~ 2 * 0.5*512*96 * 16 ~ 7.9e5 crosses the threshold.
        let (csr, _) = random_sparse(512, 96, 0.5, 24);
        assert!(2 * csr.nnz() * 16 >= 400_000, "test premise: above threshold");
        let mut rng = Xoshiro256::seed_from_u64(25);
        let x = Matrix::from_fn(96, 16, |_, _| rng.next_gaussian());
        let y = Matrix::from_fn(512, 16, |_, _| rng.next_gaussian());
        let mm1 = with_threads(1, || csr.matmul(&x));
        let mt1 = with_threads(1, || csr.matmul_t(&y));
        for t in [2, 3, 8] {
            assert_eq!(with_threads(t, || csr.matmul(&x)), mm1, "matmul t={t}");
            assert_eq!(with_threads(t, || csr.matmul_t(&y)), mt1, "matmul_t t={t}");
        }
    }

    #[test]
    fn parallel_kernels_bitwise_thread_invariant() {
        // Large enough that 2*nnz and the gram work model cross the
        // parallel threshold (~4e5): nnz ~ 0.5 * 1024 * 96 ~ 49k is short
        // of it for matvec, so scale rows up via density 1.0 on the
        // reduction kernels' own threshold instead: use a denser block.
        let (csr, _) = random_sparse(1024, 256, 0.8, 13);
        assert!(2 * csr.nnz() >= 400_000, "test premise: above threshold");
        let x: Vec<f64> = (0..256).map(|i| (i as f64 * 0.13).sin()).collect();
        let xt: Vec<f64> = (0..1024).map(|i| (i as f64 * 0.011).cos()).collect();
        let mv1 = with_threads(1, || csr.matvec(&x));
        let mt1 = with_threads(1, || csr.matvec_t(&xt));
        let g1 = with_threads(1, || csr.gram());
        let mut glx = Xoshiro256::seed_from_u64(14);
        let gl = Matrix::from_fn(8, 1024, |_, _| glx.next_gaussian());
        let lm1 = with_threads(1, || csr.left_mul(&gl));
        for t in [2, 3, 8] {
            assert_eq!(with_threads(t, || csr.matvec(&x)), mv1, "matvec t={t}");
            assert_eq!(with_threads(t, || csr.matvec_t(&xt)), mt1, "matvec_t t={t}");
            assert_eq!(with_threads(t, || csr.gram()), g1, "gram t={t}");
            assert_eq!(with_threads(t, || csr.left_mul(&gl)), lm1, "left_mul t={t}");
        }
    }

    #[test]
    fn ridge_gradient_matches_dense_problem() {
        let (csr, dense) = random_sparse(32, 8, 0.3, 4);
        let mut rng = Xoshiro256::seed_from_u64(5);
        let mut b = vec![0.0; 32];
        rng.fill_gaussian(&mut b, 1.0);
        let p = crate::solvers::RidgeProblem::new(dense, b.clone(), 0.6);
        let x: Vec<f64> = (0..8).map(|i| i as f64 * 0.1).collect();
        let gs = csr.ridge_gradient(&x, &b, 0.6);
        let gd = p.gradient(&x);
        for i in 0..8 {
            assert!((gs[i] - gd[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn append_rows_matches_concatenation() {
        let (mut csr, dense) = random_sparse(13, 7, 0.3, 30);
        let (delta, ddense) = random_sparse(5, 7, 0.4, 31);
        let before = csr.clone();
        csr.append_rows(&delta);
        assert_eq!((csr.rows(), csr.cols()), (18, 7));
        assert_eq!(csr.nnz(), before.nnz() + delta.nnz());
        // Retained rows' storage is bitwise untouched; new rows match.
        for i in 0..13 {
            assert_eq!(csr.row(i), before.row(i));
        }
        let mut full = dense.clone();
        full.append_rows(&ddense);
        assert!(csr.to_dense().max_abs_diff(&full) == 0.0);
        // Appending an empty-row block (including all-zero rows) is fine.
        let empty = CsrMatrix::from_triplets(2, 7, &[]);
        csr.append_rows(&empty);
        assert_eq!(csr.rows(), 20);
        assert_eq!(csr.row(19), (&[][..], &[][..]));
    }

    #[test]
    fn triplets_merge_duplicates_and_handle_empty_rows() {
        let csr = CsrMatrix::from_triplets(
            4,
            3,
            &[(0, 1, 2.0), (0, 1, 3.0), (2, 0, 1.0), (2, 2, -1.0)],
        );
        assert_eq!(csr.nnz(), 3);
        let d = csr.to_dense();
        assert_eq!(d.get(0, 1), 5.0);
        assert_eq!(d.get(1, 0), 0.0); // empty row
        assert_eq!(d.get(2, 2), -1.0);
    }

    #[test]
    fn empty_matrix_ok() {
        let csr = CsrMatrix::from_triplets(3, 3, &[]);
        assert_eq!(csr.nnz(), 0);
        assert_eq!(csr.matvec(&[1.0, 1.0, 1.0]), vec![0.0; 3]);
        assert_eq!(csr.transpose().nnz(), 0);
        assert_eq!(csr.gram().fro_norm(), 0.0);
    }
}
