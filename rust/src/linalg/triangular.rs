//! Triangular solves (forward / back substitution).
//!
//! These back the Cholesky-based ridge solves and the pCG baseline's
//! R-factor preconditioner applications — both on the per-iteration hot
//! path, so the loops are written over contiguous rows only.
//!
//! The `*_matrix_in_place` forms solve against an `n x k` block of
//! right-hand sides at once (row `i` of the block is updated by streams
//! of length-`k` fused loops — BLAS-3 arithmetic intensity instead of
//! `k` separate BLAS-2 sweeps over `L`). Above the
//! [`super::threads::worth_parallelizing`] threshold the `k` columns
//! split across scoped threads (one transpose puts each column
//! contiguous); every column is computed with the exact per-element
//! operation order of the serial vector kernels, so the block solves are
//! bitwise identical at any thread count *and* bitwise identical to `k`
//! independent vector solves.

use super::matrix::Matrix;
use super::threads;

/// Solve `L y = b` in place (`x` holds `b` on entry, the solution on
/// exit), `L` lower-triangular (entries above the diagonal are ignored).
/// Panics if a diagonal entry is exactly zero. The in-place forms are the
/// allocation-free primitives the iterative hot loops call.
pub fn solve_lower_in_place(l: &Matrix, x: &mut [f64]) {
    let n = l.rows();
    assert_eq!(l.cols(), n);
    assert_eq!(x.len(), n);
    for i in 0..n {
        let row = l.row(i);
        let mut s = x[i];
        // Contiguous prefix of row i times the solved prefix of x.
        for j in 0..i {
            s -= row[j] * x[j];
        }
        let d = row[i];
        assert!(d != 0.0, "singular lower-triangular matrix at {i}");
        x[i] = s / d;
    }
}

/// Solve `L y = b` with `L` lower-triangular (allocating wrapper).
pub fn solve_lower(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let mut y = b.to_vec();
    solve_lower_in_place(l, &mut y);
    y
}

/// Solve `U x = b` in place with `U` upper-triangular.
pub fn solve_upper_in_place(u: &Matrix, x: &mut [f64]) {
    let n = u.rows();
    assert_eq!(u.cols(), n);
    assert_eq!(x.len(), n);
    for i in (0..n).rev() {
        let row = u.row(i);
        let mut s = x[i];
        for j in i + 1..n {
            s -= row[j] * x[j];
        }
        let d = row[i];
        assert!(d != 0.0, "singular upper-triangular matrix at {i}");
        x[i] = s / d;
    }
}

/// Solve `U x = b` with `U` upper-triangular (allocating wrapper).
pub fn solve_upper(u: &Matrix, b: &[f64]) -> Vec<f64> {
    let mut x = b.to_vec();
    solve_upper_in_place(u, &mut x);
    x
}

/// Solve `L^T x = b` in place, without forming `L^T`.
pub fn solve_lower_transpose_in_place(l: &Matrix, x: &mut [f64]) {
    let n = l.rows();
    assert_eq!(l.cols(), n);
    assert_eq!(x.len(), n);
    for i in (0..n).rev() {
        let d = l.get(i, i);
        assert!(d != 0.0, "singular matrix at {i}");
        x[i] /= d;
        let xi = x[i];
        // Column i of L below the diagonal == row entries l[i][j], j < i;
        // iterate the row to stay contiguous in memory.
        for j in 0..i {
            x[j] -= l.get(i, j) * xi;
        }
    }
}

/// Solve `L^T x = b` with `L` lower-triangular (allocating wrapper).
pub fn solve_lower_transpose(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let mut x = b.to_vec();
    solve_lower_transpose_in_place(l, &mut x);
    x
}

/// Solve `U^T y = b` in place, without forming `U^T`.
pub fn solve_upper_transpose_in_place(u: &Matrix, y: &mut [f64]) {
    let n = u.rows();
    assert_eq!(u.cols(), n);
    assert_eq!(y.len(), n);
    for i in 0..n {
        let d = u.get(i, i);
        assert!(d != 0.0, "singular matrix at {i}");
        y[i] /= d;
        let yi = y[i];
        let row = u.row(i);
        for j in i + 1..n {
            y[j] -= row[j] * yi;
        }
    }
}

/// Solve `U^T y = b` with `U` upper-triangular (allocating wrapper).
pub fn solve_upper_transpose(u: &Matrix, b: &[f64]) -> Vec<f64> {
    let mut y = b.to_vec();
    solve_upper_transpose_in_place(u, &mut y);
    y
}

/// Effective thread count for an `n x n` triangular solve against `k`
/// right-hand sides (`0` work stays serial; parallelism is over columns).
fn block_threads(n: usize, k: usize) -> usize {
    let flops = n as f64 * n as f64 * k as f64;
    if k > 1 && threads::worth_parallelizing(flops) {
        threads::current().min(k)
    } else {
        1
    }
}

/// Run one vector triangular solve per column of `b` (`n x k`) across
/// threads: transpose once so each column is a contiguous row, deal the
/// columns to scoped workers, transpose back. Each column runs the exact
/// serial vector kernel, so the result is bitwise identical to `k`
/// sequential vector solves regardless of the thread count. Shared with
/// [`crate::linalg::cholesky::Cholesky::solve_matrix_in_place`], whose
/// fused forward+back per-column closure rides the same column dealing —
/// the determinism guarantee lives in exactly one place.
pub(super) fn solve_columns_parallel(b: &mut Matrix, t: usize, f: impl Fn(&mut [f64]) + Sync) {
    let n = b.rows();
    let mut bt = b.transpose();
    let jobs: Vec<&mut [f64]> = bt.as_mut_slice().chunks_mut(n).collect();
    threads::run_jobs(t, jobs, f);
    *b = bt.transpose();
}

/// Solve `L Y = B` in place for an `n x k` block `b` (`B` on entry, `Y`
/// on exit), `L` lower-triangular. Each element follows the serial
/// [`solve_lower_in_place`] operation order (subtract `l[i][j] * y[j]`
/// for `j` ascending, then divide), so the block solve is bitwise
/// identical to `k` vector solves at any thread count.
pub fn solve_lower_matrix_in_place(l: &Matrix, b: &mut Matrix) {
    let n = l.rows();
    assert_eq!(l.cols(), n);
    assert_eq!(b.rows(), n, "solve_lower_matrix dimension mismatch");
    let k = b.cols();
    if n == 0 || k == 0 {
        return;
    }
    let t = block_threads(n, k);
    if t > 1 {
        solve_columns_parallel(b, t, |col| solve_lower_in_place(l, col));
        return;
    }
    let data = b.as_mut_slice();
    for i in 0..n {
        let row = l.row(i);
        let (solved, rest) = data.split_at_mut(i * k);
        let bi = &mut rest[..k];
        for j in 0..i {
            let lij = row[j];
            let bj = &solved[j * k..(j + 1) * k];
            for (x, y) in bi.iter_mut().zip(bj) {
                *x -= lij * *y;
            }
        }
        let d = row[i];
        assert!(d != 0.0, "singular lower-triangular matrix at {i}");
        for x in bi.iter_mut() {
            *x /= d;
        }
    }
}

/// Solve `L^T Y = B` in place for an `n x k` block, without forming
/// `L^T`. Same per-element operation order as
/// [`solve_lower_transpose_in_place`], hence bitwise identical to `k`
/// vector solves at any thread count.
pub fn solve_lower_transpose_matrix_in_place(l: &Matrix, b: &mut Matrix) {
    let n = l.rows();
    assert_eq!(l.cols(), n);
    assert_eq!(b.rows(), n, "solve_lower_transpose_matrix dimension mismatch");
    let k = b.cols();
    if n == 0 || k == 0 {
        return;
    }
    let t = block_threads(n, k);
    if t > 1 {
        solve_columns_parallel(b, t, |col| solve_lower_transpose_in_place(l, col));
        return;
    }
    let data = b.as_mut_slice();
    for i in (0..n).rev() {
        let d = l.get(i, i);
        assert!(d != 0.0, "singular matrix at {i}");
        let (prefix, rest) = data.split_at_mut(i * k);
        let bi = &mut rest[..k];
        for x in bi.iter_mut() {
            *x /= d;
        }
        let lrow = l.row(i);
        for j in 0..i {
            let lij = lrow[j];
            let bj = &mut prefix[j * k..(j + 1) * k];
            for (x, y) in bj.iter_mut().zip(bi.iter()) {
                *x -= lij * *y;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn random_lower(n: usize, seed: u64) -> Matrix {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        Matrix::from_fn(n, n, |i, j| {
            if j < i {
                rng.next_gaussian() * 0.3
            } else if j == i {
                2.0 + rng.next_f64() // well away from zero
            } else {
                0.0
            }
        })
    }

    #[test]
    fn lower_solve_roundtrip() {
        let l = random_lower(9, 1);
        let x0: Vec<f64> = (0..9).map(|i| (i as f64 * 0.7).sin()).collect();
        let b = l.matvec(&x0);
        let x = solve_lower(&l, &b);
        for i in 0..9 {
            assert!((x[i] - x0[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn upper_solve_roundtrip() {
        let u = random_lower(9, 2).transpose();
        let x0: Vec<f64> = (0..9).map(|i| (i as f64 * 0.3).cos()).collect();
        let b = u.matvec(&x0);
        let x = solve_upper(&u, &b);
        for i in 0..9 {
            assert!((x[i] - x0[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn lower_transpose_solve_matches_explicit() {
        let l = random_lower(7, 3);
        let b: Vec<f64> = (0..7).map(|i| i as f64 + 1.0).collect();
        let x1 = solve_lower_transpose(&l, &b);
        let x2 = solve_upper(&l.transpose(), &b);
        for i in 0..7 {
            assert!((x1[i] - x2[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn upper_transpose_solve_matches_explicit() {
        let u = random_lower(7, 4).transpose();
        let b: Vec<f64> = (0..7).map(|i| (i as f64).sqrt()).collect();
        let y1 = solve_upper_transpose(&u, &b);
        let y2 = solve_lower(&u.transpose(), &b);
        for i in 0..7 {
            assert!((y1[i] - y2[i]).abs() < 1e-10);
        }
    }

    #[test]
    #[should_panic(expected = "singular")]
    fn singular_diagonal_panics() {
        let mut l = Matrix::eye(3);
        l.set(1, 1, 0.0);
        solve_lower(&l, &[1.0, 1.0, 1.0]);
    }

    fn random_block(n: usize, k: usize, seed: u64) -> Matrix {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        Matrix::from_fn(n, k, |_, _| rng.next_gaussian())
    }

    #[test]
    fn block_lower_solve_bitwise_matches_vector_solves() {
        let l = random_lower(13, 5);
        let b = random_block(13, 7, 6);
        let mut blk = b.clone();
        solve_lower_matrix_in_place(&l, &mut blk);
        for j in 0..7 {
            let col: Vec<f64> = (0..13).map(|i| b.get(i, j)).collect();
            let x = solve_lower(&l, &col);
            for i in 0..13 {
                assert_eq!(blk.get(i, j), x[i], "col {j} row {i}");
            }
        }
    }

    #[test]
    fn block_lower_transpose_solve_bitwise_matches_vector_solves() {
        let l = random_lower(11, 7);
        let b = random_block(11, 4, 8);
        let mut blk = b.clone();
        solve_lower_transpose_matrix_in_place(&l, &mut blk);
        for j in 0..4 {
            let col: Vec<f64> = (0..11).map(|i| b.get(i, j)).collect();
            let x = solve_lower_transpose(&l, &col);
            for i in 0..11 {
                assert_eq!(blk.get(i, j), x[i], "col {j} row {i}");
            }
        }
    }

    #[test]
    fn block_solves_bitwise_thread_invariant() {
        use crate::linalg::threads::with_threads;
        // 512^2 * 8 ~ 2e6 flops crosses the parallel threshold.
        let l = random_lower(512, 9);
        let b = random_block(512, 8, 10);
        let serial = with_threads(1, || {
            let mut x = b.clone();
            solve_lower_matrix_in_place(&l, &mut x);
            solve_lower_transpose_matrix_in_place(&l, &mut x);
            x
        });
        for t in [2, 3, 8] {
            let par = with_threads(t, || {
                let mut x = b.clone();
                solve_lower_matrix_in_place(&l, &mut x);
                solve_lower_transpose_matrix_in_place(&l, &mut x);
                x
            });
            assert_eq!(par, serial, "threads={t}");
        }
    }
}
