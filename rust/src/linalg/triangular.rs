//! Triangular solves (forward / back substitution).
//!
//! These back the Cholesky-based ridge solves and the pCG baseline's
//! R-factor preconditioner applications — both on the per-iteration hot
//! path, so the loops are written over contiguous rows only.

use super::matrix::Matrix;

/// Solve `L y = b` in place (`x` holds `b` on entry, the solution on
/// exit), `L` lower-triangular (entries above the diagonal are ignored).
/// Panics if a diagonal entry is exactly zero. The in-place forms are the
/// allocation-free primitives the iterative hot loops call.
pub fn solve_lower_in_place(l: &Matrix, x: &mut [f64]) {
    let n = l.rows();
    assert_eq!(l.cols(), n);
    assert_eq!(x.len(), n);
    for i in 0..n {
        let row = l.row(i);
        let mut s = x[i];
        // Contiguous prefix of row i times the solved prefix of x.
        for j in 0..i {
            s -= row[j] * x[j];
        }
        let d = row[i];
        assert!(d != 0.0, "singular lower-triangular matrix at {i}");
        x[i] = s / d;
    }
}

/// Solve `L y = b` with `L` lower-triangular (allocating wrapper).
pub fn solve_lower(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let mut y = b.to_vec();
    solve_lower_in_place(l, &mut y);
    y
}

/// Solve `U x = b` in place with `U` upper-triangular.
pub fn solve_upper_in_place(u: &Matrix, x: &mut [f64]) {
    let n = u.rows();
    assert_eq!(u.cols(), n);
    assert_eq!(x.len(), n);
    for i in (0..n).rev() {
        let row = u.row(i);
        let mut s = x[i];
        for j in i + 1..n {
            s -= row[j] * x[j];
        }
        let d = row[i];
        assert!(d != 0.0, "singular upper-triangular matrix at {i}");
        x[i] = s / d;
    }
}

/// Solve `U x = b` with `U` upper-triangular (allocating wrapper).
pub fn solve_upper(u: &Matrix, b: &[f64]) -> Vec<f64> {
    let mut x = b.to_vec();
    solve_upper_in_place(u, &mut x);
    x
}

/// Solve `L^T x = b` in place, without forming `L^T`.
pub fn solve_lower_transpose_in_place(l: &Matrix, x: &mut [f64]) {
    let n = l.rows();
    assert_eq!(l.cols(), n);
    assert_eq!(x.len(), n);
    for i in (0..n).rev() {
        let d = l.get(i, i);
        assert!(d != 0.0, "singular matrix at {i}");
        x[i] /= d;
        let xi = x[i];
        // Column i of L below the diagonal == row entries l[i][j], j < i;
        // iterate the row to stay contiguous in memory.
        for j in 0..i {
            x[j] -= l.get(i, j) * xi;
        }
    }
}

/// Solve `L^T x = b` with `L` lower-triangular (allocating wrapper).
pub fn solve_lower_transpose(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let mut x = b.to_vec();
    solve_lower_transpose_in_place(l, &mut x);
    x
}

/// Solve `U^T y = b` in place, without forming `U^T`.
pub fn solve_upper_transpose_in_place(u: &Matrix, y: &mut [f64]) {
    let n = u.rows();
    assert_eq!(u.cols(), n);
    assert_eq!(y.len(), n);
    for i in 0..n {
        let d = u.get(i, i);
        assert!(d != 0.0, "singular matrix at {i}");
        y[i] /= d;
        let yi = y[i];
        let row = u.row(i);
        for j in i + 1..n {
            y[j] -= row[j] * yi;
        }
    }
}

/// Solve `U^T y = b` with `U` upper-triangular (allocating wrapper).
pub fn solve_upper_transpose(u: &Matrix, b: &[f64]) -> Vec<f64> {
    let mut y = b.to_vec();
    solve_upper_transpose_in_place(u, &mut y);
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn random_lower(n: usize, seed: u64) -> Matrix {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        Matrix::from_fn(n, n, |i, j| {
            if j < i {
                rng.next_gaussian() * 0.3
            } else if j == i {
                2.0 + rng.next_f64() // well away from zero
            } else {
                0.0
            }
        })
    }

    #[test]
    fn lower_solve_roundtrip() {
        let l = random_lower(9, 1);
        let x0: Vec<f64> = (0..9).map(|i| (i as f64 * 0.7).sin()).collect();
        let b = l.matvec(&x0);
        let x = solve_lower(&l, &b);
        for i in 0..9 {
            assert!((x[i] - x0[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn upper_solve_roundtrip() {
        let u = random_lower(9, 2).transpose();
        let x0: Vec<f64> = (0..9).map(|i| (i as f64 * 0.3).cos()).collect();
        let b = u.matvec(&x0);
        let x = solve_upper(&u, &b);
        for i in 0..9 {
            assert!((x[i] - x0[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn lower_transpose_solve_matches_explicit() {
        let l = random_lower(7, 3);
        let b: Vec<f64> = (0..7).map(|i| i as f64 + 1.0).collect();
        let x1 = solve_lower_transpose(&l, &b);
        let x2 = solve_upper(&l.transpose(), &b);
        for i in 0..7 {
            assert!((x1[i] - x2[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn upper_transpose_solve_matches_explicit() {
        let u = random_lower(7, 4).transpose();
        let b: Vec<f64> = (0..7).map(|i| (i as f64).sqrt()).collect();
        let y1 = solve_upper_transpose(&u, &b);
        let y2 = solve_lower(&u.transpose(), &b);
        for i in 0..7 {
            assert!((y1[i] - y2[i]).abs() < 1e-10);
        }
    }

    #[test]
    #[should_panic(expected = "singular")]
    fn singular_diagonal_panics() {
        let mut l = Matrix::eye(3);
        l.set(1, 1, 0.0);
        solve_lower(&l, &[1.0, 1.0, 1.0]);
    }
}
