//! Householder QR factorization.
//!
//! The pCG baseline (Rokhlin–Tygert) preconditions CG on the ridge system
//! with the R-factor of the sketched matrix `[SA; nu I]`; this module
//! provides the thin QR it needs, plus `q_explicit` for tests and for the
//! SVD's re-orthogonalization step.

use super::matrix::Matrix;
use super::{axpy, dot, norm2};

/// Compact Householder QR of an `m x n` matrix with `m >= n`:
/// stores the Householder vectors in-place below R.
#[derive(Clone, Debug)]
pub struct QR {
    /// Upper triangle holds R; columns below the diagonal hold the
    /// (unnormalized tail of the) Householder vectors.
    qr: Matrix,
    /// Scalar `tau_k = 2 / ||v_k||^2` per reflector (0 for a no-op).
    tau: Vec<f64>,
}

impl QR {
    /// Factor `a` (consumed) into QR. Requires `rows >= cols`.
    pub fn factor(mut a: Matrix) -> Self {
        let (m, n) = (a.rows(), a.cols());
        assert!(m >= n, "QR requires rows >= cols (got {m} x {n})");
        let mut tau = vec![0.0; n];
        let mut v = vec![0.0; m];
        for k in 0..n {
            // Build the reflector for column k, rows k..m.
            let mut alpha = 0.0;
            for i in k..m {
                let x = a.get(i, k);
                v[i] = x;
                alpha += x * x;
            }
            alpha = alpha.sqrt();
            if alpha == 0.0 {
                tau[k] = 0.0;
                continue;
            }
            // Choose the sign that avoids cancellation.
            if v[k] > 0.0 {
                alpha = -alpha;
            }
            v[k] -= alpha;
            let vnorm2 = dot(&v[k..m], &v[k..m]);
            if vnorm2 == 0.0 {
                tau[k] = 0.0;
                a.set(k, k, alpha);
                continue;
            }
            let t = 2.0 / vnorm2;
            tau[k] = t;
            // Apply I - t v v^T to the trailing columns k..n.
            for j in k..n {
                let mut s = 0.0;
                for i in k..m {
                    s += v[i] * a.get(i, j);
                }
                let st = s * t;
                for i in k..m {
                    let val = a.get(i, j) - st * v[i];
                    a.set(i, j, val);
                }
            }
            // Store: R_kk = alpha already set by the reflection
            // (a[k][k] == alpha up to roundoff); stash v tail below.
            for i in k + 1..m {
                a.set(i, k, v[i] / v[k]); // scaled so v[k] == 1 implicitly
            }
            // Rescale tau to account for the v[k]=1 normalization.
            tau[k] = t * v[k] * v[k];
        }
        Self { qr: a, tau }
    }

    /// Number of rows of the original matrix.
    pub fn rows(&self) -> usize {
        self.qr.rows()
    }

    /// Number of columns of the original matrix.
    pub fn cols(&self) -> usize {
        self.qr.cols()
    }

    /// Extract the thin `n x n` upper-triangular factor R.
    pub fn r(&self) -> Matrix {
        let n = self.qr.cols();
        Matrix::from_fn(n, n, |i, j| if j >= i { self.qr.get(i, j) } else { 0.0 })
    }

    /// Apply `Q^T` to a length-`m` vector in place.
    pub fn apply_qt(&self, x: &mut [f64]) {
        let (m, n) = (self.qr.rows(), self.qr.cols());
        assert_eq!(x.len(), m);
        for k in 0..n {
            let t = self.tau[k];
            if t == 0.0 {
                continue;
            }
            // v = [1, qr[k+1..m][k]]
            let mut s = x[k];
            for i in k + 1..m {
                s += self.qr.get(i, k) * x[i];
            }
            let st = s * t;
            x[k] -= st;
            for i in k + 1..m {
                x[i] -= st * self.qr.get(i, k);
            }
        }
    }

    /// Apply `Q` to a length-`m` vector in place.
    pub fn apply_q(&self, x: &mut [f64]) {
        let (m, n) = (self.qr.rows(), self.qr.cols());
        assert_eq!(x.len(), m);
        for k in (0..n).rev() {
            let t = self.tau[k];
            if t == 0.0 {
                continue;
            }
            let mut s = x[k];
            for i in k + 1..m {
                s += self.qr.get(i, k) * x[i];
            }
            let st = s * t;
            x[k] -= st;
            for i in k + 1..m {
                x[i] -= st * self.qr.get(i, k);
            }
        }
    }

    /// Materialize the thin `m x n` orthonormal factor Q.
    pub fn q_thin(&self) -> Matrix {
        let (m, n) = (self.qr.rows(), self.qr.cols());
        let mut q = Matrix::zeros(m, n);
        let mut e = vec![0.0; m];
        for j in 0..n {
            e.iter_mut().for_each(|x| *x = 0.0);
            e[j] = 1.0;
            self.apply_q(&mut e);
            for i in 0..m {
                q.set(i, j, e[i]);
            }
        }
        q
    }

    /// Least-squares solve `min ||a x - b||` using the factorization.
    pub fn solve_ls(&self, b: &[f64]) -> Vec<f64> {
        let n = self.qr.cols();
        let mut y = b.to_vec();
        self.apply_qt(&mut y);
        // Back substitution on the top n x n triangle.
        let mut x = y[..n].to_vec();
        for i in (0..n).rev() {
            let mut s = x[i];
            for j in i + 1..n {
                s -= self.qr.get(i, j) * x[j];
            }
            let d = self.qr.get(i, i);
            assert!(d != 0.0, "rank-deficient R at {i}");
            x[i] = s / d;
        }
        x
    }
}

/// Modified Gram–Schmidt orthonormalization of the columns of `a`
/// (returns an `m x n` matrix with orthonormal columns). Used where a full
/// Householder Q would be overkill.
pub fn mgs_orthonormalize(a: &Matrix) -> Matrix {
    let (m, n) = (a.rows(), a.cols());
    let mut q = a.transpose(); // work on rows = original columns
    let mut qj_copy = vec![0.0; m];
    for k in 0..n {
        // Re-orthogonalize twice for stability ("twice is enough").
        for _ in 0..2 {
            for j in 0..k {
                qj_copy.copy_from_slice(q.row(j));
                let c = dot(q.row(k), &qj_copy);
                axpy(-c, &qj_copy, q.row_mut(k));
            }
        }
        let nrm = norm2(q.row(k));
        if nrm > 0.0 {
            let inv = 1.0 / nrm;
            for x in q.row_mut(k) {
                *x *= inv;
            }
        }
    }
    q.transpose()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn test_mat(m: usize, n: usize, seed: u64) -> Matrix {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        Matrix::from_fn(m, n, |_, _| rng.next_gaussian())
    }

    #[test]
    fn qr_reconstructs() {
        for &(m, n) in &[(5, 5), (12, 7), (33, 8)] {
            let a = test_mat(m, n, 1);
            let f = QR::factor(a.clone());
            let rec = f.q_thin().matmul(&f.r());
            assert!(rec.max_abs_diff(&a) < 1e-9, "({m},{n})");
        }
    }

    #[test]
    fn q_is_orthonormal() {
        let a = test_mat(20, 6, 2);
        let q = QR::factor(a).q_thin();
        let qtq = q.gram();
        assert!(qtq.max_abs_diff(&Matrix::eye(6)) < 1e-10);
    }

    #[test]
    fn qt_then_q_roundtrip() {
        let a = test_mat(15, 4, 3);
        let f = QR::factor(a);
        let x0: Vec<f64> = (0..15).map(|i| (i as f64 * 0.3).sin()).collect();
        let mut x = x0.clone();
        f.apply_qt(&mut x);
        f.apply_q(&mut x);
        for i in 0..15 {
            assert!((x[i] - x0[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn least_squares_matches_normal_equations() {
        let a = test_mat(25, 5, 4);
        let b: Vec<f64> = (0..25).map(|i| (i as f64 * 0.17).cos()).collect();
        let x_qr = QR::factor(a.clone()).solve_ls(&b);
        // Normal equations solution.
        let g = a.gram();
        let rhs = a.matvec_t(&b);
        let x_ne = crate::linalg::cholesky::Cholesky::factor(&g).unwrap().solve(&rhs);
        for i in 0..5 {
            assert!((x_qr[i] - x_ne[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn mgs_orthonormal_columns() {
        let a = test_mat(18, 5, 5);
        let q = mgs_orthonormalize(&a);
        assert!(q.gram().max_abs_diff(&Matrix::eye(5)) < 1e-10);
        // Span preserved: a's columns representable by q.
        let proj = q.matmul(&q.transpose().matmul(&a));
        assert!(proj.max_abs_diff(&a) < 1e-8);
    }
}
