//! Thread-count knob and scoped-thread helpers for the parallel dense
//! kernels (GEMM, FWHT, Gram products).
//!
//! Resolution order for the effective thread count, highest priority
//! first:
//!
//! 1. a per-thread override installed with [`with_threads`] — this is what
//!    the `@threads=k` solver-spec parameter and the coordinator's
//!    `"threads"` request field use, so concurrent jobs on different
//!    worker threads cannot trample each other's setting;
//! 2. the process-wide value set with [`set_global_threads`];
//! 3. the `PALLAS_THREADS` environment variable;
//! 4. [`std::thread::available_parallelism`].
//!
//! Kernels spawn plain `std::thread::scope` workers (no pool, no external
//! crates); each parallel region costs a few spawns, so the kernels only
//! split work above a minimum size ([`worth_parallelizing`]).
//!
//! Determinism note: *every* parallel kernel is bitwise identical at any
//! thread count. Partition-style kernels (GEMM, `gram_outer`, `matmul_nt`,
//! FWHT, CSR matvec / `left_mul`) compute each output element with the
//! same operation order as the serial kernels. Reduction-style kernels
//! (`Matrix::gram`, CSR `matvec_t` / `gram`) split their input rows into
//! [`REDUCE_PARTS`] *fixed* chunks — a partition of the data, not of the
//! workers — and combine the per-chunk partials in chunk order, so the
//! summation tree depends only on the matrix shape, never on how many
//! threads executed the chunks.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Fixed chunk count for reduction-style parallel kernels
/// (`Matrix::gram`, CSR `matvec_t` / `gram`): inputs above the
/// [`worth_parallelizing`] threshold always split into this many row
/// chunks (regardless of the thread count executing them), and the
/// per-chunk partials are reduced in chunk order — making the floating-
/// point summation tree a function of the matrix shape alone, hence
/// bitwise identical at any thread count. Also caps those kernels'
/// parallelism; 8 balances spawn overhead against partial-buffer memory
/// (`REDUCE_PARTS * d^2` for the Gram kernels).
pub const REDUCE_PARTS: usize = 8;

/// Process-wide thread count; 0 = unset (fall through to env / hardware).
static GLOBAL: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// Per-thread override; 0 = unset.
    static OVERRIDE: Cell<usize> = const { Cell::new(0) };
}

/// `PALLAS_THREADS` env var if valid, else the hardware parallelism.
fn default_threads() -> usize {
    static DEFAULT: OnceLock<usize> = OnceLock::new();
    *DEFAULT.get_or_init(|| {
        if let Ok(v) = std::env::var("PALLAS_THREADS") {
            if let Ok(k) = v.trim().parse::<usize>() {
                if k >= 1 {
                    return k;
                }
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

/// The thread count the kernels will use right now on this thread.
pub fn current() -> usize {
    let local = OVERRIDE.with(Cell::get);
    if local != 0 {
        return local;
    }
    let global = GLOBAL.load(Ordering::Relaxed);
    if global != 0 {
        return global;
    }
    default_threads()
}

/// Set the process-wide thread count (`0` resets to the env/hardware
/// default). Per-thread [`with_threads`] overrides still win.
pub fn set_global_threads(k: usize) {
    GLOBAL.store(k, Ordering::Relaxed);
}

/// Run `f` with the kernels pinned to `k` threads on the calling thread
/// (restored on exit, including on panic). `k = 0` means "default".
pub fn with_threads<R>(k: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            OVERRIDE.with(|c| c.set(self.0));
        }
    }
    let _guard = Restore(OVERRIDE.with(|c| c.replace(k)));
    f()
}

/// Whether a kernel of roughly `flops` floating-point operations is worth
/// splitting across threads: below this, spawn overhead (~tens of
/// microseconds per scoped thread) dominates the work itself.
pub fn worth_parallelizing(flops: f64) -> bool {
    flops >= 4e5
}

/// Run `jobs` on up to `threads` scoped threads; the calling thread works
/// too, so `threads = 1` never spawns. Jobs are dealt round-robin, which
/// balances triangular workloads (e.g. `gram_outer` rows) without a queue.
/// A panic in any job propagates to the caller when the scope joins.
pub fn run_jobs<J, F>(threads: usize, jobs: Vec<J>, f: F)
where
    J: Send,
    F: Fn(J) + Sync,
{
    let t = threads.clamp(1, jobs.len().max(1));
    if t == 1 {
        for job in jobs {
            f(job);
        }
        return;
    }
    let mut buckets: Vec<Vec<J>> = (0..t).map(|_| Vec::new()).collect();
    for (i, job) in jobs.into_iter().enumerate() {
        buckets[i % t].push(job);
    }
    let f = &f;
    std::thread::scope(|s| {
        let mut buckets = buckets.into_iter();
        let own = buckets.next().unwrap();
        for bucket in buckets {
            s.spawn(move || {
                for job in bucket {
                    f(job);
                }
            });
        }
        for job in own {
            f(job);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_at_least_one() {
        assert!(current() >= 1);
    }

    #[test]
    fn with_threads_scopes_and_restores() {
        let before = current();
        let inside = with_threads(3, current);
        assert_eq!(inside, 3);
        assert_eq!(current(), before);
        // Nesting: innermost wins.
        let nested = with_threads(2, || with_threads(5, current));
        assert_eq!(nested, 5);
    }

    #[test]
    fn with_threads_restores_on_panic() {
        let before = current();
        let result = std::panic::catch_unwind(|| with_threads(7, || panic!("boom")));
        assert!(result.is_err());
        assert_eq!(current(), before);
    }

    #[test]
    fn override_is_per_thread() {
        with_threads(4, || {
            let other = std::thread::spawn(current).join().unwrap();
            // The spawned thread sees the default, not this thread's 4.
            assert_ne!(other, 0);
            assert_eq!(current(), 4);
        });
    }

    #[test]
    fn run_jobs_executes_every_job_once() {
        use std::sync::atomic::AtomicU64;
        for threads in [1, 2, 5, 16] {
            let hits = AtomicU64::new(0);
            let jobs: Vec<u64> = (0..37).collect();
            run_jobs(threads, jobs, |j| {
                hits.fetch_add(1 << (j % 63), Ordering::Relaxed);
            });
            // 37 distinct jobs, each adding a distinct power of two
            // (mod 63): the sum is independent of scheduling.
            let expect: u64 = (0..37u64).map(|j| 1 << (j % 63)).sum();
            assert_eq!(hits.load(Ordering::Relaxed), expect, "threads={threads}");
        }
    }

    #[test]
    fn run_jobs_mutable_slices() {
        let mut data = vec![0.0f64; 64];
        let jobs: Vec<(usize, &mut [f64])> = data.chunks_mut(8).enumerate().collect();
        run_jobs(4, jobs, |(i, chunk)| {
            for (k, x) in chunk.iter_mut().enumerate() {
                *x = (i * 8 + k) as f64;
            }
        });
        for (k, x) in data.iter().enumerate() {
            assert_eq!(*x, k as f64);
        }
    }

    #[test]
    fn worth_parallelizing_thresholds() {
        assert!(!worth_parallelizing(1e3));
        assert!(worth_parallelizing(1e7));
    }
}
