//! The data-matrix operand: dense or CSR, one type for every layer.
//!
//! The paper's complexity claims are stated per *operation on `A`* —
//! sketch application, matvec, gradient — and its experimental regime
//! (bag-of-words / one-hot features) is overwhelmingly sparse. [`Operand`]
//! is the enum every subsystem consumes ([`crate::solvers::RidgeProblem`]
//! owns one; the sketch engine, solvers, coordinator and CLI dispatch on
//! it), so a 1%-dense input pays `O(nnz)` instead of `O(n d)` on every
//! hot operation while dense inputs keep the exact dense kernels they had
//! before (`Operand::Dense` is a transparent wrapper — same code paths,
//! same results).
//!
//! [`OperandRef`] is the borrowed view used at API boundaries: functions
//! that only *read* the matrix accept `impl Into<OperandRef>` so callers
//! can pass `&Matrix`, `&CsrMatrix`, or `&Operand` without cloning.

use super::matrix::Matrix;
use super::sparse::CsrMatrix;
use std::borrow::Cow;

/// Owned data matrix: dense row-major or CSR.
#[derive(Clone, Debug, PartialEq)]
pub enum Operand {
    /// Dense row-major storage.
    Dense(Matrix),
    /// Compressed sparse row storage.
    Sparse(CsrMatrix),
}

/// Borrowed view of an [`Operand`] (or of a bare `Matrix` / `CsrMatrix`).
#[derive(Clone, Copy)]
pub enum OperandRef<'a> {
    /// Borrowed dense matrix.
    Dense(&'a Matrix),
    /// Borrowed CSR matrix.
    Sparse(&'a CsrMatrix),
}

impl From<Matrix> for Operand {
    fn from(m: Matrix) -> Self {
        Operand::Dense(m)
    }
}

impl From<CsrMatrix> for Operand {
    fn from(c: CsrMatrix) -> Self {
        Operand::Sparse(c)
    }
}

impl<'a> From<&'a Matrix> for OperandRef<'a> {
    fn from(m: &'a Matrix) -> Self {
        OperandRef::Dense(m)
    }
}

impl<'a> From<&'a CsrMatrix> for OperandRef<'a> {
    fn from(c: &'a CsrMatrix) -> Self {
        OperandRef::Sparse(c)
    }
}

impl<'a> From<&'a Operand> for OperandRef<'a> {
    fn from(o: &'a Operand) -> Self {
        o.as_ref()
    }
}

impl Operand {
    /// Borrowed view for read-only kernel dispatch.
    pub fn as_ref(&self) -> OperandRef<'_> {
        match self {
            Operand::Dense(m) => OperandRef::Dense(m),
            Operand::Sparse(c) => OperandRef::Sparse(c),
        }
    }

    /// Row count `n`.
    pub fn rows(&self) -> usize {
        self.as_ref().rows()
    }

    /// Column count `d`.
    pub fn cols(&self) -> usize {
        self.as_ref().cols()
    }

    /// Stored entries: `nnz` for CSR, `rows * cols` for dense.
    pub fn nnz(&self) -> usize {
        self.as_ref().nnz()
    }

    /// `nnz / (rows * cols)`; 1.0 for dense storage.
    pub fn density(&self) -> f64 {
        self.as_ref().density()
    }

    /// Whether this operand uses CSR storage.
    pub fn is_sparse(&self) -> bool {
        matches!(self, Operand::Sparse(_))
    }

    /// The dense matrix: borrowed for `Dense`, an `O(n d)` densification
    /// for `Sparse` — oracle / diagnostic paths only (SVD spectra, the
    /// at-cap exact-Hessian fallback), never the per-iteration hot loop.
    pub fn dense(&self) -> Cow<'_, Matrix> {
        match self {
            Operand::Dense(m) => Cow::Borrowed(m),
            Operand::Sparse(c) => Cow::Owned(c.to_dense()),
        }
    }

    /// The dense matrix, if this operand is dense.
    pub fn as_dense(&self) -> Option<&Matrix> {
        match self {
            Operand::Dense(m) => Some(m),
            Operand::Sparse(_) => None,
        }
    }

    /// The CSR matrix, if this operand is sparse.
    pub fn as_csr(&self) -> Option<&CsrMatrix> {
        match self {
            Operand::Dense(_) => None,
            Operand::Sparse(c) => Some(c),
        }
    }

    /// Append `delta`'s rows below the existing ones — the streaming-ingest
    /// primitive. Storage follows the *receiver*: dense + anything stacks
    /// densely (`O(Δn · d)`), CSR + anything appends in CSR (`O(nnz(Δ))`
    /// when `delta` is sparse). Retained rows are never rewritten.
    pub fn append_rows(&mut self, delta: &Operand) {
        assert_eq!(self.cols(), delta.cols(), "append_rows column mismatch");
        match (&mut *self, delta) {
            (Operand::Dense(m), Operand::Dense(dm)) => m.append_rows(dm),
            (Operand::Dense(m), Operand::Sparse(dc)) => m.append_rows(&dc.to_dense()),
            (Operand::Sparse(c), Operand::Sparse(dc)) => c.append_rows(dc),
            (Operand::Sparse(c), Operand::Dense(dm)) => {
                c.append_rows(&CsrMatrix::from_dense(dm))
            }
        }
    }

    /// Drop every row past `rows` — the exact inverse of
    /// [`Operand::append_rows`], used by the sessions' transactional
    /// rollback: a failed append truncates back to the pre-append row
    /// count and the retained rows are bitwise what they were.
    pub fn truncate_rows(&mut self, rows: usize) {
        match self {
            Operand::Dense(m) => m.truncate_rows(rows),
            Operand::Sparse(c) => c.truncate_rows(rows),
        }
    }

    /// `A^T` — `O(rows * cols)` dense, `O(nnz)` CSR counting sort.
    pub fn transpose(&self) -> Operand {
        match self {
            Operand::Dense(m) => Operand::Dense(m.transpose()),
            Operand::Sparse(c) => Operand::Sparse(c.transpose()),
        }
    }

    /// `A x` (`O(nd)` dense, `O(nnz)` CSR).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        self.as_ref().matvec(x)
    }

    /// `A^T x`.
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        self.as_ref().matvec_t(x)
    }

    /// `y = A x` into a caller buffer.
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        self.as_ref().matvec_into(x, y)
    }

    /// `y = A^T x` into a caller buffer.
    pub fn matvec_t_into(&self, x: &[f64], y: &mut [f64]) {
        self.as_ref().matvec_t_into(x, y)
    }

    /// `y += A^T x`.
    pub fn matvec_t_add(&self, x: &[f64], y: &mut [f64]) {
        self.as_ref().matvec_t_add(x, y)
    }

    /// `A^T A` (`cols x cols`).
    pub fn gram(&self) -> Matrix {
        self.as_ref().gram()
    }

    /// `A A^T` (`rows x rows`).
    pub fn gram_outer(&self) -> Matrix {
        self.as_ref().gram_outer()
    }

    /// Block product `A * X` for a dense `cols x k` block: blocked GEMM
    /// on dense operands, `O(nnz * k)` SpMM on CSR. The BLAS-3 primitive
    /// of the multi-RHS solve path ([`crate::solvers::block`]).
    pub fn matmul(&self, x: &Matrix) -> Matrix {
        self.as_ref().matmul(x)
    }

    /// Block product `A^T * Y` for a dense `rows x k` block (`O(n d k)`
    /// dense, `O(nnz * k)` CSR), without forming the transpose.
    pub fn matmul_t(&self, y: &Matrix) -> Matrix {
        self.as_ref().matmul_t(y)
    }
}

impl<'a> OperandRef<'a> {
    /// Row count `n`.
    pub fn rows(&self) -> usize {
        match self {
            OperandRef::Dense(m) => m.rows(),
            OperandRef::Sparse(c) => c.rows(),
        }
    }

    /// Column count `d`.
    pub fn cols(&self) -> usize {
        match self {
            OperandRef::Dense(m) => m.cols(),
            OperandRef::Sparse(c) => c.cols(),
        }
    }

    /// Stored entries: `nnz` for CSR, `rows * cols` for dense.
    pub fn nnz(&self) -> usize {
        match self {
            OperandRef::Dense(m) => m.rows() * m.cols(),
            OperandRef::Sparse(c) => c.nnz(),
        }
    }

    /// `nnz / (rows * cols)`; 1.0 for dense storage.
    pub fn density(&self) -> f64 {
        match self {
            OperandRef::Dense(_) => 1.0,
            OperandRef::Sparse(c) => c.density(),
        }
    }

    /// Whether the viewed operand uses CSR storage.
    pub fn is_sparse(&self) -> bool {
        matches!(self, OperandRef::Sparse(_))
    }

    /// `y = A x` into a caller buffer (`O(nd)` dense, `O(nnz)` CSR).
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        match self {
            OperandRef::Dense(m) => m.matvec_into(x, y),
            OperandRef::Sparse(c) => c.matvec_into(x, y),
        }
    }

    /// `A x` (allocating wrapper around [`OperandRef::matvec_into`]).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.rows()];
        self.matvec_into(x, &mut y);
        y
    }

    /// `y = A^T x` into a caller buffer.
    pub fn matvec_t_into(&self, x: &[f64], y: &mut [f64]) {
        match self {
            OperandRef::Dense(m) => m.matvec_t_into(x, y),
            OperandRef::Sparse(c) => c.matvec_t_into(x, y),
        }
    }

    /// `y += A^T x`.
    pub fn matvec_t_add(&self, x: &[f64], y: &mut [f64]) {
        match self {
            OperandRef::Dense(m) => m.matvec_t_add(x, y),
            OperandRef::Sparse(c) => c.matvec_t_add(x, y),
        }
    }

    /// `A^T x` (allocating wrapper around [`OperandRef::matvec_t_add`]).
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.cols()];
        self.matvec_t_add(x, &mut y);
        y
    }

    /// `A^T A` (`cols x cols`).
    pub fn gram(&self) -> Matrix {
        match self {
            OperandRef::Dense(m) => m.gram(),
            OperandRef::Sparse(c) => c.gram(),
        }
    }

    /// `A A^T` (`rows x rows`).
    pub fn gram_outer(&self) -> Matrix {
        match self {
            OperandRef::Dense(m) => m.gram_outer(),
            OperandRef::Sparse(c) => c.gram_outer(),
        }
    }

    /// Block product `A * X` (`cols x k` block; GEMM dense, SpMM CSR).
    pub fn matmul(&self, x: &Matrix) -> Matrix {
        match self {
            OperandRef::Dense(m) => m.matmul(x),
            OperandRef::Sparse(c) => c.matmul(x),
        }
    }

    /// Block product `A^T * Y` (`rows x k` block), transpose-free.
    pub fn matmul_t(&self, y: &Matrix) -> Matrix {
        match self {
            OperandRef::Dense(m) => m.matmul_tn(y),
            OperandRef::Sparse(c) => c.matmul_t(y),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn twin(rows: usize, cols: usize, density: f64, seed: u64) -> (Operand, Operand) {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let dense = Matrix::from_fn(rows, cols, |_, _| {
            if rng.next_f64() < density {
                rng.next_gaussian()
            } else {
                0.0
            }
        });
        let csr = CsrMatrix::from_dense(&dense);
        (Operand::Dense(dense), Operand::Sparse(csr))
    }

    #[test]
    fn variants_agree_on_every_kernel() {
        let (od, os) = twin(21, 9, 0.3, 1);
        assert_eq!((od.rows(), od.cols()), (os.rows(), os.cols()));
        assert!(os.nnz() < od.nnz());
        assert!(os.density() < 1.0 && od.density() == 1.0);
        let x: Vec<f64> = (0..9).map(|i| (i as f64 * 0.4).sin()).collect();
        let xt: Vec<f64> = (0..21).map(|i| (i as f64 * 0.2).cos()).collect();
        let (mvd, mvs) = (od.matvec(&x), os.matvec(&x));
        let (mtd, mts) = (od.matvec_t(&xt), os.matvec_t(&xt));
        for i in 0..21 {
            assert!((mvd[i] - mvs[i]).abs() < 1e-12);
        }
        for j in 0..9 {
            assert!((mtd[j] - mts[j]).abs() < 1e-12);
        }
        assert!(od.gram().max_abs_diff(&os.gram()) < 1e-12);
        assert!(od.gram_outer().max_abs_diff(&os.gram_outer()) < 1e-12);
        // Block kernels agree across storage too.
        let xb = Matrix::from_fn(9, 4, |i, j| ((i * 4 + j) as f64 * 0.21).sin());
        let yb = Matrix::from_fn(21, 3, |i, j| ((i * 3 + j) as f64 * 0.13).cos());
        assert!(od.matmul(&xb).max_abs_diff(&os.matmul(&xb)) < 1e-12);
        assert!(od.matmul_t(&yb).max_abs_diff(&os.matmul_t(&yb)) < 1e-12);
        assert!(od
            .transpose()
            .dense()
            .max_abs_diff(&os.transpose().dense()) < 1e-12);
    }

    #[test]
    fn dense_view_borrows_for_dense_and_densifies_csr() {
        let (od, os) = twin(7, 5, 0.4, 2);
        assert!(matches!(od.dense(), Cow::Borrowed(_)));
        assert!(matches!(os.dense(), Cow::Owned(_)));
        assert!(od.dense().max_abs_diff(&os.dense()) == 0.0);
        assert!(od.as_dense().is_some() && od.as_csr().is_none());
        assert!(os.as_csr().is_some() && os.as_dense().is_none());
    }

    #[test]
    fn append_rows_all_storage_pairs() {
        let (base_d, base_s) = twin(11, 6, 0.4, 10);
        let (delta_d, delta_s) = twin(4, 6, 0.5, 11);
        let mut want = base_d.dense().into_owned();
        want.append_rows(&delta_d.dense());
        for base in [&base_d, &base_s] {
            for delta in [&delta_d, &delta_s] {
                let mut grown = base.clone();
                grown.append_rows(delta);
                assert_eq!(grown.rows(), 15);
                // Storage kind follows the receiver.
                assert_eq!(grown.is_sparse(), base.is_sparse());
                assert!(grown.dense().max_abs_diff(&want) == 0.0);
            }
        }
    }

    #[test]
    fn operand_ref_conversions() {
        let (od, os) = twin(6, 4, 0.5, 3);
        let m = od.as_dense().unwrap();
        let c = os.as_csr().unwrap();
        // All three &-conversions produce working views.
        let x = vec![1.0, -1.0, 0.5, 2.0];
        let via_matrix = OperandRef::from(m).matvec(&x);
        let via_csr = OperandRef::from(c).matvec(&x);
        let via_operand = OperandRef::from(&od).matvec(&x);
        for i in 0..6 {
            assert!((via_matrix[i] - via_operand[i]).abs() == 0.0);
            assert!((via_matrix[i] - via_csr[i]).abs() < 1e-12);
        }
    }
}
