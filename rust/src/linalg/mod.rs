//! Dense linear-algebra substrate.
//!
//! The paper's experiments were run on NumPy/LAPACK; this repository
//! implements its own dense kernels so the whole stack is self-contained
//! and auditable:
//!
//! * [`matrix`] — row-major [`matrix::Matrix`], blocked GEMM, GEMV, basic
//!   vector ops.
//! * [`cholesky`] — Cholesky factorization + positive-definite solves.
//! * [`qr`] — Householder QR (used by the pCG baseline's preconditioner).
//! * [`svd`] — one-sided Jacobi SVD (singular values for `d_e`, spectra,
//!   and test oracles).
//! * [`triangular`] — forward/back substitution.
//! * [`sparse`] — CSR storage + row-parallel `O(nnz)` kernels (paper
//!   Remark 4.1).
//! * [`operand`] — the [`operand::Operand`] enum (dense | CSR) that every
//!   solver, sketch, and I/O layer consumes, so sparse inputs keep their
//!   `O(nnz)` cost end to end.
//! * [`threads`] — the thread-count knob behind the row-parallel GEMM,
//!   FWHT, CSR and Gram kernels (`@threads=k` solver param,
//!   `PALLAS_THREADS` env var, hardware default).

pub mod cholesky;
pub mod matrix;
pub mod operand;
pub mod sparse;
pub mod qr;
pub mod svd;
pub mod threads;
pub mod triangular;

pub use matrix::Matrix;
pub use operand::{Operand, OperandRef};

/// Euclidean norm of a vector.
pub fn norm2(v: &[f64]) -> f64 {
    dot(v, v).sqrt()
}

/// Dot product (unrolled x4 to let the compiler vectorize).
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for i in 0..chunks {
        let j = 4 * i;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for j in 4 * chunks..n {
        s += a[j] * b[j];
    }
    s
}

/// `y += alpha * x`.
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * xi;
    }
}

/// `v *= alpha`.
pub fn scale(alpha: f64, v: &mut [f64]) {
    for x in v.iter_mut() {
        *x *= alpha;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f64> = (0..13).map(|i| i as f64).collect();
        let b: Vec<f64> = (0..13).map(|i| (i * i) as f64 * 0.5).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-12);
    }

    #[test]
    fn axpy_and_scale() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 24.0, 36.0]);
        scale(0.5, &mut y);
        assert_eq!(y, vec![6.0, 12.0, 18.0]);
    }

    #[test]
    fn norm2_pythagorean() {
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
    }
}
