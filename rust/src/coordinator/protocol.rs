//! Wire protocol: line-delimited JSON over TCP.
//!
//! Requests (one JSON object per line):
//! ```json
//! {"cmd":"solve","profile":"mnist-like","n":1024,"d":128,"nu":1.0,
//!  "solver":"adaptive-srht","eps":1e-8,"seed":7,"threads":8}
//! {"cmd":"status","job":3}
//! {"cmd":"wait","job":3,"timeout_s":60}
//! {"cmd":"result","job":3,"include_x":true}
//! {"cmd":"metrics"}
//! {"cmd":"solvers"}
//! {"cmd":"ping"}
//! {"cmd":"shutdown"}
//! ```
//! Responses: `{"ok":true, ...}` or `{"ok":false,"error":"..."}`.
//!
//! The `"solver"` field of a solve request is a [`SolverSpec`] string
//! (`"cg"`, `"adaptive-srht"`, `"ihs-sparse@m=256"`, ...); `"solvers"`
//! returns the full registry for client-side discovery. An optional
//! `"threads"` field pins the parallel dense kernels for the whole job
//! (equivalent to the `@threads=k` spec param, but also covering the
//! oracle solve).
//!
//! Sparse inputs: `"profile":"sparse"` plus an optional `"density"` field
//! generates a density-controlled CSR workload server-side, and small
//! real problems ship inline as CSR triplets —
//! `{"cmd":"solve","rows":3,"cols":2,"triplets":[[0,0,1.5],...],"b":[...]}`
//! — which bypass the synthetic profile entirely.

use super::job::{JobSpec, Workload};
use crate::linalg::sparse::CsrMatrix;
use crate::linalg::Operand;
use crate::solvers::api::SolverSpec;
use crate::util::json::{self, Json};

/// A decoded client request.
#[derive(Clone, Debug)]
pub enum Request {
    Solve(JobSpec),
    Status { job: u64 },
    Wait { job: u64, timeout_s: f64 },
    Result { job: u64, include_x: bool },
    Metrics,
    Solvers,
    Ping,
    Shutdown,
}

/// Decode one request line.
pub fn decode(line: &str) -> Result<Request, String> {
    let v = json::parse(line.trim()).map_err(|e| e.to_string())?;
    let cmd = v.get("cmd").and_then(Json::as_str).ok_or("missing cmd")?;
    match cmd {
        "solve" => {
            let mut profile = v.get("profile").and_then(Json::as_str).unwrap_or("exp").to_string();
            let n = v.get("n").and_then(Json::as_usize).unwrap_or(1024);
            let d = v.get("d").and_then(Json::as_usize).unwrap_or(128);
            let nu = v.get("nu").and_then(Json::as_f64).unwrap_or(1.0);
            let eps = v.get("eps").and_then(Json::as_f64).unwrap_or(1e-8);
            let seed = v.get("seed").and_then(Json::as_f64).unwrap_or(0.0) as u64;
            let solver_name = v.get("solver").and_then(Json::as_str).unwrap_or("adaptive");
            let solver: SolverSpec = solver_name.parse()?;
            // Optional "density": only meaningful for the sparse profile.
            if let Some(dens) = v.get("density").and_then(Json::as_f64) {
                if profile != "sparse" {
                    return Err(format!(
                        "\"density\" requires \"profile\":\"sparse\" (got {profile:?})"
                    ));
                }
                if !(dens > 0.0 && dens <= 1.0) {
                    return Err(format!("density must be in (0, 1], got {dens}"));
                }
                profile = format!("sparse:{dens}");
            }
            // Optional inline CSR payload: triplets + rows/cols + b.
            let workload = if let Some(trips) = v.get("triplets").and_then(Json::as_arr) {
                decode_triplet_workload(&v, trips)?
            } else {
                Workload::Synthetic { profile, n, d, seed }
            };
            // Optional "nus": [..] turns the job into a warm-started
            // regularization path (Figure-1 workload as a service).
            let path_nus: Vec<f64> = v
                .get("nus")
                .and_then(Json::as_arr)
                .map(|arr| arr.iter().filter_map(Json::as_f64).collect())
                .unwrap_or_default();
            let threads = match v.get("threads").and_then(Json::as_usize) {
                Some(0) => return Err("threads must be >= 1".into()),
                t => t,
            };
            Ok(Request::Solve(JobSpec { workload, nu, solver, eps, seed, path_nus, threads }))
        }
        "status" => Ok(Request::Status { job: require_job(&v)? }),
        "wait" => Ok(Request::Wait {
            job: require_job(&v)?,
            timeout_s: v.get("timeout_s").and_then(Json::as_f64).unwrap_or(120.0),
        }),
        "result" => Ok(Request::Result {
            job: require_job(&v)?,
            include_x: v.get("include_x").and_then(Json::as_bool).unwrap_or(false),
        }),
        "metrics" => Ok(Request::Metrics),
        "solvers" => Ok(Request::Solvers),
        "ping" => Ok(Request::Ping),
        "shutdown" => Ok(Request::Shutdown),
        other => Err(format!("unknown cmd: {other}")),
    }
}

/// Decode an inline CSR workload: `"rows"`, `"cols"`, `"triplets"` (array
/// of `[row, col, value]`) and `"b"` (length `rows`).
fn decode_triplet_workload(v: &Json, trips: &[Json]) -> Result<Workload, String> {
    let rows = v.get("rows").and_then(Json::as_usize).ok_or("triplets need \"rows\"")?;
    let cols = v.get("cols").and_then(Json::as_usize).ok_or("triplets need \"cols\"")?;
    if rows == 0 || cols == 0 {
        return Err("triplet workload needs rows > 0 and cols > 0".into());
    }
    let b_json = v.get("b").and_then(Json::as_arr).ok_or("triplets need \"b\"")?;
    let mut b = Vec::with_capacity(b_json.len());
    for x in b_json {
        let bv = x.as_f64().ok_or("non-numeric entry in \"b\"")?;
        if !bv.is_finite() {
            return Err("non-finite entry in \"b\"".into());
        }
        b.push(bv);
    }
    if b.len() != rows {
        return Err(format!("\"b\" has {} entries, expected rows = {rows}", b.len()));
    }
    let mut triplets = Vec::with_capacity(trips.len());
    for (k, t) in trips.iter().enumerate() {
        let t = t.as_arr().ok_or_else(|| format!("triplet {k} must be [row, col, value]"))?;
        if t.len() != 3 {
            return Err(format!("triplet {k} must have exactly 3 entries"));
        }
        let r = t[0].as_usize().ok_or_else(|| format!("bad row in triplet {k}"))?;
        let c = t[1].as_usize().ok_or_else(|| format!("bad col in triplet {k}"))?;
        let val = t[2].as_f64().ok_or_else(|| format!("bad value in triplet {k}"))?;
        if r >= rows || c >= cols {
            return Err(format!("triplet {k} ({r},{c}) out of bounds for {rows} x {cols}"));
        }
        if !val.is_finite() {
            return Err(format!("triplet {k} has non-finite value"));
        }
        triplets.push((r, c, val));
    }
    let a = Operand::Sparse(CsrMatrix::from_triplets(rows, cols, &triplets));
    Ok(Workload::Inline { a, b })
}

fn require_job(v: &Json) -> Result<u64, String> {
    v.get("job")
        .and_then(Json::as_f64)
        .map(|x| x as u64)
        .ok_or_else(|| "missing job id".to_string())
}

/// Encode a success response.
pub fn ok(mut fields: Vec<(&str, Json)>) -> String {
    fields.insert(0, ("ok", Json::Bool(true)));
    Json::obj(fields).to_string()
}

/// Encode an error response.
pub fn err(message: &str) -> String {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::from(message))]).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_solve_with_defaults() {
        let r = decode(r#"{"cmd":"solve"}"#).unwrap();
        match r {
            Request::Solve(spec) => {
                assert_eq!(spec.nu, 1.0);
                assert!(matches!(spec.workload, Workload::Synthetic { ref profile, .. } if profile == "exp"));
            }
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn decode_full_solve() {
        let line = r#"{"cmd":"solve","profile":"cifar-like","n":2048,"d":256,"nu":0.1,
                       "solver":"adaptive-srht","eps":1e-10,"seed":42}"#;
        match decode(&line.replace('\n', " ")).unwrap() {
            Request::Solve(spec) => {
                assert_eq!(spec.eps, 1e-10);
                assert_eq!(spec.seed, 42);
                assert!(matches!(spec.solver, SolverSpec::Adaptive { .. }));
            }
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn decode_spec_with_params() {
        let r = decode(r#"{"cmd":"solve","solver":"ihs-sparse@m=256"}"#).unwrap();
        match r {
            Request::Solve(spec) => assert_eq!(spec.solver.to_string(), "ihs-sparse@m=256"),
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn decode_threads_field() {
        match decode(r#"{"cmd":"solve","threads":8}"#).unwrap() {
            Request::Solve(spec) => assert_eq!(spec.threads, Some(8)),
            _ => panic!("wrong variant"),
        }
        match decode(r#"{"cmd":"solve"}"#).unwrap() {
            Request::Solve(spec) => assert_eq!(spec.threads, None),
            _ => panic!("wrong variant"),
        }
        assert!(decode(r#"{"cmd":"solve","threads":0}"#).is_err());
        // The spec-level param also survives the wire.
        match decode(r#"{"cmd":"solve","solver":"adaptive-srht@threads=4"}"#).unwrap() {
            Request::Solve(spec) => {
                assert_eq!(spec.solver.to_string(), "adaptive-srht@threads=4")
            }
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn decode_solvers_command() {
        assert!(matches!(decode(r#"{"cmd":"solvers"}"#).unwrap(), Request::Solvers));
    }

    #[test]
    fn decode_sparse_profile_and_density() {
        match decode(r#"{"cmd":"solve","profile":"sparse","density":0.05}"#).unwrap() {
            Request::Solve(spec) => match spec.workload {
                Workload::Synthetic { profile, .. } => assert_eq!(profile, "sparse:0.05"),
                other => panic!("wrong workload {other:?}"),
            },
            _ => panic!("wrong variant"),
        }
        // density without the sparse profile is rejected, as are bad values.
        assert!(decode(r#"{"cmd":"solve","density":0.05}"#).is_err());
        assert!(decode(r#"{"cmd":"solve","profile":"exp","density":0.05}"#).is_err());
        assert!(decode(r#"{"cmd":"solve","profile":"sparse","density":0}"#).is_err());
        assert!(decode(r#"{"cmd":"solve","profile":"sparse","density":1.5}"#).is_err());
    }

    #[test]
    fn decode_inline_triplets() {
        let line = r#"{"cmd":"solve","rows":3,"cols":2,
                       "triplets":[[0,0,1.5],[1,1,-2.0],[2,0,0.5]],
                       "b":[1.0,2.0,3.0],"solver":"cg"}"#;
        match decode(&line.replace('\n', " ")).unwrap() {
            Request::Solve(spec) => match spec.workload {
                Workload::Inline { a, b } => {
                    assert!(a.is_sparse());
                    assert_eq!((a.rows(), a.cols(), a.nnz()), (3, 2, 3));
                    assert_eq!(b, vec![1.0, 2.0, 3.0]);
                }
                other => panic!("wrong workload {other:?}"),
            },
            _ => panic!("wrong variant"),
        }
        // Malformed payloads are rejected with specific errors.
        assert!(decode(r#"{"cmd":"solve","triplets":[[0,0,1.0]],"b":[1.0]}"#).is_err(), "no rows");
        assert!(
            decode(r#"{"cmd":"solve","rows":2,"cols":2,"triplets":[[5,0,1.0]],"b":[1.0,1.0]}"#)
                .is_err(),
            "out of bounds"
        );
        assert!(
            decode(r#"{"cmd":"solve","rows":2,"cols":2,"triplets":[[0,0,1.0]],"b":[1.0]}"#)
                .is_err(),
            "b length"
        );
        assert!(
            decode(r#"{"cmd":"solve","rows":2,"cols":2,"triplets":[[0,0]],"b":[1.0,1.0]}"#)
                .is_err(),
            "triplet arity"
        );
    }

    #[test]
    fn decode_path_solve() {
        let r = decode(r#"{"cmd":"solve","profile":"exp","nus":[10,1,0.1]}"#).unwrap();
        match r {
            Request::Solve(spec) => assert_eq!(spec.path_nus, vec![10.0, 1.0, 0.1]),
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn decode_control_commands() {
        assert!(matches!(decode(r#"{"cmd":"ping"}"#).unwrap(), Request::Ping));
        assert!(matches!(decode(r#"{"cmd":"metrics"}"#).unwrap(), Request::Metrics));
        assert!(matches!(decode(r#"{"cmd":"shutdown"}"#).unwrap(), Request::Shutdown));
        assert!(matches!(
            decode(r#"{"cmd":"wait","job":3,"timeout_s":5}"#).unwrap(),
            Request::Wait { job: 3, .. }
        ));
    }

    #[test]
    fn decode_errors() {
        assert!(decode("not json").is_err());
        assert!(decode(r#"{"cmd":"status"}"#).is_err(), "missing job id");
        assert!(decode(r#"{"cmd":"explode"}"#).is_err());
        assert!(decode(r#"{"cmd":"solve","solver":"bogus"}"#).is_err());
    }

    #[test]
    fn response_encoding() {
        let line = ok(vec![("job", Json::from(3usize))]);
        let v = json::parse(&line).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("job").unwrap().as_usize(), Some(3));
        let e = err("boom");
        let v = json::parse(&e).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(v.get("error").unwrap().as_str(), Some("boom"));
    }
}
